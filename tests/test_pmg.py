"""p-multigrid: interpolation operators, V-cycle PCG, sharded parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import build_problem, cg_assembled, poisson_assembled
from repro.core.operator import coarsen_problem
from repro.core.precond import (
    make_pmg_preconditioner,
    make_preconditioner,
    make_transfer_pair,
    pmg_degree_ladder,
)
from repro.core.sem import gll_nodes_weights, interpolation_matrix


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(4, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


def test_degree_ladder():
    assert pmg_degree_ladder(7) == (7, 4, 2, 1)
    assert pmg_degree_ladder(15) == (15, 8, 4, 2, 1)
    assert pmg_degree_ladder(2) == (2, 1)
    with pytest.raises(ValueError):
        pmg_degree_ladder(1)


@pytest.mark.parametrize("nc,nf", [(1, 3), (2, 3), (4, 7), (2, 4)])
def test_interpolation_matrix_exact_on_polynomials(nc, nf):
    """Prolongation reproduces polynomials up to the coarse degree exactly."""
    j = interpolation_matrix(nc, nf)
    xc, _ = gll_nodes_weights(nc)
    xf, _ = gll_nodes_weights(nf)
    for p in range(nc + 1):
        np.testing.assert_allclose(j @ xc**p, xf**p, atol=1e-12)
    # round trip: sampling the embedded polynomial back at the coarse
    # nodes is the identity
    np.testing.assert_allclose(
        interpolation_matrix(nf, nc) @ j, np.eye(nc + 1), atol=1e-12
    )


def test_restriction_is_transpose_of_prolongation(prob64):
    """R == P^T exactly (the PCG-symmetry requirement), on a deformed mesh."""
    prob_c = coarsen_problem(prob64, 2)
    prolong, restrict = make_transfer_pair(prob64, prob_c)
    pmat = np.array(
        jax.vmap(prolong, in_axes=1, out_axes=1)(jnp.eye(prob_c.n_global))
    )
    rmat = np.array(
        jax.vmap(restrict, in_axes=1, out_axes=1)(jnp.eye(prob64.n_global))
    )
    np.testing.assert_array_equal(rmat, pmat.T)


def test_prolongation_reproduces_global_polynomials():
    """On an affine mesh a global polynomial of the coarse degree lives in
    both SEM spaces; prolongating its coarse nodal values must reproduce its
    fine nodal values exactly."""
    jax.config.update("jax_enable_x64", True)
    nf, nc = 5, 2
    fine = build_problem(nf, (2, 2, 2), lam=1.0, dtype=jnp.float64)
    coarse = coarsen_problem(fine, nc)
    prolong, _ = make_transfer_pair(fine, coarse)

    def f(c):  # tensor-degree <= nc per axis
        x, y, z = c[..., 0], c[..., 1], c[..., 2]
        return 1.0 + x**2 - 2.0 * y * z + 3.0 * x * y**2 * z**2

    def global_vals(prob):
        vals = np.zeros(prob.n_global)
        vals[prob.mesh.l2g.reshape(-1)] = f(prob.mesh.coords).reshape(-1)
        return vals

    got = np.array(prolong(jnp.asarray(global_vals(coarse))))
    np.testing.assert_allclose(got, global_vals(fine), atol=1e-12)


def test_coarsen_regular_matches_direct_build(prob64):
    """Rediscretized coarse factors == direct build at the coarse degree
    (regular mesh, where both constructions are exact)."""
    fine = build_problem(5, (2, 3, 2), lam=0.3, dtype=jnp.float64)
    got = coarsen_problem(fine, 3)
    want = build_problem(3, (2, 3, 2), lam=0.3, dtype=jnp.float64)
    np.testing.assert_allclose(np.array(got.g), np.array(want.g), atol=1e-12)
    np.testing.assert_allclose(np.array(got.jw), np.array(want.jw), atol=1e-12)
    assert np.array_equal(np.array(got.l2g), np.array(want.l2g))


def test_pmg_apply_is_symmetric_linear(prob64):
    """The V-cycle must be a symmetric linear map for PCG validity."""
    a = poisson_assembled(prob64)
    pc, info = make_pmg_preconditioner(prob64, a, smooth_degree=2)
    assert info.levels == (4, 2, 1)
    mmat = np.array(
        jax.vmap(pc, in_axes=1, out_axes=1)(jnp.eye(prob64.n_global))
    )
    np.testing.assert_allclose(mmat, mmat.T, atol=1e-12)
    ev = np.linalg.eigvalsh(0.5 * (mmat + mmat.T))
    assert ev.min() > 0, "V-cycle preconditioner must be positive definite"


@pytest.mark.parametrize("coarse_solve", ["direct", "chebyshev", "jacobi"])
def test_pmg_coarse_solve_variants_converge(prob64, coarse_solve):
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    pc, _ = make_pmg_preconditioner(prob64, a, coarse_solve=coarse_solve)
    res = cg_assembled(a, b, n_iter=300, tol=1e-10, precond=pc)
    assert int(res.iterations) < 300
    rel = np.linalg.norm(np.array(a(res.x) - b)) / np.linalg.norm(np.array(b))
    assert rel < 1e-8


def test_pmg_fewer_iterations_than_chebyshev(prob64):
    """ISSUE satellite: V-cycle PCG beats Chebyshev–Jacobi on a deformed
    mesh (and both converge to the plain-CG solution)."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))

    x_plain = cg_assembled(a, b, n_iter=500, tol=1e-12).x
    iters = {}
    for kind in ("chebyshev", "pmg"):
        pc, _ = make_preconditioner(kind, prob64, a)
        res = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc)
        assert int(res.iterations) < 500
        np.testing.assert_allclose(
            np.array(res.x), np.array(x_plain), atol=1e-6
        )
        iters[kind] = int(res.iterations)
    assert iters["pmg"] < iters["chebyshev"], iters


def test_pmg_halves_chebyshev_on_n7_tier():
    """ISSUE acceptance: on the N=7, lam=1.0 benchmark tier pmg reaches
    tol=1e-8 in <= half the CG iterations of chebyshev."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(7, (4, 4, 4), lam=1.0, deform=0.15, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global))
    iters = {}
    for kind in ("chebyshev", "pmg"):
        pc, _ = make_preconditioner(kind, prob, a, degree=2)
        res = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc)
        assert int(res.iterations) < 500
        iters[kind] = int(res.iterations)
    assert 2 * iters["pmg"] <= iters["chebyshev"], iters


def test_distributed_pmg_matches_single_shard():
    """ISSUE acceptance: dist_cg(precond="pmg") matches the single-shard
    solution to fp32 tolerance on an 8-virtual-device mesh."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10, precond="pmg"))
x_boxes, rdotr, iters, status, hist = run()
assert int(iters) < 200, int(iters)
pc, _ = make_preconditioner("pmg", ref, A)
res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc)
err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
# fp32 tolerance on the solution (both paths converge to the same x)
assert err < 1e-6, err
print("OK", int(iters))
"""
    )


def test_distributed_pmg_on_deformed_coords():
    """Sharded pmg on a deformed global mesh (coords path): beats plain CG
    and chebyshev in iterations-to-tolerance."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_box_mesh, geometric_factors
from repro.core.mesh import partition_elements

N = 3
grid = ProcessGrid((2, 2, 2)); local = (1, 1, 1)
mesh_g = build_box_mesh(N, (2, 2, 2), deform=0.2)
owner = partition_elements((2, 2, 2), grid.shape)
coords = np.stack([mesh_g.coords[owner == r] for r in range(8)])
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64,
                          coords=coords)
# coords path reproduces the factors of the global deformed mesh
geo = geometric_factors(mesh_g)["G"]
gf = np.stack([geo[owner == r] for r in range(8)])
assert np.abs(np.array(prob.g) - gf).max() < 1e-12
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((8, prob.m3)))
it = {}
for kind in ("none", "chebyshev", "pmg"):
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=300, tol=1e-8, precond=kind))
    x, rdotr, iters, status, hist = run()
    assert int(iters) < 300, (kind, int(iters))
    it[kind] = int(iters)
assert it["pmg"] < it["chebyshev"] < it["none"], it
print("OK", it)
"""
    )


def test_dist_pmg_without_geometry_raises():
    """Custom g_factors without coords cannot be p-coarsened."""
    import jax.numpy as jnp

    from repro.comms.topology import ProcessGrid
    from repro.core.distributed import build_dist_problem, build_pmg_levels

    grid = ProcessGrid((1, 1, 1))
    prob = build_dist_problem(2, grid, (2, 2, 2), dtype=jnp.float64)
    g = np.array(prob.g)
    prob_custom = build_dist_problem(
        2, grid, (2, 2, 2), dtype=jnp.float64, g_factors=g
    )
    with pytest.raises(ValueError, match="coords"):
        build_pmg_levels(prob_custom)
    # regular default and explicit-coords problems both build fine
    levels, jmats = build_pmg_levels(prob)
    assert [lvl.n_degree for lvl in levels] == [2, 1]
    assert len(jmats) == 1
