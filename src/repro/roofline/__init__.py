"""repro.roofline — compiled-artifact analysis: loop-aware HLO accounting."""
from .analysis import CollectiveStats, parse_collectives, roofline_report
from .bench import dryrun_roofline
from .hlo_model import HloStats, analyze_hlo

__all__ = [
    "CollectiveStats",
    "HloStats",
    "analyze_hlo",
    "dryrun_roofline",
    "parse_collectives",
    "roofline_report",
]
