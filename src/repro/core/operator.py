"""The screened Poisson operator A = S + λI in both storage modes.

hipBone (assembled) mode — paper's central contribution:
    y_L = (S_L + λW) Z x_G        (single fused kernel)
    A x_G = Z^T y_L               (gather; all MPI lives here + halo)

NekBone (scattered) baseline mode:
    b_L = (Z Z^T S_L + λ I) x_L   (combined gather-scatter after local op)

The element-local stiffness is the tensor-product SEM Laplacian
    S_L^e = D^T G^e D
with D the 3-D gradient stack of the 1-D derivative matrix. This module is
the pure-jnp reference implementation; ``repro.kernels`` provides the
Pallas TPU kernel with identical semantics (validated against this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import coefficients as _coef
from . import geometry, sem
from .gather_scatter import gather, gather_scatter, inverse_degree, scatter
from .mesh import BoxMesh, build_box_mesh, dirichlet_mask, normalize_bc

__all__ = [
    "local_poisson",
    "local_operator_columns",
    "PoissonProblem",
    "build_problem",
    "problem_from_mesh",
    "coarsen_problem",
    "cast_problem",
    "poisson_assembled",
    "poisson_scattered",
    "screen_stream",
]

# positivity floor applied when coefficient fields are resampled to a
# coarser degree: polynomial interpolation of rough (random) fields can
# overshoot below zero, which would break the SPD-ness every V-cycle level
# relies on.  A fixed constant (not data-dependent) so the single-device
# and sharded coarsening paths produce identical values rank by rank.
COARSE_K_FLOOR = 1e-6


def local_poisson(
    u: jax.Array,
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    jw: jax.Array | None = None,
) -> jax.Array:
    """Element-local screened Poisson action  (S_L + λ M) u  (pure jnp).

    Args:
      u:  (E, p) element-local field, p = (N+1)^3, node order (t, s, r).
      g:  (E, 6, p) packed geometric factors [rr, rs, rt, ss, st, tt].
      d:  (N+1, N+1) 1-D derivative matrix.
      lam: screen parameter λ.
      w:  (E, p) inverse-degree weights for the hipBone fused form
          (λW screen on assembled DOFs), or None for plain λI (NekBone
          scattered form applies λ to x_L directly).
      jw: (E, p) mass diagonal J*w_q. When given, the screen term is
          λ·(JW∘W)·u (resp. λ·JW·u) — the proper SEM mass-weighted screen.
          NekBone uses the unweighted algebraic screen λI; pass None to
          match NekBone exactly (benchmarks do).

    Returns:
      (E, p) result.
    """
    e, p = u.shape
    n1 = d.shape[0]
    u3 = u.reshape(e, n1, n1, n1)  # (E, t, s, r)

    # Gradient: three batched contractions — these hit the MXU.
    ur = jnp.einsum("ia,etsa->etsi", d, u3)
    us = jnp.einsum("jb,etbr->etjr", d, u3)
    ut = jnp.einsum("kc,ecsr->eksr", d, u3)

    g3 = g.reshape(e, 6, n1, n1, n1)
    wr = g3[:, 0] * ur + g3[:, 1] * us + g3[:, 2] * ut
    ws = g3[:, 1] * ur + g3[:, 3] * us + g3[:, 4] * ut
    wt = g3[:, 2] * ur + g3[:, 4] * us + g3[:, 5] * ut

    # Divergence: transposed contractions.
    out = (
        jnp.einsum("ia,etsi->etsa", d, wr)
        + jnp.einsum("jb,etjr->etbr", d, ws)
        + jnp.einsum("kc,eksr->ecsr", d, wt)
    ).reshape(e, p)

    screen = u if jw is None else jw * u
    if w is not None:
        screen = w * screen
    return out + lam * screen


def local_operator_columns(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    cols: jax.Array,
) -> jax.Array:
    """Element-local operator applied to a stack of shared probe columns.

    Each column of ``cols`` is broadcast to every element and pushed through
    :func:`local_poisson`, so the result materializes the element-local
    operator restricted to the probed subspace — the workhorse of
    :mod:`core.galerkin`'s setup-time block assembly, where ``cols`` holds
    the lifted coarse basis Ĵ.  Columns are swept sequentially
    (``lax.map``): setup-time memory stays one element-local field per
    probe instead of a (k × E × p) temporary blow-up.

    Args:
      g / d / lam / w: as in :func:`local_poisson`.
      cols: (p, k) probe columns, p = (N+1)³.

    Returns:
      (E, p, k) with ``out[e, :, k] = (S_L^e + λ·screen_e) cols[:, k]``.
    """
    e = g.shape[0]

    def apply_col(c: jax.Array) -> jax.Array:
        return local_poisson(jnp.broadcast_to(c, (e, c.shape[0])), g, d, lam, w)

    return jnp.moveaxis(jax.lax.map(apply_col, cols.T), 0, 2)


@dataclasses.dataclass(frozen=True)
class PoissonProblem:
    """A ready-to-run screened Poisson problem (single shard).

    All arrays are jnp in the runtime dtype; setup metadata stays numpy.
    """

    mesh: BoxMesh
    lam: float
    d: jax.Array            # (N+1, N+1)
    g: jax.Array            # (E, 6, p) — k(x) already folded in when set
    jw: jax.Array           # (E, p) mass diagonal
    l2g: jax.Array          # (E, p) int32
    w_local: jax.Array      # (E, p) inverse degree (scattered layout)
    w_global: jax.Array     # (N_G,) inverse degree (assembled layout)
    dtype: Any
    # variable-coefficient / boundary-condition extension — all None for
    # the legacy constant-λ screened Poisson (bit-identical code paths):
    k: jax.Array | None = None          # (E, p) diffusion field (unfolded copy)
    lam_field: jax.Array | None = None  # (E, p) screen field λ(x)
    mask: jax.Array | None = None       # (N_G,) 0 on Dirichlet DOFs
    bc: tuple | None = None             # 6-face tags (mesh.BC_FACES order)

    @property
    def n_global(self) -> int:
        return self.mesh.n_global

    @property
    def n_local(self) -> int:
        return self.mesh.n_local


def screen_stream(
    prob: PoissonProblem,
) -> tuple[jax.Array | None, float]:
    """The (w, lam) pair every element kernel consumes for the screen term.

    Classic mode (``lam_field is None``): ``(w_local, λ)`` — the algebraic
    λ·W screen that assembles to exactly λI (hipBone/NekBone semantics;
    bit-identical to pre-coefficient builds).

    PDE mode (``lam_field`` set): ``(JW·λ_field, 1.0)`` — the mass-weighted
    weak screen Zᵀ diag(JW·λ) Z.  No inverse-degree factor enters: the
    element-wise assembly sum IS the quadrature sum.  ``lam`` stays a
    static python float either way, which is what lets the variable screen
    ride the existing ``w`` stream through kernels whose ``lam`` is a
    static argname (``kernels.poisson`` / ``kernels.poisson_fused``).
    """
    if prob.lam_field is None:
        return prob.w_local, prob.lam
    return prob.jw * prob.lam_field, 1.0


def _eval_field(spec, coords: np.ndarray) -> np.ndarray | None:
    """Evaluate a coefficient spec on the mesh's (E, p, 3) node array.

    ``spec`` may be None, a scalar, a callable f(x, y, z) -> (E, p), or a
    ready (E, p) array.
    """
    if spec is None:
        return None
    if callable(spec):
        out = spec(coords[..., 0], coords[..., 1], coords[..., 2])
        return np.broadcast_to(np.asarray(out), coords.shape[:2])
    arr = np.asarray(spec)
    if arr.ndim == 0:
        return np.full(coords.shape[:2], float(arr))
    if arr.shape != coords.shape[:2]:
        raise ValueError(
            f"coefficient field shape {arr.shape} != (E, p) {coords.shape[:2]}"
        )
    return arr


def build_problem(
    n_degree: int,
    shape: tuple[int, int, int],
    *,
    lam: float = 1.0,
    deform: float = 0.0,
    dtype: Any = jnp.float32,
    coefficient: str | None = None,
    bc: Any = None,
) -> PoissonProblem:
    """Construct mesh, geometric factors and gather-scatter data.

    ``coefficient`` selects a named family from ``core.coefficients``
    (``"const"``/None keeps the legacy constant-λ screen bit-identical;
    ``"smooth"``/``"checker"`` switch to A = -∇·(k∇) + λ with the weak
    mass-weighted screen).  ``bc`` is a boundary-condition spec accepted
    by ``mesh.normalize_bc`` (None = legacy, no essential BCs).
    """
    m = build_box_mesh(n_degree, shape, deform=deform)
    k, lam_field = _coef.coefficient_fields(coefficient, m.coords, lam)
    return problem_from_mesh(
        m, lam=lam, dtype=dtype, k=k, lam_field=lam_field, bc=bc
    )


def problem_from_mesh(
    m: BoxMesh,
    *,
    lam: float = 1.0,
    dtype: Any = jnp.float32,
    k: Any = None,
    lam_field: Any = None,
    bc: Any = None,
) -> PoissonProblem:
    """Geometric factors + gather-scatter data for an existing mesh.

    ``k`` / ``lam_field`` accept None, a scalar, an (E, p) array, or a
    callable f(x, y, z) evaluated on the mesh nodes.  ``k`` is folded into
    the packed geometric factors here — every downstream consumer (local
    kernels, diagonals, Galerkin probes, Schwarz means, sharded boxes)
    sees variable diffusion through the ``g`` stream it already reads.
    """
    geo = geometry.geometric_factors(m)
    d = sem.derivative_matrix(m.n_degree)
    w_g = inverse_degree(m.l2g, m.n_global)
    w_l = w_g[m.l2g]
    g = np.asarray(geo["G"])
    k_arr = _eval_field(k, m.coords)
    lam_arr = _eval_field(lam_field, m.coords)
    if k_arr is not None:
        g = g * k_arr[:, None, :]
    tags = normalize_bc(bc)
    mask = None if tags is None else dirichlet_mask(m, tags)
    return PoissonProblem(
        mesh=m,
        lam=float(lam),
        d=jnp.asarray(d, dtype=dtype),
        g=jnp.asarray(g, dtype=dtype),
        jw=jnp.asarray(geo["JW"], dtype=dtype),
        l2g=jnp.asarray(m.l2g),
        w_local=jnp.asarray(w_l, dtype=dtype),
        w_global=jnp.asarray(w_g, dtype=dtype),
        dtype=dtype,
        k=None if k_arr is None else jnp.asarray(k_arr, dtype=dtype),
        lam_field=(
            None if lam_arr is None else jnp.asarray(lam_arr, dtype=dtype)
        ),
        mask=None if mask is None else jnp.asarray(mask, dtype=dtype),
        bc=tags,
    )


def coarsen_problem(prob: PoissonProblem, n_coarse: int) -> PoissonProblem:
    """p-coarsened problem: same element grid, polynomial degree ``n_coarse``.

    The coarse level is a *rediscretization*, not a Galerkin triple product:
    element connectivity comes from a degree-``n_coarse`` box mesh, node
    coordinates are the fine (polynomial) coordinate map sampled at the
    coarse GLL nodes — exact, so the coarse operator lives on the same
    curved geometry — and geometric factors are recomputed at the coarse
    degree.  This is the standard SEM p-multigrid coarse operator
    (Nek5000/RS, libParanumal).
    """
    mf = prob.mesh
    nc = int(n_coarse)
    if not 1 <= nc < mf.n_degree:
        raise ValueError(
            f"coarse degree must be in [1, {mf.n_degree - 1}], got {nc}"
        )
    base = build_box_mesh(nc, mf.shape)  # connectivity only; coords replaced
    j = sem.interpolation_matrix(mf.n_degree, nc)
    coords = sem.interp_coords_3d(j, mf.coords)
    mesh_c = dataclasses.replace(base, coords=coords)
    # coefficient fields ride to the coarse level by the same tensor
    # interpolation as the coordinates (exact on the per-element-constant
    # checker family, spectrally accurate on smooth ones); k keeps a fixed
    # positivity floor so every rediscretized level stays SPD, and the
    # Dirichlet mask is recomputed from the bc tags on the coarse grid.
    k_c = lam_c = None
    if prob.k is not None:
        k_c = np.maximum(
            sem.interp_field_3d(j, np.asarray(prob.k, np.float64)),
            COARSE_K_FLOOR,
        )
    if prob.lam_field is not None:
        lam_c = np.maximum(
            sem.interp_field_3d(j, np.asarray(prob.lam_field, np.float64)),
            0.0,
        )
    return problem_from_mesh(
        mesh_c, lam=prob.lam, dtype=prob.dtype, k=k_c, lam_field=lam_c,
        bc=prob.bc,
    )


def cast_problem(prob: PoissonProblem, dtype: Any) -> PoissonProblem:
    """The same problem with every runtime array cast to ``dtype``.

    The mixed-precision hook: ``make_preconditioner(precond_dtype=...)``
    builds its whole operator/diagonal/transfer chain from the cast copy, so
    every preconditioner byte (HBM streams and, sharded, wire payloads) is
    in the narrow dtype while the outer PCG keeps the original problem.
    Setup metadata (mesh, l2g) is shared, not copied.
    """
    cast = lambda a: None if a is None else a.astype(dtype)
    return dataclasses.replace(
        prob,
        d=prob.d.astype(dtype),
        g=prob.g.astype(dtype),
        jw=prob.jw.astype(dtype),
        w_local=prob.w_local.astype(dtype),
        w_global=prob.w_global.astype(dtype),
        dtype=dtype,
        k=cast(prob.k),
        lam_field=cast(prob.lam_field),
        mask=cast(prob.mask),
    )


def poisson_assembled(
    prob: PoissonProblem,
    local_op: Callable[..., jax.Array] | None = None,
    *,
    fused: bool | None = None,
    fused_kwargs: dict | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """hipBone operator: x_G (N_G,) -> A x_G (N_G,).

    Split form (default off-TPU): y_L = (S_L + λW) Z x_G, then the gather
    Z^T y_L — three XLA ops.  ``local_op`` lets callers swap in the Pallas
    element kernel for the middle stage; default is the pure-jnp reference.

    ``fused`` selects the single-kernel form instead
    (``kernels.ops.poisson_assembled_fused``): gather, local operator and
    scatter-add in one Pallas pass, no x_L/y_L HBM round-trips.  ``None``
    defers to ``kernels.ops.should_fuse_operator`` (native-Pallas backend +
    VMEM fit; ``HIPBONE_FUSED=0/1`` forces it off/on) — except when a
    custom ``local_op`` is given, which pins the split pipeline that uses
    it.  ``fused_kwargs`` passes ``block_e`` / ``interpret`` /
    ``gather_mode`` through to the fused wrapper.
    """
    if fused is None:
        if local_op is not None:
            fused = False
        else:
            from ..kernels import ops as _kops  # lazy: kernels import core

            fused = _kops.should_fuse_operator(
                prob.dtype,
                n_degree=prob.mesh.n_degree,
                n_global=prob.n_global,
            )
    if fused:
        if local_op is not None:
            raise ValueError(
                "poisson_assembled: fused=True replaces the whole "
                "scatter/local_op/gather pipeline; drop local_op"
            )
        from ..kernels import ops as _kops  # lazy: kernels import core

        return _kops.make_poisson_assembled_fused(prob, **(fused_kwargs or {}))

    op = local_op or local_poisson
    w_eff, lam_eff = screen_stream(prob)
    mask = prob.mask

    def apply(x_g: jax.Array) -> jax.Array:
        if mask is not None:
            x_g = mask * x_g
        x_l = scatter(x_g, prob.l2g)
        y_l = op(x_l, prob.g, prob.d, lam_eff, w_eff)
        y_g = gather(y_l, prob.l2g, prob.n_global)
        return y_g if mask is None else mask * y_g

    apply.fused = False
    return apply


def poisson_scattered(
    prob: PoissonProblem,
    local_op: Callable[..., jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """NekBone baseline operator: x_L (E, p) -> b_L = (ZZ^T S_L + λI) x_L.

    The scattered baseline keeps NekBone's algebraic λI screen; variable k
    arrives for free through the folded ``g``, but a λ(x) field or
    Dirichlet mask has no scattered-storage analogue here — the assembled
    path (:func:`poisson_assembled`) is the variable-coefficient surface.
    """
    if prob.lam_field is not None or prob.mask is not None:
        raise NotImplementedError(
            "poisson_scattered is the constant-λ NekBone baseline; "
            "λ(x) fields / Dirichlet masks need the assembled operator "
            "(poisson_assembled)"
        )
    op = local_op or local_poisson

    def apply(x_l: jax.Array) -> jax.Array:
        s_l = op(x_l, prob.g, prob.d, 0.0, None)  # S_L x_L only
        return gather_scatter(s_l, prob.l2g, prob.n_global) + prob.lam * x_l

    return apply
