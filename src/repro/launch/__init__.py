"""repro.launch — production meshes and drivers (dryrun/train/serve)."""
from .mesh import axis_sizes, flat_mesh, make_production_mesh

__all__ = ["axis_sizes", "flat_mesh", "make_production_mesh"]
