"""Structured 3-D halo communication for the distributed Poisson operator.

Each rank owns a padded DOF box of shape (mx, my, mz); interface points are
replicated on every sharing rank. Two primitives, both built from static
``lax.ppermute`` face shifts (2 per partitioned dimension):

  * ``sum_exchange``  — assemble partial sums at interface points AND leave
    every replica holding the summed value (the gather Z^T fused with the
    scatter-side refresh; see DESIGN.md: the padded-consistent storage
    merges hipBone's two communication phases into one).
  * ``copy_exchange`` — refresh replicas from the canonical owner only
    (used by the paper-faithful two-phase mode and by tests).

Sequential dimension sweeps propagate edge/corner contributions without
explicit 26-neighbor messages — the structured-grid trick NekBone's
gslib setup discovers generically.

All functions run inside shard_map over ``axis_name`` whose size equals
``grid.size``. Boxes are passed as 3-D arrays indexed [z, y, x]
(x fastest in the flat layout).

**Wire precision.**  Payload dtype follows the box dtype, so the
mixed-precision preconditioner path (fp32 boxes inside an fp64 PCG) halves
its wire bytes with no code here.  Every primitive additionally accepts
``wire_dtype``: faces/shells are rounded to that dtype just before the
``ppermute`` and widened back on receipt — fp32 wires under fp64 boxes for
payload-bound exchanges where the *accumulation* must stay wide.  Summed
exchanges still accumulate in the box dtype; only the transported slab is
narrowed, and any interface value that travels is rounded on the owning
rank as well, so owner and replicas keep holding the same value (the
consistency contract of the padded box survives the narrow wire).
"""
from __future__ import annotations

import contextlib
import itertools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from .topology import ProcessGrid

__all__ = [
    "sum_exchange",
    "copy_exchange",
    "expand_exchange",
    "contract_exchange",
    "rank_coords",
    "wire_transform",
    "SUM_ROUTINGS",
    "PAIR_ROUTINGS",
]

# Routing menus per exchange kind.  ``sum_exchange`` has three candidates
# (the per-dim face sweep, the staged bidirectional route, the fused
# one-round route); the copy/expand/contract shells have no staged
# variant distinct from the sweep, so their menu is two-wide and
# ``comms.plan.resolve_routing`` falls "crystal" back to "face_sweep".
# At the box dtype's native wire every routing reproduces the face
# sweep's IEEE reduction tree bit-for-bit — routing is a performance
# knob, never a semantics change (see the per-routing docstrings).
SUM_ROUTINGS = ("face_sweep", "crystal", "fused")
PAIR_ROUTINGS = ("face_sweep", "fused")

# Fault-injection seam: when set, every outgoing payload slab of every
# exchange primitive passes through the hook as ``fn(slab, axis_name)``
# just before its ppermute.  The hook is read at *trace* time, so it must
# be installed before the solve is first compiled (repro.testing.faults
# builds rank-targeted corruptors on top via lax.axis_index).  Production
# code never sets this; the default is a straight pass-through.
_WIRE_HOOK: Callable[[jax.Array, str], jax.Array] | None = None


@contextlib.contextmanager
def wire_transform(fn: Callable[[jax.Array, str], jax.Array]):
    """Temporarily install a wire-payload hook (fault-injection seam)."""
    global _WIRE_HOOK
    prev = _WIRE_HOOK
    _WIRE_HOOK = fn
    try:
        yield
    finally:
        _WIRE_HOOK = prev


def _wire_permute(
    val: jax.Array, axis_name: str, perm, wire_dtype: Any | None
) -> jax.Array:
    """ppermute with an optional cast-on-the-wire of the payload slab."""
    if _WIRE_HOOK is not None:
        val = _WIRE_HOOK(val, axis_name)
    if wire_dtype is None or jnp.dtype(wire_dtype) == val.dtype:
        return lax.ppermute(val, axis_name, perm)
    return lax.ppermute(
        val.astype(wire_dtype), axis_name, perm
    ).astype(val.dtype)


def _wire_round(val: jax.Array, wire_dtype: Any | None) -> jax.Array:
    """Round a slab to the wire dtype in place (idempotent).

    The replica-consistency guard for narrowed wires: any value that
    travels MUST also be rounded on the rank that keeps a copy of it,
    otherwise the owner would hold the exact value while every replica
    holds the rounded one and the same global DOF would differ by rank.
    """
    if wire_dtype is None or jnp.dtype(wire_dtype) == val.dtype:
        return val
    return val.astype(wire_dtype).astype(val.dtype)


def rank_coords(grid: ProcessGrid, axis_name: str) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Traced (i, j, k) coordinates of this rank in the process grid."""
    r = lax.axis_index(axis_name)
    px, py, _ = grid.shape
    return r % px, (r // px) % py, r // (px * py)


# Boxes are stored flat with x fastest (flat = x + mx*(y + my*z)), so the
# reshaped 3-D array is indexed [z, y, x]: spatial dim d lives on array
# axis (2 - d).


def _axis(dim: int) -> int:
    return 2 - dim


def _face(box: jax.Array, dim: int, idx: int) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box[tuple(sl)]


def _set_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].set(val)


def _add_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].add(val)


# Multi-dimension slab slicing for the fused routings: ``spec`` maps a
# spatial dim to an index interval [lo, hi); unspecified dims span fully.


def _slab_sl(spec: dict[int, tuple[int, int]]) -> tuple:
    sl = [slice(None)] * 3
    for dim, (lo, hi) in spec.items():
        sl[_axis(dim)] = slice(lo, hi)
    return tuple(sl)


def _slab(box: jax.Array, spec: dict[int, tuple[int, int]]) -> jax.Array:
    return box[_slab_sl(spec)]


def _pdims(grid: ProcessGrid) -> list[int]:
    return [d for d in range(3) if grid.shape[d] > 1]


def _subsets(dims: list[int]) -> list[tuple[int, ...]]:
    """Nonempty subsets of the partitioned dims, singletons first."""
    out: list[tuple[int, ...]] = []
    for r in range(1, len(dims) + 1):
        out.extend(itertools.combinations(dims, r))
    return out


def sum_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None = None,
    routing: str = "face_sweep",
) -> jax.Array:
    """Assemble interface partial sums; all replicas end up consistent.

    ``routing`` selects the message pattern, never the result: at the box
    dtype's native wire all three routings replicate the same IEEE
    reduction tree bit-for-bit (a narrowed ``wire_dtype`` moves the
    rounding points, so routings then agree to rounding error while each
    staying replica-consistent).

      * ``"face_sweep"`` — per partitioned dim: (1) low faces shift down
        and accumulate into the -neighbor's high face (the canonical
        interface slab); (2) the summed high face shifts back up into the
        +neighbor's low face.  6 dependent message rounds, minimal bytes.
      * ``"crystal"`` — staged bidirectional route: per dim ONE round with
        both directions in flight; each side adds own + received
        (commutative IEEE addition makes both sides bitwise equal, so no
        copy-back phase is needed).  3 dependent rounds, same bytes.
      * ``"fused"`` — all dims at once: a gather round shipping every
        face/edge/corner low slab to its diagonal owner, a masked
        broadcast round shipping summed high slabs back.  2 dependent
        rounds, slightly more bytes (edge/corner slabs), up to 7
        concurrent messages per round.

    Boundary ranks receive ppermute zero-fill and are masked.
    ``wire_dtype`` narrows the transported slabs only (sums stay in the
    box dtype); every interface value that travels is rounded on the
    owner too, so all copies of a DOF hold the *same* rounded sum — the
    consistency contract survives the narrow wire.
    """
    if routing == "crystal":
        return _sum_crystal(box, grid, axis_name, wire_dtype)
    if routing == "fused":
        return _sum_fused(box, grid, axis_name, wire_dtype)
    if routing != "face_sweep":
        raise ValueError(f"unknown sum_exchange routing: {routing!r}")
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        # (1) low face -> -neighbor high face (sum)
        low = _face(box, dim, 0)
        recv = _wire_permute(low, axis_name, grid.shift_perm(dim, -1), wire_dtype)
        box = _add_face(box, dim, m - 1, recv)
        # (2) summed high face -> +neighbor low face (copy); the owner
        # keeps the same rounded value it ships (replica consistency)
        hi = _wire_round(_face(box, dim, m - 1), wire_dtype)
        box = _set_face(box, dim, m - 1, hi)
        recv = _wire_permute(hi, axis_name, grid.shift_perm(dim, +1), wire_dtype)
        keep = _face(box, dim, 0)
        new_low = jnp.where(c > 0, recv, keep)
        box = _set_face(box, dim, 0, new_low)
    return box


def _sum_crystal(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None,
) -> jax.Array:
    """Staged bidirectional sum_exchange: one round per partitioned dim.

    Both faces travel concurrently and each side of an interface computes
    own + received itself, so the sweep's copy-back phase disappears: the
    owner adds its high face to the received low face while the +neighbor
    adds the received high face to its own low face.  IEEE addition is
    commutative (bitwise), so both sides hold the identical sum — the
    crystal-router idea (halve the dependent rounds by folding data
    bidirectionally per stage) applied to the structured face exchange,
    and valid on any grid shape, not just powers of two.
    """
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        keep = _face(box, dim, 0)
        low = _wire_round(keep, wire_dtype)
        hi = _wire_round(_face(box, dim, m - 1), wire_dtype)
        # both directions in one round, on the *original* faces
        recv_hi = _wire_permute(low, axis_name, grid.shift_perm(dim, -1), wire_dtype)
        recv_lo = _wire_permute(hi, axis_name, grid.shift_perm(dim, +1), wire_dtype)
        # owner: own-hi + recv-low == sweep's accumulate; replica:
        # recv-hi + own-low — same operands, commutative, bitwise equal
        new_hi = _wire_round(hi + recv_hi, wire_dtype)
        new_lo = jnp.where(c > 0, _wire_round(recv_lo + low, wire_dtype), keep)
        box = _set_face(box, dim, m - 1, new_hi)
        box = _set_face(box, dim, 0, new_lo)
    return box


def _gather_tree(
    recv: dict[tuple[int, ...], jax.Array], pdims: list[int], D: tuple[int, ...]
) -> jax.Array:
    """Nested slab combination replicating the face sweep's reduction tree.

    The sweep's dim-d stage ships a low face that already contains the
    accumulated results of all earlier stages; shipped directly instead,
    the same nesting is rebuilt locally: the slab for dim set ``D`` folds
    in the slabs for ``D ∪ {d'}`` (d' below min(D), ascending) at its own
    high positions before being added — reproducing, add for add, the IEEE
    tree the sequential sweep would have computed.
    """
    t = recv[D]
    for dp in [d for d in pdims if d < min(D)]:
        sub = _gather_tree(recv, pdims, tuple(sorted(set(D) | {dp})))
        ax = _axis(dp)
        idx = t.shape[ax] - 1
        sl = [slice(None)] * 3
        sl[ax] = slice(idx, idx + 1)
        t = t.at[tuple(sl)].add(sub)
    return t


def _broadcast_fused(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None,
    coords,
) -> jax.Array:
    """One-round owner→replica refresh of every low face/edge/corner slab.

    The canonical copy of an interface DOF lives where it sits on the HIGH
    face in every partitioned dim that shares it; each nonempty dim subset
    D ships the owner's high slab diagonally to the +1_D neighbor, which
    writes it into its low slab — masked so a position only accepts the
    slab whose dim set matches its actual sharing pattern (low positions
    in dims outside D defer to the larger-D message unless they sit on the
    grid boundary there).  The masks make the write regions disjoint, so
    the message order is irrelevant.
    """
    pdims = _pdims(grid)
    for D in _subsets(pdims):
        spec_hi = {d: (box.shape[_axis(d)] - 1, box.shape[_axis(d)]) for d in D}
        hi = _slab(box, spec_hi)
        off = tuple(+1 if d in D else 0 for d in range(3))
        recv = _wire_permute(hi, axis_name, grid.offset_perm(off), wire_dtype)
        spec_lo = {d: (0, 1) for d in D}
        cur = _slab(box, spec_lo)
        valid = coords[D[0]] > 0
        for d in D[1:]:
            valid = valid & (coords[d] > 0)
        for d in pdims:
            if d in D:
                continue
            ax = _axis(d)
            shape = [1, 1, 1]
            shape[ax] = cur.shape[ax]
            pos = jnp.arange(cur.shape[ax]).reshape(shape)
            valid = valid & ((pos > 0) | (coords[d] == 0))
        box = box.at[_slab_sl(spec_lo)].set(jnp.where(valid, recv, cur))
    return box


def _round_hi_faces(
    box: jax.Array, grid: ProcessGrid, wire_dtype: Any | None
) -> jax.Array:
    """Round every partitioned high face to the wire dtype (owner side)."""
    if wire_dtype is None or jnp.dtype(wire_dtype) == box.dtype:
        return box
    for d in _pdims(grid):
        m = box.shape[_axis(d)]
        box = _set_face(box, d, m - 1, _wire_round(_face(box, d, m - 1), wire_dtype))
    return box


def _sum_fused(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None,
) -> jax.Array:
    """All-dims-in-one-round sum_exchange: gather + masked broadcast.

    Gather round: every nonempty subset D of the partitioned dims ships
    the sender's low slab (face, edge or corner) straight to its -1_D
    diagonal neighbor — all messages concurrent, operating on the original
    box.  The receiver then rebuilds the sweep's accumulation order with
    :func:`_gather_tree` nested adds, so the high slabs end up holding the
    bit-identical canonical sums.  Broadcast round: the summed high slabs
    travel +1_D to refresh the replicas (masked per sharing pattern).
    Two dependent rounds total instead of the sweep's six.
    """
    pdims = _pdims(grid)
    if not pdims:
        return box
    coords = rank_coords(grid, axis_name)
    recv: dict[tuple[int, ...], jax.Array] = {}
    for D in _subsets(pdims):
        off = tuple(-1 if d in D else 0 for d in range(3))
        low = _slab(box, {d: (0, 1) for d in D})
        recv[D] = _wire_permute(low, axis_name, grid.offset_perm(off), wire_dtype)
    for d in pdims:
        m = box.shape[_axis(d)]
        box = _add_face(box, d, m - 1, _gather_tree(recv, pdims, (d,)))
    box = _round_hi_faces(box, grid, wire_dtype)
    return _broadcast_fused(box, grid, axis_name, wire_dtype, coords)


def _shell(box: jax.Array, dim: int, lo: int, hi: int) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box[tuple(sl)]


def _set_shell(box: jax.Array, dim: int, lo: int, hi: int, val) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box.at[tuple(sl)].set(val)


def _add_shell(box: jax.Array, dim: int, lo: int, hi: int, val) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box.at[tuple(sl)].add(val)


def _signed_subsets(
    pdims: list[int],
) -> list[tuple[tuple[int, ...], tuple[int, ...]]]:
    """(dim subset, sign vector) pairs — one per directed diagonal neighbor."""
    out = []
    for D in _subsets(pdims):
        for s in itertools.product((-1, +1), repeat=len(D)):
            out.append((D, s))
    return out


def _expand_fused(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None,
) -> jax.Array:
    """One-round expand: every shell region fills straight from its origin.

    The sweep routes edge/corner overlap data through intermediate ranks
    (pure copies at every hop); shipped directly instead, each directed
    diagonal neighbor sends its interior slab adjacent to the shared
    interface in one concurrent round (≤ 26 messages).  Copies are
    rounding-idempotent, so the result is bit-identical to the sweep even
    under a narrowed wire.
    """
    d = int(depth)
    if d == 0:
        return box
    pdims = _pdims(grid)
    box = jnp.pad(box, d)
    m = {dim: box.shape[_axis(dim)] for dim in range(3)}
    morig = {dim: m[dim] - 2 * d for dim in range(3)}
    for D, s in _signed_subsets(pdims):
        # receiver r's shell on side s_d of dim d comes from the rank at
        # r + sum(s_d * e_d); the permute therefore shifts by -s
        off = tuple(-s[D.index(dim)] if dim in D else 0 for dim in range(3))
        send: dict[int, tuple[int, int]] = {}
        write: dict[int, tuple[int, int]] = {}
        for dim, sd in zip(D, s):
            if sd == -1:
                # low shell <- sender's top interior (padded
                # [morig-1, morig-1+d), original [morig-1-d, morig-1))
                send[dim] = (morig[dim] - 1, morig[dim] - 1 + d)
                write[dim] = (0, d)
            else:
                # high shell <- sender's bottom interior (original [1, 1+d))
                send[dim] = (1 + d, 1 + 2 * d)
                write[dim] = (m[dim] - d, m[dim])
        for dim in range(3):
            if dim not in D:
                # original extent in the other dims: shell-of-shell slots
                # belong to larger-D regions (or stay zero)
                send[dim] = (d, m[dim] - d)
                write[dim] = (d, m[dim] - d)
        recv = _wire_permute(
            _slab(box, send), axis_name, grid.offset_perm(off), wire_dtype
        )
        box = box.at[_slab_sl(write)].set(recv)
    return box


def expand_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None = None,
    routing: str = "face_sweep",
) -> jax.Array:
    """Grow a consistent box by a ``depth``-node shell of neighbor data.

    The overlap transport of the Schwarz smoother: rank-boundary element
    blocks extend ``depth`` GLL node layers into neighbor ranks, so the
    (bz, by, bx)-shaped consistent box comes back as
    (bz+2d, by+2d, bx+2d) with shells holding the neighbors' interior
    slabs (their node layers just inside the shared interface).  Shells
    with no neighbor — unpartitioned dims and physical domain boundaries —
    stay zero (ppermute's zero-fill), matching the dummy slots of the
    extended-block FDM setup.

    The sequential dimension sweeps reuse the sum_exchange trick: the dim-1
    slab a neighbor sends already contains its dim-0 shell, so edge/corner
    overlap data propagates without explicit 26-neighbor messages.
    ``contract_exchange`` is the exact adjoint (same sweeps reversed).
    ``routing="fused"`` ships all ≤ 26 directed regions concurrently in
    one round instead (bit-identical — the hops are pure copies).
    """
    if routing == "fused":
        return _expand_fused(box, grid, axis_name, depth, wire_dtype)
    if routing != "face_sweep":
        raise ValueError(f"unknown expand_exchange routing: {routing!r}")
    d = int(depth)
    if d == 0:
        return box
    box = jnp.pad(box, d)
    for dim in range(3):
        if grid.shape[dim] == 1:
            continue
        ax = _axis(dim)
        m = box.shape[ax]          # padded length = original + 2d
        morig = m - 2 * d
        # low shell <- -neighbor's top interior slab (their original
        # indices [morig-1-d, morig-1) == padded [morig-1, morig-1+d))
        recv = _wire_permute(
            _shell(box, dim, morig - 1, morig - 1 + d),
            axis_name,
            grid.shift_perm(dim, +1),
            wire_dtype,
        )
        box = _set_shell(box, dim, 0, d, recv)
        # high shell <- +neighbor's bottom interior slab (their original
        # [1, 1+d) == padded [1+d, 1+2d))
        recv = _wire_permute(
            _shell(box, dim, 1 + d, 1 + 2 * d),
            axis_name,
            grid.shift_perm(dim, -1),
            wire_dtype,
        )
        box = _set_shell(box, dim, m - d, m, recv)
    return box


def _contract_tree(
    recv: dict, pdims: list[int], d: int, morig: dict[int, int],
    D: tuple[int, ...], s: tuple[int, ...],
) -> jax.Array:
    """Rebuild the reverse sweep's in-transit accumulation for one region.

    In the sweep (dims descending), a rank's dim-k shell accumulates
    arriving slabs from every LATER dim k' > k before shipping at stage k;
    delivered directly instead, the receiver folds the slab for
    ``(D ∪ {k'}, ·)`` into the slab for ``(D, s)`` at the k'-interior row
    positions, k' descending, + direction first — the exact add order the
    sequential sweep would have produced, so the result is bit-identical.
    """
    t = recv[(D, s)]
    for kp in sorted([k for k in pdims if k > max(D)], reverse=True):
        ax = _axis(kp)
        for sp in (+1, -1):
            sub = _contract_tree(
                recv, pdims, d, morig,
                tuple(sorted(set(D) | {kp})),
                _merge_sign(D, s, kp, sp),
            )
            # slab-local rows (the slab spans the original extent in kp,
            # i.e. padded offset d): +1 lands at the top interior
            # [morig-1, morig-1+d), -1 at the bottom [1+d, 1+2d)
            lo = (morig[kp] - 1 - d) if sp == +1 else 1
            sl = [slice(None)] * 3
            sl[ax] = slice(lo, lo + d)
            t = t.at[tuple(sl)].add(sub)
    return t


def _merge_sign(
    D: tuple[int, ...], s: tuple[int, ...], kp: int, sp: int
) -> tuple[int, ...]:
    """Sign vector for D ∪ {kp}, keeping dim order sorted."""
    pairs = sorted(zip(D, s)) + [(kp, sp)]
    pairs.sort()
    return tuple(sd for _, sd in pairs)


def _contract_fused(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None,
) -> jax.Array:
    """One-round contract: every shell region ships straight home.

    Adjoint of :func:`_expand_fused`: each directed shell region (face,
    edge, corner × side) travels to its owner in one concurrent round;
    the receiver then replays the reverse sweep's accumulation order with
    :func:`_contract_tree` nested adds, so the per-rank partial sums come
    out bit-identical to the sweep at the native wire.
    """
    d = int(depth)
    if d == 0:
        return box
    pdims = _pdims(grid)
    m = {dim: box.shape[_axis(dim)] for dim in range(3)}
    morig = {dim: m[dim] - 2 * d for dim in range(3)}
    recv: dict = {}
    for D, s in _signed_subsets(pdims):
        # recv is keyed by the ORIGIN direction s seen from the receiver:
        # the neighbor at +s ships its shell region on sides -s (its low
        # shell travels down, its high shell travels up), so the sender's
        # permute offset is -s
        off = tuple(-s[D.index(dim)] if dim in D else 0 for dim in range(3))
        spec: dict[int, tuple[int, int]] = {}
        for dim, sd in zip(D, s):
            spec[dim] = (0, d) if sd == +1 else (m[dim] - d, m[dim])
        for dim in range(3):
            if dim not in D:
                spec[dim] = (d, m[dim] - d)
        recv[(D, s)] = _wire_permute(
            _slab(box, spec), axis_name, grid.offset_perm(off), wire_dtype
        )
    # home-side adds replay the sweep's stage order: dims descending,
    # + direction (top interior) before - (bottom interior) — the two can
    # overlap on thin boxes (morig < 2d+2), where add order matters
    for k in sorted(pdims, reverse=True):
        ax = _axis(k)
        for sk in (+1, -1):
            t = _contract_tree(recv, pdims, d, morig, (k,), (sk,))
            lo = (morig[k] - 1) if sk == +1 else (1 + d)
            spec = {dim: (d, m[dim] - d) for dim in range(3)}
            spec[k] = (lo, lo + d)
            box = box.at[_slab_sl(spec)].add(t)
    return box[d:-d, d:-d, d:-d]


def contract_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None = None,
    routing: str = "face_sweep",
) -> jax.Array:
    """Adjoint of :func:`expand_exchange`: return shell contributions home.

    ``box`` is a (bz+2d, by+2d, bx+2d) accumulation of extended-block
    gather contributions; each shell slab belongs to a neighbor rank's
    interior and is shipped back and added there, then zeroed.  Dimensions
    run in reverse order so edge/corner contributions hop home
    dimension-by-dimension (the transpose of the expand sweeps).
    Contributions in never-filled shells (domain boundaries) correspond to
    dummy FDM slots and are discarded.  Returns the stripped
    (bz, by, bx) box of per-rank partial sums — interface *face* replicas
    still need the usual ``sum_exchange`` to become consistent.
    ``routing="fused"`` delivers all ≤ 26 directed regions home in one
    concurrent round, replaying the sweep's accumulation order locally
    (bit-identical at the native wire).
    """
    if routing == "fused":
        return _contract_fused(box, grid, axis_name, depth, wire_dtype)
    if routing != "face_sweep":
        raise ValueError(f"unknown contract_exchange routing: {routing!r}")
    d = int(depth)
    if d == 0:
        return box
    for dim in (2, 1, 0):
        ax = _axis(dim)
        m = box.shape[ax]
        morig = m - 2 * d
        if grid.shape[dim] > 1:
            # my low shell -> -neighbor's top interior ([morig-1, morig-1+d)
            # in their padded indexing); I receive the +neighbor's low shell
            recv = _wire_permute(
                _shell(box, dim, 0, d), axis_name,
                grid.shift_perm(dim, -1), wire_dtype,
            )
            box = _add_shell(box, dim, morig - 1, morig - 1 + d, recv)
            recv = _wire_permute(
                _shell(box, dim, m - d, m), axis_name,
                grid.shift_perm(dim, +1), wire_dtype,
            )
            box = _add_shell(box, dim, 1 + d, 1 + 2 * d, recv)
        zero = jnp.zeros_like(_shell(box, dim, 0, d))
        box = _set_shell(box, dim, 0, d, zero)
        box = _set_shell(box, dim, m - d, m, zero)
    return box[d:-d, d:-d, d:-d]


def copy_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None = None,
    routing: str = "face_sweep",
) -> jax.Array:
    """Refresh replica slabs from owners (owner = low-side rank).

    The canonical copy of an interface point lives on the rank where it sits
    on the HIGH face of the padded box; the +neighbor's low-face replica is
    overwritten. This is hipBone's scatter-side halo exchange in isolation.
    With ``wire_dtype`` the owner's high face is rounded to the wire dtype
    too, so replicas and owner agree on the rounded value.

    ``routing="fused"`` replaces the three dependent per-dim rounds with
    the single masked diagonal broadcast round of the fused sum route —
    pure copies, so the result is bit-identical to the sweep even under a
    narrowed wire.  (There is no staged variant distinct from the sweep;
    ``comms.plan.resolve_routing`` maps "crystal" here to "face_sweep".)
    """
    if routing == "fused":
        coords = rank_coords(grid, axis_name)
        box = _round_hi_faces(box, grid, wire_dtype)
        return _broadcast_fused(box, grid, axis_name, wire_dtype, coords)
    if routing != "face_sweep":
        raise ValueError(f"unknown copy_exchange routing: {routing!r}")
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        hi = _wire_round(_face(box, dim, m - 1), wire_dtype)
        box = _set_face(box, dim, m - 1, hi)
        recv = _wire_permute(hi, axis_name, grid.shift_perm(dim, +1), wire_dtype)
        keep = _face(box, dim, 0)
        box = _set_face(box, dim, 0, jnp.where(c > 0, recv, keep))
    return box
