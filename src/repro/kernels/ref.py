"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.galerkin import block_matvec_einsum
from repro.core.operator import local_poisson

__all__ = [
    "poisson_local_ref",
    "block_matvec_ref",
    "fused_axpy_dot_ref",
    "fused_xpay_ref",
    "weighted_dot_ref",
    "fused_jacobi_dot_ref",
    "fused_cheb_d_update_ref",
    "fused_axpy_dot_batched_ref",
    "fused_xpay_batched_ref",
    "fused_jacobi_dot_batched_ref",
]


def poisson_local_ref(
    u: jax.Array, g: jax.Array, w: jax.Array, d: jax.Array, *, lam: float
) -> jax.Array:
    """y = (S_L + λ diag(w)) u — reference for kernels/poisson.py."""
    return local_poisson(u, g, d, lam, w)


def block_matvec_ref(blocks: jax.Array, u: jax.Array) -> jax.Array:
    """y_e = B_e u_e — reference for kernels/blocks.py (Galerkin coarse
    apply on materialized per-element blocks)."""
    return block_matvec_einsum(blocks, u)


def fused_axpy_dot_ref(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    r_new = r - alpha * ap
    rf = r_new.astype(jnp.float32)
    return r_new, jnp.sum(rf * rf)


def fused_xpay_ref(r: jax.Array, p: jax.Array, beta: jax.Array) -> jax.Array:
    return r + beta * p


def weighted_dot_ref(w: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.sum(
        w.astype(jnp.float32) * a.astype(jnp.float32) * b.astype(jnp.float32)
    )


def fused_jacobi_dot_ref(
    dinv: jax.Array, r: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """z = D⁻¹r and r·z — reference for the PCG preconditioner-stage fusion."""
    z = dinv * r
    return z, jnp.sum(r.astype(jnp.float32) * z.astype(jnp.float32))


def fused_cheb_d_update_ref(
    a: jax.Array, c: jax.Array, d: jax.Array, r: jax.Array
) -> jax.Array:
    """d ← a·d + c·r — reference for the Chebyshev direction update."""
    return a * d + c * r


def fused_axpy_dot_batched_ref(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-column (r - αAp, ‖·‖²) over a (B, n) block; alpha: (B,)."""
    return jax.vmap(fused_axpy_dot_ref)(r, ap, alpha)


def fused_xpay_batched_ref(
    r: jax.Array, p: jax.Array, beta: jax.Array
) -> jax.Array:
    """Per-column r + β·p over a (B, n) block; beta: (B,)."""
    return jax.vmap(fused_xpay_ref)(r, p, beta)


def fused_jacobi_dot_batched_ref(
    dinv: jax.Array, r: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """(D⁻¹r, r·D⁻¹r) per column of a (B, n) block; dinv: (n,) shared."""
    return jax.vmap(fused_jacobi_dot_ref, in_axes=(None, 0))(dinv, r)
