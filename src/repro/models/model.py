"""Decoder-only LM assembly: embeddings, scanned layer stack, prefill, decode.

Layers are grouped into repeating *periods* (config.period_kinds); full
periods run under ``lax.scan`` with parameters stacked on a leading
"layers" axis (compile size O(period), not O(n_layers)), the remainder is
unrolled. ``jax.checkpoint`` on the scan body gives per-period activation
rematerialization for training.

DeepSeek's multi-token prediction (MTP) is a single extra block combining
the final hidden state with the next token's embedding (depth-1 MTP as in
arXiv:2412.19437); enabled via ``cfg.mtp_depth``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import (
    MeshContext,
    init_layer,
    init_layer_cache,
    layer_decode,
    layer_forward,
)
from .common import embed, init_embedding, init_norm, norm, unembed
from .config import ModelConfig
from .params import ParamBuilder

__all__ = ["init_model", "forward", "prefill", "decode_step", "init_caches", "mtp_logits"]


def _stack_trees(trees: list) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _mark_layer_axes(axes: Any) -> Any:
    """Prefix a 'layers' logical axis onto every stacked leaf."""
    return jax.tree.map(
        lambda a: ("layers",) + a,
        axes,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(e, (str, type(None))) for e in x),
    )


def init_model(cfg: ModelConfig, key: jax.Array, dtype=None) -> tuple[dict, dict]:
    """Returns (params, logical_axes) trees."""
    cfg.validate()
    dtype = dtype or jnp.dtype(cfg.dtype)
    pb = ParamBuilder(key, dtype=dtype)
    params: dict = {}
    axes: dict = {}
    params["embed"], axes["embed"] = init_embedding(pb.fork(), cfg, dtype)

    kinds = cfg.period_kinds()
    if cfg.n_periods:
        reps_p, reps_a = [], []
        for _ in range(cfg.n_periods):
            lp, la = {}, {}
            for j, kind in enumerate(kinds):
                lp[f"pos{j}"], la[f"pos{j}"] = init_layer(pb.fork(), cfg, kind, dtype)
            reps_p.append(lp)
            reps_a.append(la)
        params["blocks"] = _stack_trees(reps_p)
        axes["blocks"] = _mark_layer_axes(reps_a[0])

    tail_p, tail_a = {}, {}
    for j, kind in enumerate(cfg.remainder_kinds()):
        tail_p[f"t{j}"], tail_a[f"t{j}"] = init_layer(pb.fork(), cfg, kind, dtype)
    if tail_p:
        params["tail"] = tail_p
        axes["tail"] = tail_a

    params["final_norm"], axes["final_norm"] = init_norm(pb.fork(), cfg)

    if cfg.mtp_depth:
        mp, ma = {}, {}
        mp["norm_h"], ma["norm_h"] = init_norm(pb.fork(), cfg)
        mp["norm_e"], ma["norm_e"] = init_norm(pb.fork(), cfg)
        pb2 = ParamBuilder(pb.fork(), dtype=dtype)
        pb2.param(
            "w",
            (2 * cfg.d_model, cfg.d_model),
            ("embed", "embed_act"),
            scale=(2 * cfg.d_model) ** -0.5,
        )
        mp["proj"], ma["proj"] = pb2.collect()
        mp["layer"], ma["layer"] = init_layer(
            pb.fork(), cfg, cfg.layer_kind(cfg.n_layers - 1), dtype
        )
        params["mtp"] = mp
        axes["mtp"] = ma
    return params, axes


# --------------------------------------------------------------------------
# forward (training) and prefill
# --------------------------------------------------------------------------
def _run_stack(
    params: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    mc: MeshContext,
    *,
    make_cache: bool,
):
    kinds = cfg.period_kinds()
    aux = jnp.zeros((), jnp.float32)
    caches = {}

    if cfg.n_periods and "blocks" in params:
        # Sequence-parallel residual stream: constrain the scan carry (the
        # activation saved for backward) to be seq-sharded — Megatron-SP,
        # the knob that fits the 4k-train cells in HBM (EXPERIMENTS §Perf).
        def sp(x):
            if mc.mesh is not None and mc.act_seq_axis is not None:
                spec = jax.sharding.PartitionSpec(
                    mc.batch_axes if mc.batch_axes else None, mc.act_seq_axis, None
                )
                return lax.with_sharding_constraint(
                    x, jax.sharding.NamedSharding(mc.mesh, spec)
                )
            return x

        def body(carry, block_p):
            x, aux = carry
            cs = {}
            for j, kind in enumerate(kinds):
                x, c, a = layer_forward(
                    block_p[f"pos{j}"], x, positions, cfg, kind, mc,
                    make_cache=make_cache,
                )
                if make_cache:
                    cs[f"pos{j}"] = c
                aux = aux + a
            # the carry is what scan saves for backward — keep it seq-sharded
            return (sp(x), aux), (cs if make_cache else 0)

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux), ys = lax.scan(body, (sp(x), aux), params["blocks"])
        if make_cache:
            caches["blocks"] = ys

    for j, kind in enumerate(cfg.remainder_kinds()):
        x, c, a = layer_forward(
            params["tail"][f"t{j}"], x, positions, cfg, kind, mc,
            make_cache=make_cache,
        )
        if make_cache:
            caches.setdefault("tail", {})[f"t{j}"] = c
        aux = aux + a
    return x, aux, caches


def forward(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    mc: MeshContext | None = None,
):
    """Training forward: (B, S) tokens -> (logits (B,S,V), aux_loss, h_final)."""
    mc = mc or MeshContext()
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed(tokens, params["embed"], cfg)
    x, aux, _ = _run_stack(params, x, positions, cfg, mc, make_cache=False)
    h = norm(x, params["final_norm"], cfg)
    return unembed(h, params["embed"], cfg), aux, x


def mtp_logits(
    params: dict,
    tokens: jax.Array,
    h_final: jax.Array,
    cfg: ModelConfig,
    mc: MeshContext | None = None,
):
    """Depth-1 MTP head: predict token t+2 from (h_t, emb(token_{t+1}))."""
    mc = mc or MeshContext()
    mp = params["mtp"]
    b, s = tokens.shape
    h = norm(h_final[:, : s - 1], mp["norm_h"], cfg)
    e = norm(embed(tokens[:, 1:], params["embed"], cfg), mp["norm_e"], cfg)
    hm = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h, e], axis=-1), mp["proj"]["w"])
    positions = jnp.broadcast_to(
        jnp.arange(s - 1, dtype=jnp.int32)[None], (b, s - 1)
    )
    kind = cfg.layer_kind(cfg.n_layers - 1)
    hm, _, aux = layer_forward(mp["layer"], hm, positions, cfg, kind, mc)
    hm = norm(hm, params["final_norm"], cfg)
    return unembed(hm, params["embed"], cfg), aux


# --------------------------------------------------------------------------
# serving
# --------------------------------------------------------------------------
def init_caches(cfg: ModelConfig, batch: int, capacity: int, dtype=None):
    """Zeroed cache pytree matching the stacked/remainder layer layout.

    Attention caches have local capacity ``capacity`` (callers divide by the
    number of sequence shards when the cache is seq-sharded).
    """
    dtype = dtype or jnp.dtype(cfg.dtype)
    kinds = cfg.period_kinds()
    caches = {}
    if cfg.n_periods:
        reps = []
        for _ in range(cfg.n_periods):
            reps.append(
                {
                    f"pos{j}": init_layer_cache(cfg, kind, batch, capacity, dtype)
                    for j, kind in enumerate(kinds)
                }
            )
        caches["blocks"] = _stack_trees(reps)
    tail = {
        f"t{j}": init_layer_cache(cfg, kind, batch, capacity, dtype)
        for j, kind in enumerate(cfg.remainder_kinds())
    }
    if tail:
        caches["tail"] = tail
    return caches


def prefill(
    params: dict,
    tokens: jax.Array,
    cfg: ModelConfig,
    mc: MeshContext | None = None,
):
    """Process a prompt; returns (last-position logits, caches)."""
    mc = mc or MeshContext()
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    x = embed(tokens, params["embed"], cfg)
    x, _, caches = _run_stack(params, x, positions, cfg, mc, make_cache=True)
    h = norm(x[:, -1:], params["final_norm"], cfg)
    return unembed(h, params["embed"], cfg), caches


def decode_step(
    params: dict,
    token: jax.Array,       # (B, 1) current input token
    t: jax.Array,           # scalar position of this token
    caches: dict,
    cfg: ModelConfig,
    mc: MeshContext | None = None,
):
    """One decode step; returns (logits (B,1,V), new caches)."""
    mc = mc or MeshContext()
    x = embed(token, params["embed"], cfg)
    kinds = cfg.period_kinds()
    new_caches = {}

    if cfg.n_periods and "blocks" in params:

        def body(x, xs):
            block_p, block_c = xs
            new_c = {}
            for j, kind in enumerate(kinds):
                x, c = layer_decode(
                    block_p[f"pos{j}"], x, t, block_c[f"pos{j}"], cfg, kind, mc
                )
                new_c[f"pos{j}"] = c
            return x, new_c

        x, ys = lax.scan(body, x, (params["blocks"], caches["blocks"]))
        new_caches["blocks"] = ys

    for j, kind in enumerate(cfg.remainder_kinds()):
        x, c = layer_decode(
            params["tail"][f"t{j}"], x, t, caches["tail"][f"t{j}"], cfg, kind, mc
        )
        new_caches.setdefault("tail", {})[f"t{j}"] = c

    h = norm(x, params["final_norm"], cfg)
    return unembed(h, params["embed"], cfg), new_caches
