"""Batched multi-RHS solves, the setup cache, and the solver engine.

The acceptance contract: a (B, n_global) batched solve is *per-column
bit-identical* to B standalone solves in iterations and status (JAX's
while_loop batching freezes finished lanes — masked updates — so each
column stops independently); repeated requests hit the setup cache and
rebuild nothing.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SolverCache,
    batched_cg_assembled,
    build_problem,
    cg_assembled,
    make_preconditioner,
    poisson_assembled,
    precond_signature,
    solver_setup_key,
)
from repro.core.solver_cache import mesh_signature
from repro.serving import SolveRequest, SolverEngine, SolverServeConfig

TOL = 1e-6


@pytest.fixture(scope="module")
def prob():
    return build_problem(3, (2, 2, 2), lam=1.0, deform=0.1, dtype=jnp.float64)


@pytest.fixture(scope="module")
def operator(prob):
    return poisson_assembled(prob)


def _rhs_block(prob, b, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((b, prob.n_global)), prob.dtype)


# -- tentpole acceptance: B=16 bit-parity with standalone solves --------


@pytest.mark.parametrize("kind", ["none", "jacobi", "chebyshev", "pmg"])
def test_batched_b16_matches_16_standalone(prob, operator, kind):
    pc, _ = make_preconditioner(kind, prob, operator)
    b_block = _rhs_block(prob, 16)
    res = batched_cg_assembled(
        operator, b_block, n_iter=200, tol=TOL, precond=pc
    )
    assert res.x.shape == b_block.shape
    assert res.iterations.shape == (16,) and res.status.shape == (16,)
    for i in range(16):
        ref = cg_assembled(
            operator, b_block[i], n_iter=200, tol=TOL, precond=pc
        )
        # the acceptance bar: per-column iterations/status bit-identical
        assert int(res.iterations[i]) == int(ref.iterations)
        assert int(res.status[i]) == int(ref.status)
        # x agrees to solve-dtype round-off (fp32 when x64 is disabled)
        np.testing.assert_allclose(
            np.asarray(res.x[i]), np.asarray(ref.x), rtol=1e-5, atol=1e-6
        )


def test_batched_columns_stop_independently(prob, operator):
    """Easy + hard + zero RHS in one batch report distinct per-column
    iteration counts, each matching its standalone solve."""
    # an eigenvector RHS is the classic 1-iteration CG case
    n = prob.n_global
    a_mat = np.column_stack(
        [np.asarray(operator(jnp.eye(n, dtype=prob.dtype)[:, j])) for j in range(n)]
    )
    w, v = np.linalg.eigh(a_mat)
    easy = jnp.asarray(v[:, -1], prob.dtype)
    hard = _rhs_block(prob, 1, seed=3)[0]
    zero = jnp.zeros(n, prob.dtype)
    b_block = jnp.stack([easy, hard, zero])
    res = batched_cg_assembled(operator, b_block, n_iter=200, tol=TOL)
    iters = [int(i) for i in res.iterations]
    assert iters[0] == 1          # eigenvector column: one CG step
    assert iters[1] > iters[0]    # generic column keeps iterating
    assert iters[2] == 0          # zero column short-circuits
    assert all(int(s) == 0 for s in res.status)  # CONVERGED
    for i in range(3):
        ref = cg_assembled(operator, b_block[i], n_iter=200, tol=TOL)
        assert iters[i] == int(ref.iterations)
        assert int(res.status[i]) == int(ref.status)


def test_batched_zero_block_converges_at_zero(prob, operator):
    res = batched_cg_assembled(
        operator, jnp.zeros((4, prob.n_global), prob.dtype), tol=TOL
    )
    assert [int(i) for i in res.iterations] == [0, 0, 0, 0]
    assert [int(s) for s in res.status] == [0, 0, 0, 0]
    assert not np.asarray(res.x).any()


def test_batched_x0_and_history(prob, operator):
    b_block = _rhs_block(prob, 2)
    base = batched_cg_assembled(
        operator, b_block, n_iter=50, tol=TOL, record_history=True
    )
    assert base.rdotr_history.shape == (2, 50)
    # x0 threads per column: batched warm start == standalone warm starts
    # (tol is relative to ‖r₀‖, so this genuinely re-enters the loop)
    x0 = 0.5 * base.x
    warm = batched_cg_assembled(operator, b_block, x0, n_iter=50, tol=TOL)
    for i in range(2):
        ref = cg_assembled(operator, b_block[i], x0[i], n_iter=50, tol=TOL)
        assert int(warm.iterations[i]) == int(ref.iterations)
        assert int(warm.status[i]) == int(ref.status)


def test_batched_input_validation(prob, operator):
    with pytest.raises(ValueError, match="b_block must be"):
        batched_cg_assembled(operator, jnp.zeros(prob.n_global, prob.dtype))
    with pytest.raises(ValueError, match="x0 shape"):
        batched_cg_assembled(
            operator,
            jnp.zeros((2, prob.n_global), prob.dtype),
            jnp.zeros((3, prob.n_global), prob.dtype),
        )


def test_batched_fused_stages_match_unfused(prob, operator):
    """The Pallas fused residual stage (interpret mode) slots into the
    batched solve without changing per-column iteration counts."""
    from repro.kernels import ops

    prob32 = build_problem(3, (2, 2, 1), lam=1.0, dtype=jnp.float32)
    op32 = poisson_assembled(prob32)
    b_block = _rhs_block(prob32, 3)
    plain = batched_cg_assembled(op32, b_block, n_iter=100, tol=1e-4)
    fused = batched_cg_assembled(
        op32,
        b_block,
        n_iter=100,
        tol=1e-4,
        fused_update=lambda r, ap, alpha: ops.fused_axpy_dot(
            r, ap, alpha, interpret=True
        ),
    )
    assert [int(i) for i in fused.iterations] == [int(i) for i in plain.iterations]
    assert [int(s) for s in fused.status] == [int(s) for s in plain.status]
    np.testing.assert_allclose(
        np.asarray(fused.x), np.asarray(plain.x), rtol=1e-4, atol=1e-5
    )


# -- setup cache --------------------------------------------------------


def test_cache_key_determinism(prob):
    k1 = solver_setup_key(prob, "chebyshev", degree=2)
    k2 = solver_setup_key(prob, "chebyshev", degree=2)
    assert k1 == k2 and hash(k1) == hash(k2)
    # defaults filled: explicit-default spelling == omitted spelling
    assert solver_setup_key(prob, "chebyshev") == solver_setup_key(
        prob, "chebyshev", degree=2
    )
    # perturbing λ is a different setup
    prob2 = build_problem(3, (2, 2, 2), lam=1.0 + 1e-9, deform=0.1,
                          dtype=jnp.float64)
    assert solver_setup_key(prob2, "chebyshev") != k1
    # and so is any precond knob change
    assert solver_setup_key(prob, "chebyshev", degree=3) != k1


def test_mesh_signature_tracks_geometry(prob):
    s1 = mesh_signature(prob.mesh)
    assert s1 == mesh_signature(prob.mesh)
    other = build_problem(3, (2, 2, 2), lam=1.0, deform=0.11, dtype=jnp.float64)
    assert mesh_signature(other.mesh) != s1  # deformation changes coords


def test_precond_signature_rejects_unknown_knobs():
    with pytest.raises(ValueError, match="unknown preconditioner knob"):
        precond_signature("chebyshev", degre=2)


def test_cache_hit_rebuilds_nothing(prob):
    cache = SolverCache()
    s1 = cache.get_or_build(prob, "jacobi")
    assert (cache.hits, cache.misses) == (0, 1)
    s2 = cache.get_or_build(prob, "jacobi")
    assert (cache.hits, cache.misses) == (1, 1)
    # the zero-setup guarantee: the hit returns the stored object itself
    assert s2 is s1
    assert s2.precond is s1.precond and s2.operator is s1.operator
    stats = cache.stats()
    assert stats["entries"] == 1 and stats["hit_rate"] == 0.5
    assert stats["build_s_total"] == s1.build_s


def test_cache_lru_eviction(prob):
    cache = SolverCache(max_entries=2)
    cache.get_or_build(prob, "none")
    cache.get_or_build(prob, "jacobi")
    cache.get_or_build(prob, "none")          # refresh "none" (now MRU)
    cache.get_or_build(prob, "chebyshev")     # evicts LRU = "jacobi"
    assert len(cache) == 2 and cache.evictions == 1
    assert solver_setup_key(prob, "none") in cache
    assert solver_setup_key(prob, "jacobi") not in cache


def test_cache_solves_match_uncached(prob, operator):
    cache = SolverCache()
    setup = cache.get_or_build(prob, "chebyshev")
    b = _rhs_block(prob, 1)[0]
    got = cg_assembled(setup.operator, b, n_iter=200, tol=TOL,
                       precond=setup.precond)
    pc, _ = make_preconditioner("chebyshev", prob, operator)
    want = cg_assembled(operator, b, n_iter=200, tol=TOL, precond=pc)
    assert int(got.iterations) == int(want.iterations)
    np.testing.assert_allclose(np.asarray(got.x), np.asarray(want.x),
                               rtol=1e-10, atol=1e-12)


# -- solver engine ------------------------------------------------------


def _request(prob, b, kind="jacobi", **kw):
    return SolveRequest(prob=prob, b=b, kind=kind, tol=TOL, n_iter=200, **kw)


def test_engine_batches_and_preserves_order(prob):
    engine = SolverEngine(SolverServeConfig(max_batch=16))
    rhs = _rhs_block(prob, 6, seed=7)
    # interleave two dispatch groups: jacobi and plain
    reqs = [
        _request(prob, rhs[i], kind="jacobi" if i % 2 == 0 else "none")
        for i in range(6)
    ]
    responses = engine.solve(reqs)
    assert len(responses) == 6
    assert len(engine.records) == 2  # one dispatch per group
    assert sorted(r["batch"] for r in engine.records) == [3, 3]
    for i, (req, resp) in enumerate(zip(reqs, responses)):
        assert resp.converged, f"column {i}"
        ref = cg_assembled(
            poisson_assembled(prob), req.b, n_iter=200, tol=TOL,
            precond=(None if req.kind == "none"
                     else make_preconditioner(req.kind, prob,
                                              poisson_assembled(prob))[0]),
        )
        assert resp.iterations == int(ref.iterations)
        assert resp.status == int(ref.status)


def test_engine_max_batch_chunks_slabs(prob):
    engine = SolverEngine(SolverServeConfig(max_batch=2))
    responses = engine.solve(
        [_request(prob, b, kind="none") for b in _rhs_block(prob, 5)]
    )
    assert [r["batch"] for r in engine.records] == [2, 2, 1]
    assert {r.batch_size for r in responses} == {1, 2}


def test_engine_second_flush_hits_cache(prob):
    engine = SolverEngine(SolverServeConfig(max_batch=4))
    first = engine.solve([_request(prob, b) for b in _rhs_block(prob, 2)])
    assert all(r.setup_cache == "miss" for r in first)
    second = engine.solve([_request(prob, b) for b in _rhs_block(prob, 2, 5)])
    assert all(r.setup_cache == "hit" for r in second)
    assert engine.cache.stats()["misses"] == 1
    assert engine.records[-1]["setup_build_s"] == 0.0
    # identical RHS round: bit-identical answers off the cached setup
    again = engine.solve([_request(prob, b) for b in _rhs_block(prob, 2)])
    for a, b in zip(first, again):
        assert a.iterations == b.iterations
        assert np.array_equal(np.asarray(a.x), np.asarray(b.x))


def test_engine_rejects_bad_rhs(prob):
    engine = SolverEngine()
    with pytest.raises(ValueError, match="single"):
        engine.submit(_request(prob, _rhs_block(prob, 2)))
    with pytest.raises(ValueError, match="n_global"):
        engine.submit(
            SolveRequest(prob=prob, b=jnp.zeros(3, prob.dtype))
        )


def test_engine_solve_time_knobs_split_dispatch(prob):
    """tol/n_iter are dispatch-group keys, not cache keys: two tolerances
    dispatch separately but share one cached setup."""
    engine = SolverEngine()
    rhs = _rhs_block(prob, 2)
    engine.submit(SolveRequest(prob=prob, b=rhs[0], kind="jacobi", tol=1e-4))
    engine.submit(SolveRequest(prob=prob, b=rhs[1], kind="jacobi", tol=1e-8))
    responses = engine.flush()
    assert len(engine.records) == 2
    assert responses[0].iterations < responses[1].iterations
    stats = engine.cache.stats()
    assert (stats["misses"], stats["hits"]) == (1, 1)
