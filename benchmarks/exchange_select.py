"""Exchange-routing selection (paper §MPI Communication behavior).

Times all-to-all / pairwise / crystal-router over a message-size sweep on 8
emulated ranks — reproducing the paper's claim structure: crystal router
wins small (latency-bound) messages, pairwise wins large (bandwidth-bound)
ones, and the library's autotuner picks per size.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

_CHILD = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comms.exchange import EXCHANGES

from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("r",))
out = {}
for chunk in [16, 256, 4096, 65536]:
    x = jnp.zeros((64, chunk), jnp.float32)
    row = {}
    for name, fn in EXCHANGES.items():
        f = jax.jit(shard_map(partial(fn, axis_name="r"), mesh=mesh,
                                  in_specs=P("r"), out_specs=P("r")))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(x).block_until_ready()
        row[name] = (time.perf_counter() - t0) / 10
    row["winner"] = min(row, key=row.get)
    out[chunk] = row
print(json.dumps(out))
"""


def main(quick: bool = True) -> list[str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", _CHILD], capture_output=True, text=True,
        env=env, timeout=600,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    data = json.loads(res.stdout.strip().splitlines()[-1])
    rows = ["exchange,chunk_floats,all_to_all_us,pairwise_us,crystal_us,winner"]
    for chunk, row in data.items():
        rows.append(
            f"exchange,{chunk},{row['all_to_all']*1e6:.0f},"
            f"{row['pairwise']*1e6:.0f},{row['crystal_router']*1e6:.0f},"
            f"{row['winner']}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
