"""Mixed-precision preconditioning: fp32 M⁻¹ chains inside fp64 PCG.

Covers the precision axis end to end: the fp32-CG stall point that forces
the outer solve to stay fp64, the cast-boundary preconditioners
(``make_preconditioner(precond_dtype=...)``), flexible (Polak–Ribière) CG,
seed-vector dtype determinism, the fused fp32-input Pallas stage adapters,
the wire-dtype halo casts, and distributed-vs-single-shard parity of the
mixed path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import build_problem, cg_assembled, poisson_assembled
from repro.core.operator import cast_problem
from repro.core.precond import (
    assembled_diagonal,
    deterministic_seed_vector,
    make_preconditioner,
)
from repro.kernels import ops


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(4, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


# ---------------------------------------------------------------------------
# why the outer solve stays fp64
# ---------------------------------------------------------------------------


def test_fp32_cg_stalls_above_tol():
    """Characterize the fp32 stall point: an all-fp32 CG's *recurrence*
    residual may cross tol=1e-8, but the TRUE residual ‖b − Ax‖/‖b‖ stalls
    at fp32 roundoff (~1e-6), two orders of magnitude short of the target
    the fp64 solve actually delivers — the documented reason
    ``precond_dtype`` narrows only the preconditioner and never the outer
    recurrence."""
    jax.config.update("jax_enable_x64", True)
    rng = np.random.default_rng(0)
    tol, cap = 1e-8, 300

    prob32 = build_problem(3, (4, 4, 4), lam=0.1, deform=0.15, dtype=jnp.float32)
    b32 = jnp.asarray(rng.standard_normal(prob32.n_global), jnp.float32)
    res32 = cg_assembled(poisson_assembled(prob32), b32, n_iter=cap, tol=tol)
    assert res32.x.dtype == jnp.float32

    prob = build_problem(3, (4, 4, 4), lam=0.1, deform=0.15, dtype=jnp.float64)
    a64 = poisson_assembled(prob)
    b = jnp.asarray(np.asarray(b32), jnp.float64)
    bnorm = float(jnp.linalg.norm(b))

    # the fp32 "solution", measured honestly in fp64: stalled >> tol
    rel32 = float(
        jnp.linalg.norm(a64(jnp.asarray(np.asarray(res32.x), jnp.float64)) - b)
    ) / bnorm
    assert rel32 > 10 * tol, rel32

    res64 = cg_assembled(a64, b, n_iter=cap, tol=tol)
    assert int(res64.iterations) < cap
    rel64 = float(jnp.linalg.norm(a64(res64.x) - b)) / bnorm
    assert rel64 < 10 * tol, rel64
    # the gap IS the stall: fp32 lands well above what fp64 delivers
    assert rel32 > 10 * rel64, (rel32, rel64)


# ---------------------------------------------------------------------------
# the cast-boundary preconditioners
# ---------------------------------------------------------------------------


def test_precond_dtype_cast_boundary_and_info(prob64):
    """fp32 preconditioners consume/produce fp64 vectors through one cast
    boundary and report their compute dtype in PrecondInfo."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.standard_normal(prob64.n_global), jnp.float64)
    for kind in ("jacobi", "chebyshev", "schwarz", "pmg"):
        pc, info = make_preconditioner(
            kind, prob64, a, precond_dtype=jnp.float32
        )
        z = pc(r)
        assert z.dtype == jnp.float64, (kind, z.dtype)
        assert info.dtype == "float32", (kind, info.dtype)
        # the fp32 apply matches its fp64 twin to fp32 working accuracy
        pc64, info64 = make_preconditioner(kind, prob64, a)
        assert info64.dtype is None
        z64 = pc64(r)
        err = float(jnp.linalg.norm(z - z64) / jnp.linalg.norm(z64))
        assert err < 1e-5, (kind, err)


def test_mixed_precision_within_one_iteration(prob64):
    """ISSUE acceptance (small-N tier): every mixed rung reaches tol=1e-8
    within +1 iteration of the all-fp64 baseline and solves the system."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(prob64.n_global), jnp.float64)
    bnorm = float(jnp.linalg.norm(b))
    for kind, kw in (
        ("jacobi", {}),
        ("chebyshev", {}),
        ("schwarz", {}),
        ("pmg", {}),
        ("pmg", {"pmg_smoother": "schwarz"}),
    ):
        pc64, _ = make_preconditioner(kind, prob64, a, **kw)
        r64 = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc64)
        pc32, _ = make_preconditioner(
            kind, prob64, a, precond_dtype=jnp.float32, **kw
        )
        rmx = cg_assembled(
            a, b, n_iter=500, tol=1e-8, precond=pc32, cg_variant="flexible"
        )
        assert int(rmx.iterations) <= int(r64.iterations) + 1, (
            kind, kw, int(rmx.iterations), int(r64.iterations)
        )
        rel = float(jnp.linalg.norm(a(rmx.x) - b)) / bnorm
        assert rel < 1e-7, (kind, kw, rel)


@pytest.mark.slow
def test_mixed_precision_acceptance_n7():
    """ISSUE acceptance at the benchmark corner N=7, λ=0.1: mixed pMG and
    Schwarz within +1 iteration of fp64."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(7, (4, 4, 4), lam=0.1, deform=0.15, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float64)
    for kind, kw in (("schwarz", {}), ("pmg", {})):
        pc64, _ = make_preconditioner(kind, prob, a, **kw)
        r64 = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc64)
        pc32, _ = make_preconditioner(
            kind, prob, a, precond_dtype=jnp.float32, **kw
        )
        rmx = cg_assembled(
            a, b, n_iter=500, tol=1e-8, precond=pc32, cg_variant="flexible"
        )
        assert int(rmx.iterations) <= int(r64.iterations) + 1, (
            kind, int(rmx.iterations), int(r64.iterations)
        )


# ---------------------------------------------------------------------------
# flexible CG
# ---------------------------------------------------------------------------


def test_flexible_equals_standard_with_exact_precond(prob64):
    """ISSUE satellite: with an exact-fp64 (hence exactly symmetric)
    preconditioner, Polak–Ribière β reduces to Fletcher–Reeves β up to
    roundoff — same residual trajectory, same iterations-to-tol."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(prob64.n_global), jnp.float64)
    pc, _ = make_preconditioner("jacobi", prob64, a)
    std = cg_assembled(a, b, n_iter=25, precond=pc, record_history=True)
    flx = cg_assembled(
        a, b, n_iter=25, precond=pc, record_history=True,
        cg_variant="flexible",
    )
    np.testing.assert_allclose(
        np.array(flx.rdotr_history), np.array(std.rdotr_history), rtol=1e-6
    )
    s_tol = cg_assembled(a, b, n_iter=300, tol=1e-10, precond=pc)
    f_tol = cg_assembled(
        a, b, n_iter=300, tol=1e-10, precond=pc, cg_variant="flexible"
    )
    assert int(s_tol.iterations) == int(f_tol.iterations)
    np.testing.assert_allclose(
        np.array(f_tol.x), np.array(s_tol.x), atol=1e-9
    )


def test_unknown_cg_variant_rejected(prob64):
    a = poisson_assembled(prob64)
    b = jnp.zeros(prob64.n_global)
    with pytest.raises(ValueError, match="cg_variant"):
        cg_assembled(a, b, cg_variant="prestissimo")


# ---------------------------------------------------------------------------
# seed-vector dtype determinism
# ---------------------------------------------------------------------------


def test_seed_vector_dtype_follows_and_is_deterministic():
    """ISSUE satellite regression: the seed follows the requested dtype
    (default = canonical float dtype, not a hard-coded fp32), and the fp32
    seed is bit-exactly the rounded fp64 seed, so spectrum estimates on a
    cast problem see the same vector the fp64 path sees."""
    jax.config.update("jax_enable_x64", True)
    n = 257
    s64 = deterministic_seed_vector(n, jnp.float64)
    s32 = deterministic_seed_vector(n, jnp.float32)
    assert s64.dtype == jnp.float64 and s32.dtype == jnp.float32
    np.testing.assert_array_equal(
        np.array(s32), np.array(s64).astype(np.float32)
    )
    # default dtype = the canonical float dtype of the session
    assert deterministic_seed_vector(n).dtype == jnp.asarray(0.0).dtype
    # repeated calls are identical (pure function of n)
    np.testing.assert_array_equal(
        np.array(deterministic_seed_vector(n, jnp.float32)), np.array(s32)
    )


# ---------------------------------------------------------------------------
# fused fp32-input stages
# ---------------------------------------------------------------------------


def test_fused_jacobi_dot_mixed_boundary(rng):
    """The out_dtype adapter rounds r to the fp32 stage and widens (z, r·z)
    back — the fused analogue of the mixed Jacobi preconditioner stage."""
    jax.config.update("jax_enable_x64", True)
    n = 1000
    dinv32 = jnp.abs(
        jnp.asarray(rng.standard_normal(n), jnp.float32)
    ) + 0.1
    r = jnp.asarray(rng.standard_normal(n), jnp.float64)
    fdot = ops.make_fused_jacobi_dot(
        dinv32, interpret=True, out_dtype=jnp.float64
    )
    z, rz = fdot(r)
    assert z.dtype == jnp.float64 and rz.dtype == jnp.float64
    z_ref = (dinv32.astype(jnp.float64) * r).astype(jnp.float32)
    np.testing.assert_allclose(np.array(z), np.array(z_ref), rtol=1e-6)
    rz_ref = float(jnp.vdot(r.astype(jnp.float32), z_ref.astype(jnp.float32)))
    assert abs(float(rz) - rz_ref) <= 1e-4 * abs(rz_ref)


def test_should_fuse_streams_policy(monkeypatch):
    """Auto-enable only off interpret mode and only for fp32 streams."""
    import jax as _jax

    # pin the env: the auto rule is what's under test (the CI
    # pallas-interpret job runs this suite with HIPBONE_FUSED=1)
    monkeypatch.delenv("HIPBONE_FUSED", raising=False)
    on_tpu = _jax.default_backend() == "tpu"
    assert ops.should_fuse_streams(jnp.float32) == on_tpu
    # fp64 streams never auto-fuse: the kernels' reductions are fp32
    assert ops.should_fuse_streams(jnp.float64) is False
    # the override wins in both directions
    monkeypatch.setenv("HIPBONE_FUSED", "1")
    assert ops.should_fuse_streams(jnp.float64) is True
    monkeypatch.setenv("HIPBONE_FUSED", "0")
    assert ops.should_fuse_streams(jnp.float32) is False


def test_mixed_pcg_with_fused_stages(prob64):
    """The fused fp32 stages drop into the mixed path without changing the
    solution: fused jacobi-dot (cast boundary) and fused cheb-d-update
    (fp32 interior) vs their unfused twins."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(4)
    b = jnp.asarray(rng.standard_normal(prob64.n_global), jnp.float64)

    pc, _ = make_preconditioner(
        "jacobi", prob64, a, precond_dtype=jnp.float32
    )
    ref = cg_assembled(a, b, n_iter=300, tol=1e-8, precond=pc,
                       cg_variant="flexible")
    dinv32 = 1.0 / assembled_diagonal(cast_problem(prob64, jnp.float32))
    got = cg_assembled(
        a, b, n_iter=300, tol=1e-8, precond=pc, cg_variant="flexible",
        fused_precond_dot=ops.make_fused_jacobi_dot(
            dinv32, interpret=True, out_dtype=jnp.float64
        ),
    )
    np.testing.assert_allclose(np.array(got.x), np.array(ref.x), atol=1e-7)

    pc_f, _ = make_preconditioner(
        "chebyshev", prob64, a, precond_dtype=jnp.float32,
        fused_d_update=ops.make_fused_cheb_d_update(interpret=True),
    )
    pc_u, _ = make_preconditioner(
        "chebyshev", prob64, a, precond_dtype=jnp.float32
    )
    r = jnp.asarray(rng.standard_normal(prob64.n_global), jnp.float64)
    np.testing.assert_allclose(
        np.array(pc_f(r)), np.array(pc_u(r)), rtol=2e-4, atol=2e-6
    )


# ---------------------------------------------------------------------------
# wire-dtype halo casts + distributed parity
# ---------------------------------------------------------------------------


def test_halo_wire_dtype_casts():
    """wire_dtype narrows only the transported slabs: fp64 boxes keep
    their dtype, results match the wide-wire exchange to fp32 accuracy,
    and a same-dtype wire is the identity configuration."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P
from repro.comms.halo import contract_exchange, expand_exchange, sum_exchange
from repro.comms.topology import ProcessGrid

grid = ProcessGrid((2, 2, 2))
shape, depth = (5, 4, 6), 1
ext = tuple(s + 2*depth for s in shape)
mesh = make_mesh((8,), ("ranks",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8,) + shape))
y = jnp.asarray(rng.standard_normal((8,) + ext))

from repro.comms.halo import copy_exchange

def fn(x_s, y_s):
    wide = sum_exchange(x_s[0], grid, "ranks")
    narrow = sum_exchange(x_s[0], grid, "ranks", wire_dtype=jnp.float32)
    # replica consistency must survive the narrow wire: a wide replica
    # refresh from the owners must be a no-op on the narrowed result
    refreshed = copy_exchange(narrow, grid, "ranks")
    same = sum_exchange(x_s[0], grid, "ranks", wire_dtype=jnp.float64)
    e_n = expand_exchange(x_s[0], grid, "ranks", depth, wire_dtype=jnp.float32)
    e_w = expand_exchange(x_s[0], grid, "ranks", depth)
    c_n = contract_exchange(y_s[0], grid, "ranks", depth, wire_dtype=jnp.float32)
    c_w = contract_exchange(y_s[0], grid, "ranks", depth)
    return wide, narrow, refreshed, same, e_n, e_w, c_n, c_w

spec = P("ranks")
outs = jax.jit(shard_map(
    fn, mesh=mesh, in_specs=(spec, spec),
    out_specs=tuple(spec for _ in range(8)), check_rep=False))(x, y)
wide, narrow, refreshed, same, e_n, e_w, c_n, c_w = (np.array(o) for o in outs)
assert narrow.dtype == np.float64
np.testing.assert_array_equal(refreshed, narrow)  # owner == replicas
np.testing.assert_array_equal(same, wide)         # fp64 wire == no cast
np.testing.assert_allclose(narrow, wide, rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(e_n, e_w, rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(c_n, c_w, rtol=1e-6, atol=1e-6)
print("OK")
"""
    )


def test_mixed_dist_matches_single_shard_fast():
    """Mixed fp32-preconditioner dist_cg (jacobi + chebyshev + pmg)
    reproduces the single-device mixed solve iteration-for-iteration."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
for kind in ("jacobi", "chebyshev", "pmg"):
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond=kind, cheb_degree=2,
                          precond_dtype=jnp.float32, cg_variant="flexible"))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(status) == 0, (kind, int(status))  # SolveStatus.CONVERGED
    assert int(iters) < 200, (kind, int(iters))
    pc, info = make_preconditioner(kind, ref, A, degree=2,
                                   precond_dtype=jnp.float32)
    assert info.dtype == "float32"
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc,
                       cg_variant="flexible")
    assert int(iters) == int(res.iterations), (
        kind, int(iters), int(res.iterations))
    err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
    assert err < 1e-8, (kind, err)
print("OK")
"""
    )


@pytest.mark.slow
def test_mixed_dist_schwarz_parity_overlap():
    """ISSUE satellite: mixed-precision dist-vs-single-shard parity for the
    Schwarz rung at overlap 0/1/2 — same iterations, same solution, fp32
    shells on the wire."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
for overlap in (0, 1, 2):
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond="schwarz", schwarz_overlap=overlap,
                          precond_dtype=jnp.float32, cg_variant="flexible"))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(iters) < 200, int(iters)
    pc, _ = make_preconditioner("schwarz", ref, A, schwarz_overlap=overlap,
                                precond_dtype=jnp.float32)
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc,
                       cg_variant="flexible")
    assert int(iters) == int(res.iterations), (
        overlap, int(iters), int(res.iterations))
    err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
    assert err < 1e-6, (overlap, err)
    print("OK overlap", overlap, int(iters))
"""
    )
