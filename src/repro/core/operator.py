"""The screened Poisson operator A = S + λI in both storage modes.

hipBone (assembled) mode — paper's central contribution:
    y_L = (S_L + λW) Z x_G        (single fused kernel)
    A x_G = Z^T y_L               (gather; all MPI lives here + halo)

NekBone (scattered) baseline mode:
    b_L = (Z Z^T S_L + λ I) x_L   (combined gather-scatter after local op)

The element-local stiffness is the tensor-product SEM Laplacian
    S_L^e = D^T G^e D
with D the 3-D gradient stack of the 1-D derivative matrix. This module is
the pure-jnp reference implementation; ``repro.kernels`` provides the
Pallas TPU kernel with identical semantics (validated against this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import geometry, sem
from .gather_scatter import gather, gather_scatter, inverse_degree, scatter
from .mesh import BoxMesh, build_box_mesh

__all__ = [
    "local_poisson",
    "local_operator_columns",
    "PoissonProblem",
    "build_problem",
    "problem_from_mesh",
    "coarsen_problem",
    "cast_problem",
    "poisson_assembled",
    "poisson_scattered",
]


def local_poisson(
    u: jax.Array,
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    jw: jax.Array | None = None,
) -> jax.Array:
    """Element-local screened Poisson action  (S_L + λ M) u  (pure jnp).

    Args:
      u:  (E, p) element-local field, p = (N+1)^3, node order (t, s, r).
      g:  (E, 6, p) packed geometric factors [rr, rs, rt, ss, st, tt].
      d:  (N+1, N+1) 1-D derivative matrix.
      lam: screen parameter λ.
      w:  (E, p) inverse-degree weights for the hipBone fused form
          (λW screen on assembled DOFs), or None for plain λI (NekBone
          scattered form applies λ to x_L directly).
      jw: (E, p) mass diagonal J*w_q. When given, the screen term is
          λ·(JW∘W)·u (resp. λ·JW·u) — the proper SEM mass-weighted screen.
          NekBone uses the unweighted algebraic screen λI; pass None to
          match NekBone exactly (benchmarks do).

    Returns:
      (E, p) result.
    """
    e, p = u.shape
    n1 = d.shape[0]
    u3 = u.reshape(e, n1, n1, n1)  # (E, t, s, r)

    # Gradient: three batched contractions — these hit the MXU.
    ur = jnp.einsum("ia,etsa->etsi", d, u3)
    us = jnp.einsum("jb,etbr->etjr", d, u3)
    ut = jnp.einsum("kc,ecsr->eksr", d, u3)

    g3 = g.reshape(e, 6, n1, n1, n1)
    wr = g3[:, 0] * ur + g3[:, 1] * us + g3[:, 2] * ut
    ws = g3[:, 1] * ur + g3[:, 3] * us + g3[:, 4] * ut
    wt = g3[:, 2] * ur + g3[:, 4] * us + g3[:, 5] * ut

    # Divergence: transposed contractions.
    out = (
        jnp.einsum("ia,etsi->etsa", d, wr)
        + jnp.einsum("jb,etjr->etbr", d, ws)
        + jnp.einsum("kc,eksr->ecsr", d, wt)
    ).reshape(e, p)

    screen = u if jw is None else jw * u
    if w is not None:
        screen = w * screen
    return out + lam * screen


def local_operator_columns(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    cols: jax.Array,
) -> jax.Array:
    """Element-local operator applied to a stack of shared probe columns.

    Each column of ``cols`` is broadcast to every element and pushed through
    :func:`local_poisson`, so the result materializes the element-local
    operator restricted to the probed subspace — the workhorse of
    :mod:`core.galerkin`'s setup-time block assembly, where ``cols`` holds
    the lifted coarse basis Ĵ.  Columns are swept sequentially
    (``lax.map``): setup-time memory stays one element-local field per
    probe instead of a (k × E × p) temporary blow-up.

    Args:
      g / d / lam / w: as in :func:`local_poisson`.
      cols: (p, k) probe columns, p = (N+1)³.

    Returns:
      (E, p, k) with ``out[e, :, k] = (S_L^e + λ·screen_e) cols[:, k]``.
    """
    e = g.shape[0]

    def apply_col(c: jax.Array) -> jax.Array:
        return local_poisson(jnp.broadcast_to(c, (e, c.shape[0])), g, d, lam, w)

    return jnp.moveaxis(jax.lax.map(apply_col, cols.T), 0, 2)


@dataclasses.dataclass(frozen=True)
class PoissonProblem:
    """A ready-to-run screened Poisson problem (single shard).

    All arrays are jnp in the runtime dtype; setup metadata stays numpy.
    """

    mesh: BoxMesh
    lam: float
    d: jax.Array            # (N+1, N+1)
    g: jax.Array            # (E, 6, p)
    jw: jax.Array           # (E, p) mass diagonal
    l2g: jax.Array          # (E, p) int32
    w_local: jax.Array      # (E, p) inverse degree (scattered layout)
    w_global: jax.Array     # (N_G,) inverse degree (assembled layout)
    dtype: Any

    @property
    def n_global(self) -> int:
        return self.mesh.n_global

    @property
    def n_local(self) -> int:
        return self.mesh.n_local


def build_problem(
    n_degree: int,
    shape: tuple[int, int, int],
    *,
    lam: float = 1.0,
    deform: float = 0.0,
    dtype: Any = jnp.float32,
) -> PoissonProblem:
    """Construct mesh, geometric factors and gather-scatter data."""
    m = build_box_mesh(n_degree, shape, deform=deform)
    return problem_from_mesh(m, lam=lam, dtype=dtype)


def problem_from_mesh(
    m: BoxMesh, *, lam: float = 1.0, dtype: Any = jnp.float32
) -> PoissonProblem:
    """Geometric factors + gather-scatter data for an existing mesh."""
    geo = geometry.geometric_factors(m)
    d = sem.derivative_matrix(m.n_degree)
    w_g = inverse_degree(m.l2g, m.n_global)
    w_l = w_g[m.l2g]
    return PoissonProblem(
        mesh=m,
        lam=float(lam),
        d=jnp.asarray(d, dtype=dtype),
        g=jnp.asarray(geo["G"], dtype=dtype),
        jw=jnp.asarray(geo["JW"], dtype=dtype),
        l2g=jnp.asarray(m.l2g),
        w_local=jnp.asarray(w_l, dtype=dtype),
        w_global=jnp.asarray(w_g, dtype=dtype),
        dtype=dtype,
    )


def coarsen_problem(prob: PoissonProblem, n_coarse: int) -> PoissonProblem:
    """p-coarsened problem: same element grid, polynomial degree ``n_coarse``.

    The coarse level is a *rediscretization*, not a Galerkin triple product:
    element connectivity comes from a degree-``n_coarse`` box mesh, node
    coordinates are the fine (polynomial) coordinate map sampled at the
    coarse GLL nodes — exact, so the coarse operator lives on the same
    curved geometry — and geometric factors are recomputed at the coarse
    degree.  This is the standard SEM p-multigrid coarse operator
    (Nek5000/RS, libParanumal).
    """
    mf = prob.mesh
    nc = int(n_coarse)
    if not 1 <= nc < mf.n_degree:
        raise ValueError(
            f"coarse degree must be in [1, {mf.n_degree - 1}], got {nc}"
        )
    base = build_box_mesh(nc, mf.shape)  # connectivity only; coords replaced
    j = sem.interpolation_matrix(mf.n_degree, nc)
    coords = sem.interp_coords_3d(j, mf.coords)
    mesh_c = dataclasses.replace(base, coords=coords)
    return problem_from_mesh(mesh_c, lam=prob.lam, dtype=prob.dtype)


def cast_problem(prob: PoissonProblem, dtype: Any) -> PoissonProblem:
    """The same problem with every runtime array cast to ``dtype``.

    The mixed-precision hook: ``make_preconditioner(precond_dtype=...)``
    builds its whole operator/diagonal/transfer chain from the cast copy, so
    every preconditioner byte (HBM streams and, sharded, wire payloads) is
    in the narrow dtype while the outer PCG keeps the original problem.
    Setup metadata (mesh, l2g) is shared, not copied.
    """
    return dataclasses.replace(
        prob,
        d=prob.d.astype(dtype),
        g=prob.g.astype(dtype),
        jw=prob.jw.astype(dtype),
        w_local=prob.w_local.astype(dtype),
        w_global=prob.w_global.astype(dtype),
        dtype=dtype,
    )


def poisson_assembled(
    prob: PoissonProblem,
    local_op: Callable[..., jax.Array] | None = None,
    *,
    fused: bool | None = None,
    fused_kwargs: dict | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """hipBone operator: x_G (N_G,) -> A x_G (N_G,).

    Split form (default off-TPU): y_L = (S_L + λW) Z x_G, then the gather
    Z^T y_L — three XLA ops.  ``local_op`` lets callers swap in the Pallas
    element kernel for the middle stage; default is the pure-jnp reference.

    ``fused`` selects the single-kernel form instead
    (``kernels.ops.poisson_assembled_fused``): gather, local operator and
    scatter-add in one Pallas pass, no x_L/y_L HBM round-trips.  ``None``
    defers to ``kernels.ops.should_fuse_operator`` (native-Pallas backend +
    VMEM fit; ``HIPBONE_FUSED=0/1`` forces it off/on) — except when a
    custom ``local_op`` is given, which pins the split pipeline that uses
    it.  ``fused_kwargs`` passes ``block_e`` / ``interpret`` /
    ``gather_mode`` through to the fused wrapper.
    """
    if fused is None:
        if local_op is not None:
            fused = False
        else:
            from ..kernels import ops as _kops  # lazy: kernels import core

            fused = _kops.should_fuse_operator(
                prob.dtype,
                n_degree=prob.mesh.n_degree,
                n_global=prob.n_global,
            )
    if fused:
        if local_op is not None:
            raise ValueError(
                "poisson_assembled: fused=True replaces the whole "
                "scatter/local_op/gather pipeline; drop local_op"
            )
        from ..kernels import ops as _kops  # lazy: kernels import core

        return _kops.make_poisson_assembled_fused(prob, **(fused_kwargs or {}))

    op = local_op or local_poisson

    def apply(x_g: jax.Array) -> jax.Array:
        x_l = scatter(x_g, prob.l2g)
        y_l = op(x_l, prob.g, prob.d, prob.lam, prob.w_local)
        return gather(y_l, prob.l2g, prob.n_global)

    apply.fused = False
    return apply


def poisson_scattered(
    prob: PoissonProblem,
    local_op: Callable[..., jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """NekBone baseline operator: x_L (E, p) -> b_L = (ZZ^T S_L + λI) x_L."""
    op = local_op or local_poisson

    def apply(x_l: jax.Array) -> jax.Array:
        s_l = op(x_l, prob.g, prob.d, 0.0, None)  # S_L x_L only
        return gather_scatter(s_l, prob.l2g, prob.n_global) + prob.lam * x_l

    return apply
