"""Geometric factors G^e for the SEM Laplacian.

For each element, the metric tensor combined with GLL quadrature weights:

    G_ab = J * w_ijk * sum_c (dr_a/dx_c)(dr_b/dx_c),   a, b in {r, s, t}

packed as six independent entries (G is symmetric). hipBone stores all six
factors (plus the inverse-degree weight W) per node — 7 float64 = 56 bytes
per local node (paper, "Poisson Operator" section). We keep the same seven
streams; the layout is factor-major (E, 6, p) rather than the paper's
node-major packing, because TPU vector units want a contiguous lane
dimension per factor (see DESIGN.md §3).

The Jacobian is computed discretely by applying the SEM derivative matrix to
the node coordinates, which is exact for the (tri-)polynomial coordinate
maps produced by ``mesh.build_box_mesh``.
"""
from __future__ import annotations

import numpy as np

from . import sem
from .mesh import BoxMesh

__all__ = ["geometric_factors", "geometric_factors_from_coords"]


def _apply_d(d: np.ndarray, u: np.ndarray, axis: int) -> np.ndarray:
    """Apply the 1-D derivative matrix along one tensor axis of (E,n,n,n,...)."""
    return np.apply_along_axis(lambda v: d @ v, axis, u)


def geometric_factors(mesh: BoxMesh) -> dict[str, np.ndarray]:
    """Compute geometric factors and quadrature data for a mesh.

    Returns dict with:
      G:    (E, 6, p) float64 — packed [G_rr, G_rs, G_rt, G_ss, G_st, G_tt]
      J:    (E, p) float64 — Jacobian determinant at each node
      JW:   (E, p) float64 — J * quadrature weight (the SEM mass diagonal)
    """
    return geometric_factors_from_coords(mesh.coords, mesh.n_degree)


def geometric_factors_from_coords(
    coords: np.ndarray, n_degree: int
) -> dict[str, np.ndarray]:
    """Same as :func:`geometric_factors` from bare (E, p, 3) node coordinates.

    The mesh-free entry point: p-multigrid coarse levels and the distributed
    builder carry per-element coordinates without a ``BoxMesh``.
    """
    n = int(n_degree)
    npts = n + 1
    e_total = coords.shape[0]
    d = sem.derivative_matrix(n)
    _, w1 = sem.gll_nodes_weights(n)
    w3 = (w1[:, None, None] * w1[None, :, None] * w1[None, None, :]).reshape(-1)

    # coords: (E, p, 3) with local ordering (c=t slow, b=s mid, a=r fast)
    xyz = coords.reshape(e_total, npts, npts, npts, 3)  # (E, t, s, r, 3)

    # dX/dr etc: derivative along each reference axis
    dxdr = np.einsum("ia,etsac->etsic", d, xyz)   # d/dr  (axis r = 3rd)
    dxds = np.einsum("jb,etbrc->etjrc", d, xyz)   # d/ds
    dxdt = np.einsum("kc,ecsrx->eksrx", d, xyz)   # d/dt

    # Jacobian matrix dX/dR: (E, t, s, r, 3[x], 3[r])
    jac = np.stack([dxdr, dxds, dxdt], axis=-1)
    det = np.linalg.det(jac)
    if np.any(det <= 0):
        raise ValueError("mesh has non-positive Jacobian (too much deformation?)")
    inv = np.linalg.inv(jac)  # rows: dR/dX -> inv[..., a, c] = dr_a/dx_c

    p = npts**3
    det_f = det.reshape(e_total, p)
    inv_f = inv.reshape(e_total, p, 3, 3)
    jw = det_f * w3[None, :]

    gmat = np.einsum("epac,epbc->epab", inv_f, inv_f)  # (E, p, 3, 3)
    gmat = gmat * jw[..., None, None]
    g = np.stack(
        [
            gmat[..., 0, 0],
            gmat[..., 0, 1],
            gmat[..., 0, 2],
            gmat[..., 1, 1],
            gmat[..., 1, 2],
            gmat[..., 2, 2],
        ],
        axis=1,
    )  # (E, 6, p)
    return {"G": g, "J": det_f, "JW": jw}
