"""Fault injectors for the solver guardrails.

Each injector models a concrete failure mode of a production solve and is
paired with the detector that must catch it (`core.cg.SolveStatus`):

| injector              | models                              | detector        |
|-----------------------|-------------------------------------|-----------------|
| `nan_at_iteration`    | transient SDC / overflow in A·p     | BREAKDOWN_NAN   |
| `negate_precond`      | sign-corrupted M⁻¹ (r·z < 0)        | BREAKDOWN_INDEF |
| `skew_operator`       | non-symmetric operator corruption   | DIVERGED        |
| `mask_precond`        | partially-zeroed M⁻¹ payload        | STAGNATED       |
| `corrupt_wire`        | corrupted halo/shell wire payload   | any of the above|
| `force_fused_failure` | Pallas VMEM/lowering failure        | split-path      |
|                       |                                     | fallback (ops)  |

Operator/preconditioner wrappers are plain callables — compose them with
`core.resilience.solve_with_fallback`'s ``instrument`` seam (see
`on_attempt`) to fault only specific retry attempts.  `corrupt_wire` and
`force_fused_failure` are context managers because their seams are module
state read at trace time: install them *before* the solve is compiled.

Nothing here is imported by solver code; this module is the testing
surface of the robustness subsystem.
"""
from __future__ import annotations

import contextlib
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental import io_callback

__all__ = [
    "corrupt_wire",
    "force_fused_failure",
    "mask_precond",
    "nan_at_iteration",
    "negate_precond",
    "on_attempt",
    "skew_operator",
]


def nan_at_iteration(
    operator: Callable[[jax.Array], jax.Array],
    k: int,
    *,
    value: float = float("nan"),
) -> Callable[[jax.Array], jax.Array]:
    """Poison the operator's output on its k-th call (one-shot).

    Call 0 is the initial-residual apply A·x₀; call k ≥ 1 is PCG iteration
    k's A·p.  The fault is *transient*: the host-side call counter keeps
    advancing across solves, so a fallback retry of the same wrapped
    operator runs clean — the model is a single silent-data-corruption
    event, not a broken operator.  Uses an ordered ``io_callback``, so the
    wrapped operator only works on a single device (tests; not inside
    shard_map — corrupt the wire with `corrupt_wire` there).
    """
    counter = {"n": 0}

    def bump() -> np.int32:
        i = counter["n"]
        counter["n"] += 1
        return np.int32(i)

    def wrapped(x: jax.Array) -> jax.Array:
        y = operator(x)
        idx = io_callback(
            bump, jax.ShapeDtypeStruct((), jnp.int32), ordered=True
        )
        return jnp.where(idx == k, jnp.asarray(value, y.dtype), y)

    return wrapped


def skew_operator(
    operator: Callable[[jax.Array], jax.Array], magnitude: float
) -> Callable[[jax.Array], jax.Array]:
    """Add a skew-symmetric corruption s·(roll(x,1) − roll(x,−1)).

    Silently breaks the operator's symmetry while leaving p·Ap untouched
    (the skew part of a quadratic form vanishes), so CG's recurrence blows
    up *without* tripping the indefinite detector — the canonical DIVERGED
    trigger.
    """

    def wrapped(x: jax.Array) -> jax.Array:
        s = jnp.asarray(magnitude, x.dtype)
        return operator(x) + s * (jnp.roll(x, 1) - jnp.roll(x, -1))

    return wrapped


def negate_precond(
    precond: Callable[[jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """Flip the sign of M⁻¹.

    −M⁻¹ is negative-definite: r·z < 0 from the very first application,
    the BREAKDOWN_INDEFINITE trigger (note p·Ap stays positive — A is
    untouched — which is why the indefinite detector checks r·z too).
    """
    return lambda r: -precond(r)


def mask_precond(
    precond: Callable[[jax.Array], jax.Array], keep_every: int = 7
) -> Callable[[jax.Array], jax.Array]:
    """Zero every ``keep_every``-th component of M⁻¹'s output.

    A rank-deficient (positive *semi*-definite) M⁻¹ — e.g. a partially
    zeroed payload — confines the Krylov space to a subspace that cannot
    represent the solution: the residual settles at a floor and stops
    improving, the STAGNATED trigger.
    """

    def wrapped(r: jax.Array) -> jax.Array:
        z = precond(r)
        mask = (jnp.arange(z.shape[0]) % keep_every != 0).astype(z.dtype)
        return z * mask

    return wrapped


@contextlib.contextmanager
def corrupt_wire(rank: int, *, mode: str = "nan", axis_name: str | None = None):
    """Corrupt every halo/shell payload *sent* by one rank.

    Installs a `comms.halo.wire_transform` hook, so it applies to all four
    exchange primitives (sum / copy / expand / contract) of anything traced
    inside the ``with`` block — install *before* the dist solve is first
    compiled.  The corruption is targeted with ``lax.axis_index``, so only
    ``rank``'s outgoing slabs are touched; every other rank sends clean
    data, yet all ranks must exit the solve on the same iteration with the
    same status (the detector inputs are psum-derived).

    Modes: ``"nan"`` (poison), ``"zero"`` (dropped payload),
    ``"negate"`` (sign corruption), ``"scramble"`` (mis-ordered payload —
    slab rolled by one along its last axis).
    """
    if mode not in ("nan", "zero", "negate", "scramble"):
        raise ValueError(f"unknown corrupt_wire mode {mode!r}")
    from ..comms import halo

    def hook(slab: jax.Array, ax: str) -> jax.Array:
        if axis_name is not None and ax != axis_name:
            return slab
        mine = lax.axis_index(ax) == rank
        if mode == "nan":
            bad = jnp.full_like(slab, jnp.nan)
        elif mode == "zero":
            bad = jnp.zeros_like(slab)
        elif mode == "negate":
            bad = -slab
        else:  # scramble
            bad = jnp.roll(slab, 1, axis=-1)
        return jnp.where(mine, bad, slab)

    with halo.wire_transform(hook):
        yield


@contextlib.contextmanager
def force_fused_failure():
    """Make the fused-operator lowering probe fail (VMEM-overflow stand-in).

    ``kernels.ops.probe_fused_operator`` raises for every shape while
    active, so ``should_fuse_operator`` must warn once per shape and
    degrade to the split pipeline — including under ``HIPBONE_FUSED=1``.
    The probe cache is cleared on entry and restored on exit so forced
    verdicts never leak into later policy decisions.
    """
    from ..kernels import ops

    prev_flag = ops._FUSED_PROBE_FAIL
    saved = dict(ops._FUSED_PROBE_CACHE)
    ops._FUSED_PROBE_FAIL = True
    ops._FUSED_PROBE_CACHE.clear()
    try:
        yield
    finally:
        ops._FUSED_PROBE_FAIL = prev_flag
        ops._FUSED_PROBE_CACHE.clear()
        ops._FUSED_PROBE_CACHE.update(saved)


def on_attempt(
    attempt: int,
    *,
    operator: Callable | None = None,
    precond: Callable | None = None,
) -> Callable:
    """Build a `solve_with_fallback` ``instrument`` faulting one attempt.

    ``operator`` / ``precond`` are wrappers (e.g. `negate_precond`,
    `skew_operator` partially applied) applied only on the given attempt
    index; every other attempt runs the chain's genuine configuration —
    the shape of a fault that the escalation is supposed to out-run.
    """

    def instrument(i: int, op: Callable, pc: Callable | None):
        if i != attempt:
            return op, pc
        if operator is not None:
            op = operator(op)
        if precond is not None and pc is not None:
            pc = precond(pc)
        return op, pc

    return instrument
