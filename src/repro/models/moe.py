"""Mixture-of-Experts with expert-parallel dispatch via the paper's exchanges.

Token -> expert routing is a sparse nearest-neighbor collective — the exact
shape of problem hipBone's gather-scatter library solves. Dispatch:

  1. tokens are sequence-split over the EP axis (each expert shard routes
     its own slice — no replicated routing work);
  2. a sort-based, capacity-bounded pack builds per-expert send buffers
     (E, cap, d) — the "pack buffer" kernel of the paper's Fig. 2;
  3. the buffers travel through ``repro.comms.exchange`` (all-to-all /
     pairwise / crystal-router, selectable exactly as in the paper);
  4. expert FFNs run as one batched einsum over local experts;
  5. the return exchange + weighted scatter-add reassemble token outputs
     (the gather Z^T).

Routing supports softmax-top-k (Mixtral/Jamba) and the DeepSeek-V3 variant
(sigmoid scores, top-k normalization, routed scaling, shared experts).
Load-balance + router-z auxiliary losses are returned for the train loop.

When ``ep_size == 1`` the same code runs without collectives (single-device
smoke tests); correctness vs a dense per-token reference is tested in both
regimes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import axis_size
from ..comms.exchange import get_exchange
from .common import act_fn
from .config import ModelConfig
from .params import ParamBuilder

__all__ = ["init_moe", "moe_apply", "router_topk"]


def init_moe(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pb.param("w_router", (d, e), ("embed", "unsharded"), scale=d**-0.5)
    pb.param("w_gate", (e, d, ff), ("experts", "embed", "expert_mlp"), scale=d**-0.5)
    pb.param("w_up", (e, d, ff), ("experts", "embed", "expert_mlp"), scale=d**-0.5)
    pb.param("w_down", (e, ff, d), ("experts", "expert_mlp", "embed"), scale=ff**-0.5)
    if cfg.n_shared_experts:
        sf = ff * cfg.n_shared_experts
        pb.param("ws_gate", (d, sf), ("embed", "mlp"), scale=d**-0.5)
        pb.param("ws_up", (d, sf), ("embed", "mlp"), scale=d**-0.5)
        pb.param("ws_down", (sf, d), ("mlp", "embed"), scale=sf**-0.5)
    return pb.collect()


def router_topk(
    logits: jax.Array, cfg: ModelConfig
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Top-k routing. Returns (weights (T,k) f32, indices (T,k) i32, probs (T,E))."""
    lf = logits.astype(jnp.float32)
    k = cfg.experts_per_token
    if cfg.router_score == "sigmoid":          # deepseek-v3
        scores = jax.nn.sigmoid(lf)
        w, idx = lax.top_k(scores, k)
        w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-20)
        w = w * cfg.routed_scaling
        probs = scores / jnp.maximum(jnp.sum(scores, -1, keepdims=True), 1e-20)
    else:                                      # mixtral / jamba
        g, idx = lax.top_k(lf, k)
        w = jax.nn.softmax(g, axis=-1)
        probs = jax.nn.softmax(lf, axis=-1)
    return w, idx, probs


def _aux_losses(
    probs: jax.Array, idx: jax.Array, logits: jax.Array, n_experts: int
) -> jax.Array:
    """Switch-style load-balance loss + router z-loss (summed, unweighted)."""
    counts = jnp.sum(
        jax.nn.one_hot(idx, n_experts, dtype=jnp.float32), axis=(0, 1)
    )
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    imp = jnp.mean(probs, axis=0)
    lb = n_experts * jnp.sum(frac * imp)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)))
    return lb + 1e-3 * z


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(
        math.ceil(tokens * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    )
    return max(4, -(-cap // 4) * 4)  # round up to a multiple of 4


def moe_apply(
    p: dict,
    x: jax.Array,                 # (T, d) tokens (already seq-split per shard)
    cfg: ModelConfig,
    *,
    ep_axis=None,                 # mesh axis name for EP (None = no collectives)
    exchange: str = "all_to_all",
) -> tuple[jax.Array, jax.Array]:
    """Routed-expert output for a token slab. Returns (y (T, d), aux_loss)."""
    t, d = x.shape
    e = cfg.n_experts
    k = cfg.experts_per_token
    cap = _capacity(t, cfg)
    ep = axis_size(ep_axis) if ep_axis else 1
    assert e % ep == 0, f"{e} experts not divisible by ep={ep}"
    e_local = e // ep

    logits = jnp.einsum("td,de->te", x, p["w_router"])
    w, idx, probs = router_topk(logits, cfg)
    aux = _aux_losses(probs, idx, logits, e)

    # ---- sort-based capacity pack: assignments -> (E, cap) slots ----------
    a = t * k
    flat_e = idx.reshape(a)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = w.reshape(a)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    stok = flat_tok[order]
    sw = flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype), side="left")
    pos = jnp.arange(a, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < cap
    slot = jnp.where(keep, se.astype(jnp.int32) * cap + pos, e * cap)  # e*cap = drop bin

    send = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(x[stok])[:-1]

    # ---- exchange through the gather-scatter library ----------------------
    if ep_axis and ep > 1:
        exch = get_exchange(exchange)
        send = send.reshape(ep, e_local * cap, d)
        recv = exch(send, ep_axis)               # (ep=src, e_local*cap, d)
        bufs = recv.reshape(ep * e_local, cap, d)
    else:
        bufs = send.reshape(e_local, cap, d)     # ep == 1

    # ---- batched expert FFN (one einsum across local experts) -------------
    def expert_ffn(h, wg, wu, wd):
        # h: (E_l, C, d) with C = ep*cap slots per local expert
        act = act_fn(cfg.act)
        z = act(jnp.einsum("ecd,edf->ecf", h, wg)) * jnp.einsum(
            "ecd,edf->ecf", h, wu
        )
        return jnp.einsum("ecf,efd->ecd", z, wd)

    if ep_axis and ep > 1:
        my = lax.axis_index(ep_axis)
        wg = lax.dynamic_slice_in_dim(p["w_gate"], my * e_local, e_local, 0) \
            if p["w_gate"].shape[0] == e else p["w_gate"]
        wu = lax.dynamic_slice_in_dim(p["w_up"], my * e_local, e_local, 0) \
            if p["w_up"].shape[0] == e else p["w_up"]
        wd = lax.dynamic_slice_in_dim(p["w_down"], my * e_local, e_local, 0) \
            if p["w_down"].shape[0] == e else p["w_down"]
        # bufs: (ep*e_local, cap, d) grouped [src, e_local] -> regroup by expert
        h = bufs.reshape(ep, e_local, cap, d).transpose(1, 0, 2, 3).reshape(
            e_local, ep * cap, d
        )
        out = expert_ffn(h, wg, wu, wd)
        out = out.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3).reshape(
            ep, e_local * cap, d
        )
        ret = exch(out, ep_axis).reshape(e * cap, d)  # back to source shards
    else:
        ret = expert_ffn(bufs, p["w_gate"], p["w_up"], p["w_down"]).reshape(
            e * cap, d
        )

    # ---- combine: weighted scatter-add back to tokens (gather Z^T) --------
    vals = jnp.where(keep[:, None], ret[jnp.clip(slot, 0, e * cap - 1)], 0.0)
    y = jax.ops.segment_sum(
        vals.astype(jnp.float32) * sw[:, None], stok, num_segments=t
    )

    # ---- shared experts: dense path over all tokens ------------------------
    if cfg.n_shared_experts:
        act = act_fn(cfg.act)
        z = act(jnp.einsum("td,df->tf", x, p["ws_gate"])) * jnp.einsum(
            "td,df->tf", x, p["ws_up"]
        )
        y = y + jnp.einsum("tf,fd->td", z, p["ws_down"]).astype(jnp.float32)

    return y.astype(x.dtype), aux
