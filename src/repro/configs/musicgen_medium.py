"""musicgen-medium [audio] — arXiv:2306.05284. Decoder over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.

Frontend STUB per assignment: the EnCodec residual-VQ codebooks and the
delay-pattern interleaver are out of scope; ``input_specs`` provides the
flattened precomputed token stream (vocab 2048 = one codebook level).
Text-conditioning cross-attention omitted (backbone only). LayerNorm as in
the fairseq-style original; our gated GeGLU FFN replaces its plain GELU
MLP (parameter-count delta noted in DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    norm_type="layernorm",
    act="gelu",
    norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="musicgen-medium-reduced",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    norm_type="layernorm",
    act="gelu",
    dtype="float32",
)
