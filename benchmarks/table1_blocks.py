"""Table 1 analogue: occupancy metrics for the Pallas operator kernel.

The paper's Table 1 explains high-N roofline deviations via GPU occupancy
(registers/warp, wavefronts/CU). The TPU analogue (DESIGN.md §3): VMEM
working-set per grid step vs the 16 MB budget, pipelining headroom
(double-buffer fit), element block size, and MXU lane alignment of the
contraction shapes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.fom import TPU_V5E
from repro.kernels.poisson import pick_block_e, vmem_bytes_per_block


def main(quick: bool = True) -> list[str]:
    rows = [
        "table1,N,block_e,vmem_kb_per_block,vmem_util_pct,double_buffer_fit,"
        "matmul_k,lane_pad_eff_pct,elements_resident"
    ]
    vmem = TPU_V5E.vmem_bytes
    for n in range(1, 16):
        n1 = n + 1
        eb = pick_block_e(n, jnp.float32)
        ws = vmem_bytes_per_block(eb, n1, jnp.float32)
        # MXU processes 128-lane tiles; the contraction K dim is n+1.
        lane_eff = min(1.0, n1 / 128) if n1 < 128 else 1.0
        # effective element-batched M dim is eb*(n+1)^2 — sublane (8) padding
        m = eb * n1 * n1
        sublane_eff = m / (-(-m // 8) * 8)
        rows.append(
            f"table1,{n},{eb},{ws/1024:.0f},{100*ws/vmem:.1f},"
            f"{'yes' if 2*ws <= vmem else 'NO'},{n1},"
            f"{100*lane_eff*sublane_eff:.1f},{eb}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
