"""SEM reference-element tests: GLL nodes/weights, derivative matrix."""
import numpy as np
import pytest

from repro.core import sem


@pytest.mark.parametrize("n", [1, 2, 3, 7, 9, 15])
def test_gll_weights_sum_to_two(n):
    x, w = sem.gll_nodes_weights(n)
    assert x[0] == -1.0 and x[-1] == 1.0
    assert np.all(np.diff(x) > 0)
    np.testing.assert_allclose(w.sum(), 2.0, rtol=1e-13)


def test_gll_known_values():
    x, w = sem.gll_nodes_weights(2)
    np.testing.assert_allclose(x, [-1, 0, 1], atol=1e-15)
    np.testing.assert_allclose(w, [1 / 3, 4 / 3, 1 / 3], rtol=1e-14)
    x, w = sem.gll_nodes_weights(3)
    s5 = 1 / np.sqrt(5)
    np.testing.assert_allclose(x, [-1, -s5, s5, 1], atol=1e-14)
    np.testing.assert_allclose(w, [1 / 6, 5 / 6, 5 / 6, 1 / 6], rtol=1e-13)


@pytest.mark.parametrize("n", [2, 5, 8, 15])
def test_gll_quadrature_exactness(n):
    """GLL quadrature is exact for polynomials of degree <= 2n-1."""
    x, w = sem.gll_nodes_weights(n)
    for k in range(2 * n):
        exact = (1 - (-1) ** (k + 1)) / (k + 1)
        np.testing.assert_allclose(np.sum(w * x**k), exact, atol=1e-12)


@pytest.mark.parametrize("n", [1, 3, 7, 15])
def test_derivative_matrix_exact_on_polynomials(n):
    x, _ = sem.gll_nodes_weights(n)
    d = sem.derivative_matrix(n)
    for k in range(n + 1):
        want = k * x ** max(k - 1, 0) if k else np.zeros_like(x)
        np.testing.assert_allclose(d @ x**k, want, atol=1e-9)


def test_derivative_matrix_corner_values():
    n = 6
    d = sem.derivative_matrix(n)
    np.testing.assert_allclose(d[0, 0], -n * (n + 1) / 4)
    np.testing.assert_allclose(d[n, n], n * (n + 1) / 4)
    # row sums vanish: derivative of the constant
    np.testing.assert_allclose(d.sum(axis=1), 0, atol=1e-12)


@pytest.mark.parametrize("n_from,n_to", [(2, 5), (4, 7), (7, 15), (15, 8)])
def test_interpolation_matrix_properties(n_from, n_to):
    """Row-sum 1 (partition of unity), exactness on source-degree
    polynomials, and identity when degrees match."""
    j = sem.interpolation_matrix(n_from, n_to)
    assert j.shape == (n_to + 1, n_from + 1)
    np.testing.assert_allclose(j.sum(axis=1), 1.0, atol=1e-13)
    xf, _ = sem.gll_nodes_weights(n_from)
    xt, _ = sem.gll_nodes_weights(n_to)
    for p in range(min(n_from, n_to) + 1):
        np.testing.assert_allclose(j @ xf**p, xt**p, atol=1e-12)
    np.testing.assert_allclose(
        sem.interpolation_matrix(n_from, n_from), np.eye(n_from + 1), atol=1e-14
    )


def test_interp_coords_3d_exact_for_mesh_maps():
    """Sampling the polynomial coordinate map at coarse GLL nodes matches
    building the coarse mesh directly (regular geometry)."""
    from repro.core.mesh import build_box_mesh

    fine = build_box_mesh(6, (2, 1, 2))
    coarse = build_box_mesh(3, (2, 1, 2))
    j = sem.interpolation_matrix(6, 3)
    got = sem.interp_coords_3d(j, fine.coords)
    np.testing.assert_allclose(got, coarse.coords, atol=1e-13)
