"""Fig. 3 reproduction: fused Poisson operator performance vs degree N.

The paper measures GFLOPS of the operator kernel for N = 1..15 on three
GPUs against an empirically calibrated streaming roofline (Eq. 4). Here:
  * measured: wall-clock of the jit'd operator on THIS host (CPU), with an
    empirically measured CPU streaming bandwidth calibrating the same
    roofline form — the paper's methodology, ported to the host we have;
  * modeled: the TPU-v5e roofline targets (197 TF peak / 819 GB/s HBM)
    that §Roofline uses for the dry-run cells;
  * dry-run roofline: every row also carries ``model_bytes`` /
    ``achievable_s`` / ``pct_roofline`` from an AOT compile of the *full*
    assembled apply y_G = Z^T (S_L + λW) Z x_G — the analytic
    ``fom.assembled_apply_bytes`` bound over the compiled program's own
    HLO roofline time (see roofline/bench.py). Machine-independent, gated
    across PRs by scripts/compare_bench.py. ``fused_model_bytes`` is the
    single-kernel bound (``fom.fused_apply_bytes``) the fused operator
    (kernels/poisson_fused.py) targets; the ratio is the headroom the
    fusion can reclaim.

``records`` returns the structured rows for the BENCH json
(``fig3_records``); ``main`` renders the CSV.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import build_problem, fom, poisson_assembled
from repro.core.operator import local_poisson
from repro.roofline import dryrun_roofline


def _time(f, *args, reps=5) -> float:
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def measure_stream_bandwidth() -> float:
    """Empirical streaming rate with the paper's 8:1 read:write kernel shape."""
    n = 4 * 2**20
    xs = [jnp.arange(n, dtype=jnp.float32) + i for i in range(8)]

    @jax.jit
    def stream(*arrs):
        return sum(arrs)

    dt = _time(stream, *xs)
    return 9 * n * 4 / dt  # 8 reads + 1 write


def records(quick: bool = True) -> list[dict]:
    """One dict per degree N = 1..15 (plus the stream-bandwidth meta row)."""
    bw = measure_stream_bandwidth()
    target_dofs = 80_000 if quick else 2_000_000
    recs: list[dict] = []
    for n in range(1, 16):
        # mesh sized to ~target DOFs (paper: fixed ~40M per degree)
        e_per_dim = max(2, round((target_dofs / n**3) ** (1 / 3)))
        shape = (e_per_dim,) * 3
        prob = build_problem(n, shape, lam=1.0, dtype=jnp.float32)
        e = prob.mesh.n_elements
        u = jnp.ones((e, prob.mesh.points_per_element), jnp.float32)

        op = jax.jit(lambda u, g, d, w: local_poisson(u, g, d, 1.0, w))
        dt = _time(op, u, prob.g, prob.d, prob.w_local)
        flops = fom.operator_flops(e, n)
        ai = flops / fom.operator_bytes(e, n, word=4)
        cpu_gflops = flops / dt / 1e9
        cpu_roof = min(
            # CPU peak unknown; streaming bound is the relevant arm
            1e12, ai * bw
        ) / 1e9
        tpu_roof = fom.roofline_gflops(
            n, peak_gflops=197_000, bandwidth_gbs=819, word=4
        )

        # dry-run roofline of the full assembled apply (split pipeline):
        # analytic Eq. 4 + gather traffic over the compiled HLO bound
        a = poisson_assembled(prob, fused=False)
        x = jnp.ones((prob.n_global,), jnp.float32)
        compiled = jax.jit(a).lower(x).compile()
        roof = dryrun_roofline(
            compiled,
            model_bytes=fom.assembled_apply_bytes(e, n, word=4),
        )

        recs.append(
            {
                "n": n,
                "dofs": prob.n_global,
                "elements": e,
                "cpu_us": dt * 1e6,
                "cpu_gflops": cpu_gflops,
                "cpu_roofline_gflops": cpu_roof,
                "tpu_roofline_gflops": tpu_roof,
                "ai_f32": ai,
                "model_bytes": roof["model_bytes"],
                "achievable_s": roof["achievable_s"],
                "pct_roofline": roof["pct_roofline"],
                "fused_model_bytes": fom.fused_apply_bytes(e, n, word=4),
                "stream_bw_gbs": bw / 1e9,
            }
        )
    return recs


def rows_from(recs: list[dict]) -> list[str]:
    """CSV rows for a list of :func:`records` results."""
    rows = [
        "fig3,N,dofs,elements,cpu_us,cpu_gflops,cpu_roofline_gflops,"
        "tpu_roofline_gflops,ai_f32,pct_roofline"
    ]
    for r in recs:
        rows.append(
            f"fig3,{r['n']},{r['dofs']},{r['elements']},{r['cpu_us']:.0f},"
            f"{r['cpu_gflops']:.2f},{r['cpu_roofline_gflops']:.2f},"
            f"{r['tpu_roofline_gflops']:.0f},{r['ai_f32']:.3f},"
            f"{r['pct_roofline']:.1f}"
        )
    if recs:
        rows.append(f"fig3_meta,stream_bw_gbs,{recs[0]['stream_bw_gbs']:.2f}")
    return rows


def main(quick: bool = True) -> list[str]:
    return rows_from(records(quick))


if __name__ == "__main__":
    print("\n".join(main()))
