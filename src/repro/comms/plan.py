"""Topology-aware autotuned exchange plans (paper §MPI Communication).

hipBone inherits gslib's setup-time exchange selection: for every gather
-scatter it *times* the candidate routings (pairwise, all-to-all,
crystal router) on the actual machine and caches the winner per cluster.
This module is that idea for the structured halo exchanges of the
distributed solver: every exchange *site* — the CG ``sum_exchange``, the
Schwarz ``expand``/``contract`` shells, each pMG level's exchanges (where
payloads shrink ~8× per rung and the latency/bandwidth tradeoff flips) —
is timed over the actual (process grid, box shape, dtype, wire dtype)
at solver setup, and the winning routing is recorded in an
:class:`ExchangePlan`.

Every candidate routing reproduces the face sweep's IEEE reduction tree
bit-for-bit at the native wire (see ``comms.halo``), so the plan is a
pure performance knob: PCG iteration counts are identical whatever the
plan says.  A narrowed wire dtype is an opt-in tuning axis
(``wire="auto"``) that does change rounding points — it never activates
unless asked for.

Plans persist to disk keyed by a content signature (grid shape, site
set, dtype, wire candidates, device topology — hashed with
``core.solver_cache.content_signature``, the same keying style as the
solver cache's mesh signatures), so a cluster is tuned once: the second
setup with the same signature loads the plan without re-timing.

Environment knobs:

  * ``HIPBONE_EXCHANGE`` — force a policy (``face_sweep``, ``crystal``,
    ``fused``) or ``auto`` for every solve that doesn't pass an explicit
    ``exchange=``;
  * ``HIPBONE_EXCHANGE_CACHE`` — plan cache directory (default
    ``~/.cache/hipbone/exchange_plans``; set to an empty string to
    disable persistence).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Mapping

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..core.solver_cache import content_signature
from . import halo
from .topology import ProcessGrid

__all__ = [
    "ExchangePlan",
    "ExchangeSite",
    "SitePlan",
    "POLICIES",
    "build_exchange_plan",
    "clear_plan_cache",
    "default_policy",
    "plan_cache_dir",
    "resolve_routing",
]

POLICIES = ("auto", "face_sweep", "crystal", "fused")

_ROUTING_MENUS = {
    "sum": halo.SUM_ROUTINGS,
    "copy": halo.PAIR_ROUTINGS,
    "expand": halo.PAIR_ROUTINGS,
    "contract": halo.PAIR_ROUTINGS,
}

# in-process plan memo (signature -> ExchangePlan): repeated setups in one
# process skip even the disk read
_MEMORY: dict[str, "ExchangePlan"] = {}


def default_policy() -> str:
    """The session's exchange policy: ``HIPBONE_EXCHANGE`` or face_sweep."""
    return os.environ.get("HIPBONE_EXCHANGE", "face_sweep")


def plan_cache_dir() -> str | None:
    """Plan persistence directory (None = persistence disabled)."""
    d = os.environ.get(
        "HIPBONE_EXCHANGE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "hipbone", "exchange_plans"),
    )
    return d or None


def resolve_routing(kind: str, name: str) -> str:
    """Map a policy name onto ``kind``'s routing menu.

    The copy/expand/contract shells have no staged variant distinct from
    the per-dim sweep, so a forced ``crystal`` policy falls back cleanly
    to ``face_sweep`` for them (the sum sites still get the staged route).
    """
    menu = _ROUTING_MENUS[kind]
    if name in menu:
        return name
    if name == "crystal":
        return "face_sweep"
    raise ValueError(f"unknown exchange routing {name!r} for {kind!r} sites")


@dataclasses.dataclass(frozen=True)
class ExchangeSite:
    """One exchange call site of a distributed solve, as seen by the tuner.

    ``kind`` is the primitive (``sum``/``copy``/``expand``/``contract``),
    ``level`` the pMG level (0 = fine), ``box_shape`` the [z, y, x] shape
    of the box the primitive is applied to (for ``contract`` that is the
    *expanded* box), ``depth`` the shell depth of expand/contract sites.
    """

    kind: str
    level: int
    box_shape: tuple[int, int, int]
    dtype: str
    depth: int = 0

    @property
    def key(self) -> str:
        return f"{self.kind}@{self.level}"

    def descriptor(self) -> tuple:
        """Identity WITHOUT the level: same-shaped sites share one timing."""
        return (self.kind, tuple(self.box_shape), self.dtype, self.depth)


@dataclasses.dataclass(frozen=True)
class SitePlan:
    """The tuner's verdict for one site: winner + the measured evidence."""

    site: str
    routing: str
    wire_dtype: str | None
    bytes: int
    timings: Mapping[str, float]  # "{routing}/{wire}" -> best seconds

    def to_json(self) -> dict:
        return {
            "site": self.site,
            "routing": self.routing,
            "wire_dtype": self.wire_dtype,
            "bytes": self.bytes,
            "timings": dict(self.timings),
        }


@dataclasses.dataclass(frozen=True)
class ExchangePlan:
    """Per-site routing decisions for one (grid, site set, device) identity.

    ``lookup(kind, level)`` is the hot-path accessor: returns the
    ``(routing, wire_dtype)`` pair an exchange call should use.  Sites the
    plan never saw resolve through the plan's policy (a forced plan has no
    timings at all and resolves everything this way).
    """

    policy: str
    signature: str
    sites: Mapping[str, SitePlan]
    from_cache: bool = False
    timed: bool = False

    def lookup(self, kind: str, level: int = 0) -> tuple[str, Any | None]:
        sp = self.sites.get(f"{kind}@{level}")
        if sp is None:
            name = self.policy if self.policy != "auto" else "face_sweep"
            return resolve_routing(kind, name), None
        wire = None if sp.wire_dtype is None else jnp.dtype(sp.wire_dtype)
        return sp.routing, wire

    def records(self) -> list[dict]:
        """Json-ready per-site rows (the BENCH ``exchange_records`` shape)."""
        return [
            {**self.sites[k].to_json(), "policy": self.policy,
             "signature": self.signature, "from_cache": self.from_cache}
            for k in sorted(self.sites)
        ]


def _forced_plan(policy: str, signature: str = "") -> ExchangePlan:
    return ExchangePlan(policy=policy, signature=signature, sites={})


def _site_bytes(grid: ProcessGrid, site: ExchangeSite, wire: Any | None) -> int:
    """Analytic wire bytes per exchange application (face-sweep route)."""
    item = jnp.dtype(wire if wire is not None else site.dtype).itemsize
    elems = 1
    for s in site.box_shape:
        elems *= s
    total = 0
    per_round = 2 if site.kind in ("sum", "expand", "contract") else 1
    for d in range(3):
        if grid.shape[d] == 1:
            continue
        face = elems // site.box_shape[2 - d]
        width = max(site.depth, 1)
        total += per_round * face * width * item
    return total


def _site_apply(
    grid: ProcessGrid, axis_name: str, site: ExchangeSite, routing: str,
    wire: Any | None,
):
    if site.kind == "sum":
        return lambda b: halo.sum_exchange(b, grid, axis_name, wire, routing)
    if site.kind == "copy":
        return lambda b: halo.copy_exchange(b, grid, axis_name, wire, routing)
    if site.kind == "expand":
        return lambda b: halo.expand_exchange(
            b, grid, axis_name, site.depth, wire, routing
        )
    if site.kind == "contract":
        return lambda b: halo.contract_exchange(
            b, grid, axis_name, site.depth, wire, routing
        )
    raise ValueError(f"unknown exchange site kind: {site.kind!r}")


def _time_candidate(
    mesh, grid: ProcessGrid, axis_name: str, site: ExchangeSite,
    routing: str, wire: Any | None, repeats: int,
) -> float:
    apply = _site_apply(grid, axis_name, site, routing, wire)
    fn = jax.jit(
        shard_map(
            lambda b: apply(b[0])[None],
            mesh=mesh,
            in_specs=P(axis_name),
            out_specs=P(axis_name),
        )
    )
    x = jnp.ones((grid.size, *site.box_shape), jnp.dtype(site.dtype))
    fn(x).block_until_ready()  # compile outside the timed region
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        fn(x).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _wire_candidates(site: ExchangeSite, wire: str) -> list[Any | None]:
    """The wire-dtype axis of the search space for one site.

    ``"native"`` pins the box dtype (the default: candidate routings stay
    bit-identical).  ``"auto"`` adds fp32-on-the-wire for fp64 boxes —
    an opt-in tradeoff that moves rounding points (each routing stays
    replica-consistent, but iteration counts may shift).  A concrete
    dtype name forces that wire.
    """
    if wire == "native":
        return [None]
    if wire == "auto":
        cands: list[Any | None] = [None]
        if jnp.dtype(site.dtype).itemsize > 4:
            cands.append(jnp.float32)
        return cands
    return [jnp.dtype(wire)]


def build_exchange_plan(
    mesh,
    grid: ProcessGrid,
    axis_name: str,
    sites: list[ExchangeSite],
    *,
    policy: str | None = None,
    wire: str = "native",
    repeats: int = 3,
    cache_dir: "str | None" = ...,
) -> ExchangePlan:
    """Build (or load) the exchange plan for one distributed solve setup.

    A non-``auto`` policy forces that routing at every site — no timing,
    no persistence, nothing to load.  ``auto`` resolves in order: the
    in-process memo, the on-disk plan for the same content signature,
    and finally a measured sweep over every (routing, wire) candidate per
    site *class* (sites sharing (kind, box shape, dtype, depth) share one
    timing — coarse pMG levels of equal shape are not re-measured), whose
    winners are persisted for the next process.
    """
    policy = default_policy() if policy is None else policy
    if policy not in POLICIES:
        raise ValueError(
            f"unknown exchange policy {policy!r}; expected one of {POLICIES}"
        )
    if policy != "auto":
        return _forced_plan(policy)
    if cache_dir is ...:
        cache_dir = plan_cache_dir()
    devices = [(d.platform, str(d.device_kind)) for d in mesh.devices.flat]
    signature = content_signature(
        "exchange-plan-v1",
        tuple(grid.shape),
        sorted((s.key, s.descriptor()) for s in sites),
        wire,
        devices,
    )
    cached = _MEMORY.get(signature)
    if cached is not None:
        return cached
    loaded = _load_plan(signature, cache_dir)
    if loaded is not None:
        _MEMORY[signature] = loaded
        return loaded

    timings_by_class: dict[tuple, dict[str, float]] = {}
    site_plans: dict[str, SitePlan] = {}
    for site in sites:
        cls = site.descriptor()
        if cls not in timings_by_class:
            sweep: dict[str, float] = {}
            for routing in _ROUTING_MENUS[site.kind]:
                for wdt in _wire_candidates(site, wire):
                    label = f"{routing}/{'native' if wdt is None else jnp.dtype(wdt).name}"
                    sweep[label] = _time_candidate(
                        mesh, grid, axis_name, site, routing, wdt, repeats
                    )
            timings_by_class[cls] = sweep
        sweep = timings_by_class[cls]
        win = min(sweep, key=sweep.get)
        routing, wire_name = win.split("/")
        site_plans[site.key] = SitePlan(
            site=site.key,
            routing=routing,
            wire_dtype=None if wire_name == "native" else wire_name,
            bytes=_site_bytes(
                grid, site, None if wire_name == "native" else wire_name
            ),
            timings=sweep,
        )
    plan = ExchangePlan(
        policy="auto", signature=signature, sites=site_plans, timed=True
    )
    _MEMORY[signature] = plan
    _save_plan(plan, cache_dir)
    return plan


def _plan_path(signature: str, cache_dir: str) -> str:
    return os.path.join(cache_dir, f"plan_{signature}.json")


def _save_plan(plan: ExchangePlan, cache_dir: str | None) -> None:
    if cache_dir is None:
        return
    os.makedirs(cache_dir, exist_ok=True)
    payload = {
        "signature": plan.signature,
        "policy": plan.policy,
        "sites": [plan.sites[k].to_json() for k in sorted(plan.sites)],
    }
    path = _plan_path(plan.signature, cache_dir)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)  # atomic: concurrent setups never see half a plan


def _load_plan(signature: str, cache_dir: str | None) -> ExchangePlan | None:
    if cache_dir is None:
        return None
    path = _plan_path(signature, cache_dir)
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    if payload.get("signature") != signature:
        return None
    sites = {
        s["site"]: SitePlan(
            site=s["site"],
            routing=s["routing"],
            wire_dtype=s.get("wire_dtype"),
            bytes=int(s.get("bytes", 0)),
            timings=dict(s.get("timings", {})),
        )
        for s in payload.get("sites", [])
    }
    return ExchangePlan(
        policy=payload.get("policy", "auto"),
        signature=signature,
        sites=sites,
        from_cache=True,
        timed=False,
    )


def clear_plan_cache(cache_dir: "str | None" = ...) -> None:
    """Drop the in-process memo and (optionally) the on-disk plans.

    Tests use this to force a re-time; pass ``cache_dir=None`` to leave
    the disk alone.
    """
    _MEMORY.clear()
    if cache_dir is ...:
        cache_dir = plan_cache_dir()
    if cache_dir is None or not os.path.isdir(cache_dir):
        return
    for name in os.listdir(cache_dir):
        if name.startswith("plan_") and name.endswith(".json"):
            try:
                os.remove(os.path.join(cache_dir, name))
            except OSError:
                pass


# re-exported for call sites that only need a forced plan (tests, tools)
forced_plan = _forced_plan
