"""repro.roofline — compiled-artifact analysis: loop-aware HLO accounting."""
from .analysis import CollectiveStats, parse_collectives, roofline_report
from .hlo_model import HloStats, analyze_hlo

__all__ = ["CollectiveStats", "HloStats", "analyze_hlo", "parse_collectives", "roofline_report"]
