"""Figs. 4-6 reproduction: full-benchmark scaling across ranks.

The paper sweeps problem sizes over 1..64 GPUs and plots FOM (GFLOPS) and
throughput = DOFs*iters/(ranks*time) (Eq. 6). We run the full distributed
hipBone CG on 1/2/4/8 emulated devices (subprocesses with
--xla_force_host_platform_device_count, so this bench itself keeps a
1-device view) across a problem-size sweep, and report both metrics.
Wall-clock here is host-CPU emulation — the shape of the curves (weak-
scaling collapse at large DOFs/rank) is the reproducible signal, not the
absolute GFLOPS; TPU absolutes live in §Roofline.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

_CHILD = r"""
import os, sys, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={RANKS}"
import jax, numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid, factor3
from repro.core.distributed import build_dist_problem, dist_cg
from repro.core.fom import nekbone_flops_per_iter

ranks = RANKS
n = DEGREE
local = LOCAL
n_iter = 50
grid = ProcessGrid(factor3(ranks))
mesh = make_mesh((ranks,), ("ranks",))
prob = build_dist_problem(n, grid, local, lam=1.0, dtype=jnp.float32)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((ranks, prob.m3)), jnp.float32)
run = jax.jit(dist_cg(prob, mesh, b, n_iter=n_iter))
run()[1].block_until_ready()          # compile + warm
t0 = time.perf_counter()
reps = 3
for _ in range(reps):
    run()[1].block_until_ready()
dt = (time.perf_counter() - t0) / reps
e_tot = ranks * prob.e_local
dofs = prob.n_global
fom = nekbone_flops_per_iter(e_tot, n) * n_iter / dt / 1e9
thru = dofs * n_iter / (ranks * dt)
print(json.dumps({"ranks": ranks, "N": n, "dofs": dofs, "time_s": dt,
                  "fom_gflops": fom, "throughput": thru}))
"""


def _run(ranks: int, degree: int, local: tuple) -> dict:
    code = (
        _CHILD.replace("RANKS", str(ranks))
        .replace("DEGREE", str(degree))
        .replace("LOCAL", str(local))
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=900,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(quick: bool = True) -> list[str]:
    rows = ["fig456,N,ranks,dofs,dofs_per_rank,time_s,fom_gflops,throughput"]
    sizes = {7: [(1, 1, 1), (2, 2, 2)], 15: [(1, 1, 1)]} if quick else {
        7: [(1, 1, 1), (2, 2, 2), (4, 4, 4)],
        15: [(1, 1, 1), (2, 2, 2)],
    }
    rank_list = [1, 2, 4, 8]
    for degree, locals_ in sizes.items():
        for local in locals_:
            for ranks in rank_list:
                try:
                    r = _run(ranks, degree, local)
                except RuntimeError as e:
                    rows.append(f"fig456,{degree},{ranks},ERROR,{e}")
                    continue
                rows.append(
                    f"fig456,{degree},{r['ranks']},{r['dofs']},"
                    f"{r['dofs']//r['ranks']},{r['time_s']:.4f},"
                    f"{r['fom_gflops']:.2f},{r['throughput']:.3e}"
                )
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=False)))
