import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import — jax locks the device count at first init.
__doc__ = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import — jax locks the device
count at first init. This proves, without hardware, that the distribution
config is coherent: shardings divide, collectives partition, the program
compiles; memory_analysis/cost_analysis feed EXPERIMENTS.md §Dry-run and
§Roofline.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
    python -m repro.launch.dryrun --arch hipbone_n15 --mesh multi
    python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import functools
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, POISSON, SHAPES, get_config, long_context_eligible
from repro.core.fom import TPU_V5E, nekbone_flops_per_iter
from repro.launch.mesh import flat_mesh, make_production_mesh
from repro.models.blocks import MeshContext
from repro.models.config import ModelConfig
from repro.models.model import decode_step, init_caches, init_model, prefill
from repro.models.params import RULES_TP_DP, RULES_TP_FSDP, tree_shardings_for
from repro.training.optimizer import adafactor
from repro.training.train_step import make_train_step, warmup_cosine

# per-arch training microbatch counts (memory posture; see EXPERIMENTS.md)
MICROBATCHES = {
    "chameleon-34b": 4,
    "command-r-35b": 4,
    "deepseek-v3-671b": 4,   # §Perf iteration C3: activation/dispatch footprint /4
    "yi-9b": 2,
    "mixtral-8x7b": 2,
    "jamba-v0.1-52b": 2,
}

_IS_AXES = lambda x: isinstance(x, tuple) and all(
    isinstance(e, (str, type(None))) for e in x
)


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _vocab_axis(cfg, mesh):
    return "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None


def abstract_model(cfg: ModelConfig):
    """(abstract params, logical axes) without allocating anything."""
    box = {}

    def f(k):
        p, a = init_model(cfg, k)
        box["axes"] = a          # python data, captured during tracing
        return p

    params_abs = jax.eval_shape(f, jax.random.key(0))
    return params_abs, box["axes"]


def _shards(abstract_tree, sharding_tree):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        abstract_tree,
        sharding_tree,
    )


def _replicated(mesh, tree):
    return jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, P())
        ),
        tree,
    )


def _opt_axes(params_axes):
    def v_for(a):
        if len(a) >= 2:
            return {"vr": tuple(a[:-1]), "vc": tuple(a[:-2]) + (a[-1],)}
        return {"v": tuple(a)}

    return {
        "m": params_axes,
        "v": jax.tree.map(v_for, params_axes, is_leaf=_IS_AXES),
        "count": (),
    }


def _cache_shardings(mesh, caches_abs, batch_axes, seq_axes):
    b_ax = batch_axes if batch_axes else None
    seq = (
        seq_axes
        if len(seq_axes) > 1
        else (seq_axes[0] if seq_axes else None)
    )

    def spec_for(path, leaf):
        key = getattr(path[-1], "key", "")
        nd = leaf.ndim
        if key in ("k", "v"):          # (L?, B, S, KV, D)
            lead = [None] * (nd - 4)
            return P(*lead, b_ax, seq, None, None)
        if key in ("c_kv", "k_rope"):  # (L?, B, S, R)
            lead = [None] * (nd - 3)
            return P(*lead, b_ax, seq, None)
        if key == "state":             # (L?, B, H, N, P)
            lead = [None] * (nd - 4)
            return P(*lead, b_ax, None, None, None)
        if key == "conv":              # (L?, B, W, C)
            lead = [None] * (nd - 3)
            return P(*lead, b_ax, None, None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(caches_abs)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, spec_for(p, l)) for p, l in flat]
    )


def _analyse(lowered, compiled, *, chips, model_flops, extra=None):
    from repro.roofline.hlo_model import analyze_hlo

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # old jax returns [dict], new a dict
        cost = cost[0] if cost else {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()

    # loop-aware accounting (XLA's cost_analysis counts while bodies once;
    # verified in EXPERIMENTS.md §Dry-run)
    st = analyze_hlo(hlo)
    t_compute = st.flops / TPU_V5E.peak_flops
    t_memory = st.hbm_bytes / TPU_V5E.hbm_bandwidth
    t_coll = st.total_link_bytes / TPU_V5E.ici_bandwidth
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    roof = {
        **terms,
        "dominant": dominant,
        "hlo_dot_flops_per_chip": st.flops,
        "hlo_bytes_per_chip_proxy": st.hbm_bytes,
        "link_bytes_per_chip": st.total_link_bytes,
        "collective_counts": st.coll_counts,
        "collective_link_bytes": st.coll_link_bytes,
        "scan_trip_counts": st.trip_counts,
        "roofline_bound_s": bound,
        "model_flops": model_flops,
        "model_flops_per_chip": model_flops / chips,
        "useful_flop_fraction": (model_flops / chips / st.flops) if st.flops else 0.0,
        "roofline_fraction": (
            (model_flops / chips / TPU_V5E.peak_flops) / bound if bound > 0 else 0.0
        ),
        # naive (loop-unaware) reference values from XLA's own counters
        "xla_cost_flops": float(cost.get("flops", 0.0)),
        "xla_cost_bytes": float(cost.get("bytes accessed", 0.0)),
    }
    rec = {
        "status": "ok",
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_nonaliased_bytes": mem.argument_size_in_bytes
            + mem.output_size_in_bytes
            + mem.temp_size_in_bytes
            - mem.alias_size_in_bytes,
        },
        "roofline": roof,
    }
    if extra:
        rec.update(extra)
    return rec


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------
def run_lm_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    cfg = get_config(arch)
    sh = SHAPES[shape]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(mesh.devices.shape))
    dp = _dp_axes(mesh)
    seq_len, gb, step_kind = sh["seq_len"], sh["global_batch"], sh["step"]

    if shape == "long_500k" and not long_context_eligible(cfg):
        return {"status": "skipped", "reason": "full attention; DESIGN.md skip list"}

    # training stores params "assembled" (FSDP over dp axes, paper C1);
    # inference keeps them TP-sharded + dp-replicated: weight-stationary
    # serving has no per-layer all-gather (§Perf iteration 3)
    rules = dict(RULES_TP_FSDP if step_kind == "train" else RULES_TP_DP)
    params_abs, axes = abstract_model(cfg)
    params_sh = tree_shardings_for(params_abs, axes, rules, mesh)
    params_in = _shards(params_abs, params_sh)
    t0 = time.time()

    if step_kind == "train":
        mc = MeshContext(
            mesh=mesh, batch_axes=dp, tp_axis="model", act_seq_axis="model"
        )
        opt = adafactor()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_sh = tree_shardings_for(opt_abs, _opt_axes(axes), rules, mesh)
        opt_in = _shards(opt_abs, opt_sh)
        mb = MICROBATCHES.get(arch, 1)
        step = make_train_step(
            cfg, opt, warmup_cosine(peak_lr=1e-4, warmup=100, total=10000),
            mc, microbatches=mb,
        )
        batch_in = {
            "tokens": jax.ShapeDtypeStruct(
                (gb, seq_len + 1), jnp.int32,
                sharding=NamedSharding(mesh, P(dp, None)),
            )
        }
        idx = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
        metrics_keys = ["nll", "aux", "loss", "grad_norm", "lr"]
        if cfg.mtp_depth:
            metrics_keys.insert(2, "mtp_nll")
        out_shardings = (
            params_sh,
            opt_sh,
            {k: NamedSharding(mesh, P()) for k in metrics_keys},
        )
        lowered = jax.jit(step, out_shardings=out_shardings).lower(
            params_in, opt_in, batch_in, idx
        )
        model_flops = 6.0 * cfg.n_active_params() * gb * seq_len
    elif step_kind == "prefill":
        mc = MeshContext(mesh=mesh, batch_axes=dp, tp_axis="model")
        caches_abs = jax.eval_shape(lambda: init_caches(cfg, gb, seq_len))
        cache_sh = _cache_shardings(mesh, caches_abs, dp, ("model",))
        tokens_in = jax.ShapeDtypeStruct(
            (gb, seq_len), jnp.int32, sharding=NamedSharding(mesh, P(dp, None))
        )
        fn = functools.partial(prefill, cfg=cfg, mc=mc)
        out_shardings = (NamedSharding(mesh, P(dp, None, _vocab_axis(cfg, mesh))), cache_sh)
        lowered = jax.jit(fn, out_shardings=out_shardings).lower(
            params_in, tokens_in
        )
        model_flops = 2.0 * cfg.n_active_params() * gb * seq_len
    else:  # decode
        if shape == "long_500k":
            batch_axes: tuple = ()
            seq_axes = ("pod", "data", "model") if mesh_kind == "multi" else (
                "data", "model"
            )
        else:
            batch_axes = dp
            seq_axes = ("model",)
        mc = MeshContext(
            mesh=mesh, batch_axes=batch_axes, tp_axis="model", seq_axes=seq_axes
        )
        caches_abs = jax.eval_shape(lambda: init_caches(cfg, gb, seq_len))
        cache_sh = _cache_shardings(mesh, caches_abs, batch_axes, seq_axes)
        caches_in = _shards(caches_abs, cache_sh)
        b_ax = batch_axes if batch_axes else None
        token_in = jax.ShapeDtypeStruct(
            (gb, 1), jnp.int32, sharding=NamedSharding(mesh, P(b_ax, None))
        )
        t_in = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
        fn = functools.partial(decode_step, cfg=cfg, mc=mc)
        out_shardings = (NamedSharding(mesh, P(b_ax, None, _vocab_axis(cfg, mesh))), cache_sh)
        lowered = jax.jit(fn, out_shardings=out_shardings).lower(
            params_in, token_in, t_in, caches_in
        )
        model_flops = 2.0 * cfg.n_active_params() * gb  # one token per slot

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    return _analyse(
        lowered, compiled, chips=chips, model_flops=model_flops,
        extra={
            "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "params_b": cfg.n_params(), "active_params_b": cfg.n_active_params(),
        },
    )


# --------------------------------------------------------------------------
# hipBone cells (extra, beyond the 40)
# --------------------------------------------------------------------------
def run_poisson_cell(name: str, mesh_kind: str) -> dict:
    from repro.comms.topology import ProcessGrid, factor3
    from repro.core.distributed import DistPoisson, _local_l2g, dist_cg
    from repro.core import sem

    pc = POISSON[name]
    # fp64 presets (mixed-precision cells) need x64, else jit canonicalizes
    # every fp64 aval to fp32 and the lowered HLO measures the wrong program
    if jnp.dtype(pc.dtype) == jnp.float64 or (
        pc.precond_dtype is not None
        and jnp.dtype(pc.precond_dtype) == jnp.float64
    ):
        jax.config.update("jax_enable_x64", True)
    prod = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    mesh = flat_mesh(prod)
    chips = int(np.prod(mesh.devices.shape))
    grid = ProcessGrid(factor3(chips))
    n = pc.n_degree
    bx, by, bz = pc.local_elems
    l2g, halo = _local_l2g(n, pc.local_elems)
    e_loc, p = l2g.shape
    m3 = (bx * n + 1) * (by * n + 1) * (bz * n + 1)
    dtype = jnp.dtype(pc.dtype)

    prob = DistPoisson(
        grid=grid, axis_name="ranks", n_degree=n, local_shape=pc.local_elems,
        box_shape=(bx * n + 1, by * n + 1, bz * n + 1), lam=pc.lam,
        halo_elems=halo, l2g=l2g,
        d=jnp.asarray(sem.derivative_matrix(n), dtype),
        g=jax.ShapeDtypeStruct(
            (chips, e_loc, 6, p), dtype,
            sharding=NamedSharding(mesh, P("ranks")),
        ),
        w_local=jax.ShapeDtypeStruct(
            (chips, e_loc, p), dtype, sharding=NamedSharding(mesh, P("ranks"))
        ),
        mask=jax.ShapeDtypeStruct(
            (chips, m3), dtype, sharding=NamedSharding(mesh, P("ranks"))
        ),
        dtype=dtype,
    )
    b_in = jax.ShapeDtypeStruct(
        (chips, m3), dtype, sharding=NamedSharding(mesh, P("ranks"))
    )
    t0 = time.time()
    run = dist_cg(
        prob, mesh, b_in, n_iter=pc.n_iter, tol=pc.tol,
        precond=pc.precond, cheb_degree=pc.cheb_degree,
        pmg_smooth_degree=pc.pmg_smooth_degree,
        pmg_smoother=pc.pmg_smoother,
        pmg_coarse_op=pc.pmg_coarse_op,
        pmg_coarse_iters=pc.pmg_coarse_iters,
        schwarz_overlap=pc.schwarz_overlap,
        schwarz_inner_degree=pc.schwarz_inner_degree,
        precond_dtype=pc.precond_dtype,
        cg_variant=pc.cg_variant,
        fused_operator=pc.fused_operator,
        exchange=pc.exchange,
    )
    lowered = jax.jit(run.func).lower(*run.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    e_total = chips * e_loc
    model_flops = nekbone_flops_per_iter(e_total, n) * pc.n_iter
    return _analyse(
        lowered, compiled, chips=chips, model_flops=model_flops,
        extra={
            "arch": name, "shape": f"N={n} E/rank={e_loc}", "mesh": mesh_kind,
            "chips": chips, "grid": grid.shape,
            "dofs": chips * pc.dofs_per_rank(),
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        },
    )


# --------------------------------------------------------------------------
def all_cells() -> list[tuple[str, str, str]]:
    cells = []
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh_kind in ("single", "multi"):
                cells.append((arch, shape, mesh_kind))
    for name in POISSON:
        for mesh_kind in ("single", "multi"):
            cells.append((name, "-", mesh_kind))
    return cells


def run_cell(arch: str, shape: str, mesh_kind: str) -> dict:
    try:
        if arch in POISSON:
            return run_poisson_cell(arch, mesh_kind)
        return run_lm_cell(arch, shape, mesh_kind)
    except Exception as e:  # a failure here is a bug in the system
        return {
            "status": "failed",
            "arch": arch, "shape": shape, "mesh": mesh_kind,
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-2000:],
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="architecture id (or hipbone_*)")
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES) + ["-"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for c in all_cells():
            print(*c)
        return

    results = []
    if args.all:
        cells = all_cells()
    else:
        cells = [(args.arch, args.shape, args.mesh)]
    for arch, shape, mesh_kind in cells:
        print(f"=== {arch} x {shape} x {mesh_kind} ===", flush=True)
        rec = run_cell(arch, shape, mesh_kind)
        show = {k: v for k, v in rec.items() if k != "traceback"}
        print(json.dumps(show, indent=2, default=str), flush=True)
        results.append(rec)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2, default=str)
        print(f"wrote {args.out}")
    n_fail = sum(r.get("status") == "failed" for r in results)
    if n_fail:
        raise SystemExit(f"{n_fail} cells FAILED")


if __name__ == "__main__":
    main()
