#!/usr/bin/env python
"""Gate benchmark regressions against the previous PR's BENCH json.

Usage:
    python scripts/compare_bench.py BENCH_pr5.json BENCH_pr6.json \
        [--slack N] [--roofline-slack PTS] [--allow-new-sections]

Three gated record sections, compared on the cases both jsons share:

  * ``precond_records`` (key: N, lam, kind, dtype, coefficient — rows
    without the coefficient field are the "const" family) — fails if any
    case
    needs more than ``--slack`` extra CG iterations to reach tolerance,
    or loses more than ``--roofline-slack`` percentage points of
    ``pct_roofline``;
  * ``fig3_records`` (key: N) — fails on ``pct_roofline`` drops beyond
    the slack;
  * ``batched_records`` (key: N, lam, kind, dtype, batch) — the
    multi-RHS amortization sweep; fails on iteration regressions beyond
    the slack (``iters_to_tol`` is the max per-column count of the
    dispatch).  Wall-time amortization itself is machine-dependent and
    reported, never gated.
  * ``exchange_records`` (key: site, N) — the halo-exchange plan build.
    Candidate-side validity gate only: each site's winning ``routing``
    must actually be the argmin of its own reported ``timings`` (a plan
    that picks a loser is a tuner bug, not a tuning).  The timings
    themselves are machine-dependent and never compared across jsons;
    winner changes are reported as information.

Independently of the pairwise comparison, every *candidate* row in a
gated section must report ``status: "converged"`` (the
``core.cg.SolveStatus`` wire name) — a benchmark row that ended in
MAX_ITER or a breakdown status is not a performance number and fails the
gate outright, whatever its iteration count.  Rows without a ``status``
field are treated as legacy-converged (jsons predating the field; also
fig3's operator-only rows, which never run a solve).

``pct_roofline`` is machine-independent by construction (analytic traffic
bound over the dry-run HLO roofline time, both at the TPU_V5E constants —
see roofline/bench.py), which is what makes it gateable; wall-clock and
GFLOPS are machine-dependent and intentionally ignored.  Records without
a ``pct_roofline`` field (jsons predating this PR) are simply not
roofline-gated, mirroring how records without ``dtype`` are treated as
"fp64" — schema growth never breaks old baselines.

Section-presence is itself checked: a gated section present in the
candidate but missing from the baseline is an error (the baseline predates
the section — rerun it, or pass ``--allow-new-sections`` to acknowledge
the schema growth explicitly, as CI does on the first PR that introduces
a section), and a section present in the baseline but missing from the
candidate always fails (benchmark coverage must not shrink).  New kinds
within a section (ladder growth) and removed cases are reported but never
fail.
"""
from __future__ import annotations

import argparse
import json
import sys

GATED_SECTIONS = (
    "precond_records", "fig3_records", "batched_records", "exchange_records"
)


def _key(section: str, r: dict) -> tuple:
    if section == "precond_records":
        # coefficient joined the key in pr10; rows predating it (and the
        # constant-λ rows after it) are the "const" family, so old
        # baselines keep matching byte-for-byte
        return (
            r["n"], r["lam"], r["kind"], r.get("dtype", "fp64"),
            r.get("coefficient", "const"),
        )
    if section == "batched_records":
        return (
            r["n"], r["lam"], r["kind"], r.get("dtype", "fp64"), r["batch"]
        )
    if section == "exchange_records":
        return (r["site"], r.get("n", 0))
    return (r["n"],)


def _fmt_key(section: str, key: tuple) -> str:
    if section == "precond_records":
        n, lam, kind, dtype, coefficient = key
        coef = "" if coefficient == "const" else f" k={coefficient}"
        return f"N={n} lam={lam} {kind:>16} [{dtype}]{coef}"
    if section == "batched_records":
        n, lam, kind, dtype, batch = key
        return f"N={n} lam={lam} {kind:>16} [{dtype}] B={batch}"
    if section == "exchange_records":
        site, n = key
        return f"{site:>12} N={n}"
    return f"N={key[0]}"


def load_summary(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def load_records(path: str) -> dict[tuple, int]:
    """Iteration counts keyed by case (kept for tooling that imports it)."""
    recs = load_summary(path).get("precond_records", [])
    if not recs:
        raise SystemExit(f"{path}: no precond_records section")
    return {_key("precond_records", r): int(r["iters_to_tol"]) for r in recs}


def compare_section(
    section: str,
    base: list[dict],
    cand: list[dict],
    *,
    slack: int,
    roofline_slack: float,
) -> list[str]:
    """Print the per-case comparison; return failure descriptions."""
    bmap = {_key(section, r): r for r in base}
    cmap = {_key(section, r): r for r in cand}
    shared = sorted(set(bmap) & set(cmap))
    failures: list[str] = []
    # candidate-side status gate: every row, shared or new — a
    # non-converged solve is invalid as a benchmark number regardless of
    # what the baseline says.  Missing status = legacy-converged.
    for key in sorted(cmap):
        status = cmap[key].get("status", "converged")
        if status != "converged":
            label = _fmt_key(section, key)
            print(f"{'REGRESSION':>10}  {section[:-8]} {label}: status={status}")
            failures.append(f"{section} {label}: status={status}")
        if section == "exchange_records":
            # winner-validity gate: the recorded routing must be the argmin
            # of the record's own timing sweep (over every wire candidate of
            # that routing)
            r = cmap[key]
            timings = r.get("timings") or {}
            if timings:
                best = min(timings, key=timings.get).split("/")[0]
                if r.get("routing") != best:
                    label = _fmt_key(section, key)
                    print(
                        f"{'REGRESSION':>10}  {section[:-8]} {label}: "
                        f"winner {r.get('routing')} is not the timed best "
                        f"({best})"
                    )
                    failures.append(f"{section} {label}: invalid winner")
    for key in shared:
        b, c = bmap[key], cmap[key]
        label = _fmt_key(section, key)
        msgs = []
        bad = False
        if section == "exchange_records":
            msgs.append(f"winner {b.get('routing')} -> {c.get('routing')}")
        if "iters_to_tol" in b and "iters_to_tol" in c:
            delta = int(c["iters_to_tol"]) - int(b["iters_to_tol"])
            msgs.append(
                f"iters {b['iters_to_tol']} -> {c['iters_to_tol']} ({delta:+d})"
            )
            if delta > slack:
                bad = True
        if b.get("pct_roofline") is not None and c.get("pct_roofline") is not None:
            drop = float(b["pct_roofline"]) - float(c["pct_roofline"])
            msgs.append(
                f"roofline {b['pct_roofline']:.1f}% -> "
                f"{c['pct_roofline']:.1f}% ({-drop:+.1f}pt)"
            )
            if drop > roofline_slack:
                bad = True
        marker = "REGRESSION" if bad else "ok"
        print(f"{marker:>10}  {section[:-8]} {label}: {', '.join(msgs)}")
        if bad:
            failures.append(f"{section} {label}")
    for key in sorted(set(cmap) - set(bmap)):
        print(f"{'new':>10}  {section[:-8]} {_fmt_key(section, key)}")
    for key in sorted(set(bmap) - set(cmap)):
        print(f"{'removed':>10}  {section[:-8]} {_fmt_key(section, key)}")
    if not shared:
        failures.append(f"{section}: no shared cases to compare")
    return failures


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous PR's BENCH json")
    ap.add_argument("candidate", help="this PR's BENCH json")
    ap.add_argument(
        "--slack",
        type=int,
        default=0,
        help="allowed extra iterations per case (default 0)",
    )
    ap.add_argument(
        "--roofline-slack",
        type=float,
        default=5.0,
        help="allowed pct_roofline drop in percentage points (default 5)",
    )
    ap.add_argument(
        "--allow-new-sections",
        action="store_true",
        help="don't fail when the baseline predates a gated section",
    )
    args = ap.parse_args(argv)

    base = load_summary(args.baseline)
    cand = load_summary(args.candidate)

    failures: list[str] = []
    compared = 0
    for section in GATED_SECTIONS:
        in_base, in_cand = bool(base.get(section)), bool(cand.get(section))
        if in_cand and not in_base:
            if args.allow_new_sections:
                print(f"{'new-section':>11}  {section} (baseline predates it)")
                continue
            print(
                f"error: baseline {args.baseline} has no {section!r} but the "
                f"candidate does; rerun the baseline or pass "
                f"--allow-new-sections"
            )
            return 1
        if in_base and not in_cand:
            print(
                f"error: candidate {args.candidate} dropped the {section!r} "
                f"section present in {args.baseline}"
            )
            return 1
        if not in_base:
            continue
        compared += 1
        failures.extend(
            compare_section(
                section,
                base[section],
                cand[section],
                slack=args.slack,
                roofline_slack=args.roofline_slack,
            )
        )

    if compared == 0:
        print("error: no gated record sections found in either json")
        return 1
    if failures:
        print(f"\n{len(failures)} regression(s) vs {args.baseline}:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(
        f"\nall shared cases within slack (iters={args.slack}, "
        f"roofline={args.roofline_slack}pt)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
