"""Architecture registry: ``--arch <id>`` resolves here.

Ten assigned LM architectures (full + reduced smoke variants) plus the
paper's own hipBone Poisson configs.
"""
from repro.models.config import ModelConfig

from . import (
    chameleon_34b,
    command_r_35b,
    deepseek_v3_671b,
    gemma3_1b,
    gemma_2b,
    hipbone,
    jamba_v01_52b,
    mamba2_780m,
    mixtral_8x7b,
    musicgen_medium,
    yi_9b,
)

_MODULES = {
    "chameleon-34b": chameleon_34b,
    "mamba2-780m": mamba2_780m,
    "command-r-35b": command_r_35b,
    "gemma3-1b": gemma3_1b,
    "gemma-2b": gemma_2b,
    "yi-9b": yi_9b,
    "mixtral-8x7b": mixtral_8x7b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "jamba-v0.1-52b": jamba_v01_52b,
    "musicgen-medium": musicgen_medium,
}

ARCHS: dict[str, ModelConfig] = {k: m.CONFIG for k, m in _MODULES.items()}
REDUCED: dict[str, ModelConfig] = {k: m.REDUCED for k, m in _MODULES.items()}
POISSON = hipbone.CONFIGS

# assignment shape table: (seq_len, global_batch, step kind)
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, step="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, step="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, step="decode"),
}


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    table = REDUCED if reduced else ARCHS
    if arch not in table:
        raise KeyError(f"unknown arch '{arch}'; have {sorted(table)}")
    return table[arch]


def long_context_eligible(cfg: ModelConfig) -> bool:
    """long_500k runs only for sub-quadratic archs (DESIGN.md skip list)."""
    return cfg.sub_quadratic
