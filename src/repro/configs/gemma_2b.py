"""gemma-2b [dense] — arXiv:2403.08295.

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000. GeGLU,
head_dim=256, tied embeddings, sqrt(d) embedding scale, (1+w) RMSNorm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    act="gelu",
    gemma_norm=True,
    emb_scale=True,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-2b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    act="gelu",
    gemma_norm=True,
    emb_scale=True,
    tie_embeddings=True,
    dtype="float32",
)
