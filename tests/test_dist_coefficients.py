"""Sharded variable-coefficient parity: dist_cg vs the single-device solve.

The acceptance bar for the variable-coefficient operator (PR10) is
iteration-for-iteration parity: a sharded solve with k(x)/λ(x) fields and
mixed BCs must report *exactly* the same CG iteration count as the
single-device solve of the identical global problem, for every ladder rung
(jacobi / chebyshev / schwarz / pmg-galerkin_mat) and again with the whole
preconditioner chain demoted to fp32 (flexible PCG).  Anything looser
would let a partitioning bug hide behind "close enough" convergence.

Each test runs one subprocess with 8 fake CPU devices (a 2x2x2 rank grid,
2x1x1 elements per rank) and loops the rung matrix inside it so the mesh /
reference-problem setup is paid once.  Slow-marked: the distributed pMG
jit compile dominates the runtime.
"""
import pytest

from conftest import run_subprocess

# Shared subprocess preamble: builds the global reference problem and the
# matching dist problem, partitions fields/vectors into halo-first box
# order, and defines check() asserting exact iteration parity + solution
# agreement.  {checks} is replaced per-test with the rung matrix.
_TEMPLATE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg, _ordered_elements
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.mesh import partition_elements
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
mesh = make_mesh((8,), ("ranks",))
rng = np.random.default_rng(0)
GX, GY = gshape[0]*N+1, gshape[1]*N+1

def box_from_global(prob, vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz),
                              indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2, 1, 0).reshape(-1)]
    return out

def box_partition_field(field):
    # partition an (E, p) element field into (R, E_loc, p) halo-first order
    ordered, _ = _ordered_elements(local)
    out = np.zeros((grid.size, local[0]*local[1]*local[2], field.shape[1]))
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ex = ordered[:, 0] + ci*local[0]
        ey = ordered[:, 1] + cj*local[1]
        ez = ordered[:, 2] + ck*local[2]
        gid = ex + gshape[0]*(ey + gshape[1]*ez)
        out[r] = field[gid]
    return out

def check(coef, bc, kind, pdtype=None, variant="standard", **kw):
    cname = None if coef == "const" else coef
    ref = build_problem(N, gshape, lam=0.8, coefficient=cname, bc=bc,
                        dtype=jnp.float64)
    A = poisson_assembled(ref)
    prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64,
                              coefficient=cname, bc=bc)
    if ref.k is not None:
        k_part = box_partition_field(np.asarray(ref.k, np.float64))
        assert np.array_equal(k_part, prob.k), (coef, "k field mismatch")
    bg = rng.standard_normal(ref.n_global)
    if ref.mask is not None:
        bg = bg * np.asarray(ref.mask, np.float64)
    b_boxes = jnp.asarray(box_from_global(prob, bg))
    dkw = dict(kw)
    skw = {}
    if kind == "pmg" and kw.get("pmg_coarse_op") == "galerkin_mat":
        skw["pmg_coarse_op"] = "galerkin_mat"
    if kind == "pmg":
        # force the same iterative coarse solve on both sides so the rung
        # is comparable down to the last digit
        skw["pmg_coarse_solve"] = "chebyshev"
        skw["pmg_coarse_iters"] = 16
        dkw["pmg_coarse_iters"] = 16
    if kind == "schwarz":
        skw["schwarz_overlap"] = dkw["schwarz_overlap"] = 1
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond=kind, cheb_degree=2,
                          precond_dtype=pdtype, cg_variant=variant, **dkw))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(status) == 0, (coef, bc, kind, "status", int(status))
    pc, info = make_preconditioner(kind, ref, A, degree=2,
                                   precond_dtype=pdtype, **skw)
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc,
                       cg_variant=variant)
    err = np.abs(np.array(x_boxes) - box_from_global(prob,
                                                     np.array(res.x))).max()
    tag = (coef, bc, kind, None if pdtype is None else "fp32")
    print(tag, "dist", int(iters), "single", int(res.iterations),
          "err %.2e" % err)
    assert int(iters) == int(res.iterations), tag
    assert err < 1e-8, (tag, err)

{checks}
print("PARITY-OK")
"""

_FP64_CHECKS = """
# every rung under smooth k(x)/lam(x) with mixed BCs
for kind, kw in [("jacobi", {}), ("chebyshev", {}), ("schwarz", {}),
                 ("pmg", {"pmg_coarse_op": "galerkin_mat"})]:
    check("smooth", "mixed", kind, **kw)
# bc machinery alone (constant coefficients)
check("const", "dirichlet", "jacobi")
# jump coefficients
check("checker", "dirichlet", "pmg", pmg_coarse_op="galerkin_mat")
# legacy const/no-bc path stays in parity too
check("const", None, "jacobi")
"""

_FP32_CHECKS = """
# fp32 preconditioner chains inside the fp64 flexible PCG
for kind, kw in [("jacobi", {}), ("chebyshev", {}), ("schwarz", {}),
                 ("pmg", {"pmg_coarse_op": "galerkin_mat"})]:
    check("smooth", "mixed", kind, pdtype=jnp.float32, variant="flexible",
          **kw)
"""


@pytest.mark.slow
def test_dist_parity_variable_coefficient_fp64():
    code = _TEMPLATE.replace("{checks}", _FP64_CHECKS)
    out = run_subprocess(code, timeout=3500)
    assert "PARITY-OK" in out


@pytest.mark.slow
def test_dist_parity_variable_coefficient_fp32_chain():
    code = _TEMPLATE.replace("{checks}", _FP32_CHECKS)
    out = run_subprocess(code, timeout=3500)
    assert "PARITY-OK" in out
