#!/usr/bin/env python
"""Verify that markdown cross-references in this repo resolve.

Usage:
    python scripts/check_links.py [--no-code-refs] [files...]
    # default files: README + ROADMAP + docs/

Checks every ``[text](target)`` markdown link and every backtick reference:

  * relative file links (``docs/SOLVERS.md``, ``src/repro/core/precond.py``)
    must exist on disk (anchors after ``#`` are stripped);
  * **code references** (the stricter mode, on by default): backtick
    tokens that look like code must resolve against the source tree —
    dotted module paths (``repro.core.galerkin``, ``benchmarks.run``) must
    map to a module file/package, and identifier references (public
    symbols like ``dist_cg_scattered``, config knobs like
    ``pmg_coarse_op``, env vars like ``HIPBONE_FUSED``) must appear as a
    word somewhere under src/, scripts/, benchmarks/, examples/, tests/ or
    .github/ — so renaming a symbol without updating the docs fails CI;
  * http(s) URLs are *not* fetched (CI runs offline) — only syntax-checked.

Only the *leading* dotted identifier of a backtick span is checked (so
``make_preconditioner(kind, prob, a)`` checks ``make_preconditioner``),
bare identifiers are checked only when they contain an underscore (plain
words like ``direct`` or ``pmg`` are prose, not references), and spans
containing ``<``/``>``/``*`` placeholders (``BENCH_pr<k>.json``) are
descriptive and skipped.

Exit 1 with a per-file report if anything dangles, so the docs cannot
drift from the tree they describe.
"""
from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = [
    "README.md",
    "ROADMAP.md",
    "docs/ARCHITECTURE.md",
    "docs/SOLVERS.md",
    "docs/BENCHMARKS.md",
]
# where code-reference identifiers must live
SOURCE_DIRS = ("src", "scripts", "benchmarks", "examples", "tests", ".github")
SOURCE_SUFFIXES = {".py", ".yml", ".yaml", ".toml", ".md", ".json"}

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick references that look like repo paths (contain a slash and a dot)
TICK_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.[A-Za-z0-9]+)`")
TICK_ANY = re.compile(r"`([^`\n]+)`")
LEADING_IDENT = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*(?:\.[A-Za-z_][A-Za-z0-9_]*)*)")


def _display(md: Path) -> str:
    try:
        return str(md.resolve().relative_to(REPO))
    except ValueError:
        return str(md)


def _source_universe() -> str:
    """Concatenated source text the identifier references resolve against."""
    chunks = []
    for d in SOURCE_DIRS:
        root = REPO / d
        if not root.exists():
            continue
        for f in sorted(root.rglob("*")):
            if f.is_file() and f.suffix in SOURCE_SUFFIXES:
                try:
                    chunks.append(f.read_text())
                except UnicodeDecodeError:
                    pass
    chunks.append((REPO / "pyproject.toml").read_text())
    return "\n".join(chunks)


def _module_candidates(parts: list[str]) -> list[Path]:
    """Places a dotted module prefix may live (``repro.`` maps to src/)."""
    rel = "/".join(parts)
    cands = [REPO / f"{rel}.py", REPO / rel]
    cands += [REPO / "src" / f"{rel}.py", REPO / "src" / rel]
    if parts and parts[0] != "repro":
        cands += [
            REPO / "src" / "repro" / f"{rel}.py",
            REPO / "src" / "repro" / rel,
        ]
    return cands


def check_code_ref(token: str, universe: str) -> str | None:
    """Resolve one leading dotted identifier; return an error or None."""
    parts = token.split(".")
    if len(parts) > 1:
        # dotted: accept any prefix resolving to a module file/package whose
        # remaining attribute parts appear in the source universe
        for k in range(len(parts), 0, -1):
            if any(c.exists() for c in _module_candidates(parts[:k])):
                missing = [
                    a
                    for a in parts[k:]
                    if not re.search(rf"\b{re.escape(a)}\b", universe)
                ]
                if missing:
                    return f"module {'.'.join(parts[:k])} lacks {missing}"
                return None
        # external libs (jnp.float32, lax.psum): final attribute must at
        # least occur in the source — docs shouldn't cite calls we never make
        if re.search(rf"\b{re.escape(parts[-1])}\b", universe):
            return None
        return f"attribute {parts[-1]!r} not found in source tree"
    if "_" not in token:
        return None  # plain word — prose, not a reference
    if re.search(rf"\b{re.escape(token)}\b", universe):
        return None
    return f"identifier {token!r} not found in source tree"


def check_file(md: Path, universe: str | None) -> list[str]:
    errors = []
    text = md.read_text()
    targets = []
    for match in MD_LINK.finditer(text):
        targets.append((match.group(1), "link"))
    tick_paths = set()
    for match in TICK_PATH.finditer(text):
        targets.append((match.group(1), "backtick path"))
        tick_paths.add(match.group(1))
    for target, kind in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue  # pure intra-document anchor
        # glob-ish references ("src/repro/configs/*.py") are descriptive
        if any(ch in path for ch in "*<>"):
            continue
        candidates = (
            (md.parent / path).resolve(),
            (REPO / path).resolve(),
            # module shorthand: `core/precond.py` means the package path
            (REPO / "src" / "repro" / path).resolve(),
        )
        if not any(c.exists() for c in candidates):
            errors.append(f"{_display(md)}: dangling {kind} -> {target}")

    if universe is None:
        return errors

    seen = set()
    for match in TICK_ANY.finditer(text):
        span = match.group(1)
        if span in tick_paths or any(ch in span for ch in "*<>"):
            continue
        ident = LEADING_IDENT.match(span)
        if not ident:
            continue
        token = ident.group(1)
        if token in seen:
            continue
        seen.add(token)
        # repo files referenced without a slash (BENCH_pr4.json)
        if (REPO / token).exists():
            continue
        err = check_code_ref(token, universe)
        if err:
            errors.append(f"{_display(md)}: dangling code ref `{span}`: {err}")
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("files", nargs="*")
    ap.add_argument(
        "--no-code-refs",
        action="store_true",
        help="skip the backtick code-reference resolution (links only)",
    )
    args = ap.parse_args()
    # relative CLI paths resolve against the repo root, not the cwd
    files = [
        Path(a) if Path(a).is_absolute() else REPO / a for a in args.files
    ] or [REPO / rel for rel in DEFAULT if (REPO / rel).exists()]
    universe = None if args.no_code_refs else _source_universe()
    all_errors = []
    for md in files:
        if not md.exists():
            all_errors.append(f"missing file: {md}")
            continue
        all_errors.extend(check_file(md, universe))
    for err in all_errors:
        print(err)
    if all_errors:
        print(f"\n{len(all_errors)} dangling reference(s)")
        return 1
    mode = "links only" if args.no_code_refs else "links + code refs"
    print(f"all references resolve in {len(files)} file(s) ({mode})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
