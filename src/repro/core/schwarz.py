"""Overlapping additive-Schwarz smoothing via tensor-product fast diagonalization.

The next rung of the preconditioner ladder after Chebyshev–Jacobi and
p-multigrid: per-element *extended-block* local solves, the smoother that
gives production Nek5000/RS its robustness on deformed / ill-conditioned
meshes (Min et al. 2023).  Each element solves a local screened-Poisson
problem on its own nodes plus ``overlap`` GLL node layers borrowed from
every face neighbor; the solves are exact inverses of a separable
(tensor-product) approximation of the local operator, applied in O(m^4)
per element via the 1-D fast diagonalization of :mod:`core.sem`:

    Â_e = A⊗B⊗B + B⊗A⊗B + B⊗B⊗A + λ·I           (per-direction 1-D A, B)
    Â_e⁻¹ = (T⊗T⊗T) diag(1/(μ_i+μ_j+μ_k+λ s_i s_j s_k)) (T⊗T⊗T)ᵀ

with ``(T_d, μ_d, s_d)`` from ``sem.fast_diagonalization_1d`` of the
extended-interval matrices.  Deformed elements are approximated by an
axis-aligned box with the element's mean directional lengths — the same
approximation Nek makes; the Schwarz apply is a *preconditioner*, so the
approximation error only costs CG iterations, never correctness.

The global apply is symmetric weighted additive Schwarz,

    M⁻¹ = W½ Z_sᵀ blkdiag(Â_e⁻¹) Z_s W½,

where ``Z_s`` is the *extended* scatter (each block also reads its overlap
nodes) and ``W`` the inverse overlap-count weights.  Symmetric weighting
keeps M⁻¹ SPD so plain PCG remains valid; ``weighting="post"`` gives the
classical RAS variant (weights on the output only — slightly stronger per
application but nonsymmetric, for flexible/Richardson use only).

Overlap transport reuses the existing machinery: single-device blocks read
through an extended local-to-global map (a dummy index absorbs
out-of-domain slots); the sharded path (core.distributed) feeds the same
solves from a shell-expanded padded box filled by ``comms.halo``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import sem
from .gather_scatter import gather_masked, scatter_masked

__all__ = [
    "SCHWARZ_INNER_DEGREE",
    "SCHWARZ_WEIGHTINGS",
    "SchwarzFDM",
    "element_lengths",
    "element_neighbor_flags",
    "element_screen_means",
    "build_fdm",
    "fdm_solve",
    "extended_l2g",
    "overlap_counts_1d",
    "overlap_counts_global",
    "make_schwarz_apply",
]

SCHWARZ_WEIGHTINGS = ("sqrt", "post", "none")
# Default Chebyshev degree of the in-eigenbasis block solve.  The algebraic
# screen λI is the one term of the local operator that pure tensor structure
# cannot diagonalize (see build_fdm); degree 7 brings the blocks within a
# few percent of their exact inverses at roughly 4x the bare-FDM apply cost.
SCHWARZ_INNER_DEGREE = 7


@dataclasses.dataclass(frozen=True)
class SchwarzFDM:
    """Per-element fast-diagonalization factors for the extended blocks.

    In the tensor eigenbasis ``T₃⊗T₂⊗T₁`` the local screened operator is

        H = diag(μ_i + μ_j + μ_k) + λ (C₃⊗C₂⊗C₁),   C_d = T_dᵀT_d,

    exactly (``TᵀBT = I`` turns the separable stiffness into the diagonal
    part; the algebraic screen ``λI`` becomes the ``C`` product, which does
    NOT diagonalize — NekBone's screen is the one term that breaks pure
    tensor structure).  The block solve is a fixed-degree Chebyshev
    iteration on ``H`` preconditioned by ``diag(H)⁻¹``, run entirely in the
    eigenbasis: one forward/backward transform pair plus ``inner_degree``
    cheap ``C``-contractions.  ``inner_degree = 1`` is the bare diagonal
    approximation; 2-3 recovers most of the exact-block quality.

    Attributes:
      tmats: (E, 3, m, m) eigenvector matrices, direction order (r, s, t).
      cmats: (E, 3, m, m) Gram matrices ``C_d = T_dᵀT_d``.
      denom_inv: (E, m, m, m) ``1/diag(H)`` in (t, s, r) order.
      musum: (E, m, m, m) tensor eigenvalue sums ``μ_i + μ_j + μ_k``.
      inner_lo / inner_hi: (E,) per-element Chebyshev interval for the
        diagonally-preconditioned ``H`` (setup-time power iteration).
      lam: screen parameter λ — a float (legacy algebraic screen) or an
        (E, 1, 1, 1) per-element array (variable-coefficient blocks),
        broadcasting identically through ``fdm_solve``'s hop.
      overlap: extension width s (m = N + 1 + 2s).
      inner_degree: Chebyshev degree of the block solve.
    """

    tmats: jax.Array
    cmats: jax.Array
    denom_inv: jax.Array
    musum: jax.Array
    inner_lo: jax.Array
    inner_hi: jax.Array
    lam: jax.Array | float
    overlap: int
    inner_degree: int

    @property
    def m(self) -> int:
        return int(self.tmats.shape[-1])


def element_lengths(coords: np.ndarray, n_degree: int) -> np.ndarray:
    """(E, 3) mean physical element lengths along (r, s, t).

    ``coords``: (E, (N+1)^3, 3) node coordinates in (t, s, r) order.  Each
    length is the Euclidean end-to-end distance along one reference
    direction, averaged over the transverse nodes — the axis-aligned-box fit
    of a (possibly deformed) element that the separable FDM operator uses.
    """
    e = coords.shape[0]
    n1 = int(n_degree) + 1
    c3 = coords.reshape(e, n1, n1, n1, 3)  # (E, t, s, r, 3)
    out = np.empty((e, 3))
    for d, axis in enumerate((3, 2, 1)):  # r, s, t
        lo = np.take(c3, 0, axis=axis)
        hi = np.take(c3, n1 - 1, axis=axis)
        out[:, d] = np.linalg.norm(hi - lo, axis=-1).mean(axis=(1, 2))
    return out


def element_neighbor_flags(
    elem_idx: np.ndarray, grid_shape: tuple[int, int, int]
) -> np.ndarray:
    """(E, 3, 2) booleans: does element ``(i, j, k)`` have a (lo, hi)
    neighbor along each direction of the *global* element grid?

    ``elem_idx``: (E, 3) integer element coordinates in the global grid
    (single-device meshes pass 0..shape-1; sharded callers pass rank-offset
    coordinates so rank boundaries correctly count as interior).
    """
    out = np.empty((elem_idx.shape[0], 3, 2), dtype=bool)
    for d in range(3):
        out[:, d, 0] = elem_idx[:, d] > 0
        out[:, d, 1] = elem_idx[:, d] < grid_shape[d] - 1
    return out


def _cprod_apply(
    cr: np.ndarray, cs: np.ndarray, ct: np.ndarray, v: np.ndarray
) -> np.ndarray:
    """(C₃⊗C₂⊗C₁) v on (E, m, m, m) arrays in (t, s, r) order (numpy)."""
    v = np.einsum("eai,etsi->etsa", cr, v)
    v = np.einsum("ebj,etjr->etbr", cs, v)
    v = np.einsum("eck,eksr->ecsr", ct, v)
    return v


def build_fdm(
    lengths: np.ndarray,
    flags: np.ndarray,
    n_degree: int,
    lam,
    overlap: int,
    dtype,
    *,
    inner_degree: int = SCHWARZ_INNER_DEGREE,
    k_elem: np.ndarray | None = None,
    screen: str = "algebraic",
) -> SchwarzFDM:
    """Assemble the per-element FDM factors (numpy setup, cast once).

    Args:
      lengths: (E, 3) directional element lengths (:func:`element_lengths`).
      flags: (E, 3, 2) neighbor-present booleans
        (:func:`element_neighbor_flags`).
      n_degree: polynomial degree N.
      lam: screen parameter λ — a scalar, or an (E,) per-element array
        (element means of a λ(x) field).  The screen keeps every block SPD
        even on an all-Neumann single-element patch where the stiffness
        alone is singular (a tiny floor guards λ = 0).
      overlap: extension width s in GLL nodes (0 = block Jacobi).
      inner_degree: Chebyshev degree of the in-eigenbasis block solve
        (1 = pure diagonal/fast-diagonalization approximation of the
        screen; 2-3 nearly exact).  The per-element Chebyshev interval is
        estimated here by power iteration on the diagonally-preconditioned
        block operator — pure setup-time numpy.
      k_elem: optional (E,) element-mean diffusion coefficients.  Each
        block approximates k_e·(-Δ) by scaling its tensor eigenvalue sums
        — the same axis-aligned-box spirit as the mean-length fit, and
        exact for per-element-constant k (the checker family).
      screen: "algebraic" — the legacy λI screen, which becomes the
        non-diagonal ``λ(C₃⊗C₂⊗C₁)`` in the eigenbasis; "mass" — the weak
        λ·M screen of variable-coefficient problems.  Because the
        eigenbasis is B-orthonormal (``TᵀBT = I``), the mass screen is
        *exactly* λ·I in-basis — implemented by setting the Gram matrices
        C to the identity, which makes the block solve exactly diagonal
        (the one term that breaks tensor structure disappears).

    Returns:
      :class:`SchwarzFDM` with jnp arrays in ``dtype``.
    """
    if screen not in ("algebraic", "mass"):
        raise ValueError(f"unknown fdm screen {screen!r}; 'algebraic'|'mass'")
    e_total = lengths.shape[0]
    n = int(n_degree)
    m = n + 1 + 2 * int(overlap)
    lam_arr = np.asarray(lam, np.float64)
    if lam_arr.ndim == 0:
        lam = float(lam_arr)  # scalar stays a python float (legacy contract)
    elif lam_arr.shape == (e_total,):
        lam = lam_arr[:, None, None, None]  # broadcasts through hop / denom
    else:
        raise ValueError(
            f"lam must be a scalar or ({e_total},) element array, "
            f"got shape {lam_arr.shape}"
        )
    tmats = np.empty((e_total, 3, m, m))
    cmats = np.empty((e_total, 3, m, m))
    mus = np.empty((e_total, 3, m))
    # identical (h, flags) tuples share one eigendecomposition — on regular
    # meshes that is a single factorization for the whole grid
    cache: dict[tuple, tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
    for e in range(e_total):
        for d in range(3):
            key = (round(float(lengths[e, d]), 12), bool(flags[e, d, 0]),
                   bool(flags[e, d, 1]))
            if key not in cache:
                a_ext, b_ext = sem.extended_interval_matrices(
                    n, overlap, key[0], has_lo=key[1], has_hi=key[2]
                )
                cache[key] = sem.fast_diagonalization_1d(a_ext, b_ext)
            t, mu, _ = cache[key]
            tmats[e, d], mus[e, d] = t, mu
            cmats[e, d] = (
                np.eye(m) if screen == "mass" else t.T @ t
            )

    mu_r, mu_s, mu_t = mus[:, 0], mus[:, 1], mus[:, 2]
    musum = (
        mu_t[:, :, None, None] + mu_s[:, None, :, None] + mu_r[:, None, None, :]
    )
    if k_elem is not None:
        musum = musum * np.asarray(k_elem, np.float64)[:, None, None, None]
    s_r, s_s, s_t = (np.einsum("eii->ei", cmats[:, d]) for d in range(3))
    denom = musum + lam * (
        s_t[:, :, None, None] * s_s[:, None, :, None] * s_r[:, None, None, :]
    )
    # λ=0 on an all-Neumann patch leaves the constant mode at exactly 0;
    # floor it so the pseudo-inverse-like apply stays finite
    denom = np.maximum(denom, 1e-12 * denom.max())
    dinv = 1.0 / denom

    # Chebyshev interval of diag(H)⁻¹H per element: its spectrum lies in
    # [1 - r, 1 + r] (H SPD with unit preconditioned diagonal), with r the
    # dominant |eigenvalue| of diag(H)⁻¹H - I from a few power steps.
    rng = np.random.default_rng(12)
    x = rng.standard_normal((e_total, m, m, m))
    r_est = np.ones(e_total)
    cr, cs, ct = cmats[:, 0], cmats[:, 1], cmats[:, 2]
    for _ in range(30):
        y = dinv * (musum * x + lam * _cprod_apply(cr, cs, ct, x)) - x
        nrm = np.sqrt((y * y).sum(axis=(1, 2, 3)))
        r_est = nrm / np.maximum(
            np.sqrt((x * x).sum(axis=(1, 2, 3))), 1e-300
        )
        x = y / np.maximum(nrm, 1e-300)[:, None, None, None]
    hi = 1.0 + 1.05 * r_est
    lo = np.maximum(1.0 - 1.05 * r_est, 0.05 * hi)
    # λ=0 (or any exactly-diagonal H) collapses the interval to a point;
    # widen it symmetrically so the Chebyshev recurrence stays finite while
    # the interval midpoint — which alone enters the degree-1 stage — is
    # untouched (the solve is exact after that first stage in this case)
    mid, half = 0.5 * (hi + lo), 0.5 * (hi - lo)
    half = np.maximum(half, 1e-3 * mid)
    lo, hi = mid - half, mid + half

    return SchwarzFDM(
        tmats=jnp.asarray(tmats, dtype),
        cmats=jnp.asarray(cmats, dtype),
        denom_inv=jnp.asarray(dinv, dtype),
        musum=jnp.asarray(musum, dtype),
        inner_lo=jnp.asarray(lo[:, None, None, None], dtype),
        inner_hi=jnp.asarray(hi[:, None, None, None], dtype),
        lam=lam if isinstance(lam, float) else jnp.asarray(lam, dtype),
        overlap=int(overlap),
        inner_degree=int(inner_degree),
    )


def fdm_solve(fdm: SchwarzFDM, u: jax.Array) -> jax.Array:
    """Batched extended-block solves ``Â_e⁻¹ u_e`` via tensor contractions.

    ``u``: (E, m^3) extended-block right-hand sides in (t, s, r) node order.
    One transform pair into/out of the tensor eigenbasis (three batched
    contractions each — the operator's MXU pattern with per-element
    matrices) around a degree-``inner_degree`` diagonally-preconditioned
    Chebyshev solve of the in-basis block operator ``H``.  The iteration
    is a fixed polynomial ``q(D⁻¹H) D⁻¹`` per element, hence a symmetric
    linear map — the Schwarz apply stays PCG-valid.
    """
    from .precond import chebyshev_apply  # local import (precond imports us)

    e = u.shape[0]
    m = fdm.m
    tr, ts, tt = fdm.tmats[:, 0], fdm.tmats[:, 1], fdm.tmats[:, 2]
    cr, cs, ct = fdm.cmats[:, 0], fdm.cmats[:, 1], fdm.cmats[:, 2]
    u3 = u.reshape(e, m, m, m)
    # into the eigenbasis: Tᵀ along each direction
    u3 = jnp.einsum("eai,etsa->etsi", tr, u3)
    u3 = jnp.einsum("ebj,etbr->etjr", ts, u3)
    u3 = jnp.einsum("eck,ecsr->eksr", tt, u3)

    def hop(v: jax.Array) -> jax.Array:
        cv = jnp.einsum("eai,etsi->etsa", cr, v)
        cv = jnp.einsum("ebj,etjr->etbr", cs, cv)
        cv = jnp.einsum("eck,eksr->ecsr", ct, cv)
        return fdm.musum * v + fdm.lam * cv

    # the (E,1,1,1) per-element intervals broadcast through the shared
    # semi-iteration: E independent Chebyshev solves in one trace
    solve = chebyshev_apply(
        hop,
        lambda v: fdm.denom_inv * v,
        fdm.inner_hi,
        lmin=fdm.inner_lo,
        degree=fdm.inner_degree,
    )
    z = solve(u3)

    # back out: T along each direction
    z = jnp.einsum("eai,etsi->etsa", tr, z)
    z = jnp.einsum("ebj,etjr->etbr", ts, z)
    z = jnp.einsum("eck,eksr->ecsr", tt, z)
    return z.reshape(e, -1)


def extended_l2g(
    n_degree: int, shape: tuple[int, int, int], overlap: int
) -> np.ndarray:
    """Extended local-to-global map Z_s for a single-device box mesh.

    (E, m^3) int32 with m = N+1+2s; entry = global DOF of each extended
    block node, or the dummy index ``n_global`` for out-of-domain slots
    (callers scatter from a zero-padded vector and drop the dummy segment
    on the gather).  Element and node orderings match ``mesh.build_box_mesh``.
    """
    ex, ey, ez = shape
    n = int(n_degree)
    s = int(overlap)
    gx, gy, gz = ex * n + 1, ey * n + 1, ez * n + 1
    n_global = gx * gy * gz

    a = np.arange(-s, n + s + 1)
    la, lb, lc = np.meshgrid(a, a, a, indexing="ij")  # (r, s, t)
    loc_a = la.transpose(2, 1, 0).reshape(-1)
    loc_b = lb.transpose(2, 1, 0).reshape(-1)
    loc_c = lc.transpose(2, 1, 0).reshape(-1)

    ei, ej, ek = np.meshgrid(
        np.arange(ex), np.arange(ey), np.arange(ez), indexing="ij"
    )
    ei = ei.transpose(2, 1, 0).reshape(-1)
    ej = ej.transpose(2, 1, 0).reshape(-1)
    ek = ek.transpose(2, 1, 0).reshape(-1)

    gxi = ei[:, None] * n + loc_a[None, :]
    gyj = ej[:, None] * n + loc_b[None, :]
    gzk = ek[:, None] * n + loc_c[None, :]
    valid = (
        (gxi >= 0) & (gxi < gx)
        & (gyj >= 0) & (gyj < gy)
        & (gzk >= 0) & (gzk < gz)
    )
    l2g = gxi + gx * (gyj + gy * gzk)
    return np.where(valid, l2g, n_global).astype(np.int32)


def overlap_counts_1d(ne: int, n_degree: int, overlap: int) -> np.ndarray:
    """Per-grid-line count of extended element windows along one axis.

    ``counts[q] = #{elements i : i·N - s <= q <= i·N + N + s}`` for the
    global 1-D grid coordinate q — the separable factor of the Schwarz
    overlap multiplicity (the 3-D count is the product over axes), used to
    build the partition-of-unity weights identically on the single-device
    and sharded paths.
    """
    n, s = int(n_degree), int(overlap)
    q = np.arange(ne * n + 1)
    i = np.arange(ne)
    inside = (q[:, None] >= i[None, :] * n - s) & (
        q[:, None] <= i[None, :] * n + n + s
    )
    return inside.sum(axis=1).astype(np.float64)


def overlap_counts_global(
    n_degree: int, shape: tuple[int, int, int], overlap: int
) -> np.ndarray:
    """(N_G,) overlap multiplicity of every assembled DOF (x fastest)."""
    cx = overlap_counts_1d(shape[0], n_degree, overlap)
    cy = overlap_counts_1d(shape[1], n_degree, overlap)
    cz = overlap_counts_1d(shape[2], n_degree, overlap)
    return (
        cz[:, None, None] * cy[None, :, None] * cx[None, None, :]
    ).reshape(-1)


def element_screen_means(prob) -> tuple[np.ndarray | None, object, str]:
    """``(k_elem, lam, screen)`` for :func:`build_fdm` from a problem.

    Element means of the coefficient fields: the Schwarz blocks are already
    an axis-aligned separable *approximation* of each element, so per-block
    mean coefficients are the natural (and for per-element-constant
    families, exact) extension — any residual variation is absorbed by the
    outer Chebyshev/CG like the geometry approximation is.  Legacy
    problems return ``(None, λ, "algebraic")`` — bit-identical factors.
    """
    k_e = (
        None if prob.k is None
        else np.asarray(prob.k, np.float64).mean(axis=1)
    )
    if prob.lam_field is None:
        return k_e, float(prob.lam), "algebraic"
    return k_e, np.asarray(prob.lam_field, np.float64).mean(axis=1), "mass"


def make_schwarz_apply(
    prob,
    *,
    overlap: int = 1,
    weighting: str = "sqrt",
    inner_degree: int = SCHWARZ_INNER_DEGREE,
) -> Callable[[jax.Array], jax.Array]:
    """Single-device overlapping-Schwarz application z = M⁻¹ r.

    Args:
      prob: a ``PoissonProblem`` (assembled storage).
      overlap: extension width s in GLL nodes; 0 degenerates to FDM
        block Jacobi (the blocks still overlap at shared element faces).
      weighting: "sqrt" (default) — symmetric weighted additive Schwarz
        ``W½ Z_sᵀ Â⁻¹ Z_s W½``, SPD, valid for plain PCG; "post" —
        RAS-style output-side weighting ``W Z_sᵀ Â⁻¹ Z_s`` (nonsymmetric);
        "none" — unweighted additive Schwarz (symmetric, overcounts
        overlap regions).
      inner_degree: Chebyshev degree of the in-eigenbasis block solve
        (see :func:`build_fdm`).

    Returns:
      ``apply(r) -> z`` on assembled (N_G,) vectors.
    """
    if weighting not in SCHWARZ_WEIGHTINGS:
        raise ValueError(
            f"unknown weighting {weighting!r}; choose from {SCHWARZ_WEIGHTINGS}"
        )
    mesh = prob.mesh
    k_elem, lam_fdm, screen = element_screen_means(prob)
    fdm = build_fdm(
        element_lengths(mesh.coords, mesh.n_degree),
        element_neighbor_flags(_element_indices(mesh.shape), mesh.shape),
        mesh.n_degree,
        lam_fdm,
        overlap,
        prob.dtype,
        inner_degree=inner_degree,
        k_elem=k_elem,
        screen=screen,
    )
    l2g_ext = jnp.asarray(extended_l2g(mesh.n_degree, mesh.shape, overlap))
    counts = overlap_counts_global(mesh.n_degree, mesh.shape, overlap)
    if weighting == "sqrt":
        w_in = w_out = jnp.asarray(1.0 / np.sqrt(counts), prob.dtype)
    elif weighting == "post":
        w_in, w_out = None, jnp.asarray(1.0 / counts, prob.dtype)
    else:
        w_in = w_out = None
    n_global = prob.n_global
    bc_mask = prob.mask

    def apply(r: jax.Array) -> jax.Array:
        # mask ∘ M ∘ mask keeps the Schwarz apply SPD on the Dirichlet-
        # interior subspace (the extended blocks read across the boundary)
        rw = r if bc_mask is None else bc_mask * r
        rw = rw if w_in is None else w_in * rw
        z = fdm_solve(fdm, scatter_masked(rw, l2g_ext))
        out = gather_masked(z, l2g_ext, n_global)
        out = out if w_out is None else w_out * out
        return out if bc_mask is None else bc_mask * out

    return apply


def _element_indices(shape: tuple[int, int, int]) -> np.ndarray:
    """(E, 3) element grid coordinates in build_box_mesh flat order."""
    ex, ey, ez = shape
    ei, ej, ek = np.meshgrid(
        np.arange(ex), np.arange(ey), np.arange(ez), indexing="ij"
    )
    return np.stack(
        [
            ei.transpose(2, 1, 0).reshape(-1),
            ej.transpose(2, 1, 0).reshape(-1),
            ek.transpose(2, 1, 0).reshape(-1),
        ],
        axis=1,
    )
