"""Figure-of-merit, FLOP counts, data-motion and roofline models (Eqs. 3-6).

All formulas are per the paper, parameterized by element count E, degree N,
and the runtime word size (the paper is FP64; TPUs run FP32/BF16 — the
byte counts scale with ``word`` and the index size stays 4 bytes).
"""
from __future__ import annotations

import dataclasses

__all__ = [
    "nekbone_flops_per_iter",
    "hipbone_flops_per_iter",
    "operator_flops",
    "operator_bytes",
    "assembled_apply_bytes",
    "fused_apply_bytes",
    "cg_iter_bytes",
    "roofline_gflops",
    "fom_gflops",
    "TpuSpec",
    "TPU_V5E",
]


def _np1(n: int) -> int:
    return n + 1


def nekbone_flops_per_iter(e: int, n: int) -> float:
    """Eq. (3): NekBone's historical FLOP count per CG iteration (the FOM)."""
    return 12.0 * e * _np1(n) ** 4 + 34.0 * e * _np1(n) ** 3


def hipbone_flops_per_iter(e: int, n: int) -> float:
    """Eq. (5): hipBone's true FLOP count per CG iteration."""
    return 12.0 * e * _np1(n) ** 4 + 19.0 * e * _np1(n) ** 3 + 10.0 * e * n**3


def operator_flops(e: int, n: int) -> float:
    """Fused (S_L + λW)Z kernel FLOPs: 12E(N+1)^4 + 18E(N+1)^3."""
    return 12.0 * e * _np1(n) ** 4 + 18.0 * e * _np1(n) ** 3


def operator_bytes(e: int, n: int, *, word: int = 8, index: int = 4) -> float:
    """Fused operator data motion, perfect caching: 8 N_G + 68 N_L  (FP64).

    Generalized: word*N_G + (index + 7*word + word)*N_L
      = x_G read + [Z index + 6 G factors + W + y_L write] per local node.
    """
    n_l = e * _np1(n) ** 3
    n_g = e * n**3
    return word * n_g + (index + 8 * word) * n_l


def assembled_apply_bytes(e: int, n: int, *, word: int = 8, index: int = 4) -> float:
    """Full assembled A-apply  y_G = Z^T (S_L + λW) Z x_G, split form.

    The Eq. 4 operator bound plus the gather's CSR traffic: the split
    (scatter → local op → gather) pipeline materializes y_L once, so
      operator (word N_G + (index + 8 word) N_L)
    + gather  (read y_L + CSR cols (word+index) N_L, rows + write b_G
               (word+index) N_G).
    """
    n_l = e * _np1(n) ** 3
    n_g = e * n**3
    op = word * n_g + (index + 8 * word) * n_l
    gather = (word + index) * n_l + (word + index) * n_g
    return op + gather


def fused_apply_bytes(e: int, n: int, *, word: int = 8, index: int = 4) -> float:
    """Single-kernel fused A-apply (kernels/poisson_fused.py) traffic bound.

    The gather, local operator and scatter-add share one pass, so y_L is
    never materialized and the l2g index tile is read once for both the
    gather and the scatter:
      x_G read + y_G write (2 word N_G) + [l2g index + 6 G factors + W]
      per local node ((index + 7 word) N_L).
    """
    n_l = e * _np1(n) ** 3
    n_g = e * n**3
    return 2 * word * n_g + (index + 7 * word) * n_l


def cg_iter_bytes(e: int, n: int, *, word: int = 8, index: int = 4) -> float:
    """Per-CG-iteration data motion, assembled form: 108 N_G + 80 N_L (FP64).

    operator (8 N_G + 68 N_L) + gather (index-CSR 12 N_L + 12 N_G read/write)
    + 11 vector reads/writes (88 N_G). Generalized to ``word`` bytes/value:
    the 4-byte index streams stay fixed.
    """
    n_l = e * _np1(n) ** 3
    n_g = e * n**3
    op = word * n_g + (index + 8 * word) * n_l
    # gather: read y_L (word*N_L) + CSR cols (index*N_L) + CSR rows (index*N_G)
    # + write b_G (word*N_G)
    gather = (word + index) * n_l + (word + index) * n_g
    vectors = 11 * word * n_g
    return op + gather + vectors


def nekbone_iter_bytes(e: int, n: int, *, word: int = 8, index: int = 4) -> float:
    """Per-iteration data motion for the scattered NekBone baseline.

    Everything streams N_L-length vectors; the two inner products also read
    the weight vector; gather-scatter touches y_L twice plus indices.
    Operator: word*(2 N_L) + 7*word N_L + index N_L   (x_L read, 6G+W, y write)
    Gather-scatter ZZ^T: read+write N_L + indices.
    Vector ops: 11 N_L streams + 2 weight reads.
    """
    n_l = e * _np1(n) ** 3
    n_g = e * n**3
    op = (2 + 7) * word * n_l
    gs_bytes = (2 * word + index) * n_l + (word + index) * n_g
    vectors = 11 * word * n_l + 2 * word * n_l  # + weight reads in both dots
    return op + gs_bytes + vectors


def roofline_gflops(
    n: int, *, peak_gflops: float, bandwidth_gbs: float, word: int = 8
) -> float:
    """Eq. (4): modelled operator rate min(C, AI * B) in GFLOPS.

    AI uses per-element counts: (12(N+1)^4 + 18(N+1)^3) FLOPs over
    (word N^3 + (index + 8 word)(N+1)^3) bytes.
    """
    flops = 12.0 * _np1(n) ** 4 + 18.0 * _np1(n) ** 3
    bts = word * n**3 + (4 + 8 * word) * _np1(n) ** 3
    return min(peak_gflops, flops / bts * bandwidth_gbs)


def fom_gflops(e: int, n: int, n_iter: int, seconds: float) -> float:
    """The benchmark FOM: NekBone FLOP count (Eq. 3) over wall time."""
    return nekbone_flops_per_iter(e, n) * n_iter / seconds / 1e9


@dataclasses.dataclass(frozen=True)
class TpuSpec:
    """Roofline hardware constants (per chip)."""

    name: str
    peak_flops: float          # FLOP/s at the benchmark dtype
    hbm_bandwidth: float       # bytes/s
    ici_bandwidth: float       # bytes/s per link
    hbm_bytes: float           # capacity
    vmem_bytes: float = 16 * 2**20


# Constants given by the assignment: 197 TFLOP/s bf16, 819 GB/s HBM,
# ~50 GB/s/link ICI. (f32 peak is half of bf16 on the MXU.)
TPU_V5E = TpuSpec(
    name="tpu-v5e-like",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 2**30,
)
