"""Distributed hipBone: the screened Poisson operator over a device mesh.

The global element grid is block-partitioned over a 3-D process grid mapped
onto the (flattened) device mesh — each rank owns a box of elements plus a
*padded, consistent* assembled-DOF box (interface points replicated across
sharing ranks, every replica holding the true value). See DESIGN.md §5.

Operator application follows the paper's Fig. 2 communication-hiding split:

    scatter (local)                     u_L = x_box[l2g]
    halo elements first                 y_h = (S_L + λW) u_L[:Eh]
    local gather of halo contributions  box_h = Z_loc^T y_h
    ── sum_exchange(box_h) ──╮          (async collective...)
    interior elements        │          y_i = (S_L + λW) u_L[Eh:]   ...overlaps
    local gather             │          box_i = Z_loc^T y_i          this compute
    ─────────────────────────╯
    combine                             A x = exchanged(box_h) + box_i

Interior elements touch no rank-boundary points, so their contributions
commute with the exchange — that is exactly why the split hides the
communication. Because the padded storage keeps replicas consistent, one
sum-exchange does the work of hipBone's two phases (halo + gather); the
paper-faithful two-phase dataflow is available as ``two_phase=True`` for
comparison.

Inner products mask out replica slots (each interface DOF counted once),
then ``psum`` — the assembled-storage analogue of the paper's observation
that hipBone needs no weighted inner products.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..comms.halo import copy_exchange, sum_exchange
from ..comms.topology import ProcessGrid
from . import sem
from .cg import CGResult, _cg
from .operator import local_poisson

__all__ = ["DistPoisson", "build_dist_problem", "dist_cg", "dist_cg_scattered"]


@dataclasses.dataclass(frozen=True)
class DistPoisson:
    """Sharded screened-Poisson problem state.

    Static (identical on every rank): l2g, halo_elems, d, lam, box_shape,
    grid. Sharded data (leading axis = ranks): g, w_local, mask, and the
    solution/rhs vectors (P, m3).
    """

    grid: ProcessGrid
    axis_name: Any               # mesh axis name (or tuple) the ranks live on
    n_degree: int
    local_shape: tuple[int, int, int]    # elements per rank (bx, by, bz)
    box_shape: tuple[int, int, int]      # padded DOF box (bx*N+1, ...)
    lam: float
    halo_elems: int              # elements [0:Eh] touch the rank boundary
    l2g: np.ndarray              # (E_loc, p) int32, same on all ranks
    d: jax.Array                 # (n1, n1)
    g: jax.Array                 # (R, E_loc, 6, p) sharded
    w_local: jax.Array           # (R, E_loc, p) sharded — global inverse degree
    mask: jax.Array              # (R, m3) sharded — 1 where rank owns the DOF
    dtype: Any

    @property
    def m3(self) -> int:
        return int(np.prod(self.box_shape))

    @property
    def e_local(self) -> int:
        return int(np.prod(self.local_shape))

    @property
    def n_global(self) -> int:
        n = self.n_degree
        gx = self.grid.shape[0] * self.local_shape[0] * n + 1
        gy = self.grid.shape[1] * self.local_shape[1] * n + 1
        gz = self.grid.shape[2] * self.local_shape[2] * n + 1
        return gx * gy * gz


def _local_l2g(n: int, local_shape: tuple[int, int, int]) -> tuple[np.ndarray, int]:
    """Halo-first element ordering + local node -> padded-box flat map."""
    bx, by, bz = local_shape
    npts = n + 1
    mx, my, mz = bx * n + 1, by * n + 1, bz * n + 1

    a = np.arange(npts)
    la, lb, lc = np.meshgrid(a, a, a, indexing="ij")
    loc_a = la.transpose(2, 1, 0).reshape(-1)
    loc_b = lb.transpose(2, 1, 0).reshape(-1)
    loc_c = lc.transpose(2, 1, 0).reshape(-1)

    elems = [
        (i, j, k) for k in range(bz) for j in range(by) for i in range(bx)
    ]
    # halo-first: an element on any face of the local box goes first
    halo = [
        e
        for e in elems
        if e[0] in (0, bx - 1) or e[1] in (0, by - 1) or e[2] in (0, bz - 1)
    ]
    interior = [e for e in elems if e not in set(halo)]
    ordered = halo + interior

    l2g = np.empty((len(ordered), npts**3), dtype=np.int32)
    for idx, (i, j, k) in enumerate(ordered):
        gx = i * n + loc_a
        gy = j * n + loc_b
        gz = k * n + loc_c
        l2g[idx] = gx + mx * (gy + my * gz)
    return l2g, len(halo)


def _rank_data(
    grid: ProcessGrid,
    n: int,
    local_shape: tuple[int, int, int],
    l2g: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-rank (mask, w_local) arrays, stacked over ranks (numpy)."""
    bx, by, bz = local_shape
    px, py, pz = grid.shape
    mx, my, mz = bx * n + 1, by * n + 1, bz * n + 1
    gx_n, gy_n, gz_n = px * bx * n, py * by * n, pz * bz * n  # global max index

    def axis_count(g: np.ndarray, gmax: int) -> np.ndarray:
        """Number of elements sharing a global grid line index."""
        return np.where((g % n == 0) & (g > 0) & (g < gmax), 2, 1)

    masks, ws = [], []
    x = np.arange(mx)
    y = np.arange(my)
    z = np.arange(mz)
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        gx = ci * bx * n + x
        gy = cj * by * n + y
        gz = ck * bz * n + z
        # ownership: not on a low face that has a -neighbor
        own_x = (x > 0) | (ci == 0)
        own_y = (y > 0) | (cj == 0)
        own_z = (z > 0) | (ck == 0)
        mask = (
            own_x[:, None, None] & own_y[None, :, None] & own_z[None, None, :]
        )
        # mask grid is (x, y, z) but flat box index is x + mx*(y + my*z)
        mask_flat = mask.transpose(2, 1, 0).reshape(-1)  # z slow -> matches
        cx = axis_count(gx, gx_n)
        cy = axis_count(gy, gy_n)
        cz = axis_count(gz, gz_n)
        count = (
            cx[:, None, None] * cy[None, :, None] * cz[None, None, :]
        ).transpose(2, 1, 0).reshape(-1)
        w_box = 1.0 / count
        ws.append(w_box[l2g])          # scatter to element-local layout
        masks.append(mask_flat.astype(np.float64))
    return np.stack(masks), np.stack(ws)


def build_dist_problem(
    n_degree: int,
    grid: ProcessGrid,
    local_shape: tuple[int, int, int],
    *,
    axis_name: Any = "ranks",
    lam: float = 1.0,
    dtype: Any = jnp.float32,
    g_factors: np.ndarray | None = None,
) -> DistPoisson:
    """Build the sharded problem.

    ``g_factors``: optional (R, E_loc, 6, p) geometric factors (tests pass
    factors extracted from a deformed global mesh); default is the regular
    unit-box mesh where every element is identical.
    """
    n = n_degree
    bx, by, bz = local_shape
    l2g, halo = _local_l2g(n, local_shape)
    mask, w_local = _rank_data(grid, n, local_shape, l2g)

    if g_factors is None:
        # regular mesh: every element congruent; element size = 1/(P_d*b_d)
        from .geometry import geometric_factors
        from .mesh import build_box_mesh

        ref_mesh = build_box_mesh(
            n,
            (1, 1, 1),
            extent=(
                1.0 / (grid.shape[0] * bx),
                1.0 / (grid.shape[1] * by),
                1.0 / (grid.shape[2] * bz),
            ),
        )
        g_one = geometric_factors(ref_mesh)["G"][0]  # (6, p)
        e_loc = bx * by * bz
        g_factors = np.broadcast_to(
            g_one, (grid.size, e_loc, 6, g_one.shape[-1])
        )

    d = sem.derivative_matrix(n)
    return DistPoisson(
        grid=grid,
        axis_name=axis_name,
        n_degree=n,
        local_shape=local_shape,
        box_shape=(bx * n + 1, by * n + 1, bz * n + 1),
        lam=float(lam),
        halo_elems=halo,
        l2g=l2g,
        d=jnp.asarray(d, dtype),
        g=jnp.asarray(g_factors, dtype),
        w_local=jnp.asarray(w_local, dtype),
        mask=jnp.asarray(mask, dtype),
        dtype=dtype,
    )


def _apply_assembled(
    prob: DistPoisson,
    x_box: jax.Array,       # (m3,)
    g: jax.Array,           # (E_loc, 6, p)
    w: jax.Array,           # (E_loc, p)
    *,
    local_op: Callable[..., jax.Array],
    two_phase: bool,
) -> jax.Array:
    """One A-apply inside shard_map, with the Fig. 2 overlap split."""
    eh = prob.halo_elems
    l2g_flat = jnp.asarray(prob.l2g.reshape(-1))
    m3 = prob.m3

    if two_phase:
        # paper-faithful: explicit scatter-side halo refresh first
        x_box = copy_exchange(
            x_box.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name
        ).reshape(-1)

    u = jnp.take(x_box, l2g_flat, axis=0).reshape(prob.e_local, -1)

    # halo elements first; their contributions feed the exchange
    y_h = local_op(u[:eh], g[:eh], prob.d, prob.lam, w[:eh])
    box_h = jax.ops.segment_sum(
        y_h.reshape(-1), l2g_flat[: eh * y_h.shape[1]], num_segments=m3
    )
    box_h = sum_exchange(
        box_h.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name
    ).reshape(-1)

    # interior elements: no boundary contact -> overlaps the exchange above
    y_i = local_op(u[eh:], g[eh:], prob.d, prob.lam, w[eh:])
    box_i = jax.ops.segment_sum(
        y_i.reshape(-1), l2g_flat[eh * y_i.shape[1] :], num_segments=m3
    )
    return box_h + box_i


def dist_cg(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    b: jax.Array,
    *,
    n_iter: int = 100,
    local_op: Callable[..., jax.Array] | None = None,
    two_phase: bool = False,
    record_history: bool = False,
):
    """Distributed hipBone CG. ``b``: (R, m3) sharded rhs (made consistent).

    Returns a jitted callable () -> CGResult-like tuple, plus the shard_map
    step for dry-run lowering via ``.lower()``.
    """
    op = local_op or local_poisson
    spec = P(prob.axis_name)

    def shard_fn(b_s, g_s, w_s, mask_s):
        b1, g1, w1, m1 = b_s[0], g_s[0], w_s[0], mask_s[0]
        # make rhs consistent (replicas hold true values)
        b1 = copy_exchange(
            b1.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name
        ).reshape(-1)

        operator = lambda v: _apply_assembled(
            prob, v, g1, w1, local_op=op, two_phase=two_phase
        )
        res = _cg(
            operator,
            b1,
            None,
            n_iter=n_iter,
            weight=m1,
            psum=lambda v: lax.psum(v, prob.axis_name),
            fused_update=None,
            record_history=record_history,
        )
        hist = res.rdotr_history
        return (
            res.x[None],
            res.rdotr,
            hist if hist is not None else jnp.zeros((n_iter,), b1.dtype),
        )

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P()),
    )
    return functools.partial(fn, b, prob.g, prob.w_local, prob.mask)


def dist_cg_scattered(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    b_l: jax.Array,
    *,
    n_iter: int = 100,
    local_op: Callable[..., jax.Array] | None = None,
):
    """Distributed NekBone baseline: scattered (R, E_loc, p) vectors.

    Operator: b = ZZ^T S_L x + λ x  (gather-scatter through the padded box
    + sum exchange); weighted inner products read the W stream, exactly the
    extra traffic the paper charges against NekBone.
    """
    op = local_op or local_poisson
    spec = P(prob.axis_name)
    l2g_flat = jnp.asarray(prob.l2g.reshape(-1))
    m3 = prob.m3

    def gather_scatter(y_l):
        box = jax.ops.segment_sum(y_l.reshape(-1), l2g_flat, num_segments=m3)
        box = sum_exchange(
            box.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name
        ).reshape(-1)
        return jnp.take(box, l2g_flat, axis=0).reshape(y_l.shape)

    def shard_fn(b_s, g_s, w_s):
        # caller passes a consistent b_L (NekBone gather-scatters its random
        # forcing at setup; applying ZZ^T here would alter a general rhs)
        b1, g1, w1 = b_s[0], g_s[0], w_s[0]

        def operator(x_l):
            s = op(x_l, g1, prob.d, 0.0, None)
            return gather_scatter(s) + prob.lam * x_l

        res = _cg(
            operator,
            b1,
            None,
            n_iter=n_iter,
            weight=w1,
            psum=lambda v: lax.psum(v, prob.axis_name),
            fused_update=None,
            record_history=False,
        )
        return res.x[None], res.rdotr

    fn = jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=(spec, P()),
    )
    return functools.partial(fn, b_l, prob.g, prob.w_local)
