"""repro.models — the ten assigned architectures as composable JAX modules."""
from .blocks import MeshContext, init_layer, layer_decode, layer_forward
from .config import LayerKind, ModelConfig
from .model import decode_step, forward, init_caches, init_model, mtp_logits, prefill
from .params import (
    RULES_SINGLE,
    RULES_TP_DP,
    RULES_TP_FSDP,
    ParamBuilder,
    logical_to_spec,
    tree_shardings,
    tree_specs,
)

__all__ = [k for k in dir() if not k.startswith("_")]
