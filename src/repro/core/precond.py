"""Preconditioners for the screened-Poisson CG solve.

NekBone (and hence hipBone) fixes 100 unpreconditioned CG iterations, but
the parent applications do not: production Nek5000/RS Poisson solves are
preconditioned (Jacobi, Chebyshev-accelerated Jacobi, Schwarz, p-multigrid).
This module supplies the first two rungs of that ladder on top of the
existing assembled-storage machinery:

  * **Jacobi**: ``M = diag(A)`` where ``A = Z^T (S_L + λW) Z``.  The
    assembled diagonal is computed *without materializing S* — the
    element-local diagonal of the tensor-product stiffness

        diag(S_L^e)[t,s,r] = Σ_i D[i,r]² G_rr[t,s,i]
                           + Σ_j D[j,s]² G_ss[t,j,r]
                           + Σ_k D[k,t]² G_tt[k,s,r]
                           + 2 (D_rr D_ss G_rs + D_rr D_tt G_rt
                                + D_ss D_tt G_st)[t,s,r]

    (the three contractions are the divergence einsums with D squared and
    the diagonal metric blocks; the cross terms collapse to products of
    the diagonal entries of D), then gathered with Z^T like any other
    element-local field.

  * **Chebyshev–Jacobi**: a degree-k Chebyshev polynomial in the
    Jacobi-preconditioned operator ``D⁻¹A``, i.e. ``M⁻¹ = q_k(D⁻¹A) D⁻¹``.
    Because q_k is a fixed polynomial the map r → z is *linear and
    symmetric* (D^{1/2}-similarity), so plain PCG remains valid — no
    flexible-CG machinery needed.  The spectrum bound λ_max(D⁻¹A) is
    estimated by power iteration from a deterministic high-frequency seed
    vector; the smoothing interval is the usual [λ_max/ratio, safety·λ_max].

Everything here is expressed through the caller's ``operator`` /
``dot`` / ``psum`` callables, so the same code serves the single-device
assembled path and the sharded padded-box path in core.distributed (where
dots are replica-masked and psum is a real collective).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from .gather_scatter import gather

__all__ = [
    "local_operator_diagonal",
    "assembled_diagonal",
    "power_lambda_max",
    "jacobi_apply",
    "chebyshev_apply",
    "make_preconditioner",
    "PRECOND_KINDS",
    "CHEB_LMIN_RATIO",
    "CHEB_SAFETY",
]

PRECOND_KINDS = ("none", "jacobi", "chebyshev")

# Standard Chebyshev-smoother interval: [lmax/ratio, safety * lmax].
CHEB_LMIN_RATIO = 30.0
CHEB_SAFETY = 1.1


def local_operator_diagonal(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
) -> jax.Array:
    """Element-local diagonal of (S_L + λ·screen) without forming S_L.

    Args:
      g: (E, 6, p) packed geometric factors [rr, rs, rt, ss, st, tt].
      d: (N+1, N+1) 1-D derivative matrix.
      lam: screen parameter λ.
      w: (E, p) inverse-degree weights (hipBone λW screen) or None (λI).

    Returns:
      (E, p) local diagonal, node order (t, s, r) matching local_poisson.
    """
    e = g.shape[0]
    n1 = d.shape[0]
    d2 = d * d
    g3 = g.reshape(e, 6, n1, n1, n1)

    # Same contraction patterns as the divergence in local_poisson, with D²
    # and the diagonal metric blocks.
    diag = (
        jnp.einsum("ia,etsi->etsa", d2, g3[:, 0])   # Σ_i D[i,r]² G_rr
        + jnp.einsum("jb,etjr->etbr", d2, g3[:, 3])  # Σ_j D[j,s]² G_ss
        + jnp.einsum("kc,eksr->ecsr", d2, g3[:, 5])  # Σ_k D[k,t]² G_tt
    )
    dd = jnp.diagonal(d)
    ddr = dd.reshape(1, 1, 1, n1)
    dds = dd.reshape(1, 1, n1, 1)
    ddt = dd.reshape(1, n1, 1, 1)
    diag = diag + 2.0 * (
        ddr * dds * g3[:, 1] + ddr * ddt * g3[:, 2] + dds * ddt * g3[:, 4]
    )
    diag = diag.reshape(e, -1)

    screen = jnp.ones_like(diag) if w is None else w
    return diag + lam * screen


def assembled_diagonal(prob) -> jax.Array:
    """diag(A) on assembled DOFs: Z^T diag(S_L + λW) Z (Z picks out the
    diagonal entries, so this is just the gather of the local diagonal)."""
    dloc = local_operator_diagonal(prob.g, prob.d, prob.lam, prob.w_local)
    return gather(dloc, prob.l2g, prob.n_global)


def _default_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a, b)


def power_lambda_max(
    operator: Callable[[jax.Array], jax.Array],
    dinv: jax.Array,
    v0: jax.Array,
    *,
    iters: int = 15,
    dot: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
) -> jax.Array:
    """λ_max(D⁻¹A) by power iteration from ``v0``.

    D⁻¹A is similar to the SPD matrix D^{-1/2} A D^{-1/2}, so the dominant
    eigenvalue is real and positive and plain power iteration converges.
    ``dot``/``psum`` let the distributed caller mask replicas and reduce
    across ranks; the growth ratio ‖w‖/‖v‖ is the eigenvalue estimate.
    """
    dp = dot or _default_dot
    allsum = psum or (lambda v: v)

    def body(carry, _):
        v, _ = carry
        w = dinv * operator(v)
        nrm = jnp.sqrt(allsum(dp(w, w)))
        lam = nrm / jnp.sqrt(allsum(dp(v, v)))
        return (w / jnp.maximum(nrm, 1e-30), lam), lam

    v0 = v0 / jnp.sqrt(allsum(dp(v0, v0)))
    (_, lam), _ = jax.lax.scan(body, (v0, jnp.array(0.0, v0.dtype)), None, length=iters)
    return lam


def deterministic_seed_vector(n: int, dtype=jnp.float32) -> jax.Array:
    """Reproducible high-frequency start vector for the power iteration.

    A smooth vector (ones) is nearly the *lowest* mode of D⁻¹A; this hash
    puts energy in the top of the spectrum so few iterations suffice.  The
    same formula evaluated on *global* indices is what the distributed path
    uses, keeping replicas consistent by construction.
    """
    return jnp.asarray(seed_values(np.arange(n)), dtype)


def seed_values(global_idx: np.ndarray) -> np.ndarray:
    """sin-hash of global DOF indices (numpy, evaluated at setup time)."""
    t = np.sin((global_idx.astype(np.float64) + 1.0) * 12.9898) * 43758.5453
    return t - np.floor(t) - 0.5


def jacobi_apply(dinv: jax.Array) -> Callable[[jax.Array], jax.Array]:
    """z = D⁻¹ r."""
    return lambda r: dinv * r


def chebyshev_apply(
    operator: Callable[[jax.Array], jax.Array],
    dinv: jax.Array,
    lmax: jax.Array | float,
    *,
    lmin: jax.Array | float | None = None,
    degree: int = 2,
    fused_d_update: Callable[..., jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """Degree-k Chebyshev–Jacobi preconditioner application z ≈ A⁻¹ r.

    The classic Chebyshev semi-iteration for A z = r with z₀ = 0 on the
    interval [lmin, lmax] of D⁻¹A; each step costs one A-apply and one
    D⁻¹-scale.  Under sharding the A-applies reuse the communication-hiding
    split operator, so Chebyshev needs *no new exchange machinery*.

    ``fused_d_update`` optionally fuses the streaming update
    d ← a·d + c·(D⁻¹ res) (signature (a, c, d, r) -> d_new; see
    kernels.ops.fused_cheb_d_update).
    """
    if degree < 1:
        raise ValueError(f"chebyshev degree must be >= 1, got {degree}")
    lmax = jnp.asarray(lmax)
    lmin_v = lmax / CHEB_LMIN_RATIO if lmin is None else jnp.asarray(lmin)
    theta = 0.5 * (lmax + lmin_v)
    delta = 0.5 * (lmax - lmin_v)
    sigma = theta / delta

    dupd = fused_d_update or (lambda a, c, d, r: a * d + c * r)

    def apply(r: jax.Array) -> jax.Array:
        rho = 1.0 / sigma
        d = (dinv * r) / theta
        z = d
        res = r
        # degree is a small static int: unrolled at trace time, one compiled
        # A-apply chain per CG iteration body.
        for _ in range(degree - 1):
            res = res - operator(d)
            rho_new = 1.0 / (2.0 * sigma - rho)
            d = dupd(rho_new * rho, 2.0 * rho_new / delta, d, dinv * res)
            z = z + d
            rho = rho_new
        return z

    return apply


@dataclasses.dataclass(frozen=True)
class PrecondInfo:
    """What make_preconditioner built (for logging/benchmark reporting)."""

    kind: str
    degree: int
    lmax: float | None


def make_preconditioner(
    kind: str,
    prob,
    operator: Callable[[jax.Array], jax.Array],
    *,
    degree: int = 2,
    power_iters: int = 15,
    fused_d_update: Callable[..., jax.Array] | None = None,
) -> tuple[Callable[[jax.Array], jax.Array] | None, PrecondInfo]:
    """Build a single-device assembled-path preconditioner by name.

    kind: "none" | "jacobi" | "chebyshev".  Returns (apply, info);
    apply is None for "none" (plain CG).
    """
    if kind not in PRECOND_KINDS:
        raise ValueError(f"unknown precond {kind!r}; choose from {PRECOND_KINDS}")
    if kind == "none":
        return None, PrecondInfo("none", 0, None)
    diag = assembled_diagonal(prob)
    dinv = 1.0 / diag
    if kind == "jacobi":
        return jacobi_apply(dinv), PrecondInfo("jacobi", 1, None)
    v0 = deterministic_seed_vector(prob.n_global, diag.dtype)
    lmax = CHEB_SAFETY * power_lambda_max(operator, dinv, v0, iters=power_iters)
    apply = chebyshev_apply(
        operator, dinv, lmax, degree=degree, fused_d_update=fused_d_update
    )
    return apply, PrecondInfo("chebyshev", degree, float(lmax))
