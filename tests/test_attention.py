"""Attention: flash-chunked vs naive softmax, windows, sharded flash-decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.models.attention import flash_attention


def _naive(q, k, v, q_pos, kv_pos, n_kv, window, scale):
    b, sq, h, d = q.shape
    g = h // n_kv
    q5 = q.astype(jnp.float32).reshape(b, sq, n_kv, g, d) * scale
    s = jnp.einsum("bskgd,bckd->bskgc", q5, k.astype(jnp.float32))
    mask = kv_pos[:, None, None, None, :] <= q_pos[:, :, None, None, None]
    mask &= kv_pos[:, None, None, None, :] >= 0
    if window is not None:
        mask &= kv_pos[:, None, None, None, :] > q_pos[:, :, None, None, None] - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgc,bckd->bskgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, d)


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("n_kv", [1, 2, 4])
def test_flash_matches_naive(window, n_kv, rng):
    b, s, h, d = 2, 32, 4, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, n_kv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, n_kv, d)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s)).astype(jnp.int32)
    chunk = 8

    def kv_fn(c):
        sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * chunk, chunk, 1)
        return sl(k), sl(v), sl(pos)

    out = flash_attention(
        q, kv_fn, s // chunk, q_positions=pos, n_kv_heads=n_kv,
        window=window, scale=d**-0.5, dv=d,
    )
    want = _naive(q, k, v, pos, pos, n_kv, window, d**-0.5)
    np.testing.assert_allclose(np.array(out), np.array(want), rtol=2e-5, atol=2e-5)


def test_sharded_flash_decode_matches_unsharded():
    """Seq-parallel decode combine == single-shard attention over full cache."""
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.models.config import ModelConfig
from repro.models.attention import init_attention, attn_decode, init_attn_cache
from repro.compat import make_mesh, shard_map

cfg = ModelConfig(name="a", family="dense", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=2, head_dim=8, d_ff=32, vocab_size=8, dtype="float32")
p, _ = init_attention(jax.random.key(0), cfg, jnp.float32)
B, CAP = 2, 64
cache = init_attn_cache(cfg, B, CAP, jnp.float32)
rng = np.random.default_rng(0)
cache = {"k": jnp.asarray(rng.standard_normal(cache["k"].shape), jnp.float32),
         "v": jnp.asarray(rng.standard_normal(cache["v"].shape), jnp.float32)}
x = jnp.asarray(rng.standard_normal((B, 1, 32)), jnp.float32)
t = jnp.int32(40)

# unsharded reference
y_ref, cache_ref = attn_decode(p, x, t, cache, cfg, local=False, seq_axes=None)

# sharded: seq over 8 shards
mesh = make_mesh((8,), ("s",))
pspec = jax.tree.map(lambda a: P(*([None] * a.ndim)), p)
cspec = {"k": P(None, "s", None, None), "v": P(None, "s", None, None)}
fn = jax.jit(shard_map(
    partial(attn_decode, cfg=cfg, local=False, seq_axes=("s",), vary_axes=("s",)),
    mesh=mesh, in_specs=(pspec, P(), P(), cspec), out_specs=(P(), cspec)))
y_sh, cache_sh = fn(p, x, t, cache)
err = np.abs(np.array(y_sh) - np.array(y_ref)).max()
assert err < 1e-5, err
np.testing.assert_allclose(np.array(cache_sh["k"]), np.array(cache_ref["k"]), atol=1e-6)
print("OK", err)
"""
    )


def test_mla_decode_matches_unsharded():
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.models.config import ModelConfig
from repro.models.attention import init_attention, attn_decode, init_attn_cache
from repro.compat import make_mesh, shard_map

cfg = ModelConfig(name="a", family="moe", n_layers=1, d_model=32, n_heads=4,
                  n_kv_heads=4, head_dim=8, d_ff=32, vocab_size=8, attn_kind="mla",
                  q_lora_rank=16, kv_lora_rank=16, qk_rope_head_dim=8,
                  qk_nope_head_dim=8, v_head_dim=8, dtype="float32")
p, _ = init_attention(jax.random.key(0), cfg, jnp.float32)
B, CAP = 2, 32
rng = np.random.default_rng(0)
cache = init_attn_cache(cfg, B, CAP, jnp.float32)
cache = jax.tree.map(lambda a: jnp.asarray(rng.standard_normal(a.shape), jnp.float32), cache)
x = jnp.asarray(rng.standard_normal((B, 1, 32)), jnp.float32)
t = jnp.int32(20)
y_ref, _ = attn_decode(p, x, t, cache, cfg, local=False, seq_axes=None)
mesh = make_mesh((8,), ("s",))
pspec = jax.tree.map(lambda a: P(*([None] * a.ndim)), p)
cspec = {"c_kv": P(None, "s", None), "k_rope": P(None, "s", None)}
fn = jax.jit(shard_map(
    partial(attn_decode, cfg=cfg, local=False, seq_axes=("s",), vary_axes=("s",)),
    mesh=mesh, in_specs=(pspec, P(), P(), cspec), out_specs=(P(), cspec)))
y_sh, _ = fn(p, x, t, cache)
err = np.abs(np.array(y_sh) - np.array(y_ref)).max()
assert err < 1e-5, err
print("OK", err)
"""
    )
