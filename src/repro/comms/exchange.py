"""Nearest-neighbor exchange algorithms — the paper's gslib routing, JAX-native.

hipBone re-implements gslib with three interchangeable exchange routines
(paper §MPI Communication): **all-to-all**, **pairwise**, and **crystal
router**. We provide the same three over ``shard_map`` collectives for the
dense uniform-chunk case (every rank holds a (P, chunk) buffer; after the
exchange, rank d holds src-indexed chunks — lax.all_to_all convention).
This is the exchange primitive the MoE expert-parallel dispatch uses, and
the benchmark harness times all three (the paper's setup-time autotuning).

Cost model (per rank), matching the paper's analysis:
  pairwise:        P-1 messages,  (P-1)·chunk bytes     — min data, max msgs
  crystal router:  log2 P msgs,   (P/2)·log2(P)·chunk   — min msgs, more data
  all-to-all:      library's choice (XLA/ICI native)

All functions run INSIDE shard_map over ``axis_name`` and are jit-safe.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .topology import hypercube_stages

__all__ = [
    "exchange_all_to_all",
    "exchange_pairwise",
    "exchange_crystal_router",
    "EXCHANGES",
    "get_exchange",
]


def _axis_size(axis_name) -> int:
    from ..compat import axis_size

    return axis_size(axis_name)


def exchange_all_to_all(buf: jax.Array, axis_name: str) -> jax.Array:
    """Dense exchange via the native collective (XLA picks the routing)."""
    return lax.all_to_all(buf, axis_name, split_axis=0, concat_axis=0, tiled=True)


def exchange_pairwise(buf: jax.Array, axis_name: str) -> jax.Array:
    """P-1 direct messages — the paper's MPI_Isend/Irecv pairwise exchange.

    Step d sends chunk[(r+d) % P] to rank (r+d) % P; minimal total bytes,
    maximal message count. Ring-scheduled so each step is a disjoint
    permutation (no congestion), as an MPI implementation would.
    """
    p = _axis_size(axis_name)
    me = lax.axis_index(axis_name)
    out = jnp.zeros_like(buf)
    out = lax.dynamic_update_index_in_dim(
        out, lax.dynamic_index_in_dim(buf, me, 0, keepdims=False), me, 0
    )

    # Unrolled python loop: ppermute permutations must be static.
    for d in range(1, p):
        dst = (me + d) % p
        src = (me - d) % p
        send = lax.dynamic_index_in_dim(buf, dst, 0, keepdims=False)
        perm = [(r, (r + d) % p) for r in range(p)]
        recv = lax.ppermute(send, axis_name, perm)
        out = lax.dynamic_update_index_in_dim(out, recv, src, 0)
    return out


def exchange_crystal_router(buf: jax.Array, axis_name: str) -> jax.Array:
    """Recursive hypercube folding (Lamb et al. 1988), log2(P) messages.

    Stage s pairs each rank with ``rank XOR 2^s`` and forwards every chunk
    whose destination lies in the partner's half. Chunk count per rank is
    invariant (P): after stage s, slot-index bit s is reinterpreted from
    "destination bit" to "source bit". Fewer, larger messages — the
    latency-optimal routing for small problems (paper §Crystal Router).
    """
    p = _axis_size(axis_name)
    k = hypercube_stages(p)
    me = lax.axis_index(axis_name)

    for s in range(k):
        bit = 1 << s
        mybit = (me >> s) & 1
        pre = p >> (s + 1)
        # view slots as (pre, 2, bit) — axis 1 is slot-index bit s
        b4 = buf.reshape((pre, 2, bit) + buf.shape[1:])
        # send the half whose bit differs from mine; receive partner's
        send = lax.dynamic_index_in_dim(b4, 1 - mybit, 1, keepdims=False)
        perm = [(r, r ^ bit) for r in range(p)]
        recv = lax.ppermute(send, axis_name, perm)
        # partner's sent half slots had bit s == my bit on their side; placing
        # them at my (1 - mybit) half performs the src/dst bit swap in place
        b4 = lax.dynamic_update_index_in_dim(b4, recv, 1 - mybit, 1)
        buf = b4.reshape(buf.shape)
    return buf


EXCHANGES: dict[str, Callable[[jax.Array, str], jax.Array]] = {
    "all_to_all": exchange_all_to_all,
    "pairwise": exchange_pairwise,
    "crystal_router": exchange_crystal_router,
}


def get_exchange(name: str) -> Callable[[jax.Array, str], jax.Array]:
    if name not in EXCHANGES:
        raise KeyError(f"unknown exchange '{name}', have {sorted(EXCHANGES)}")
    return EXCHANGES[name]
