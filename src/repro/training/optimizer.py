"""Optimizers with memory-posture knobs for 100B+ models on 16 GB chips.

All pure pytree transforms: ``init(params) -> state``, ``update(grads,
state, params, lr) -> (new_params, new_state)``. State dtypes are
configurable (bf16 first moment), and Adafactor offers the factored second
moment (O(rows+cols) instead of O(rows*cols)) that the deepseek-v3 train
cell needs to fit. States inherit parameter sharding (FSDP "assembled"
storage — paper C1) automatically under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["Optimizer", "adamw", "adafactor", "sgd_momentum", "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    name: str = "opt"


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw(
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype: Any = jnp.float32,
) -> Optimizer:
    """AdamW; ``state_dtype=bfloat16`` halves optimizer memory."""

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            step = (mf / c1) / (jnp.sqrt(vf / c2) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (
                (p.astype(jnp.float32) - lr * step).astype(p.dtype),
                mf.astype(state_dtype),
                vf.astype(state_dtype),
            )

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer(init=init, update=update, name="adamw")


def adafactor(
    *,
    b1: float = 0.9,
    decay: float = 0.99,
    eps: float = 1e-30,
    momentum_dtype: Any = jnp.bfloat16,
    clip_threshold: float = 1.0,
) -> Optimizer:
    """Adafactor with factored second moment for matrices (>=2D leaves).

    Memory: 1-D leaves keep a full v; N-D leaves keep row/col statistics
    over the last two axes — for deepseek's (256, 7168, 2048) expert stacks
    that is ~4000x less second-moment memory than Adam.
    """

    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def v_for(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, momentum_dtype), params),
            "v": jax.tree.map(v_for, params, is_leaf=lambda x: hasattr(x, "ndim")),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        beta2 = 1.0 - count.astype(jnp.float32) ** -0.8  # Adafactor schedule
        beta2 = jnp.minimum(beta2, decay)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                prec = (
                    (vr / denom)[..., None] * vc[..., None, :]
                )
                step = gf * jax.lax.rsqrt(jnp.maximum(prec, eps))
                new_v = {"vr": vr, "vc": vc}
            else:
                vf = beta2 * v["v"] + (1 - beta2) * g2
                step = gf * jax.lax.rsqrt(jnp.maximum(vf, eps))
                new_v = {"v": vf}
            # update clipping (RMS of step)
            rms = jnp.sqrt(jnp.mean(jnp.square(step)) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * step
            newp = (p.astype(jnp.float32) - lr * mf).astype(p.dtype)
            return newp, mf.astype(momentum_dtype), new_v

        # grads' array leaves drive the flattening; the v-tree's {vr,vc}/{v}
        # dicts sit below leaf positions and arrive whole via flatten_up_to.
        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return pick(0), {"m": pick(1), "v": pick(2), "count": count}

    return Optimizer(init=init, update=update, name="adafactor")


def sgd_momentum(*, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        def upd(g, m, p):
            mf = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * mf).astype(p.dtype), mf

        out = jax.tree.map(upd, grads, state["m"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "count": state["count"] + 1}

    return Optimizer(init=init, update=update, name="sgd_momentum")
