"""repro.training — optimizers, train step, checkpointing, fault tolerance."""
from .checkpoint import CheckpointManager, latest_step, restore, save
from .compress import compressed_psum, dequantize_int8, ef_compressed_psum, quantize_int8
from .optimizer import Optimizer, adafactor, adamw, clip_by_global_norm, global_norm, sgd_momentum
from .runtime import RunnerConfig, TrainRunner
from .train_step import cross_entropy, make_loss_fn, make_train_step, warmup_cosine

__all__ = [k for k in dir() if not k.startswith("_")]
