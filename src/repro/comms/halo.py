"""Structured 3-D halo communication for the distributed Poisson operator.

Each rank owns a padded DOF box of shape (mx, my, mz); interface points are
replicated on every sharing rank. Two primitives, both built from static
``lax.ppermute`` face shifts (2 per partitioned dimension):

  * ``sum_exchange``  — assemble partial sums at interface points AND leave
    every replica holding the summed value (the gather Z^T fused with the
    scatter-side refresh; see DESIGN.md: the padded-consistent storage
    merges hipBone's two communication phases into one).
  * ``copy_exchange`` — refresh replicas from the canonical owner only
    (used by the paper-faithful two-phase mode and by tests).

Sequential dimension sweeps propagate edge/corner contributions without
explicit 26-neighbor messages — the structured-grid trick NekBone's
gslib setup discovers generically.

All functions run inside shard_map over ``axis_name`` whose size equals
``grid.size``. Boxes are passed as 3-D arrays indexed [z, y, x]
(x fastest in the flat layout).

**Wire precision.**  Payload dtype follows the box dtype, so the
mixed-precision preconditioner path (fp32 boxes inside an fp64 PCG) halves
its wire bytes with no code here.  Every primitive additionally accepts
``wire_dtype``: faces/shells are rounded to that dtype just before the
``ppermute`` and widened back on receipt — fp32 wires under fp64 boxes for
payload-bound exchanges where the *accumulation* must stay wide.  Summed
exchanges still accumulate in the box dtype; only the transported slab is
narrowed, and any interface value that travels is rounded on the owning
rank as well, so owner and replicas keep holding the same value (the
consistency contract of the padded box survives the narrow wire).
"""
from __future__ import annotations

import contextlib
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from .topology import ProcessGrid

__all__ = [
    "sum_exchange",
    "copy_exchange",
    "expand_exchange",
    "contract_exchange",
    "rank_coords",
    "wire_transform",
]

# Fault-injection seam: when set, every outgoing payload slab of every
# exchange primitive passes through the hook as ``fn(slab, axis_name)``
# just before its ppermute.  The hook is read at *trace* time, so it must
# be installed before the solve is first compiled (repro.testing.faults
# builds rank-targeted corruptors on top via lax.axis_index).  Production
# code never sets this; the default is a straight pass-through.
_WIRE_HOOK: Callable[[jax.Array, str], jax.Array] | None = None


@contextlib.contextmanager
def wire_transform(fn: Callable[[jax.Array, str], jax.Array]):
    """Temporarily install a wire-payload hook (fault-injection seam)."""
    global _WIRE_HOOK
    prev = _WIRE_HOOK
    _WIRE_HOOK = fn
    try:
        yield
    finally:
        _WIRE_HOOK = prev


def _wire_permute(
    val: jax.Array, axis_name: str, perm, wire_dtype: Any | None
) -> jax.Array:
    """ppermute with an optional cast-on-the-wire of the payload slab."""
    if _WIRE_HOOK is not None:
        val = _WIRE_HOOK(val, axis_name)
    if wire_dtype is None or jnp.dtype(wire_dtype) == val.dtype:
        return lax.ppermute(val, axis_name, perm)
    return lax.ppermute(
        val.astype(wire_dtype), axis_name, perm
    ).astype(val.dtype)


def _wire_round(val: jax.Array, wire_dtype: Any | None) -> jax.Array:
    """Round a slab to the wire dtype in place (idempotent).

    The replica-consistency guard for narrowed wires: any value that
    travels MUST also be rounded on the rank that keeps a copy of it,
    otherwise the owner would hold the exact value while every replica
    holds the rounded one and the same global DOF would differ by rank.
    """
    if wire_dtype is None or jnp.dtype(wire_dtype) == val.dtype:
        return val
    return val.astype(wire_dtype).astype(val.dtype)


def rank_coords(grid: ProcessGrid, axis_name: str) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Traced (i, j, k) coordinates of this rank in the process grid."""
    r = lax.axis_index(axis_name)
    px, py, _ = grid.shape
    return r % px, (r // px) % py, r // (px * py)


# Boxes are stored flat with x fastest (flat = x + mx*(y + my*z)), so the
# reshaped 3-D array is indexed [z, y, x]: spatial dim d lives on array
# axis (2 - d).


def _axis(dim: int) -> int:
    return 2 - dim


def _face(box: jax.Array, dim: int, idx: int) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box[tuple(sl)]


def _set_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].set(val)


def _add_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].add(val)


def sum_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None = None,
) -> jax.Array:
    """Assemble interface partial sums; all replicas end up consistent.

    Per partitioned dim: (1) low faces shift down and accumulate into the
    -neighbor's high face (which is the canonical interface slab); (2) the
    summed high face shifts back up into the +neighbor's low face.
    Boundary ranks receive ppermute zero-fill and are masked.
    ``wire_dtype`` narrows the transported faces only (sums stay in the
    box dtype); every interface value that travels is rounded on the
    owner too, so all copies of a DOF hold the *same* rounded sum — the
    consistency contract survives the narrow wire.
    """
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        # (1) low face -> -neighbor high face (sum)
        low = _face(box, dim, 0)
        recv = _wire_permute(low, axis_name, grid.shift_perm(dim, -1), wire_dtype)
        box = _add_face(box, dim, m - 1, recv)
        # (2) summed high face -> +neighbor low face (copy); the owner
        # keeps the same rounded value it ships (replica consistency)
        hi = _wire_round(_face(box, dim, m - 1), wire_dtype)
        box = _set_face(box, dim, m - 1, hi)
        recv = _wire_permute(hi, axis_name, grid.shift_perm(dim, +1), wire_dtype)
        keep = _face(box, dim, 0)
        new_low = jnp.where(c > 0, recv, keep)
        box = _set_face(box, dim, 0, new_low)
    return box


def _shell(box: jax.Array, dim: int, lo: int, hi: int) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box[tuple(sl)]


def _set_shell(box: jax.Array, dim: int, lo: int, hi: int, val) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box.at[tuple(sl)].set(val)


def _add_shell(box: jax.Array, dim: int, lo: int, hi: int, val) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(lo, hi)
    return box.at[tuple(sl)].add(val)


def expand_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None = None,
) -> jax.Array:
    """Grow a consistent box by a ``depth``-node shell of neighbor data.

    The overlap transport of the Schwarz smoother: rank-boundary element
    blocks extend ``depth`` GLL node layers into neighbor ranks, so the
    (bz, by, bx)-shaped consistent box comes back as
    (bz+2d, by+2d, bx+2d) with shells holding the neighbors' interior
    slabs (their node layers just inside the shared interface).  Shells
    with no neighbor — unpartitioned dims and physical domain boundaries —
    stay zero (ppermute's zero-fill), matching the dummy slots of the
    extended-block FDM setup.

    The sequential dimension sweeps reuse the sum_exchange trick: the dim-1
    slab a neighbor sends already contains its dim-0 shell, so edge/corner
    overlap data propagates without explicit 26-neighbor messages.
    ``contract_exchange`` is the exact adjoint (same sweeps reversed).
    """
    d = int(depth)
    if d == 0:
        return box
    box = jnp.pad(box, d)
    for dim in range(3):
        if grid.shape[dim] == 1:
            continue
        ax = _axis(dim)
        m = box.shape[ax]          # padded length = original + 2d
        morig = m - 2 * d
        # low shell <- -neighbor's top interior slab (their original
        # indices [morig-1-d, morig-1) == padded [morig-1, morig-1+d))
        recv = _wire_permute(
            _shell(box, dim, morig - 1, morig - 1 + d),
            axis_name,
            grid.shift_perm(dim, +1),
            wire_dtype,
        )
        box = _set_shell(box, dim, 0, d, recv)
        # high shell <- +neighbor's bottom interior slab (their original
        # [1, 1+d) == padded [1+d, 1+2d))
        recv = _wire_permute(
            _shell(box, dim, 1 + d, 1 + 2 * d),
            axis_name,
            grid.shift_perm(dim, -1),
            wire_dtype,
        )
        box = _set_shell(box, dim, m - d, m, recv)
    return box


def contract_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    depth: int,
    wire_dtype: Any | None = None,
) -> jax.Array:
    """Adjoint of :func:`expand_exchange`: return shell contributions home.

    ``box`` is a (bz+2d, by+2d, bx+2d) accumulation of extended-block
    gather contributions; each shell slab belongs to a neighbor rank's
    interior and is shipped back and added there, then zeroed.  Dimensions
    run in reverse order so edge/corner contributions hop home
    dimension-by-dimension (the transpose of the expand sweeps).
    Contributions in never-filled shells (domain boundaries) correspond to
    dummy FDM slots and are discarded.  Returns the stripped
    (bz, by, bx) box of per-rank partial sums — interface *face* replicas
    still need the usual ``sum_exchange`` to become consistent.
    """
    d = int(depth)
    if d == 0:
        return box
    for dim in (2, 1, 0):
        ax = _axis(dim)
        m = box.shape[ax]
        morig = m - 2 * d
        if grid.shape[dim] > 1:
            # my low shell -> -neighbor's top interior ([morig-1, morig-1+d)
            # in their padded indexing); I receive the +neighbor's low shell
            recv = _wire_permute(
                _shell(box, dim, 0, d), axis_name,
                grid.shift_perm(dim, -1), wire_dtype,
            )
            box = _add_shell(box, dim, morig - 1, morig - 1 + d, recv)
            recv = _wire_permute(
                _shell(box, dim, m - d, m), axis_name,
                grid.shift_perm(dim, +1), wire_dtype,
            )
            box = _add_shell(box, dim, 1 + d, 1 + 2 * d, recv)
        zero = jnp.zeros_like(_shell(box, dim, 0, d))
        box = _set_shell(box, dim, 0, d, zero)
        box = _set_shell(box, dim, m - d, m, zero)
    return box[d:-d, d:-d, d:-d]


def copy_exchange(
    box: jax.Array,
    grid: ProcessGrid,
    axis_name: str,
    wire_dtype: Any | None = None,
) -> jax.Array:
    """Refresh replica slabs from owners (owner = low-side rank).

    The canonical copy of an interface point lives on the rank where it sits
    on the HIGH face of the padded box; the +neighbor's low-face replica is
    overwritten. This is hipBone's scatter-side halo exchange in isolation.
    With ``wire_dtype`` the owner's high face is rounded to the wire dtype
    too, so replicas and owner agree on the rounded value.
    """
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        hi = _wire_round(_face(box, dim, m - 1), wire_dtype)
        box = _set_face(box, dim, m - 1, hi)
        recv = _wire_permute(hi, axis_name, grid.shift_perm(dim, +1), wire_dtype)
        keep = _face(box, dim, 0)
        box = _set_face(box, dim, 0, jnp.where(c > 0, recv, keep))
    return box
