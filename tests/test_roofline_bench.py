"""roofline modules: collective parsing, loop trip counts, dry-run e2e."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fom import TPU_V5E, assembled_apply_bytes, cg_iter_bytes
from repro.roofline import analyze_hlo, dryrun_roofline, parse_collectives

# A hand-written post-optimization-style module: an explicit-group
# all-reduce, an iota-group all-gather, and a permute.
HLO_COLLECTIVES = """
HloModule m

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %p0), replica_groups={{0,1,2,3}}, to_apply=%sum
  %ag = f32[4096]{0} all-gather(f32[1024]{0} %ar), replica_groups=[2,4]<=[8], dimensions={0}
  %cp = f32[1024]{0} collective-permute(f32[1024]{0} %ar), source_target_pairs={{0,1},{1,0}}
  ROOT %out = f32[1024]{0} add(f32[1024]{0} %ar, f32[1024]{0} %cp)
}
"""

# A while loop whose trip bound lives in the cond, containing a dot.
HLO_WHILE = """
HloModule m

%cond (arg: (s32[], f32[8,8])) -> pred[] {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,8]) %arg), index=0
  %n = s32[] constant(50)
  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT
}

%body (arg: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %arg = (s32[], f32[8,8]) parameter(0)
  %i = s32[] get-tuple-element((s32[], f32[8,8]) %arg), index=0
  %x = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]) %arg), index=1
  %d = f32[8,8]{1,0} dot(f32[8,8]{1,0} %x, f32[8,8]{1,0} %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %j = s32[] add(s32[] %i, s32[] %one)
  ROOT %t = (s32[], f32[8,8]) tuple(s32[] %j, f32[8,8]{1,0} %d)
}

ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,8]) tuple(s32[] %zero, f32[8,8]{1,0} %p0)
  %w = (s32[], f32[8,8]) while((s32[], f32[8,8]) %init), condition=%cond, body=%body
  ROOT %r = f32[8,8]{1,0} get-tuple-element((s32[], f32[8,8]) %w), index=1
}
"""


class TestParseCollectives:
    def test_explicit_groups_all_reduce(self):
        stats = parse_collectives(HLO_COLLECTIVES)
        assert stats.counts["all-reduce"] == 1
        # ring all-reduce over g=4: 2(g-1)/g * 4096 bytes
        assert stats.link_bytes["all-reduce"] == pytest.approx(
            2 * 3 / 4 * 4096
        )

    def test_iota_groups_all_gather(self):
        stats = parse_collectives(HLO_COLLECTIVES)
        # [2,4]<=[8]: group size is the second iota dim (4); result 16 KiB
        assert stats.counts["all-gather"] == 1
        assert stats.link_bytes["all-gather"] == pytest.approx(
            3 / 4 * 4096 * 4
        )

    def test_collective_permute(self):
        stats = parse_collectives(HLO_COLLECTIVES)
        assert stats.link_bytes["collective-permute"] == pytest.approx(4096)


class TestAnalyzeHlo:
    def test_while_trip_multiplies_flops(self):
        stats = analyze_hlo(HLO_WHILE)
        assert stats.n_whiles == 1
        assert stats.trip_counts == [50]
        # 8x8x8 dot = 2*64*8 = 1024 flops, x50 trips
        assert stats.flops == pytest.approx(50 * 1024)

    def test_body_bytes_multiplied(self):
        stats = analyze_hlo(HLO_WHILE)
        once = analyze_hlo(HLO_WHILE.replace("constant(50)", "constant(1)"))
        assert stats.hbm_bytes > 10 * once.hbm_bytes

    def test_hoisted_bound_via_called_fusion(self):
        # bound constant inside a computation the cond calls (LICM shape)
        hlo = HLO_WHILE.replace(
            "%n = s32[] constant(50)\n  ROOT %lt = pred[] compare(s32[] %i, s32[] %n), direction=LT",
            "ROOT %lt = pred[] fusion(s32[] %i), kind=kLoop, calls=%cmp",
        ).replace(
            "%cond (arg",
            "%cmp (ci: s32[]) -> pred[] {\n"
            "  %ci = s32[] parameter(0)\n"
            "  %cn = s32[] constant(50)\n"
            "  ROOT %clt = pred[] compare(s32[] %ci, s32[] %cn), direction=LT\n"
            "}\n\n%cond (arg",
        )
        assert analyze_hlo(hlo).trip_counts == [50]


class TestDryrunRoofline:
    def test_requires_exactly_one_model(self):
        with pytest.raises(ValueError):
            dryrun_roofline(HLO_WHILE)
        with pytest.raises(ValueError):
            dryrun_roofline(
                HLO_WHILE, model_bytes=1.0, model_bytes_per_iter=1.0
            )

    def test_per_iter_times_trip(self):
        r = dryrun_roofline(HLO_WHILE, model_bytes_per_iter=100.0)
        assert r["trip_count"] == 50
        assert r["model_bytes"] == pytest.approx(5000.0)

    def test_trip_cap_skips_lowering_loops(self):
        # fake a second loop with a huge trip count ahead of the solver loop
        r = dryrun_roofline(
            HLO_WHILE, model_bytes_per_iter=1.0, trip_cap=10_000
        )
        assert r["trip_count"] == 50

    def test_e2e_quickstart_solve(self):
        """Dry-run roofline of a real compiled CG solve on the tiny config."""
        from repro.configs.hipbone import REDUCED
        from repro.core import build_problem, cg_assembled, poisson_assembled

        cfg = REDUCED
        prob = build_problem(
            cfg.n_degree, cfg.local_elems, lam=cfg.lam, dtype=jnp.float32
        )
        a = poisson_assembled(prob, fused=False)
        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
        compiled = (
            jax.jit(lambda bb: cg_assembled(a, bb, n_iter=100, tol=1e-5))
            .lower(b)
            .compile()
        )
        e = prob.mesh.n_elements
        r = dryrun_roofline(
            compiled,
            model_bytes_per_iter=cg_iter_bytes(e, cfg.n_degree, word=4),
            trip_cap=100,
        )
        assert r["trip_count"] == 100
        assert 0.0 < r["pct_roofline"] <= 100.0
        assert r["achievable_s"] >= r["model_bytes"] / TPU_V5E.hbm_bandwidth

    def test_e2e_single_apply(self):
        from repro.core import build_problem, poisson_assembled

        prob = build_problem(3, (3, 3, 3), lam=1.0, dtype=jnp.float32)
        a = poisson_assembled(prob, fused=False)
        x = jnp.ones((prob.n_global,), jnp.float32)
        compiled = jax.jit(a).lower(x).compile()
        r = dryrun_roofline(
            compiled,
            model_bytes=assembled_apply_bytes(
                prob.mesh.n_elements, 3, word=4
            ),
        )
        assert 0.0 < r["pct_roofline"] <= 100.0
