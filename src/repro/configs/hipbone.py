"""hipBone — the paper's own benchmark as a selectable 'architecture'.

Shapes follow the paper's scaling studies: degree N=7 (3-D-threadblock
regime) and N=15 (2-D regime / peak-FOM degree), with per-rank element
boxes sized so the per-rank DOF counts bracket the paper's sweep. These
cells are EXTRA, beyond the 40 assigned LM cells.
"""
import dataclasses

__all__ = ["PoissonConfig", "CONFIGS"]


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    name: str
    n_degree: int
    local_elems: tuple[int, int, int]   # elements per rank
    lam: float = 1.0
    n_iter: int = 100                   # NekBone's fixed CG iteration count
    dtype: str = "float32"
    # preconditioner ladder rung: "none" (NekBone-faithful plain CG),
    # "jacobi" (assembled-diagonal scale), "chebyshev" (degree-`cheb_degree`
    # Chebyshev–Jacobi on the Lanczos-estimated [λ_min, λ_max] interval),
    # "schwarz" (overlapping element-block FDM solves, symmetric weighted
    # additive Schwarz — the robust rung for deformed/ill-conditioned
    # meshes), or "pmg" (p-multigrid V-cycle N → ⌈N/2⌉ → … → 1, the
    # production Nek5000/RS configuration).
    precond: str = "none"
    cheb_degree: int = 2                # standalone Chebyshev polynomial degree
    tol: float | None = None            # None = fixed n_iter (NekBone mode)
    # pmg knobs: per-level smoother degree (Chebyshev order of the pre/post
    # smoothing sweeps; None = per-smoother default), the smoother base
    # ("chebyshev" = Chebyshev–Jacobi, "schwarz" = Chebyshev-accelerated
    # overlapping Schwarz), the coarse-operator construction ("redisc"
    # rediscretizes, "galerkin" = exact P^T A P chained matrix-free,
    # single-device only, "galerkin_mat" = the same triple products
    # materialized at setup into per-element blocks — sharded-capable,
    # zero fine-operator work per coarse apply), and the degree of the
    # full-interval Chebyshev solve on the coarsest (N=1) ladder level.
    pmg_smooth_degree: int | None = None
    pmg_smoother: str = "chebyshev"
    pmg_coarse_op: str = "redisc"
    pmg_coarse_iters: int = 16
    # schwarz knobs: overlap width in GLL nodes (0 = FDM block Jacobi) and
    # the Chebyshev degree of the in-eigenbasis block solve (the algebraic
    # screen λI breaks pure tensor structure; higher = closer to exact
    # block inverses at ~linear extra cost per application).
    schwarz_overlap: int = 1
    schwarz_inner_degree: int = 7
    # mixed precision: compute dtype of the whole preconditioner chain
    # (None = dtype).  "float32" inside a float64 solve halves
    # preconditioner HBM/wire traffic (the production Nek5000/RS trick);
    # pair it with cg_variant="flexible" — the fp32 M⁻¹ is only
    # approximately symmetric in fp64 arithmetic.
    precond_dtype: str | None = None
    cg_variant: str = "standard"        # "standard" (FR β) | "flexible" (PR β)
    # fused assembled operator: True forces the single-kernel Pallas apply
    # (kernels/poisson_fused.py — gather, local operator and scatter-add in
    # one pass, interior block only under sharding), False pins the split
    # scatter/local/gather pipeline, None defers to the backend policy
    # (kernels.ops.should_fuse_operator; HIPBONE_FUSED=0/1 overrides).
    fused_operator: bool | None = None

    def __post_init__(self):
        if self.precond not in ("none", "jacobi", "chebyshev", "schwarz", "pmg"):
            raise ValueError(f"unknown precond {self.precond!r}")
        if self.pmg_smoother not in ("chebyshev", "schwarz"):
            raise ValueError(f"unknown pmg_smoother {self.pmg_smoother!r}")
        if self.pmg_coarse_op not in ("redisc", "galerkin", "galerkin_mat"):
            raise ValueError(f"unknown pmg_coarse_op {self.pmg_coarse_op!r}")
        if self.precond_dtype not in (None, "float32", "float64"):
            raise ValueError(f"unknown precond_dtype {self.precond_dtype!r}")
        if self.cg_variant not in ("standard", "flexible"):
            raise ValueError(f"unknown cg_variant {self.cg_variant!r}")
        if self.fused_operator not in (None, True, False):
            raise ValueError(
                f"fused_operator must be None/True/False, "
                f"got {self.fused_operator!r}"
            )

    def dofs_per_rank(self) -> int:
        n = self.n_degree
        bx, by, bz = self.local_elems
        return bx * by * bz * n**3


CONFIGS = {
    "hipbone_n7": PoissonConfig("hipbone_n7", 7, (8, 8, 8)),      # ~176k DOF/rank
    "hipbone_n7_large": PoissonConfig("hipbone_n7_large", 7, (16, 16, 16)),
    "hipbone_n15": PoissonConfig("hipbone_n15", 15, (4, 4, 4)),   # ~216k DOF/rank
    "hipbone_n15_large": PoissonConfig("hipbone_n15_large", 15, (8, 8, 8)),
    # beyond-the-benchmark: production-style preconditioned solves to tol
    "hipbone_n7_pcg": PoissonConfig(
        "hipbone_n7_pcg", 7, (8, 8, 8), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n15_pcg": PoissonConfig(
        "hipbone_n15_pcg", 15, (4, 4, 4), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n7_pmg": PoissonConfig(
        "hipbone_n7_pmg", 7, (8, 8, 8), precond="pmg", tol=1e-6
    ),
    "hipbone_n15_pmg": PoissonConfig(
        "hipbone_n15_pmg", 15, (4, 4, 4), precond="pmg", tol=1e-6
    ),
    # the robust rung: overlapping-Schwarz FDM blocks, for the
    # ill-conditioned (small-λ / deformed-mesh) regime
    "hipbone_n7_schwarz": PoissonConfig(
        "hipbone_n7_schwarz", 7, (8, 8, 8), lam=0.1,
        precond="schwarz", tol=1e-8
    ),
    "hipbone_n7_pmg_schwarz": PoissonConfig(
        "hipbone_n7_pmg_schwarz", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_smoother="schwarz", tol=1e-8
    ),
    # the iteration-count champion for the ill-conditioned tier:
    # variationally-exact P^T A P coarse operators, materialized once at
    # setup into per-element blocks (sharded-capable, no fine-operator
    # work per coarse apply — core/galerkin.py)
    "hipbone_n7_pmg_galerkin": PoissonConfig(
        "hipbone_n7_pmg_galerkin", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8
    ),
    "hipbone_n7_pmg_galerkin_fp32": PoissonConfig(
        "hipbone_n7_pmg_galerkin_fp32", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8,
        dtype="float64", precond_dtype="float32", cg_variant="flexible"
    ),
    # mixed precision: fp64 outer PCG, fp32 preconditioner chain (halved
    # preconditioner HBM streams and halo wire payloads), flexible β
    "hipbone_n7_pmg_fp32": PoissonConfig(
        "hipbone_n7_pmg_fp32", 7, (8, 8, 8), lam=0.1,
        precond="pmg", tol=1e-8, dtype="float64",
        precond_dtype="float32", cg_variant="flexible"
    ),
    "hipbone_n7_schwarz_fp32": PoissonConfig(
        "hipbone_n7_schwarz_fp32", 7, (8, 8, 8), lam=0.1,
        precond="schwarz", tol=1e-8, dtype="float64",
        precond_dtype="float32", cg_variant="flexible"
    ),
}

REDUCED = PoissonConfig("hipbone_reduced", 3, (2, 2, 2))
