"""Parameter initialization with logical-axis sharding metadata.

Minimal functional "module system": builders initialize nested param dicts
while recording a parallel tree of logical-axis tuples. A rules table maps
logical axes onto mesh axes (MaxText-style), giving NamedShardings for
pjit in/out shardings — this is where DP/FSDP/TP/EP/SP policy lives.

The FSDP rule realizes the paper's C1 (assembled storage): parameters and
optimizer state are stored *sharded* over the data axes ("one canonical
copy") and gathered on use, instead of replicated ("scattered") — the
Z / Z^T algebra at the parameter level (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamBuilder",
    "ShardingRules",
    "RULES_TP_FSDP",
    "RULES_TP_DP",
    "RULES_SINGLE",
    "logical_to_spec",
    "tree_shardings",
    "tree_specs",
]


# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
ShardingRules = dict[str, Any]

# Production profile: TP over "model"; FSDP ("assembled" parameter storage,
# paper C1) over ("pod","data") applied to the embed axis of weight matrices;
# experts over "model" (EP); batch over ("pod","data"); decode-time KV
# sequence over "model" (flash-decode SP).
RULES_TP_FSDP: ShardingRules = {
    "batch": ("pod", "data"),
    "embed": ("pod", "data"),      # FSDP shard dim of params
    "embed_act": None,             # activations: d_model unsharded
    "heads": "model",
    "kv_heads": "model",
    "qk": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "seq": None,
    "seq_shard": "model",          # SP constraint points / KV-cache seq
    "layers": None,
    "conv": None,
    "state": None,
    "lora": None,
    "unsharded": None,
}

# Pure DP + TP (params replicated over data axes) — the "scattered" baseline.
RULES_TP_DP: ShardingRules = dict(RULES_TP_FSDP, embed=None)

# Single-device (smoke tests).
RULES_SINGLE: ShardingRules = {k: None for k in RULES_TP_FSDP}


def logical_to_spec(axes: tuple[str | None, ...], rules: ShardingRules, mesh: Mesh | None = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    out = []
    for a in axes:
        m = rules.get(a) if a else None
        if m is None:
            out.append(None)
            continue
        # drop mesh axes that don't exist (e.g. "pod" on single-pod meshes)
        if mesh is not None:
            names = mesh.axis_names
            if isinstance(m, tuple):
                m = tuple(x for x in m if x in names) or None
                if m is not None and len(m) == 1:
                    m = m[0]
            elif m not in names:
                m = None
        out.append(m)
    return P(*out)


@dataclasses.dataclass
class ParamBuilder:
    """Initializes params and records their logical axes (flat, one level).

    Init functions follow the convention ``init_x(key, ...) -> (params, axes)``
    and nest children manually::

        pb = ParamBuilder(key, dtype=jnp.bfloat16)
        pb.param("wq", (d, h, hd), ("embed", "heads", "qk"), scale=d**-0.5)
        params, axes = pb.collect()
        params["ffn"], axes["ffn"] = init_ffn(pb.fork(), ...)
    """

    key: jax.Array
    dtype: Any = jnp.float32
    params: dict = dataclasses.field(default_factory=dict)
    axes: dict = dataclasses.field(default_factory=dict)

    def fork(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple,
        *,
        scale: float | None = None,
        init: str = "normal",
    ) -> jax.Array:
        if len(shape) != len(axes):
            raise ValueError(f"{name}: shape {shape} vs axes {axes}")
        if name in self.params:
            raise KeyError(f"duplicate param {name}")
        if init == "zeros":
            v = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            v = jnp.ones(shape, self.dtype)
        else:
            s = scale if scale is not None else 0.02
            v = (jax.random.normal(self.fork(), shape, jnp.float32) * s).astype(
                self.dtype
            )
        self.params[name] = v
        self.axes[name] = tuple(axes)
        return v

    def collect(self) -> tuple[dict, dict]:
        return self.params, self.axes


def tree_specs(axes_tree: Any, rules: ShardingRules, mesh: Mesh | None = None) -> Any:
    """Logical-axes tree -> PartitionSpec tree."""
    return jax.tree.map(
        lambda a: logical_to_spec(a, rules, mesh),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x
        ),
    )


def tree_shardings(axes_tree: Any, rules: ShardingRules, mesh: Mesh) -> Any:
    """Logical-axes tree -> NamedSharding tree for pjit in/out shardings."""
    specs = tree_specs(axes_tree, rules, mesh)
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _spec_with_fallback(
    shape: tuple[int, ...], axes: tuple, rules: ShardingRules, mesh: Mesh
) -> P:
    """Rules -> spec, dropping mesh axes that don't divide the dimension.

    A 1-kv-head cache can't shard over a 16-way model axis; Mixtral's 8
    experts can't EP over 16 shards — such dims fall back to replication
    (or to a divisible prefix of a tuple assignment). Each mesh axis is
    used at most once per spec.
    """
    used: set[str] = set()
    parts: list = []
    names = set(mesh.axis_names)
    for dim, a in zip(shape, axes):
        m = rules.get(a) if a else None
        if m is None:
            parts.append(None)
            continue
        cand = m if isinstance(m, tuple) else (m,)
        cand = tuple(x for x in cand if x in names and x not in used)
        # drop trailing axes until the product divides the dimension
        while cand:
            prod = 1
            for x in cand:
                prod *= mesh.shape[x]
            if dim % prod == 0:
                break
            cand = cand[:-1]
        if not cand:
            parts.append(None)
        else:
            used.update(cand)
            parts.append(cand if len(cand) > 1 else cand[0])
    return P(*parts)


def tree_shardings_for(
    abstract_tree: Any, axes_tree: Any, rules: ShardingRules, mesh: Mesh
) -> Any:
    """Shape-aware shardings: like tree_shardings but checks divisibility."""
    return jax.tree.map(
        lambda leaf, a: NamedSharding(
            mesh, _spec_with_fallback(tuple(leaf.shape), a, rules, mesh)
        ),
        abstract_tree,
        axes_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
