"""hipBone — the paper's own benchmark as a selectable 'architecture'.

Shapes follow the paper's scaling studies: degree N=7 (3-D-threadblock
regime) and N=15 (2-D regime / peak-FOM degree), with per-rank element
boxes sized so the per-rank DOF counts bracket the paper's sweep. These
cells are EXTRA, beyond the 40 assigned LM cells.
"""
import dataclasses

__all__ = ["PoissonConfig", "CONFIGS"]


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    name: str
    n_degree: int
    local_elems: tuple[int, int, int]   # elements per rank
    lam: float = 1.0
    n_iter: int = 100                   # NekBone's fixed CG iteration count
    dtype: str = "float32"
    # preconditioner ladder rung: "none" (NekBone-faithful plain CG),
    # "jacobi" (assembled-diagonal scale), "chebyshev" (degree-`cheb_degree`
    # Chebyshev–Jacobi on the Lanczos-estimated [λ_min, λ_max] interval), or
    # "pmg" (Chebyshev-smoothed p-multigrid V-cycle N → ⌈N/2⌉ → … → 1, the
    # production Nek5000/RS configuration).
    precond: str = "none"
    cheb_degree: int = 2                # standalone Chebyshev polynomial degree
    tol: float | None = None            # None = fixed n_iter (NekBone mode)
    # pmg knobs: per-level smoother degree (Chebyshev order of the pre/post
    # smoothing sweeps) and the degree of the full-interval Chebyshev solve
    # on the coarsest (N=1) level of the ladder.
    pmg_smooth_degree: int = 4
    pmg_coarse_iters: int = 16

    def __post_init__(self):
        if self.precond not in ("none", "jacobi", "chebyshev", "pmg"):
            raise ValueError(f"unknown precond {self.precond!r}")

    def dofs_per_rank(self) -> int:
        n = self.n_degree
        bx, by, bz = self.local_elems
        return bx * by * bz * n**3


CONFIGS = {
    "hipbone_n7": PoissonConfig("hipbone_n7", 7, (8, 8, 8)),      # ~176k DOF/rank
    "hipbone_n7_large": PoissonConfig("hipbone_n7_large", 7, (16, 16, 16)),
    "hipbone_n15": PoissonConfig("hipbone_n15", 15, (4, 4, 4)),   # ~216k DOF/rank
    "hipbone_n15_large": PoissonConfig("hipbone_n15_large", 15, (8, 8, 8)),
    # beyond-the-benchmark: production-style preconditioned solves to tol
    "hipbone_n7_pcg": PoissonConfig(
        "hipbone_n7_pcg", 7, (8, 8, 8), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n15_pcg": PoissonConfig(
        "hipbone_n15_pcg", 15, (4, 4, 4), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n7_pmg": PoissonConfig(
        "hipbone_n7_pmg", 7, (8, 8, 8), precond="pmg", tol=1e-6
    ),
    "hipbone_n15_pmg": PoissonConfig(
        "hipbone_n15_pmg", 15, (4, 4, 4), precond="pmg", tol=1e-6
    ),
}

REDUCED = PoissonConfig("hipbone_reduced", 3, (2, 2, 2))
