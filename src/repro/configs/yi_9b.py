"""yi-9b [dense] — arXiv:2403.04652. Llama-architecture GQA.

48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000. SwiGLU, RMSNorm.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="yi-9b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    dtype="float32",
)
