"""Materialized Galerkin coarse operators: block assembly, V-cycle wiring,
apply-count accounting, Pallas matvec, sharded parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import build_problem, cg_assembled, poisson_assembled
from repro.core.galerkin import (
    coarsen_element_blocks,
    galerkin_assembled_diagonal,
    galerkin_block_apply,
    galerkin_element_blocks,
    galerkin_ladder_blocks,
)
from repro.core.operator import coarsen_problem, local_operator_columns
from repro.core.precond import (
    make_pmg_preconditioner,
    make_preconditioner,
    make_transfer_pair,
)
from repro.core.sem import interpolation_matrix


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(4, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


def _dense(f, n):
    return np.array(jax.vmap(f, in_axes=1, out_axes=1)(jnp.eye(n)))


def test_local_operator_columns_matches_per_column(prob64):
    """The probing helper equals column-by-column local_poisson applies."""
    from repro.core.operator import local_poisson

    rng = np.random.default_rng(0)
    cols = jnp.asarray(rng.standard_normal((prob64.g.shape[-1], 3)))
    got = local_operator_columns(
        prob64.g, prob64.d, prob64.lam, prob64.w_local, cols
    )
    e = prob64.g.shape[0]
    for k in range(cols.shape[1]):
        want = local_poisson(
            jnp.broadcast_to(cols[:, k], (e, cols.shape[0])),
            prob64.g, prob64.d, prob64.lam, prob64.w_local,
        )
        np.testing.assert_allclose(np.array(got[:, :, k]), np.array(want))


def test_materialized_equals_chained_triple_product(prob64):
    """Z_cᵀ[Ĵᵀ(S_L+λW)Ĵ]Z_c == R A P exactly (to roundoff), levels 1 and 2,
    on a deformed mesh — the embedding identity the materialization rests on."""
    a = poisson_assembled(prob64)
    pc1 = coarsen_problem(prob64, 2)
    prolong, restrict = make_transfer_pair(prob64, pc1)
    want1 = _dense(lambda v: restrict(a(prolong(v))), pc1.n_global)
    blocks1 = galerkin_element_blocks(
        prob64.g, prob64.d, prob64.lam, prob64.w_local, 2
    )
    got1 = _dense(
        galerkin_block_apply(blocks1, pc1.l2g, pc1.n_global), pc1.n_global
    )
    np.testing.assert_allclose(got1, want1, atol=1e-12)

    # level 2: coarsen the *blocks*; chain the transfers for the reference
    pc2 = coarsen_problem(pc1, 1)
    p2, r2 = make_transfer_pair(pc1, pc2)
    want2 = _dense(lambda v: r2(restrict(a(prolong(p2(v))))), pc2.n_global)
    blocks2 = coarsen_element_blocks(blocks1, interpolation_matrix(1, 2))
    got2 = _dense(
        galerkin_block_apply(blocks2, pc2.l2g, pc2.n_global), pc2.n_global
    )
    np.testing.assert_allclose(got2, want2, atol=1e-12)

    # blocks are exactly symmetric; exact diagonal cross-checks the assembly
    np.testing.assert_array_equal(
        np.array(blocks1), np.array(blocks1.transpose(0, 2, 1))
    )
    np.testing.assert_allclose(
        np.array(galerkin_assembled_diagonal(blocks1, pc1.l2g, pc1.n_global)),
        np.diag(want1),
        atol=1e-12,
    )


@pytest.mark.parametrize("coefficient", ["smooth", "checker"])
def test_galerkin_probing_is_coefficient_agnostic(coefficient):
    """ISSUE tentpole: the block probe consumes exactly the streams the
    fine operator does — variable k rides the folded g and λ(x) rides the
    screen stream — so Z_cᵀ[Ĵᵀ(S_k+JWλ)Ĵ]Z_c == R A P with no
    coefficient-aware code anywhere in the probing path."""
    from repro.core.operator import screen_stream

    jax.config.update("jax_enable_x64", True)
    prob = build_problem(
        4, (2, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64,
        coefficient=coefficient,
    )
    a = poisson_assembled(prob)
    pc1 = coarsen_problem(prob, 2)
    prolong, restrict = make_transfer_pair(prob, pc1)
    want = _dense(lambda v: restrict(a(prolong(v))), pc1.n_global)
    w_eff, lam_eff = screen_stream(prob)
    blocks = galerkin_element_blocks(prob.g, prob.d, lam_eff, w_eff, 2)
    got = _dense(
        galerkin_block_apply(blocks, pc1.l2g, pc1.n_global), pc1.n_global
    )
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_ladder_blocks_match_per_level_probing(prob64):
    """galerkin_ladder_blocks (probe once, contract deeper) equals probing
    the fine operator independently at every coarse degree."""
    ladder = galerkin_ladder_blocks(
        prob64.g, prob64.d, prob64.lam, prob64.w_local, (4, 2, 1)
    )
    for nc, blocks in zip((2, 1), ladder):
        direct = galerkin_element_blocks(
            prob64.g, prob64.d, prob64.lam, prob64.w_local, nc
        )
        np.testing.assert_allclose(
            np.array(blocks), np.array(direct), atol=1e-12
        )


def test_galerkin_mat_vcycle_matches_chained_and_is_spd(prob64):
    """The galerkin_mat V-cycle is the chained-galerkin V-cycle to roundoff
    (same matrix, materialized) and stays a symmetric positive-definite map."""
    a = poisson_assembled(prob64)
    pc_chained, _ = make_pmg_preconditioner(prob64, a, coarse_op="galerkin")
    pc_mat, info = make_pmg_preconditioner(prob64, a, coarse_op="galerkin_mat")
    assert info.coarse_op == "galerkin_mat"
    m_chained = _dense(pc_chained, prob64.n_global)
    m_mat = _dense(pc_mat, prob64.n_global)
    scale = np.abs(m_chained).max()
    assert np.abs(m_mat - m_chained).max() < 1e-12 * scale
    np.testing.assert_allclose(m_mat, m_mat.T, atol=1e-12)
    assert np.linalg.eigvalsh(0.5 * (m_mat + m_mat.T)).min() > 0


def test_galerkin_mat_zero_fine_applies_per_coarse_apply(prob64):
    """ISSUE acceptance: materialized coarse applies never invoke the fine
    operator — per V-cycle the galerkin_mat fine-apply count equals the
    rediscretized count (fine-level smoothing + residual only), while the
    chained form pays extra fine applies for every coarse-level visit."""
    a = poisson_assembled(prob64)
    counts = {}
    rng = np.random.default_rng(0)
    r = jnp.asarray(rng.standard_normal(prob64.n_global))
    for coarse_op in ("redisc", "galerkin", "galerkin_mat"):
        calls = {"n": 0}

        def counting_a(v, _calls=calls):
            _calls["n"] += 1
            return a(v)

        pc, _ = make_pmg_preconditioner(prob64, counting_a, coarse_op=coarse_op)
        calls["n"] = 0          # discard setup-time (spectrum) applies
        jax.block_until_ready(pc(r))
        counts[coarse_op] = calls["n"]
    assert counts["galerkin_mat"] == counts["redisc"], counts
    assert counts["galerkin"] > counts["galerkin_mat"], counts

    # and the materialized coarse operator itself makes zero fine applies
    calls = {"n": 0}

    def counting_a2(v):
        calls["n"] += 1
        return a(v)

    blocks = galerkin_element_blocks(
        prob64.g, prob64.d, prob64.lam, prob64.w_local, 2
    )
    pc1 = coarsen_problem(prob64, 2)
    coarse = galerkin_block_apply(blocks, pc1.l2g, pc1.n_global)
    jax.block_until_ready(coarse(jnp.ones(pc1.n_global)))
    assert calls["n"] == 0


def test_galerkin_mat_iteration_parity_small(prob64):
    """galerkin_mat walks the same PCG iterates as chained galerkin."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    iters = {}
    for coarse_op in ("galerkin", "galerkin_mat"):
        pc, _ = make_preconditioner(
            "pmg", prob64, a, pmg_coarse_op=coarse_op
        )
        res = cg_assembled(a, b, n_iter=300, tol=1e-10, precond=pc)
        assert int(res.iterations) < 300
        iters[coarse_op] = int(res.iterations)
        rel = np.linalg.norm(np.array(a(res.x) - b)) / np.linalg.norm(
            np.array(b)
        )
        assert rel < 1e-8
    assert iters["galerkin_mat"] == iters["galerkin"], iters


def test_galerkin_mat_mixed_within_one_iteration():
    """fp32-assembled blocks behind the cast boundary: within +1 iteration
    of the fp64 galerkin_mat solve (flexible β)."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(4, (3, 2, 2), lam=0.1, deform=0.2, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(prob.n_global))
    iters = {}
    for pdt in (None, jnp.float32):
        pc, info = make_preconditioner(
            "pmg", prob, a, pmg_coarse_op="galerkin_mat", precond_dtype=pdt
        )
        if pdt is not None:
            assert info.dtype == "float32"
        res = cg_assembled(
            a, b, n_iter=300, tol=1e-8, precond=pc,
            cg_variant="standard" if pdt is None else "flexible",
        )
        assert int(res.iterations) < 300
        iters[pdt] = int(res.iterations)
    assert iters[jnp.float32] <= iters[None] + 1, iters


def test_acceptance_n7_small_lambda_parity():
    """ISSUE acceptance: on the PR 3 case (N=7, λ=0.1, tol=1e-8) the
    materialized form reproduces chained Galerkin iteration-for-iteration
    and keeps the gap closed vs rediscretized pmg."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(7, (4, 4, 4), lam=0.1, deform=0.15, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global))
    iters = {}
    for coarse_op in ("redisc", "galerkin", "galerkin_mat"):
        pc, _ = make_preconditioner("pmg", prob, a, pmg_coarse_op=coarse_op)
        res = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc)
        assert int(res.iterations) < 500
        iters[coarse_op] = int(res.iterations)
    assert iters["galerkin_mat"] == iters["galerkin"], iters
    assert iters["galerkin_mat"] < iters["redisc"], iters


def test_pallas_block_matvec_matches_ref():
    """kernels.ops.block_matvec (interpret mode) == the einsum reference,
    incl. element counts that don't divide the block size."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(0)
    for e, p, dt in ((7, 27, jnp.float32), (13, 125, jnp.float64)):
        b = jnp.asarray(rng.standard_normal((e, p, p)), dt)
        u = jnp.asarray(rng.standard_normal((e, p)), dt)
        got = ops.block_matvec(b, u, block_e=4, interpret=True)
        np.testing.assert_allclose(
            np.array(got), np.array(ref.block_matvec_ref(b, u)), rtol=1e-6
        )


def test_galerkin_matvec_injection(prob64):
    """make_pmg_preconditioner(galerkin_matvec=...) routes coarse applies
    through the injected batched matvec (the Pallas wiring hook)."""
    from repro.kernels import ops

    a = poisson_assembled(prob64)
    pc_default, _ = make_pmg_preconditioner(
        prob64, a, coarse_op="galerkin_mat"
    )
    pc_pallas, _ = make_pmg_preconditioner(
        prob64, a, coarse_op="galerkin_mat",
        galerkin_matvec=ops.make_block_matvec(interpret=True),
    )
    rng = np.random.default_rng(3)
    r = jnp.asarray(rng.standard_normal(prob64.n_global))
    np.testing.assert_allclose(
        np.array(pc_pallas(r)), np.array(pc_default(r)), rtol=1e-12
    )


def test_dist_galerkin_chained_raises():
    """The chained form stays single-device: dist_cg must refuse it loudly
    rather than silently rediscretizing."""
    from repro.comms.topology import ProcessGrid
    from repro.core.distributed import build_dist_problem, dist_cg

    grid = ProcessGrid((1, 1, 1))
    prob = build_dist_problem(2, grid, (2, 2, 2), dtype=jnp.float64)
    with pytest.raises(NotImplementedError, match="galerkin_mat"):
        dist_cg(prob, None, None, precond="pmg", pmg_coarse_op="galerkin")


def test_dist_galerkin_mat_matches_single_shard():
    """ISSUE acceptance: sharded pmg_coarse_op="galerkin_mat" matches the
    single-shard solve iteration-for-iteration at fp64 AND under
    precond_dtype=fp32, and beats rediscretized dist pmg at small λ."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.1, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.1, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
it_mat = {}
for pdtype, variant in ((None, "standard"), (jnp.float32, "flexible")):
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond="pmg", pmg_coarse_op="galerkin_mat",
                          precond_dtype=pdtype, cg_variant=variant))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(iters) < 200, int(iters)
    pc, info = make_preconditioner("pmg", ref, A,
                                   pmg_coarse_op="galerkin_mat",
                                   precond_dtype=pdtype)
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc,
                       cg_variant=variant)
    assert int(iters) == int(res.iterations), (
        pdtype, int(iters), int(res.iterations))
    err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
    assert err < 1e-8, (pdtype, err)
    it_mat[pdtype] = int(iters)
run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                      precond="pmg"))
_, _, it_redisc, _, _ = run()
assert it_mat[None] < int(it_redisc), (it_mat, int(it_redisc))
print("OK", it_mat, int(it_redisc))
"""
    )
