"""Manufactured-solutions convergence oracle for the generalized operator.

The variable-coefficient operator A = -∇·(k(x)∇) + λ(x) with mixed
Dirichlet/Neumann faces is *proven* correct here, not just regression-
pinned: pick a closed-form u*, derive f = -∇·(k∇u*) + λu* analytically,
assemble b = Zᵀ(JW∘f) and solve.  A correct discretization converges
**spectrally** in the polynomial degree N — the discrete-L2 error drops
by orders of magnitude per degree step for analytic u* — while any
consistency bug (a mis-folded k, a wrong screen weight, a mask applied
on the wrong side) flattens the curve immediately.  That makes the
convergence sweep a far sharper oracle than any fixed-tolerance
reference comparison.

Each :class:`MMSCase` pairs a coefficient family with a bc spec whose
boundary terms vanish identically for its u*:

* Dirichlet faces: u* = 0 there (no lifting needed, b is just masked);
* Neumann faces: k·∂u*/∂n = 0 there (the natural bc of the weak form —
  nothing to assemble);
* the checker case additionally has zero flux at the interior k-jump
  planes, so the piecewise forcing needs no interface terms.

The screen always rides the weak mass-weighted form (an explicit
``lam_field``, even for the "const" family): the legacy *algebraic* λI
screen is deliberately NOT the weak discretization of λu — it is
NekBone's benchmark semantics — and would cap convergence at the mass-
lumping error.  See ``core.operator.screen_stream``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

from ..core import cg_assembled, poisson_assembled
from ..core import coefficients as coef
from ..core.mesh import build_box_mesh
from ..core.operator import PoissonProblem, problem_from_mesh

__all__ = [
    "MMS_CASES",
    "MMSCase",
    "convergence_sweep",
    "discrete_l2_error",
    "exact_solution_global",
    "mms_problem",
    "mms_rhs",
]

_PI = np.pi


def _u_dirichlet(x, y, z):
    """sin(πx)sin(πy)sin(πz): vanishes on all six faces of [0,1]³."""
    return np.sin(_PI * x) * np.sin(_PI * y) * np.sin(_PI * z)


def _grad_dirichlet(x, y, z):
    sx, sy, sz = np.sin(_PI * x), np.sin(_PI * y), np.sin(_PI * z)
    cx, cy, cz = np.cos(_PI * x), np.cos(_PI * y), np.cos(_PI * z)
    return _PI * cx * sy * sz, _PI * sx * cy * sz, _PI * sx * sy * cz


def _lap_dirichlet(x, y, z):
    return -3.0 * _PI**2 * _u_dirichlet(x, y, z)


def _u_mixed(x, y, z):
    """sin(πx)cos(πy)cos(πz): u = 0 on the x faces, ∂u/∂n = 0 on y/z faces.

    Matches ``bc="mixed"`` (Dirichlet on x_lo/x_hi, Neumann elsewhere).
    """
    return np.sin(_PI * x) * np.cos(_PI * y) * np.cos(_PI * z)


def _grad_mixed(x, y, z):
    sx, sy, sz = np.sin(_PI * x), np.sin(_PI * y), np.sin(_PI * z)
    cx, cy, cz = np.cos(_PI * x), np.cos(_PI * y), np.cos(_PI * z)
    return _PI * cx * cy * cz, -_PI * sx * sy * cz, -_PI * sx * cy * sz


def _lap_mixed(x, y, z):
    return -3.0 * _PI**2 * _u_mixed(x, y, z)


def _u_neumann(x, y, z):
    """cos(2πx)cos(2πy)cos(2πz): zero normal derivative on every face AND
    on the x/y/z = ½ checker jump planes — the flux k·∂u/∂n is continuous
    (identically zero) across every k-discontinuity, so this smooth u* is
    the exact weak solution of the piecewise-k interface problem."""
    return np.cos(2 * _PI * x) * np.cos(2 * _PI * y) * np.cos(2 * _PI * z)


def _grad_neumann(x, y, z):
    sx, sy, sz = np.sin(2 * _PI * x), np.sin(2 * _PI * y), np.sin(2 * _PI * z)
    cx, cy, cz = np.cos(2 * _PI * x), np.cos(2 * _PI * y), np.cos(2 * _PI * z)
    return (
        -2 * _PI * sx * cy * cz,
        -2 * _PI * cx * sy * cz,
        -2 * _PI * cx * cy * sz,
    )


def _lap_neumann(x, y, z):
    return -12.0 * _PI**2 * _u_neumann(x, y, z)


@dataclasses.dataclass(frozen=True)
class MMSCase:
    """One manufactured solution: (coefficient family, bc spec, u*, ∇u*, Δu*)."""

    name: str
    coefficient: str            # "const" | "smooth" | "checker"
    bc: str                     # spec accepted by mesh.normalize_bc
    exact: Callable             # u*(x, y, z)
    grad: Callable              # (∂x, ∂y, ∂z) u*
    lap: Callable               # Δu*


MMS_CASES = {
    c.name: c
    for c in (
        MMSCase("const-dirichlet", "const", "dirichlet",
                _u_dirichlet, _grad_dirichlet, _lap_dirichlet),
        MMSCase("const-mixed", "const", "mixed",
                _u_mixed, _grad_mixed, _lap_mixed),
        MMSCase("smooth-dirichlet", "smooth", "dirichlet",
                _u_dirichlet, _grad_dirichlet, _lap_dirichlet),
        MMSCase("smooth-mixed", "smooth", "mixed",
                _u_mixed, _grad_mixed, _lap_mixed),
        MMSCase("checker-neumann", "checker", "neumann",
                _u_neumann, _grad_neumann, _lap_neumann),
    )
}


def mms_problem(
    case: MMSCase,
    n_degree: int,
    shape: tuple[int, int, int] = (2, 2, 2),
    *,
    lam: float = 1.0,
    dtype=jnp.float64,
) -> PoissonProblem:
    """The case's problem at degree ``n_degree`` on a ``shape`` element box.

    Always passes an explicit (constant) ``lam_field`` so the screen is
    the weak mass form — required for the convergence order; the "const"
    family here is NOT the legacy algebraic-λI problem (see module doc).
    """
    m = build_box_mesh(n_degree, shape)
    if case.coefficient == "const":
        k = None
    elif case.coefficient == "smooth":
        x, y, z = (m.coords[..., i] for i in range(3))
        k = coef.smooth_k(x, y, z)
    elif case.coefficient == "checker":
        k = coef.checker_k_elements(m.coords)
    else:
        raise ValueError(f"unknown coefficient {case.coefficient!r}")
    return problem_from_mesh(
        m, lam=lam, dtype=dtype, k=k, lam_field=lam, bc=case.bc
    )


def _forcing(case: MMSCase, coords: np.ndarray, lam: float) -> np.ndarray:
    """f = -∇·(k∇u*) + λu* on the (E, p) node set, closed form."""
    x, y, z = coords[..., 0], coords[..., 1], coords[..., 2]
    u = case.exact(x, y, z)
    lap = case.lap(x, y, z)
    if case.coefficient == "smooth":
        k = coef.smooth_k(x, y, z)
        kx, ky, kz = coef.smooth_k_grad(x, y, z)
        ux, uy, uz = case.grad(x, y, z)
        return -k * lap - (kx * ux + ky * uy + kz * uz) + lam * u
    if case.coefficient == "checker":
        # element-constant k: no ∇k term inside elements, and the
        # interface flux terms vanish for this u* (zero normal flux).
        return -coef.checker_k_elements(coords) * lap + lam * u
    return -lap + lam * u


def mms_rhs(prob: PoissonProblem, case: MMSCase) -> jnp.ndarray:
    """Assembled load vector b = mask ∘ Zᵀ(JW ∘ f_L), in the solve dtype."""
    coords = np.asarray(prob.mesh.coords, np.float64)
    f_l = _forcing(case, coords, float(prob.lam))
    b_l = np.asarray(prob.jw, np.float64) * f_l
    b = np.zeros(prob.n_global)
    np.add.at(b, np.asarray(prob.mesh.l2g), b_l)
    if prob.mask is not None:
        b = b * np.asarray(prob.mask, np.float64)
    return jnp.asarray(b, prob.dtype)


def exact_solution_global(prob: PoissonProblem, case: MMSCase) -> np.ndarray:
    """u* sampled on the assembled (N_G,) DOF set."""
    coords = np.asarray(prob.mesh.coords, np.float64)
    xg = np.zeros((prob.n_global, 3))
    xg[np.asarray(prob.mesh.l2g)] = coords
    return case.exact(xg[:, 0], xg[:, 1], xg[:, 2])


def discrete_l2_error(
    prob: PoissonProblem, x, u_exact: np.ndarray
) -> float:
    """Relative discrete L2 error √(Σ JW·e²) / √(Σ JW·u*²), e = x − u*.

    Quadrature-weighted over the element-local node set (the assembly sum
    over duplicated interface nodes IS the quadrature sum), so the norm
    is mesh-independent and the sweep's errors are comparable across N.
    """
    e_l = (np.asarray(x, np.float64) - u_exact)[np.asarray(prob.mesh.l2g)]
    u_l = u_exact[np.asarray(prob.mesh.l2g)]
    jw = np.asarray(prob.jw, np.float64)
    return float(
        np.sqrt(np.sum(jw * e_l**2)) / np.sqrt(np.sum(jw * u_l**2))
    )


def convergence_sweep(
    case: MMSCase,
    degrees=(3, 5, 7, 9),
    shape: tuple[int, int, int] = (2, 2, 2),
    *,
    lam: float = 1.0,
    dtype=jnp.float64,
    tol: float = 1e-11,
    n_iter: int = 2000,
    fused: bool | None = None,
    fused_kwargs: dict | None = None,
    solve=None,
) -> list[float]:
    """Relative discrete-L2 errors of the case's solve at each degree.

    The oracle assertion pattern (tests/test_mms.py): errors decrease
    monotonically (small slack for the last near-roundoff step) and the
    first/last ratio spans ≥ 4 orders of magnitude — spectral convergence
    for analytic u*.  ``solve`` overrides the default fp64 plain-CG solve
    with a custom ``solve(prob, operator, b) -> x`` (the sharded and
    preconditioned sweeps reuse the same builder + oracle this way);
    ``fused`` pins the fused/split assembled operator.
    """
    errs = []
    for n in degrees:
        prob = mms_problem(case, n, shape, lam=lam, dtype=dtype)
        operator = poisson_assembled(
            prob, fused=fused, fused_kwargs=fused_kwargs
        )
        b = mms_rhs(prob, case)
        if solve is None:
            # plain fp64 CG driven deep; the stagnation detector is off —
            # jump-coefficient spectra plateau for > a window and then
            # resume (checker at N=9 trips it at ~1e-5 error otherwise).
            res = cg_assembled(
                operator, b, n_iter=n_iter, tol=tol, stagnation_window=None
            )
            x = res.x
        else:
            x = solve(prob, operator, b)
        errs.append(
            discrete_l2_error(prob, x, exact_solution_global(prob, case))
        )
    return errs
