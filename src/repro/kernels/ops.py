"""Public jit'd wrappers for the Pallas kernels.

Handles padding to tile shapes, the CPU/TPU interpret switch, and the
reference fallback. Everything downstream (core.operator, core.cg,
benchmarks) calls these, never pl.pallas_call directly.

``interpret`` defaults to True off-TPU so the same code validates on CPU;
on a real TPU backend it compiles via Mosaic.
"""
from __future__ import annotations

import os
import warnings

import jax
import jax.numpy as jnp

from . import ref
from .blocks import block_matvec_pallas, pick_block_matvec_e
from .poisson import pick_block_e, poisson_local_pallas
from .poisson_fused import (
    fused_fits_vmem,
    pick_fused_block_e,
    poisson_assembled_fused_pallas,
)
from .streams import (
    LANES,
    fused_axpy_dot_batched_pallas,
    fused_axpy_dot_pallas,
    fused_cheb_d_update_pallas,
    fused_jacobi_dot_batched_pallas,
    fused_jacobi_dot_pallas,
    fused_xpay_batched_pallas,
    fused_xpay_pallas,
    weighted_dot_pallas,
)

__all__ = [
    "default_interpret",
    "fused_override",
    "should_fuse_streams",
    "should_fuse_operator",
    "probe_fused_operator",
    "poisson_local",
    "poisson_assembled_fused",
    "make_poisson_assembled_fused",
    "block_matvec",
    "make_block_matvec",
    "fused_axpy_dot",
    "fused_xpay",
    "weighted_dot",
    "fused_jacobi_dot",
    "fused_cheb_d_update",
    "fused_axpy_dot_batched",
    "fused_xpay_batched",
    "fused_jacobi_dot_batched",
    "make_local_op",
    "make_fused_jacobi_dot",
    "make_fused_cheb_d_update",
    "make_fused_jacobi_dot_batched",
]


def default_interpret() -> bool:
    """Interpret Pallas kernels unless running on a real TPU."""
    return jax.default_backend() != "tpu"


def fused_override() -> bool | None:
    """The HIPBONE_FUSED env override shared by every auto-enable policy.

    "0" forces the fused paths off, "1" forces them on even off-TPU (the
    CI pallas-interpret job routes the whole test suite through the
    interpret-mode kernels this way); anything else defers to the
    per-policy auto rule.
    """
    env = os.environ.get("HIPBONE_FUSED", "")
    if env in ("0", "1"):
        return env == "1"
    return None


def should_fuse_streams(dtype) -> bool:
    """Auto-enable policy for the fused streaming stages in solver hot paths.

    True when Pallas compiles natively (non-interpret backend, i.e. real
    TPU/GPU — interpret mode makes the fusions *slower* on CPU) AND the
    vectors the stage streams are fp32: the kernels' scalar reductions
    accumulate in fp32, which is exact enough for fp32 solves and for the
    fp32 interior of a mixed-precision preconditioner, but would throw away
    bits an fp64 tol=1e-8 recurrence needs (and TPUs have no native fp64
    regardless).  ``HIPBONE_FUSED`` (``fused_override``) wins over the auto
    rule; callers keep an explicit opt-out knob on top of this.
    """
    ov = fused_override()
    if ov is not None:
        return ov
    return (not default_interpret()) and jnp.dtype(dtype) == jnp.float32


# probe_fused_operator state: verdict per (n_degree, n_global, dtype,
# gather_mode) so the lowering attempt and its warning happen once per
# shape.  _FUSED_PROBE_FAIL is the fault-injection hook
# (repro.testing.faults.force_fused_failure) standing in for a real
# Mosaic/VMEM failure, which needs TPU hardware to reproduce.
_FUSED_PROBE_CACHE: dict[tuple, bool] = {}
_FUSED_PROBE_FAIL = False


def probe_fused_operator(
    n_degree: int, n_global: int, dtype, *, gather_mode: str = "take"
) -> bool:
    """Can the fused assembled kernel actually lower for this shape?

    ``should_fuse_operator``'s static policy (backend + VMEM model) can be
    wrong on shapes the model was never calibrated for; a policy mistake
    used to surface as a Pallas lowering / Mosaic VMEM-exhaustion crash in
    the middle of the user's jit.  This probe attempts the lowering once
    per shape on abstract operands (and, on a native backend, the Mosaic
    compile — that is where VMEM overflows are raised), caches the
    verdict, and turns a failure into a one-time warning + ``False`` so
    callers degrade to the split scatter→local-op→gather pipeline instead
    of crashing.
    """
    key = (int(n_degree), int(n_global), jnp.dtype(dtype).name, gather_mode)
    cached = _FUSED_PROBE_CACHE.get(key)
    if cached is not None:
        return cached
    n1 = n_degree + 1
    p = n1**3
    eb = max(1, pick_fused_block_e(n_degree, n_global, dtype))
    try:
        if _FUSED_PROBE_FAIL:
            raise RuntimeError(
                "forced fused-operator failure (repro.testing.faults)"
            )
        # one grid block's worth of elements exercises the kernel's full
        # VMEM residency (field block + element streams)
        args = (
            jax.ShapeDtypeStruct((int(n_global),), jnp.dtype(dtype)),
            jax.ShapeDtypeStruct((eb, p), jnp.int32),
            jax.ShapeDtypeStruct((eb, 6, p), jnp.dtype(dtype)),
            jax.ShapeDtypeStruct((eb, p), jnp.dtype(dtype)),
            jax.ShapeDtypeStruct((n1, n1), jnp.dtype(dtype)),
        )
        fn = lambda x, l2g, g, w, d: poisson_assembled_fused(
            x, l2g, g, w, d, lam=1.0, gather_mode=gather_mode
        )
        lowered = jax.jit(fn).lower(*args)
        if not default_interpret():
            lowered.compile()
        ok = True
    except Exception as exc:  # noqa: BLE001 — any lowering failure degrades
        warnings.warn(
            f"fused assembled operator failed to lower for N={n_degree}, "
            f"n_global={n_global}, dtype={jnp.dtype(dtype).name} "
            f"({type(exc).__name__}: {exc}); falling back to the split "
            "scatter/local-op/gather pipeline for this shape",
            RuntimeWarning,
            stacklevel=2,
        )
        ok = False
    _FUSED_PROBE_CACHE[key] = ok
    return ok


def should_fuse_operator(
    dtype, *, n_degree: int | None = None, n_global: int | None = None
) -> bool:
    """Auto-enable policy for the single-kernel fused assembled operator.

    True when Pallas compiles natively AND the resident x_G/y_G blocks fit
    the fused kernel's VMEM budget (``fused_fits_vmem``); the split
    scatter→local-op→gather path remains the fallback.  Unlike the stream
    stages there is no dtype restriction — the kernel accumulates in
    ``promote_types(dtype, f32)``, preserving fp64 semantics bit-for-bit at
    the summation-order level.  ``HIPBONE_FUSED`` (``fused_override``)
    forces the choice either way, including through interpret mode.

    Graceful degradation: whenever the answer would be True and the shape
    is known, ``probe_fused_operator`` verifies the kernel actually lowers
    (cached, once per shape) — a lowering/VMEM failure demotes the answer
    to False with a warning instead of crashing the solve, including under
    ``HIPBONE_FUSED=1``.
    """
    ov = fused_override()
    if ov is not None:
        enable = ov
    elif default_interpret():
        return False  # interpret-mode gather/scatter is slower than XLA's
    elif n_degree is not None and n_global is not None:
        enable = fused_fits_vmem(n_degree, n_global, dtype)
    else:
        enable = True
    if enable and n_degree is not None and n_global is not None:
        enable = probe_fused_operator(n_degree, n_global, dtype)
    return enable


def _pad_rows(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def poisson_local(
    u: jax.Array,
    g: jax.Array,
    w: jax.Array | None,
    d: jax.Array,
    *,
    lam: float,
    block_e: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused (S_L + λW) u with element padding. See kernels/poisson.py."""
    interp = default_interpret() if interpret is None else interpret
    e = u.shape[0]
    n1 = d.shape[0]
    eb = block_e or pick_block_e(n1 - 1, u.dtype)
    eb = max(1, min(eb, e))
    if w is None:
        w = jnp.ones_like(u)
    u_p, _ = _pad_rows(u, eb)
    g_p, _ = _pad_rows(g, eb)
    w_p, _ = _pad_rows(w, eb)
    out = poisson_local_pallas(
        u_p, g_p, w_p, d, lam=lam, block_e=eb, interpret=interp
    )
    return out[:e]


def poisson_assembled_fused(
    x_g: jax.Array,
    l2g: jax.Array,
    g: jax.Array,
    w: jax.Array,
    d: jax.Array,
    *,
    lam: float,
    block_e: int | None = None,
    interpret: bool | None = None,
    gather_mode: str = "take",
) -> jax.Array:
    """Single-pass y_G = Z^T (S_L + λW) Z x_G with padding handled.

    The array-level fused assembled apply (kernels/poisson_fused.py): pads
    x_G to the 128-lane tile and the element streams to ``block_e``, points
    padded elements at slot 0 (their zero G/W contributes exactly 0.0), and
    slices the result back to (n_global,).  Matches
    ``core.operator.poisson_assembled`` to summation-order round-off.
    """
    interp = default_interpret() if interpret is None else interpret
    n_g = x_g.shape[0]
    e = l2g.shape[0]
    n1 = d.shape[0]
    eb = block_e or pick_fused_block_e(n1 - 1, n_g, x_g.dtype)
    eb = max(1, min(eb, max(e, 1)))
    x_p, _ = _pad_vec(x_g, LANES)
    x2 = x_p.reshape(-1, LANES)
    l2g_p, _ = _pad_rows(l2g.astype(jnp.int32), eb)
    g_p, _ = _pad_rows(g, eb)
    w_p, _ = _pad_rows(w, eb)
    y2 = poisson_assembled_fused_pallas(
        x2,
        l2g_p,
        g_p,
        w_p,
        d,
        lam=float(lam),
        block_e=eb,
        interpret=interp,
        gather_mode=gather_mode,
    )
    return y2.reshape(-1)[:n_g]


def make_poisson_assembled_fused(
    prob,
    *,
    block_e: int | None = None,
    interpret: bool | None = None,
    gather_mode: str = "take",
):
    """Fused-operator apply closure for a ``core.operator.PoissonProblem``.

    Same call signature as the split ``poisson_assembled(prob)`` result —
    x_G -> A x_G — so the two are drop-in interchangeable; the returned
    closure carries ``apply.fused = True`` for introspection.

    Variable-coefficient problems need no kernel changes: k(x) is already
    folded into ``prob.g`` and the λ(x) screen rides the ``w`` stream with
    ``lam`` pinned to 1.0 (``core.operator.screen_stream`` — ``lam`` is a
    static argname in the Pallas jit, which is exactly why the field form
    cannot go through it); Dirichlet BCs are the same mask∘A∘mask wrap as
    the split path.
    """
    from ..core.operator import screen_stream  # lazy: core imports kernels

    w_eff, lam_eff = screen_stream(prob)
    mask = prob.mask

    def apply(x_g: jax.Array) -> jax.Array:
        if mask is not None:
            x_g = mask * x_g
        y_g = poisson_assembled_fused(
            x_g,
            prob.l2g,
            prob.g,
            w_eff,
            prob.d,
            lam=lam_eff,
            block_e=block_e,
            interpret=interpret,
            gather_mode=gather_mode,
        )
        return y_g if mask is None else mask * y_g

    apply.fused = True
    return apply


def block_matvec(
    blocks: jax.Array,
    u: jax.Array,
    *,
    block_e: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Batched dense element matvec y_e = B_e u_e with element padding.

    The Pallas form of the materialized-Galerkin coarse apply
    (``core.galerkin.block_matvec_einsum`` is the XLA reference); see
    kernels/blocks.py.  Shapes: (E, p, p), (E, p) -> (E, p).
    """
    interp = default_interpret() if interpret is None else interpret
    e, p = u.shape
    eb = block_e or pick_block_matvec_e(p, u.dtype)
    eb = max(1, min(eb, e))
    b_p, _ = _pad_rows(blocks, eb)
    u_p, _ = _pad_rows(u, eb)
    out = block_matvec_pallas(b_p, u_p, block_e=eb, interpret=interp)
    return out[:e]


def make_block_matvec(*, block_e: int | None = None, interpret: bool | None = None):
    """Adapter with core.galerkin's ``matvec`` signature (blocks, u) -> y."""
    return lambda blocks, u: block_matvec(
        blocks, u, block_e=block_e, interpret=interpret
    )


def _pad_vec(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    n = x.size
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x.reshape(-1), jnp.zeros((pad,), x.dtype)])
    return x.reshape(-1), n


def _stream_block_rows(padded_size: int, want: int = 512) -> int:
    rows = padded_size // LANES
    br = min(want, rows)
    while rows % br:
        br -= 1
    return br


def fused_axpy_dot(
    r: jax.Array, ap: jax.Array, alpha: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """One-pass (r - α·Ap, ||r - α·Ap||²) for arbitrary-length vectors."""
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    r_p, n = _pad_vec(r, LANES)
    ap_p, _ = _pad_vec(ap, LANES)
    br = _stream_block_rows(r_p.size)
    r_new, rr = fused_axpy_dot_pallas(
        r_p, ap_p, alpha, block_rows=br, interpret=interp
    )
    # padded tail contributes alpha*0 - 0 = 0 to both outputs
    return r_new[:n].reshape(shape), rr


def fused_xpay(
    r: jax.Array, p: jax.Array, beta: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    r_p, n = _pad_vec(r, LANES)
    p_p, _ = _pad_vec(p, LANES)
    br = _stream_block_rows(r_p.size)
    out = fused_xpay_pallas(r_p, p_p, beta, block_rows=br, interpret=interp)
    return out[:n].reshape(shape)


def weighted_dot(
    w: jax.Array, a: jax.Array, b: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    interp = default_interpret() if interpret is None else interpret
    w_p, _ = _pad_vec(w, LANES)
    a_p, _ = _pad_vec(a, LANES)
    b_p, _ = _pad_vec(b, LANES)
    br = _stream_block_rows(w_p.size)
    return weighted_dot_pallas(w_p, a_p, b_p, block_rows=br, interpret=interp)


def fused_jacobi_dot(
    dinv: jax.Array, r: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """One-pass (D⁻¹r, r·D⁻¹r) for arbitrary-length vectors (PCG z-stage)."""
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    d_p, n = _pad_vec(dinv, LANES)
    r_p, _ = _pad_vec(r, LANES)
    br = _stream_block_rows(r_p.size)
    # padded tail: dinv pad is 0 so z and the r·z partials stay 0 there
    z, rz = fused_jacobi_dot_pallas(d_p, r_p, block_rows=br, interpret=interp)
    return z[:n].reshape(shape), rz


def fused_cheb_d_update(
    a: jax.Array,
    c: jax.Array,
    d: jax.Array,
    r: jax.Array,
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """d ← a·d + c·r for arbitrary-length vectors (Chebyshev d-update)."""
    interp = default_interpret() if interpret is None else interpret
    shape = d.shape
    d_p, n = _pad_vec(d, LANES)
    r_p, _ = _pad_vec(r, LANES)
    br = _stream_block_rows(d_p.size)
    out = fused_cheb_d_update_pallas(a, c, d_p, r_p, block_rows=br, interpret=interp)
    return out[:n].reshape(shape)


def _pad_block(x: jax.Array, multiple: int) -> tuple[jax.Array, int]:
    """Pad the trailing axis of a (B, n) block to a multiple of ``multiple``."""
    n = x.shape[-1]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1
        )
    return x, n


def fused_axpy_dot_batched(
    r: jax.Array, ap: jax.Array, alpha: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Per-column one-pass (r - α·Ap, ‖·‖²) over a (B, n) RHS block.

    ``alpha`` is (B,) — each solve column advances by its own CG step.
    Returns the updated (B, n) block and the (B,) squared norms.
    """
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    r_p, n = _pad_block(r, LANES)
    ap_p, _ = _pad_block(ap, LANES)
    br = _stream_block_rows(r_p.shape[-1])
    # padded tail contributes alpha*0 - 0 = 0 to both outputs
    r_new, rr = fused_axpy_dot_batched_pallas(
        r_p, ap_p, alpha, block_rows=br, interpret=interp
    )
    return r_new[:, :n].reshape(shape), rr


def fused_xpay_batched(
    r: jax.Array, p: jax.Array, beta: jax.Array, *, interpret: bool | None = None
) -> jax.Array:
    """Per-column r + β·p over a (B, n) block; ``beta`` is (B,)."""
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    r_p, n = _pad_block(r, LANES)
    p_p, _ = _pad_block(p, LANES)
    br = _stream_block_rows(r_p.shape[-1])
    out = fused_xpay_batched_pallas(r_p, p_p, beta, block_rows=br, interpret=interp)
    return out[:, :n].reshape(shape)


def fused_jacobi_dot_batched(
    dinv: jax.Array, r: jax.Array, *, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Per-column (D⁻¹r, r·D⁻¹r) over a (B, n) block.

    ``dinv`` stays (n,) — the diagonal stream is shared by every column,
    never replicated B-fold through memory.
    """
    interp = default_interpret() if interpret is None else interpret
    shape = r.shape
    d_p, n = _pad_vec(dinv, LANES)
    r_p, _ = _pad_block(r, LANES)
    br = _stream_block_rows(r_p.shape[-1])
    # padded tail: dinv pad is 0 so z and the r·z partials stay 0 there
    z, rz = fused_jacobi_dot_batched_pallas(
        d_p, r_p, block_rows=br, interpret=interp
    )
    return z[:, :n].reshape(shape), rz


def make_fused_jacobi_dot_batched(
    dinv: jax.Array, *, interpret: bool | None = None, out_dtype=None
):
    """Batched counterpart of ``make_fused_jacobi_dot``: r_block -> (z, r·z).

    Same mixed-precision boundary: with ``out_dtype`` the (B, n) block is
    rounded to ``dinv.dtype`` for the fused pass and widened back.
    """
    if out_dtype is None:
        return lambda r: fused_jacobi_dot_batched(dinv, r, interpret=interpret)
    odt = jnp.dtype(out_dtype)

    def apply(r: jax.Array) -> tuple[jax.Array, jax.Array]:
        z, rz = fused_jacobi_dot_batched(
            dinv, r.astype(dinv.dtype), interpret=interpret
        )
        return z.astype(odt), rz.astype(odt)

    return apply


def make_fused_jacobi_dot(
    dinv: jax.Array, *, interpret: bool | None = None, out_dtype=None
):
    """Adapter with cg_assembled's fused_precond_dot signature r -> (z, r·z).

    ``out_dtype`` is the mixed-precision boundary: r is rounded to
    ``dinv.dtype`` before the fused pass and (z, r·z) widened back, so an
    fp32 fused Jacobi stage (fp32 dinv) can gate an fp64 outer PCG — the
    fp32-input variant of the stage the mixed path uses.
    """
    if out_dtype is None:
        return lambda r: fused_jacobi_dot(dinv, r, interpret=interpret)
    odt = jnp.dtype(out_dtype)

    def apply(r: jax.Array) -> tuple[jax.Array, jax.Array]:
        z, rz = fused_jacobi_dot(
            dinv, r.astype(dinv.dtype), interpret=interpret
        )
        return z.astype(odt), rz.astype(odt)

    return apply


def make_fused_cheb_d_update(*, interpret: bool | None = None):
    """Adapter with chebyshev_apply's fused_d_update signature (a, c, d, r)."""
    return lambda a, c, d, r: fused_cheb_d_update(a, c, d, r, interpret=interpret)


def make_local_op(*, block_e: int | None = None, interpret: bool | None = None):
    """Adapter with core.operator's local_op signature (u, g, d, lam, w)."""

    def op(u, g, d, lam, w, jw=None):
        del jw
        return poisson_local(
            u, g, w, d, lam=float(lam), block_e=block_e, interpret=interpret
        )

    return op
