#!/usr/bin/env python
"""Verify that markdown cross-references in this repo resolve.

Usage:
    python scripts/check_links.py [files...]       # default: README + docs/

Checks every ``[text](target)`` and bare ``path`` reference in backticks:

  * relative file links (``docs/SOLVERS.md``, ``src/repro/core/precond.py``)
    must exist on disk (anchors after ``#`` are stripped);
  * ``module.attr``-style backtick references are left alone (not links);
  * http(s) URLs are *not* fetched (CI runs offline) — only syntax-checked.

Exit 1 with a per-file report if anything dangles, so the docs cannot
drift from the tree they describe.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DEFAULT = ["README.md", "ROADMAP.md", "docs/ARCHITECTURE.md", "docs/SOLVERS.md"]

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
# backtick references that look like repo paths (contain a slash and a dot)
TICK_PATH = re.compile(r"`([A-Za-z0-9_./-]+/[A-Za-z0-9_.-]+\.[A-Za-z0-9]+)`")


def _display(md: Path) -> str:
    try:
        return str(md.resolve().relative_to(REPO))
    except ValueError:
        return str(md)


def check_file(md: Path) -> list[str]:
    errors = []
    text = md.read_text()
    targets = []
    for match in MD_LINK.finditer(text):
        targets.append((match.group(1), "link"))
    for match in TICK_PATH.finditer(text):
        targets.append((match.group(1), "backtick path"))
    for target, kind in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue  # pure intra-document anchor
        # glob-ish references ("src/repro/configs/*.py") are descriptive
        if any(ch in path for ch in "*<>"):
            continue
        candidates = (
            (md.parent / path).resolve(),
            (REPO / path).resolve(),
            # module shorthand: `core/precond.py` means the package path
            (REPO / "src" / "repro" / path).resolve(),
        )
        if not any(c.exists() for c in candidates):
            errors.append(f"{_display(md)}: dangling {kind} -> {target}")
    return errors


def main() -> int:
    # relative CLI paths resolve against the repo root, not the cwd
    files = [
        Path(a) if Path(a).is_absolute() else REPO / a for a in sys.argv[1:]
    ] or [REPO / rel for rel in DEFAULT if (REPO / rel).exists()]
    all_errors = []
    for md in files:
        if not md.exists():
            all_errors.append(f"missing file: {md}")
            continue
        all_errors.extend(check_file(md))
    for err in all_errors:
        print(err)
    if all_errors:
        print(f"\n{len(all_errors)} dangling reference(s)")
        return 1
    print(f"all references resolve in {len(files)} file(s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
