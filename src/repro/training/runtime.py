"""Fault-tolerant training runner: checkpoint-restart, preemption, stragglers.

Design targets for thousand-node runs (DESIGN.md §5):
  * every step is restartable — state = (params, opt_state, step), data is
    a pure function of step, so recovery = restore + continue;
  * SIGTERM (preemption notice) triggers a synchronous checkpoint before
    exit;
  * transient step failures retry from the last checkpoint with a bounded
    budget (node-failure handling: in a real cluster the relaunch happens
    with a fresh mesh, and restore reshards — see checkpoint.restore);
  * per-step wall-time statistics feed a straggler watermark: steps slower
    than ``straggler_factor`` x the rolling median are counted and
    reported, the signal a cluster scheduler uses to evict slow hosts.
"""
from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Any, Callable

import jax

from .checkpoint import CheckpointManager, latest_step, restore

__all__ = ["RunnerConfig", "TrainRunner"]


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int
    checkpoint_dir: str
    checkpoint_every: int = 100
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 2.0
    log_every: int = 10


class TrainRunner:
    def __init__(
        self,
        cfg: RunnerConfig,
        step_fn: Callable,                      # (params, opt, batch, idx) -> ...
        data_fn: Callable[[int], Any],          # step -> batch (deterministic)
        params: Any,
        opt_state: Any,
        *,
        log: Callable[[str], None] = print,
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.params = params
        self.opt_state = opt_state
        self.log = log
        self.ckpt = CheckpointManager(cfg.checkpoint_dir, keep=cfg.keep)
        self.step = 0
        self.step_times: list[float] = []
        self.straggler_events = 0
        self._preempted = False

    # -- fault-tolerance hooks ------------------------------------------
    def _install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True
            self.log("[runner] SIGTERM received — checkpointing before exit")

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # non-main thread (tests)

    def _save(self, sync: bool = False):
        tree = {"params": self.params, "opt_state": self.opt_state}
        if sync:
            self.ckpt.wait()
            from .checkpoint import save

            save(self.cfg.checkpoint_dir, self.step, jax.device_get(tree), keep=self.cfg.keep)
        else:
            self.ckpt.save_async(self.step, tree)

    def try_restore(self) -> bool:
        s = latest_step(self.cfg.checkpoint_dir)
        if s is None:
            return False
        tree = {"params": self.params, "opt_state": self.opt_state}
        restored, step = restore(self.cfg.checkpoint_dir, tree)
        self.params, self.opt_state = restored["params"], restored["opt_state"]
        self.step = step
        self.log(f"[runner] restored checkpoint at step {step}")
        return True

    # -- straggler watermark ---------------------------------------------
    def _record_time(self, dt: float):
        self.step_times.append(dt)
        window = self.step_times[-50:]
        if len(window) >= 10:
            med = statistics.median(window)
            if dt > self.cfg.straggler_factor * med:
                self.straggler_events += 1
                self.log(
                    f"[runner] straggler: step {self.step} took {dt*1e3:.1f}ms "
                    f"(median {med*1e3:.1f}ms)"
                )

    # -- main loop ---------------------------------------------------------
    def run(self) -> dict:
        self._install_preemption_handler()
        restarts = 0
        metrics = {}
        while self.step < self.cfg.total_steps:
            try:
                t0 = time.perf_counter()
                batch = self.data_fn(self.step)
                out = self.step_fn(self.params, self.opt_state, batch, self.step)
                self.params, self.opt_state, metrics = out
                jax.block_until_ready(metrics)
                self._record_time(time.perf_counter() - t0)
                self.step += 1
                if self.step % self.cfg.log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    self.log(f"[runner] step {self.step}: {m}")
                if self.step % self.cfg.checkpoint_every == 0:
                    self._save()
                if self._preempted:
                    self._save(sync=True)
                    self.log("[runner] exiting on preemption")
                    break
            except (FloatingPointError, RuntimeError, ValueError) as e:
                restarts += 1
                self.log(f"[runner] step {self.step} failed ({e!r}); restart {restarts}")
                if restarts > self.cfg.max_restarts:
                    raise
                if not self.try_restore():
                    self.log("[runner] no checkpoint to restore; re-raising")
                    raise
        self.ckpt.wait()
        return {
            "final_step": self.step,
            "straggler_events": self.straggler_events,
            "mean_step_time": (
                sum(self.step_times) / len(self.step_times) if self.step_times else 0.0
            ),
            "metrics": {k: float(v) for k, v in metrics.items()} if metrics else {},
        }
