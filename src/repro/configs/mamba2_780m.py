"""mamba2-780m [ssm] — SSD state-space duality (arXiv:2405.21060).

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072, head_dim 64 -> 48 SSD heads, depthwise conv 4.
No FFN sublayers (the Mamba mixer is the whole block).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    attn_kind="none",
    ssm_state=128,
    ssm_heads=48,          # d_inner 3072 / head_dim 64
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=128,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="mamba2-780m-reduced",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    attn_kind="none",
    ssm_state=16,
    ssm_heads=4,           # d_inner 128 / head_dim 32
    ssm_expand=2,
    ssm_chunk=8,
    tie_embeddings=True,
    dtype="float32",
)
