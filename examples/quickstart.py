"""Quickstart: solve a screened Poisson problem with hipBone-in-JAX.

Runs the single-device benchmark in both storage modes and prints the FOM,
reproducing the paper's core comparison in ~a minute on CPU:

    PYTHONPATH=src python examples/quickstart.py [--n 7] [--elems 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_problem,
    cg_assembled,
    cg_scattered,
    fom,
    poisson_assembled,
    poisson_scattered,
)
from repro.core.gather_scatter import gather, scatter
from repro.kernels import ops


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=7, help="polynomial degree")
    ap.add_argument("--elems", type=int, default=6, help="elements per axis")
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--pallas", action="store_true", help="use the Pallas kernel (interpret mode on CPU)")
    args = ap.parse_args()

    prob = build_problem(args.n, (args.elems,) * 3, lam=1.0, dtype=jnp.float32)
    e = prob.mesh.n_elements
    print(f"mesh: {args.elems}^3 elements, N={args.n}  "
          f"N_G={prob.n_global:,} DOFs, N_L={prob.n_local:,} local nodes")

    local_op = ops.make_local_op(interpret=True) if args.pallas else None
    a = poisson_assembled(prob, local_op=local_op)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)

    solve = jax.jit(lambda b: cg_assembled(a, b, n_iter=args.iters))
    res = solve(b)
    jax.block_until_ready(res.x)
    t0 = time.perf_counter()
    res = solve(b)
    jax.block_until_ready(res.x)
    dt = time.perf_counter() - t0
    gflops = fom.fom_gflops(e, args.n, args.iters, dt)
    print(f"hipBone (assembled): {dt:.3f}s for {args.iters} CG iters "
          f"-> FOM {gflops:.2f} GFLOPS, final r.r = {float(res.rdotr):.3e}")

    a_s = poisson_scattered(prob)
    b_l = scatter(b, prob.l2g)
    solve_s = jax.jit(
        lambda bl: cg_scattered(a_s, bl, prob.w_local, n_iter=args.iters)
    )
    res_s = solve_s(b_l)
    jax.block_until_ready(res_s.x)
    t0 = time.perf_counter()
    res_s = solve_s(b_l)
    jax.block_until_ready(res_s.x)
    dt_s = time.perf_counter() - t0
    print(f"NekBone (scattered, baseline): {dt_s:.3f}s "
          f"-> FOM {fom.fom_gflops(e, args.n, args.iters, dt_s):.2f} GFLOPS")
    print(f"assembled-storage speedup: {dt_s/dt:.2f}x "
          f"(modeled byte ratio {fom.nekbone_iter_bytes(e, args.n, word=4)/fom.cg_iter_bytes(e, args.n, word=4):.2f}x)")

    # solutions agree
    xg = gather(prob.w_local * res_s.x, prob.l2g, prob.n_global)
    err = float(jnp.max(jnp.abs(xg - res.x)))
    print(f"storage-mode solution agreement: max|dx| = {err:.2e}")


if __name__ == "__main__":
    main()
