"""Single-pass fused assembled operator vs the split pipeline.

The acceptance bar for kernels/poisson_fused.py: the fused kernel matches
``poisson_assembled`` to fp64 round-off (<= 1e-12 rel) across degrees and
deformed coordinates, PCG iteration counts are identical with the fused
operator swapped in, and the auto-enable policy (``should_fuse_operator``
+ the HIPBONE_FUSED override) picks the right path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess

jax.config.update("jax_enable_x64", True)

from repro.core import build_problem, cg_assembled, poisson_assembled  # noqa: E402
from repro.core.precond import make_preconditioner  # noqa: E402
from repro.kernels import ops  # noqa: E402

SHAPES = {3: (3, 2, 2), 7: (2, 2, 2), 9: (2, 2, 2), 15: (2, 2, 2)}


def _rand_x(prob, rng, dtype):
    return jnp.asarray(rng.standard_normal(prob.n_global), dtype)


@pytest.mark.parametrize("n", [3, 7, 9, 15])
def test_fused_matches_split_fp64(n, rng):
    prob = build_problem(n, SHAPES[n], lam=1.3, deform=0.15, dtype=jnp.float64)
    x = _rand_x(prob, rng, jnp.float64)
    want = poisson_assembled(prob, fused=False)(x)
    got = ops.poisson_assembled_fused(
        x, prob.l2g, prob.g, prob.w_local, prob.d, lam=prob.lam, interpret=True
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12


@pytest.mark.parametrize("coefficient", ["smooth", "checker"])
@pytest.mark.parametrize("deform", [0.0, 0.15])
def test_fused_matches_split_variable_coefficient_fp64(coefficient, deform, rng):
    """The fused kernel sees k(x)/λ(x)/bc only through its g/w streams and
    the mask wrap — parity with the split pipeline must stay at fp64
    round-off, deformed coordinates included."""
    prob = build_problem(
        3, (2, 2, 2), lam=0.7, deform=deform, dtype=jnp.float64,
        coefficient=coefficient, bc="mixed",
    )
    x = _rand_x(prob, rng, jnp.float64)
    want = poisson_assembled(prob, fused=False)(x)
    got = poisson_assembled(
        prob, fused=True, fused_kwargs={"interpret": True}
    )(x)
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12


def test_fused_matches_split_fp32(rng):
    prob = build_problem(5, (2, 2, 2), lam=0.9, deform=0.12, dtype=jnp.float32)
    x = _rand_x(prob, rng, jnp.float32)
    want = poisson_assembled(prob, fused=False)(x)
    got = ops.poisson_assembled_fused(
        x, prob.l2g, prob.g, prob.w_local, prob.d, lam=prob.lam, interpret=True
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel < 3e-6


@pytest.mark.parametrize("block_e", [1, 2, 4, 8])
def test_fused_block_sweep_and_padding(block_e, rng):
    # E=12 is not a multiple of 8; N_G is far from a 128-lane multiple
    prob = build_problem(3, (3, 2, 2), lam=0.7, deform=0.1, dtype=jnp.float64)
    x = _rand_x(prob, rng, jnp.float64)
    want = poisson_assembled(prob, fused=False)(x)
    got = ops.poisson_assembled_fused(
        x,
        prob.l2g,
        prob.g,
        prob.w_local,
        prob.d,
        lam=prob.lam,
        block_e=block_e,
        interpret=True,
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12


def test_fused_gather_mode_loop(rng):
    """The PrefetchScalarGridSpec dynamic-slice fallback matches too."""
    prob = build_problem(3, (2, 2, 1), lam=1.0, deform=0.1, dtype=jnp.float64)
    x = _rand_x(prob, rng, jnp.float64)
    want = poisson_assembled(prob, fused=False)(x)
    got = ops.poisson_assembled_fused(
        x,
        prob.l2g,
        prob.g,
        prob.w_local,
        prob.d,
        lam=prob.lam,
        interpret=True,
        gather_mode="loop",
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12


@pytest.mark.parametrize("n", [3, 7])
def test_pcg_iterations_identical_with_fused_operator(n, rng):
    prob = build_problem(n, SHAPES[n], lam=0.5, deform=0.15, dtype=jnp.float64)
    b = _rand_x(prob, rng, jnp.float64)
    a_split = poisson_assembled(prob, fused=False)
    a_fused = poisson_assembled(
        prob, fused=True, fused_kwargs={"interpret": True}
    )
    pc, _ = make_preconditioner("jacobi", prob, a_split)
    res_s = cg_assembled(a_split, b, n_iter=300, tol=1e-8, precond=pc)
    res_f = cg_assembled(a_fused, b, n_iter=300, tol=1e-8, precond=pc)
    assert int(res_s.iterations) == int(res_f.iterations)
    rel = float(
        jnp.max(jnp.abs(res_f.x - res_s.x)) / jnp.max(jnp.abs(res_s.x))
    )
    assert rel < 1e-10


def test_should_fuse_operator_policy(monkeypatch):
    monkeypatch.delenv("HIPBONE_FUSED", raising=False)
    # CPU backend -> interpret mode -> auto policy stays off
    assert ops.default_interpret()
    assert not ops.should_fuse_operator(jnp.float64, n_degree=7, n_global=1000)
    monkeypatch.setenv("HIPBONE_FUSED", "1")
    assert ops.should_fuse_operator(jnp.float64, n_degree=7, n_global=1000)
    assert ops.should_fuse_streams(jnp.float64)
    monkeypatch.setenv("HIPBONE_FUSED", "0")
    assert not ops.should_fuse_operator(jnp.float32, n_degree=7, n_global=1000)
    assert not ops.should_fuse_streams(jnp.float32)


def test_poisson_assembled_switch(monkeypatch, rng):
    prob = build_problem(3, (2, 2, 2), lam=1.0, dtype=jnp.float64)
    monkeypatch.delenv("HIPBONE_FUSED", raising=False)
    assert poisson_assembled(prob).fused is False
    monkeypatch.setenv("HIPBONE_FUSED", "1")
    ap = poisson_assembled(prob)
    assert ap.fused is True
    x = _rand_x(prob, rng, jnp.float64)
    want = poisson_assembled(prob, fused=False)(x)
    rel = float(jnp.max(jnp.abs(ap(x) - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12
    # an explicit local_op pins the split pipeline even under the override
    calls = []

    def counting_op(u, g, d, lam, w, jw=None):
        calls.append(1)
        from repro.core.operator import local_poisson

        return local_poisson(u, g, d, lam, w, jw)

    a_custom = poisson_assembled(prob, local_op=counting_op)
    assert a_custom.fused is False
    a_custom(x)
    assert calls
    with pytest.raises(ValueError):
        poisson_assembled(prob, local_op=counting_op, fused=True)


def test_fused_vmem_budget_helpers():
    from repro.kernels.poisson_fused import (
        fused_fits_vmem,
        fused_vmem_bytes,
        pick_fused_block_e,
    )

    assert fused_fits_vmem(7, 100_000, jnp.float32)
    assert not fused_fits_vmem(7, 10**9, jnp.float32)
    eb = pick_fused_block_e(7, 100_000, jnp.float32)
    n_pad = -(-100_000 // 128) * 128
    assert fused_vmem_bytes(eb, 8, n_pad, jnp.float32) <= 8 * 2**20
    assert eb >= 1


@pytest.mark.slow
def test_dist_cg_fused_operator_parity():
    """fused_operator=True matches the split distributed solve exactly."""
    code = """
import jax
import jax.numpy as jnp
import numpy as np
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid, factor3
from repro.core.distributed import build_dist_problem, dist_cg

ranks = 8
grid = ProcessGrid(factor3(ranks))
mesh = make_mesh((ranks,), ("ranks",))
prob = build_dist_problem(3, grid, (3, 3, 3), lam=1.0, dtype=jnp.float32)
assert prob.e_local > prob.halo_elems, "need a non-empty interior block"
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((ranks, prob.m3)), jnp.float32)
runs = {}
for fused in (False, True):
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=40, tol=1e-6,
                          precond="jacobi", fused_operator=fused))
    x, rr, iters, status, hist = run()
    runs[fused] = (np.asarray(x), int(iters))
assert runs[True][1] == runs[False][1], runs
np.testing.assert_allclose(runs[True][0], runs[False][0], rtol=1e-6)
print("OK")
"""
    assert "OK" in run_subprocess(code, devices=8)
