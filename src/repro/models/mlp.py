"""Gated MLPs: SwiGLU (llama/yi/mixtral/...) and GeGLU (gemma)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import act_fn
from .config import ModelConfig
from .params import ParamBuilder

__all__ = ["init_mlp", "mlp_apply"]


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    pb.param("w_gate", (d, ff), ("embed", "mlp"), scale=d**-0.5)
    pb.param("w_up", (d, ff), ("embed", "mlp"), scale=d**-0.5)
    pb.param("w_down", (ff, d), ("mlp", "embed"), scale=ff**-0.5)
    return pb.collect()


def mlp_apply(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    act = act_fn(cfg.act)
    h = act(jnp.einsum("...d,df->...f", x, p["w_gate"])) * jnp.einsum(
        "...d,df->...f", x, p["w_up"]
    )
    return jnp.einsum("...f,fd->...d", h, p["w_down"])
