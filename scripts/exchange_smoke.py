#!/usr/bin/env python
"""CI exchange-policy smoke: every routing, identical iterations/status.

Runs one sharded pMG solve (8 virtual ranks) under each
``HIPBONE_EXCHANGE`` policy — ``face_sweep``, ``crystal``, ``fused`` and
``auto`` (timed plan, persistence disabled) — through the *env-var*
path a production launch would use (``dist_cg(exchange=None)`` defers to
the env), and fails unless every policy reports the same iteration count
and solve status.  This is the plan subsystem's core contract: routing
is a pure performance knob, never a numerics knob.
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ["HIPBONE_EXCHANGE_CACHE"] = ""  # smoke runs never write plans

import jax  # noqa: E402

jax.config.update("jax_enable_x64", True)

import numpy as np  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.compat import make_mesh  # noqa: E402
from repro.comms.topology import ProcessGrid  # noqa: E402
from repro.core.cg import status_name  # noqa: E402
from repro.core.distributed import build_dist_problem, dist_cg  # noqa: E402


def main() -> int:
    grid = ProcessGrid((2, 2, 2))
    mesh = make_mesh((8,), ("ranks",))
    prob = build_dist_problem(3, grid, (2, 1, 1), lam=0.8, dtype=jnp.float64)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((grid.size, prob.m3)))

    results: dict[str, tuple[int, str]] = {}
    for policy in ("face_sweep", "crystal", "fused", "auto"):
        os.environ["HIPBONE_EXCHANGE"] = policy  # the production knob path
        run = dist_cg(prob, mesh, b, n_iter=60, tol=1e-9, precond="pmg")
        _, _, iters, status, _ = jax.jit(run)()
        results[policy] = (int(iters), status_name(int(status)))
        plan = run.exchange_plan
        print(
            f"{policy:>10}: iters={int(iters)} status={results[policy][1]} "
            f"(plan: policy={plan.policy}, {len(plan.sites)} timed sites)"
        )
    ref = results["face_sweep"]
    bad = {p: r for p, r in results.items() if r != ref}
    if bad:
        print(f"FAIL: policies disagree with face_sweep {ref}: {bad}")
        return 1
    if ref[1] != "converged":
        print(f"FAIL: smoke solve did not converge: {ref}")
        return 1
    print(f"OK: all policies identical at {ref[0]} iterations, {ref[1]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
