"""Production device meshes.

``make_production_mesh`` builds the assignment's single-pod 16x16
("data", "model") or two-pod 2x16x16 ("pod", "data", "model") mesh. It is
a FUNCTION so importing this module never touches jax device state — the
caller (dryrun.py) is responsible for forcing the 512-device host platform
before any jax initialization.

The hipBone Poisson cells run over the same devices viewed as a single
flattened ("ranks",) axis: a 3-D process grid (comms.topology.factor3) is
laid over the flattened device list, so the pod boundary falls on the
outermost grid dimension (nearest-neighbor faces cross the pod link only
on one plane — the layout a real deployment would choose).
"""
from __future__ import annotations

import jax

from ..compat import make_mesh

__all__ = ["make_production_mesh", "flat_mesh", "axis_sizes"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def flat_mesh(mesh: jax.sharding.Mesh, name: str = "ranks") -> jax.sharding.Mesh:
    """View the same devices as one flattened axis (Poisson process grid).

    Constructs the Mesh directly: jax.make_mesh would topology-reorder the
    devices, breaking the rank->device correspondence with the production
    mesh's (pod, data, model) flattening.
    """
    devices = mesh.devices.reshape(-1)
    return jax.sharding.Mesh(devices, (name,))


def axis_sizes(mesh: jax.sharding.Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
