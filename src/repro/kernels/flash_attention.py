"""Pallas TPU flash-attention (forward) — the LM-side memory hot spot.

§Roofline showed the pure-XLA chunked attention dominates every prefill/
train cell's memory term: the (Sq x C) score tensors are real HBM buffers
on any backend without a fused kernel (e.g. chameleon-34b prefill_32k:
~8.6 GB of score traffic per layer per chunk pass). This kernel keeps the
whole online-softmax tile pipeline in VMEM — HBM traffic collapses to one
pass over q, k, v, o, exactly like the hipBone Poisson kernel collapses
the operator to one pass over its seven streams (paper C2, transplanted).

Layout: q (B, H, Sq, D), k/v (B, KV, Sk, D), grid (B, H, Sq/bq, Sk/bk)
with the kv-block axis innermost-sequential; VMEM scratch carries the
(acc, m, l) online-softmax state across kv blocks; GQA maps head h to kv
head h // (H/KV) inside the BlockSpec index maps. Causal/window masking
via iota against absolute positions. Backward runs the rematerializing
jnp path through jax.custom_vjp (Pallas backward kernel: future work,
noted in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_fwd_pallas", "flash_vmem_bytes"]

NEG_INF = -1e30


def _kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale: float, causal: bool, window: int | None,
    bq: int, bk: int, n_kv_blocks: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                   # (bq, bk)

    q_pos = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                 # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (bq, bk)
    corr = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_new = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    v = v_ref[0, 0].astype(jnp.float32)                 # (bk, D)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_ref[...] / jnp.maximum(l_ref[...], 1e-37)
        ).astype(o_ref.dtype)


def flash_vmem_bytes(bq: int, bk: int, d: int, dv: int | None = None) -> int:
    """VMEM working set per grid step (the Table-1 occupancy metric)."""
    dv = dv or d
    tiles = (bq * d + bk * d + bk * dv + bq * dv) * 4   # q, k, v, o
    scratch = (bq * dv + 2 * bq) * 4                    # acc, m, l
    score = 2 * bq * bk * 4                             # s, p
    return tiles + scratch + score


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "window", "bq", "bk", "interpret"),
)
def flash_attention_fwd_pallas(
    q: jax.Array,            # (B, H, Sq, Dq)
    k: jax.Array,            # (B, KV, Sk, Dq)
    v: jax.Array,            # (B, KV, Sk, Dv) — Dv may differ (absorbed MLA)
    *,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    dv = v.shape[-1]
    g = h // kvh
    bq = min(bq, sq)
    bk = min(bk, sk)
    if sq % bq or sk % bk:
        raise ValueError(f"seq ({sq},{sk}) not divisible by blocks ({bq},{bk})")
    n_kv = sk // bk
    grid = (b, h, sq // bq, n_kv)

    return pl.pallas_call(
        functools.partial(
            _kernel, scale=scale, causal=causal, window=window,
            bq=bq, bk=bk, n_kv_blocks=n_kv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec(
                (1, 1, bk, d), lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, dv), lambda b_, h_, qi, ki, g=g: (b_, h_ // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, dv), lambda b_, h_, qi, ki: (b_, h_, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, dv), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
