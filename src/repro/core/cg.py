"""Preconditioned Conjugate Gradient in hipBone-assembled and NekBone-scattered form.

One PCG implementation serves every solver path; plain CG is PCG with the
identity preconditioner, and in that case the preconditioner stage folds
away so the compiled program is exactly the seed's CG (same reductions,
same fusion schedule):

  * one fused pass computes ``r_{j+1} = r_j - α A p`` AND accumulates
    ``r_{j+1}·r_{j+1}`` (paper: "Fusing this reduction with the update of r
    avoids the need for a separate kernel to read the vector r again");
  * with a preconditioner, a second fused pass computes ``z = M⁻¹ r`` AND
    accumulates ``r·z`` (the same streaming trick applied to the PCG
    inner product — kernels/streams.py has the Pallas version);
  * the AXPY ``x += α p`` carries no data dependence on the reductions, so
    XLA may overlap the cross-device psums with it — the paper's
    allreduce-hiding trick, expressed as dataflow;
  * inner products on assembled vectors are plain (unweighted) dots.

The scattered baseline replicates NekBone: vectors of length N_L, weighted
inner products reading the extra W vector, and a combined ZZ^T
gather-scatter inside the operator.

Iteration control: a fixed count (NekBone uses 100) runs under ``lax.scan``
so a single compiled program covers the whole benchmark; passing ``tol``
switches to ``lax.while_loop`` stopping at ‖r‖ ≤ tol·‖r₀‖ (capped at
``n_iter``), with ``CGResult.iterations`` reporting the count actually run.

Solver guardrails: every iteration the loop inspects the scalars it already
reduces (p·Ap, r·z, r·r) for breakdown — NaN/Inf residual, indefinite
curvature (p·Ap ≤ 0) or indefinite preconditioner (r·z < 0), divergence
(rdotr > ``divergence_factor`` · rdotr₀) and stagnation (no relative
reduction of the best-seen rdotr by ``stagnation_rtol`` within
``stagnation_window`` iterations).  In tolerance mode a tripped detector
exits the while-loop on that iteration; in fixed-count mode (no early exit
under ``lax.scan``) the first failure is recorded and reported.  Every
detector input is an already-allreduced scalar, so under ``shard_map`` all
replicas see the same flag and exit on the same iteration — no extra
collective is added, and a healthy solve runs the exact same iterations as
before.  The outcome is ``CGResult.status``, a jit-safe ``SolveStatus``
code (see its docstring for the enum contract).

CG variants: the default ``cg_variant="standard"`` uses the Fletcher–Reeves
β = (r·z)_new/(r·z)_old, which assumes M⁻¹ is a *fixed symmetric* linear
map.  ``cg_variant="flexible"`` switches β to the Polak–Ribière form
β = z_new·(r_new − r_old)/(r·z)_old (flexible CG, Notay 2000) — robust to
preconditioners that are only approximately symmetric in the outer dtype's
arithmetic, e.g. an fp32 V-cycle or Schwarz apply inside an fp64 solve
(precond.make_preconditioner(precond_dtype=...)).  The extra cost is one
inner product per iteration, fused into the existing allreduce as a
length-2 payload.
"""
from __future__ import annotations

import enum
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "CGResult",
    "CG_VARIANTS",
    "DIVERGENCE_FACTOR",
    "STAGNATION_RTOL",
    "STAGNATION_WINDOW",
    "SolveStatus",
    "batched_cg_assembled",
    "cg_assembled",
    "cg_scattered",
    "fused_residual_update",
    "status_name",
]

CG_VARIANTS = ("standard", "flexible")

# Detector defaults (override per solve; None disables that detector).
# divergence: rdotr is the *squared* residual norm, so 1e6 means the
# residual grew 1000× over r₀ — far outside healthy CG oscillation (which
# stays within ~√cond(A) of r₀) and small enough to outrace the stagnation
# window on an exponentially blowing-up solve.
# stagnation: a healthy tol-mode solve reduces its best-seen rdotr by ≫1 %
# well within any 50-iteration window; a solve pinned at a noise floor
# (corrupted operator bits, rank-deficient M⁻¹) does not.
DIVERGENCE_FACTOR = 1e6
STAGNATION_WINDOW = 50
STAGNATION_RTOL = 0.99

# in-loop sentinel; never escapes into CGResult.status
_RUNNING = -1


class SolveStatus(enum.IntEnum):
    """Terminal state of a (P)CG solve — `CGResult.status`.

    * ``CONVERGED`` — ‖r‖ ≤ tol·‖r₀‖ (tolerance mode), including the
      rdotr₀ = 0 edge case (zero RHS / exact x₀: 0 iterations).
    * ``MAX_ITER`` — the iteration budget ran out before the tolerance was
      met.  In fixed-count mode (``tol=None``) there is no tolerance to
      certify, so MAX_ITER is the *normal* completion status there (unless
      rdotr₀ = 0, which still reports CONVERGED at 0 iterations).
    * ``BREAKDOWN_NAN`` — a non-finite reduction scalar (NaN/Inf residual
      or p·Ap): bit corruption, overflow, or a NaN in the operator chain.
    * ``BREAKDOWN_INDEFINITE`` — p·Ap ≤ 0 (operator not positive-definite
      on the Krylov space) or r·z < 0 (preconditioner not positive-
      definite, e.g. a sign-flipped M⁻¹).
    * ``STAGNATED`` — best-seen rdotr not reduced by ``stagnation_rtol``
      for ``stagnation_window`` consecutive iterations (tolerance mode
      only).
    * ``DIVERGED`` — rdotr > ``divergence_factor`` · rdotr₀ (tolerance
      mode only).  ``divergence_factor`` applies to rdotr, the *squared*
      residual norm.

    Codes are small non-negative ints carried through jit as int32;
    ``status_name`` maps a code to its lowercase wire name (the form
    benchmark records and logs use).
    """

    CONVERGED = 0
    MAX_ITER = 1
    BREAKDOWN_NAN = 2
    BREAKDOWN_INDEFINITE = 3
    STAGNATED = 4
    DIVERGED = 5


def status_name(code: int | jax.Array) -> str:
    """Lowercase wire name of a `SolveStatus` code (e.g. ``"converged"``)."""
    return SolveStatus(int(code)).name.lower()


class CGResult(NamedTuple):
    x: jax.Array
    rdotr: jax.Array
    iterations: jax.Array
    status: jax.Array
    rdotr_history: jax.Array | None


def fused_residual_update(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One-pass r update + self-dot (reference; Pallas version in kernels/)."""
    r_new = r - alpha * ap
    return r_new, jnp.vdot(r_new, r_new)


def _dot(a: jax.Array, b: jax.Array, w: jax.Array | None) -> jax.Array:
    if w is None:
        return jnp.vdot(a, b)
    return jnp.vdot(a * w, b)


def _safe_div(a, b):
    # fixed-iteration CG (NekBone runs exactly 100) keeps iterating after
    # convergence; guard 0/0 so x simply freezes at the solution
    return jnp.where(b != 0, a / jnp.where(b != 0, b, 1), 0.0)


def _pcg(
    operator: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None,
    *,
    n_iter: int,
    tol: float | None,
    weight: jax.Array | None,
    psum: Callable[[jax.Array], jax.Array] | None,
    precond: Callable[[jax.Array], jax.Array] | None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None,
    fused_precond_dot: Callable[..., tuple[jax.Array, jax.Array]] | None,
    record_history: bool,
    variant: str = "standard",
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
) -> CGResult:
    if variant not in CG_VARIANTS:
        raise ValueError(
            f"unknown cg_variant {variant!r}; choose from {CG_VARIANTS}"
        )
    if isinstance(precond, str):
        raise TypeError(
            f"precond must be a callable z = M⁻¹r (or None), got the string "
            f"{precond!r}; build one with core.precond.make_preconditioner "
            f"(string kinds are only accepted by distributed.dist_cg)"
        )
    if fused_precond_dot is not None and precond is None:
        raise ValueError(
            "fused_precond_dot given without precond; pass the (unfused) "
            "apply as precond too — it gates the PCG recurrence"
        )
    allsum = psum or (lambda v: v)
    upd = fused_update or fused_residual_update
    # without a preconditioner z_new == r_new, so Polak–Ribière reduces to
    # Fletcher–Reeves up to the (exactly-orthogonal) r_new·r_old term — keep
    # the cheaper standard recurrence there
    flexible = variant == "flexible" and precond is not None
    x = jnp.zeros_like(b) if x0 is None else x0

    def apply_precond(r_vec):
        """z = M⁻¹r and the local part of r·z, in one fused pass if given."""
        if precond is None:
            raise AssertionError("apply_precond called without a preconditioner")
        if fused_precond_dot is not None:
            return fused_precond_dot(r_vec)
        z_vec = precond(r_vec)
        return z_vec, _dot(r_vec, z_vec, weight)

    r = b - operator(x)
    rdotr0 = allsum(_dot(r, r, weight))
    if precond is None:
        z, rz = r, rdotr0
    else:
        z, rz_local = apply_precond(r)
        rz = allsum(rz_local)
    p = z

    # Guardrails: status codes as int32 scalars so they live in the loop
    # carry.  Detector inputs (pap, rz, rdotr) are already allreduced, so
    # under shard_map every replica computes the same flag — replicas stay
    # in lockstep with zero added collectives.
    run = jnp.asarray(_RUNNING, jnp.int32)
    converged = jnp.asarray(SolveStatus.CONVERGED, jnp.int32)
    max_iter_ = jnp.asarray(SolveStatus.MAX_ITER, jnp.int32)
    nan_code = jnp.asarray(SolveStatus.BREAKDOWN_NAN, jnp.int32)
    indef_code = jnp.asarray(SolveStatus.BREAKDOWN_INDEFINITE, jnp.int32)

    def detect(pap, rz_new, rdotr_pre, rdotr_new):
        """NaN/indefinite breakdown code for one iteration, else _RUNNING.

        ``rdotr_pre > 0`` guards the indefinite test: a fixed-count solve
        keeps stepping after convergence with p ≈ 0, where p·Ap = 0 is not
        a breakdown.
        """
        bad = ~jnp.isfinite(rdotr_new) | ~jnp.isfinite(pap)
        indef = ((pap <= 0) | (rz_new < 0)) & (rdotr_pre > 0)
        return jnp.where(bad, nan_code, jnp.where(indef, indef_code, run))

    # pre-loop breakdowns: non-finite b/x0/operator, or an indefinite M⁻¹
    # visible in r·M⁻¹r before the first step
    status0 = jnp.where(
        ~jnp.isfinite(rdotr0),
        nan_code,
        jnp.where(rz < 0, indef_code, run),
    )

    def step(x, r, p, rz, rdotr):
        ap = operator(p)
        pap = allsum(_dot(p, ap, weight))
        alpha = _safe_div(rz, pap)
        if weight is None:
            # hipBone fusion: r-update + local reduction in one pass...
            r_new, rr_local = upd(r, ap, alpha)
        else:
            r_new = r - alpha * ap
            rr_local = _dot(r_new, r_new, weight)
        # ...and x-update independent of the psum -> overlappable allreduce.
        x_new = x + alpha * p
        rdotr_new = allsum(rr_local)
        if precond is None:
            z_new, rz_new = r_new, rdotr_new
            beta = _safe_div(rz_new, rz)
        elif flexible:
            # Polak–Ribière β = z_new·(r_new − r_old)/rz_old; the extra
            # z_new·r_old dot rides the same allreduce as r_new·z_new
            z_new, rz_local = apply_precond(r_new)
            pair = allsum(jnp.stack([rz_local, _dot(z_new, r, weight)]))
            rz_new = pair[0]
            beta = _safe_div(rz_new - pair[1], rz)
        else:
            z_new, rz_local = apply_precond(r_new)
            rz_new = allsum(rz_local)
            beta = _safe_div(rz_new, rz)
        p_new = z_new + beta * p
        fail = detect(pap, rz_new, rdotr, rdotr_new)
        return x_new, r_new, p_new, rz_new, rdotr_new, fail

    zero_rhs = rdotr0 == 0

    if tol is None:
        # lax.scan cannot exit early (and the sharded fixed-count path
        # relies on scan for shard_map's check_rep) — record the *first*
        # breakdown and keep stepping; _safe_div keeps the post-breakdown
        # arithmetic inert where it can.
        def body(carry, _):
            x, r, p, rz, rdotr, status = carry
            x, r, p, rz, rdotr, fail = step(x, r, p, rz, rdotr)
            status = jnp.where(status == run, fail, status)
            return (x, r, p, rz, rdotr, status), rdotr

        (x, r, p, rz, rdotr, status), hist = jax.lax.scan(
            body, (x, r, p, rz, rdotr0, status0), None, length=n_iter
        )
        status = jnp.where(
            status == run, jnp.where(zero_rhs, converged, max_iter_), status
        )
        return CGResult(
            x=x,
            rdotr=rdotr,
            iterations=jnp.where(zero_rhs, 0, n_iter),
            status=status,
            rdotr_history=hist if record_history else None,
        )

    # tolerance mode: ‖r‖ ≤ tol·‖r₀‖, capped at n_iter; the history buffer
    # (and its per-iteration scatter) only enters the carry when asked for
    target = jnp.asarray(tol, rdotr0.dtype) ** 2 * rdotr0
    hist0 = (jnp.zeros((n_iter,), rdotr0.dtype),) if record_history else ()
    diverged_code = jnp.asarray(SolveStatus.DIVERGED, jnp.int32)
    stagnated_code = jnp.asarray(SolveStatus.STAGNATED, jnp.int32)

    def cond(carry):
        rdotr, k, status = carry[4], carry[5], carry[6]
        return (k < n_iter) & (rdotr > target) & (status == run)

    def wbody(carry):
        x, r, p, rz, rdotr, k, status, best, since = carry[:9]
        x, r, p, rz, rdotr_new, fail = step(x, r, p, rz, rdotr)
        if divergence_factor is not None:
            div = rdotr_new > jnp.asarray(
                divergence_factor, rdotr0.dtype
            ) * rdotr0
            fail = jnp.where((fail == run) & div, diverged_code, fail)
        if stagnation_window is not None:
            improved = rdotr_new < jnp.asarray(
                stagnation_rtol, rdotr0.dtype
            ) * best
            since = jnp.where(improved, 0, since + 1)
            best = jnp.minimum(best, rdotr_new)
            fail = jnp.where(
                (fail == run) & (since >= stagnation_window),
                stagnated_code,
                fail,
            )
        # cond guarantees status == run on entry, so fail IS the new status
        hist = (carry[9].at[k].set(rdotr_new),) if record_history else ()
        return (x, r, p, rz, rdotr_new, k + 1, fail, best, since) + hist

    out = jax.lax.while_loop(
        cond,
        wbody,
        (x, r, p, rz, rdotr0, jnp.asarray(0), status0, rdotr0,
         jnp.asarray(0)) + hist0,
    )
    rdotr, k, status = out[4], out[5], out[6]
    status = jnp.where(
        status == run,
        jnp.where(rdotr <= target, converged, max_iter_),
        status,
    )
    return CGResult(
        x=out[0],
        rdotr=rdotr,
        iterations=k,
        status=status,
        rdotr_history=out[9] if record_history else None,
    )


def cg_assembled(
    operator: Callable[[jax.Array], jax.Array],
    b_g: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    fused_precond_dot: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    record_history: bool = False,
    cg_variant: str = "standard",
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
) -> CGResult:
    """hipBone (P)CG on assembled (length N_G) vectors; unweighted dots.

    ``precond``: optional z = M⁻¹r application (see core.precond); None
    gives the seed's plain CG.  ``fused_precond_dot``: optional one-pass
    (M⁻¹r, r·M⁻¹r) — the Pallas streaming fusion of the PCG inner product.
    ``tol``: stop at ‖r‖ ≤ tol·‖r₀‖ instead of running n_iter iterations.
    ``cg_variant``: "standard" (Fletcher–Reeves β, exact-symmetric M⁻¹) or
    "flexible" (Polak–Ribière β, robust to inexactly-symmetric appliers
    such as mixed-precision preconditioners — see module docstring).

    Guardrail knobs (see `SolveStatus` and the module docstring):
    ``divergence_factor`` trips DIVERGED at rdotr > factor·rdotr₀ and
    ``stagnation_window``/``stagnation_rtol`` trip STAGNATED after a
    window without relative progress — both tolerance-mode only; pass
    None to disable either detector.  NaN and indefinite breakdown
    detection is always on.  The outcome lands in ``CGResult.status``.
    """
    return _pcg(
        operator,
        b_g,
        x0,
        n_iter=n_iter,
        tol=tol,
        weight=None,
        psum=psum,
        precond=precond,
        fused_update=fused_update,
        fused_precond_dot=fused_precond_dot,
        record_history=record_history,
        variant=cg_variant,
        divergence_factor=divergence_factor,
        stagnation_window=stagnation_window,
        stagnation_rtol=stagnation_rtol,
    )


def batched_cg_assembled(
    operator: Callable[[jax.Array], jax.Array],
    b_block: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    fused_precond_dot: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    record_history: bool = False,
    cg_variant: str = "standard",
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
) -> CGResult:
    """Multi-RHS (P)CG: solve ``A x_i = b_i`` for every row of ``b_block``.

    The batched front end of the solver service (ROADMAP "millions of
    users" direction): ``b_block`` is a ``(B, n_global)`` block of
    right-hand sides sharing ONE operator and ONE preconditioner setup —
    every setup cost (assembled diagonals, Lanczos intervals, Schwarz FDM
    eigendecompositions, Galerkin blocks) is paid once and amortized over
    the batch, and the B solves run as a single compiled program whose
    vector stages stream ``(B, n)`` blocks instead of B separate ``(n,)``
    passes.

    Implementation: :func:`cg_assembled` vmapped over the leading batch
    dimension.  ``jax.vmap`` of ``lax.while_loop`` runs the loop while ANY
    column is still active and freezes finished columns with masked
    (``select``) carry updates, so every column independently stops at
    ``tol`` — per-column ``iterations`` and ``status`` are *bit-identical*
    to B standalone :func:`cg_assembled` calls (the zero-RHS column
    short-circuit included: a zero row reports CONVERGED at 0 iterations).
    Already-converged columns ride along masked (their carries are frozen,
    not recomputed), so a batch mixing easy and hard RHS costs the max
    column's iterations, not the sum.

    Args:
      operator: single-column A-apply ``(n,) -> (n,)`` (batching is
        applied here — pass the same apply a standalone solve would use).
      b_block: ``(B, n_global)`` RHS block.
      x0: optional ``(B, n_global)`` initial guesses.
      precond / fused_update / fused_precond_dot: single-column callables,
        exactly as :func:`cg_assembled` takes them; they are vmapped along
        with the loop.
      Everything else: as :func:`cg_assembled` (shared by all columns;
        per-column tolerances are a grouping concern — the serving engine
        batches only requests that share them).

    Returns:
      ``CGResult`` with batched leaves: ``x`` ``(B, n)``, ``rdotr`` /
      ``iterations`` / ``status`` ``(B,)``, and ``rdotr_history``
      ``(B, n_iter)`` when ``record_history`` (frozen columns repeat their
      final value in unreached slots).
    """
    if b_block.ndim != 2:
        raise ValueError(
            f"b_block must be (B, n_global), got shape {b_block.shape}; "
            "for a single RHS use cg_assembled (or pass b[None, :])"
        )
    if x0 is not None and x0.shape != b_block.shape:
        raise ValueError(
            f"x0 shape {x0.shape} must match b_block shape {b_block.shape}"
        )

    def solve_one(b_i, x0_i):
        return cg_assembled(
            operator,
            b_i,
            x0_i,
            n_iter=n_iter,
            tol=tol,
            precond=precond,
            fused_update=fused_update,
            fused_precond_dot=fused_precond_dot,
            record_history=record_history,
            cg_variant=cg_variant,
            divergence_factor=divergence_factor,
            stagnation_window=stagnation_window,
            stagnation_rtol=stagnation_rtol,
        )

    if x0 is None:
        return jax.vmap(lambda b_i: solve_one(b_i, None))(b_block)
    return jax.vmap(solve_one)(b_block, x0)


def cg_scattered(
    operator: Callable[[jax.Array], jax.Array],
    b_l: jax.Array,
    w_local: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    record_history: bool = False,
    cg_variant: str = "standard",
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
) -> CGResult:
    """NekBone baseline (P)CG on scattered (length N_L) vectors; weighted dots."""
    return _pcg(
        operator,
        b_l,
        x0,
        n_iter=n_iter,
        tol=tol,
        weight=w_local,
        psum=psum,
        precond=precond,
        fused_update=None,
        fused_precond_dot=None,
        record_history=record_history,
        variant=cg_variant,
        divergence_factor=divergence_factor,
        stagnation_window=stagnation_window,
        stagnation_rtol=stagnation_rtol,
    )
