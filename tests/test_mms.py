"""Manufactured-solutions convergence oracle (``repro.testing.mms``).

The correctness proof for the generalized operator
A = -∇·(k(x)∇) + λ(x): solve against a closed-form u* whose forcing is
derived analytically, and assert the discrete-L2 error converges
*spectrally* in the degree N — monotone decay and ≥ 4 orders of
magnitude from N=3 to N=9 on a fixed 2³ element box.  Any consistency
bug in the coefficient folding, the weak screen, the bc masking, the
fused kernel or the sharded assembly flattens the curve; no reference
implementation needed.

Covers every path the solve can take: the split single-device operator,
the fused single-kernel Pallas operator (interpret mode), a
mixed-precision (fp32 chain inside fp64 PCG) solve, and the sharded
``dist_cg`` stack on 8 fake devices (slow-marked subprocess).
"""
import jax
import jax.numpy as jnp
import pytest

from conftest import run_subprocess
from repro.core import cg_assembled
from repro.core.precond import make_preconditioner
from repro.testing.mms import MMS_CASES, convergence_sweep

DEGREES = (3, 5, 7, 9)
MIN_ORDERS = 4.0


@pytest.fixture(autouse=True)
def _x64():
    jax.config.update("jax_enable_x64", True)
    yield


def assert_spectral(errs, degrees=DEGREES, orders=MIN_ORDERS):
    """Monotone decay (10 % slack per step) and ≥ `orders` decades total."""
    for (na, ea), (nb, eb) in zip(
        zip(degrees, errs), zip(degrees[1:], errs[1:])
    ):
        assert eb < ea * 1.1, (
            f"error rose from N={na} ({ea:.3e}) to N={nb} ({eb:.3e}): {errs}"
        )
    span = errs[0] / errs[-1]
    assert span >= 10.0**orders, (
        f"error dropped only {span:.1e}× from N={degrees[0]} to "
        f"N={degrees[-1]} (need >= 1e{orders:g}): {errs}"
    )


@pytest.mark.parametrize("name", sorted(MMS_CASES))
def test_convergence_single_device(name):
    """Every (coefficient family, bc) pairing converges spectrally."""
    errs = convergence_sweep(MMS_CASES[name], DEGREES)
    assert_spectral(errs)


def test_convergence_fused_operator():
    """The single-kernel fused apply passes the same oracle (interpret mode)."""
    errs = convergence_sweep(
        MMS_CASES["smooth-mixed"],
        DEGREES,
        fused=True,
        fused_kwargs={"interpret": True},
    )
    assert_spectral(errs)


def test_convergence_mixed_precision_chain():
    """fp64 flexible PCG with an fp32 Chebyshev chain keeps the order.

    The narrowed preconditioner only redirects the search directions —
    the fp64 outer recurrence still drives the residual to the oracle's
    tolerance, so the convergence curve must be unchanged in shape.
    """

    def solve(prob, operator, b):
        pc, _ = make_preconditioner(
            "chebyshev", prob, operator, degree=2,
            precond_dtype=jnp.float32,
        )
        res = cg_assembled(
            operator, b, n_iter=2000, tol=1e-11, precond=pc,
            cg_variant="flexible", stagnation_window=None,
        )
        assert int(res.status) == 0, int(res.status)
        return res.x

    errs = convergence_sweep(MMS_CASES["smooth-mixed"], DEGREES, solve=solve)
    assert_spectral(errs)


_SHARDED_TEMPLATE = """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid
from repro.core.distributed import build_dist_problem, dist_cg, _ordered_elements
from repro.core.mesh import partition_elements
from repro.testing.mms import (
    MMS_CASES, discrete_l2_error, exact_solution_global, mms_problem, mms_rhs,
)

case = MMS_CASES["{name}"]
grid = ProcessGrid((2, 2, 2)); local = (1, 1, 1); shape = (2, 2, 2)
mesh = make_mesh((8,), ("ranks",))
degrees = {degrees}


def partition_field(field):
    # (E, p) element field -> (R, E_loc, p) in the halo-first local order
    ordered, _ = _ordered_elements(local)
    out = np.zeros((grid.size,) + (len(ordered),) + field.shape[1:])
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ex = ordered[:, 0] + ci * local[0]
        ey = ordered[:, 1] + cj * local[1]
        ez = ordered[:, 2] + ck * local[2]
        out[r] = field[ex + shape[0] * (ey + shape[1] * ez)]
    return out


def boxes_from_global(prob, n, vec):
    gx, gy = shape[0] * n + 1, shape[1] * n + 1
    mx, my, mz = prob.box_shape
    out = np.zeros((grid.size, prob.m3))
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci * local[0] * n, cj * local[1] * n, ck * local[2] * n
        x, y, z = np.meshgrid(
            np.arange(mx), np.arange(my), np.arange(mz), indexing="ij"
        )
        gidx = (ox + x) + gx * ((oy + y) + gy * (oz + z))
        out[r] = vec[gidx.transpose(2, 1, 0).reshape(-1)]
    return out


errs = []
for n in degrees:
    ref = mms_problem(case, n, shape)
    b = np.asarray(mms_rhs(ref, case), np.float64)
    k_part = (
        None if ref.k is None
        else partition_field(np.asarray(ref.k, np.float64))
    )
    lam_part = partition_field(np.asarray(ref.lam_field, np.float64))
    prob = build_dist_problem(
        n, grid, local, lam=float(ref.lam), dtype=jnp.float64,
        k=k_part, lam_field=lam_part, bc=case.bc,
    )
    b_boxes = jnp.asarray(boxes_from_global(prob, n, b))
    run = jax.jit(dist_cg(
        prob, mesh, b_boxes, n_iter=2000, tol=1e-11, precond="jacobi",
        stagnation_window=None,
    ))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(status) == 0, (n, int(status))
    # assemble the sharded solution back to the global DOF vector
    x = np.zeros(ref.n_global)
    gx, gy = shape[0] * n + 1, shape[1] * n + 1
    mx, my, mz = prob.box_shape
    xb = np.asarray(x_boxes)
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci * local[0] * n, cj * local[1] * n, ck * local[2] * n
        xg, yg, zg = np.meshgrid(
            np.arange(mx), np.arange(my), np.arange(mz), indexing="ij"
        )
        gidx = (ox + xg) + gx * ((oy + yg) + gy * (oz + zg))
        x[gidx.transpose(2, 1, 0).reshape(-1)] = xb[r]
    errs.append(discrete_l2_error(ref, x, exact_solution_global(ref, case)))
print("ERRS", " ".join("%.6e" % e for e in errs))
"""


@pytest.mark.slow
@pytest.mark.parametrize("name", ["const-dirichlet", "smooth-mixed"])
def test_convergence_sharded(name):
    """The full dist_cg stack (8 fake devices) passes the same oracle —
    coefficient partitioning, halo exchange, bc masks and the sharded
    Jacobi chain included."""
    out = run_subprocess(
        _SHARDED_TEMPLATE.format(name=name, degrees=DEGREES), timeout=1200
    )
    errs = [float(t) for t in out.split("ERRS")[1].split()]
    assert_spectral(errs)
