"""command-r-35b [dense] — Cohere c4ai-command-r-v01.

40L d_model=8192 64H (GQA kv=8) d_ff=22528 vocab=256000. No-bias,
parallel attention+FFN residual, LayerNorm, rope theta 8M, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    parallel_residual=True,
    norm_type="layernorm",
    rope_theta=8_000_000.0,
    tie_embeddings=True,
    norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="command-r-35b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    parallel_residual=True,
    norm_type="layernorm",
    tie_embeddings=True,
    dtype="float32",
)
