"""Named coefficient families for A = -∇·(k(x)∇) + λ(x).

The operator generalization keeps the kernel contract untouched: the
diffusion coefficient ``k`` is folded *multiplicatively* into the packed
geometric factors at setup (G carries J·W·(∂r/∂x)(∂r/∂x)ᵀ — scaling all
six entries by k(x_q) per quadrature point makes DᵀGD discretize
-∇·(k∇·) exactly), and the screen field λ(x) rides the existing ``w``
stream as the mass-weighted JW·λ with the kernels' static ``lam`` pinned
to 1.0 (``core.operator.screen_stream``).  ``local_poisson`` stays three
MXU contractions; no Pallas kernel signature changes.

Families (``configs.hipbone.PoissonConfig.coefficient``):

  * ``"const"`` — the legacy constant-λ screened Poisson (k ≡ 1,
    algebraic λI screen); bit-identical to pre-coefficient builds.
  * ``"smooth"`` — k = 1 + ½·cos(πx)cos(πy)cos(πz) ∈ [½, 3/2], λ(x) = λ
    as a field (weak mass-weighted screen).  Analytic gradient exported
    for the manufactured-solutions oracle (``repro.testing.mms``).
  * ``"checker"`` — per-element octant checkerboard jumping between 1 and
    ``CHECKER_RHO`` across the x/y/z = ½ planes, evaluated at element
    centroids so each element carries one constant (quadrature stays
    exact; element interfaces own the jump).  Needs even element counts
    for the jump planes to land on element boundaries.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "CHECKER_RHO",
    "COEFFICIENTS",
    "checker_k",
    "checker_k_elements",
    "coefficient_fields",
    "smooth_k",
    "smooth_k_grad",
]

COEFFICIENTS = ("const", "smooth", "checker")
CHECKER_RHO = 3.0


def smooth_k(x, y, z):
    """Smooth positive diffusion field 1 + ½·cos(πx)cos(πy)cos(πz)."""
    pi = np.pi
    return 1.0 + 0.5 * np.cos(pi * x) * np.cos(pi * y) * np.cos(pi * z)


def smooth_k_grad(x, y, z):
    """(∂x k, ∂y k, ∂z k) of :func:`smooth_k` — closed form for the MMS."""
    pi = np.pi
    cx, cy, cz = np.cos(pi * x), np.cos(pi * y), np.cos(pi * z)
    sx, sy, sz = np.sin(pi * x), np.sin(pi * y), np.sin(pi * z)
    return (
        -0.5 * pi * sx * cy * cz,
        -0.5 * pi * cx * sy * cz,
        -0.5 * pi * cx * cy * sz,
    )


def checker_k(x, y, z, *, rho: float = CHECKER_RHO):
    """Octant checkerboard: ``rho`` on odd-parity octants of the ½-planes."""
    parity = (
        np.floor(2.0 * np.asarray(x)).astype(np.int64)
        + np.floor(2.0 * np.asarray(y)).astype(np.int64)
        + np.floor(2.0 * np.asarray(z)).astype(np.int64)
    ) % 2
    return np.where(parity == 1, rho, 1.0)


def checker_k_elements(coords: np.ndarray, *, rho: float = CHECKER_RHO):
    """(E, p) per-element-constant checker field from element centroids.

    Evaluating at centroids (not nodes) keeps interface GLL nodes — which
    sit exactly on the jump planes and belong to both neighbours —
    unambiguous: each element integrates its own constant.
    """
    c = np.asarray(coords).mean(axis=1)  # (E, 3)
    k_e = checker_k(c[:, 0], c[:, 1], c[:, 2], rho=rho)
    return np.broadcast_to(k_e[:, None], coords.shape[:2]).copy()


def coefficient_fields(name: str | None, coords, lam: float):
    """(k, lam_field) arrays for a named family, or (None, None) for legacy.

    ``coords`` is the mesh's (E, p, 3) node array.  ``"const"`` (and
    ``None``) return the legacy sentinels — constant-λ algebraic screen,
    bit-identical code paths.  The variable families return per-node k and
    a constant λ *field* (which switches the screen to the weak
    mass-weighted form — see ``core.operator.screen_stream``).
    """
    if name is None or name == "const":
        return None, None
    coords = np.asarray(coords)
    x, y, z = coords[..., 0], coords[..., 1], coords[..., 2]
    if name == "smooth":
        k = smooth_k(x, y, z)
    elif name == "checker":
        k = checker_k_elements(coords)
    else:
        raise ValueError(
            f"unknown coefficient family {name!r}; choose from {COEFFICIENTS}"
        )
    lam_field = np.full(coords.shape[:2], float(lam))
    return k, lam_field
