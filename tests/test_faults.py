"""Solver guardrails: detectors × injected faults, fallback-chain recovery.

Every `repro.testing.faults` injector is driven into the solve it targets
and must trip exactly the `SolveStatus` its docstring promises; the
fallback chain (`core.resilience`) must then recover each scenario to
CONVERGED.  The slow 8-rank test corrupts one rank's wire payloads and
asserts every replica exits on the same iteration with the same status —
the lockstep guarantee that makes the detectors safe under shard_map.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import (
    SolveStatus,
    build_problem,
    cg_assembled,
    cg_scattered,
    poisson_assembled,
    run_fallback_chain,
    solve_with_fallback,
    status_name,
)
from repro.core.operator import poisson_scattered
from repro.core.precond import make_preconditioner
from repro.testing import (
    mask_precond,
    nan_at_iteration,
    negate_precond,
    on_attempt,
    skew_operator,
)


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(3, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


@pytest.fixture(scope="module")
def rhs(prob64):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.standard_normal(prob64.n_global))


# ---------------------------------------------------------------- detectors


def test_healthy_solve_converges_with_detectors_on(prob64, rhs):
    """Guardrails must be free on the healthy path: same iteration count
    with every detector armed as with all of them disabled."""
    a = poisson_assembled(prob64)
    res = cg_assembled(a, rhs, n_iter=500, tol=1e-8)
    off = cg_assembled(a, rhs, n_iter=500, tol=1e-8,
                       divergence_factor=None, stagnation_window=None)
    assert res.status == SolveStatus.CONVERGED
    assert status_name(res.status) == "converged"
    assert int(res.iterations) == int(off.iterations)


def test_zero_rhs_converged_at_zero_iterations(prob64):
    """Satellite: rdotr0 = 0 reports CONVERGED/0 in both iteration modes."""
    a = poisson_assembled(prob64)
    zero = jnp.zeros(prob64.n_global, jnp.float64)
    for kwargs in ({"tol": 1e-8}, {}):  # tol mode and fixed-count mode
        res = cg_assembled(a, zero, n_iter=50, **kwargs)
        assert res.status == SolveStatus.CONVERGED, kwargs
        assert int(res.iterations) == 0, kwargs
        assert np.array_equal(np.array(res.x), np.zeros(prob64.n_global))


def test_zero_rhs_scattered(prob64):
    op = poisson_scattered(prob64)
    zero = jnp.zeros((prob64.mesh.n_elements,
                      prob64.mesh.points_per_element), jnp.float64)
    res = cg_scattered(op, zero, prob64.w_local, n_iter=50, tol=1e-8)
    assert res.status == SolveStatus.CONVERGED
    assert int(res.iterations) == 0


def test_nan_at_iteration_k_detected(prob64, rhs):
    """NaN injected into A·p at iteration k exits AT iteration k."""
    a = poisson_assembled(prob64)
    res = cg_assembled(nan_at_iteration(a, 3), rhs, n_iter=500, tol=1e-8)
    assert res.status == SolveStatus.BREAKDOWN_NAN
    assert int(res.iterations) == 3


def test_nan_in_initial_residual(prob64, rhs):
    """Call 0 is A·x₀: a NaN there is caught before the loop starts."""
    a = poisson_assembled(prob64)
    res = cg_assembled(nan_at_iteration(a, 0), rhs, n_iter=500, tol=1e-8)
    assert res.status == SolveStatus.BREAKDOWN_NAN
    assert int(res.iterations) == 0


def test_nan_recorded_in_fixed_count_mode(prob64, rhs):
    """lax.scan cannot exit early; the first failure is still recorded."""
    a = poisson_assembled(prob64)
    res = cg_assembled(nan_at_iteration(a, 5), rhs, n_iter=30)
    assert res.status == SolveStatus.BREAKDOWN_NAN
    assert int(res.iterations) == 30  # fixed-count always runs the budget


def test_indefinite_operator_detected(prob64, rhs):
    a = poisson_assembled(prob64)
    res = cg_assembled(lambda x: -a(x), rhs, n_iter=500, tol=1e-8)
    assert res.status == SolveStatus.BREAKDOWN_INDEFINITE
    assert int(res.iterations) <= 1


def test_sign_flipped_precond_detected(prob64, rhs):
    """−M⁻¹ shows up as r·z < 0 in the very first application (p·Ap stays
    positive — A is untouched), caught before iteration 1."""
    a = poisson_assembled(prob64)
    pc, _ = make_preconditioner("jacobi", prob64, a)
    res = cg_assembled(a, rhs, n_iter=500, tol=1e-8,
                       precond=negate_precond(pc))
    assert res.status == SolveStatus.BREAKDOWN_INDEFINITE
    assert int(res.iterations) == 0


def test_skew_corruption_diverges(prob64, rhs):
    """Skew-symmetric corruption keeps p·Ap > 0 (no indefinite trip) but
    blows up the recurrence: the DIVERGED detector's canonical trigger."""
    a = poisson_assembled(prob64)
    res = cg_assembled(skew_operator(a, 5000.0), rhs, n_iter=500, tol=1e-8)
    assert res.status == SolveStatus.DIVERGED, status_name(res.status)
    assert int(res.iterations) < 500


def test_rank_deficient_precond_stagnates(prob64, rhs):
    """A partially-zeroed (PSD, rank-deficient) M⁻¹ pins the residual at a
    floor: STAGNATED after the no-progress window."""
    a = poisson_assembled(prob64)
    pc, _ = make_preconditioner("jacobi", prob64, a)
    res = cg_assembled(a, rhs, n_iter=500, tol=1e-12,
                       precond=mask_precond(pc, keep_every=7),
                       cg_variant="flexible")
    assert res.status == SolveStatus.STAGNATED, status_name(res.status)
    assert int(res.iterations) >= 50  # needs a full window to decide


def test_detectors_can_be_disabled(prob64, rhs):
    """divergence_factor=None / stagnation_window=None fall back to the
    pre-guardrail behaviour: the budget runs out as MAX_ITER."""
    a = poisson_assembled(prob64)
    res = cg_assembled(skew_operator(a, 5000.0), rhs, n_iter=60, tol=1e-8,
                       divergence_factor=None, stagnation_window=None)
    assert res.status == SolveStatus.MAX_ITER
    assert int(res.iterations) == 60


def test_status_under_jit_with_history(prob64, rhs):
    a = poisson_assembled(prob64)
    run = jax.jit(lambda bb: cg_assembled(
        a, bb, n_iter=500, tol=1e-8, record_history=True))
    res = run(rhs)
    assert int(res.status) == SolveStatus.CONVERGED
    hist = np.asarray(res.rdotr_history)[: int(res.iterations)]
    assert hist[-1] < hist[0]


def test_status_name_roundtrip():
    for s in SolveStatus:
        assert status_name(s) == s.name.lower()
    with pytest.raises(ValueError):
        status_name(99)


# ---------------------------------------------------------- fallback chain


def test_fallback_healthy_single_attempt(prob64, rhs):
    fb = solve_with_fallback(prob64, rhs, precond="jacobi", tol=1e-8)
    assert fb.recovered and fb.status == SolveStatus.CONVERGED
    assert [a.action for a in fb.attempts] == ["initial"]


def test_fallback_retry_recovers_transient_fault(prob64, rhs):
    """A one-shot skew corruption on attempt 0 is outrun by the retry rung
    — no configuration degradation needed."""
    fb = solve_with_fallback(
        prob64, rhs, precond="jacobi", tol=1e-8,
        instrument=on_attempt(0, operator=lambda op: skew_operator(op, 5000.0)),
    )
    assert fb.recovered
    assert [(a.action, a.status) for a in fb.attempts] == [
        ("initial", "diverged"),
        ("retry", "converged"),
    ]
    # the recovery attempt kept the caller's configuration
    assert fb.attempts[-1].precond == "jacobi"


def test_fallback_retry_recovers_transient_nan(prob64, rhs):
    """nan_at_iteration's counter keeps advancing across attempts, so the
    retry of the *same wrapped operator* runs clean — SDC semantics."""
    base = poisson_assembled(prob64)
    faulty = nan_at_iteration(base, 2)
    fb = solve_with_fallback(prob64, rhs, operator=faulty,
                             precond="jacobi", tol=1e-8)
    assert fb.recovered
    assert fb.attempts[0].status == "breakdown_nan"
    assert fb.attempts[1].action == "retry"


def test_fallback_walks_ladder_on_persistent_precond_fault(prob64, rhs):
    """A *persistent* sign-flipped M⁻¹ defeats retry and flexible β; the
    chain keeps degrading until plain CG (no M⁻¹ to corrupt) converges."""

    def instrument(i, op, pc):
        return op, (None if pc is None else negate_precond(pc))

    fb = solve_with_fallback(prob64, rhs, precond="jacobi", tol=1e-8,
                             instrument=instrument)
    assert fb.recovered
    assert [a.action for a in fb.attempts] == [
        "initial", "retry", "flexible_cg", "downgrade_precond:jacobi->none",
    ]
    assert fb.attempts[-1].precond == "none"
    assert all(a.status == "breakdown_indefinite" for a in fb.attempts[:-1])
    # the attempt log is json-ready
    rec = fb.record()
    assert rec[-1]["status"] == "converged"
    assert {type(v) for r in rec for v in r.values()} <= {
        str, int, float, type(None)
    }


def test_fallback_chain_exhaustion():
    """attempt_fn that never converges: the chain stops after walking every
    rung and reports recovered=False with the full log."""

    class Fail:
        status = int(SolveStatus.STAGNATED)
        iterations = 7
        rdotr = 1.0

    calls = []

    def attempt_fn(**kw):
        calls.append((kw["precond"], kw["precond_dtype"], kw["cg_variant"]))
        return Fail()

    fb = run_fallback_chain(attempt_fn, precond="pmg",
                            precond_dtype="float32", cg_variant="standard")
    assert not fb.recovered and fb.status == SolveStatus.STAGNATED
    assert [a.action for a in fb.attempts] == [
        "initial", "retry", "flexible_cg", "full_precision_precond",
        "downgrade_precond:pmg->chebyshev",
        "downgrade_precond:chebyshev->jacobi",
        "downgrade_precond:jacobi->none",
    ]
    # the last rung really is plain CG
    assert calls[-1] == ("none", None, "flexible")


def test_fallback_max_attempts_cap():
    class Fail:
        status = int(SolveStatus.DIVERGED)
        iterations = 1
        rdotr = float("inf")

    fb = run_fallback_chain(lambda **kw: Fail(), precond="pmg",
                            max_attempts=2)
    assert not fb.recovered and len(fb.attempts) == 2
    with pytest.raises(ValueError):
        run_fallback_chain(lambda **kw: Fail(), max_attempts=0)


def test_fallback_requires_tol(prob64, rhs):
    with pytest.raises(ValueError, match="tol"):
        solve_with_fallback(prob64, rhs, tol=None)


# -------------------------------------------------------- config hardening


def test_config_rejects_invalid_knob_combos():
    """Satellite: PoissonConfig fails fast with the offending knob named
    instead of surfacing as a deep-stack solver failure."""
    from repro.configs.hipbone import PoissonConfig

    base = dict(name="bad", n_degree=7, local_elems=(2, 2, 2))
    cases = [
        dict(n_degree=0), dict(local_elems=(0, 2, 2)), dict(lam=0.0),
        dict(n_iter=0), dict(tol=-1.0), dict(dtype="float16"),
        dict(precond="ilu"), dict(cheb_degree=0),
        dict(n_degree=1, precond="pmg"), dict(schwarz_overlap=7),
        dict(precond_dtype="bfloat16"),
        dict(precond_dtype="float32", precond="none"),
        dict(cg_variant="cgs"), dict(fused_operator=1),
        dict(divergence_factor=1.0), dict(stagnation_window=0),
        dict(stagnation_rtol=0.0),
    ]
    for kw in cases:
        args = dict(base)
        args.update(kw)
        with pytest.raises(ValueError, match="bad"):
            PoissonConfig(**args)


def test_config_warns_on_narrowed_precond_with_standard_beta():
    """Satellite: the documented legal-but-suspect combination — fp32 M⁻¹
    with the Fletcher–Reeves β — emits ConfigWarning, and the flexible-β
    pairing stays silent."""
    from repro.configs.hipbone import ConfigWarning, PoissonConfig

    with pytest.warns(ConfigWarning, match="flexible"):
        PoissonConfig("w", 7, (2, 2, 2), precond="jacobi",
                      dtype="float64", precond_dtype="float32")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        PoissonConfig("ok", 7, (2, 2, 2), precond="jacobi",
                      dtype="float64", precond_dtype="float32",
                      cg_variant="flexible")


def test_config_detector_defaults_mirror_cg():
    """The config's detector-knob defaults must stay in sync with the
    solver's module constants (both are documented contracts)."""
    from repro.configs.hipbone import CONFIGS, REDUCED
    from repro.core import cg

    assert REDUCED.divergence_factor == cg.DIVERGENCE_FACTOR
    assert REDUCED.stagnation_window == cg.STAGNATION_WINDOW
    assert REDUCED.stagnation_rtol == cg.STAGNATION_RTOL
    # every shipped preset passes its own validation at import time, and
    # the shipped mixed-precision presets pair fp32 chains with flexible β
    for cfg in CONFIGS.values():
        if cfg.precond_dtype is not None and cfg.precond_dtype != cfg.dtype:
            assert cfg.cg_variant == "flexible", cfg.name


# ------------------------------------------------- fused-operator fallback


def test_forced_probe_failure_degrades_to_split(prob64, monkeypatch):
    """A Pallas lowering/VMEM failure in the fused-operator probe must turn
    into one warning + the split pipeline — even under HIPBONE_FUSED=1."""
    from repro.kernels import ops
    from repro.testing import force_fused_failure

    monkeypatch.setenv("HIPBONE_FUSED", "1")
    args = dict(n_degree=prob64.mesh.n_degree, n_global=prob64.n_global)
    with force_fused_failure():
        with pytest.warns(RuntimeWarning, match="split"):
            assert ops.should_fuse_operator(jnp.float64, **args) is False
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # cached verdict: no re-warn
            assert ops.should_fuse_operator(jnp.float64, **args) is False
        # the degraded policy builds the split-path operator
        a = poisson_assembled(prob64)
        assert a.fused is False
    # probe state restored: the genuine lowering succeeds again
    assert ops._FUSED_PROBE_FAIL is False
    assert ops.should_fuse_operator(jnp.float64, **args) is True


# ----------------------------------------------------------- sharded paths


@pytest.mark.slow
def test_corrupted_wire_exits_all_ranks_in_lockstep():
    """ISSUE acceptance: corrupt ONE rank's outgoing halo payloads on an
    8-rank solve — every rank must exit on the same iteration with the
    same status (detector inputs are psum-derived), and the same solve
    runs clean without the hook."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core.cg import SolveStatus
from repro.testing import corrupt_wire

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((8, prob.m3)))

# healthy baseline, per-rank observability
run = jax.jit(dist_cg(prob, mesh, b, n_iter=200, tol=1e-10,
                      precond="jacobi", per_rank_stats=True))
x, rdotr, iters, status, hist = run()
iters, status = np.asarray(iters), np.asarray(status)
assert iters.shape == (8,) and status.shape == (8,)
assert np.unique(status).size == 1 and status[0] == SolveStatus.CONVERGED
healthy_iters = int(iters[0])
assert np.unique(iters).size == 1 and healthy_iters < 200

# rank 3 poisons every payload it sends; install BEFORE tracing
with corrupt_wire(3, mode="nan"):
    bad = jax.jit(dist_cg(prob, mesh, b, n_iter=200, tol=1e-10,
                          precond="jacobi", per_rank_stats=True))
    xb, rdb, itb, stb, _ = bad()
itb, stb = np.asarray(itb), np.asarray(stb)
assert np.unique(stb).size == 1, stb
assert stb[0] == SolveStatus.BREAKDOWN_NAN, stb
assert np.unique(itb).size == 1, itb
assert int(itb[0]) <= 1, itb  # NaN spreads through the first halo sum

# zeroed payloads corrupt the operator less dramatically: still a
# single lockstep non-CONVERGED exit on every rank
with corrupt_wire(3, mode="zero"):
    z = jax.jit(dist_cg(prob, mesh, b, n_iter=200, tol=1e-10,
                        precond="jacobi", per_rank_stats=True))
    _, _, itz, stz, _ = z()
itz, stz = np.asarray(itz), np.asarray(stz)
assert np.unique(stz).size == 1 and np.unique(itz).size == 1, (stz, itz)
assert stz[0] != SolveStatus.CONVERGED, stz

# hook gone after the context: clean solve again, same iteration count
again = jax.jit(dist_cg(prob, mesh, b, n_iter=200, tol=1e-10,
                        precond="jacobi"))
_, _, it2, st2, _ = again()
assert int(st2) == SolveStatus.CONVERGED and int(it2) == healthy_iters

# zero-RHS edge case, sharded: CONVERGED at 0 iterations
zrun = jax.jit(dist_cg(prob, mesh, jnp.zeros_like(b), n_iter=200,
                       tol=1e-10))
_, _, it0, st0, _ = zrun()
assert int(st0) == SolveStatus.CONVERGED and int(it0) == 0
print("OK", healthy_iters)
""",
        devices=8,
    )


def test_dist_status_in_fixed_count_mode():
    """Fixed-count sharded solve (the scan path check_rep relies on) still
    threads a status: MAX_ITER on completion."""
    run_subprocess(
        """
import jax
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core.cg import SolveStatus

grid = ProcessGrid((2, 1, 1))
mesh = make_mesh((2,), ("ranks",))
prob = build_dist_problem(3, grid, (1, 1, 1), lam=1.0, dtype=jnp.float32)
b = jnp.asarray(
    np.random.default_rng(0).standard_normal((2, prob.m3)), jnp.float32)
x, rdotr, iters, status, hist = jax.jit(
    dist_cg(prob, mesh, b, n_iter=20))()
assert int(status) == SolveStatus.MAX_ITER and int(iters) == 20
print("OK")
""",
        devices=2,
    )
