"""Setup amortization for batched multi-RHS solves (beyond the benchmark).

The serving question hipBone's parent workload (Nek5000/RS time stepping)
answers every step: given one operator/preconditioner setup, how cheap
does a solve get when B right-hand sides ride one dispatch?  This
benchmark drives the :class:`repro.serving.SolverEngine` through a
B ∈ {1, 4, 16} sweep per preconditioner kind and records, per (N, λ,
kind, dtype, B) case:

  * ``iters_to_tol`` — the max per-column iteration count (columns stop
    independently; the max is what bounds the dispatch) and ``status`` —
    "converged" only if *every* column converged;
  * ``setup_cache`` / ``setup_s`` — whether this dispatch built the setup
    or reused it, and what the build cost;
  * ``solve_s`` / ``per_solve_s`` — batched wall time and its per-column
    share, the amortization curve (per_solve_s falls as B grows while a
    single setup serves the whole sweep).

The zero-setup-on-hit contract is *asserted*, not just reported: after
the sweep the engine's cache counters must show exactly one miss per
(kind) and hits everywhere else, and every hit row must carry
``setup_s == 0.0``.  ``scripts/compare_bench.py`` gates the
``batched_records`` section across PRs on iterations and status.
"""
from __future__ import annotations

BATCHES = (1, 4, 16)
KINDS = ("jacobi", "chebyshev")
TOL = 1e-6
LAM = 1.0


def records(quick: bool = True) -> list[dict]:
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build_problem
    from repro.serving import SolveRequest, SolverEngine, SolverServeConfig

    degrees = [3] if quick else [3, 7]
    out: list[dict] = []
    for n in degrees:
        prob = build_problem(
            n, (4, 4, 4), lam=LAM, deform=0.15, dtype=jnp.float64
        )
        rng = np.random.default_rng(0)
        engine = SolverEngine(SolverServeConfig(max_batch=max(BATCHES)))
        for kind in KINDS:
            for batch in BATCHES:
                reqs = [
                    SolveRequest(
                        prob=prob,
                        b=jnp.asarray(
                            rng.standard_normal(prob.n_global), prob.dtype
                        ),
                        kind=kind,
                        tol=TOL,
                        n_iter=500,
                    )
                    for _ in range(batch)
                ]
                responses = engine.solve(reqs)
                rec = engine.records[-1]
                statuses = {r.status_name for r in responses}
                out.append(
                    {
                        "n": n,
                        "dofs": prob.n_global,
                        "lam": LAM,
                        "kind": kind,
                        "dtype": "fp64",
                        "batch": batch,
                        "iters_to_tol": max(r.iterations for r in responses),
                        "status": (
                            "converged"
                            if statuses == {"converged"}
                            else sorted(statuses - {"converged"})[0]
                        ),
                        "setup_cache": rec["setup_cache"],
                        "setup_s": rec["setup_build_s"],
                        "solve_s": rec["solve_s"],
                        "per_solve_s": rec["per_solve_s"],
                    }
                )
        # the zero-setup contract the docstring promises: one miss per
        # kind, every other dispatch a hit that rebuilt nothing
        stats = engine.cache.stats()
        assert stats["misses"] == len(KINDS), stats
        assert stats["hits"] == len(KINDS) * (len(BATCHES) - 1), stats
        for r in out:
            if r["n"] == n and r["setup_cache"] == "hit":
                assert r["setup_s"] == 0.0, r
    return out


def rows_from(recs: list[dict]) -> list[str]:
    rows = ["section,n,kind,batch,iters,status,setup,setup_s,per_solve_s"]
    for r in recs:
        rows.append(
            f"batched,{r['n']},{r['kind']},{r['batch']},{r['iters_to_tol']},"
            f"{r['status']},{r['setup_cache']},{r['setup_s']:.4f},"
            f"{r['per_solve_s']:.4f}"
        )
    return rows


def main(quick: bool = True):
    return rows_from(records(quick))


if __name__ == "__main__":
    print("\n".join(main(quick=False)))
