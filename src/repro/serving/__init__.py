"""repro.serving — the solver service (and the seed's LM decode engine).

``SolverEngine`` (engine.py) is the production face of the repo: batched
multi-RHS screened-Poisson dispatch over a setup cache.  The original
LLM prefill/decode engine lives in ``lm.py`` and keeps its historical
``Engine``/``ServeConfig`` names for ``examples/serve_lm.py``.
"""
from .engine import SolveRequest, SolveResponse, SolverEngine, SolverServeConfig
from .lm import Engine, ServeConfig

__all__ = [
    "Engine",
    "ServeConfig",
    "SolveRequest",
    "SolveResponse",
    "SolverEngine",
    "SolverServeConfig",
]
