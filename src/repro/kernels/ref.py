"""Pure-jnp oracles for every Pallas kernel (ground truth for tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.operator import local_poisson

__all__ = ["poisson_local_ref", "fused_axpy_dot_ref", "fused_xpay_ref", "weighted_dot_ref"]


def poisson_local_ref(
    u: jax.Array, g: jax.Array, w: jax.Array, d: jax.Array, *, lam: float
) -> jax.Array:
    """y = (S_L + λ diag(w)) u — reference for kernels/poisson.py."""
    return local_poisson(u, g, d, lam, w)


def fused_axpy_dot_ref(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    r_new = r - alpha * ap
    rf = r_new.astype(jnp.float32)
    return r_new, jnp.sum(rf * rf)


def fused_xpay_ref(r: jax.Array, p: jax.Array, beta: jax.Array) -> jax.Array:
    return r + beta * p


def weighted_dot_ref(w: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.sum(
        w.astype(jnp.float32) * a.astype(jnp.float32) * b.astype(jnp.float32)
    )
