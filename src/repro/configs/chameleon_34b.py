"""chameleon-34b [vlm] — early-fusion, VQ image tokens (arXiv:2405.09818).

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536. The modality
frontend is a STUB per the assignment: the vocabulary already contains the
VQ image codes, so ``input_specs`` provides the precomputed token stream
(text + image codes interleaved); the VQ-VAE encoder is out of scope.
Chameleon stabilizes early fusion with QK-norm — kept.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    rope_theta=10000.0,
    act="silu",
)

REDUCED = ModelConfig(
    name="chameleon-34b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    dtype="float32",
)
