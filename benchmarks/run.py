"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--full]`` prints CSV rows per benchmark:
  fig3     — operator GFLOPS vs N + roofline      (paper Fig. 3)
  table1   — kernel occupancy/VMEM analogue       (paper Table 1)
  fig456   — multi-rank scaling + throughput      (paper Figs. 4-6)
  table2   — peak FOM / weak scaling / NekBone-vs-hipBone (paper Table 2)
  exchange — routing-algorithm selection          (paper §MPI Communication)
  precond  — PCG iterations-to-tolerance + FOM    (beyond the benchmark)
"""
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger problem sizes")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        exchange_select,
        fig3_operator,
        fig456_scaling,
        precond_solve,
        table1_blocks,
        table2_fom,
    )

    sections = {
        "fig3": fig3_operator.main,
        "table1": table1_blocks.main,
        "fig456": fig456_scaling.main,
        "table2": table2_fom.main,
        "exchange": exchange_select.main,
        "precond": precond_solve.main,
    }
    failures = 0
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            for row in fn(quick=quick):
                print(row, flush=True)
        except Exception as e:  # report and continue
            failures += 1
            print(f"{name},ERROR,{type(e).__name__}: {e}", flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
