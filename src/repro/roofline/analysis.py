"""Three-term roofline from compiled dry-run artifacts (no hardware needed).

    compute term    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = link_bytes_per_chip / ICI_bw

``compiled.cost_analysis()`` supplies per-chip FLOPs and bytes (the SPMD
module is the per-device program). Collective bytes are NOT in
cost_analysis: we parse the post-optimization HLO and charge each
collective its ring-algorithm link traffic:

    all-gather      (g-1)/g * result_bytes
    reduce-scatter  (g-1)/g * operand_bytes
    all-reduce      2(g-1)/g * operand_bytes
    all-to-all      (g-1)/g * operand_bytes
    collective-permute  operand_bytes

with g parsed from replica_groups (both explicit {{...}} and iota
[n,g]<=[N] forms).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any


from ..core.fom import TPU_V5E, TpuSpec

__all__ = ["CollectiveStats", "parse_collectives", "roofline_report"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

# result-type token, e.g. f32[8,128]{1,0} or (f32[8],f32[8]) for tuples
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    raw_bytes: dict[str, float]      # sum of result sizes per op kind
    link_bytes: dict[str, float]     # ring-model per-device link traffic

    @property
    def total_link_bytes(self) -> float:
        return sum(self.link_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    raw: dict[str, float] = {}
    link: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if m is None:
            continue
        type_str, kind = m.group(1), m.group(2)
        size = _type_bytes(type_str)
        if size == 0:
            continue
        # group size
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            if gm:
                g = int(gm.group(2))
        if g is None or g <= 1:
            g = 2 if kind == "collective-permute" else 1
        if kind == "all-reduce":
            lb = 2 * (g - 1) / g * size
        elif kind == "all-gather":
            lb = (g - 1) / g * size       # size = gathered result
        elif kind == "reduce-scatter":
            lb = (g - 1) * size           # size = scattered result; operand = g*size
        elif kind == "all-to-all":
            lb = (g - 1) / g * size
        else:  # collective-permute
            lb = size
        counts[kind] = counts.get(kind, 0) + 1
        raw[kind] = raw.get(kind, 0.0) + size
        link[kind] = link.get(kind, 0.0) + lb
    return CollectiveStats(counts=counts, raw_bytes=raw, link_bytes=link)


def roofline_report(
    cost: dict[str, Any],
    coll: CollectiveStats,
    *,
    spec: TpuSpec = TPU_V5E,
    chips: int = 1,
    model_flops: float | None = None,
) -> dict[str, Any]:
    """Build the §Roofline record for one (arch, shape, mesh) cell."""
    flops = float(cost.get("flops", 0.0))
    if flops < 0:
        flops = 0.0
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    t_compute = flops / spec.peak_flops
    t_memory = bytes_acc / spec.hbm_bandwidth
    t_coll = coll.total_link_bytes / spec.ici_bandwidth
    terms = {"compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    bound = max(terms.values())
    out = {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "link_bytes_per_chip": coll.total_link_bytes,
        "collective_counts": coll.counts,
        "collective_link_bytes": coll.link_bytes,
        "roofline_bound_s": bound,
    }
    if model_flops is not None and flops > 0:
        out["model_flops"] = model_flops
        out["model_flops_per_chip"] = model_flops / chips
        out["useful_flop_fraction"] = model_flops / chips / flops
        # fraction of the peak the dominant-term-limited execution achieves
        ideal_t = model_flops / chips / spec.peak_flops
        out["roofline_fraction"] = ideal_t / bound if bound > 0 else 0.0
    return out
