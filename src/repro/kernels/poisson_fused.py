"""Single-pass Pallas kernel for the full assembled apply  y_G = Z^T (S_L + λW) Z x_G.

The split pipeline (core/operator.py ``poisson_assembled``) runs three XLA
ops — scatter Z, the element-local kernel, gather Z^T — and therefore
materializes x_L and y_L through HBM between stages. This kernel fuses all
three into one grid sweep over element blocks, so the seven input streams
(x_G, the l2g index map, the six geometric-factor planes + W) are each read
exactly once per CG iteration and y_L never exists:

  * x_G stays VMEM-resident across the whole grid (constant-index-map block
    — Mosaic fetches it once), viewed as (rows, 128) lane tiles;
  * per grid step, the (block_e, p) tile of ``l2g`` indices streams in and
    drives the in-kernel gather of the element-local field Z x_G;
  * the existing three-contraction MXU body from kernels/poisson.py
    (``local_body``) produces (S_L + λW) on the gathered block;
  * the scatter-add Z^T accumulates into a y_G output block that every
    sequential grid step revisits (``@pl.when(i == 0)`` zero-init, ``+=``
    per step) — TPU grids are serialized, so the accumulation is
    deterministic without atomics.

Two gather/scatter strategies, selected by ``gather_mode``:

  * ``"take"`` (default): vectorized ``jnp.take`` / ``.at[].add`` on the
    VMEM-resident x_G/y_G blocks — the fast path wherever the backend
    supports lane gather (and the interpret path CI validates on CPU).
  * ``"loop"``: the l2g map rides a ``PrefetchScalarGridSpec`` scalar-
    prefetch argument (SMEM), and gather/scatter run as a serial
    ``fori_loop`` of single-node dynamic slices — the fallback for Mosaic
    versions without per-lane VMEM gather. Slow but bit-compatible up to
    summation order; duplicates within a block are handled by the serial
    read-modify-write.

VMEM budget: unlike the element-local kernel, x_G and y_G are resident, so
``fused_fits_vmem`` gates the auto-enable policy (``ops.should_fuse_operator``)
and the split path remains the fallback for global vectors too large to
pin. Padding (elements to block_e, DOFs to the 128-lane tile) is handled by
``ops.poisson_assembled_fused``; padded elements carry zero G/W so they
contribute exactly 0.0 wherever their dummy index points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .poisson import local_body, pick_block_e
from .streams import LANES

__all__ = [
    "poisson_assembled_fused_pallas",
    "fused_vmem_bytes",
    "fused_fits_vmem",
    "pick_fused_block_e",
]

FUSED_VMEM_BUDGET = 8 * 2**20


def fused_vmem_bytes(block_e: int, n1: int, n_pad: int, dtype=jnp.float32) -> int:
    """Estimated VMEM working set: resident x_G/y_G + one grid step's tiles."""
    p = n1**3
    word = jnp.dtype(dtype).itemsize
    acc = jnp.promote_types(jnp.dtype(dtype), jnp.float32).itemsize
    resident = 2 * n_pad * word  # x_G + y_G, pinned across the grid
    tiles = block_e * p * (4 + 7 * word)  # l2g (int32) + 6 G planes + W
    temps = block_e * p * 8 * acc  # u, ur/us/ut, wr/ws/wt, out
    return resident + tiles + temps


def fused_fits_vmem(
    n_degree: int,
    n_global: int,
    dtype=jnp.float32,
    budget_bytes: int = FUSED_VMEM_BUDGET,
) -> bool:
    """True when the single-kernel form fits the VMEM budget at block_e=1."""
    n_pad = -(-max(n_global, 1) // LANES) * LANES
    return fused_vmem_bytes(1, n_degree + 1, n_pad, dtype) <= budget_bytes


def pick_fused_block_e(
    n_degree: int,
    n_global: int,
    dtype=jnp.float32,
    budget_bytes: int = FUSED_VMEM_BUDGET,
) -> int:
    """Largest power-of-two element block fitting the budget with x/y resident."""
    n1 = n_degree + 1
    n_pad = -(-max(n_global, 1) // LANES) * LANES
    eb = min(256, pick_block_e(n_degree, dtype))
    while eb > 1 and fused_vmem_bytes(eb, n1, n_pad, dtype) > budget_bytes:
        eb //= 2
    return eb


def _kernel_take(idx_ref, x_ref, g_ref, w_ref, d_ref, y_ref, *, lam, n1):
    """One grid step, vector gather/scatter on the resident x/y blocks."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros(y_ref.shape, y_ref.dtype)

    idx = idx_ref[...].reshape(-1)  # (Eb*p,) int32
    x = x_ref[...].reshape(-1)  # (rows*128,) resident x_G
    eb, p = idx_ref.shape
    u = jnp.take(x, idx, axis=0).reshape(eb, p)  # gather Z x_G
    y_l = local_body(u, g_ref[...], w_ref[...], d_ref[...], lam=lam, n1=n1)
    # scatter-add Z^T into the revisited y_G block; duplicate indices within
    # the tile accumulate correctly through the segment-style .at[].add
    delta = jnp.zeros(x.shape, y_ref.dtype).at[idx].add(
        y_l.reshape(-1).astype(y_ref.dtype)
    )
    y_ref[...] += delta.reshape(y_ref.shape)


def _kernel_loop(idx_ref, x_ref, g_ref, w_ref, d_ref, y_ref, *, lam, n1):
    """One grid step, serial dynamic-slice gather/scatter (no lane gather).

    ``idx_ref`` is the scalar-prefetched full (E_pad*p,) l2g map in SMEM.
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        y_ref[...] = jnp.zeros(y_ref.shape, y_ref.dtype)

    eb, p = g_ref.shape[0], g_ref.shape[2]
    total = eb * p
    base = i * total

    def gather_one(k, u_flat):
        node = idx_ref[base + k]
        val = x_ref[node // LANES, node % LANES]
        return u_flat.at[k].set(val)

    u = jax.lax.fori_loop(
        0, total, gather_one, jnp.zeros((total,), x_ref.dtype)
    ).reshape(eb, p)
    y_l = local_body(u, g_ref[...], w_ref[...], d_ref[...], lam=lam, n1=n1)
    y_flat = y_l.reshape(-1).astype(y_ref.dtype)

    def scatter_one(k, carry):
        node = idx_ref[base + k]
        r, c = node // LANES, node % LANES
        y_ref[r, c] = y_ref[r, c] + y_flat[k]
        return carry

    jax.lax.fori_loop(0, total, scatter_one, 0)


@functools.partial(
    jax.jit,
    static_argnames=("lam", "block_e", "interpret", "gather_mode"),
)
def poisson_assembled_fused_pallas(
    x2: jax.Array,
    l2g: jax.Array,
    g: jax.Array,
    w: jax.Array,
    d: jax.Array,
    *,
    lam: float,
    block_e: int,
    interpret: bool = True,
    gather_mode: str = "take",
) -> jax.Array:
    """Single-kernel y_G = Z^T (S_L + λW) Z x_G on pre-padded operands.

    Args:
      x2: (rows, 128) lane-tiled padded x_G (zeros beyond n_global).
      l2g: (E, p) int32 local-to-global map into the flattened x2; E must be
        a multiple of block_e (ops.poisson_assembled_fused pads, pointing
        padded elements at slot 0 — their zero G/W makes that a no-op).
      g / w / d / lam: as in kernels/poisson.py.
      block_e: elements per grid step (pick_fused_block_e).
      interpret: run via the Pallas interpreter (CPU validation path).
      gather_mode: "take" (vector lane gather) or "loop" (scalar-prefetch +
        dynamic-slice fallback).

    Returns:
      (rows, 128) lane-tiled padded y_G.
    """
    e, p = l2g.shape
    n1 = d.shape[0]
    if n1**3 != p:
        raise ValueError(f"p={p} is not (N+1)^3 for n1={n1}")
    if e % block_e:
        raise ValueError(
            f"E={e} not a multiple of block_e={block_e}; "
            "use ops.poisson_assembled_fused"
        )
    rows = x2.shape[0]
    grid = (e // block_e,)
    out_shape = jax.ShapeDtypeStruct((rows, LANES), x2.dtype)
    data_specs = [
        pl.BlockSpec((rows, LANES), lambda i: (0, 0)),  # x_G, resident
        pl.BlockSpec((block_e, 6, p), lambda i: (i, 0, 0)),
        pl.BlockSpec((block_e, p), lambda i: (i, 0)),
        pl.BlockSpec((n1, n1), lambda i: (0, 0)),
    ]
    out_spec = pl.BlockSpec((rows, LANES), lambda i: (0, 0))  # revisited acc

    if gather_mode == "take":
        return pl.pallas_call(
            functools.partial(_kernel_take, lam=lam, n1=n1),
            grid=grid,
            in_specs=[pl.BlockSpec((block_e, p), lambda i: (i, 0))] + data_specs,
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(l2g, x2, g, w, d)
    if gather_mode == "loop":
        # index maps receive the scalar-prefetch ref as a trailing argument
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((rows, LANES), lambda i, s: (0, 0)),
                pl.BlockSpec((block_e, 6, p), lambda i, s: (i, 0, 0)),
                pl.BlockSpec((block_e, p), lambda i, s: (i, 0)),
                pl.BlockSpec((n1, n1), lambda i, s: (0, 0)),
            ],
            out_specs=pl.BlockSpec((rows, LANES), lambda i, s: (0, 0)),
        )
        return pl.pallas_call(
            functools.partial(_kernel_loop, lam=lam, n1=n1),
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(l2g.reshape(-1), x2, g, w, d)
    raise ValueError(f"unknown gather_mode {gather_mode!r}")
