"""Process-grid topology for nearest-neighbor communication.

Maps a flat list of P ranks (the flattened device mesh) onto a 3-D process
grid for the Poisson element partition, and provides the static
src->dst permutation tables that lax.ppermute consumes. This replaces the
rank bookkeeping that gslib/MPI communicators do in hipBone.
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["ProcessGrid", "factor3", "hypercube_stages"]


def factor3(p: int) -> tuple[int, int, int]:
    """Factor P into a near-cubic (px, py, pz) grid (px >= py >= pz)."""
    best = (p, 1, 1)
    best_cost = float("inf")
    for a in range(1, int(round(p ** (1 / 3))) + 2):
        if p % a:
            continue
        q = p // a
        for b in range(a, int(math.isqrt(q)) + 1):
            if q % b:
                continue
            c = q // b
            dims = tuple(sorted((a, b, c), reverse=True))
            cost = dims[0] / dims[2]  # aspect ratio
            if cost < best_cost:
                best, best_cost = dims, cost
    return best  # type: ignore[return-value]


@dataclasses.dataclass(frozen=True)
class ProcessGrid:
    """A (px, py, pz) grid over P ranks, x fastest (rank = i + px*(j + py*k))."""

    shape: tuple[int, int, int]

    @property
    def size(self) -> int:
        px, py, pz = self.shape
        return px * py * pz

    def strides(self) -> tuple[int, int, int]:
        px, py, _ = self.shape
        return (1, px, px * py)

    def coords(self, rank: int) -> tuple[int, int, int]:
        px, py, _ = self.shape
        return (rank % px, (rank // px) % py, rank // (px * py))

    def rank(self, i: int, j: int, k: int) -> int:
        px, py, _ = self.shape
        return i + px * (j + py * k)

    def shift_perm(self, dim: int, direction: int) -> list[tuple[int, int]]:
        """ppermute pairs sending along ``dim`` by ``direction`` (+1/-1).

        Ranks on the boundary simply don't send (and receive zeros) —
        lax.ppermute's fill semantics implement the non-periodic mesh edge.
        """
        pairs = []
        pd = self.shape[dim]
        stride = self.strides()[dim]
        for r in range(self.size):
            c = self.coords(r)[dim]
            if 0 <= c + direction < pd:
                pairs.append((r, r + direction * stride))
        return pairs

    def offset_perm(self, offset: tuple[int, int, int]) -> list[tuple[int, int]]:
        """ppermute pairs shifting by a diagonal ``(ox, oy, oz)`` offset.

        Generalizes :meth:`shift_perm` to edge/corner neighbors — the
        message table of the fused one-round exchange routings, where all
        face/edge/corner slabs travel concurrently instead of propagating
        through sequential dimension sweeps.  Ranks whose offset target
        falls outside the grid don't send (and receive ppermute zero-fill),
        exactly like the face-shift boundary handling.
        """
        pairs = []
        for r in range(self.size):
            c = self.coords(r)
            cc = tuple(c[d] + offset[d] for d in range(3))
            if all(0 <= cc[d] < self.shape[d] for d in range(3)):
                pairs.append((r, self.rank(*cc)))
        return pairs

    def neighbor_count(self, rank: int) -> int:
        """Number of face neighbors (the paper's pairwise message count /2... per direction)."""
        n = 0
        for dim in range(3):
            c = self.coords(rank)[dim]
            n += (c > 0) + (c < self.shape[dim] - 1)
        return n


def hypercube_stages(p: int) -> int:
    """log2(P) for the crystal router; P must be a power of two."""
    k = p.bit_length() - 1
    if (1 << k) != p:
        raise ValueError(f"crystal router needs power-of-two ranks, got {p}")
    return k
