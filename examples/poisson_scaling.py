"""Distributed hipBone: multi-rank CG with communication-hiding split.

Emulates a multi-rank run on N fake CPU devices (set before jax import),
exercising the full distributed path: padded-consistent assembled storage,
halo sum-exchange via static ppermutes, interior/halo overlap split, and
masked+psum inner products.

    PYTHONPATH=src python examples/poisson_scaling.py --ranks 8 --n 7
"""
import argparse
import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    # relaunch with the device count pinned before jax import
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=8)
    args, rest = ap.parse_known_args()
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={args.ranks}"
    )
    os.execv(
        sys.executable,
        [sys.executable, __file__, "--ranks", str(args.ranks)] + rest,
    )

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid, factor3
from repro.core.cg import status_name
from repro.core.distributed import build_dist_problem, dist_cg, dist_spectrum
from repro.core.fom import nekbone_flops_per_iter


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=8)
    ap.add_argument("--n", type=int, default=7)
    ap.add_argument("--local", type=int, default=2, help="elements per axis per rank")
    ap.add_argument("--iters", type=int, default=100)
    ap.add_argument("--precond",
                    choices=["none", "jacobi", "chebyshev", "schwarz", "pmg",
                             "pmg-schwarz", "pmg-galerkin-mat"],
                    default="none", help="PCG preconditioner "
                    "(pmg-galerkin-mat = materialized P^T A P coarse "
                    "operators, the benchmark ladder's name for "
                    "pmg_coarse_op='galerkin_mat')")
    ap.add_argument("--cheb-degree", type=int, default=2)
    ap.add_argument("--tol", type=float, default=None,
                    help="stop at ||r|| <= tol*||r0|| instead of fixed iters")
    ap.add_argument("--precond-dtype", choices=["float32", "float64"],
                    default=None,
                    help="mixed precision: compute dtype of the whole "
                         "preconditioner chain (fp32 halves M⁻¹ HBM/wire "
                         "bytes inside an fp64 solve; implies --dtype "
                         "float64 makes sense)")
    ap.add_argument("--dtype", choices=["float32", "float64"],
                    default="float32", help="outer solve dtype")
    ap.add_argument("--cg-variant", choices=["standard", "flexible"],
                    default=None,
                    help="CG β recurrence; default flexible when the "
                         "preconditioner dtype is narrower than the solve")
    ap.add_argument("--two-phase", action="store_true",
                    help="paper-faithful two-phase comm (halo + gather)")
    ap.add_argument("--fused-operator", action="store_true",
                    help="single-kernel fused assembled apply for the "
                         "interior element block (kernels/poisson_fused.py); "
                         "default: kernels.ops.should_fuse_operator policy")
    ap.add_argument("--exchange",
                    choices=["auto", "face_sweep", "crystal", "fused"],
                    default=None,
                    help="halo-exchange routing policy (comms.plan): "
                         "'auto' times the candidates per site at setup "
                         "and picks winners; a named routing pins every "
                         "site.  Default: HIPBONE_EXCHANGE env, else auto. "
                         "Iteration counts are identical under every "
                         "choice — only wall time moves.")
    args = ap.parse_args()

    ranks = args.ranks
    assert len(jax.devices()) == ranks, "device count mismatch"
    dtype = jnp.dtype(args.dtype)
    if dtype == jnp.float64:
        jax.config.update("jax_enable_x64", True)
    pdtype = None if args.precond_dtype is None else jnp.dtype(args.precond_dtype)
    if pdtype is not None and pdtype.itemsize > dtype.itemsize:
        ap.error(
            f"--precond-dtype {pdtype.name} is wider than --dtype "
            f"{dtype.name}; mixed precision narrows the preconditioner"
        )
    variant = args.cg_variant or (
        "flexible" if pdtype is not None and pdtype != dtype else "standard"
    )
    grid = ProcessGrid(factor3(ranks))
    mesh = make_mesh((ranks,), ("ranks",))
    local = (args.local,) * 3
    prob = build_dist_problem(args.n, grid, local, lam=1.0, dtype=dtype)
    print(f"ranks={ranks} grid={grid.shape} local={local} N={args.n} "
          f"global DOFs={prob.n_global:,} halo elems/rank={prob.halo_elems}/{prob.e_local} "
          f"precond={args.precond}")

    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal((ranks, prob.m3)), dtype)
    # estimate the Chebyshev interval once at setup so the timed runs below
    # are pure solve (dist_cg would otherwise re-run the Lanczos operator
    # applies inside every compiled call); pmg estimates per level in-graph
    lmin = lmax = None
    if args.precond == "chebyshev":
        lmin, lmax = dist_spectrum(prob, mesh, two_phase=args.two_phase)
        print(f"lanczos: spectrum(D^-1 A) ~= [{lmin:.4f}, {lmax:.4f}]")
    precond, smoother, coarse_op = args.precond, "chebyshev", "redisc"
    if precond == "pmg-schwarz":
        precond, smoother = "pmg", "schwarz"
    elif precond == "pmg-galerkin-mat":
        precond, coarse_op = "pmg", "galerkin_mat"
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=args.iters, tol=args.tol,
                          precond=precond, cheb_degree=args.cheb_degree,
                          pmg_smoother=smoother, pmg_coarse_op=coarse_op,
                          lmin=lmin, lmax=lmax,
                          precond_dtype=pdtype, cg_variant=variant,
                          two_phase=args.two_phase, record_history=True,
                          fused_operator=args.fused_operator or None,
                          exchange=args.exchange))
    plan = getattr(getattr(run, "__wrapped__", run), "exchange_plan", None)
    if plan is not None:
        if plan.sites:
            for rec in plan.records():
                print(f"exchange plan: {rec['site']:>12} -> {rec['routing']}"
                      f"/{rec['wire_dtype'] or 'native'}"
                      + (" (cached)" if rec["from_cache"] else ""))
        else:
            print(f"exchange plan: policy {plan.policy!r} pinned at every site")
    x, rdotr, iters, status, hist = run()
    jax.block_until_ready(x)
    t0 = time.perf_counter()
    x, rdotr, iters, status, hist = run()
    jax.block_until_ready(x)
    dt = time.perf_counter() - t0

    n_done = int(iters)
    print(f"status: {status_name(status)}")
    e_tot = ranks * prob.e_local
    fom = nekbone_flops_per_iter(e_tot, args.n) * n_done / dt / 1e9
    print(f"{n_done} CG iters in {dt:.3f}s -> FOM {fom:.2f} GFLOPS "
          f"({fom/ranks:.2f}/rank)  final r.r={float(rdotr):.3e}")
    h = np.asarray(hist)[:max(n_done, 1)]
    print(f"residual: {h[0]:.3e} -> {h[-1]:.3e} over {n_done} iters")


if __name__ == "__main__":
    main()
