"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_problem
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 2, 3, 5, 7, 9, 15])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_poisson_kernel_matches_oracle(n, dtype, rng):
    shape = (2, 2, 2) if n > 7 else (3, 2, 2)
    prob = build_problem(n, shape, lam=1.3, deform=0.1, dtype=dtype)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), dtype)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=1.3)
    got = ops.poisson_local(
        u, prob.g, prob.w_local, prob.d, lam=1.3, interpret=True
    )
    scale = float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) / scale < 3e-6


@pytest.mark.parametrize("block_e", [1, 2, 4, 8])
def test_poisson_kernel_block_sweep(block_e, rng):
    prob = build_problem(4, (3, 1, 1), lam=0.5, deform=0.05, dtype=jnp.float32)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), jnp.float32)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=0.5)
    got = ops.poisson_local(
        u, prob.g, prob.w_local, prob.d, lam=0.5, block_e=block_e, interpret=True
    )
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("coefficient", ["smooth", "checker"])
@pytest.mark.parametrize("deform", [0.0, 0.15])
def test_poisson_kernel_variable_coefficient_fp64(coefficient, deform, rng):
    """Variable k(x)/λ(x) reach the Pallas kernel only through the folded
    g factors and the mass-weighted w stream (``screen_stream``) — parity
    with the jnp oracle stays at fp64 round-off, deformed coords included."""
    import jax

    from repro.core.operator import screen_stream

    jax.config.update("jax_enable_x64", True)
    prob = build_problem(
        4, (2, 2, 2), lam=0.7, deform=deform, dtype=jnp.float64,
        coefficient=coefficient, bc="mixed",
    )
    w_eff, lam_eff = screen_stream(prob)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), jnp.float64)
    want = ref.poisson_local_ref(u, prob.g, w_eff, prob.d, lam=lam_eff)
    got = ops.poisson_local(
        u, prob.g, w_eff, prob.d, lam=lam_eff, interpret=True
    )
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel <= 1e-12


def test_poisson_kernel_bf16(rng):
    prob = build_problem(3, (2, 2, 2), lam=1.0, dtype=jnp.bfloat16)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), jnp.bfloat16)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=1.0)
    got = ops.poisson_local(u, prob.g, prob.w_local, prob.d, lam=1.0, interpret=True)
    assert got.dtype == jnp.bfloat16
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32))))
    err = float(jnp.max(jnp.abs((got - want).astype(jnp.float32))))
    assert err / scale < 0.05  # bf16 tolerance


def test_vmem_budget_picks_smaller_blocks():
    from repro.kernels.poisson import pick_block_e, vmem_bytes_per_block

    assert pick_block_e(15) <= pick_block_e(7) or pick_block_e(7) == 256
    for n in (7, 15):
        eb = pick_block_e(n)
        assert vmem_bytes_per_block(eb, n + 1) <= 4 * 2**20


@pytest.mark.parametrize("n", [64, 128, 1000, 128 * 9, 40000])
def test_stream_kernels_match_oracle(n, rng):
    r = jnp.asarray(rng.standard_normal(n), jnp.float32)
    ap = jnp.asarray(rng.standard_normal(n), jnp.float32)
    alpha = jnp.float32(0.37)
    rn, rr = ops.fused_axpy_dot(r, ap, alpha, interpret=True)
    rn2, rr2 = ref.fused_axpy_dot_ref(r, ap, alpha)
    np.testing.assert_allclose(np.array(rn), np.array(rn2), atol=1e-6)
    assert abs(float(rr - rr2)) / float(rr2) < 1e-5

    out = ops.fused_xpay(r, ap, alpha, interpret=True)
    np.testing.assert_allclose(
        np.array(out), np.array(ref.fused_xpay_ref(r, ap, alpha)), atol=1e-6
    )

    w = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    wd = ops.weighted_dot(w, r, ap, interpret=True)
    wd2 = ref.weighted_dot_ref(w, r, ap)
    assert abs(float(wd - wd2)) <= 1e-4 * abs(float(wd2)) + 1e-4


@pytest.mark.parametrize("b,n", [(1, 256), (3, 1000), (16, 128 * 9)])
def test_batched_stream_kernels_match_oracle(b, n, rng):
    r = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    ap = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(b), jnp.float32)
    beta = jnp.asarray(rng.standard_normal(b), jnp.float32)
    dinv = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)

    rn, rr = ops.fused_axpy_dot_batched(r, ap, alpha, interpret=True)
    rn2, rr2 = ref.fused_axpy_dot_batched_ref(r, ap, alpha)
    assert rn.shape == (b, n) and rr.shape == (b,)
    np.testing.assert_allclose(np.array(rn), np.array(rn2), atol=1e-6)
    np.testing.assert_allclose(np.array(rr), np.array(rr2), rtol=1e-5)

    out = ops.fused_xpay_batched(r, ap, beta, interpret=True)
    np.testing.assert_allclose(
        np.array(out), np.array(ref.fused_xpay_batched_ref(r, ap, beta)), atol=1e-6
    )

    z, rz = ops.fused_jacobi_dot_batched(dinv, r, interpret=True)
    z2, rz2 = ref.fused_jacobi_dot_batched_ref(dinv, r)
    np.testing.assert_allclose(np.array(z), np.array(z2), atol=1e-6)
    np.testing.assert_allclose(np.array(rz), np.array(rz2), rtol=1e-5)


def test_batched_stream_kernels_row_equals_unbatched(rng):
    """Each column of the 2-D layout does the unbatched kernel's arithmetic
    bit-for-bit — the property the batched solver's per-column parity
    guarantee rests on."""
    b, n = 4, 1024
    r = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    ap = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(b), jnp.float32)
    dinv = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    rn, rr = ops.fused_axpy_dot_batched(r, ap, alpha, interpret=True)
    z, rz = ops.fused_jacobi_dot_batched(dinv, r, interpret=True)
    out = ops.fused_xpay_batched(r, ap, alpha, interpret=True)
    for i in range(b):
        rn1, rr1 = ops.fused_axpy_dot(r[i], ap[i], alpha[i], interpret=True)
        assert np.array_equal(np.array(rn[i]), np.array(rn1))
        assert float(rr[i]) == float(rr1)
        z1, rz1 = ops.fused_jacobi_dot(dinv, r[i], interpret=True)
        assert np.array_equal(np.array(z[i]), np.array(z1))
        assert float(rz[i]) == float(rz1)
        out1 = ops.fused_xpay(r[i], ap[i], alpha[i], interpret=True)
        assert np.array_equal(np.array(out[i]), np.array(out1))


def test_batched_stream_kernels_pin_vmap_semantics(rng):
    """vmap of the unbatched stages (what batched_cg_assembled lowers the
    per-column fused closures through) computes exactly the explicit 2-D
    batched kernels."""
    import jax

    b, n = 3, 640
    r = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    ap = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(b), jnp.float32)
    rn_v, rr_v = jax.vmap(
        lambda r_i, ap_i, a_i: ops.fused_axpy_dot(r_i, ap_i, a_i, interpret=True)
    )(r, ap, alpha)
    rn_b, rr_b = ops.fused_axpy_dot_batched(r, ap, alpha, interpret=True)
    assert np.array_equal(np.array(rn_v), np.array(rn_b))
    assert np.array_equal(np.array(rr_v), np.array(rr_b))


def test_batched_jacobi_adapter_mixed_precision(rng):
    b, n = 2, 384
    dinv = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    r = jnp.asarray(rng.standard_normal((b, n)), jnp.float32)
    f = ops.make_fused_jacobi_dot_batched(dinv, interpret=True)
    z, rz = f(r)
    z2, rz2 = ref.fused_jacobi_dot_batched_ref(dinv, r)
    np.testing.assert_allclose(np.array(z), np.array(z2), atol=1e-6)
    np.testing.assert_allclose(np.array(rz), np.array(rz2), rtol=1e-5)


def test_assembled_operator_with_pallas_kernel(rng):
    from repro.core import poisson_assembled

    prob = build_problem(5, (2, 2, 2), lam=0.9, deform=0.12, dtype=jnp.float32)
    a_ref = poisson_assembled(prob)
    a_pl = poisson_assembled(prob, local_op=ops.make_local_op(interpret=True))
    x = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    want = a_ref(x)
    got = a_pl(x)
    scale = float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) / scale < 3e-6
