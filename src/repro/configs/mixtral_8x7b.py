"""mixtral-8x7b [moe] — arXiv:2401.04088.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, 8 experts top-2,
sliding-window attention (4096). Softmax-after-top-k router.

On a 16-way model axis the 8 experts are not EP-divisible, so the MoE runs
in ff-sharded TP mode (see models/blocks.py) — no dispatch exchange.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    sliding_window=4096,
    n_experts=8,
    experts_per_token=2,
    moe_every=1,
    router_score="softmax",
    norm_eps=1e-5,
)

REDUCED = ModelConfig(
    name="mixtral-8x7b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    sliding_window=8,
    n_experts=4,
    experts_per_token=2,
    moe_every=1,
    dtype="float32",
)
