"""Doc freshness: README quickstart snippets must execute, and every
README/docs cross-reference (links, paths, code symbols) must resolve."""
import os
import re
import subprocess
import sys


from conftest import REPO, run_subprocess

README = os.path.join(REPO, "README.md")
CHECKER = os.path.join(REPO, "scripts", "check_links.py")

PY_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def _readme_python_blocks() -> list[str]:
    with open(README) as f:
        text = f.read()
    return [m.group(1) for m in PY_FENCE.finditer(text)]


def test_readme_has_python_snippets():
    """The quickstart keeps (at least) its single-device and sharded
    fenced Python examples."""
    blocks = _readme_python_blocks()
    assert len(blocks) >= 2, f"expected >=2 python fences, got {len(blocks)}"
    joined = "\n".join(blocks)
    assert "make_preconditioner" in joined
    assert "dist_cg" in joined


def test_readme_quickstart_snippets_execute():
    """ISSUE satellite: the README's fenced Python blocks are executable
    as-is (concatenated in order, CPU, small N, 8 virtual ranks) — the
    quickstart cannot silently rot."""
    blocks = _readme_python_blocks()
    run_subprocess("\n".join(blocks), devices=8, timeout=600)


def test_docs_references_resolve():
    """scripts/check_links.py (links + the code-reference mode) passes on
    the default README/ROADMAP/docs file set."""
    proc = subprocess.run(
        [sys.executable, CHECKER],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, (
        f"dangling doc references:\n{proc.stdout}\n{proc.stderr}"
    )


def test_check_links_catches_danglers(tmp_path):
    """The checker actually fails on a broken link, a bogus identifier and
    a bogus module attribute (guards the guard).  The planted tokens are
    assembled at runtime so this test file itself (part of the checker's
    source universe) cannot satisfy them."""
    bogus_ident = "zz_" + "bogus" + "_symbol" + "_qqq"
    bogus_attr = "zz_not" + "_a_thing" + "_qqq"
    bad = tmp_path / "bad.md"
    bad.write_text(
        f"[x](docs/NOPE_does_not_exist.md) and `{bogus_ident}` "
        f"and `repro.core.precond.{bogus_attr}`\n"
    )
    proc = subprocess.run(
        [sys.executable, CHECKER, str(bad)],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 1, proc.stdout
    assert "dangling link" in proc.stdout
    assert bogus_ident in proc.stdout, proc.stdout
    assert bogus_attr in proc.stdout, proc.stdout
    # and the escape hatch skips only the code refs, not the link check
    proc2 = subprocess.run(
        [sys.executable, CHECKER, "--no-code-refs", str(bad)],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc2.returncode == 1
    assert bogus_ident not in proc2.stdout
