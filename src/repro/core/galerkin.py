"""Materialized sparse Galerkin coarse operators for p-multigrid.

PR 3's ``pmg_coarse_op="galerkin"`` builds the variationally-exact coarse
operators ``A_{l+1} = R_l A_l P_l`` as *chained* matrix-free triple
products: every coarse A-apply recurses through the transfer chain all the
way to the fine grid, re-streaming the fine geometric factors on every
V-cycle visit to every coarse level.  That is exactly the redundant data
movement the hipBone paper eliminates for the fine operator by assembling
DOF storage — and coarse levels are *latency*-bound, so paying a fine-grid
sweep per coarse apply is the worst place to spend bandwidth.

This module materializes the triple product once at setup.  Because
p-coarsening keeps the element grid (only the polynomial degree drops) and
the prolongation ``P = Z_fᵀ W_f Ĵ Z_c`` is the exact nodal embedding of
the coarse SEM space into the fine one, the chained product collapses to
an element-block operator:

    PᵀAP = Z_cᵀ [ Ĵᵀ (S_L^e + λ W_e) Ĵ ] Z_c

— one dense (N_c+1)³ × (N_c+1)³ block per element, the standard FEM
sparsity (coarse DOFs couple only through shared elements).  The identity
behind the collapse: ``Ĵ Z_c x`` is a *continuous* element-local field
(adjacent elements interpolate identical shared-face values, because a
face value of the tensor-product interpolant depends only on that face's
coarse values), so the fine-level averaging gather-scatter inside the
chain is transparent to it,

    Z_f Z_fᵀ W_f (Ĵ Z_c) = Ĵ Z_c        (since Z_fᵀ W_f Z_f = I),

and both ``Z_f Z_fᵀ W_f`` factors of the expanded triple product cancel.
The identity is purely topological — it holds on deformed meshes and for
any SPD per-element operator, so deeper ladder rungs coarsen the *blocks*
directly (``B_{l+1} = Ĵᵀ B_l Ĵ``, :func:`coarsen_element_blocks`) without
ever touching the fine operator again.

Setup probes the fine element-local operator with the (N_c+1)³ lifted
coarse basis columns (``operator.local_operator_columns``) — a handful of
batched fine applies, once.  Apply time is scatter → one batched dense
element matvec → gather: **zero fine-operator applies per coarse apply**,
the same dataflow (and, sharded, the same single sum-exchange) as any
rediscretized level, but variationally exact.  The sharded path assembles
each rank's owned element blocks locally — ``w_local`` already carries the
global inverse degree, so no setup exchange is needed either
(``distributed.build_pmg_galerkin_blocks``).
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import sem
from .gather_scatter import gather, scatter
from .operator import local_operator_columns

__all__ = [
    "tensor3_interp_matrix",
    "galerkin_element_blocks",
    "coarsen_element_blocks",
    "galerkin_ladder_blocks",
    "block_matvec_einsum",
    "galerkin_block_apply",
    "galerkin_assembled_diagonal",
]


def tensor3_interp_matrix(j: np.ndarray) -> np.ndarray:
    """The 3-D tensor-product lift Ĵ = J ⊗ J ⊗ J as a dense matrix.

    ``j``: (n_out+1, n_in+1) 1-D interpolation matrix.  Node order is
    (t, s, r) with r fastest, matching ``local_poisson`` /
    ``precond.tensor3_interp`` — the rightmost Kronecker factor acts on r.
    Setup-time numpy; the result is tiny (≤ a few k per side).
    """
    return np.kron(np.kron(j, j), j)


def _symmetrize(blocks: jax.Array) -> jax.Array:
    """Remove probing roundoff asymmetry so PCG symmetry holds exactly."""
    return 0.5 * (blocks + blocks.transpose(0, 2, 1))


def galerkin_element_blocks(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    n_coarse: int,
) -> jax.Array:
    """Dense per-element Galerkin blocks ``Ĵᵀ (S_L^e + λW_e) Ĵ``.

    Batched probing of the chained triple product: the (N_c+1)³ columns of
    the lift Ĵ are pushed through the fine element-local operator
    (``local_operator_columns``) and contracted back with Ĵᵀ.  By the
    embedding identity in the module docstring the result assembles (via
    ``Z_cᵀ · Z_c``) to exactly ``PᵀAP`` on coarse DOFs.

    Args:
      g: (E, 6, p_f) fine geometric factors.
      d: (N_f+1, N_f+1) fine 1-D derivative matrix.
      lam: screen parameter λ.
      w: (E, p_f) fine inverse-degree weights (the hipBone λW screen) or
        None for the λI screen.
      n_coarse: coarse polynomial degree N_c < N_f.

    Returns:
      (E, p_c, p_c) symmetric blocks, p_c = (N_c+1)³, in ``g``'s dtype —
      assembled once in fp32 when the caller probes a cast problem (the
      mixed-precision path).
    """
    n_fine = d.shape[0] - 1
    jhat = jnp.asarray(
        tensor3_interp_matrix(sem.interpolation_matrix(n_coarse, n_fine)),
        g.dtype,
    )
    cols = local_operator_columns(g, d, lam, w, jhat)    # (E, p_f, p_c)
    return _symmetrize(jnp.einsum("pj,epk->ejk", jhat, cols))


def coarsen_element_blocks(blocks: jax.Array, j: np.ndarray) -> jax.Array:
    """Next-rung blocks ``B_{l+1,e} = Ĵᵀ B_{l,e} Ĵ`` — no operator probes.

    ``j``: (n_f+1, n_c+1) 1-D interpolation between the two ladder levels
    (``sem.interpolation_matrix(n_c, n_f)``).  Two batched contractions of
    already-materialized blocks; the fine grid is never revisited.
    """
    jhat = jnp.asarray(tensor3_interp_matrix(j), blocks.dtype)
    return _symmetrize(jnp.einsum("pj,epq,qk->ejk", jhat, blocks, jhat))


def galerkin_ladder_blocks(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
    degrees: Sequence[int],
) -> list[jax.Array]:
    """Materialized blocks for every coarse rung of a degree ladder.

    ``degrees[0]`` is the fine degree (of ``g``/``d``/``w``); the returned
    list holds one (E, p_c, p_c) block stack per coarse degree
    ``degrees[1:]``.  The fine operator is probed exactly once (for
    ``degrees[1]``); deeper rungs contract the previous rung's blocks.
    """
    degrees = tuple(int(n) for n in degrees)
    if len(degrees) < 2:
        raise ValueError(f"galerkin ladder needs >= 2 levels, got {degrees}")
    out = [galerkin_element_blocks(g, d, lam, w, degrees[1])]
    for nf, nc in zip(degrees[1:], degrees[2:]):
        out.append(
            coarsen_element_blocks(out[-1], sem.interpolation_matrix(nc, nf))
        )
    return out


def block_matvec_einsum(blocks: jax.Array, u: jax.Array) -> jax.Array:
    """Reference batched element matvec ``y_e = B_e u_e`` (XLA einsum).

    XLA lowers this to one batched MXU matmul; ``kernels.ops.block_matvec``
    is the explicit Pallas variant with the same contract.
    """
    return jnp.einsum("eij,ej->ei", blocks, u)


def galerkin_block_apply(
    blocks: jax.Array,
    l2g: jax.Array | np.ndarray,
    n_global: int,
    *,
    matvec: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """Assembled coarse-operator apply ``x → Z_cᵀ [B_e (Z_c x)_e]``.

    Single-device form: scatter, one batched dense element matvec, gather —
    no fine-operator work.  ``matvec`` lets callers swap in the Pallas
    batched matvec (``kernels.ops.block_matvec``); default is the einsum.
    The sharded analogue (halo/interior split + sum-exchange) is
    ``distributed._box_galerkin_apply``.
    """
    mv = matvec or block_matvec_einsum
    l2g = jnp.asarray(l2g)

    def apply(x_c: jax.Array) -> jax.Array:
        return gather(mv(blocks, scatter(x_c, l2g)), l2g, n_global)

    return apply


def galerkin_assembled_diagonal(
    blocks: jax.Array, l2g: jax.Array | np.ndarray, n_global: int
) -> jax.Array:
    """Exact assembled diagonal of the materialized Galerkin operator.

    ``diag(Z_cᵀ B Z_c)`` = gather of the per-element block diagonals.  The
    pMG smoothers keep the *rediscretized* diagonal by default (the
    standard spectrally-equivalent choice, and what keeps ``galerkin_mat``
    iteration-identical to the chained form); this exact diagonal is
    exposed for experimentation and used by tests as an independent
    cross-check of the block assembly.
    """
    diag_loc = jnp.diagonal(blocks, axis1=1, axis2=2)
    return gather(diag_loc, jnp.asarray(l2g), n_global)
