"""Pallas TPU kernel for the batched dense element-block matvec.

The materialized Galerkin coarse operator (``core.galerkin``) applies one
dense (p_c, p_c) block per element: ``y_e = B_e u_e``.  XLA's batched
einsum already lowers this to MXU matmuls; this kernel is the explicit
streaming form for the non-interpret TPU path, matching the repo's other
kernels: grid over *blocks of elements*, each step DMAs a
``(block_e, p, p)`` tile of stencil blocks plus its ``(block_e, p)`` input
tile HBM→VMEM, performs one element-batched ``dot_general`` (the element
batch rides the dot's batch dimension, so the MXU sees p×p matmuls back to
back), and writes the single output tile.  Coarse levels are
latency-bound, so the single-pass traffic bound — every block byte read
exactly once per apply — is the point.

The VMEM knob is ``block_e``; blocks dominate the footprint at
``block_e · p² · word`` bytes, so deep-ladder levels (p ≤ 125) batch many
elements per step while the widest coarse level (p = 729 on the N=15
ladder) streams element by element.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["block_matvec_pallas", "pick_block_matvec_e"]


def _kernel(b_ref, u_ref, out_ref):
    """One grid step: y_e = B_e u_e for block_e elements resident in VMEM."""
    b = b_ref[...]          # (Eb, p, p)
    u = u_ref[...]          # (Eb, p)
    acc = jnp.promote_types(u.dtype, jnp.float32)
    # element-batched matvec: batch dim 0, contract B's j with u's j
    y = jax.lax.dot_general(
        b.astype(acc), u.astype(acc),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=acc,
    )
    out_ref[...] = y.astype(out_ref.dtype)


def pick_block_matvec_e(
    p: int, dtype=jnp.float32, budget_bytes: int = 4 * 2**20
) -> int:
    """Largest power-of-two element batch whose tiles fit the VMEM budget.

    The 4 MB default leaves room for Mosaic's double-buffered pipelining,
    like ``kernels.poisson.pick_block_e``; the block tile (p² words/elt)
    dominates u/y (p words each).
    """
    word = jnp.dtype(dtype).itemsize
    eb = 256
    while eb > 1 and eb * (p * p + 2 * p) * word > budget_bytes:
        eb //= 2
    return eb


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def block_matvec_pallas(
    blocks: jax.Array,
    u: jax.Array,
    *,
    block_e: int,
    interpret: bool = True,
) -> jax.Array:
    """y[e] = blocks[e] @ u[e].  Shapes: (E, p, p), (E, p) -> (E, p).

    ``E`` must be a multiple of ``block_e`` (callers pad, see
    ``kernels.ops.block_matvec``).
    """
    e, p, _ = blocks.shape
    assert e % block_e == 0, (e, block_e)
    grid = (e // block_e,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, p, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_e, p), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, p), u.dtype),
        interpret=interpret,
    )(blocks, u)
