"""Fig. 3 reproduction: fused Poisson operator performance vs degree N.

The paper measures GFLOPS of the operator kernel for N = 1..15 on three
GPUs against an empirically calibrated streaming roofline (Eq. 4). Here:
  * measured: wall-clock of the jit'd operator on THIS host (CPU), with an
    empirically measured CPU streaming bandwidth calibrating the same
    roofline form — the paper's methodology, ported to the host we have;
  * modeled: the TPU-v5e roofline targets (197 TF peak / 819 GB/s HBM)
    that §Roofline uses for the dry-run cells.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_problem, fom
from repro.core.operator import local_poisson
from repro.kernels import ops


def _time(f, *args, reps=5) -> float:
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps


def measure_stream_bandwidth() -> float:
    """Empirical streaming rate with the paper's 8:1 read:write kernel shape."""
    n = 4 * 2**20
    xs = [jnp.arange(n, dtype=jnp.float32) + i for i in range(8)]

    @jax.jit
    def stream(*arrs):
        return sum(arrs)

    dt = _time(stream, *xs)
    return 9 * n * 4 / dt  # 8 reads + 1 write


def main(quick: bool = True) -> list[str]:
    rows = ["fig3,N,dofs,elements,cpu_us,cpu_gflops,cpu_roofline_gflops,tpu_roofline_gflops,ai_f32"]
    bw = measure_stream_bandwidth()
    target_dofs = 80_000 if quick else 2_000_000
    for n in range(1, 16):
        # mesh sized to ~target DOFs (paper: fixed ~40M per degree)
        e_per_dim = max(2, round((target_dofs / n**3) ** (1 / 3)))
        shape = (e_per_dim,) * 3
        prob = build_problem(n, shape, lam=1.0, dtype=jnp.float32)
        e = prob.mesh.n_elements
        u = jnp.ones((e, prob.mesh.points_per_element), jnp.float32)

        op = jax.jit(
            lambda u, g, d, w: local_poisson(u, g, d, 1.0, w)
        )
        dt = _time(op, u, prob.g, prob.d, prob.w_local)
        flops = fom.operator_flops(e, n)
        ai = flops / fom.operator_bytes(e, n, word=4)
        cpu_gflops = flops / dt / 1e9
        cpu_roof = min(
            # CPU peak unknown; streaming bound is the relevant arm
            1e12, ai * bw
        ) / 1e9
        tpu_roof = fom.roofline_gflops(
            n, peak_gflops=197_000, bandwidth_gbs=819, word=4
        )
        rows.append(
            f"fig3,{n},{prob.n_global},{e},{dt*1e6:.0f},{cpu_gflops:.2f},"
            f"{cpu_roof:.2f},{tpu_roof:.0f},{ai:.3f}"
        )
    rows.append(f"fig3_meta,stream_bw_gbs,{bw/1e9:.2f}")
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
