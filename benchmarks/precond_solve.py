"""Preconditioned-solve benchmark: iterations-to-tolerance and FOM.

Beyond the NekBone 100-fixed-iteration benchmark: solve λ-screened deformed
Poisson problems to ``tol=1e-8`` with each rung of the preconditioner
ladder — none / jacobi / chebyshev / schwarz / pmg (Chebyshev-smoothed) /
pmg-schwarz (Schwarz-smoothed) / pmg-galerkin (exact PᵀAP coarse
operators) — and report

  * iterations to tolerance (the preconditioner-quality signal),
  * wall time, and the *effective* FOM GFLOPS (NekBone flop model ×
    iterations / time) — Chebyshev pays extra operator applies per
    iteration, Schwarz pays per-element extended-block FDM solves, and the
    pMG V-cycle pays a whole smoothing hierarchy, so fewer iterations must
    buy back the per-iteration cost to win wall-clock.

Degrees follow the paper's sweep corners: N ∈ {3, 7, 9, 15} (quick: {3, 7}),
deform=0.15 so Jacobi has a non-trivial diagonal to chew on.  Solves run in
float64 (tol=1e-8 sits below what fp32 CG can resolve).  Acceptance tiers
(tests/test_schwarz.py, tests/test_pmg.py): at N=7, λ=1.0 pmg reaches tol
in ≤ half the chebyshev iterations; at N=7, λ=0.1 (the ill-conditioned
regime Schwarz targets) pmg-schwarz and pmg-galerkin each need ≤ the plain
pmg count.

``main`` returns CSV rows; ``records`` returns the same data as dicts for
the machine-readable BENCH json emitted by ``benchmarks.run``.
"""
from __future__ import annotations

import time

# ladder order: cost per application rises, iterations-to-tol falls
PRECONDS = (
    "none",
    "jacobi",
    "chebyshev",
    "schwarz",
    "pmg",
    "pmg-schwarz",
    "pmg-galerkin",
)
# kind -> (make_preconditioner kind, extra kwargs)
PRECOND_RECIPES = {
    "none": ("none", {}),
    "jacobi": ("jacobi", {}),
    "chebyshev": ("chebyshev", {"degree": 2}),
    "schwarz": ("schwarz", {}),
    "pmg": ("pmg", {}),
    "pmg-schwarz": ("pmg", {"pmg_smoother": "schwarz"}),
    "pmg-galerkin": ("pmg", {"pmg_coarse_op": "galerkin"}),
}
TOL = 1e-8


def _solve_case(n: int, shape, lam: float, tol: float):
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build_problem, cg_assembled, poisson_assembled
    from repro.core.fom import nekbone_flops_per_iter
    from repro.core.precond import make_preconditioner

    prob = build_problem(n, shape, lam=lam, deform=0.15, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float64)
    e = prob.mesh.n_elements

    out = []
    for name in PRECONDS:
        kind, kwargs = PRECOND_RECIPES[name]
        pc, info = make_preconditioner(kind, prob, a, **kwargs)
        solve = jax.jit(
            lambda bb, pc=pc: cg_assembled(a, bb, n_iter=500, tol=tol, precond=pc)
        )
        res = solve(b)
        jax.block_until_ready(res.x)
        t0 = time.perf_counter()
        res = solve(b)
        jax.block_until_ready(res.x)
        dt = time.perf_counter() - t0
        iters = int(res.iterations)
        fom = nekbone_flops_per_iter(e, n) * iters / dt / 1e9
        out.append(
            {
                "n": n,
                "dofs": prob.n_global,
                "lam": lam,
                "kind": name,
                "iters_to_tol": iters,
                "time_s": dt,
                "fom_gflops": fom,
                "lmax": info.lmax,
                "lmin": info.lmin,
                "levels": None if info.levels is None else list(info.levels),
            }
        )
    return out


def records(quick: bool = True) -> list[dict]:
    """Structured sweep results (one dict per (N, λ, precond) case)."""
    degrees = [3, 7] if quick else [3, 7, 9, 15]
    shapes = {3: (4, 4, 4), 7: (4, 4, 4), 9: (3, 3, 3), 15: (2, 2, 2)}
    recs: list[dict] = []
    for n in degrees:
        for lam in (0.1, 1.0):
            recs.extend(_solve_case(n, shapes[n], lam, tol=TOL))
    return recs


def rows_from(recs: list[dict]) -> list[str]:
    """CSV rows for a list of :func:`records` results."""
    rows = [
        "precond,N,dofs,lam,kind,iters_to_tol,time_s,fom_gflops,"
        "cheb_lmax,cheb_lmin,pmg_levels"
    ]
    for r in recs:
        lmax = "" if r["lmax"] is None else f"{r['lmax']:.3f}"
        lmin = "" if r["lmin"] is None else f"{r['lmin']:.3f}"
        levels = "" if r["levels"] is None else "-".join(map(str, r["levels"]))
        rows.append(
            f"precond,{r['n']},{r['dofs']},{r['lam']},{r['kind']},"
            f"{r['iters_to_tol']},{r['time_s']:.4f},{r['fom_gflops']:.2f},"
            f"{lmax},{lmin},{levels}"
        )
    return rows


def main(quick: bool = True):
    return rows_from(records(quick))


if __name__ == "__main__":
    print("\n".join(main(quick=False)))
