"""Shared fixtures. NOTE: no XLA_FLAGS here — unit tests and benches see the
real single CPU device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a fresh process with N fake CPU devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
