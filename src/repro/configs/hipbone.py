"""hipBone — the paper's own benchmark as a selectable 'architecture'.

Shapes follow the paper's scaling studies: degree N=7 (3-D-threadblock
regime) and N=15 (2-D regime / peak-FOM degree), with per-rank element
boxes sized so the per-rank DOF counts bracket the paper's sweep. These
cells are EXTRA, beyond the 40 assigned LM cells.

Knob validation lives in ``PoissonConfig.__post_init__``: invalid values
and invalid *combinations* raise immediately with the offending knob named
(rather than surfacing as a deep-stack solver failure), and legal-but-
suspect combinations emit a `ConfigWarning` (see its docstring for the
list).
"""
import dataclasses
import warnings

from repro.core.coefficients import COEFFICIENTS
from repro.core.mesh import normalize_bc

__all__ = ["PoissonConfig", "ConfigWarning", "CONFIGS"]


class ConfigWarning(UserWarning):
    """A legal but suspect knob combination.

    Emitted (never raised) by ``PoissonConfig.__post_init__`` for:

    * ``precond_dtype`` narrower than ``dtype`` with
      ``cg_variant="standard"`` — a narrowed M⁻¹ is only approximately
      symmetric in the solve dtype, which the Fletcher–Reeves β assumes
      exactly; pair narrowed chains with ``cg_variant="flexible"`` (the
      Polak–Ribière β) or expect extra iterations /
      BREAKDOWN_INDEFINITE statuses near the tolerance
      (docs/SOLVERS.md, Mixed precision).
    """


@dataclasses.dataclass(frozen=True)
class PoissonConfig:
    name: str
    n_degree: int
    local_elems: tuple[int, int, int]   # elements per rank
    lam: float = 1.0
    n_iter: int = 100                   # NekBone's fixed CG iteration count
    dtype: str = "float32"
    # operator generalization knobs (core.coefficients / core.mesh):
    # coefficient selects the diffusion/screen family for
    # A = -∇·(k(x)∇) + λ(x) — "const" is the legacy constant-λ screen
    # (bit-identical builds), "smooth" a C∞ k ∈ [½, 3/2], "checker" a
    # per-element octant jump of ratio CHECKER_RHO.  bc is a boundary-
    # condition spec accepted by mesh.normalize_bc: None (legacy, no
    # essential BCs), "dirichlet"/"neumann"/"mixed", or a 6-tuple of
    # per-face tags (-x, +x, -y, +y, -z, +z).
    coefficient: str = "const"
    bc: str | tuple | None = None
    # preconditioner ladder rung: "none" (NekBone-faithful plain CG),
    # "jacobi" (assembled-diagonal scale), "chebyshev" (degree-`cheb_degree`
    # Chebyshev–Jacobi on the Lanczos-estimated [λ_min, λ_max] interval),
    # "schwarz" (overlapping element-block FDM solves, symmetric weighted
    # additive Schwarz — the robust rung for deformed/ill-conditioned
    # meshes), or "pmg" (p-multigrid V-cycle N → ⌈N/2⌉ → … → 1, the
    # production Nek5000/RS configuration).
    precond: str = "none"
    cheb_degree: int = 2                # standalone Chebyshev polynomial degree
    tol: float | None = None            # None = fixed n_iter (NekBone mode)
    # pmg knobs: per-level smoother degree (Chebyshev order of the pre/post
    # smoothing sweeps; None = per-smoother default), the smoother base
    # ("chebyshev" = Chebyshev–Jacobi, "schwarz" = Chebyshev-accelerated
    # overlapping Schwarz), the coarse-operator construction ("redisc"
    # rediscretizes, "galerkin" = exact P^T A P chained matrix-free,
    # single-device only, "galerkin_mat" = the same triple products
    # materialized at setup into per-element blocks — sharded-capable,
    # zero fine-operator work per coarse apply), and the degree of the
    # full-interval Chebyshev solve on the coarsest (N=1) ladder level.
    pmg_smooth_degree: int | None = None
    pmg_smoother: str = "chebyshev"
    pmg_coarse_op: str = "redisc"
    pmg_coarse_iters: int = 16
    # schwarz knobs: overlap width in GLL nodes (0 = FDM block Jacobi) and
    # the Chebyshev degree of the in-eigenbasis block solve (the algebraic
    # screen λI breaks pure tensor structure; higher = closer to exact
    # block inverses at ~linear extra cost per application).
    schwarz_overlap: int = 1
    schwarz_inner_degree: int = 7
    # mixed precision: compute dtype of the whole preconditioner chain
    # (None = dtype).  "float32" inside a float64 solve halves
    # preconditioner HBM/wire traffic (the production Nek5000/RS trick);
    # pair it with cg_variant="flexible" — the fp32 M⁻¹ is only
    # approximately symmetric in fp64 arithmetic.
    precond_dtype: str | None = None
    cg_variant: str = "standard"        # "standard" (FR β) | "flexible" (PR β)
    # fused assembled operator: True forces the single-kernel Pallas apply
    # (kernels/poisson_fused.py — gather, local operator and scatter-add in
    # one pass, interior block only under sharding), False pins the split
    # scatter/local/gather pipeline, None defers to the backend policy
    # (kernels.ops.should_fuse_operator; HIPBONE_FUSED=0/1 overrides).
    fused_operator: bool | None = None
    # halo-exchange routing policy for sharded solves (comms.plan):
    # "auto" times face_sweep/crystal/fused per exchange site at setup and
    # records the winners (persisted per content signature), a named
    # routing pins every site, None defers to HIPBONE_EXCHANGE (default
    # auto-less face_sweep).  Pure performance knob: iteration counts are
    # identical under every choice.  Single-device solves ignore it.
    exchange: str | None = None
    # multi-RHS serving: how many right-hand sides one solver dispatch
    # carries (core.cg.batched_cg_assembled / serving.SolverEngine slot
    # width).  1 = the classic single-column solve; the batched-solve
    # benchmark sweeps {1, 4, 16} to show setup amortization.
    batch_rhs: int = 1
    # solver guardrails (core.cg.SolveStatus): DIVERGED above
    # divergence_factor·rdotr₀ (squared-norm semantics), STAGNATED after
    # stagnation_window iterations without a stagnation_rtol relative
    # reduction of the best-seen rdotr.  None disables that detector.
    # Defaults mirror core.cg's module constants (tests pin the equality).
    divergence_factor: float | None = 1e6
    stagnation_window: int | None = 50
    stagnation_rtol: float = 0.99

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"PoissonConfig {self.name!r}: {msg}")

        if self.n_degree < 1:
            bad(f"n_degree must be >= 1, got {self.n_degree}")
        if len(self.local_elems) != 3 or any(
            e < 1 for e in self.local_elems
        ):
            bad(
                f"local_elems must be three positive counts, "
                f"got {self.local_elems!r}"
            )
        if not self.lam > 0:
            bad(f"lam must be > 0 (screened operator is SPD), got {self.lam}")
        if self.n_iter < 1:
            bad(f"n_iter must be >= 1, got {self.n_iter}")
        if self.tol is not None and not self.tol > 0:
            bad(f"tol must be > 0 (or None for fixed-count), got {self.tol}")
        if self.dtype not in ("float32", "float64"):
            bad(f"unknown dtype {self.dtype!r}; use 'float32' or 'float64'")
        if self.coefficient not in COEFFICIENTS:
            bad(
                f"unknown coefficient {self.coefficient!r}; "
                f"choose from {COEFFICIENTS}"
            )
        try:
            normalize_bc(self.bc)
        except ValueError as e:
            bad(f"invalid bc spec: {e}")
        if self.coefficient == "checker" and any(
            e % 2 for e in self.local_elems
        ):
            warnings.warn(
                f"PoissonConfig {self.name!r}: coefficient='checker' with "
                f"odd local_elems {self.local_elems!r} — the octant jump "
                "planes at x/y/z = ½ only land on element boundaries when "
                "the per-axis *global* element counts are even; make sure "
                "the process grid restores evenness",
                ConfigWarning,
                stacklevel=3,
            )
        if self.precond not in ("none", "jacobi", "chebyshev", "schwarz", "pmg"):
            bad(f"unknown precond {self.precond!r}")
        if self.cheb_degree < 1:
            bad(f"cheb_degree must be >= 1, got {self.cheb_degree}")
        if self.pmg_smoother not in ("chebyshev", "schwarz"):
            bad(f"unknown pmg_smoother {self.pmg_smoother!r}")
        if self.pmg_coarse_op not in ("redisc", "galerkin", "galerkin_mat"):
            bad(f"unknown pmg_coarse_op {self.pmg_coarse_op!r}")
        if self.pmg_coarse_iters < 1:
            bad(f"pmg_coarse_iters must be >= 1, got {self.pmg_coarse_iters}")
        if self.precond == "pmg" and self.n_degree < 2:
            bad(
                "precond='pmg' needs n_degree >= 2 — the degree ladder "
                f"N → ⌈N/2⌉ → … → 1 has a single level at N={self.n_degree}"
            )
        if not 0 <= self.schwarz_overlap <= max(self.n_degree - 1, 0):
            bad(
                f"schwarz_overlap must be in [0, n_degree-1] = "
                f"[0, {self.n_degree - 1}], got {self.schwarz_overlap} "
                "(the overlap shell cannot exceed one element's interior)"
            )
        if self.schwarz_inner_degree < 1:
            bad(
                f"schwarz_inner_degree must be >= 1, "
                f"got {self.schwarz_inner_degree}"
            )
        if self.precond_dtype not in (None, "float32", "float64"):
            bad(f"unknown precond_dtype {self.precond_dtype!r}")
        if self.precond_dtype is not None and self.precond == "none":
            bad(
                "precond_dtype set with precond='none' — there is no "
                "preconditioner chain to cast; drop precond_dtype or pick "
                "a rung"
            )
        if self.cg_variant not in ("standard", "flexible"):
            bad(f"unknown cg_variant {self.cg_variant!r}")
        if not isinstance(self.fused_operator, (bool, type(None))):
            bad(
                f"fused_operator must be None/True/False, "
                f"got {self.fused_operator!r}"
            )
        if self.exchange not in (None, "auto", "face_sweep", "crystal", "fused"):
            bad(
                f"unknown exchange {self.exchange!r}; use 'auto', "
                "'face_sweep', 'crystal', 'fused', or None "
                "(= HIPBONE_EXCHANGE env)"
            )
        if self.batch_rhs < 1:
            bad(f"batch_rhs must be >= 1, got {self.batch_rhs}")
        if self.divergence_factor is not None and not self.divergence_factor > 1:
            bad(
                f"divergence_factor must be > 1 (or None to disable), "
                f"got {self.divergence_factor}"
            )
        if self.stagnation_window is not None and self.stagnation_window < 1:
            bad(
                f"stagnation_window must be >= 1 (or None to disable), "
                f"got {self.stagnation_window}"
            )
        if not 0 < self.stagnation_rtol <= 1:
            bad(
                f"stagnation_rtol must be in (0, 1], "
                f"got {self.stagnation_rtol}"
            )
        if (
            self.precond_dtype is not None
            and self.precond_dtype != self.dtype
            and self.cg_variant == "standard"
        ):
            warnings.warn(
                f"PoissonConfig {self.name!r}: precond_dtype="
                f"{self.precond_dtype!r} with cg_variant='standard' — the "
                "narrowed M⁻¹ is only approximately symmetric in the solve "
                "dtype, which the Fletcher–Reeves β assumes exactly; use "
                "cg_variant='flexible' (see ConfigWarning)",
                ConfigWarning,
                stacklevel=3,
            )

    def dofs_per_rank(self) -> int:
        n = self.n_degree
        bx, by, bz = self.local_elems
        return bx * by * bz * n**3

    def problem_kwargs(self) -> dict:
        """This spec's operator knobs as ``core.build_problem`` kwargs.

        ``coefficient="const"`` maps to ``None`` (the legacy sentinel —
        ``build_problem`` then skips the field machinery entirely and the
        build is bit-identical to pre-coefficient configs).
        """
        return {
            "coefficient": (
                None if self.coefficient == "const" else self.coefficient
            ),
            "bc": self.bc,
        }

    def precond_kwargs(self) -> dict:
        """This spec's rung as ``core.precond.make_preconditioner`` kwargs.

        The translation the solver service (``repro.launch.serve``) and
        the setup-cache key (``core.precond.precond_signature``) share —
        only knobs relevant to the selected rung are emitted, so two
        configs differing in an inert knob map to the same setup.
        """
        if self.precond == "none":
            return {}
        kw: dict = {}
        if self.precond == "chebyshev":
            kw["degree"] = self.cheb_degree
        elif self.precond == "pmg":
            kw.update(
                pmg_smooth_degree=self.pmg_smooth_degree,
                pmg_smoother=self.pmg_smoother,
                pmg_coarse_op=self.pmg_coarse_op,
                pmg_coarse_iters=self.pmg_coarse_iters,
            )
            if self.pmg_smoother == "schwarz":
                kw.update(
                    schwarz_overlap=self.schwarz_overlap,
                    schwarz_inner_degree=self.schwarz_inner_degree,
                )
        elif self.precond == "schwarz":
            kw.update(
                schwarz_overlap=self.schwarz_overlap,
                schwarz_inner_degree=self.schwarz_inner_degree,
            )
        if self.precond_dtype is not None:
            kw["precond_dtype"] = self.precond_dtype
        return kw


CONFIGS = {
    "hipbone_n7": PoissonConfig("hipbone_n7", 7, (8, 8, 8)),      # ~176k DOF/rank
    "hipbone_n7_large": PoissonConfig("hipbone_n7_large", 7, (16, 16, 16)),
    "hipbone_n15": PoissonConfig("hipbone_n15", 15, (4, 4, 4)),   # ~216k DOF/rank
    "hipbone_n15_large": PoissonConfig("hipbone_n15_large", 15, (8, 8, 8)),
    # beyond-the-benchmark: production-style preconditioned solves to tol
    "hipbone_n7_pcg": PoissonConfig(
        "hipbone_n7_pcg", 7, (8, 8, 8), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n15_pcg": PoissonConfig(
        "hipbone_n15_pcg", 15, (4, 4, 4), precond="chebyshev", tol=1e-6
    ),
    "hipbone_n7_pmg": PoissonConfig(
        "hipbone_n7_pmg", 7, (8, 8, 8), precond="pmg", tol=1e-6
    ),
    "hipbone_n15_pmg": PoissonConfig(
        "hipbone_n15_pmg", 15, (4, 4, 4), precond="pmg", tol=1e-6
    ),
    # the robust rung: overlapping-Schwarz FDM blocks, for the
    # ill-conditioned (small-λ / deformed-mesh) regime
    "hipbone_n7_schwarz": PoissonConfig(
        "hipbone_n7_schwarz", 7, (8, 8, 8), lam=0.1,
        precond="schwarz", tol=1e-8
    ),
    "hipbone_n7_pmg_schwarz": PoissonConfig(
        "hipbone_n7_pmg_schwarz", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_smoother="schwarz", tol=1e-8
    ),
    # the iteration-count champion for the ill-conditioned tier:
    # variationally-exact P^T A P coarse operators, materialized once at
    # setup into per-element blocks (sharded-capable, no fine-operator
    # work per coarse apply — core/galerkin.py)
    "hipbone_n7_pmg_galerkin": PoissonConfig(
        "hipbone_n7_pmg_galerkin", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8
    ),
    "hipbone_n7_pmg_galerkin_fp32": PoissonConfig(
        "hipbone_n7_pmg_galerkin_fp32", 7, (8, 8, 8), lam=0.1,
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8,
        dtype="float64", precond_dtype="float32", cg_variant="flexible"
    ),
    # mixed precision: fp64 outer PCG, fp32 preconditioner chain (halved
    # preconditioner HBM streams and halo wire payloads), flexible β
    "hipbone_n7_pmg_fp32": PoissonConfig(
        "hipbone_n7_pmg_fp32", 7, (8, 8, 8), lam=0.1,
        precond="pmg", tol=1e-8, dtype="float64",
        precond_dtype="float32", cg_variant="flexible"
    ),
    "hipbone_n7_schwarz_fp32": PoissonConfig(
        "hipbone_n7_schwarz_fp32", 7, (8, 8, 8), lam=0.1,
        precond="schwarz", tol=1e-8, dtype="float64",
        precond_dtype="float32", cg_variant="flexible"
    ),
    # variable-coefficient tier: A = -∇·(k(x)∇) + λ(x) with mixed
    # Dirichlet/Neumann faces, solved by the iteration-count champion
    # rung (coefficients fold into the g/w streams at setup — same
    # kernels, same FLOP count per apply; docs/SOLVERS.md)
    "hipbone_n7_smooth_mixed": PoissonConfig(
        "hipbone_n7_smooth_mixed", 7, (8, 8, 8), lam=0.1,
        coefficient="smooth", bc="mixed",
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8
    ),
    "hipbone_n7_checker": PoissonConfig(
        "hipbone_n7_checker", 7, (8, 8, 8), lam=0.1,
        coefficient="checker", bc="dirichlet",
        precond="pmg", pmg_coarse_op="galerkin_mat", tol=1e-8
    ),
    # the serving shape: one Chebyshev setup amortized over a 16-column
    # RHS slab per dispatch (serving.SolverEngine / batched_cg_assembled)
    "hipbone_n7_batched": PoissonConfig(
        "hipbone_n7_batched", 7, (8, 8, 8), precond="chebyshev",
        tol=1e-6, batch_rhs=16
    ),
}

REDUCED = PoissonConfig("hipbone_reduced", 3, (2, 2, 2))
