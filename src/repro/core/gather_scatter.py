"""Gather/scatter operators Z, Z^T, ZZ^T and the inverse-degree weight W.

Terminology follows the paper:
  Z      ('scatter'):  x_L = Z x_G      — copy each global DOF to every
                                          element-local node that shares it.
  Z^T    ('gather'):   b_G = Z^T y_L    — sum element-local contributions
                                          into the assembled DOF vector.
  ZZ^T   ('gather-scatter'): the NekBone combined operation on scattered
                             vectors (sum shared values, write the sum back
                             to every copy).
  W:     diagonal inverse-degree weights with Z^T W Z = I; used (a) fused
         into the hipBone operator kernel as the screen term λW, and (b) as
         the weighting for inner products on scattered vectors in the
         NekBone baseline.

On TPU, Z is an XLA dynamic-gather (``take``) and Z^T a ``segment_sum``
scatter-add — see DESIGN.md §3 for why the indirect load lives at the XLA
level rather than inside the Pallas kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "scatter",
    "gather",
    "gather_scatter",
    "scatter_masked",
    "gather_masked",
    "inverse_degree",
    "local_inverse_degree",
]


def scatter(x_g: jax.Array, l2g: jax.Array) -> jax.Array:
    """x_L = Z x_G. Shapes: x_G (N_G,), l2g (E, p) -> (E, p)."""
    return jnp.take(x_g, l2g, axis=0)


def gather(y_l: jax.Array, l2g: jax.Array, n_global: int) -> jax.Array:
    """b_G = Z^T y_L. Shapes: y_L (E, p), l2g (E, p) -> (N_G,)."""
    return jax.ops.segment_sum(
        y_l.reshape(-1), l2g.reshape(-1), num_segments=n_global
    )


def gather_scatter(y_l: jax.Array, l2g: jax.Array, n_global: int) -> jax.Array:
    """ZZ^T y_L — NekBone's combined gather-scatter on scattered vectors."""
    return scatter(gather(y_l, l2g, n_global), l2g)


def scatter_masked(x_g: jax.Array, l2g_ext: jax.Array) -> jax.Array:
    """Z_s x_G for maps with a dummy slot: out-of-domain entries read 0.

    The extended (overlapping-Schwarz) local-to-global maps use the index
    ``n_global`` for nodes outside the physical domain; scattering from a
    zero-padded copy of ``x_g`` turns those slots into zeros without any
    branching.  Shapes: x_G (N_G,), l2g_ext (E, m^3) -> (E, m^3).
    """
    padded = jnp.concatenate([x_g, jnp.zeros((1,), x_g.dtype)])
    return jnp.take(padded, l2g_ext, axis=0)


def gather_masked(y_l: jax.Array, l2g_ext: jax.Array, n_global: int) -> jax.Array:
    """Z_sᵀ y_L for maps with a dummy slot: out-of-domain entries dropped.

    The transpose of :func:`scatter_masked` — contributions indexed
    ``n_global`` land in the dummy segment and are sliced away, so the
    pair stays an exact (adjoint) scatter/gather pair for the PCG-symmetry
    argument.  Shapes: y_L (E, m^3), l2g_ext (E, m^3) -> (N_G,).
    """
    return jax.ops.segment_sum(
        y_l.reshape(-1), l2g_ext.reshape(-1), num_segments=n_global + 1
    )[:n_global]


def inverse_degree(l2g: np.ndarray, n_global: int) -> np.ndarray:
    """Global inverse-degree vector diag(Z^T Z)^{-1} as numpy float64."""
    counts = np.zeros((n_global,), dtype=np.float64)
    np.add.at(counts, l2g.reshape(-1), 1.0)
    return 1.0 / counts


def local_inverse_degree(l2g: np.ndarray, n_global: int) -> np.ndarray:
    """W in scattered layout: (E, p) inverse multiplicity of each local node.

    Satisfies Z^T W Z = I; this is the weight hipBone fuses into the operator
    kernel (λW term) and NekBone uses for weighted inner products.
    """
    inv = inverse_degree(l2g, n_global)
    return inv[l2g]
