"""Pallas TPU streaming kernels for the CG vector operations.

The paper's CG-iteration optimizations are streaming fusions:
  * ``fused_axpy_dot``:  r_new = r - α·Ap  AND  Σ r_new²  in ONE pass —
    "Fusing this reduction with the update of r avoids the need for a
    separate kernel to read the vector r again."
  * ``fused_xpay``:      p = r + β·p  (the CG direction update).
  * ``weighted_dot``:    Σ w·a·b — NekBone-baseline weighted inner product
    (reads the extra weight stream, as the paper charges it).
  * ``fused_jacobi_dot``: z = D⁻¹r  AND  Σ r·z in ONE pass — the same
    streaming trick applied to the PCG preconditioner stage (the z vector
    is produced and the r·z reduction taken without re-reading r).
  * ``fused_cheb_d_update``: d = a·d + c·(D⁻¹·res) — the Chebyshev–Jacobi
    direction update with the Jacobi scale folded in (three streams, two
    SMEM scalars, one pass).

TPU mapping: 1-D vectors are viewed as (rows, 128) lane tiles; the grid
walks row blocks; scalar reductions accumulate into a (1, 1) output block
that every grid step revisits (TPU grids are sequential, so the
accumulation is deterministic — unlike GPU atomics). α/β arrive as (1, 1)
SMEM scalars so the same compiled kernel serves every iteration.

Batched (multi-RHS) layouts: the ``*_batched`` variants take a
``(B, rows, 128)`` block of vectors — the leading-batch-dim layout of the
batched PCG (``core.cg.batched_cg_assembled``) — on a ``(B, row-blocks)``
grid.  Per-column scalars (α per RHS, the Σ reductions) become ``(B,)``
vectors: α/β ride in SMEM as a ``(B, 1)`` table indexed by the batch grid
axis, and each batch row accumulates into its own revisited ``(1, 1)``
block of a ``(B, 1)`` output.  Shared streams (the Jacobi diagonal) keep a
single copy indexed only by the row-block axis, so the batch never
materializes B copies of per-problem state — the per-batch-seed idiom of
the pie ``rand_mv`` kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "fused_axpy_dot_pallas",
    "fused_axpy_dot_batched_pallas",
    "fused_xpay_pallas",
    "fused_xpay_batched_pallas",
    "weighted_dot_pallas",
    "fused_jacobi_dot_pallas",
    "fused_jacobi_dot_batched_pallas",
    "fused_cheb_d_update_pallas",
]

LANES = 128
DEFAULT_BLOCK_ROWS = 512  # 512x128 f32 tile = 256 KB per stream


def _axpy_dot_kernel(alpha_ref, r_ref, ap_ref, rnew_ref, acc_ref):
    i = pl.program_id(0)
    alpha = alpha_ref[0, 0]
    r = r_ref[...]
    ap = ap_ref[...]
    r_new = r - alpha * ap
    rnew_ref[...] = r_new
    # explicit f32 (not weak-typed literals): see _jacobi_dot_kernel
    part = jnp.sum(
        r_new.astype(jnp.float32) * r_new.astype(jnp.float32)
    ).astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    acc_ref[0, 0] += part


def _xpay_kernel(beta_ref, r_ref, p_ref, out_ref):
    beta = beta_ref[0, 0]
    out_ref[...] = r_ref[...] + beta * p_ref[...]


def _wdot_kernel(w_ref, a_ref, b_ref, acc_ref):
    i = pl.program_id(0)
    part = jnp.sum(
        w_ref[...].astype(jnp.float32)
        * a_ref[...].astype(jnp.float32)
        * b_ref[...].astype(jnp.float32)
    ).astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    acc_ref[0, 0] += part


def _jacobi_dot_kernel(dinv_ref, r_ref, z_ref, acc_ref):
    i = pl.program_id(0)
    r = r_ref[...]
    z = dinv_ref[...] * r
    z_ref[...] = z
    # explicit f32 throughout: weak-typed literals would become f64 when the
    # host process runs with jax_enable_x64 (interpret-mode discharge does
    # not weak-cast stores)
    part = jnp.sum(r.astype(jnp.float32) * z.astype(jnp.float32)).astype(
        jnp.float32
    )

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    acc_ref[0, 0] += part


def _cheb_d_kernel(a_ref, c_ref, d_ref, r_ref, out_ref):
    a = a_ref[0, 0]
    c = c_ref[0, 0]
    out_ref[...] = a * d_ref[...] + c * r_ref[...]


def _axpy_dot_batched_kernel(alpha_ref, r_ref, ap_ref, rnew_ref, acc_ref):
    b = pl.program_id(0)
    i = pl.program_id(1)
    alpha = alpha_ref[b, 0]
    r = r_ref[...]
    ap = ap_ref[...]
    r_new = r - alpha * ap
    rnew_ref[...] = r_new
    part = jnp.sum(
        r_new.astype(jnp.float32) * r_new.astype(jnp.float32)
    ).astype(jnp.float32)

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    acc_ref[0, 0] += part


def _jacobi_dot_batched_kernel(dinv_ref, r_ref, z_ref, acc_ref):
    i = pl.program_id(1)
    r = r_ref[...]
    # dinv is the SHARED per-problem stream: one (br, LANES) block serves
    # every batch row (broadcast against the (1, br, LANES) r block)
    z = dinv_ref[...][None, :, :] * r
    z_ref[...] = z
    part = jnp.sum(r.astype(jnp.float32) * z.astype(jnp.float32)).astype(
        jnp.float32
    )

    @pl.when(i == 0)
    def _init():
        acc_ref[0, 0] = jnp.float32(0.0)

    acc_ref[0, 0] += part


def _xpay_batched_kernel(beta_ref, r_ref, p_ref, out_ref):
    b = pl.program_id(0)
    out_ref[...] = r_ref[...] + beta_ref[b, 0] * p_ref[...]


def _as_tiles(x: jax.Array) -> jax.Array:
    """View a (rows*LANES,) vector as (rows, LANES); caller pre-pads."""
    return x.reshape(-1, LANES)


def _as_batched_tiles(x: jax.Array) -> jax.Array:
    """View a (B, rows*LANES) block as (B, rows, LANES); caller pre-pads."""
    return x.reshape(x.shape[0], -1, LANES)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_axpy_dot_pallas(
    r: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(r - α·Ap, Σ(r - α·Ap)²) in one pass. r, ap: (rows, 128) tiles."""
    r2, ap2 = _as_tiles(r), _as_tiles(ap)
    rows = r2.shape[0]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    alpha2 = jnp.asarray(alpha, r2.dtype).reshape(1, 1)
    grid = (rows // br,)
    r_new, acc = pl.pallas_call(
        _axpy_dot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r2.shape, r2.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha2, r2, ap2)
    return r_new.reshape(r.shape), acc[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_xpay_pallas(
    r: jax.Array,
    p: jax.Array,
    beta: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """r + β·p, one pass."""
    r2, p2 = _as_tiles(r), _as_tiles(p)
    rows = r2.shape[0]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    beta2 = jnp.asarray(beta, r2.dtype).reshape(1, 1)
    out = pl.pallas_call(
        _xpay_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(r2.shape, r2.dtype),
        interpret=interpret,
    )(beta2, r2, p2)
    return out.reshape(r.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def weighted_dot_pallas(
    w: jax.Array,
    a: jax.Array,
    b: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Σ w·a·b — NekBone's weighted inner product (extra weight stream)."""
    w2, a2, b2 = _as_tiles(w), _as_tiles(a), _as_tiles(b)
    rows = w2.shape[0]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    acc = pl.pallas_call(
        _wdot_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(w2, a2, b2)
    return acc[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_jacobi_dot_pallas(
    dinv: jax.Array,
    r: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(D⁻¹r, Σ r·D⁻¹r) in one pass — the PCG preconditioner-stage fusion."""
    d2, r2 = _as_tiles(dinv), _as_tiles(r)
    rows = r2.shape[0]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    z, acc = pl.pallas_call(
        _jacobi_dot_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r2.shape, r2.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(d2, r2)
    return z.reshape(r.shape), acc[0, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_axpy_dot_batched_pallas(
    r: jax.Array,
    ap: jax.Array,
    alpha: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched (r - α·Ap, Σ(r - α·Ap)²): one pass over a (B, rows, 128) block.

    ``r``/``ap``: (B, rows*128) RHS blocks; ``alpha``: (B,) per-column CG
    step sizes (an SMEM table indexed by the batch grid axis).  Returns the
    updated (B, rows*128) block and the (B,) per-column reductions.
    """
    r3, ap3 = _as_batched_tiles(r), _as_batched_tiles(ap)
    nb, rows = r3.shape[0], r3.shape[1]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    alpha2 = jnp.asarray(alpha, r3.dtype).reshape(nb, 1)
    grid = (nb, rows // br)
    r_new, acc = pl.pallas_call(
        _axpy_dot_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r3.shape, r3.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha2, r3, ap3)
    return r_new.reshape(r.shape), acc[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_jacobi_dot_batched_pallas(
    dinv: jax.Array,
    r: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Batched (D⁻¹r, Σ r·D⁻¹r) over a (B, rows, 128) block, one pass.

    ``dinv``: (rows*128,) — the ONE shared diagonal stream, never
    replicated per column; ``r``: (B, rows*128).  Returns the (B, rows*128)
    z block and the (B,) per-column r·z reductions.
    """
    d2, r3 = _as_tiles(dinv), _as_batched_tiles(r)
    nb, rows = r3.shape[0], r3.shape[1]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    z, acc = pl.pallas_call(
        _jacobi_dot_batched_kernel,
        grid=(nb, rows // br),
        in_specs=[
            pl.BlockSpec((br, LANES), lambda b, i: (i, 0)),
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0), memory_space=pltpu.SMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(r3.shape, r3.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(d2, r3)
    return z.reshape(r.shape), acc[:, 0]


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_xpay_batched_pallas(
    r: jax.Array,
    p: jax.Array,
    beta: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Batched r + β·p over a (B, rows, 128) block; β: (B,) SMEM table."""
    r3, p3 = _as_batched_tiles(r), _as_batched_tiles(p)
    nb, rows = r3.shape[0], r3.shape[1]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    beta2 = jnp.asarray(beta, r3.dtype).reshape(nb, 1)
    out = pl.pallas_call(
        _xpay_batched_kernel,
        grid=(nb, rows // br),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, br, LANES), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(r3.shape, r3.dtype),
        interpret=interpret,
    )(beta2, r3, p3)
    return out.reshape(r.shape)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def fused_cheb_d_update_pallas(
    a: jax.Array,
    c: jax.Array,
    d: jax.Array,
    r: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """d ← a·d + c·r, one pass (Chebyshev direction update; two SMEM scalars)."""
    d2, r2 = _as_tiles(d), _as_tiles(r)
    rows = d2.shape[0]
    br = min(block_rows, rows)
    if rows % br:
        raise ValueError(f"rows={rows} not a multiple of block_rows={br}")
    a2 = jnp.asarray(a, d2.dtype).reshape(1, 1)
    c2 = jnp.asarray(c, d2.dtype).reshape(1, 1)
    out = pl.pallas_call(
        _cheb_d_kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
            pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(d2.shape, d2.dtype),
        interpret=interpret,
    )(a2, c2, d2, r2)
    return out.reshape(d.shape)
