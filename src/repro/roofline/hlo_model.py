"""Loop-aware HLO analysis: flops / bytes / collectives with trip counts.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE — for
scan-over-layers models that understates flops, bytes and collective
traffic by the trip count (verified empirically; see EXPERIMENTS.md
§Dry-run). This module re-derives the roofline inputs from the
post-optimization HLO text, multiplying each computation by the product of
trip counts of the while-loops it sits under:

  * dot flops: 2 * numel(result) * prod(contracted lhs dims), shapes from a
    per-computation symbol table;
  * collective link bytes: ring-model per op (as roofline/analysis.py),
    times loop multiplier;
  * HBM-traffic proxy: sum of materialized buffer sizes (every non-trivial
    instruction's output, i.e. post-fusion buffers) x2 for write+read,
    times loop multiplier.

Trip counts come from the loop-condition computation's comparison constant
(scan lowers to ``while(cond: i < N)``).
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["analyze_hlo", "HloStats"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_INSTR = re.compile(r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|\S+)\s+([\w\-]+)")
_INSTR_START = re.compile(r"^\s+(?:ROOT\s+)?%[\w.\-]+\s*=\s")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
_WHILE = re.compile(r"while\(.*?condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_COND_BODY = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "add-dependency", "iota",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_info(type_str: str) -> tuple[int, list[int], str]:
    """(total bytes, dims of first array, dtype of first array)."""
    total = 0
    first_dims: list[int] = []
    first_dt = ""
    for m in _SHAPE.finditer(type_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",")] if dims_s else []
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
        if not first_dt:
            first_dims, first_dt = dims, dt
    return total, first_dims, first_dt


@dataclasses.dataclass
class _Comp:
    name: str
    dot_flops: float = 0.0
    buffer_bytes: float = 0.0
    coll_link_bytes: dict = dataclasses.field(default_factory=dict)
    coll_counts: dict = dataclasses.field(default_factory=dict)
    whiles: list = dataclasses.field(default_factory=list)   # (cond, body, hint)
    calls: list = dataclasses.field(default_factory=list)
    max_const: int = 1


@dataclasses.dataclass
class HloStats:
    flops: float
    hbm_bytes: float
    coll_link_bytes: dict[str, float]
    coll_counts: dict[str, float]
    n_whiles: int
    trip_counts: list[int]   # visit order: outermost loop first

    @property
    def total_link_bytes(self) -> float:
        return sum(self.coll_link_bytes.values())


def _join_wrapped_lines(hlo: str) -> list[str]:
    """Merge physical continuation lines into logical instruction lines.

    Scheduled HLO wraps long tuple types (with /*index=N*/ comments) across
    lines; a logical line starts at a computation header, an instruction
    definition, or a closing brace.
    """
    out: list[str] = []
    for line in hlo.splitlines():
        starts_new = (
            not line
            or not line[0].isspace()             # header / close / metadata
            or _INSTR_START.match(line) is not None
        )
        if starts_new or not out:
            out.append(line)
        else:
            out[-1] += " " + line.strip()
    return out


def _parse_computations(hlo: str) -> dict[str, _Comp]:
    comps: dict[str, _Comp] = {}
    cur: _Comp | None = None
    shapes: dict[str, list[int] | None] = {}
    # scalar integer constants flowing through tuple/copy chains: loop bounds
    # hoisted out of the cond land in the while's init tuple (LICM / the
    # "wide." transform), so the cond alone no longer names the trip count
    const_vals: dict[str, int] = {}
    for line in _join_wrapped_lines(hlo):
        if line and not line[0].isspace():
            m = _COMP_HDR.match(line)
            if m and "{" in line:
                cur = _Comp(m.group(1))
                comps[cur.name] = cur
                shapes = {}
                const_vals = {}
                # parameter shapes from the header signature
                for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|[\w\[\],]+)", line):
                    _, dims, _ = _shape_info(pm.group(2))
                    shapes[pm.group(1)] = dims
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            # track trip-count constants even on bare constant lines
            for c in _CONST.finditer(line):
                cur.max_const = max(cur.max_const, int(c.group(1)))
            # whiles may still be detectable on unmatched lines
            if " while(" in line:
                wm = _COND_BODY.search(line)
                if wm:
                    cur.whiles.append((wm.group(1), wm.group(2), 1))
            continue
        name, type_str, op = mi.group(1), mi.group(2), mi.group(3)
        size, dims, _ = _shape_info(type_str)
        shapes[name] = dims
        if op == "dynamic-update-slice" or "dynamic-update-slice" in name:
            # In-place update of an aliased buffer (XLA lowers scatter to a
            # while of these on CPU): the honest HBM charge is the updated
            # slice, not the full result re-written every trip. The slice
            # shape isn't in the result type, so charge one element — the
            # surrounding dynamic-slice reads carry the rest of the traffic.
            _, _, dt = _shape_info(type_str)
            size = _DTYPE_BYTES.get(dt, 4)

        for c in _CONST.finditer(line):
            cur.max_const = max(cur.max_const, int(c.group(1)))

        if op == "constant" and not dims:
            cm = _CONST.search(line)
            if cm:
                const_vals[name] = int(cm.group(1))
        elif op in ("copy", "bitcast", "convert", "tuple"):
            ops_in = re.findall(r"%([\w.\-]+)", line.split("=", 1)[1])
            vals = [const_vals[o] for o in ops_in if o in const_vals]
            if vals:
                const_vals[name] = max(vals)

        wm = _COND_BODY.search(line)
        if op == "while" and wm:
            # trip hint: the largest scalar int constant feeding the init
            # tuple — catches bounds hoisted out of the cond computation
            im = re.search(r"while\((?:\([^()]*\)\s*)?%([\w.\-]+)\)", line)
            hint = const_vals.get(im.group(1), 1) if im else 1
            cur.whiles.append((wm.group(1), wm.group(2), hint))
            continue
        cm = _CALLS.search(line)
        if cm:
            cur.calls.append(cm.group(1))

        if op == "dot":
            # operands: dot(f32[..] %a, f32[..] %b) — lhs shape from symbol
            # table; an optional type token (never %-prefixed) precedes the
            # operand name in post-optimization HLO
            om = re.search(r"\bdot\(\s*(?:[^%\s]\S*\s+)?%([\w.\-]+)", line)
            k = 1
            if om:
                lhs = shapes.get(om.group(1))
                cd = _CONTRACT.search(line)
                if lhs and cd and cd.group(1):
                    for d in cd.group(1).split(","):
                        di = int(d)
                        if di < len(lhs):
                            k *= lhs[di]
            numel = 1
            for d in dims:
                numel *= d
            cur.dot_flops += 2.0 * numel * k
        elif op in _COLLECTIVES:
            kind = op.replace("-start", "")
            g = None
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len(gm.group(1).split(","))
            else:
                gm = _GROUPS_IOTA_RE.search(line)
                if gm:
                    g = int(gm.group(2))
            if g is None or g <= 1:
                g = 2 if kind == "collective-permute" else 1
            if kind == "all-reduce":
                lb = 2 * (g - 1) / g * size
            elif kind == "all-gather":
                lb = (g - 1) / g * size
            elif kind == "reduce-scatter":
                lb = (g - 1) * size
            elif kind == "all-to-all":
                lb = (g - 1) / g * size
            else:
                lb = size
            cur.coll_link_bytes[kind] = cur.coll_link_bytes.get(kind, 0.0) + lb
            cur.coll_counts[kind] = cur.coll_counts.get(kind, 0) + 1

        if op not in _SKIP_OPS:
            cur.buffer_bytes += size
    return comps


def _comp_max_const(comps: dict[str, _Comp], name: str, depth: int = 0) -> int:
    """Largest int constant in a computation or anything it calls."""
    if depth > 8 or name not in comps:
        return 1
    c = comps[name]
    m = c.max_const
    for cal in c.calls:
        m = max(m, _comp_max_const(comps, cal, depth + 1))
    return m


def analyze_hlo(hlo: str, entry: str | None = None) -> HloStats:
    comps = _parse_computations(hlo)
    if not comps:
        return HloStats(0, 0, {}, {}, 0, [])
    entry_name = entry
    if entry_name is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", hlo, re.MULTILINE)
        entry_name = m.group(1) if m else next(iter(comps))

    flops = 0.0
    bytes_ = 0.0
    coll_b: dict[str, float] = {}
    coll_c: dict[str, float] = {}
    n_whiles = 0
    trips: list[int] = []
    seen: set[tuple[str, float]] = set()

    def visit(name: str, mult: float, depth: int = 0):
        nonlocal flops, bytes_, n_whiles
        if depth > 32 or name not in comps:
            return
        c = comps[name]
        key = (name, mult)
        if key in seen:            # same computation at same multiplier
            return
        seen.add(key)
        flops_local = c.dot_flops * mult
        nonloc_add(flops_local)
        bytes_add(c.buffer_bytes * mult)
        for k, v in c.coll_link_bytes.items():
            coll_b[k] = coll_b.get(k, 0.0) + v * mult
        for k, v in c.coll_counts.items():
            coll_c[k] = coll_c.get(k, 0.0) + v * mult
        for cal in c.calls:
            visit(cal, mult, depth + 1)
        for cond, body, hint in c.whiles:
            # the bound constant may sit in a fusion the cond calls
            trip = max(_comp_max_const(comps, cond), hint)
            n_whiles += 1
            trips.append(trip)
            visit(body, mult * max(trip, 1), depth + 1)
            visit(cond, mult * max(trip, 1), depth + 1)

    def nonloc_add(v):
        nonlocal flops
        flops += v

    def bytes_add(v):
        nonlocal bytes_
        bytes_ += v

    visit(entry_name, 1.0)
    return HloStats(
        flops=flops,
        hbm_bytes=2.0 * bytes_,     # write + ~one read per buffer
        coll_link_bytes=coll_b,
        coll_counts=coll_c,
        n_whiles=n_whiles,
        trip_counts=trips[:12],   # DFS order: the outermost (solver) loop
    )                             # is trips[0]; nested lowering loops follow
