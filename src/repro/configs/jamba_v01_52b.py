"""jamba-v0.1-52b [hybrid] — arXiv:2403.19887.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536. Mamba+attention
1:7 interleave (attention at offset 4 of each 8-layer block), MoE 16
experts top-2 on every other layer (offset 1). SSM state 16.

Adaptation note (DESIGN.md): Jamba v0.1 uses Mamba-1 mixers; we use our
Mamba-2 SSD mixer with the same d_state=16 and d_inner=8192 (head_dim 64
-> 128 SSD heads) — the SSD formulation is the TPU-friendly chunked form.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_heads=128,         # d_inner 8192 / head_dim 64
    ssm_expand=2,
    ssm_groups=1,
    conv_width=4,
    ssm_chunk=128,
    n_experts=16,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    router_score="softmax",
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced",
    family="hybrid",
    n_layers=8,            # one full period: same 1:7 + MoE-every-other pattern
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    attn_every=8,
    attn_offset=4,
    ssm_state=16,
    ssm_heads=4,
    ssm_expand=2,
    ssm_chunk=8,
    n_experts=4,
    experts_per_token=2,
    moe_every=2,
    moe_offset=1,
    dtype="float32",
)
