"""Production serve driver: ``python -m repro.launch.serve --arch <id>``."""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.model import init_model
from repro.serving import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    eng = Engine(cfg, params, ServeConfig(batch=args.batch, capacity=64))
    prompts = jax.random.randint(jax.random.key(1), (args.batch, 8), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, max_new=args.max_new)
    print("generated shape:", out.shape)


if __name__ == "__main__":
    main()
