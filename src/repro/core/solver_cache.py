"""Setup cache for the solver service: build once, solve many.

hipBone exists because Nek5000/NekRS amortize one operator/preconditioner
setup over thousands of solves (every time step re-solves the same
pressure Poisson system).  This module is that amortization made explicit:
a :class:`SolverCache` maps a *problem identity* — mesh signature, degree
N, screen λ, preconditioner configuration, dtypes — to the built
:class:`SolverSetup` (operator apply, preconditioner apply, spectrum
estimates), so a repeated request pays **zero** setup work: no assembled
diagonals, no Lanczos sweeps, no Schwarz FDM eigendecompositions, no
Galerkin block probing.

Keying contract:

  * the **mesh signature** hashes the full geometry (degree, element-grid
    shape, node coordinates, the l2g connectivity) — two meshes that
    differ only by a deformation hash differently;
  * **λ** and the problem/preconditioner **dtypes** are part of the key
    (perturbing λ rebuilds; an fp32 chain is a different setup than fp64);
  * the **preconditioner config** is canonicalized through
    :func:`core.precond.precond_signature` (defaults filled in), so two
    spellings of the same config share one entry;
  * solve-time knobs (tol, n_iter, cg_variant, detector thresholds) are
    deliberately NOT in the key — they don't change the setup.  Grouping
    by those is the serving engine's dispatch concern
    (``repro.serving.SolverEngine``).

Hit/miss counters are first-class: :meth:`SolverCache.stats` feeds the
benchmark/serving solve records, and the batched-solve benchmark asserts
the hit path did zero preconditioner setup.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from collections import OrderedDict
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .precond import PrecondInfo, make_preconditioner, precond_signature

__all__ = [
    "SolverCache",
    "SolverSetup",
    "content_signature",
    "mesh_signature",
    "solver_setup_key",
]


def content_signature(*parts: Any) -> str:
    """sha256[:16] over a canonical json rendering of ``parts``.

    The :func:`mesh_signature` hashing style for non-mesh identities:
    stable across processes (no ``id()``, no dict ordering), short enough
    to live in filenames, records and logs.  ``comms.plan`` keys its
    persisted exchange plans with this, so a tuned plan sits alongside
    the solver cache's mesh-signature keys on disk.
    """
    h = hashlib.sha256()
    h.update(json.dumps(parts, sort_keys=True, default=str).encode())
    return h.hexdigest()[:16]


def mesh_signature(mesh) -> str:
    """Deterministic content hash of a ``BoxMesh``'s geometry.

    Hashes degree, element-grid shape, node coordinates and the l2g
    connectivity (coordinates are rounded through their raw float64 bytes —
    bit-equal geometry in, equal signature out; any deformation or
    re-gridding changes it).  Stable across processes, unlike ``id()``-
    based identity, so cache keys can live in solve records and logs.
    """
    h = hashlib.sha256()
    h.update(f"N={int(mesh.n_degree)};shape={tuple(mesh.shape)};".encode())
    h.update(np.ascontiguousarray(np.asarray(mesh.coords, np.float64)).tobytes())
    h.update(np.ascontiguousarray(np.asarray(mesh.l2g, np.int64)).tobytes())
    return h.hexdigest()[:16]


def solver_setup_key(prob, kind: str = "none", **precond_kwargs) -> tuple:
    """The cache key for (problem, preconditioner config).

    A flat hashable tuple: mesh signature, degree, λ, problem dtype, and
    the canonicalized preconditioner signature
    (:func:`core.precond.precond_signature` — defaults filled, so every
    spelling of the same config maps to the same key).

    Variable-coefficient state extends the key only when present — a
    content hash of the k / λ(x) fields and the normalized bc tags — so
    every legacy (constant-λ, no-bc) key is *unchanged* byte for byte:
    cached entries from before the operator generalization still hit, and
    perturbing a coefficient field or flipping one face's bc tag rebuilds.
    """
    key = (
        ("mesh", mesh_signature(prob.mesh)),
        ("n", int(prob.mesh.n_degree)),
        ("lam", float(prob.lam)),
        ("dtype", jnp.dtype(prob.dtype).name),
    )
    coef_parts = []
    if prob.k is not None:
        h = hashlib.sha256(
            np.ascontiguousarray(np.asarray(prob.k, np.float64)).tobytes()
        )
        coef_parts.append(("k", h.hexdigest()[:16]))
    if prob.lam_field is not None:
        h = hashlib.sha256(
            np.ascontiguousarray(
                np.asarray(prob.lam_field, np.float64)
            ).tobytes()
        )
        coef_parts.append(("lam_field", h.hexdigest()[:16]))
    if prob.bc is not None:
        coef_parts.append(("bc", tuple(prob.bc)))
    return key + tuple(coef_parts) + precond_signature(kind, **precond_kwargs)


@dataclasses.dataclass(frozen=True)
class SolverSetup:
    """One cached build: everything a solve needs beyond (b, tol, n_iter)."""

    key: tuple
    prob: Any                     # the PoissonProblem the setup was built on
    operator: Callable[[jax.Array], jax.Array]
    precond: Callable[[jax.Array], jax.Array] | None
    info: PrecondInfo
    build_s: float                # wall time the (hit path's skipped) setup cost


class SolverCache:
    """Keyed store of built :class:`SolverSetup`\\ s with hit/miss counters.

    ``max_entries`` bounds the cache LRU-style (least-recently *used*
    entry evicted first); ``None`` = unbounded, the right default for a
    benchmark or a service with a fixed problem population.
    """

    def __init__(self, max_entries: int | None = None):
        if max_entries is not None and max_entries < 1:
            raise ValueError(f"max_entries must be >= 1, got {max_entries}")
        self.max_entries = max_entries
        self._entries: OrderedDict[tuple, SolverSetup] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.build_s_total = 0.0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: tuple) -> bool:
        return key in self._entries

    def get_or_build(
        self,
        prob,
        kind: str = "none",
        *,
        operator: Callable[[jax.Array], jax.Array] | None = None,
        **precond_kwargs,
    ) -> SolverSetup:
        """Return the setup for (prob, config), building it on first miss.

        On a miss the operator apply (``poisson_assembled``, unless one is
        injected via ``operator``) and the full preconditioner chain are
        built and the wall time recorded; on a hit NOTHING is rebuilt —
        the returned setup is the stored object, and only the hit counter
        moves (the zero-setup guarantee the batched benchmark asserts).
        """
        key = solver_setup_key(prob, kind, **precond_kwargs)
        entry = self._entries.get(key)
        if entry is not None:
            self.hits += 1
            self._entries.move_to_end(key)
            return entry
        self.misses += 1
        t0 = time.perf_counter()
        if operator is None:
            from .operator import poisson_assembled

            operator = poisson_assembled(prob)
        precond, info = make_preconditioner(
            kind, prob, operator, **precond_kwargs
        )
        build_s = time.perf_counter() - t0
        entry = SolverSetup(
            key=key,
            prob=prob,
            operator=operator,
            precond=precond,
            info=info,
            build_s=build_s,
        )
        self.build_s_total += build_s
        self._entries[key] = entry
        if self.max_entries is not None and len(self._entries) > self.max_entries:
            self._entries.popitem(last=False)
            self.evictions += 1
        return entry

    def stats(self) -> dict:
        """Json-ready counters for solve records / service telemetry."""
        total = self.hits + self.misses
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "hit_rate": (self.hits / total) if total else None,
            "build_s_total": self.build_s_total,
        }

    def clear(self) -> None:
        self._entries.clear()
