"""repro.testing — fault-injection and test-support utilities.

Not imported by any solver path; tests (and chaos-style soak scripts) use
these to prove the guardrails in `repro.core.cg` / `repro.core.resilience`
actually fire and recover.  See `repro.testing.faults`.
"""
from .faults import (
    corrupt_wire,
    force_fused_failure,
    mask_precond,
    nan_at_iteration,
    negate_precond,
    on_attempt,
    skew_operator,
)

__all__ = [
    "corrupt_wire",
    "force_fused_failure",
    "mask_precond",
    "nan_at_iteration",
    "negate_precond",
    "on_attempt",
    "skew_operator",
]
