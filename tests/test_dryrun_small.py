"""Dry-run machinery on a small emulated mesh (the 512-chip pattern at 8).

Validates the same lower+compile+analyze path dryrun.py uses, at test
speed: reduced configs on a (2, 4) ("data","model") mesh, all three step
kinds, plus the loop-aware HLO accounting and sharding-rule fallbacks.
"""
import pytest

from conftest import run_subprocess


def test_sharding_rules_fallback():
    import jax.numpy as jnp
    import jax

    from repro.models.params import RULES_TP_FSDP, _spec_with_fallback

    from repro.compat import abstract_mesh

    mesh = abstract_mesh((16,), ("model",))
    # kv_heads=1 cannot shard over a 16-way model axis: falls back to None
    spec = _spec_with_fallback((64, 1, 16), ("embed", "kv_heads", "qk"),
                               RULES_TP_FSDP, mesh)
    assert spec[1] is None
    # heads=32 CAN shard
    spec2 = _spec_with_fallback((64, 32, 16), ("embed", "heads", "qk"),
                                RULES_TP_FSDP, mesh)
    assert spec2[1] == "model"


@pytest.mark.slow
def test_small_mesh_train_prefill_decode():
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp, functools
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import REDUCED
from repro.models.blocks import MeshContext
from repro.models.model import decode_step, init_caches, init_model, prefill
from repro.models.params import RULES_TP_FSDP, tree_shardings_for
from repro.training.optimizer import adafactor
from repro.training.train_step import make_train_step, warmup_cosine
from repro.roofline.hlo_model import analyze_hlo

from repro.compat import make_mesh
mesh = make_mesh((2, 4), ("data", "model"))
cfg = REDUCED["jamba-v0.1-52b"]    # hybrid: mamba + attn + MoE(4e over 4 shards)

box = {}
def f(k):
    p, a = init_model(cfg, k)
    box["axes"] = a
    return p
params_abs = jax.eval_shape(f, jax.random.key(0))
axes = box["axes"]
params_sh = tree_shardings_for(params_abs, axes, RULES_TP_FSDP, mesh)
shards = jax.tree.map(lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
                      params_abs, params_sh)

# --- train ---
mc = MeshContext(mesh=mesh, batch_axes=("data",), tp_axis="model",
                 act_seq_axis="model")
opt = adafactor()
opt_abs = jax.eval_shape(opt.init, params_abs)
step = make_train_step(cfg, opt, warmup_cosine(peak_lr=1e-3, warmup=5, total=50),
                       mc, microbatches=2)
batch = {"tokens": jax.ShapeDtypeStruct((4, 17), jnp.int32,
         sharding=NamedSharding(mesh, P("data", None)))}
lowered = jax.jit(step).lower(shards, opt_abs, batch,
                              jax.ShapeDtypeStruct((), jnp.int32))
compiled = lowered.compile()
st = analyze_hlo(compiled.as_text())
assert st.flops > 0 and st.n_whiles >= 1
assert st.total_link_bytes > 0        # FSDP gathers + grad reductions exist
print("train OK", st.trip_counts)

# --- prefill ---
mc2 = MeshContext(mesh=mesh, batch_axes=("data",), tp_axis="model")
fn = functools.partial(prefill, cfg=cfg, mc=mc2)
tok = jax.ShapeDtypeStruct((2, 16), jnp.int32,
                           sharding=NamedSharding(mesh, P("data", None)))
c2 = jax.jit(fn).lower(shards, tok).compile()
print("prefill OK")

# --- decode with seq-sharded cache ---
mc3 = MeshContext(mesh=mesh, batch_axes=("data",), tp_axis="model",
                  seq_axes=("model",))
caches_abs = jax.eval_shape(lambda: init_caches(cfg, 2, 32))
def cspec(path, leaf):
    key = getattr(path[-1], "key", "")
    nd = leaf.ndim
    if key in ("k", "v"):
        return P(*([None]*(nd-4)), "data", "model", None, None)
    if key in ("c_kv", "k_rope"):
        return P(*([None]*(nd-3)), "data", "model", None)
    if key in ("state",):
        return P(*([None]*(nd-4)), "data", None, None, None)
    if key == "conv":
        return P(*([None]*(nd-3)), "data", None, None)
    return P(*([None]*nd))
flat, td = jax.tree_util.tree_flatten_with_path(caches_abs)
caches_in = jax.tree_util.tree_unflatten(td, [
    jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=NamedSharding(mesh, cspec(p, l)))
    for p, l in flat])
fn3 = functools.partial(decode_step, cfg=cfg, mc=mc3)
tok1 = jax.ShapeDtypeStruct((2, 1), jnp.int32,
                            sharding=NamedSharding(mesh, P("data", None)))
t_in = jax.ShapeDtypeStruct((), jnp.int32)
c3 = jax.jit(fn3).lower(shards, tok1, t_in, caches_in).compile()
print("decode OK")
""",
        devices=8,
        timeout=900,
    )


def test_poisson_dryrun_small_mesh():
    run_subprocess(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.comms.topology import ProcessGrid, factor3
from repro.core.distributed import DistPoisson, _local_l2g, dist_cg
from repro.core import sem
from repro.roofline.hlo_model import analyze_hlo

from repro.compat import make_mesh
mesh = make_mesh((8,), ("ranks",))
grid = ProcessGrid(factor3(8))
n, local = 3, (2, 2, 2)
l2g, halo = _local_l2g(n, local)
e_loc, p = l2g.shape
m3 = (local[0]*n+1)**3
prob = DistPoisson(
    grid=grid, axis_name="ranks", n_degree=n, local_shape=local,
    box_shape=(local[0]*n+1,)*3, lam=1.0, halo_elems=halo, l2g=l2g,
    d=jnp.asarray(sem.derivative_matrix(n), jnp.float32),
    g=jax.ShapeDtypeStruct((8, e_loc, 6, p), jnp.float32,
                           sharding=NamedSharding(mesh, P("ranks"))),
    w_local=jax.ShapeDtypeStruct((8, e_loc, p), jnp.float32,
                                 sharding=NamedSharding(mesh, P("ranks"))),
    mask=jax.ShapeDtypeStruct((8, m3), jnp.float32,
                              sharding=NamedSharding(mesh, P("ranks"))),
    dtype=jnp.float32,
)
b = jax.ShapeDtypeStruct((8, m3), jnp.float32,
                         sharding=NamedSharding(mesh, P("ranks")))
run = dist_cg(prob, mesh, b, n_iter=10)
compiled = jax.jit(run.func).lower(*run.args).compile()
st = analyze_hlo(compiled.as_text())
assert st.coll_counts.get("collective-permute", 0) >= 6 * 10  # 6 ppermutes/iter
print("OK", st.coll_counts)
""",
        devices=8,
    )
