"""gemma3-1b [dense] — google/gemma-3-1b-pt.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144. 5:1 local:global
attention (window 512 on local layers), QK-norm, head_dim=256, GeGLU,
tied embeddings, Gemma (1+w) RMSNorm, 128k context (rope theta 1M on the
global layers; we use the global theta throughout — noted in DESIGN.md).
26 = 4 full periods of 6 + 2 remainder local layers.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    sliding_window=512,
    global_every=6,
    qk_norm=True,
    act="gelu",
    gemma_norm=True,
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="gemma3-1b-reduced",
    family="dense",
    n_layers=8,                # 1 period of 6 + 2 remainder, same pattern
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
    global_every=6,
    qk_norm=True,
    act="gelu",
    gemma_norm=True,
    emb_scale=True,
    tie_embeddings=True,
    dtype="float32",
)
