"""Hypothesis property tests on the system's invariants.

Example budgets come from a named profile selected by the
``HYPOTHESIS_PROFILE`` env var (default ``ci``): ``fast`` for smoke runs,
``ci`` for the bounded CI budget, ``thorough`` for local fuzzing.  CI
exports ``HYPOTHESIS_PROFILE=ci`` explicitly and asserts this module is
collected (not skipped) — see .github/workflows/ci.yml.
"""
import os

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import run_subprocess
from repro.core import (
    batched_cg_assembled,
    build_problem,
    cg_assembled,
    make_preconditioner,
    poisson_assembled,
    precond_signature,
    solver_setup_key,
)
from repro.core.gather_scatter import gather, scatter
from repro.core.mesh import build_box_mesh, partition_elements
from repro.core.operator import problem_from_mesh
from repro.comms.topology import factor3
from repro.models.moe import router_topk
from repro.models.config import ModelConfig
from repro.training.compress import dequantize_int8, quantize_int8

settings.register_profile("fast", max_examples=10, deadline=None)
settings.register_profile("ci", max_examples=25, deadline=None)
settings.register_profile("thorough", max_examples=200, deadline=None)
settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
SMALL = settings()  # the loaded profile's budget
# Coefficient-field strategies build a full problem + preconditioner per
# example (seconds each, vs milliseconds for the pure-array properties) —
# a reduced example count keeps them inside the ci leg's wall-clock
# budget; deadline stays None profile-wide (single examples legitimately
# exceed hypothesis' default 200 ms deadline under jit compilation).
HEAVY = settings(SMALL, max_examples=max(settings().max_examples // 3, 5))


@SMALL
@given(
    n=st.integers(1, 5),
    ex=st.integers(1, 3),
    ey=st.integers(1, 3),
    ez=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_gather_scatter_adjoint(n, ex, ey, ez, seed):
    """<Z x, y>_L == <x, Z^T y>_G — Z and Z^T are adjoint by construction."""
    m = build_box_mesh(n, (ex, ey, ez))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(m.n_global), jnp.float32)
    y = jnp.asarray(
        rng.standard_normal((m.n_elements, m.points_per_element)), jnp.float32
    )
    lhs = float(jnp.vdot(scatter(x, jnp.asarray(m.l2g)), y))
    rhs = float(jnp.vdot(x, gather(y, jnp.asarray(m.l2g), m.n_global)))
    assert abs(lhs - rhs) <= 1e-3 * (abs(lhs) + 1.0)


@SMALL
@given(n=st.integers(1, 4), seed=st.integers(0, 100))
def test_operator_linearity(n, seed):
    prob = build_problem(n, (2, 2, 1), lam=1.0, dtype=jnp.float32)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    y = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    lhs = np.array(a(2.0 * x + 3.0 * y))
    rhs = 2.0 * np.array(a(x)) + 3.0 * np.array(a(y))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)


@SMALL
@given(p=st.integers(1, 4096))
def test_factor3_partitions_exactly(p):
    a, b, c = factor3(p)
    assert a * b * c == p and a >= b >= c >= 1


@SMALL
@given(
    px=st.integers(1, 3), py=st.integers(1, 3), pz=st.integers(1, 3),
)
def test_partition_covers_all_elements(px, py, pz):
    shape = (2 * px, 2 * py, 2 * pz)
    owner = partition_elements(shape, (px, py, pz))
    counts = np.bincount(owner, minlength=px * py * pz)
    assert (counts == counts[0]).all()  # balanced block partition
    assert counts.sum() == np.prod(shape)


@SMALL
@given(
    t=st.integers(1, 64),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_router_topk_weights_normalized(t, e, k, seed):
    k = min(k, e)
    cfg = ModelConfig(
        name="x", family="moe", n_layers=1, d_model=8, n_heads=1, n_kv_heads=1,
        head_dim=8, d_ff=8, vocab_size=8, n_experts=e, experts_per_token=k,
    )
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    w, idx, probs = router_topk(logits, cfg)
    assert w.shape == (t, k) and idx.shape == (t, k)
    np.testing.assert_allclose(np.array(w).sum(-1), 1.0, rtol=1e-5)
    assert (np.array(idx) >= 0).all() and (np.array(idx) < e).all()
    # indices unique per token
    for row in np.array(idx):
        assert len(set(row.tolist())) == k


@SMALL
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


@SMALL
@given(
    n=st.integers(2, 3),
    nb=st.integers(1, 4),
    kind=st.sampled_from(["none", "jacobi", "chebyshev"]),
    seed=st.integers(0, 1000),
)
def test_batched_solve_matches_looped(n, nb, kind, seed):
    """A (B, n_global) batched solve is iteration-for-iteration identical
    to B standalone solves — per-column independent stopping."""
    prob = build_problem(n, (2, 2, 1), lam=1.0, dtype=jnp.float32)
    a = poisson_assembled(prob)
    pc, _ = make_preconditioner(kind, prob, a)
    rng = np.random.default_rng(seed)
    b_block = jnp.asarray(
        rng.standard_normal((nb, prob.n_global)), jnp.float32
    )
    res = batched_cg_assembled(a, b_block, n_iter=150, tol=1e-5, precond=pc)
    for i in range(nb):
        ref = cg_assembled(a, b_block[i], n_iter=150, tol=1e-5, precond=pc)
        assert int(res.iterations[i]) == int(ref.iterations)
        assert int(res.status[i]) == int(ref.status)


@SMALL
@given(
    n=st.integers(2, 3),
    lam=st.floats(0.05, 10.0),
    kind=st.sampled_from(["jacobi", "chebyshev", "pmg", "schwarz"]),
    seed=st.integers(0, 1000),
)
def test_preconditioner_inverse_spd(n, lam, kind, seed):
    """M⁻¹ stays symmetric positive definite across random (N, λ, kind)
    draws — the property the PCG recurrence assumes.  Checked on the Gram
    matrix Yᵀ M⁻¹ Y of random probes: symmetry and positive eigenvalues."""
    prob = build_problem(n, (2, 1, 1), lam=lam, dtype=jnp.float32)
    a = poisson_assembled(prob)
    pc, _ = make_preconditioner(kind, prob, a)
    rng = np.random.default_rng(seed)
    y = rng.standard_normal((prob.n_global, 6)).astype(np.float32)
    mz = np.stack(
        [np.asarray(pc(jnp.asarray(y[:, j]))) for j in range(y.shape[1])],
        axis=1,
    )
    gram = y.T @ mz
    asym = np.abs(gram - gram.T).max() / (np.abs(gram).max() + 1e-12)
    assert asym < 5e-3, f"M⁻¹ not symmetric: rel asym {asym}"
    eig = np.linalg.eigvalsh(0.5 * (gram + gram.T))
    assert eig.min() > 0, f"M⁻¹ not positive definite: min eig {eig.min()}"


@SMALL
@given(
    n=st.integers(2, 3),
    lam=st.floats(0.1, 10.0),
    delta=st.floats(1e-6, 1e-2),
    kind=st.sampled_from(["none", "jacobi", "chebyshev", "pmg", "schwarz"]),
)
def test_cache_key_determinism(n, lam, delta, kind):
    """Same problem → same setup-cache key (across rebuilds); perturbing
    λ — however slightly — changes it; knob spellings canonicalize."""
    p1 = build_problem(n, (2, 1, 1), lam=lam, dtype=jnp.float32)
    p2 = build_problem(n, (2, 1, 1), lam=lam, dtype=jnp.float32)
    k1 = solver_setup_key(p1, kind)
    assert k1 == solver_setup_key(p2, kind)
    p3 = build_problem(n, (2, 1, 1), lam=lam + delta, dtype=jnp.float32)
    assert solver_setup_key(p3, kind) != k1
    # canonicalization: spelling out a default == omitting it
    assert precond_signature(kind, degree=2) == precond_signature(kind)
    assert precond_signature(kind, degree=3) != precond_signature(kind)


def _random_coefficient_problem(n, seed, bc, *, lam=0.8, dtype=jnp.float32):
    """Random positive k(x)/λ(x) fields on a 2³ box (log-normal k keeps the
    draws strictly positive with O(10×) contrast — the SPD precondition).

    Field sizes are bounded by n ≤ 3 on 8 elements so the whole strategy
    stays far inside the hypothesis ``ci`` example budget.
    """
    m = build_box_mesh(n, (2, 2, 2))
    rng = np.random.default_rng(seed)
    shape = m.coords.shape[:2]
    k = np.exp(rng.normal(0.0, 0.8, shape))
    lam_field = 0.05 + np.abs(rng.normal(lam, 0.5, shape))
    return problem_from_mesh(
        m, lam=lam, dtype=dtype, k=k, lam_field=lam_field, bc=bc
    )


def _masked_probes(prob, seed, cols=6):
    """Random probe block restricted to the Dirichlet-interior subspace."""
    rng = np.random.default_rng(seed)
    y = rng.standard_normal((prob.n_global, cols)).astype(np.float32)
    if prob.mask is not None:
        y = y * np.asarray(prob.mask, np.float32)[:, None]
    return y


def _assert_gram_spd(y, apply, label):
    mz = np.stack(
        [np.asarray(apply(jnp.asarray(y[:, j]))) for j in range(y.shape[1])],
        axis=1,
    )
    gram = y.T @ mz
    asym = np.abs(gram - gram.T).max() / (np.abs(gram).max() + 1e-12)
    assert asym < 5e-3, f"{label} not symmetric: rel asym {asym}"
    eig = np.linalg.eigvalsh(0.5 * (gram + gram.T))
    assert eig.min() > 0, f"{label} not positive definite: min eig {eig.min()}"


@HEAVY
@given(
    n=st.integers(2, 3),
    seed=st.integers(0, 1000),
    bc=st.sampled_from([None, "dirichlet", "mixed", "neumann"]),
)
def test_operator_spd_variable_coefficients(n, seed, bc):
    """A = -∇·(k∇) + λ(x) stays SPD on the Dirichlet-interior subspace for
    random positive coefficient draws — the property CG itself assumes."""
    prob = _random_coefficient_problem(n, seed, bc)
    _assert_gram_spd(
        _masked_probes(prob, seed + 1), poisson_assembled(prob), "A"
    )


@HEAVY
@given(
    n=st.integers(2, 3),
    seed=st.integers(0, 1000),
    kind=st.sampled_from(["jacobi", "chebyshev", "pmg", "schwarz"]),
    bc=st.sampled_from([None, "mixed"]),
)
def test_ladder_spd_variable_coefficients(n, seed, kind, bc):
    """Every preconditioner rung's M⁻¹ stays SPD under random coefficient
    fields and bc masks (pmg exercises the field-resampling coarsen path,
    schwarz the element-mean FDM blocks)."""
    prob = _random_coefficient_problem(n, seed, bc)
    a = poisson_assembled(prob)
    pc, _ = make_preconditioner(kind, prob, a)
    _assert_gram_spd(_masked_probes(prob, seed + 1), pc, f"M⁻¹[{kind}]")


@HEAVY
@given(
    n=st.integers(2, 3),
    seed=st.integers(0, 1000),
    kind=st.sampled_from(["none", "jacobi", "pmg"]),
)
def test_cache_key_coefficient_sensitivity(n, seed, kind):
    """The setup-cache key misses whenever the physics changes — and ONLY
    then: legacy constant-λ keys are unchanged by the coefficient
    extension, rebuilding the same fields hits, perturbing one node of k,
    swapping the family, or flipping a bc tag all miss."""
    legacy = build_problem(n, (2, 2, 2), lam=0.8, dtype=jnp.float32)
    const = build_problem(
        n, (2, 2, 2), lam=0.8, dtype=jnp.float32, coefficient="const"
    )
    assert solver_setup_key(legacy, kind) == solver_setup_key(const, kind)

    p1 = _random_coefficient_problem(n, seed, "mixed")
    p2 = _random_coefficient_problem(n, seed, "mixed")
    k1 = solver_setup_key(p1, kind)
    assert k1 == solver_setup_key(p2, kind)          # determinism → hit
    assert k1 != solver_setup_key(legacy, kind)      # physics differs

    # one node, one ulp-scale (in the stored fp32 dtype) perturbation —
    # the key hashes the fields as the problem stores them, so the nudge
    # must survive the dtype cast
    k_pert = np.asarray(p1.k, np.float64).copy()
    k_pert.flat[seed % k_pert.size] *= 1.0 + 1e-6
    p3 = problem_from_mesh(
        p1.mesh, lam=p1.lam, dtype=jnp.float32, k=k_pert,
        lam_field=np.asarray(p1.lam_field, np.float64), bc="mixed",
    )
    assert solver_setup_key(p3, kind) != k1          # any field bit → miss

    p4 = _random_coefficient_problem(n, seed, "dirichlet")
    assert solver_setup_key(p4, kind) != k1          # bc tag → miss

    smooth = build_problem(
        n, (2, 2, 2), lam=0.8, dtype=jnp.float32, coefficient="smooth"
    )
    checker = build_problem(
        n, (2, 2, 2), lam=0.8, dtype=jnp.float32, coefficient="checker"
    )
    assert solver_setup_key(smooth, kind) != solver_setup_key(checker, kind)


@pytest.mark.slow
def test_sharded_parity_random_coefficient_fields():
    """Sharded-vs-single iteration parity holds under random positive
    coefficient draws, not just the named families — three seeded draws
    through the full dist_cg stack on 8 fake devices."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid
from repro.core import build_box_mesh, cg_assembled, poisson_assembled
from repro.core.operator import problem_from_mesh
from repro.core.distributed import build_dist_problem, dist_cg, _ordered_elements

N = 3
grid = ProcessGrid((2, 2, 2)); local = (1, 1, 1); shape = (2, 2, 2)
mesh = make_mesh((8,), ("ranks",))
GX, GY = shape[0] * N + 1, shape[1] * N + 1
ordered, _ = _ordered_elements(local)


def partition_field(field):
    out = np.zeros((grid.size, len(ordered)) + field.shape[1:])
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ex = ordered[:, 0] + ci * local[0]
        ey = ordered[:, 1] + cj * local[1]
        ez = ordered[:, 2] + ck * local[2]
        out[r] = field[ex + shape[0] * (ey + shape[1] * ez)]
    return out


def boxes_from_global(prob, vec):
    mx, my, mz = prob.box_shape
    out = np.zeros((grid.size, prob.m3))
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci * local[0] * N, cj * local[1] * N, ck * local[2] * N
        x, y, z = np.meshgrid(
            np.arange(mx), np.arange(my), np.arange(mz), indexing="ij"
        )
        gidx = (ox + x) + GX * ((oy + y) + GY * (oz + z))
        out[r] = vec[gidx.transpose(2, 1, 0).reshape(-1)]
    return out


for seed in (0, 7, 42):
    rng = np.random.default_rng(seed)
    m = build_box_mesh(N, shape)
    fshape = m.coords.shape[:2]
    k = np.exp(rng.normal(0.0, 0.8, fshape))
    lam_field = 0.05 + np.abs(rng.normal(0.8, 0.5, fshape))
    ref = problem_from_mesh(
        m, lam=0.8, dtype=jnp.float64, k=k, lam_field=lam_field, bc="mixed"
    )
    bg = rng.standard_normal(ref.n_global) * np.asarray(ref.mask, np.float64)
    res = cg_assembled(
        poisson_assembled(ref), jnp.asarray(bg), n_iter=300, tol=1e-10
    )
    prob = build_dist_problem(
        N, grid, local, lam=0.8, dtype=jnp.float64,
        k=partition_field(k), lam_field=partition_field(lam_field),
        bc="mixed",
    )
    run = jax.jit(dist_cg(prob, mesh, jnp.asarray(boxes_from_global(prob, bg)),
                          n_iter=300, tol=1e-10))
    x_boxes, rdotr, iters, status, hist = run()
    err = np.abs(
        np.asarray(x_boxes) - boxes_from_global(prob, np.asarray(res.x))
    ).max()
    print(seed, int(iters), int(res.iterations), err)
    assert int(status) == 0, (seed, int(status))
    assert int(iters) == int(res.iterations), (seed, int(iters), int(res.iterations))
    assert err < 1e-8, (seed, err)
print("PARITY-OK")
""",
        timeout=900,
    )


@SMALL
@given(n=st.integers(1, 8), seed=st.integers(0, 50))
def test_ssd_chunk_invariance(n, seed):
    """Chunk size must not change SSD results (associativity of the scan)."""
    from repro.models.mamba2 import ssd_chunked

    s = 8 * n
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, s, 2, 4)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((1, s, 2))) * 0.3 + 0.05, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal(2)) - 0.1, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((1, s, 1, 3)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((1, s, 1, 3)), jnp.float32)
    y1, s1 = ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y2, s2 = ssd_chunked(x, dt, a, bm, cm, chunk=min(s, 4 * n))
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(s1), np.array(s2), rtol=2e-4, atol=2e-4)
