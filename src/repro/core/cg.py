"""Conjugate Gradient (Algorithm 1) in hipBone-assembled and NekBone-scattered form.

The assembled solver follows hipBone's fusion schedule exactly:
  * one fused pass computes ``r_{j+1} = r_j - α A p`` AND accumulates
    ``r_{j+1}·r_{j+1}`` (paper: "Fusing this reduction with the update of r
    avoids the need for a separate kernel to read the vector r again");
  * the AXPY ``x += α p`` carries no data dependence on that reduction, so
    XLA may overlap the cross-device psum with it — the paper's
    allreduce-hiding trick, expressed as dataflow;
  * inner products on assembled vectors are plain (unweighted) dots.

The scattered baseline replicates NekBone: vectors of length N_L, weighted
inner products reading the extra W vector, and a combined ZZ^T
gather-scatter inside the operator.

Both run a fixed iteration count (NekBone uses 100) under ``lax.scan`` so a
single compiled program covers the whole benchmark, or until tolerance with
``lax.while_loop`` when ``tol`` is given.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["CGResult", "cg_assembled", "cg_scattered", "fused_residual_update"]


class CGResult(NamedTuple):
    x: jax.Array
    rdotr: jax.Array
    iterations: jax.Array
    rdotr_history: jax.Array | None


def fused_residual_update(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One-pass r update + self-dot (reference; Pallas version in kernels/)."""
    r_new = r - alpha * ap
    return r_new, jnp.vdot(r_new, r_new)


def _dot(a: jax.Array, b: jax.Array, w: jax.Array | None) -> jax.Array:
    if w is None:
        return jnp.vdot(a, b)
    return jnp.vdot(a * w, b)


def _cg(
    operator: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None,
    *,
    n_iter: int,
    weight: jax.Array | None,
    psum: Callable[[jax.Array], jax.Array] | None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None,
    record_history: bool,
) -> CGResult:
    allsum = psum or (lambda v: v)
    upd = fused_update or fused_residual_update
    x = jnp.zeros_like(b) if x0 is None else x0

    r = b - operator(x)
    p = r
    rdotr = allsum(_dot(r, r, weight))

    def _safe_div(a, b):
        # fixed-iteration CG (NekBone runs exactly 100) keeps iterating after
        # convergence; guard 0/0 so x simply freezes at the solution
        return jnp.where(b != 0, a / jnp.where(b != 0, b, 1), 0.0)

    def body(carry, _):
        x, r, p, rdotr = carry
        ap = operator(p)
        pap = allsum(_dot(p, ap, weight))
        alpha = _safe_div(rdotr, pap)
        if weight is None:
            # hipBone fusion: r-update + local reduction in one pass...
            r_new, rr_local = upd(r, ap, alpha)
        else:
            r_new = r - alpha * ap
            rr_local = _dot(r_new, r_new, weight)
        # ...and x-update independent of the psum -> overlappable allreduce.
        x_new = x + alpha * p
        rdotr_new = allsum(rr_local)
        beta = _safe_div(rdotr_new, rdotr)
        p_new = r_new + beta * p
        return (x_new, r_new, p_new, rdotr_new), rdotr_new

    (x, r, p, rdotr), hist = jax.lax.scan(
        body, (x, r, p, rdotr), None, length=n_iter
    )
    return CGResult(
        x=x,
        rdotr=rdotr,
        iterations=jnp.asarray(n_iter),
        rdotr_history=hist if record_history else None,
    )


def cg_assembled(
    operator: Callable[[jax.Array], jax.Array],
    b_g: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    record_history: bool = False,
) -> CGResult:
    """hipBone CG on assembled (length N_G) vectors; unweighted dots."""
    return _cg(
        operator,
        b_g,
        x0,
        n_iter=n_iter,
        weight=None,
        psum=psum,
        fused_update=fused_update,
        record_history=record_history,
    )


def cg_scattered(
    operator: Callable[[jax.Array], jax.Array],
    b_l: jax.Array,
    w_local: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    record_history: bool = False,
) -> CGResult:
    """NekBone baseline CG on scattered (length N_L) vectors; weighted dots."""
    return _cg(
        operator,
        b_l,
        x0,
        n_iter=n_iter,
        weight=w_local,
        psum=psum,
        fused_update=None,
        record_history=record_history,
    )
