"""deepseek-v3-671b [moe] — arXiv:2412.19437.

61L d_model=7168 128H, MLA (q_lora 1536 / kv_lora 512 / rope 64 / nope 128
/ v 128), MoE: 1 shared + 256 routed experts (d_ff 2048 each), top-8 with
sigmoid scoring, normalization, and routed scaling 2.5; depth-1 MTP.

Per the assignment spec all 61 layers are MoE (the release's 3 dense lead
layers are not part of the assigned config). Node-limited routing is
omitted (single-pass top-k); noted in DESIGN.md.

Memory posture (the 671B-on-16GB-chips problem): bf16 params + FSDP over
(pod, data) + EP over model + factored Adafactor second moment + full
remat + 4-way microbatching — see EXPERIMENTS.md §Dry-run.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    head_dim=128,
    d_ff=2048,
    vocab_size=129280,
    attn_kind="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    qk_nope_head_dim=128,
    v_head_dim=128,
    n_experts=256,
    n_shared_experts=1,
    experts_per_token=8,
    moe_every=1,
    router_score="sigmoid",
    routed_scaling=2.5,
    capacity_factor=1.25,
    aux_loss_weight=0.0001,
    mtp_depth=1,
    mtp_loss_weight=0.3,
)

REDUCED = ModelConfig(
    name="deepseek-v3-671b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=64,
    vocab_size=256,
    attn_kind="mla",
    q_lora_rank=32,
    kv_lora_rank=32,
    qk_rope_head_dim=8,
    qk_nope_head_dim=16,
    v_head_dim=16,
    n_experts=8,
    n_shared_experts=1,
    experts_per_token=2,
    moe_every=1,
    router_score="sigmoid",
    routed_scaling=2.5,
    mtp_depth=1,
    dtype="float32",
)
