"""Table 2 analogue: peak FOM and per-rank FOM vs rank count; weak-scaling
efficiency — plus the NekBone-baseline comparison the paper motivates with.

Runs BOTH storage modes (hipBone assembled vs NekBone scattered) at N=7 on
1..8 emulated ranks and reports the per-iteration data-motion advantage
(the paper's Eq. data-motion analysis realized as measured wall-time).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

_CHILD = r"""
import os, json, time
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={RANKS}"
import jax, numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid, factor3
from repro.core.distributed import build_dist_problem, dist_cg, dist_cg_scattered
from repro.core.fom import nekbone_flops_per_iter, cg_iter_bytes, nekbone_iter_bytes

ranks, n, local, n_iter = RANKS, 7, (2, 2, 2), 50
grid = ProcessGrid(factor3(ranks))
mesh = make_mesh((ranks,), ("ranks",))
prob = build_dist_problem(n, grid, local, lam=1.0, dtype=jnp.float32)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((ranks, prob.m3)), jnp.float32)
bL = jnp.take(b, jnp.asarray(prob.l2g.reshape(-1)), axis=1).reshape(
    ranks, prob.e_local, -1)

def bench(run):
    fn = jax.jit(run)
    fn()[1].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        fn()[1].block_until_ready()
    return (time.perf_counter() - t0) / 3

t_asm = bench(dist_cg(prob, mesh, b, n_iter=n_iter, fused_operator=FUSED))
t_sca = bench(dist_cg_scattered(prob, mesh, bL, n_iter=n_iter))
e_tot = ranks * prob.e_local
flops = nekbone_flops_per_iter(e_tot, n) * n_iter
print(json.dumps({
    "ranks": ranks,
    "fom_assembled": flops / t_asm / 1e9,
    "fom_scattered": flops / t_sca / 1e9,
    "speedup": t_sca / t_asm,
    "bytes_model_ratio": nekbone_iter_bytes(e_tot, n, word=4)
                        / cg_iter_bytes(e_tot, n, word=4),
}))
"""


def _run(ranks: int, fused: bool | None = None) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    child = _CHILD.replace("RANKS", str(ranks)).replace("FUSED", repr(fused))
    out = subprocess.run(
        [sys.executable, "-c", child],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def main(quick: bool = True, fused: bool | None = None) -> list[str]:
    rows = [
        "table2,ranks,fom_assembled_gflops,fom_per_rank,weak_scaling_eff_pct,"
        "fom_scattered_gflops,assembled_speedup,bytes_model_ratio"
    ]
    base = None
    for ranks in ([1, 2, 4, 8] if not quick else [1, 4]):
        r = _run(ranks, fused)
        per = r["fom_assembled"] / ranks
        if base is None:
            base = per
        rows.append(
            f"table2,{ranks},{r['fom_assembled']:.2f},{per:.2f},"
            f"{100*per/base:.1f},{r['fom_scattered']:.2f},"
            f"{r['speedup']:.3f},{r['bytes_model_ratio']:.3f}"
        )
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--fused-operator",
        action="store_true",
        help="single-kernel fused assembled apply on the interior block "
             "(kernels/poisson_fused.py) in the assembled-mode runs",
    )
    args = ap.parse_args()
    print(
        "\n".join(
            main(quick=args.quick, fused=args.fused_operator or None)
        )
    )
