"""Exchange-routing selection (paper §MPI Communication behavior).

Two layers, matching how hipBone inherits gslib's setup-time selection:

* the legacy *library* sweep (``main``): times all-to-all / pairwise /
  crystal-router over a message-size ladder on 8 emulated ranks —
  reproducing the paper's claim structure that the crystal router wins
  small (latency-bound) messages and pairwise wins large ones;
* the *solver-site* plan build (``records``): runs the actual
  ``comms.plan`` autotuner over every halo-exchange site of a sharded
  pMG solve setup (CG sum, Schwarz expand/contract shells, each coarse
  level's exchanges) and reports per-site candidate timings, the winning
  routing and the analytic wire bytes — the ``exchange_records`` section
  of the benchmark json.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

_CHILD = r"""
import os, json, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comms.exchange import EXCHANGES

from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("r",))
out = {}
for chunk in [16, 256, 4096, 65536]:
    x = jnp.zeros((64, chunk), jnp.float32)
    row = {}
    for name, fn in EXCHANGES.items():
        f = jax.jit(shard_map(partial(fn, axis_name="r"), mesh=mesh,
                                  in_specs=P("r"), out_specs=P("r")))
        f(x).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(10):
            f(x).block_until_ready()
        row[name] = (time.perf_counter() - t0) / 10
    row["winner"] = min(row, key=row.get)
    out[chunk] = row
print(json.dumps(out))
"""

# halo-site plan build: the comms.plan autotuner over a real solver setup's
# site list.  Persistence is disabled — this run is timing *evidence*, not
# cache state, and must re-measure every time.
_CHILD_PLAN = r"""
import os, json
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["HIPBONE_EXCHANGE_CACHE"] = ""
import jax
jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
from repro.compat import make_mesh
from repro.comms.topology import ProcessGrid
from repro.comms import plan as xplan
from repro.core.precond import SCHWARZ_INNER_DEGREE
from repro.core.distributed import (
    build_dist_problem, build_pmg_levels, _exchange_sites, _schwarz_setup,
)

cfg = json.loads(os.environ["EXCHANGE_PLAN_CFG"])
grid = ProcessGrid((2, 2, 2))
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(
    cfg["n"], grid, tuple(cfg["local"]), lam=1.0, dtype=jnp.float64
)
levels, _ = build_pmg_levels(prob, None)
schwarz = [
    _schwarz_setup(lvl, min(1, lvl.n_degree - 1), SCHWARZ_INNER_DEGREE)
    for lvl in levels[:-1]
]
sites = _exchange_sites(prob, levels, schwarz)
plan = xplan.build_exchange_plan(
    mesh, grid, prob.axis_name, sites,
    policy="auto", repeats=cfg["repeats"],
)
recs = plan.records()
for r in recs:
    r["n"] = cfg["n"]
print(json.dumps(recs))
"""


def _run_child(code: str, extra_env: dict | None = None, timeout: int = 600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.update(extra_env or {})
    res = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=env, timeout=timeout,
    )
    if res.returncode != 0:
        raise RuntimeError(res.stderr[-2000:])
    return json.loads(res.stdout.strip().splitlines()[-1])


def records(quick: bool = True) -> list[dict]:
    """Per-site exchange plan records for the json summary.

    Each record: ``site`` (kind@level), per-candidate ``timings``
    ("routing/wire" -> best seconds), the winning ``routing`` +
    ``wire_dtype``, analytic ``bytes`` on the wire, and the plan
    ``signature`` the persistence layer would key on.
    """
    cfg = {
        "n": 4 if quick else 7,
        "local": [2, 2, 1] if quick else [2, 2, 2],
        "repeats": 3 if quick else 5,
    }
    return _run_child(
        _CHILD_PLAN,
        {"EXCHANGE_PLAN_CFG": json.dumps(cfg)},
        timeout=900,
    )


def rows_from(recs: list[dict]) -> list[str]:
    """CSV rows from plan records (one per site, winner + best timings)."""
    rows = ["exchange_plan,site,N,winner,wire,bytes,best_us,candidates"]
    for r in recs:
        best = min(r["timings"].values()) if r["timings"] else float("nan")
        cands = "|".join(
            f"{k}:{v*1e6:.0f}" for k, v in sorted(r["timings"].items())
        )
        rows.append(
            f"exchange_plan,{r['site']},{r.get('n', '')},{r['routing']},"
            f"{r['wire_dtype'] or 'native'},{r['bytes']},{best*1e6:.0f},"
            f"{cands}"
        )
    return rows


def main(quick: bool = True) -> list[str]:
    data = _run_child(_CHILD)
    rows = ["exchange,chunk_floats,all_to_all_us,pairwise_us,crystal_us,winner"]
    for chunk, row in data.items():
        rows.append(
            f"exchange,{chunk},{row['all_to_all']*1e6:.0f},"
            f"{row['pairwise']*1e6:.0f},{row['crystal_router']*1e6:.0f},"
            f"{row['winner']}"
        )
    return rows


if __name__ == "__main__":
    print("\n".join(main()))
    print("\n".join(rows_from(records())))
