"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import build_problem
from repro.kernels import ops, ref


@pytest.mark.parametrize("n", [1, 2, 3, 5, 7, 9, 15])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_poisson_kernel_matches_oracle(n, dtype, rng):
    shape = (2, 2, 2) if n > 7 else (3, 2, 2)
    prob = build_problem(n, shape, lam=1.3, deform=0.1, dtype=dtype)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), dtype)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=1.3)
    got = ops.poisson_local(
        u, prob.g, prob.w_local, prob.d, lam=1.3, interpret=True
    )
    scale = float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) / scale < 3e-6


@pytest.mark.parametrize("block_e", [1, 2, 4, 8])
def test_poisson_kernel_block_sweep(block_e, rng):
    prob = build_problem(4, (3, 1, 1), lam=0.5, deform=0.05, dtype=jnp.float32)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), jnp.float32)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=0.5)
    got = ops.poisson_local(
        u, prob.g, prob.w_local, prob.d, lam=0.5, block_e=block_e, interpret=True
    )
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-5, atol=1e-5)


def test_poisson_kernel_bf16(rng):
    prob = build_problem(3, (2, 2, 2), lam=1.0, dtype=jnp.bfloat16)
    e, p = prob.mesh.n_elements, prob.mesh.points_per_element
    u = jnp.asarray(rng.standard_normal((e, p)), jnp.bfloat16)
    want = ref.poisson_local_ref(u, prob.g, prob.w_local, prob.d, lam=1.0)
    got = ops.poisson_local(u, prob.g, prob.w_local, prob.d, lam=1.0, interpret=True)
    assert got.dtype == jnp.bfloat16
    scale = float(jnp.max(jnp.abs(want.astype(jnp.float32))))
    err = float(jnp.max(jnp.abs((got - want).astype(jnp.float32))))
    assert err / scale < 0.05  # bf16 tolerance


def test_vmem_budget_picks_smaller_blocks():
    from repro.kernels.poisson import pick_block_e, vmem_bytes_per_block

    assert pick_block_e(15) <= pick_block_e(7) or pick_block_e(7) == 256
    for n in (7, 15):
        eb = pick_block_e(n)
        assert vmem_bytes_per_block(eb, n + 1) <= 4 * 2**20


@pytest.mark.parametrize("n", [64, 128, 1000, 128 * 9, 40000])
def test_stream_kernels_match_oracle(n, rng):
    r = jnp.asarray(rng.standard_normal(n), jnp.float32)
    ap = jnp.asarray(rng.standard_normal(n), jnp.float32)
    alpha = jnp.float32(0.37)
    rn, rr = ops.fused_axpy_dot(r, ap, alpha, interpret=True)
    rn2, rr2 = ref.fused_axpy_dot_ref(r, ap, alpha)
    np.testing.assert_allclose(np.array(rn), np.array(rn2), atol=1e-6)
    assert abs(float(rr - rr2)) / float(rr2) < 1e-5

    out = ops.fused_xpay(r, ap, alpha, interpret=True)
    np.testing.assert_allclose(
        np.array(out), np.array(ref.fused_xpay_ref(r, ap, alpha)), atol=1e-6
    )

    w = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32))
    wd = ops.weighted_dot(w, r, ap, interpret=True)
    wd2 = ref.weighted_dot_ref(w, r, ap)
    assert abs(float(wd - wd2)) <= 1e-4 * abs(float(wd2)) + 1e-4


def test_assembled_operator_with_pallas_kernel(rng):
    from repro.core import poisson_assembled

    prob = build_problem(5, (2, 2, 2), lam=0.9, deform=0.12, dtype=jnp.float32)
    a_ref = poisson_assembled(prob)
    a_pl = poisson_assembled(prob, local_op=ops.make_local_op(interpret=True))
    x = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    want = a_ref(x)
    got = a_pl(x)
    scale = float(jnp.max(jnp.abs(want)))
    assert float(jnp.max(jnp.abs(got - want))) / scale < 3e-6
