"""scripts/compare_bench.py gating semantics: sections, iters, roofline."""
import importlib.util
import json
import pathlib

import pytest

def _load():
    spec = importlib.util.spec_from_file_location(
        "compare_bench",
        pathlib.Path(__file__).resolve().parents[1]
        / "scripts"
        / "compare_bench.py",
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cb = _load()


def _prec(n, kind, iters, *, lam=1.0, dtype="fp64", pct=None):
    r = {
        "n": n,
        "lam": lam,
        "kind": kind,
        "dtype": dtype,
        "iters_to_tol": iters,
    }
    if pct is not None:
        r["pct_roofline"] = pct
    return r


def _fig3(n, pct):
    return {"n": n, "pct_roofline": pct}


def _batched(n, kind, batch, iters, *, lam=1.0, status="converged"):
    return {
        "n": n,
        "lam": lam,
        "kind": kind,
        "dtype": "fp64",
        "batch": batch,
        "iters_to_tol": iters,
        "status": status,
    }


def _write(tmp_path, name, summary):
    p = tmp_path / name
    p.write_text(json.dumps(summary))
    return str(p)


def test_identical_passes(tmp_path):
    s = {"precond_records": [_prec(3, "jacobi", 20, pct=10.0)]}
    b = _write(tmp_path, "a.json", s)
    c = _write(tmp_path, "b.json", s)
    assert cb.main([b, c]) == 0


def test_iters_regression_fails(tmp_path):
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    c = _write(
        tmp_path, "b.json", {"precond_records": [_prec(3, "jacobi", 25)]}
    )
    assert cb.main([b, c]) == 1
    assert cb.main([b, c, "--slack", "5"]) == 0


def test_roofline_regression_fails(tmp_path):
    b = _write(
        tmp_path,
        "a.json",
        {"precond_records": [_prec(3, "jacobi", 20, pct=30.0)]},
    )
    c = _write(
        tmp_path,
        "b.json",
        {"precond_records": [_prec(3, "jacobi", 20, pct=10.0)]},
    )
    assert cb.main([b, c]) == 1
    assert cb.main([b, c, "--roofline-slack", "25"]) == 0


def test_fig3_roofline_gated(tmp_path):
    base = {
        "precond_records": [_prec(3, "jacobi", 20)],
        "fig3_records": [_fig3(3, 40.0), _fig3(7, 35.0)],
    }
    cand = {
        "precond_records": [_prec(3, "jacobi", 20)],
        "fig3_records": [_fig3(3, 12.0), _fig3(7, 35.0)],
    }
    b = _write(tmp_path, "a.json", base)
    c = _write(tmp_path, "b.json", cand)
    assert cb.main([b, c]) == 1
    assert cb.main([b, c, "--roofline-slack", "30"]) == 0


def test_missing_pct_field_not_gated(tmp_path):
    """Baselines predating the roofline fields compare on iterations only."""
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    c = _write(
        tmp_path,
        "b.json",
        {"precond_records": [_prec(3, "jacobi", 20, pct=1.0)]},
    )
    assert cb.main([b, c]) == 0


def test_baseline_missing_section_fails(tmp_path, capsys):
    """Satellite: candidate grew a gated section the baseline lacks."""
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    c = _write(
        tmp_path,
        "b.json",
        {
            "precond_records": [_prec(3, "jacobi", 20)],
            "fig3_records": [_fig3(3, 40.0)],
        },
    )
    assert cb.main([b, c]) == 1
    out = capsys.readouterr().out
    assert "fig3_records" in out and "--allow-new-sections" in out
    assert cb.main([b, c, "--allow-new-sections"]) == 0


def test_candidate_dropping_section_fails(tmp_path, capsys):
    b = _write(
        tmp_path,
        "a.json",
        {
            "precond_records": [_prec(3, "jacobi", 20)],
            "fig3_records": [_fig3(3, 40.0)],
        },
    )
    c = _write(
        tmp_path, "b.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    assert cb.main([b, c]) == 1
    assert "dropped" in capsys.readouterr().out
    # --allow-new-sections does NOT excuse shrinking coverage
    assert cb.main([b, c, "--allow-new-sections"]) == 1


def test_no_gated_sections_fails(tmp_path):
    b = _write(tmp_path, "a.json", {"sections": {}})
    c = _write(tmp_path, "b.json", {"sections": {}})
    assert cb.main([b, c]) == 1


def test_new_and_removed_cases_report_only(tmp_path):
    b = _write(
        tmp_path,
        "a.json",
        {
            "precond_records": [
                _prec(3, "jacobi", 20),
                _prec(3, "chebyshev", 15),
            ]
        },
    )
    c = _write(
        tmp_path,
        "b.json",
        {
            "precond_records": [
                _prec(3, "jacobi", 20),
                _prec(3, "schwarz", 12),
            ]
        },
    )
    assert cb.main([b, c]) == 0


def test_candidate_non_converged_status_fails(tmp_path, capsys):
    """Satellite: a gated row that did not CONVERGE is not a benchmark
    number — it fails outright even with identical iteration counts."""
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    row = _prec(3, "jacobi", 20)
    row["status"] = "max_iter"
    c = _write(tmp_path, "b.json", {"precond_records": [row]})
    assert cb.main([b, c]) == 1
    assert "status=max_iter" in capsys.readouterr().out
    # slack does not excuse a failed solve
    assert cb.main([b, c, "--slack", "100"]) == 1


def test_candidate_converged_status_passes(tmp_path):
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    row = _prec(3, "jacobi", 20)
    row["status"] = "converged"
    c = _write(tmp_path, "b.json", {"precond_records": [row]})
    assert cb.main([b, c]) == 0


def test_missing_status_is_legacy_converged(tmp_path):
    """Rows without a status field (pre-guardrail jsons, fig3's operator
    rows) are treated as converged — schema growth never breaks old
    baselines."""
    s = {
        "precond_records": [_prec(3, "jacobi", 20)],
        "fig3_records": [_fig3(3, 40.0)],
    }
    b = _write(tmp_path, "a.json", s)
    c = _write(tmp_path, "b.json", s)
    assert cb.main([b, c]) == 0


def test_non_converged_new_case_also_fails(tmp_path, capsys):
    """The status gate covers candidate-only (new) rows too, not just the
    shared comparison set."""
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    new_row = _prec(3, "schwarz", 500)
    new_row["status"] = "stagnated"
    c = _write(
        tmp_path,
        "b.json",
        {"precond_records": [_prec(3, "jacobi", 20), new_row]},
    )
    assert cb.main([b, c]) == 1
    assert "status=stagnated" in capsys.readouterr().out


def test_batched_section_gated_on_iters_and_status(tmp_path, capsys):
    """batched_records rows key on (n, lam, kind, dtype, batch) and gate
    on iterations + status like precond rows; wall times are ignored."""
    base = {
        "precond_records": [_prec(3, "jacobi", 20)],
        "batched_records": [
            _batched(3, "jacobi", 1, 30),
            _batched(3, "jacobi", 16, 31),
        ],
    }
    good = {
        "precond_records": [_prec(3, "jacobi", 20)],
        "batched_records": [
            _batched(3, "jacobi", 1, 30),
            _batched(3, "jacobi", 16, 31),
        ],
    }
    b = _write(tmp_path, "a.json", base)
    assert cb.main([b, _write(tmp_path, "b.json", good)]) == 0
    # B=16 case regresses by 2 iterations: fails at slack 0, passes at 2
    bad = json.loads(json.dumps(good))
    bad["batched_records"][1]["iters_to_tol"] = 33
    c = _write(tmp_path, "c.json", bad)
    assert cb.main([b, c]) == 1
    assert "B=16" in capsys.readouterr().out
    assert cb.main([b, c, "--slack", "2"]) == 0
    # a non-converged batched row fails outright
    sick = json.loads(json.dumps(good))
    sick["batched_records"][0]["status"] = "max_iter"
    assert cb.main([b, _write(tmp_path, "d.json", sick)]) == 1


def test_batched_section_new_needs_acknowledgement(tmp_path):
    """First PR with batched_records must pass --allow-new-sections."""
    b = _write(
        tmp_path, "a.json", {"precond_records": [_prec(3, "jacobi", 20)]}
    )
    c = _write(
        tmp_path,
        "b.json",
        {
            "precond_records": [_prec(3, "jacobi", 20)],
            "batched_records": [_batched(3, "jacobi", 1, 30)],
        },
    )
    assert cb.main([b, c]) == 1
    assert cb.main([b, c, "--allow-new-sections"]) == 0


def test_legacy_load_records_missing_section(tmp_path):
    p = _write(tmp_path, "a.json", {"sections": {}})
    with pytest.raises(SystemExit):
        cb.load_records(p)
