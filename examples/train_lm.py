"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the full production stack — model zoo config (scaled-down yi-style
llama), Adafactor/AdamW, microbatch accumulation, fault-tolerant runner
with checkpoint-restart, deterministic data pipeline:

    PYTHONPATH=src python examples/train_lm.py --steps 300

Optionally exercises error-feedback int8 gradient compression across a
data-parallel axis (--ranks 4 --compress).
"""
import argparse
import os
import sys

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1)
    args_pre, _ = ap.parse_known_args()
    if args_pre.ranks > 1 and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args_pre.ranks}"
        )
        os.execv(sys.executable, [sys.executable] + sys.argv)

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM
from repro.models.config import ModelConfig
from repro.models.model import init_model
from repro.training import (
    RunnerConfig,
    TrainRunner,
    adamw,
    make_train_step,
    warmup_cosine,
)


def build_cfg(size: str) -> ModelConfig:
    if size == "100m":
        return ModelConfig(
            name="llama-100m", family="dense", n_layers=8, d_model=512,
            n_heads=8, n_kv_heads=4, head_dim=64, d_ff=1536, vocab_size=8192,
            dtype="float32", remat=False,
        )
    return ModelConfig(
        name="llama-tiny", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=256, vocab_size=1024,
        dtype="float32", remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=1)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--size", default="100m", choices=["100m", "tiny"])
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress", action="store_true",
                    help="int8+error-feedback gradient psum over the dp axis")
    args = ap.parse_args()

    cfg = build_cfg(args.size)
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name}  {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")

    opt = adamw(b1=0.9, b2=0.95)
    opt_state = opt.init(params)
    schedule = warmup_cosine(peak_lr=3e-3, warmup=50, total=args.steps)

    if args.ranks > 1:
        # manual-DP variant: per-rank grads synced with (optionally int8)
        # psum under shard_map — the inter-pod compression path.
        from jax.sharding import PartitionSpec as P

        from repro.training.compress import ef_compressed_psum
        from repro.training.train_step import make_loss_fn
        from repro.training.optimizer import clip_by_global_norm

        from repro.compat import make_mesh, shard_map

        mesh = make_mesh((args.ranks,), ("dp",))
        loss_fn = make_loss_fn(cfg)
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params) \
            if args.compress else None

        def dp_step(params, opt_state, residual, batch, idx):
            pspec = jax.tree.map(lambda _: P(), params)

            def shard_fn(p, tokens, res):
                (_, m), g = grad_fn(p, {"tokens": tokens[0]})
                if args.compress:
                    pairs = jax.tree.map(
                        lambda gg, rr: ef_compressed_psum(gg, rr, "dp"), g, res
                    )
                    g = jax.tree.map(lambda o: o[0] / args.ranks, pairs,
                                     is_leaf=lambda x: isinstance(x, tuple))
                    res = jax.tree.map(lambda o: o[1], pairs,
                                       is_leaf=lambda x: isinstance(x, tuple))
                else:
                    g = jax.lax.pmean(g, "dp")
                m = jax.lax.pmean(m, "dp")
                return g, m, res

            res_spec = jax.tree.map(lambda _: P("dp"), residual) if args.compress else None
            fn = shard_map(
                shard_fn, mesh=mesh,
                in_specs=(pspec, P("dp"),
                          (jax.tree.map(lambda _: P("dp"), residual)
                           if args.compress else P())),
                out_specs=(pspec, P(), (res_spec if args.compress else P())),
            )
            res_in = residual if args.compress else jnp.zeros((args.ranks, 1))
            grads, metrics, res_out = fn(params, batch["tokens"][None].reshape(
                args.ranks, -1, batch["tokens"].shape[-1]), res_in)
            grads, gnorm = clip_by_global_norm(grads, 1.0)
            params, opt_state = opt.update(grads, opt_state, params,
                                           schedule(idx))
            return params, opt_state, res_out, dict(metrics, grad_norm=gnorm)

        step = jax.jit(dp_step)
        data = SyntheticLM(vocab_size=cfg.vocab_size, batch=args.batch,
                           seq_len=args.seq)
        p, s, r = params, opt_state, (residual if args.compress
                                      else jnp.zeros((args.ranks, 1)))
        t0 = time.time()
        for i in range(args.steps):
            batch = {"tokens": jnp.asarray(data(i)["tokens"])}
            p, s, r, m = step(p, s, r, batch, jnp.int32(i))
            if (i + 1) % 25 == 0:
                print(f"step {i+1}: nll={float(m['nll']):.4f}")
        print(f"done in {time.time()-t0:.1f}s "
              f"(compress={'on' if args.compress else 'off'})")
        return

    step_fn = jax.jit(make_train_step(
        cfg, opt, schedule, microbatches=args.microbatches,
    ))
    data = SyntheticLM(vocab_size=cfg.vocab_size, batch=args.batch,
                       seq_len=args.seq)

    def data_fn(i):
        return {"tokens": jnp.asarray(data(i)["tokens"])}

    runner = TrainRunner(
        RunnerConfig(
            total_steps=args.steps, checkpoint_dir=args.ckpt_dir,
            checkpoint_every=100, log_every=25,
        ),
        step_fn, data_fn, params, opt_state,
    )
    runner.try_restore()   # resume if a previous run was interrupted
    out = runner.run()
    print(f"final: {out}")


if __name__ == "__main__":
    main()
