"""Solver serving engine: slot-batched multi-RHS solves over a setup cache.

The production shape of hipBone's workload is a *service*: a stream of
screened-Poisson solve requests against a small population of problem
setups (same mesh every time step, a handful of λ/preconditioner
configurations).  The engine turns that stream into efficient batched
dispatches:

  1. **Group** pending requests by their dispatch key — the
     :func:`core.solver_cache.solver_setup_key` (mesh signature, N, λ,
     precond config, dtype) plus the solve-time knobs (tol, n_iter,
     cg_variant).  Requests in one group share everything but the RHS.
  2. **Slot-batch** each group into slabs of ``max_batch`` columns and
     stack the RHS vectors into a (B, n_global) block.
  3. **Dispatch** one :func:`core.cg.batched_cg_assembled` per slab —
     one operator apply streams all B columns; columns stop
     independently, so an easy RHS doesn't pay for its hard neighbour's
     iterations.

Setup is cached across dispatches (:class:`core.solver_cache.SolverCache`):
the first slab of a key pays the build (operator + preconditioner chain),
every later slab — and every later *request batch* — reuses it untouched.
Each dispatch appends a json-ready record with the cache hit/miss state,
wall times and per-column iterations/status, so the batched-solve
benchmark (and a service log) can assert the hit path did zero setup.

The seed's LLM decode engine this replaced lives on in
``repro.serving.lm`` (same slot-batching idea, token streams instead of
RHS columns); ``examples/serve_lm.py`` still drives it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Mapping

import jax
import jax.numpy as jnp

from ..core.cg import batched_cg_assembled, status_name
from ..core.solver_cache import SolverCache, SolverSetup, solver_setup_key
from ..kernels import ops

__all__ = ["SolveRequest", "SolveResponse", "SolverEngine", "SolverServeConfig"]


@dataclasses.dataclass(frozen=True)
class SolverServeConfig:
    """Engine knobs (not part of any cache key).

    ``max_batch`` bounds one dispatch's slot count; ``fuse`` forces the
    Pallas fused vector stages on/off (None = the per-dtype auto policy
    ``kernels.ops.should_fuse_streams``); ``interpret`` is the usual
    Pallas CPU/TPU switch for those stages; ``max_cache_entries`` bounds
    the setup cache LRU-style.
    """

    max_batch: int = 16
    fuse: bool | None = None
    interpret: bool | None = None
    max_cache_entries: int | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One screened-Poisson solve: A(prob) x = b to tol, preconditioned.

    ``precond`` holds the ``make_preconditioner`` keyword config (degree,
    pmg ladder, …); ``tol``/``n_iter``/``cg_variant`` are solve-time knobs
    — they group dispatches but never touch the setup cache key.
    """

    prob: Any  # core.operator.PoissonProblem
    b: jax.Array
    kind: str = "none"
    precond: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    tol: float | None = 1e-8
    n_iter: int = 200
    cg_variant: str = "standard"


@dataclasses.dataclass(frozen=True)
class SolveResponse:
    """One column's result plus the dispatch context it rode in."""

    x: jax.Array
    rdotr: float
    iterations: int
    status: int
    status_name: str
    setup_cache: str  # "hit" | "miss" — the setup-cache state this dispatch saw
    batch_size: int   # columns in the slab this request was solved with
    solve_s: float    # wall time of the whole slab's batched solve

    @property
    def converged(self) -> bool:
        return self.status == 0


class SolverEngine:
    """Accepts solve requests, groups them by setup, dispatches batched.

    ``submit`` queues; ``flush`` solves everything pending and returns
    responses in submission order; ``solve`` is submit-all-then-flush.
    A shared :class:`SolverCache` may be injected (e.g. pre-warmed by a
    benchmark); otherwise the engine owns one.
    """

    def __init__(
        self,
        cfg: SolverServeConfig | None = None,
        cache: SolverCache | None = None,
    ):
        self.cfg = cfg or SolverServeConfig()
        self.cache = cache or SolverCache(
            max_entries=self.cfg.max_cache_entries
        )
        self._pending: list[SolveRequest] = []
        self.records: list[dict] = []

    # -- request intake ------------------------------------------------

    def submit(self, req: SolveRequest) -> int:
        """Queue a request; returns its ticket (position in flush order)."""
        if req.b.ndim != 1:
            raise ValueError(
                f"SolveRequest.b must be a single (n_global,) RHS column, "
                f"got shape {req.b.shape}; submit one request per column"
            )
        if req.b.shape[0] != req.prob.n_global:
            raise ValueError(
                f"RHS length {req.b.shape[0]} != n_global {req.prob.n_global}"
            )
        self._pending.append(req)
        return len(self._pending) - 1

    def solve(self, requests: list[SolveRequest]) -> list[SolveResponse]:
        for req in requests:
            self.submit(req)
        return self.flush()

    def solve_one(self, req: SolveRequest) -> SolveResponse:
        self.submit(req)
        return self.flush()[0]

    # -- dispatch ------------------------------------------------------

    def _dispatch_key(self, req: SolveRequest) -> tuple:
        return solver_setup_key(req.prob, req.kind, **dict(req.precond)) + (
            ("tol", req.tol),
            ("n_iter", int(req.n_iter)),
            ("cg_variant", req.cg_variant),
        )

    def _cg_kwargs(self, req: SolveRequest, setup: SolverSetup) -> dict:
        fuse = (
            ops.should_fuse_streams(req.prob.dtype)
            if self.cfg.fuse is None
            else self.cfg.fuse
        )
        kwargs: dict = {
            "n_iter": int(req.n_iter),
            "tol": req.tol,
            "precond": setup.precond,
            "cg_variant": req.cg_variant,
        }
        if fuse:
            # per-column Pallas stage; batched_cg_assembled's vmap batches
            # it into the 2-D (B, rows, 128) layout (kernels/streams.py)
            interp = self.cfg.interpret
            kwargs["fused_update"] = lambda r, ap, alpha: ops.fused_axpy_dot(
                r, ap, alpha, interpret=interp
            )
        return kwargs

    def flush(self) -> list[SolveResponse]:
        """Solve all pending requests; responses in submission order."""
        pending, self._pending = self._pending, []
        groups: dict[tuple, list[int]] = {}
        for ticket, req in enumerate(pending):
            groups.setdefault(self._dispatch_key(req), []).append(ticket)

        responses: list[SolveResponse | None] = [None] * len(pending)
        for key, tickets in groups.items():
            for lo in range(0, len(tickets), self.cfg.max_batch):
                slab = tickets[lo : lo + self.cfg.max_batch]
                self._dispatch(key, [pending[t] for t in slab], slab, responses)
        return responses  # type: ignore[return-value]

    def _dispatch(
        self,
        key: tuple,
        reqs: list[SolveRequest],
        tickets: list[int],
        responses: list,
    ) -> None:
        req0 = reqs[0]
        setup_key = solver_setup_key(
            req0.prob, req0.kind, **dict(req0.precond)
        )
        state = "hit" if setup_key in self.cache else "miss"
        setup = self.cache.get_or_build(
            req0.prob, req0.kind, **dict(req0.precond)
        )
        b_block = jnp.stack([r.b for r in reqs])
        t0 = time.perf_counter()
        res = batched_cg_assembled(
            setup.operator, b_block, **self._cg_kwargs(req0, setup)
        )
        jax.block_until_ready(res.x)
        solve_s = time.perf_counter() - t0

        iters = [int(i) for i in res.iterations]
        stats = [int(s) for s in res.status]
        for col, ticket in enumerate(tickets):
            responses[ticket] = SolveResponse(
                x=res.x[col],
                rdotr=float(res.rdotr[col]),
                iterations=iters[col],
                status=stats[col],
                status_name=status_name(stats[col]),
                setup_cache=state,
                batch_size=len(reqs),
                solve_s=solve_s,
            )
        self.records.append(
            {
                "kind": req0.kind,
                "batch": len(reqs),
                "setup_cache": state,
                "setup_build_s": setup.build_s if state == "miss" else 0.0,
                "solve_s": solve_s,
                "per_solve_s": solve_s / len(reqs),
                "iterations": iters,
                "status": stats,
                "cache": self.cache.stats(),
            }
        )
