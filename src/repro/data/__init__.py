"""repro.data — deterministic, restartable data pipelines."""
from .pipeline import SyntheticLM, TextLM

__all__ = ["SyntheticLM", "TextLM"]
