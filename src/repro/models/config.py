"""Model configuration schema covering all ten assigned architectures.

One frozen dataclass describes dense/GQA transformers, MLA (DeepSeek),
MoE (Mixtral/DeepSeek/Jamba), SSM (Mamba-2), hybrid interleaves (Jamba),
and the modality-stub backbones (Chameleon VLM, MusicGen audio).

Layer heterogeneity (Jamba's 1:7 attn:mamba, Gemma-3's 5:1 local:global,
MoE-every-other) is expressed as a repeating *period* of layer kinds; the
model scans over full periods with stacked parameters and unrolls the
remainder — no wasted parameters, no traced branching.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ModelConfig", "LayerKind"]


@dataclasses.dataclass(frozen=True)
class LayerKind:
    """Static structure of one layer inside the repeating period."""

    mixer: Literal["attn", "attn_local", "mamba"]
    ffn: Literal["dense", "moe", "none"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention
    attn_kind: str = "gqa"            # gqa | mla | none
    rope_theta: float = 10000.0
    sliding_window: int | None = None  # SWA width (mixtral, gemma3 locals)
    global_every: int = 0             # gemma3: every k-th layer is global attn
    qk_norm: bool = False             # chameleon, gemma3
    parallel_residual: bool = False   # command-r
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    gemma_norm: bool = False          # RMSNorm scale is (1 + w)
    emb_scale: bool = False           # embed * sqrt(d_model)  (gemma)
    tie_embeddings: bool = False
    attn_bias: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu (SwiGLU) | gelu (GeGLU)
    logit_soft_cap: float | None = None

    # MLA (deepseek-v3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 0                # MoE on layers with idx % moe_every == moe_offset
    moe_offset: int = 0
    router_score: str = "softmax"     # softmax (mixtral/jamba) | sigmoid (dsv3)
    routed_scaling: float = 1.0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.0

    # SSM (mamba-2 SSD)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 64

    # hybrid (jamba): attention at idx % attn_every == attn_offset
    attn_every: int = 0
    attn_offset: int = 0

    # deepseek multi-token prediction
    mtp_depth: int = 0
    mtp_loss_weight: float = 0.3

    # runtime
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True

    # ---------------------------------------------------------------- kinds
    def layer_kind(self, idx: int) -> LayerKind:
        if self.attn_kind == "none":
            mixer = "mamba"
        elif self.attn_every:
            mixer = "attn" if idx % self.attn_every == self.attn_offset else "mamba"
        elif self.global_every:
            mixer = (
                "attn" if (idx + 1) % self.global_every == 0 else "attn_local"
            )
        elif self.sliding_window:
            mixer = "attn_local"
        else:
            mixer = "attn"
        if self.n_experts and self.moe_every:
            ffn = "moe" if idx % self.moe_every == self.moe_offset else "dense"
        elif self.n_experts:
            ffn = "moe"
        else:
            ffn = "dense"
        if ffn == "dense" and self.d_ff == 0:
            ffn = "none"                       # pure-SSM layers have no FFN
        return LayerKind(mixer=mixer, ffn=ffn)

    @property
    def period(self) -> int:
        """Length of the repeating layer-kind pattern."""
        import math

        p = 1
        if self.attn_every:
            p = math.lcm(p, self.attn_every)
        if self.global_every:
            p = math.lcm(p, self.global_every)
        if self.n_experts and self.moe_every:
            p = math.lcm(p, self.moe_every)
        return p

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def n_remainder(self) -> int:
        return self.n_layers % self.period

    def period_kinds(self) -> tuple[LayerKind, ...]:
        return tuple(self.layer_kind(i) for i in range(self.period))

    def remainder_kinds(self) -> tuple[LayerKind, ...]:
        start = self.n_periods * self.period
        return tuple(self.layer_kind(start + i) for i in range(self.n_remainder))

    # --------------------------------------------------------------- derived
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode (see DESIGN.md skip list)."""
        if self.attn_kind == "none":
            return True
        if self.attn_every:           # hybrid: mostly SSM
            return True
        if self.global_every:         # gemma3 local:global
            return True
        if self.sliding_window:       # bounded-window KV
            return True
        return False

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind.mixer == "mamba":
                di, g, ns, nh = (
                    self.d_inner,
                    self.ssm_groups,
                    self.ssm_state,
                    self.ssm_heads,
                )
                total += d * (2 * di + 2 * g * ns + nh) + di * d + di
            elif self.attn_kind == "mla":
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                total += d * self.q_lora_rank
                total += self.q_lora_rank * self.n_heads * qk
                total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                total += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                total += self.n_heads * self.v_head_dim * d
            else:
                total += d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * self.head_dim * d
            if kind.ffn == "moe":
                total += d * self.n_experts  # router
                total += 3 * d * self.d_ff * (self.n_experts + self.n_shared_experts)
            else:
                ff = self.d_ff if kind.ffn == "dense" else 0
                total += 3 * d * ff
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed-to experts)."""
        if not self.n_experts:
            return self.n_params()
        d = self.d_model
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if kind.mixer == "mamba":
                di, g, ns, nh = (
                    self.d_inner,
                    self.ssm_groups,
                    self.ssm_state,
                    self.ssm_heads,
                )
                total += d * (2 * di + 2 * g * ns + nh) + di * d + di
            elif self.attn_kind == "mla":
                qk = self.qk_nope_head_dim + self.qk_rope_head_dim
                total += d * self.q_lora_rank
                total += self.q_lora_rank * self.n_heads * qk
                total += d * (self.kv_lora_rank + self.qk_rope_head_dim)
                total += self.kv_lora_rank * self.n_heads * (
                    self.qk_nope_head_dim + self.v_head_dim
                )
                total += self.n_heads * self.v_head_dim * d
            else:
                total += d * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
                total += self.n_heads * self.head_dim * d
            if kind.ffn == "moe":
                total += d * self.n_experts
                total += 3 * d * self.d_ff * (
                    self.experts_per_token + self.n_shared_experts
                )
            else:
                total += 3 * d * self.d_ff
        return total

    def validate(self) -> None:
        assert self.n_layers >= 1 and self.d_model >= 1
        if self.attn_kind != "none":
            assert self.n_heads % max(self.n_kv_heads, 1) == 0
        if self.n_experts:
            assert self.experts_per_token >= 1
        if self.attn_every or self.global_every or (self.n_experts and self.moe_every):
            assert self.n_layers >= self.period
