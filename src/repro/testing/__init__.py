"""repro.testing — fault-injection and test-support utilities.

Not imported by any solver path; tests (and chaos-style soak scripts) use
these to prove the guardrails in `repro.core.cg` / `repro.core.resilience`
actually fire and recover (`repro.testing.faults`), and that the
variable-coefficient operator converges at spectral order against
manufactured solutions (`repro.testing.mms`).
"""
from .faults import (
    corrupt_wire,
    force_fused_failure,
    mask_precond,
    nan_at_iteration,
    negate_precond,
    on_attempt,
    skew_operator,
)
from .mms import (
    MMS_CASES,
    MMSCase,
    convergence_sweep,
    discrete_l2_error,
    exact_solution_global,
    mms_problem,
    mms_rhs,
)

__all__ = [
    "MMSCase",
    "MMS_CASES",
    "convergence_sweep",
    "discrete_l2_error",
    "exact_solution_global",
    "mms_problem",
    "mms_rhs",
    "corrupt_wire",
    "force_fused_failure",
    "mask_precond",
    "nan_at_iteration",
    "negate_precond",
    "on_attempt",
    "skew_operator",
]
