"""repro.comms — device-aware nearest-neighbor collectives (the gslib rewrite).

Exchange algorithms (all-to-all / pairwise / crystal router), structured
halo sum/copy exchanges, process-grid topology, and the autotune harness
that times the algorithms and picks the fastest — hipBone's setup-time
exchange selection.
"""
from .autotune import autotune_exchange
from .exchange import (
    EXCHANGES,
    exchange_all_to_all,
    exchange_crystal_router,
    exchange_pairwise,
    get_exchange,
)
from .halo import copy_exchange, rank_coords, sum_exchange
from .topology import ProcessGrid, factor3, hypercube_stages

__all__ = [k for k in dir() if not k.startswith("_")]
