"""Exchange-algorithm autotuning — hipBone's setup-time routing selection.

"During the initial setup of the gather-scatter library, each of the
exchange routines is timed, and the fastest exchange is selected for use in
subsequent communication operations." (paper §MPI Communication)

We do the same: jit each exchange over the actual mesh axis and buffer
shape, time a few repetitions, and cache the winner per
(axis, shape, dtype) key. On this CPU container the timings are host
emulation, but the machinery (and its tests) carry to real ICI unchanged.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .exchange import EXCHANGES

__all__ = ["autotune_exchange", "clear_cache"]

_CACHE: dict[tuple, str] = {}


def clear_cache() -> None:
    """Drop every cached winner (tests force a re-time through this)."""
    _CACHE.clear()


def _mesh_key(mesh: jax.sharding.Mesh) -> tuple:
    """Content identity of a mesh: axis layout + device ids.

    ``id(mesh)`` is wrong twice over — two meshes over the same devices
    miss each other's timings, and a dead mesh's id can be recycled by a
    *different* mesh, silently serving it a stale winner.
    """
    return (
        tuple(mesh.shape.items()),
        tuple(int(d.id) for d in mesh.devices.flat),
    )


def autotune_exchange(
    mesh: jax.sharding.Mesh,
    axis_name: str,
    chunk_shape: tuple[int, ...],
    dtype=jnp.float32,
    *,
    repeats: int = 3,
    candidates: tuple[str, ...] | None = None,
) -> str:
    """Time each exchange algorithm on (P, *chunk_shape) buffers; return winner."""
    key = (_mesh_key(mesh), axis_name, tuple(chunk_shape), jnp.dtype(dtype).name)
    if key in _CACHE:
        return _CACHE[key]

    p = mesh.shape[axis_name]
    names = list(candidates or EXCHANGES)
    if p & (p - 1):  # crystal router needs a power of two
        names = [n for n in names if n != "crystal_router"]

    global_shape = (p * p,) + tuple(chunk_shape)
    x = jnp.zeros(global_shape, dtype)
    best_name, best_t = names[0], float("inf")
    for name in names:
        fn = EXCHANGES[name]
        shmapped = jax.jit(
            shard_map(
                functools.partial(fn, axis_name=axis_name),
                mesh=mesh,
                in_specs=P(axis_name),
                out_specs=P(axis_name),
            )
        )
        try:
            shmapped(x).block_until_ready()  # compile + warm
            t0 = time.perf_counter()
            for _ in range(repeats):
                shmapped(x).block_until_ready()
            dt = (time.perf_counter() - t0) / repeats
        except Exception:  # algorithm unavailable on this topology
            continue
        if dt < best_t:
            best_name, best_t = name, dt
    _CACHE[key] = best_name
    return best_name
