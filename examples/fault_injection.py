"""Fault-injection smoke: every guardrail detector fires, every fault recovers.

Drives the `repro.testing.faults` injectors through a real solve and checks
that each one trips exactly the `SolveStatus` it models, then that the
fallback chain (`repro.core.resilience`) recovers each scenario to
CONVERGED.  Exits non-zero on the first wrong verdict — CI runs this as
the fault-injection smoke leg, once plain and once under HIPBONE_FUSED=1
(where the forced-probe-failure scenario additionally proves the fused
operator degrades to the split pipeline instead of crashing).

    PYTHONPATH=src python examples/fault_injection.py
"""
import os
import warnings

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp
import numpy as np

from repro.core import (
    SolveStatus,
    build_problem,
    cg_assembled,
    poisson_assembled,
    solve_with_fallback,
    status_name,
)
from repro.core.precond import make_preconditioner
from repro.kernels import ops
from repro.testing import (
    force_fused_failure,
    mask_precond,
    nan_at_iteration,
    negate_precond,
    on_attempt,
    skew_operator,
)

FAILED = []


def check(name: str, got, want) -> None:
    ok = got == want
    print(f"  {'ok' if ok else 'FAIL':>4}  {name}: {got}" +
          ("" if ok else f" (wanted {want})"))
    if not ok:
        FAILED.append(name)


def main() -> int:
    prob = build_problem(3, (3, 2, 2), lam=0.7, deform=0.2,
                         dtype=jnp.float64)
    a = poisson_assembled(prob)
    b = jnp.asarray(np.random.default_rng(0).standard_normal(prob.n_global))
    pc, _ = make_preconditioner("jacobi", prob, a)

    print("detectors:")
    res = cg_assembled(a, b, n_iter=500, tol=1e-8)
    check("healthy solve", status_name(res.status), "converged")

    res = cg_assembled(a, jnp.zeros_like(b), n_iter=500, tol=1e-8)
    check("zero rhs", (status_name(res.status), int(res.iterations)),
          ("converged", 0))

    res = cg_assembled(nan_at_iteration(a, 3), b, n_iter=500, tol=1e-8)
    check("NaN in A·p at iteration 3",
          (status_name(res.status), int(res.iterations)),
          ("breakdown_nan", 3))

    res = cg_assembled(a, b, n_iter=500, tol=1e-8,
                       precond=negate_precond(pc))
    check("sign-flipped M⁻¹",
          (status_name(res.status), int(res.iterations)),
          ("breakdown_indefinite", 0))

    res = cg_assembled(skew_operator(a, 5000.0), b, n_iter=500, tol=1e-8)
    check("skew-corrupted operator", status_name(res.status), "diverged")

    res = cg_assembled(a, b, n_iter=500, tol=1e-12, cg_variant="flexible",
                       precond=mask_precond(pc, keep_every=7))
    check("rank-deficient M⁻¹", status_name(res.status), "stagnated")

    print("fallback chain:")
    fb = solve_with_fallback(
        prob, b, precond="jacobi", tol=1e-8,
        instrument=on_attempt(0, operator=lambda op: skew_operator(op, 5000.0)),
    )
    check("transient fault → retry",
          (fb.recovered, [x.action for x in fb.attempts]),
          (True, ["initial", "retry"]))

    fb = solve_with_fallback(
        prob, b, precond="jacobi", tol=1e-8,
        instrument=lambda i, op, m: (op, None if m is None
                                     else negate_precond(m)),
    )
    check("persistent M⁻¹ fault → ladder walk",
          (fb.recovered, fb.attempts[-1].precond), (True, "none"))
    for att in fb.record():
        print(f"        attempt {att['attempt']}: {att['action']:>32} "
              f"precond={att['precond']:<7} -> {att['status']}")

    print("fused-operator degradation:")
    # force the static policy to "yes" so the probe is consulted even on a
    # CPU host — the degradation must hold under HIPBONE_FUSED=1 too
    os.environ["HIPBONE_FUSED"] = "1"
    shape = dict(n_degree=prob.mesh.n_degree, n_global=prob.n_global)
    with force_fused_failure():
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            fuse = ops.should_fuse_operator(jnp.float64, **shape)
        check("probe failure → split pipeline",
              (fuse, sum(issubclass(x.category, RuntimeWarning)
                         for x in w)),
              (False, 1))
        res = cg_assembled(poisson_assembled(prob), b, n_iter=500, tol=1e-8)
        check("solve on the degraded path", status_name(res.status),
              "converged")

    if FAILED:
        print(f"\n{len(FAILED)} scenario(s) failed: {FAILED}")
        return 1
    print("\nall fault scenarios detected and recovered")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
