"""Shared model components: norms, RoPE, embeddings, activations."""
from __future__ import annotations


import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamBuilder

__all__ = [
    "rms_norm",
    "layer_norm",
    "norm",
    "init_norm",
    "rope",
    "init_embedding",
    "embed",
    "unembed",
    "act_fn",
    "soft_cap",
]


def init_norm(key, cfg: ModelConfig, name: str = "norm") -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=jnp.float32)  # norms kept in f32
    init = "zeros" if cfg.gemma_norm else "ones"
    pb.param("scale", (cfg.d_model,), ("embed_act",), init=init)
    return pb.collect()


def rms_norm(x: jax.Array, scale: jax.Array, *, eps: float, plus_one: bool) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    s = (1.0 + scale) if plus_one else scale
    return (y * s).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, *, eps: float) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * scale).astype(dt)


def norm(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["scale"], eps=cfg.norm_eps)
    return rms_norm(x, p["scale"], eps=cfg.norm_eps, plus_one=cfg.gemma_norm)


def rope(x: jax.Array, positions: jax.Array, *, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D) or (..., S, D); positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def init_embedding(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    pb.param("tok", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0)
    if not cfg.tie_embeddings:
        pb.param(
            "out",
            (cfg.d_model, cfg.vocab_size),
            ("embed", "vocab"),
            scale=cfg.d_model**-0.5,
        )
    return pb.collect()


def embed(tokens: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0)
    if cfg.emb_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def unembed(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["tok"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["out"])
    return soft_cap(logits, cfg.logit_soft_cap)


def soft_cap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return lambda v: jax.nn.gelu(v, approximate=True)
    raise ValueError(f"unknown activation {name}")
