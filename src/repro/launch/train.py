"""Production train driver: ``python -m repro.launch.train --arch <id> ...``

On a real TPU pod this runs under `jax.distributed.initialize()` with the
production mesh; on this container it runs reduced configs single-device.
The step function is identical to the one the dry-run lowers.
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models.blocks import MeshContext
from repro.models.model import init_model
from repro.training import (
    RunnerConfig, TrainRunner, adafactor, make_train_step, warmup_cosine,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized); full configs need a pod")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    opt = adafactor()
    step = jax.jit(make_train_step(
        cfg, opt, warmup_cosine(peak_lr=1e-3, warmup=10, total=args.steps),
        MeshContext(), microbatches=args.microbatches,
    ))
    data = SyntheticLM(vocab_size=cfg.vocab_size, batch=args.batch,
                       seq_len=args.seq)
    runner = TrainRunner(
        RunnerConfig(total_steps=args.steps, checkpoint_dir=args.ckpt_dir,
                     checkpoint_every=max(args.steps // 2, 1), log_every=10),
        step, lambda i: {"tokens": jnp.asarray(data(i)["tokens"])},
        params, opt.init(params),
    )
    runner.try_restore()
    print(runner.run())


if __name__ == "__main__":
    main()
