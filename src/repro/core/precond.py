"""Preconditioners for the screened-Poisson CG solve.

NekBone (and hence hipBone) fixes 100 unpreconditioned CG iterations, but
the parent applications do not: production Nek5000/RS Poisson solves are
preconditioned (Jacobi, Chebyshev-accelerated Jacobi, Schwarz, p-multigrid).
This module supplies the first three rungs of that ladder on top of the
existing assembled-storage machinery:

  * **Jacobi**: ``M = diag(A)`` where ``A = Z^T (S_L + λW) Z``.  The
    assembled diagonal is computed *without materializing S* — the
    element-local diagonal of the tensor-product stiffness

        diag(S_L^e)[t,s,r] = Σ_i D[i,r]² G_rr[t,s,i]
                           + Σ_j D[j,s]² G_ss[t,j,r]
                           + Σ_k D[k,t]² G_tt[k,s,r]
                           + 2 (D_rr D_ss G_rs + D_rr D_tt G_rt
                                + D_ss D_tt G_st)[t,s,r]

    (the three contractions are the divergence einsums with D squared and
    the diagonal metric blocks; the cross terms collapse to products of
    the diagonal entries of D), then gathered with Z^T like any other
    element-local field.

  * **Chebyshev–Jacobi**: a degree-k Chebyshev polynomial in the
    Jacobi-preconditioned operator ``D⁻¹A``, i.e. ``M⁻¹ = q_k(D⁻¹A) D⁻¹``.
    Because q_k is a fixed polynomial the map r → z is *linear and
    symmetric* (D^{1/2}-similarity), so plain PCG remains valid — no
    flexible-CG machinery needed.  The spectrum bound λ_max(D⁻¹A) is
    estimated by power iteration from a deterministic high-frequency seed
    vector; the smoothing interval is the usual [λ_max/ratio, safety·λ_max].

  * **p-multigrid** (``pmg``): the production Nek5000/RS configuration — a
    V-cycle over a degree ladder N → ⌈N/2⌉ → … → 1 with Chebyshev–Jacobi
    smoothing on every level and a direct (or Chebyshev/Jacobi-iterated)
    solve on the degree-1 coarsest level.  Transfers are the tensor-product
    lift of the 1-D GLL interpolation matrix (``sem.interpolation_matrix``);
    prolongation is nodal interpolation expressed through the assembled
    machinery as ``P = Z_f^T W_f Ĵ Z_c`` (averaging gather of the
    element-local interpolant) and restriction is its *exact transpose*
    ``R = Z_c^T Ĵ^T W_f Z_f``, so the V-cycle is a symmetric linear map and
    plain PCG remains valid.

  * **overlapping Schwarz** (``schwarz``): per-element extended-block local
    solves via tensor-product fast diagonalization (core.schwarz), combined
    as symmetric weighted additive Schwarz — the Nek5000/RS smoother for
    deformed / ill-conditioned meshes.  Available standalone
    (``make_preconditioner("schwarz", ...)``) and as the pMG smoother
    (``make_pmg_preconditioner(smoother="schwarz")``, Chebyshev-accelerated
    the way nekRS runs it).

Everything here is expressed through the caller's ``operator`` /
``dot`` / ``psum`` callables, so the same code serves the single-device
assembled path and the sharded padded-box path in core.distributed (where
dots are replica-masked and psum is a real collective).

**Precision is a first-class axis**: ``make_preconditioner(...,
precond_dtype=jnp.float32)`` builds the whole ladder rung — diagonals,
Chebyshev A-apply chains, Schwarz FDM blocks, every pMG level and transfer
— in fp32 and wraps it in a single :func:`cast_apply` boundary, so an fp64
outer PCG streams half the preconditioner bytes (the production
Nek5000/NekRS trick).  The fp32 apply is symmetric only to fp32 roundoff
when viewed from fp64, so pair it with ``cg_variant="flexible"``
(core.cg) near tight tolerances.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import sem
from .gather_scatter import gather, scatter
from .schwarz import SCHWARZ_INNER_DEGREE, make_schwarz_apply

__all__ = [
    "local_operator_diagonal",
    "assembled_diagonal",
    "masked_dinv",
    "masked_seed",
    "power_lambda_max",
    "lanczos_extremes",
    "jacobi_apply",
    "chebyshev_apply",
    "cast_apply",
    "deterministic_seed_vector",
    "tensor3_interp",
    "pmg_degree_ladder",
    "make_transfer_pair",
    "make_vcycle",
    "make_pmg_preconditioner",
    "make_preconditioner",
    "precond_signature",
    "PRECOND_KINDS",
    "PMG_SMOOTHERS",
    "PMG_COARSE_OPS",
    "CHEB_LMIN_RATIO",
    "CHEB_SAFETY",
    "CHEB_LMIN_SAFETY",
    "PMG_SMOOTH_RATIO",
    "SCHWARZ_INNER_DEGREE",
    "pmg_smooth_degree_default",
    "smoother_interval",
]

PRECOND_KINDS = ("none", "jacobi", "chebyshev", "schwarz", "pmg")
PMG_SMOOTHERS = ("chebyshev", "schwarz")
PMG_COARSE_OPS = ("redisc", "galerkin", "galerkin_mat")

# Standard Chebyshev-smoother interval: [lmax/ratio, safety * lmax].
CHEB_LMIN_RATIO = 30.0
CHEB_SAFETY = 1.1
# Lanczos interior Ritz values overestimate λ_min — back the bound off.
CHEB_LMIN_SAFETY = 0.8
# pMG smoother targets the top 1/ratio of the spectrum; the rest is the
# coarse grid's job (degree halving shifts roughly half the spectrum down).
# When Lanczos says the whole spectrum sits above lmax/ratio (well-conditioned
# large-λ regime) the interval tightens to [0.8·λ_min, 1.1·λ_max] instead.
PMG_SMOOTH_RATIO = 6.0
PMG_SMOOTH_DEGREE = 4
# Schwarz-smoothed V-cycles need fewer Chebyshev stages per sweep — each
# Schwarz application is already a strong (near-block-exact) smoother.
PMG_SCHWARZ_SMOOTH_DEGREE = 2


def local_operator_diagonal(
    g: jax.Array,
    d: jax.Array,
    lam: jax.Array | float,
    w: jax.Array | None,
) -> jax.Array:
    """Element-local diagonal of (S_L + λ·screen) without forming S_L.

    Args:
      g: (E, 6, p) packed geometric factors [rr, rs, rt, ss, st, tt].
      d: (N+1, N+1) 1-D derivative matrix.
      lam: screen parameter λ.
      w: (E, p) inverse-degree weights (hipBone λW screen) or None (λI).

    Returns:
      (E, p) local diagonal, node order (t, s, r) matching local_poisson.
    """
    e = g.shape[0]
    n1 = d.shape[0]
    d2 = d * d
    g3 = g.reshape(e, 6, n1, n1, n1)

    # Same contraction patterns as the divergence in local_poisson, with D²
    # and the diagonal metric blocks.
    diag = (
        jnp.einsum("ia,etsi->etsa", d2, g3[:, 0])   # Σ_i D[i,r]² G_rr
        + jnp.einsum("jb,etjr->etbr", d2, g3[:, 3])  # Σ_j D[j,s]² G_ss
        + jnp.einsum("kc,eksr->ecsr", d2, g3[:, 5])  # Σ_k D[k,t]² G_tt
    )
    dd = jnp.diagonal(d)
    ddr = dd.reshape(1, 1, 1, n1)
    dds = dd.reshape(1, 1, n1, 1)
    ddt = dd.reshape(1, n1, 1, 1)
    diag = diag + 2.0 * (
        ddr * dds * g3[:, 1] + ddr * ddt * g3[:, 2] + dds * ddt * g3[:, 4]
    )
    diag = diag.reshape(e, -1)

    screen = jnp.ones_like(diag) if w is None else w
    return diag + lam * screen


def assembled_diagonal(prob) -> jax.Array:
    """diag(A) on assembled DOFs: Z^T diag(S_L + λ·screen) Z (Z picks out
    the diagonal entries, so this is just the gather of the local diagonal).

    The screen factors come from ``operator.screen_stream`` — the algebraic
    λW pair on legacy problems, the mass-weighted JW·λ(x) stream on
    variable-coefficient ones (k(x) is already folded into ``prob.g``).
    Deliberately *unmasked* even when ``prob.mask`` is set: the diagonal of
    the unmasked operator is strictly positive everywhere, so ``1/diag``
    stays finite; consumers keep M⁻¹ in the Dirichlet-interior subspace by
    multiplying the *inverse* by the mask (see :func:`masked_dinv`).
    """
    from .operator import screen_stream  # lazy: mirrors sibling call sites

    w_eff, lam_eff = screen_stream(prob)
    dloc = local_operator_diagonal(prob.g, prob.d, lam_eff, w_eff)
    return gather(dloc, prob.l2g, prob.n_global)


def masked_dinv(prob, diag: jax.Array) -> jax.Array:
    """Inverse diagonal restricted to the Dirichlet-interior subspace.

    ``mask ∘ D⁻¹`` (elementwise, hence = mask∘D⁻¹∘mask): zero on Dirichlet
    DOFs, so every Jacobi/Chebyshev base built from it maps into — and
    Lanczos/power iterates stay inside — the subspace where the masked
    operator is SPD.  No-op on unmasked (legacy) problems.
    """
    dinv = 1.0 / diag
    return dinv if prob.mask is None else prob.mask * dinv


def masked_seed(prob, v0: jax.Array) -> jax.Array:
    """Spectrum-estimation seed projected into the BC subspace.

    Unmasked seed components on Dirichlet DOFs sit in the null space of
    the masked operator: they never propagate under A but linger in the
    Lanczos orthogonalization, dragging the λ_min Ritz value toward 0 and
    wrecking the Chebyshev interval.  No-op on legacy problems.
    """
    return v0 if prob.mask is None else prob.mask * v0


def _default_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.vdot(a, b)


def _base_apply(
    dinv: jax.Array | Callable[[jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """Normalize a base preconditioner: a diagonal array or a callable."""
    return dinv if callable(dinv) else (lambda r: dinv * r)


def power_lambda_max(
    operator: Callable[[jax.Array], jax.Array],
    dinv: jax.Array | Callable[[jax.Array], jax.Array],
    v0: jax.Array,
    *,
    iters: int = 15,
    dot: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
) -> jax.Array:
    """λ_max(M⁻¹A) by power iteration from ``v0``.

    ``dinv`` is the base preconditioner M⁻¹: the inverse assembled diagonal
    (array, the Jacobi case) or any SPD apply callable (e.g. the Schwarz
    application).  M⁻¹A is similar to the SPD matrix M^{-1/2} A M^{-1/2},
    so the dominant eigenvalue is real and positive and plain power
    iteration converges.  ``dot``/``psum`` let the distributed caller mask
    replicas and reduce across ranks; the growth ratio ‖w‖/‖v‖ is the
    eigenvalue estimate.

    Returns:
      Scalar λ_max estimate (traced; a raw Ritz value — callers apply
      their own safety factors).
    """
    dp = dot or _default_dot
    allsum = psum or (lambda v: v)
    base = _base_apply(dinv)

    def body(carry, _):
        v, _ = carry
        w = base(operator(v))
        nrm = jnp.sqrt(allsum(dp(w, w)))
        lam = nrm / jnp.sqrt(allsum(dp(v, v)))
        return (w / jnp.maximum(nrm, 1e-30), lam), lam

    v0 = v0 / jnp.sqrt(allsum(dp(v0, v0)))
    (_, lam), _ = jax.lax.scan(body, (v0, jnp.array(0.0, v0.dtype)), None, length=iters)
    return lam


def deterministic_seed_vector(n: int, dtype=None) -> jax.Array:
    """Reproducible high-frequency start vector for the power iteration.

    A smooth vector (ones) is nearly the *lowest* mode of D⁻¹A; this hash
    puts energy in the top of the spectrum so few iterations suffice.  The
    same formula evaluated on *global* indices is what the distributed path
    uses, keeping replicas consistent by construction.

    ``dtype=None`` resolves to the canonical float dtype (fp64 under
    jax_enable_x64) — every solver call site passes the problem dtype
    explicitly so the seed follows the solve precision; the hash itself is
    always evaluated in numpy fp64 and *then* cast, so the fp32 seed is
    exactly the rounded fp64 seed (dtype-stable determinism).
    """
    if dtype is None:
        dtype = jnp.asarray(0.0).dtype
    return jnp.asarray(seed_values(np.arange(n)), dtype)


def seed_values(global_idx: np.ndarray) -> np.ndarray:
    """sin-hash of global DOF indices (numpy, evaluated at setup time)."""
    t = np.sin((global_idx.astype(np.float64) + 1.0) * 12.9898) * 43758.5453
    return t - np.floor(t) - 0.5


def lanczos_extremes(
    operator: Callable[[jax.Array], jax.Array],
    dinv: jax.Array,
    v0: jax.Array,
    *,
    iters: int = 10,
    dot: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array]:
    """(λ_min, λ_max) estimates of D⁻¹A by a few Lanczos steps.

    Lanczos runs on the symmetrized operator B = D^{-1/2} A D^{-1/2}
    (similar to D⁻¹A, so same spectrum); the extremal eigenvalues of the
    k×k tridiagonal are the Ritz estimates.  Unlike power iteration this
    yields *both* ends of the spectrum, so the Chebyshev interval can be
    tight in the well-conditioned (large-λ) regime instead of the fixed
    λ_max/30 lower bound.  Ritz values approach extremes from inside, so
    callers should widen by CHEB_SAFETY / CHEB_LMIN_SAFETY.

    ``dot``/``psum`` as in :func:`power_lambda_max`; the loop is a static
    python unroll (iters is small), traceable inside shard_map.  Unlike
    :func:`power_lambda_max` this needs the *diagonal* ``dinv`` (the
    symmetrization splits D^{-1/2} to both sides); callable base
    preconditioners use power iteration instead.

    Returns:
      ``(λ_min, λ_max)`` Ritz estimates (traced scalars, no safety factors).
    """
    if callable(dinv):
        raise TypeError(
            "lanczos_extremes needs the diagonal dinv array (it splits "
            "D^-1/2 symmetrically); use power_lambda_max for callable bases"
        )
    dp = dot or _default_dot
    allsum = psum or (lambda v: v)
    k = max(2, min(int(iters), int(np.prod(v0.shape)) - 1))
    dhalf = jnp.sqrt(dinv)
    bop = lambda v: dhalf * operator(dhalf * v)

    v = v0 / jnp.sqrt(allsum(dp(v0, v0)))
    v_prev = jnp.zeros_like(v0)
    beta = jnp.array(0.0, v0.dtype)
    alive = jnp.array(1.0, v0.dtype)   # zeroed after an invariant-subspace breakdown
    alphas, betas = [], []
    for _ in range(k):
        w = bop(v)
        alpha = allsum(dp(v, w))
        w = w - alpha * v - beta * v_prev
        beta_new = jnp.sqrt(jnp.maximum(allsum(dp(w, w)), 0.0))
        # after a breakdown v is zero, so alpha is a spurious 0 that would
        # pollute the Ritz extremes; substitute the first Rayleigh quotient,
        # an interior point of the true spectrum (step 0 is always alive)
        alphas.append(alpha if not alphas else jnp.where(alive > 0, alpha, alphas[0]))
        betas.append(beta_new * alive)
        v_prev = v
        # on breakdown (beta ~ 0) freeze: the Krylov space is invariant and
        # later steps would amplify roundoff into spurious Ritz values
        alive = alive * (beta_new > 1e-12 * jnp.abs(alpha)).astype(alive.dtype)
        v = alive * w / jnp.maximum(beta_new, 1e-30)
        beta = beta_new * alive
    tmat = (
        jnp.diag(jnp.stack(alphas))
        + jnp.diag(jnp.stack(betas[:-1]), 1)
        + jnp.diag(jnp.stack(betas[:-1]), -1)
    )
    eig = jnp.linalg.eigvalsh(tmat)
    lmax = eig[-1]
    # safety net only (post-breakdown eigenvalues are already interior):
    # keep the interval inside (0, lmax] whatever the estimates did
    lmin = jnp.clip(eig[0], lmax * 1e-4, lmax / 1.2)
    return lmin, lmax


def jacobi_apply(dinv: jax.Array) -> Callable[[jax.Array], jax.Array]:
    """z = D⁻¹ r."""
    return lambda r: dinv * r


def chebyshev_apply(
    operator: Callable[[jax.Array], jax.Array],
    dinv: jax.Array | Callable[[jax.Array], jax.Array],
    lmax: jax.Array | float,
    *,
    lmin: jax.Array | float | None = None,
    degree: int = 2,
    fused_d_update: Callable[..., jax.Array] | None = None,
) -> Callable[[jax.Array], jax.Array]:
    """Degree-k Chebyshev-accelerated preconditioner application z ≈ A⁻¹ r.

    The classic Chebyshev semi-iteration for A z = r with z₀ = 0 on the
    interval [lmin, lmax] of M⁻¹A, where the base preconditioner M⁻¹ is
    ``dinv`` — the inverse assembled diagonal (array, the Chebyshev–Jacobi
    case) or any SPD apply callable (Chebyshev-accelerated Schwarz, the
    nekRS smoother configuration).  Each step costs one A-apply and one
    M⁻¹-apply.  Under sharding the A-applies reuse the communication-hiding
    split operator, so Chebyshev needs *no new exchange machinery*.

    The result is a fixed polynomial ``q(M⁻¹A) M⁻¹`` — a symmetric linear
    map whenever M⁻¹ is symmetric (M^{1/2}-similarity), so plain PCG stays
    valid with any base preconditioner from this module.

    ``fused_d_update`` optionally fuses the streaming update
    d ← a·d + c·(M⁻¹ res) (signature (a, c, d, r) -> d_new; see
    kernels.ops.fused_cheb_d_update).

    Returns:
      ``apply(r) -> z``, same vector layout as ``operator``.
    """
    if degree < 1:
        raise ValueError(f"chebyshev degree must be >= 1, got {degree}")
    lmax = jnp.asarray(lmax)
    lmin_v = lmax / CHEB_LMIN_RATIO if lmin is None else jnp.asarray(lmin)
    theta = 0.5 * (lmax + lmin_v)
    delta = 0.5 * (lmax - lmin_v)
    sigma = theta / delta

    base = _base_apply(dinv)
    dupd = fused_d_update or (lambda a, c, d, r: a * d + c * r)

    def apply(r: jax.Array) -> jax.Array:
        rho = 1.0 / sigma
        d = base(r) / theta
        z = d
        res = r
        # degree is a small static int: unrolled at trace time, one compiled
        # A-apply chain per CG iteration body.
        for _ in range(degree - 1):
            res = res - operator(d)
            rho_new = 1.0 / (2.0 * sigma - rho)
            d = dupd(rho_new * rho, 2.0 * rho_new / delta, d, base(res))
            z = z + d
            rho = rho_new
        return z

    return apply


def chebyshev_apply_deferred(
    operator: Callable[[jax.Array], jax.Array],
    operator_pair: Callable[[jax.Array, jax.Array], jax.Array],
    dinv: jax.Array,
    lmax: jax.Array | float,
    *,
    lmin: jax.Array | float | None = None,
    degree: int = 2,
) -> Callable[[jax.Array, jax.Array], jax.Array]:
    """Chebyshev–Jacobi apply whose FIRST A-apply consumes a deferred input.

    The cross-level V-cycle overlap hands each coarse level its residual as
    a ``(raw, consistent)`` pair: ``raw`` is the restriction *before* its
    halo sum-exchange, bitwise final on every interior slot (the exchange
    only rewrites face slabs), while ``consistent`` carries the exchange.
    Because the Jacobi base is elementwise, ``d = D⁻¹ raw / θ`` matches
    ``D⁻¹ con / θ`` bitwise on the interior — so the first A-apply's
    *interior* element block can start from ``raw`` with no data dependence
    on the restriction exchange, and XLA overlaps that exchange with the
    finer level's interior work.  ``operator_pair(d_raw, d_con)`` is that
    split A-apply (interior gathers from the first argument); it must equal
    ``operator(d_con)`` bitwise, which keeps this whole apply bit-identical
    to :func:`chebyshev_apply` on the consistent input.

    Only valid for an *array* ``dinv`` base (elementwise); Schwarz bases
    transport face values through their expand shells and cannot defer.

    Returns:
      ``apply(raw, con) -> z`` equal bitwise to
      ``chebyshev_apply(...)(con)``.
    """
    if degree < 1:
        raise ValueError(f"chebyshev degree must be >= 1, got {degree}")
    lmax = jnp.asarray(lmax)
    lmin_v = lmax / CHEB_LMIN_RATIO if lmin is None else jnp.asarray(lmin)
    theta = 0.5 * (lmax + lmin_v)
    delta = 0.5 * (lmax - lmin_v)
    sigma = theta / delta

    def apply(raw: jax.Array, con: jax.Array) -> jax.Array:
        rho = 1.0 / sigma
        d = dinv * con / theta
        z = d
        res = con
        for step in range(degree - 1):
            if step == 0 and raw is not con:
                d_raw = dinv * raw / theta
                res = res - operator_pair(d_raw, d)
            else:
                res = res - operator(d)
            rho_new = 1.0 / (2.0 * sigma - rho)
            d = rho_new * rho * d + (2.0 * rho_new / delta) * (dinv * res)
            z = z + d
            rho = rho_new
        return z

    return apply


# ---------------------------------------------------------------------------
# p-multigrid: degree ladder, transfers, V-cycle
# ---------------------------------------------------------------------------


def pmg_smooth_degree_default(smoother: str) -> int:
    """Default Chebyshev stages per pMG smoothing sweep for a base kind.

    Schwarz applications are already strong (near-block-exact) smoothers,
    so they take fewer acceleration stages than pointwise Jacobi.
    """
    return (
        PMG_SCHWARZ_SMOOTH_DEGREE if smoother == "schwarz"
        else PMG_SMOOTH_DEGREE
    )


def smoother_interval(
    operator: Callable[[jax.Array], jax.Array],
    base: jax.Array | Callable[[jax.Array], jax.Array],
    v0: jax.Array,
    *,
    smoother: str,
    lanczos_iters: int = 10,
    dot: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-level pMG smoothing interval — one policy for every solver path.

    The Chebyshev base ("chebyshev", diagonal ``base``) takes both interval
    ends from Lanczos, tightened to
    [max(0.8·λ_min, λ_max/PMG_SMOOTH_RATIO), λ_max]; the Schwarz base
    (callable ``base``) uses power iteration for λ_max(M⁻¹A) and the fixed
    λ_max/PMG_SMOOTH_RATIO bottom (the Schwarz-preconditioned spectrum is
    already compressed).  ``lanczos_iters`` budgets the estimation on both
    branches; the power branch runs 1.5x the steps, since power iteration
    approaches λ_max markedly slower than a Lanczos Ritz value (at the
    default 10 that recovers the 15-step power budget the standalone
    estimators use).  Callers multiply λ_max by CHEB_SAFETY themselves.

    Returns:
      ``(lo, lmax, lmin)`` traced scalars — the interval bottom, the raw
      λ_max Ritz estimate, and the raw λ_min estimate (λ_max/ratio for the
      Schwarz base, where no lower Ritz value exists).
    """
    if smoother == "schwarz":
        lmax_e = power_lambda_max(
            operator, base, v0,
            iters=max(2, (3 * lanczos_iters) // 2),
            dot=dot, psum=psum,
        )
        lo = lmax_e / PMG_SMOOTH_RATIO
        return lo, lmax_e, lo
    lmin_e, lmax_e = lanczos_extremes(
        operator, base, v0, iters=lanczos_iters, dot=dot, psum=psum
    )
    lo = jnp.maximum(CHEB_LMIN_SAFETY * lmin_e, lmax_e / PMG_SMOOTH_RATIO)
    return lo, lmax_e, lmin_e


def pmg_degree_ladder(n: int) -> tuple[int, ...]:
    """The p-MG degree hierarchy N → ⌈N/2⌉ → … → 1 (Nek5000/RS halving)."""
    n = int(n)
    if n < 2:
        raise ValueError(f"p-multigrid needs fine degree >= 2, got N={n}")
    ladder = [n]
    while ladder[-1] > 1:
        ladder.append((ladder[-1] + 1) // 2)
    return tuple(ladder)


def tensor3_interp(j: jax.Array, u: jax.Array) -> jax.Array:
    """Tensor-product lift (J ⊗ J ⊗ J) u on element-local fields.

    ``u``: (E, (n_in+1)^3) in (t, s, r) node order; ``j``: (n_out+1, n_in+1)
    1-D interpolation matrix.  Three batched contractions, same MXU pattern
    as the operator's gradient stage.
    """
    e = u.shape[0]
    n_in = j.shape[1]
    u3 = u.reshape(e, n_in, n_in, n_in)
    u3 = jnp.einsum("ra,etsa->etsr", j, u3)
    u3 = jnp.einsum("sb,etbr->etsr", j, u3)
    u3 = jnp.einsum("tc,ecsr->etsr", j, u3)
    return u3.reshape(e, -1)


def make_transfer_pair(
    prob_f, prob_c
) -> tuple[Callable[[jax.Array], jax.Array], Callable[[jax.Array], jax.Array]]:
    """(prolong, restrict) between two assembled levels of one element grid.

    Prolongation is global nodal interpolation: scatter the coarse vector,
    lift with J⊗J⊗J per element, then *average* the (identical) element
    copies back onto fine DOFs — ``P = Z_f^T W_f Ĵ Z_c``.  Restriction is
    built as the exact transpose ``R = P^T = Z_c^T Ĵ^T W_f Z_f`` so the
    V-cycle stays symmetric for PCG.
    """
    j = jnp.asarray(
        sem.interpolation_matrix(prob_c.mesh.n_degree, prob_f.mesh.n_degree),
        prob_f.dtype,
    )
    l2g_f, l2g_c = prob_f.l2g, prob_c.l2g
    w_lf = prob_f.w_local
    ngf, ngc = prob_f.n_global, prob_c.n_global

    def prolong(x_c: jax.Array) -> jax.Array:
        u_f = tensor3_interp(j, scatter(x_c, l2g_c))
        return gather(w_lf * u_f, l2g_f, ngf)

    def restrict(r_f: jax.Array) -> jax.Array:
        u_c = tensor3_interp(j.T, w_lf * scatter(r_f, l2g_f))
        return gather(u_c, l2g_c, ngc)

    return prolong, restrict


def make_vcycle(
    operators: Sequence[Callable[[jax.Array], jax.Array]],
    smoothers: Sequence[Callable[[jax.Array], jax.Array]],
    restricts: Sequence[Callable[[jax.Array], jax.Array]],
    prolongs: Sequence[Callable[[jax.Array], jax.Array]],
    coarse_apply: Callable[[jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """Symmetric V-cycle z = M⁻¹ r over pre-built level callables.

    ``operators``/``smoothers`` cover the smoothed levels 0..L-1 (fine
    first); ``restricts[i]`` maps level i -> i+1, ``prolongs[i]`` back;
    ``coarse_apply`` handles level L outright.  Pre- and post-smoothing use
    the *same* symmetric smoother (Chebyshev–Jacobi with z₀=0 is the fixed
    polynomial q(D⁻¹A)D⁻¹), which with R = P^T makes the whole cycle a
    symmetric linear map — plain PCG stays valid, no flexible CG needed.
    The recursion is a static python unroll: one compiled chain per apply.
    """
    n_smoothed = len(smoothers)

    def cycle(level: int, r: jax.Array) -> jax.Array:
        if level == n_smoothed:
            return coarse_apply(r)
        smooth, op = smoothers[level], operators[level]
        z = smooth(r)                                   # pre-smooth (z₀ = 0)
        zc = cycle(level + 1, restricts[level](r - op(z)))
        z = z + prolongs[level](zc)                     # coarse-grid correction
        return z + smooth(r - op(z))                    # post-smooth

    return lambda r: cycle(0, r)


def make_vcycle_overlapped(
    operators: Sequence[Callable[[jax.Array], jax.Array]],
    operators_pair: Sequence[Callable[[jax.Array, jax.Array], jax.Array]],
    smoothers: Sequence[Callable[[jax.Array], jax.Array]],
    smoothers_pair: Sequence[Callable[[jax.Array, jax.Array], jax.Array]],
    restricts_pair: Sequence[
        Callable[[jax.Array], tuple[jax.Array, jax.Array]]
    ],
    prolongs_pair: Sequence[
        Callable[[jax.Array], tuple[jax.Array, jax.Array]]
    ],
    coarse_apply_pair: Callable[[jax.Array, jax.Array], jax.Array],
) -> Callable[[jax.Array], jax.Array]:
    """V-cycle with cross-level exchange/compute overlap, bit-identical to
    :func:`make_vcycle`.

    The sharded transfers end in a halo sum-exchange that only rewrites
    face slabs — every interior slot of the *raw* (pre-exchange) restricted
    or prolonged box is already bitwise final.  So each transfer here
    returns the ``(raw, consistent)`` pair instead of the consistent box
    alone, and the next consumer starts its interior element work from
    ``raw``: the coarse level's first smoother A-apply
    (``smoothers_pair`` / ``coarse_apply_pair``, see
    :func:`chebyshev_apply_deferred`) overlaps the restriction exchange,
    and the fine level's post-smooth residual A-apply (``operators_pair``,
    interior gathers from its first argument) overlaps the prolongation
    exchange.  Every deferred operand is bitwise equal to its consistent
    twin on the slots actually read, so the cycle output — and hence PCG
    iteration counts — cannot move.

    ``smoothers_pair[i]`` may ignore its raw argument (Schwarz bases must:
    their expand shells transport face values); that degrades the overlap
    at that level, never the result.
    """
    n_smoothed = len(smoothers)

    def cycle(level: int, raw: jax.Array, con: jax.Array) -> jax.Array:
        if level == n_smoothed:
            return coarse_apply_pair(raw, con)
        z = smoothers_pair[level](raw, con)             # pre-smooth (z₀ = 0)
        raw_c, con_c = restricts_pair[level](con - operators[level](z))
        zc = cycle(level + 1, raw_c, con_c)
        p_raw, p_con = prolongs_pair[level](zc)         # coarse-grid corr.
        resid = con - operators_pair[level](z + p_raw, z + p_con)
        return (z + p_con) + smoothers[level](resid)    # post-smooth

    return lambda r: cycle(0, r, r)


@dataclasses.dataclass(frozen=True)
class PrecondInfo:
    """What make_preconditioner built (for logging/benchmark reporting)."""

    kind: str
    degree: int
    lmax: float | None
    lmin: float | None = None
    levels: tuple[int, ...] | None = None
    smoother: str | None = None
    coarse_op: str | None = None
    overlap: int | None = None
    # compute dtype of the preconditioner chain when it differs from the
    # problem dtype (mixed precision); None = same as the problem
    dtype: str | None = None


def make_pmg_preconditioner(
    prob,
    operator: Callable[[jax.Array], jax.Array],
    *,
    smooth_degree: int | None = None,
    smoother: str = "chebyshev",
    coarse_op: str = "redisc",
    lanczos_iters: int = 10,
    coarse_solve: str = "direct",
    coarse_iters: int = 16,
    ladder: Sequence[int] | None = None,
    schwarz_overlap: int = 1,
    schwarz_inner_degree: int = SCHWARZ_INNER_DEGREE,
    galerkin_matvec: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
) -> tuple[Callable[[jax.Array], jax.Array], PrecondInfo]:
    """Single-shard p-multigrid V-cycle preconditioner.

    Args:
      prob: the fine-level ``PoissonProblem``.
      operator: the fine-level A-apply (assembled storage).
      smooth_degree: Chebyshev stages per smoothing sweep.  Defaults to
        ``PMG_SMOOTH_DEGREE`` for the Jacobi base and the smaller
        ``PMG_SCHWARZ_SMOOTH_DEGREE`` for the Schwarz base (each Schwarz
        application is already a strong smoother).
      smoother: per-level smoother base — "chebyshev" (Chebyshev–Jacobi on
        the Lanczos interval) or "schwarz" (Chebyshev-accelerated
        overlapping Schwarz, the nekRS configuration; spectrum top from
        power iteration, interval [λ_max/PMG_SMOOTH_RATIO, 1.1·λ_max]).
      coarse_op: "redisc" (default) rediscretizes every coarse level on the
        same curved geometry; "galerkin" builds coarse operators as the
        exact triple products ``A_{l+1} = R_l A_l P_l`` applied matrix-free
        through the transfer chain — variationally exact (closes the
        rediscretization gap that caps the small-λ regime) but each coarse
        A-apply recurses to the fine grid, so per-iteration cost grows with
        depth; "galerkin_mat" materializes the *same* triple products once
        at setup into dense per-element blocks (``core.galerkin``), so
        every level below the finest applies the variationally-exact
        operator with one batched element matvec and **zero fine-operator
        applies per coarse apply**.  Smoother diagonals stay the
        rediscretized ones for both Galerkin variants (the standard
        spectrally-equivalent approximation — and what keeps
        "galerkin_mat" iteration-identical to the chained form).
      galerkin_matvec: optional batched element matvec ``(blocks, u) → y``
        for the "galerkin_mat" coarse applies (e.g.
        ``kernels.ops.block_matvec``, the Pallas variant); default is the
        XLA einsum.
      lanczos_iters: Lanczos steps per level for the Chebyshev intervals.
      coarse_solve: coarsest-level treatment — "direct" (dense inverse of
        the degree-1 operator, exact and cheap), "chebyshev" (degree
        ``coarse_iters`` full-interval Chebyshev), or "jacobi"
        (``coarse_iters`` damped-Jacobi sweeps) — all fixed linear
        symmetric maps.
      coarse_iters: iteration count for the iterated coarse solves.
      ladder: explicit degree ladder (default N → ⌈N/2⌉ → … → 1).
      schwarz_overlap / schwarz_inner_degree: Schwarz-smoother knobs
        (see ``core.schwarz.make_schwarz_apply``).

    Returns:
      ``(apply, info)``: the V-cycle application z = M⁻¹r and its
      :class:`PrecondInfo` (fine-level spectrum bounds, ladder, smoother).
    """
    from .operator import coarsen_problem, poisson_assembled

    if smoother not in PMG_SMOOTHERS:
        raise ValueError(
            f"unknown pmg smoother {smoother!r}; choose from {PMG_SMOOTHERS}"
        )
    if coarse_op not in PMG_COARSE_OPS:
        raise ValueError(
            f"unknown pmg coarse_op {coarse_op!r}; choose from {PMG_COARSE_OPS}"
        )
    if smooth_degree is None:
        smooth_degree = pmg_smooth_degree_default(smoother)
    degrees = tuple(ladder) if ladder is not None else pmg_degree_ladder(
        prob.mesh.n_degree
    )
    if len(degrees) < 2:
        raise ValueError(f"pmg ladder needs >= 2 levels, got {degrees}")
    probs = [prob]
    for nc in degrees[1:]:
        probs.append(coarsen_problem(probs[-1], nc))

    prolongs, restricts = [], []
    for fine, coarse in zip(probs[:-1], probs[1:]):
        p_up, r_down = make_transfer_pair(fine, coarse)
        prolongs.append(p_up)
        restricts.append(r_down)

    # Dirichlet masking of the coarse Galerkin applies: the transfer pair
    # preserves the BC subspace (GLL grids share face nodes, so the lifted
    # interpolant's face values depend only on face values), but R = Pᵀ
    # smears interior fine residual onto coarse Dirichlet DOFs — the coarse
    # operator must be mask∘RAP∘mask to stay SPD on its own subspace.
    # Rediscretized levels mask inside poisson_assembled already.
    def _mask_wrap(mask, op):
        if mask is None:
            return op
        return lambda v: mask * op(mask * v)

    ops = [operator]
    if coarse_op == "galerkin_mat":
        # materialize P^T A P once: probe the fine element-local operator
        # for level 1, contract blocks for deeper rungs (core.galerkin).
        # The probing is coefficient-agnostic: variable k rides the folded
        # prob.g and λ(x) rides the screen stream, so the probe consumes
        # exactly the streams the fine operator does.
        from .galerkin import galerkin_block_apply, galerkin_ladder_blocks
        from .operator import screen_stream

        w_eff, lam_eff = screen_stream(prob)
        ladder_blocks = galerkin_ladder_blocks(
            prob.g, prob.d, lam_eff, w_eff, degrees
        )
        for pc_prob, blocks in zip(probs[1:], ladder_blocks):
            ops.append(
                _mask_wrap(
                    pc_prob.mask,
                    galerkin_block_apply(
                        blocks, pc_prob.l2g, pc_prob.n_global,
                        matvec=galerkin_matvec,
                    ),
                )
            )
    else:
        for i in range(1, len(probs)):
            if coarse_op == "galerkin":
                # A_l = R_{l-1} A_{l-1} P_{l-1}, matrix-free through the
                # chain — every coarse apply recurses to the fine grid
                ops.append(
                    _mask_wrap(
                        probs[i].mask,
                        lambda v, op=ops[-1], r=restricts[i - 1],
                        p=prolongs[i - 1]: r(op(p(v))),
                    )
                )
            else:
                ops.append(poisson_assembled(probs[i]))

    smoothers = []
    lmax0 = lmin0 = None
    for i in range(len(probs) - 1):
        dinv = masked_dinv(probs[i], assembled_diagonal(probs[i]))
        v0 = masked_seed(
            probs[i], deterministic_seed_vector(probs[i].n_global, dinv.dtype)
        )
        if smoother == "schwarz":
            base = make_schwarz_apply(
                probs[i],
                overlap=min(schwarz_overlap, probs[i].mesh.n_degree - 1),
                inner_degree=schwarz_inner_degree,
            )
        else:
            base = dinv
        lo, lmax_e, lmin_e = smoother_interval(
            ops[i], base, v0, smoother=smoother, lanczos_iters=lanczos_iters
        )
        if i == 0:
            lmax0, lmin0 = float(lmax_e), float(lmin_e)
        smoothers.append(
            chebyshev_apply(
                ops[i],
                base,
                CHEB_SAFETY * lmax_e,
                lmin=lo,
                degree=smooth_degree,
            )
        )

    pc, opc = probs[-1], ops[-1]
    if coarse_solve == "direct":
        eye = jnp.eye(pc.n_global, dtype=dinv.dtype)
        amat = jax.vmap(opc, in_axes=1, out_axes=1)(eye)
        if pc.mask is not None:
            # the masked coarse operator has zero rows/columns on Dirichlet
            # DOFs; put 1 there so the inverse exists, then project the
            # apply — exactly the subspace inverse, identity-free outside
            amat = amat + jnp.diag(1.0 - pc.mask.astype(amat.dtype))
        ainv = jnp.linalg.inv(amat)
        if pc.mask is None:
            coarse_apply = lambda r: ainv @ r
        else:
            coarse_apply = lambda r: pc.mask * (ainv @ (pc.mask * r))
    elif coarse_solve in ("chebyshev", "jacobi"):
        dinv_c = masked_dinv(pc, assembled_diagonal(pc))
        if coarse_solve == "chebyshev":
            v0 = masked_seed(
                pc, deterministic_seed_vector(pc.n_global, dinv_c.dtype)
            )
            lmin_e, lmax_e = lanczos_extremes(opc, dinv_c, v0, iters=lanczos_iters)
            coarse_apply = chebyshev_apply(
                opc,
                dinv_c,
                CHEB_SAFETY * lmax_e,
                lmin=CHEB_LMIN_SAFETY * lmin_e,
                degree=coarse_iters,
            )
        else:

            def coarse_apply(r: jax.Array) -> jax.Array:
                # damped-Jacobi sweeps from z₀=0: a fixed polynomial in
                # D⁻¹A, hence linear and symmetric like the other choices
                z = (2.0 / 3.0) * dinv_c * r
                for _ in range(coarse_iters - 1):
                    z = z + (2.0 / 3.0) * dinv_c * (r - opc(z))
                return z

    else:
        raise ValueError(
            f"unknown pmg coarse_solve {coarse_solve!r}; "
            "choose direct | chebyshev | jacobi"
        )

    apply = make_vcycle(ops[:-1], smoothers, restricts, prolongs, coarse_apply)
    return apply, PrecondInfo(
        "pmg",
        smooth_degree,
        lmax0,
        lmin0,
        degrees,
        smoother=smoother,
        coarse_op=coarse_op,
        overlap=schwarz_overlap if smoother == "schwarz" else None,
    )


def cast_apply(
    apply: Callable[[jax.Array], jax.Array], compute_dtype, out_dtype
) -> Callable[[jax.Array], jax.Array]:
    """Wrap an apply with the mixed-precision cast boundary.

    The returned callable rounds its input to ``compute_dtype``, runs the
    wrapped chain there, and widens the result back to ``out_dtype`` — the
    single pair of casts the whole mixed-precision preconditioner needs
    (everything inside already lives in ``compute_dtype``).
    """
    cdt, odt = jnp.dtype(compute_dtype), jnp.dtype(out_dtype)
    return lambda r: apply(r.astype(cdt)).astype(odt)


# make_preconditioner knobs that shape the built setup, with their defaults.
# Callable knobs (fused_d_update, galerkin_matvec) are kernel substitutions —
# they change how a stage is computed, never what it computes — so they are
# deliberately NOT part of the signature.
_SIGNATURE_DEFAULTS = {
    "degree": 2,
    "power_iters": 15,
    "lanczos_iters": 10,
    "lmin_source": "lanczos",
    "pmg_smooth_degree": None,
    "pmg_smoother": "chebyshev",
    "pmg_coarse_op": "redisc",
    "pmg_coarse_solve": "direct",
    "pmg_coarse_iters": 16,
    "pmg_ladder": None,
    "schwarz_overlap": 1,
    "schwarz_weighting": "sqrt",
    "schwarz_inner_degree": SCHWARZ_INNER_DEGREE,
    "precond_dtype": None,
}


def precond_signature(kind: str, **kwargs) -> tuple:
    """Canonical hashable signature of a :func:`make_preconditioner` config.

    Every knob that affects the *built setup* is normalized (defaults
    filled in, ladder tuples frozen, dtypes resolved to their names) and
    emitted in a fixed order, so two calls that would build the same
    preconditioner produce equal signatures whatever subset of knobs they
    spelled out — the keying contract ``core.solver_cache`` relies on.
    Unknown knobs raise instead of being silently dropped (a typo must not
    alias two different configs to one cache slot).
    """
    if kind not in PRECOND_KINDS:
        raise ValueError(f"unknown precond {kind!r}; choose from {PRECOND_KINDS}")
    unknown = set(kwargs) - set(_SIGNATURE_DEFAULTS)
    if unknown:
        raise ValueError(
            f"unknown preconditioner knob(s) {sorted(unknown)}; "
            f"known: {sorted(_SIGNATURE_DEFAULTS)}"
        )
    merged = {**_SIGNATURE_DEFAULTS, **kwargs}
    if merged["pmg_ladder"] is not None:
        merged["pmg_ladder"] = tuple(int(d) for d in merged["pmg_ladder"])
    if merged["precond_dtype"] is not None:
        merged["precond_dtype"] = jnp.dtype(merged["precond_dtype"]).name
    return (("kind", kind),) + tuple(
        (name, merged[name]) for name in sorted(_SIGNATURE_DEFAULTS)
    )


def make_preconditioner(
    kind: str,
    prob,
    operator: Callable[[jax.Array], jax.Array],
    *,
    degree: int = 2,
    power_iters: int = 15,
    lanczos_iters: int = 10,
    lmin_source: str = "lanczos",
    fused_d_update: Callable[..., jax.Array] | None = None,
    pmg_smooth_degree: int | None = None,
    pmg_smoother: str = "chebyshev",
    pmg_coarse_op: str = "redisc",
    pmg_coarse_solve: str = "direct",
    pmg_coarse_iters: int = 16,
    pmg_ladder: Sequence[int] | None = None,
    schwarz_overlap: int = 1,
    schwarz_weighting: str = "sqrt",
    schwarz_inner_degree: int = SCHWARZ_INNER_DEGREE,
    galerkin_matvec: Callable[[jax.Array, jax.Array], jax.Array] | None = None,
    precond_dtype=None,
) -> tuple[Callable[[jax.Array], jax.Array] | None, PrecondInfo]:
    """Build a single-device assembled-path preconditioner by name.

    Args:
      kind: "none" | "jacobi" | "chebyshev" | "schwarz" | "pmg".
      prob: the ``PoissonProblem``.
      operator: the assembled A-apply the preconditioner wraps.
      degree: standalone-Chebyshev polynomial degree.
      power_iters / lanczos_iters: spectrum-estimation step budget.  For
        "chebyshev", ``lmin_source="lanczos"`` (default) estimates *both*
        interval ends with ``lanczos_iters`` Lanczos steps; ``"ratio"``
        reproduces the legacy fixed λ_max/CHEB_LMIN_RATIO lower bound
        (with ``power_iters`` power-iteration steps for λ_max).
      fused_d_update: optional Pallas streaming fusion for the Chebyshev
        d-update (kernels.ops.fused_cheb_d_update).
      pmg_*: p-multigrid knobs, forwarded to
        :func:`make_pmg_preconditioner` (``pmg_smooth_degree`` is the
        per-level smoother degree; ``degree`` stays the standalone knob;
        ``pmg_coarse_op="galerkin_mat"`` materializes the PᵀAP coarse
        operators into per-element blocks — ``core.galerkin``).
      galerkin_matvec: optional batched element matvec for the
        "galerkin_mat" coarse applies (``kernels.ops.block_matvec``).
      schwarz_*: overlapping-Schwarz knobs — extension width in GLL nodes
        (``schwarz_overlap``, 0 = block Jacobi), partition-of-unity
        weighting ("sqrt" symmetric default; "post" = RAS, nonsymmetric,
        rejected here because plain PCG needs a symmetric M), and the
        in-eigenbasis block-solve Chebyshev degree
        (``schwarz_inner_degree``).  Shared by kind="schwarz" and the
        pmg smoother="schwarz".
      precond_dtype: compute dtype of the *entire* preconditioner chain
        (default None = the problem dtype).  Passing e.g. ``jnp.float32``
        inside an fp64 solve rebuilds every preconditioner ingredient —
        A-applies, diagonals, Chebyshev recurrences, Schwarz FDM blocks,
        pMG levels and transfers — from an fp32 cast of the problem
        (``operator.cast_problem``), wraps the result in one
        :func:`cast_apply` boundary, and roughly halves preconditioner
        bandwidth.  The fp32 apply is only approximately symmetric in fp64
        arithmetic, so pair it with ``cg_assembled(cg_variant="flexible")``
        for robustness near tight tolerances.  The caller's ``operator``
        is NOT used inside the mixed chain (it computes in the problem
        dtype); it still defines the outer solve.

    Returns:
      ``(apply, info)``; ``apply`` is None for "none" (plain CG), else the
      z = M⁻¹r application, always a symmetric linear map (PCG-valid) —
      symmetric to working precision only under ``precond_dtype``.
    """
    if kind not in PRECOND_KINDS:
        raise ValueError(f"unknown precond {kind!r}; choose from {PRECOND_KINDS}")
    if kind == "none":
        return None, PrecondInfo("none", 0, None)
    if precond_dtype is not None and jnp.dtype(precond_dtype) != jnp.dtype(
        prob.dtype
    ):
        from .operator import cast_problem, poisson_assembled

        prob_c = cast_problem(prob, precond_dtype)
        inner, info = make_preconditioner(
            kind,
            prob_c,
            poisson_assembled(prob_c),
            degree=degree,
            power_iters=power_iters,
            lanczos_iters=lanczos_iters,
            lmin_source=lmin_source,
            fused_d_update=fused_d_update,
            pmg_smooth_degree=pmg_smooth_degree,
            pmg_smoother=pmg_smoother,
            pmg_coarse_op=pmg_coarse_op,
            pmg_coarse_solve=pmg_coarse_solve,
            pmg_coarse_iters=pmg_coarse_iters,
            pmg_ladder=pmg_ladder,
            schwarz_overlap=schwarz_overlap,
            schwarz_weighting=schwarz_weighting,
            schwarz_inner_degree=schwarz_inner_degree,
            galerkin_matvec=galerkin_matvec,
        )
        return (
            cast_apply(inner, precond_dtype, prob.dtype),
            dataclasses.replace(info, dtype=jnp.dtype(precond_dtype).name),
        )
    if kind == "pmg":
        return make_pmg_preconditioner(
            prob,
            operator,
            smooth_degree=pmg_smooth_degree,
            smoother=pmg_smoother,
            coarse_op=pmg_coarse_op,
            lanczos_iters=lanczos_iters,
            coarse_solve=pmg_coarse_solve,
            coarse_iters=pmg_coarse_iters,
            ladder=pmg_ladder,
            schwarz_overlap=schwarz_overlap,
            schwarz_inner_degree=schwarz_inner_degree,
            galerkin_matvec=galerkin_matvec,
        )
    if kind == "schwarz":
        if schwarz_weighting == "post":
            raise ValueError(
                "schwarz weighting='post' (RAS) is nonsymmetric; plain PCG "
                "needs the symmetric 'sqrt' (or 'none') weighting — use "
                "make_schwarz_apply directly for Richardson/flexible solvers"
            )
        apply = make_schwarz_apply(
            prob,
            overlap=schwarz_overlap,
            weighting=schwarz_weighting,
            inner_degree=schwarz_inner_degree,
        )
        return apply, PrecondInfo(
            "schwarz", schwarz_inner_degree, None, overlap=schwarz_overlap
        )
    diag = assembled_diagonal(prob)
    dinv = masked_dinv(prob, diag)
    if kind == "jacobi":
        return jacobi_apply(dinv), PrecondInfo("jacobi", 1, None)
    v0 = masked_seed(prob, deterministic_seed_vector(prob.n_global, diag.dtype))
    if lmin_source == "lanczos":
        lmin_e, lmax_e = lanczos_extremes(operator, dinv, v0, iters=lanczos_iters)
        lmax = CHEB_SAFETY * lmax_e
        lmin = CHEB_LMIN_SAFETY * lmin_e
    elif lmin_source == "ratio":
        lmax = CHEB_SAFETY * power_lambda_max(operator, dinv, v0, iters=power_iters)
        lmin = None
    else:
        raise ValueError(f"unknown lmin_source {lmin_source!r}")
    apply = chebyshev_apply(
        operator, dinv, lmax, lmin=lmin, degree=degree,
        fused_d_update=fused_d_update,
    )
    return apply, PrecondInfo(
        "chebyshev", degree, float(lmax), None if lmin is None else float(lmin)
    )
