"""Multi-device communication tests (8 fake CPU devices via subprocess)."""

from conftest import run_subprocess


def test_exchange_algorithms_equivalent():
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.compat import make_mesh, shard_map
from repro.comms.exchange import EXCHANGES
mesh = make_mesh((8,), ("r",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((64, 5)), jnp.float32)
outs = {}
for name, fn in EXCHANGES.items():
    f = jax.jit(shard_map(partial(fn, axis_name="r"), mesh=mesh,
                              in_specs=P("r"), out_specs=P("r")))
    outs[name] = np.array(f(x))
for name, o in outs.items():
    assert np.array_equal(o, outs["all_to_all"]), name
print("OK")
"""
    )


def test_crystal_router_message_count():
    """log2(P) ppermutes for crystal router vs P-1 for pairwise (HLO check)."""
    run_subprocess(
        """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.compat import make_mesh, shard_map
from repro.comms.exchange import exchange_crystal_router, exchange_pairwise
mesh = make_mesh((8,), ("r",))
x = jnp.zeros((64, 4), jnp.float32)
def count(fn):
    f = jax.jit(shard_map(partial(fn, axis_name="r"), mesh=mesh,
                              in_specs=P("r"), out_specs=P("r")))
    return f.lower(x).as_text().count("collective_permute")
c = count(exchange_crystal_router)
p = count(exchange_pairwise)
assert c == 3, c     # log2(8)
assert p == 7, p     # P-1
print("OK", c, p)
"""
    )


def test_halo_sum_and_copy_exchange():
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.comms.topology import ProcessGrid
from repro.comms.halo import sum_exchange, copy_exchange
from repro.compat import make_mesh, shard_map
grid = ProcessGrid((2, 2, 2))
mesh = make_mesh((8,), ("ranks",))
mx = my = mz = 3   # per-rank box, [z,y,x] indexed
rng = np.random.default_rng(0)
boxes = rng.standard_normal((8, mz, my, mx)).astype(np.float32)

@partial(shard_map, mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"))
def do_sum(b):
    return sum_exchange(b[0], grid, "ranks")[None]

out = np.array(do_sum(jnp.asarray(boxes)))
# verify: assemble global field (2 ranks per dim, overlap of 1 plane)
G = 2 * (mx - 1) + 1
glob = np.zeros((G, G, G))
for r in range(8):
    ci, cj, ck = grid.coords(r)
    glob[ck*2:ck*2+3, cj*2:cj*2+3, ci*2:ci*2+3] += boxes[r]
for r in range(8):
    ci, cj, ck = grid.coords(r)
    want = glob[ck*2:ck*2+3, cj*2:cj*2+3, ci*2:ci*2+3]
    np.testing.assert_allclose(out[r], want, rtol=1e-5)
print("sum OK")

@partial(shard_map, mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"))
def do_copy(b):
    return copy_exchange(b[0], grid, "ranks")[None]
out2 = np.array(do_copy(jnp.asarray(boxes)))
# replicas (low faces) must equal the owner's (high-face) values
for r in range(8):
    ci, cj, ck = grid.coords(r)
    if ci > 0:
        left = grid.rank(ci - 1, cj, ck)
        np.testing.assert_allclose(out2[r][:, :, 0], out2[left][:, :, 2], rtol=1e-6)
print("OK")
"""
    )


def test_halo_routings_bitwise_equivalent():
    """crystal/fused halo routings == face sweep, bit for bit.

    At the native wire every routing must replicate the face sweep's IEEE
    reduction tree exactly (that is what makes ``comms.plan`` a pure
    performance knob); with a narrowed fp32 wire on fp64 boxes the sum
    routings agree to wire rounding while each stays replica-consistent.
    """
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.comms.topology import ProcessGrid
from repro.comms import halo
from repro.compat import make_mesh, shard_map

mesh = make_mesh((8,), ("r",))
rng = np.random.default_rng(0)

def run(fn, boxes):
    f = jax.jit(shard_map(lambda b: fn(b[0])[None], mesh=mesh,
                          in_specs=P("r"), out_specs=P("r")))
    return np.array(f(jnp.asarray(boxes)))

for shape in [(2, 2, 2), (4, 2, 1), (8, 1, 1)]:
    grid = ProcessGrid(shape)
    for box_shape, dt in [((3, 4, 5), np.float64), ((3, 3, 3), np.float32)]:
        boxes = rng.standard_normal((8, *box_shape)).astype(dt)
        for wire in (None, jnp.float32):
            bitwise = wire is None or dt == np.float32
            ref = run(lambda b: halo.sum_exchange(b, grid, "r", wire), boxes)
            for routing in ("crystal", "fused"):
                got = run(lambda b: halo.sum_exchange(
                    b, grid, "r", wire, routing), boxes)
                if bitwise:
                    assert np.array_equal(ref, got), (shape, routing, wire)
                else:
                    assert np.allclose(ref, got, rtol=1e-6, atol=1e-6)
            refc = run(lambda b: halo.copy_exchange(b, grid, "r", wire), boxes)
            gotc = run(lambda b: halo.copy_exchange(
                b, grid, "r", wire, "fused"), boxes)
            # copy ships owner values verbatim: bitwise at every wire
            assert np.array_equal(refc, gotc), (shape, wire)
            depth = 1
            refe = run(lambda b: halo.expand_exchange(
                b, grid, "r", depth, wire), boxes)
            gote = run(lambda b: halo.expand_exchange(
                b, grid, "r", depth, wire, "fused"), boxes)
            assert np.array_equal(refe, gote), (shape, wire)
            big = rng.standard_normal(
                (8, *(s + 2 * depth for s in box_shape))).astype(dt)
            refk = run(lambda b: halo.contract_exchange(
                b, grid, "r", depth, wire), big)
            gotk = run(lambda b: halo.contract_exchange(
                b, grid, "r", depth, wire, "fused"), big)
            if bitwise:
                assert np.array_equal(refk, gotk), (shape, wire)
            else:
                assert np.allclose(refk, gotk, rtol=1e-5, atol=1e-5)
print("OK")
""",
        timeout=900,
    )


def test_distributed_cg_matches_single_device():
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.core.distributed import build_dist_problem, dist_cg, dist_cg_scattered
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.compat import make_mesh

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
x_boxes, rdotr, iters, status, hist = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=150))()
res = cg_assembled(A, jnp.asarray(bg), n_iter=150)
err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
assert err < 1e-9, err
# scattered baseline
bL = jnp.take(b_boxes, jnp.asarray(prob.l2g.reshape(-1)), axis=1).reshape(
    grid.size, prob.e_local, -1)
xl, rd2, _it, _st = jax.jit(dist_cg_scattered(prob, mesh, bL, n_iter=150))()
xl_ref = jnp.take(jnp.asarray(box_from_global(np.array(res.x))),
                  jnp.asarray(prob.l2g.reshape(-1)), axis=1).reshape(xl.shape)
assert np.abs(np.array(xl) - np.array(xl_ref)).max() < 1e-9
print("OK")
"""
    )


def test_two_phase_matches_one_phase():
    """Paper-faithful two-phase operator == merged one-phase operator."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.core.distributed import build_dist_problem, _apply_assembled
from repro.comms.topology import ProcessGrid
from repro.core.operator import local_poisson

from repro.compat import make_mesh, shard_map
grid = ProcessGrid((2, 2, 1)); local = (1, 1, 2)
mesh = make_mesh((4,), ("ranks",))
prob = build_dist_problem(2, grid, local, lam=0.5, dtype=jnp.float64)
rng = np.random.default_rng(0)
x = rng.standard_normal((4, prob.m3))
# make consistent: copy owners into replicas by reusing copy_exchange
from repro.comms.halo import copy_exchange
spec = P("ranks")
@partial(shard_map, mesh=mesh, in_specs=(spec,)*3, out_specs=(spec, spec))
def apply_both(xb, g, w):
    xc = copy_exchange(xb[0].reshape(prob.box_shape[::-1]), prob.grid, "ranks").reshape(-1)
    one = _apply_assembled(prob, xc, g[0], w[0], local_op=local_poisson, two_phase=False)
    two = _apply_assembled(prob, xc, g[0], w[0], local_op=local_poisson, two_phase=True)
    return one[None], two[None]
one, two = apply_both(jnp.asarray(x), prob.g, prob.w_local)
np.testing.assert_allclose(np.array(one), np.array(two), atol=1e-11)
print("OK")
"""
    )


def test_compressed_psum_error_feedback():
    run_subprocess(
        """
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
from repro.training.compress import compressed_psum, ef_compressed_psum
from repro.compat import make_mesh, shard_map
mesh = make_mesh((8,), ("r",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)

@partial(shard_map, mesh=mesh, in_specs=P("r"), out_specs=P("r"))
def f(xs):
    return compressed_psum(xs[0], "r")[None]
got = np.array(f(x))[0]
want = np.array(x).sum(0)
# int8 quantization error bounded
assert np.abs(got - want).max() < 8 * np.abs(x).max() / 127 + 1e-5

# error feedback: mean of compressed psums over steps converges to true sum
@partial(shard_map, mesh=mesh, in_specs=(P("r"), P("r")), out_specs=(P("r"), P("r")))
def g(xs, res):
    t, r = ef_compressed_psum(xs[0], res[0], "r")
    return t[None], r[None]
res = jnp.zeros_like(x)
acc = np.zeros(256)
steps = 20
for _ in range(steps):
    t, res = g(x, res)
    acc += np.array(t)[0]
err_ef = np.abs(acc / steps - want).max()
assert err_ef < np.abs(got - want).max() + 1e-5  # EF at least as good
print("OK", err_ef)
"""
    )
