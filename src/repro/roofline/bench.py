"""Per-row roofline-%-of-peak from an AOT-compiled program (dry run, no TPU).

``dryrun_roofline`` joins the two halves of the §Roofline methodology into
one record that benchmark rows can carry:

  * the *achievable* time: the loop-aware HLO cost model
    (:func:`repro.roofline.hlo_model.analyze_hlo` on
    ``compiled.as_text()``) — dot FLOPs, the ×2 materialized-buffer HBM
    proxy and ring-model collective link bytes, each multiplied by the
    while-loop trip counts — pushed through the three-term roofline at the
    :data:`repro.core.fom.TPU_V5E` constants;
  * the *ideal* time: the paper's analytic traffic bound (Eqs. 4–6 via
    ``core.fom`` — ``assembled_apply_bytes`` for one A-apply,
    ``cg_iter_bytes`` × trip count for a whole solve) over the same HBM
    bandwidth.

``pct_roofline = 100 · ideal / achievable`` is therefore machine-
independent — both sides come from compiler output and model constants,
never a clock — which is what lets ``scripts/compare_bench.py`` gate it
across PRs: a drop means the *compiled program* moved away from the
streaming bound (new materializations, lost fusions), not that the host
got slower.

For while-loop solves pass ``model_bytes_per_iter``: it is multiplied by
the HLO trip count (the ``n_iter`` cap, e.g. 500), the same multiplier the
achievable side charges, so the early-exit actually taken at runtime
cancels out of the ratio.
"""
from __future__ import annotations

from typing import Any

from ..core.fom import TPU_V5E, TpuSpec
from .hlo_model import HloStats, analyze_hlo

__all__ = ["dryrun_roofline"]


def dryrun_roofline(
    compiled_or_hlo: Any,
    *,
    model_bytes: float | None = None,
    model_bytes_per_iter: float | None = None,
    trip_cap: int | None = None,
    spec: TpuSpec = TPU_V5E,
) -> dict[str, Any]:
    """Roofline record for one compiled program.

    Args:
      compiled_or_hlo: a ``jax.jit(f).lower(...).compile()`` result (its
        ``as_text()`` is analyzed) or a post-optimization HLO string.
      model_bytes: the analytic Eq. 4–6 traffic bound for the whole program.
      model_bytes_per_iter: per-iteration bound instead; multiplied by the
        solver loop's HLO trip count (1 when the program has no loop).
        Exactly one of the two must be given.
      trip_cap: identifies the solver loop among the program's whiles: the
        largest trip count ≤ ``trip_cap`` is used (callers know the static
        ``n_iter`` bound they compiled with — scatter/gather lowering
        loops trip once per local node, far above it). Default: the first
        while in DFS-from-entry order.
      spec: roofline hardware constants (default TPU_V5E).

    Returns:
      dict with ``model_bytes``, ``achievable_s``, ``pct_roofline`` (the
      gated triple) plus the HLO-side diagnostics ``hlo_flops``,
      ``hlo_bytes``, ``link_bytes``, ``trip_count`` and ``dominant``.
    """
    if (model_bytes is None) == (model_bytes_per_iter is None):
        raise ValueError("pass exactly one of model_bytes / model_bytes_per_iter")
    hlo = (
        compiled_or_hlo
        if isinstance(compiled_or_hlo, str)
        else compiled_or_hlo.as_text()
    )
    stats: HloStats = analyze_hlo(hlo)
    if trip_cap is not None:
        trip = max((t for t in stats.trip_counts if t <= trip_cap), default=1)
    else:
        trip = stats.trip_counts[0] if stats.trip_counts else 1
    if model_bytes is None:
        model_bytes = float(model_bytes_per_iter) * trip

    terms = {
        "compute": stats.flops / spec.peak_flops,
        "memory": stats.hbm_bytes / spec.hbm_bandwidth,
        "collective": stats.total_link_bytes / spec.ici_bandwidth,
    }
    achievable_s = max(terms.values())
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    ideal_s = model_bytes / spec.hbm_bandwidth
    pct = 100.0 * ideal_s / achievable_s if achievable_s > 0 else 0.0
    return {
        "model_bytes": float(model_bytes),
        "achievable_s": achievable_s,
        "pct_roofline": pct,
        "hlo_flops": stats.flops,
        "hlo_bytes": stats.hbm_bytes,
        "link_bytes": stats.total_link_bytes,
        "trip_count": trip,
        "dominant": dominant,
    }
