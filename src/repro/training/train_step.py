"""Loss and train step: remat, microbatch accumulation, clipping, schedules.

The step is a single jittable function suitable for pjit with the sharding
rules from models/params.py. Gradient accumulation runs as ``lax.scan``
over microbatches — each microbatch's backward produces reduce-scattered
gradients that XLA can overlap with the next microbatch's compute, the
paper's C4 overlap at the training-loop level (the two "interior halves"
of Fig. 2 map onto microbatch halves).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..models.blocks import MeshContext
from ..models.config import ModelConfig
from ..models.model import forward, mtp_logits
from .optimizer import Optimizer, clip_by_global_norm

__all__ = ["cross_entropy", "make_loss_fn", "make_train_step", "warmup_cosine"]


def warmup_cosine(
    *, peak_lr: float, warmup: int, total: int, floor: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = s / jnp.maximum(warmup, 1)
        prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)

    return lr


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token NLL; f32 logsumexp for stability under bf16 logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def make_loss_fn(cfg: ModelConfig, mc: MeshContext | None = None):
    """batch = {"tokens": (B, S+1)} -> next-token loss (+aux, +MTP)."""

    def loss_fn(params, batch):
        tokens = batch["tokens"]
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits, aux, h = forward(params, inp, cfg, mc)
        loss = cross_entropy(logits, tgt)
        metrics = {"nll": loss, "aux": aux}
        total = loss + cfg.aux_loss_weight * aux
        if cfg.mtp_depth and "mtp" in params:
            # depth-1 MTP: from position t predict token t+2
            lg2, aux2 = mtp_logits(params, inp, h, cfg, mc)
            mtp_tgt = tgt[:, 1:]
            mtp_loss = cross_entropy(lg2[:, : mtp_tgt.shape[1]], mtp_tgt)
            total = total + cfg.mtp_loss_weight * mtp_loss + cfg.aux_loss_weight * aux2
            metrics["mtp_nll"] = mtp_loss
        metrics["loss"] = total
        return total, metrics

    return loss_fn


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    lr_schedule: Callable[[jax.Array], jax.Array],
    mc: MeshContext | None = None,
    *,
    microbatches: int = 1,
    clip_norm: float = 1.0,
):
    """Returns step(params, opt_state, batch, step_idx) -> (params, opt_state, metrics).

    ``batch["tokens"]``: (global_batch, seq+1). With microbatches > 1 the
    batch is split on the leading axis and gradients are accumulated in a
    scan (activation memory / microbatches, the deepseek-v3 fit knob).
    """
    loss_fn = make_loss_fn(cfg, mc)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch, step_idx):
        tokens = batch["tokens"]
        b = tokens.shape[0]
        assert b % microbatches == 0, (b, microbatches)

        if microbatches == 1:
            (_, metrics), grads = grad_fn(params, {"tokens": tokens})
        else:
            mb = tokens.reshape(microbatches, b // microbatches, -1)

            def accum(carry, mtok):
                g_acc, m_acc = carry
                (_, m), g = grad_fn(params, {"tokens": mtok})
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g
                )
                m_acc = jax.tree.map(lambda a, x: a + x, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            m0 = {
                "nll": jnp.zeros((), jnp.float32),
                "aux": jnp.zeros((), jnp.float32),
                "loss": jnp.zeros((), jnp.float32),
            }
            if cfg.mtp_depth:
                m0["mtp_nll"] = jnp.zeros((), jnp.float32)
            (grads, metrics), _ = lax.scan(accum, (g0, m0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)

        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        lr = lr_schedule(step_idx)
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return step
