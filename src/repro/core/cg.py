"""Preconditioned Conjugate Gradient in hipBone-assembled and NekBone-scattered form.

One PCG implementation serves every solver path; plain CG is PCG with the
identity preconditioner, and in that case the preconditioner stage folds
away so the compiled program is exactly the seed's CG (same reductions,
same fusion schedule):

  * one fused pass computes ``r_{j+1} = r_j - α A p`` AND accumulates
    ``r_{j+1}·r_{j+1}`` (paper: "Fusing this reduction with the update of r
    avoids the need for a separate kernel to read the vector r again");
  * with a preconditioner, a second fused pass computes ``z = M⁻¹ r`` AND
    accumulates ``r·z`` (the same streaming trick applied to the PCG
    inner product — kernels/streams.py has the Pallas version);
  * the AXPY ``x += α p`` carries no data dependence on the reductions, so
    XLA may overlap the cross-device psums with it — the paper's
    allreduce-hiding trick, expressed as dataflow;
  * inner products on assembled vectors are plain (unweighted) dots.

The scattered baseline replicates NekBone: vectors of length N_L, weighted
inner products reading the extra W vector, and a combined ZZ^T
gather-scatter inside the operator.

Iteration control: a fixed count (NekBone uses 100) runs under ``lax.scan``
so a single compiled program covers the whole benchmark; passing ``tol``
switches to ``lax.while_loop`` stopping at ‖r‖ ≤ tol·‖r₀‖ (capped at
``n_iter``), with ``CGResult.iterations`` reporting the count actually run.

CG variants: the default ``cg_variant="standard"`` uses the Fletcher–Reeves
β = (r·z)_new/(r·z)_old, which assumes M⁻¹ is a *fixed symmetric* linear
map.  ``cg_variant="flexible"`` switches β to the Polak–Ribière form
β = z_new·(r_new − r_old)/(r·z)_old (flexible CG, Notay 2000) — robust to
preconditioners that are only approximately symmetric in the outer dtype's
arithmetic, e.g. an fp32 V-cycle or Schwarz apply inside an fp64 solve
(precond.make_preconditioner(precond_dtype=...)).  The extra cost is one
inner product per iteration, fused into the existing allreduce as a
length-2 payload.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "CGResult",
    "CG_VARIANTS",
    "cg_assembled",
    "cg_scattered",
    "fused_residual_update",
]

CG_VARIANTS = ("standard", "flexible")


class CGResult(NamedTuple):
    x: jax.Array
    rdotr: jax.Array
    iterations: jax.Array
    rdotr_history: jax.Array | None


def fused_residual_update(
    r: jax.Array, ap: jax.Array, alpha: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """One-pass r update + self-dot (reference; Pallas version in kernels/)."""
    r_new = r - alpha * ap
    return r_new, jnp.vdot(r_new, r_new)


def _dot(a: jax.Array, b: jax.Array, w: jax.Array | None) -> jax.Array:
    if w is None:
        return jnp.vdot(a, b)
    return jnp.vdot(a * w, b)


def _safe_div(a, b):
    # fixed-iteration CG (NekBone runs exactly 100) keeps iterating after
    # convergence; guard 0/0 so x simply freezes at the solution
    return jnp.where(b != 0, a / jnp.where(b != 0, b, 1), 0.0)


def _pcg(
    operator: Callable[[jax.Array], jax.Array],
    b: jax.Array,
    x0: jax.Array | None,
    *,
    n_iter: int,
    tol: float | None,
    weight: jax.Array | None,
    psum: Callable[[jax.Array], jax.Array] | None,
    precond: Callable[[jax.Array], jax.Array] | None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None,
    fused_precond_dot: Callable[..., tuple[jax.Array, jax.Array]] | None,
    record_history: bool,
    variant: str = "standard",
) -> CGResult:
    if variant not in CG_VARIANTS:
        raise ValueError(
            f"unknown cg_variant {variant!r}; choose from {CG_VARIANTS}"
        )
    if isinstance(precond, str):
        raise TypeError(
            f"precond must be a callable z = M⁻¹r (or None), got the string "
            f"{precond!r}; build one with core.precond.make_preconditioner "
            f"(string kinds are only accepted by distributed.dist_cg)"
        )
    if fused_precond_dot is not None and precond is None:
        raise ValueError(
            "fused_precond_dot given without precond; pass the (unfused) "
            "apply as precond too — it gates the PCG recurrence"
        )
    allsum = psum or (lambda v: v)
    upd = fused_update or fused_residual_update
    # without a preconditioner z_new == r_new, so Polak–Ribière reduces to
    # Fletcher–Reeves up to the (exactly-orthogonal) r_new·r_old term — keep
    # the cheaper standard recurrence there
    flexible = variant == "flexible" and precond is not None
    x = jnp.zeros_like(b) if x0 is None else x0

    def apply_precond(r_vec):
        """z = M⁻¹r and the local part of r·z, in one fused pass if given."""
        if precond is None:
            raise AssertionError("apply_precond called without a preconditioner")
        if fused_precond_dot is not None:
            return fused_precond_dot(r_vec)
        z_vec = precond(r_vec)
        return z_vec, _dot(r_vec, z_vec, weight)

    r = b - operator(x)
    rdotr0 = allsum(_dot(r, r, weight))
    if precond is None:
        z, rz = r, rdotr0
    else:
        z, rz_local = apply_precond(r)
        rz = allsum(rz_local)
    p = z

    def step(x, r, p, rz, rdotr):
        ap = operator(p)
        pap = allsum(_dot(p, ap, weight))
        alpha = _safe_div(rz, pap)
        if weight is None:
            # hipBone fusion: r-update + local reduction in one pass...
            r_new, rr_local = upd(r, ap, alpha)
        else:
            r_new = r - alpha * ap
            rr_local = _dot(r_new, r_new, weight)
        # ...and x-update independent of the psum -> overlappable allreduce.
        x_new = x + alpha * p
        rdotr_new = allsum(rr_local)
        if precond is None:
            z_new, rz_new = r_new, rdotr_new
            beta = _safe_div(rz_new, rz)
        elif flexible:
            # Polak–Ribière β = z_new·(r_new − r_old)/rz_old; the extra
            # z_new·r_old dot rides the same allreduce as r_new·z_new
            z_new, rz_local = apply_precond(r_new)
            pair = allsum(jnp.stack([rz_local, _dot(z_new, r, weight)]))
            rz_new = pair[0]
            beta = _safe_div(rz_new - pair[1], rz)
        else:
            z_new, rz_local = apply_precond(r_new)
            rz_new = allsum(rz_local)
            beta = _safe_div(rz_new, rz)
        p_new = z_new + beta * p
        return x_new, r_new, p_new, rz_new, rdotr_new

    if tol is None:
        def body(carry, _):
            x, r, p, rz, rdotr = carry
            carry = step(x, r, p, rz, rdotr)
            return carry, carry[-1]

        (x, r, p, rz, rdotr), hist = jax.lax.scan(
            body, (x, r, p, rz, rdotr0), None, length=n_iter
        )
        return CGResult(
            x=x,
            rdotr=rdotr,
            iterations=jnp.asarray(n_iter),
            rdotr_history=hist if record_history else None,
        )

    # tolerance mode: ‖r‖ ≤ tol·‖r₀‖, capped at n_iter; the history buffer
    # (and its per-iteration scatter) only enters the carry when asked for
    target = jnp.asarray(tol, rdotr0.dtype) ** 2 * rdotr0
    hist0 = (jnp.zeros((n_iter,), rdotr0.dtype),) if record_history else ()

    def cond(carry):
        rdotr, k = carry[4], carry[5]
        return (k < n_iter) & (rdotr > target)

    def wbody(carry):
        x, r, p, rz, rdotr, k = carry[:6]
        x, r, p, rz, rdotr = step(x, r, p, rz, rdotr)
        hist = (carry[6].at[k].set(rdotr),) if record_history else ()
        return (x, r, p, rz, rdotr, k + 1) + hist

    out = jax.lax.while_loop(
        cond, wbody, (x, r, p, rz, rdotr0, jnp.asarray(0)) + hist0
    )
    return CGResult(
        x=out[0],
        rdotr=out[4],
        iterations=out[5],
        rdotr_history=out[6] if record_history else None,
    )


def cg_assembled(
    operator: Callable[[jax.Array], jax.Array],
    b_g: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    fused_update: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    fused_precond_dot: Callable[..., tuple[jax.Array, jax.Array]] | None = None,
    record_history: bool = False,
    cg_variant: str = "standard",
) -> CGResult:
    """hipBone (P)CG on assembled (length N_G) vectors; unweighted dots.

    ``precond``: optional z = M⁻¹r application (see core.precond); None
    gives the seed's plain CG.  ``fused_precond_dot``: optional one-pass
    (M⁻¹r, r·M⁻¹r) — the Pallas streaming fusion of the PCG inner product.
    ``tol``: stop at ‖r‖ ≤ tol·‖r₀‖ instead of running n_iter iterations.
    ``cg_variant``: "standard" (Fletcher–Reeves β, exact-symmetric M⁻¹) or
    "flexible" (Polak–Ribière β, robust to inexactly-symmetric appliers
    such as mixed-precision preconditioners — see module docstring).
    """
    return _pcg(
        operator,
        b_g,
        x0,
        n_iter=n_iter,
        tol=tol,
        weight=None,
        psum=psum,
        precond=precond,
        fused_update=fused_update,
        fused_precond_dot=fused_precond_dot,
        record_history=record_history,
        variant=cg_variant,
    )


def cg_scattered(
    operator: Callable[[jax.Array], jax.Array],
    b_l: jax.Array,
    w_local: jax.Array,
    x0: jax.Array | None = None,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    psum: Callable[[jax.Array], jax.Array] | None = None,
    precond: Callable[[jax.Array], jax.Array] | None = None,
    record_history: bool = False,
    cg_variant: str = "standard",
) -> CGResult:
    """NekBone baseline (P)CG on scattered (length N_L) vectors; weighted dots."""
    return _pcg(
        operator,
        b_l,
        x0,
        n_iter=n_iter,
        tol=tol,
        weight=w_local,
        psum=psum,
        precond=precond,
        fused_update=None,
        fused_precond_dot=None,
        record_history=record_history,
        variant=cg_variant,
    )
