"""Serve a small LM with batched requests: prefill + jitted decode loop.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-1b --reduced
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import init_model
from repro.serving import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"serving {cfg.name}: {n/1e6:.1f}M params, batch={args.batch}")

    eng = Engine(
        cfg, params,
        ServeConfig(
            batch=args.batch,
            capacity=args.prompt_len + args.max_new + 8,
            temperature=args.temperature,
        ),
    )
    prompts = jax.random.randint(
        jax.random.key(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    out = eng.generate(prompts, max_new=args.max_new)  # compile + warm
    t0 = time.perf_counter()
    out = eng.generate(prompts, max_new=args.max_new)
    dt = time.perf_counter() - t0
    total_new = args.batch * args.max_new
    print(f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s batched)")
    print("sample continuation token ids:", np.asarray(out[0, args.prompt_len:]))
    assert out.shape == (args.batch, args.prompt_len + args.max_new)


if __name__ == "__main__":
    main()
