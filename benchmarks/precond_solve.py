"""Preconditioned-solve benchmark: iterations-to-tolerance and FOM.

Beyond the NekBone 100-fixed-iteration benchmark: solve λ-screened deformed
Poisson problems to ``tol=1e-6`` with each preconditioner and report

  * iterations to tolerance (the preconditioner-quality signal),
  * wall time and FOM GFLOPS (NekBone flop model × iterations / time) —
    Chebyshev pays extra operator applies per iteration, so fewer
    iterations must buy back the per-iteration cost to win wall-clock.

Degrees follow the paper's sweep corners: N ∈ {3, 7, 9, 15} (quick: {3, 7}),
deform=0.15 so Jacobi has a non-trivial diagonal to chew on.
"""
from __future__ import annotations

import time

PRECONDS = ("none", "jacobi", "chebyshev")


def _solve_case(n: int, shape, lam: float, tol: float):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import build_problem, cg_assembled, poisson_assembled
    from repro.core.fom import nekbone_flops_per_iter
    from repro.core.precond import make_preconditioner

    prob = build_problem(n, shape, lam=lam, deform=0.15, dtype=jnp.float32)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    e = prob.mesh.n_elements

    out = []
    for kind in PRECONDS:
        pc, info = make_preconditioner(kind, prob, a, degree=2)
        solve = jax.jit(
            lambda bb, pc=pc: cg_assembled(a, bb, n_iter=500, tol=tol, precond=pc)
        )
        res = solve(b)
        jax.block_until_ready(res.x)
        t0 = time.perf_counter()
        res = solve(b)
        jax.block_until_ready(res.x)
        dt = time.perf_counter() - t0
        iters = int(res.iterations)
        fom = nekbone_flops_per_iter(e, n) * iters / dt / 1e9
        out.append((kind, iters, dt, fom, info.lmax))
    return prob.n_global, out


def main(quick: bool = True):
    degrees = [3, 7] if quick else [3, 7, 9, 15]
    shapes = {3: (4, 4, 4), 7: (4, 4, 4), 9: (3, 3, 3), 15: (2, 2, 2)}
    rows = ["precond,N,dofs,lam,kind,iters_to_tol,time_s,fom_gflops,cheb_lmax"]
    for n in degrees:
        for lam in (0.1, 1.0):
            dofs, cases = _solve_case(n, shapes[n], lam, tol=1e-6)
            for kind, iters, dt, fom, lmax in cases:
                rows.append(
                    f"precond,{n},{dofs},{lam},{kind},{iters},{dt:.4f},"
                    f"{fom:.2f},{'' if lmax is None else f'{lmax:.3f}'}"
                )
    return rows


if __name__ == "__main__":
    print("\n".join(main(quick=False)))
