"""Int8 gradient compression with error feedback for slow (inter-pod) links.

The intra-pod ICI is fast; the pod-to-pod links are the scarce resource on
a 512-chip two-pod mesh. ``compressed_psum`` quantizes a tensor to int8
with a per-tensor scale, all-reduces the int8 payload (4x less traffic on
the slow axis), and dequantizes. Error feedback (residual carried between
steps) keeps SGD convergence — quantization noise is compensated, not
accumulated (Seide et al. 2014 / Karimireddy et al. 2019).

Used by the manual-DP trainer variant (examples/train_lm.py --compress)
and unit-tested for the error-feedback contraction property.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "dequantize_int8", "compressed_psum", "ef_compressed_psum"]


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name) -> jax.Array:
    """psum with int8 payload (int32 accumulation; scales maxed)."""
    q, scale = quantize_int8(x)
    # use the max scale across ranks so dequantization is consistent
    gmax = lax.pmax(scale, axis_name)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / gmax), -127, 127
    ).astype(jnp.int8)
    total = lax.psum(q.astype(jnp.int32), axis_name)
    return total.astype(jnp.float32) * gmax


def ef_compressed_psum(
    x: jax.Array, residual: jax.Array, axis_name
) -> tuple[jax.Array, jax.Array]:
    """Error-feedback compressed psum.

    Sends Q(x + residual); the new residual is what compression dropped.
    Returns (psum result, new residual).
    """
    xc = x.astype(jnp.float32) + residual
    q, scale = quantize_int8(xc)
    gmax = lax.pmax(scale, axis_name)
    qv = jnp.clip(jnp.round(xc / gmax), -127, 127).astype(jnp.int8)
    sent = qv.astype(jnp.float32) * gmax
    new_residual = xc - sent
    total = lax.psum(qv.astype(jnp.int32), axis_name).astype(jnp.float32) * gmax
    return total, new_residual
