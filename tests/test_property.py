"""Hypothesis property tests on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import build_problem, poisson_assembled
from repro.core.gather_scatter import gather, scatter
from repro.core.mesh import build_box_mesh, partition_elements
from repro.comms.topology import factor3
from repro.models.moe import router_topk
from repro.models.config import ModelConfig
from repro.training.compress import dequantize_int8, quantize_int8

SMALL = settings(max_examples=25, deadline=None)


@SMALL
@given(
    n=st.integers(1, 5),
    ex=st.integers(1, 3),
    ey=st.integers(1, 3),
    ez=st.integers(1, 3),
    seed=st.integers(0, 10_000),
)
def test_gather_scatter_adjoint(n, ex, ey, ez, seed):
    """<Z x, y>_L == <x, Z^T y>_G — Z and Z^T are adjoint by construction."""
    m = build_box_mesh(n, (ex, ey, ez))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(m.n_global), jnp.float32)
    y = jnp.asarray(
        rng.standard_normal((m.n_elements, m.points_per_element)), jnp.float32
    )
    lhs = float(jnp.vdot(scatter(x, jnp.asarray(m.l2g)), y))
    rhs = float(jnp.vdot(x, gather(y, jnp.asarray(m.l2g), m.n_global)))
    assert abs(lhs - rhs) <= 1e-3 * (abs(lhs) + 1.0)


@SMALL
@given(n=st.integers(1, 4), seed=st.integers(0, 100))
def test_operator_linearity(n, seed):
    prob = build_problem(n, (2, 2, 1), lam=1.0, dtype=jnp.float32)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    y = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    lhs = np.array(a(2.0 * x + 3.0 * y))
    rhs = 2.0 * np.array(a(x)) + 3.0 * np.array(a(y))
    np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)


@SMALL
@given(p=st.integers(1, 4096))
def test_factor3_partitions_exactly(p):
    a, b, c = factor3(p)
    assert a * b * c == p and a >= b >= c >= 1


@SMALL
@given(
    px=st.integers(1, 3), py=st.integers(1, 3), pz=st.integers(1, 3),
)
def test_partition_covers_all_elements(px, py, pz):
    shape = (2 * px, 2 * py, 2 * pz)
    owner = partition_elements(shape, (px, py, pz))
    counts = np.bincount(owner, minlength=px * py * pz)
    assert (counts == counts[0]).all()  # balanced block partition
    assert counts.sum() == np.prod(shape)


@SMALL
@given(
    t=st.integers(1, 64),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 1000),
)
def test_router_topk_weights_normalized(t, e, k, seed):
    k = min(k, e)
    cfg = ModelConfig(
        name="x", family="moe", n_layers=1, d_model=8, n_heads=1, n_kv_heads=1,
        head_dim=8, d_ff=8, vocab_size=8, n_experts=e, experts_per_token=k,
    )
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    w, idx, probs = router_topk(logits, cfg)
    assert w.shape == (t, k) and idx.shape == (t, k)
    np.testing.assert_allclose(np.array(w).sum(-1), 1.0, rtol=1e-5)
    assert (np.array(idx) >= 0).all() and (np.array(idx) < e).all()
    # indices unique per token
    for row in np.array(idx):
        assert len(set(row.tolist())) == k


@SMALL
@given(seed=st.integers(0, 1000), scale=st.floats(1e-3, 1e3))
def test_int8_quantization_bounded_error(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s)
    # error bounded by half a quantization step
    assert float(jnp.max(jnp.abs(back - x))) <= float(s) * 0.5 + 1e-6


@SMALL
@given(n=st.integers(1, 8), seed=st.integers(0, 50))
def test_ssd_chunk_invariance(n, seed):
    """Chunk size must not change SSD results (associativity of the scan)."""
    from repro.models.mamba2 import ssd_chunked

    s = 8 * n
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((1, s, 2, 4)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((1, s, 2))) * 0.3 + 0.05, jnp.float32)
    a = jnp.asarray(-np.abs(rng.standard_normal(2)) - 0.1, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((1, s, 1, 3)), jnp.float32)
    cm = jnp.asarray(rng.standard_normal((1, s, 1, 3)), jnp.float32)
    y1, s1 = ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y2, s2 = ssd_chunked(x, dt, a, bm, cm, chunk=min(s, 4 * n))
    np.testing.assert_allclose(np.array(y1), np.array(y2), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.array(s1), np.array(s2), rtol=2e-4, atol=2e-4)
