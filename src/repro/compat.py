"""jax version-compat shims for the small API surface the repo depends on.

The repo targets the current jax API (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``), but the
pinned CI/runtime image may carry an older jax (0.4.x) where shard_map
still lives in ``jax.experimental`` under the ``check_rep`` spelling and
meshes have no axis types.  Everything in-repo goes through these three
helpers so a jax upgrade is a no-op and a downgrade is survivable:

  * ``make_mesh(shape, names)``       — Auto-typed mesh where supported
  * ``shard_map(f, mesh=..., ...)``   — check_rep/check_vma translated
  * ``abstract_mesh(shape, names)``   — both AbstractMesh signatures
"""
from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

__all__ = ["make_mesh", "shard_map", "abstract_mesh", "axis_size", "pcast_varying"]

_AXIS_TYPE_AUTO = getattr(getattr(jax.sharding, "AxisType", None), "Auto", None)


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[Any] | None = None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with Auto axis types where the concept exists."""
    if _AXIS_TYPE_AUTO is not None:
        return jax.make_mesh(
            tuple(axis_shapes),
            tuple(axis_names),
            devices=devices,
            axis_types=(_AXIS_TYPE_AUTO,) * len(tuple(axis_names)),
        )
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), devices=devices)


if hasattr(jax, "shard_map"):

    def shard_map(f: Callable, *, mesh, in_specs, out_specs, check_rep: bool = True):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_rep,
        )

else:  # jax < 0.5: experimental module, check_rep spelling
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f: Callable, *, mesh, in_specs, out_specs, check_rep: bool = True):
        return _shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=check_rep,
        )


if hasattr(jax.lax, "axis_size"):

    def axis_size(axis_name) -> int:
        return jax.lax.axis_size(axis_name)

else:

    def axis_size(axis_name) -> int:
        # psum of a Python literal over a named axis folds to a static int
        return jax.lax.psum(1, axis_name)


if hasattr(jax.lax, "pcast"):

    def pcast_varying(x, axes):
        """Mark ``x`` device-varying over ``axes`` (new-jax vma typing)."""
        return jax.lax.pcast(x, tuple(axes), to="varying")

else:

    def pcast_varying(x, axes):
        # old shard_map has no varying-manual-axes type system; its
        # check_rep rewrite inserts pbroadcasts itself, so identity is right
        return x


def abstract_mesh(
    axis_shapes: Sequence[int], axis_names: Sequence[str]
) -> jax.sharding.AbstractMesh:
    """AbstractMesh across the (sizes, names) / ((name, size),...) signatures."""
    try:
        return jax.sharding.AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(zip(tuple(axis_names), tuple(axis_shapes)))
        )
