"""Batched serving engine: prefill + jitted decode loop with KV caches.

Slot-based batching: a fixed batch of request slots decodes in lockstep
(the decode_32k dry-run shape); prompts are right-aligned into a shared
capacity. Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models.blocks import MeshContext
from ..models.config import ModelConfig
from ..models.model import decode_step, init_caches, prefill

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch: int
    capacity: int           # max context length
    temperature: float = 0.0
    seed: int = 0


class Engine:
    def __init__(self, cfg: ModelConfig, params: Any, scfg: ServeConfig, mc: MeshContext | None = None):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.mc = mc or MeshContext()
        self._decode = jax.jit(
            functools.partial(decode_step, cfg=cfg, mc=self.mc)
        )
        self._prefill = jax.jit(functools.partial(prefill, cfg=cfg, mc=self.mc))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits[:, -1], axis=-1)
        return jax.random.categorical(
            key, logits[:, -1] / self.scfg.temperature, axis=-1
        )

    def generate(self, prompts: jax.Array, max_new: int) -> jax.Array:
        """prompts: (B, S0) int32 -> (B, S0 + max_new)."""
        b, s0 = prompts.shape
        assert b == self.scfg.batch
        logits, caches = self._prefill(self.params, prompts)
        # re-home prefill caches into full-capacity buffers
        full = init_caches(self.cfg, b, self.scfg.capacity, jnp.dtype(self.cfg.dtype))
        def place(pref, buf):
            if pref.shape == buf.shape:
                return pref
            sl = [slice(None)] * buf.ndim
            for i, (a, c) in enumerate(zip(pref.shape, buf.shape)):
                if a != c:
                    sl[i] = slice(0, a)
                    break
            return buf.at[tuple(sl)].set(pref)
        caches = jax.tree.map(place, caches, full)

        key = jax.random.key(self.scfg.seed)
        toks = [self._sample(logits, key)]
        out = prompts
        for i in range(max_new):
            key, sub = jax.random.split(key)
            tok = toks[-1][:, None]
            out = jnp.concatenate([out, tok], axis=1)
            if i == max_new - 1:
                break
            logits, caches = self._decode(
                self.params, tok, jnp.int32(s0 + i), caches
            )
            toks.append(self._sample(logits, sub))
        return out
