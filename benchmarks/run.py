"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--full]`` prints CSV rows per benchmark:
  fig3     — operator GFLOPS vs N + roofline      (paper Fig. 3)
  table1   — kernel occupancy/VMEM analogue       (paper Table 1)
  fig456   — multi-rank scaling + throughput      (paper Figs. 4-6)
  table2   — peak FOM / weak scaling / NekBone-vs-hipBone (paper Table 2)
  exchange — routing-algorithm selection          (paper §MPI Communication)
  precond  — PCG iterations-to-tolerance + FOM    (beyond the benchmark)
  batched  — multi-RHS setup amortization sweep   (beyond the benchmark)

``--only`` takes a comma-separated section list (``--only fig3,precond``).

``--json PATH`` additionally writes a machine-readable summary: every
section's raw CSV rows plus the precond sweep (``precond_records``), the
fig3 sweep (``fig3_records``), the multi-RHS amortization sweep
(``batched_records``: per-(N, kind, B) max column iterations, setup-cache
hit/miss state and per-solve wall share) and the halo-exchange plan build
(``exchange_records``: per-site candidate timings, winning routing, wire
bytes — the ``comms.plan`` autotuner over a real solver setup's site
list) as structured records.  Every record in
both carries the dry-run roofline triple ``model_bytes`` /
``achievable_s`` / ``pct_roofline`` (analytic Eq. 4–6 traffic bound over
the AOT-compiled program's own HLO roofline time at the TPU_V5E
constants — machine-independent; see roofline/bench.py), alongside the
precond sweep's per-config iterations-to-tol, solve time, effective FOM
and per-application preconditioner wall time ``precond_apply_s``.  The
perf trajectory is tracked across PRs — CI passes ``--json
BENCH_pr6.json`` (bump the name per PR) and gates on
``scripts/compare_bench.py``, which fails if any shared case needs more
iterations or loses more roofline fraction than the slack allows.  The
full json schema and gate rules are documented in docs/BENCHMARKS.md.
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger problem sizes")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated section names (e.g. fig3,precond)",
    )
    ap.add_argument(
        "--json",
        default="",
        help="write a machine-readable summary to this path (off by default)",
    )
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        batched_solve,
        exchange_select,
        fig3_operator,
        fig456_scaling,
        precond_solve,
        table1_blocks,
        table2_fom,
    )

    sections = {
        "fig3": None,  # records sections: sweep runs once, json gets dicts
        "table1": table1_blocks.main,
        "fig456": fig456_scaling.main,
        "table2": table2_fom.main,
        "exchange": None,
        "precond": None,
        "batched": None,
    }
    only = set(args.only.split(",")) if args.only else None
    if only:
        unknown = only - set(sections)
        if unknown:
            sys.exit(f"unknown section(s): {','.join(sorted(unknown))}")
    summary: dict = {"quick": quick, "sections": {}, "failures": []}
    failures = 0
    for name, fn in sections.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            if name == "precond":
                recs = precond_solve.records(quick=quick)
                rows = precond_solve.rows_from(recs)
                summary["precond_records"] = recs
            elif name == "fig3":
                recs = fig3_operator.records(quick=quick)
                rows = fig3_operator.rows_from(recs)
                summary["fig3_records"] = recs
            elif name == "batched":
                recs = batched_solve.records(quick=quick)
                rows = batched_solve.rows_from(recs)
                summary["batched_records"] = recs
            elif name == "exchange":
                recs = exchange_select.records(quick=quick)
                rows = exchange_select.main(quick=quick)
                rows += exchange_select.rows_from(recs)
                summary["exchange_records"] = recs
            else:
                rows = list(fn(quick=quick))
            for row in rows:
                print(row, flush=True)
            summary["sections"][name] = rows
        except Exception as e:  # report and continue
            failures += 1
            msg = f"{name},ERROR,{type(e).__name__}: {e}"
            summary["failures"].append(msg)
            print(msg, flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
