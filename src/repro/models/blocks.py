"""Layer assembly: mixer + FFN with residuals/norms; MoE sharding modes.

A layer is (pre-)norm -> mixer (attention or mamba) -> residual -> norm ->
FFN (dense or MoE) -> residual, or the Cohere parallel-residual variant
(one norm feeding mixer and FFN simultaneously).

The MoE runs under an explicit shard_map:
  * ``ep`` mode (n_experts divisible by the model-axis size): tokens are
    sequence-split across expert shards and dispatched through the paper's
    exchange library (comms.exchange) — C3 at work;
  * ``tp`` mode (few large experts, e.g. Mixtral 8e on a 16-way axis):
    every shard processes all tokens against ff-sharded experts and psums —
    no exchange needed.
The mode is picked statically per (config, mesh, token count).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from .attention import attn_decode, attn_forward, init_attention, init_attn_cache
from .common import init_norm, norm
from .config import LayerKind, ModelConfig
from .mamba2 import init_mamba, init_mamba_cache, mamba_decode, mamba_forward
from .mlp import init_mlp, mlp_apply
from .moe import moe_apply
from .moe import init_moe as _init_moe
from .params import ParamBuilder

__all__ = ["MeshContext", "init_layer", "layer_forward", "layer_decode", "init_layer_cache"]


@dataclasses.dataclass(frozen=True)
class MeshContext:
    """How this forward pass maps onto the device mesh (None = single device)."""

    mesh: Any = None
    batch_axes: tuple = ()          # mesh axes sharding the batch dim
    tp_axis: str | None = None      # tensor/expert-parallel axis
    seq_axes: tuple = ()            # decode: KV-cache sequence sharding
    exchange: str = "all_to_all"    # MoE dispatch routing algorithm
    act_seq_axis: str | None = None  # SP: shard stored layer inputs over seq

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------
def init_layer(key, cfg: ModelConfig, kind: LayerKind, dtype) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    params, axes = pb.collect()
    params["norm1"], axes["norm1"] = init_norm(pb.fork(), cfg)
    if kind.mixer == "mamba":
        params["mixer"], axes["mixer"] = init_mamba(pb.fork(), cfg, dtype)
    else:
        params["mixer"], axes["mixer"] = init_attention(pb.fork(), cfg, dtype)
    if not cfg.parallel_residual:
        params["norm2"], axes["norm2"] = init_norm(pb.fork(), cfg)
    if kind.ffn == "moe":
        params["ffn"], axes["ffn"] = _init_moe(pb.fork(), cfg, dtype)
    elif kind.ffn == "dense":
        params["ffn"], axes["ffn"] = init_mlp(pb.fork(), cfg, dtype)
    return params, axes


def init_layer_cache(cfg: ModelConfig, kind: LayerKind, batch: int, capacity: int, dtype):
    if kind.mixer == "mamba":
        return init_mamba_cache(cfg, batch, dtype)
    return init_attn_cache(cfg, batch, capacity, dtype)


# --------------------------------------------------------------------------
# FFN dispatch (dense / MoE under shard_map)
# --------------------------------------------------------------------------
def _moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig, mc: MeshContext):
    b, s, d = x.shape
    tp = mc.tp_size
    if tp == 1:
        y, aux = moe_apply(p, x.reshape(b * s, d), cfg, ep_axis=None)
        return y.reshape(b, s, d), aux

    ep_ok = (cfg.n_experts % tp == 0) and (s % tp == 0)
    ax = mc.tp_axis
    b_ax = mc.batch_axes if mc.batch_axes else None
    if ep_ok:
        # EP: shard_map splits the sequence over the expert axis — each
        # shard routes its own token slab and dispatches via the exchange.
        xspec = P(b_ax, ax, None)
        espec = P(ax, None, None)        # experts sharded over model axis

        def inner(xs, wr, wg, wu, wd, *shared):
            bl, sl, _ = xs.shape
            pp = {"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}
            if shared:
                pp["ws_gate"], pp["ws_up"], pp["ws_down"] = shared
            y, aux = moe_apply(
                pp, xs.reshape(bl * sl, d), cfg, ep_axis=ax,
                exchange=mc.exchange,
            )
            # aux varies over batch and expert axes — average both away
            aux = lax.pmean(aux, tuple(mc.batch_axes) + (ax,))
            return y.reshape(bl, sl, d), aux

        in_specs = [xspec, P(None, None), espec, espec, espec]
        out_specs = (xspec, P())
        args = [x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"]]
        if cfg.n_shared_experts:
            in_specs += [P(None, None)] * 3
            args += [p["ws_gate"], p["ws_up"], p["ws_down"]]
    else:
        # TP: experts ff-sharded; every shard processes all tokens and the
        # partial down-projections psum over the model axis.
        xspec = P(b_ax, None, None)
        wg_spec = P(None, None, ax)
        wd_spec = P(None, ax, None)

        def inner(xs, wr, wg, wu, wd, *shared):
            bl, sl, _ = xs.shape
            pp = {"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}
            if shared:
                pp["ws_gate"], pp["ws_up"], pp["ws_down"] = shared
            y, aux = moe_apply(pp, xs.reshape(bl * sl, d), cfg, ep_axis=None)
            y = lax.psum(y, ax)
            if mc.batch_axes:       # aux is already invariant over ax
                aux = lax.pmean(aux, tuple(mc.batch_axes))
            return y.reshape(bl, sl, d), aux

        in_specs = [xspec, P(None, None), wg_spec, wg_spec, wd_spec]
        out_specs = (xspec, P())
        args = [x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"]]
        if cfg.n_shared_experts:
            in_specs += [P(None, ax), P(None, ax), P(ax, None)]
            args += [p["ws_gate"], p["ws_up"], p["ws_down"]]

    fn = shard_map(
        inner,
        mesh=mc.mesh,
        in_specs=tuple(in_specs),
        out_specs=out_specs,
    )
    return fn(*args)


def _axsize(mc: MeshContext, axes: tuple) -> int:
    if not axes or mc.mesh is None:
        return 1
    n = 1
    for a in axes:
        n *= mc.mesh.shape[a]
    return n


def _ffn(p: dict, x: jax.Array, cfg: ModelConfig, kind: LayerKind, mc: MeshContext):
    if kind.ffn == "moe":
        return _moe_ffn(p["ffn"], x, cfg, mc)
    if kind.ffn == "dense":
        return mlp_apply(p["ffn"], x, cfg), jnp.zeros((), jnp.float32)
    return jnp.zeros_like(x), jnp.zeros((), jnp.float32)


# --------------------------------------------------------------------------
# forward / decode
# --------------------------------------------------------------------------
def layer_forward(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    kind: LayerKind,
    mc: MeshContext,
    *,
    make_cache: bool = False,
):
    h = norm(x, p["norm1"], cfg)
    if kind.mixer == "mamba":
        mix, cache = mamba_forward(p["mixer"], h, cfg, make_cache=make_cache)
    else:
        mix, cache = attn_forward(
            p["mixer"], h, positions, cfg,
            local=(kind.mixer == "attn_local"),
            make_cache=make_cache,
        )
    if cfg.parallel_residual:
        f, aux = _ffn(p, h, cfg, kind, mc)
        x = x + mix + f
    else:
        x = x + mix
        h2 = norm(x, p["norm2"], cfg)
        f, aux = _ffn(p, h2, cfg, kind, mc)
        x = x + f
    return x, cache, aux


def _attn_decode_dispatch(
    p: dict,
    h: jax.Array,
    t: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    local: bool,
    mc: MeshContext,
):
    """attn_decode, wrapped in shard_map when the KV seq axis is sharded."""
    if not mc.seq_axes or mc.mesh is None:
        return attn_decode(p, h, t, cache, cfg, local=local, seq_axes=None)

    b_ax = mc.batch_axes if mc.batch_axes else None
    seq = mc.seq_axes if len(mc.seq_axes) > 1 else mc.seq_axes[0]
    xspec = P(b_ax, None, None)
    pspec = jax.tree.map(lambda a: P(*([None] * a.ndim)), p)
    cspec = jax.tree.map(
        lambda a: P(*([b_ax, seq] + [None] * (a.ndim - 2))), cache
    )

    fn = shard_map(
        functools.partial(
            attn_decode, cfg=cfg, local=local, seq_axes=mc.seq_axes,
            vary_axes=tuple(mc.batch_axes) + tuple(mc.seq_axes),
        ),
        mesh=mc.mesh,
        in_specs=(pspec, xspec, P(), cspec),
        out_specs=(xspec, cspec),
    )
    return fn(p, h, t, cache)


def layer_decode(
    p: dict,
    x: jax.Array,
    t: jax.Array,
    cache: dict,
    cfg: ModelConfig,
    kind: LayerKind,
    mc: MeshContext,
):
    h = norm(x, p["norm1"], cfg)
    if kind.mixer == "mamba":
        mix, cache = mamba_decode(p["mixer"], h, cache, cfg)
    else:
        mix, cache = _attn_decode_dispatch(
            p["mixer"], h, t, cache, cfg, kind.mixer == "attn_local", mc
        )
    if cfg.parallel_residual:
        f, _ = _ffn(p, h, cfg, kind, mc)
        x = x + mix + f
    else:
        x = x + mix
        h2 = norm(x, p["norm2"], cfg)
        f, _ = _ffn(p, h2, cfg, kind, mc)
        x = x + f
    return x, cache
