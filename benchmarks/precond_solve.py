"""Preconditioned-solve benchmark: iterations-to-tolerance, FOM, precision.

Beyond the NekBone 100-fixed-iteration benchmark: solve λ-screened deformed
Poisson problems to ``tol=1e-8`` with each rung of the preconditioner
ladder — none / jacobi / chebyshev / schwarz / pmg (Chebyshev-smoothed) /
pmg-schwarz (Schwarz-smoothed) / pmg-galerkin (exact PᵀAP coarse
operators, chained matrix-free) / pmg-galerkin-mat (the same PᵀAP
materialized at setup into per-element blocks, zero fine-operator work per
coarse apply) — and report

  * iterations to tolerance (the preconditioner-quality signal),
  * wall time, and the *effective* FOM GFLOPS (NekBone flop model ×
    iterations / time) — Chebyshev pays extra operator applies per
    iteration, Schwarz pays per-element extended-block FDM solves, and the
    pMG V-cycle pays a whole smoothing hierarchy, so fewer iterations must
    buy back the per-iteration cost to win wall-clock;
  * the per-application preconditioner wall time (``precond_apply_s``) —
    the bandwidth axis: a mixed row that ties on iterations still wins if
    each M⁻¹ apply streams half the bytes.

Every preconditioned rung runs twice per (N, λ) cell: ``dtype="fp64"``
(the all-fp64 baseline — the outer solve must stay fp64 because tol=1e-8
sits below fp32 CG's stall point) and ``dtype="mixed"`` (fp32
preconditioner chain behind one cast boundary, flexible-β outer PCG —
``make_preconditioner(precond_dtype=jnp.float32)`` +
``cg_assembled(cg_variant="flexible")``).  The acceptance bar: every mixed
row reaches tolerance within +1 iteration of its fp64 twin.

The fused Pallas streaming stages (fused_jacobi_dot / fused_cheb_d_update)
auto-enable on the mixed rows when the backend compiles Pallas natively
(``kernels.ops.should_fuse_streams``: non-interpret backend + fp32 data);
set ``HIPBONE_FUSED=0`` (or pass ``use_fused=False``) to opt out, ``=1``
to force them through interpret mode.

Degrees cover the paper's sweep corners: N ∈ {3, 7, 15} (quick; full adds
9), deform=0.15 so Jacobi has a non-trivial diagonal to chew on.

``main`` returns CSV rows; ``records`` returns the same data as dicts for
the machine-readable BENCH json emitted by ``benchmarks.run``
(``scripts/compare_bench.py`` gates on the (N, λ, kind, dtype,
coefficient) keys).

The variable-coefficient rows (pr10) solve A = -∇·(k(x)∇) + λ(x) with
the "smooth" family under mixed BCs and the "checker" octant-jump family
under pure Dirichlet, at N ∈ {3, 7} over the ``VARCOEF_PRECONDS`` ladder
subset — coefficients reach every rung through the folded g/w streams,
so per-apply cost is unchanged by construction and the new rows gate on
iterations/status like every other.
"""
from __future__ import annotations

import time

# ladder order: cost per application rises, iterations-to-tol falls
# (pmg-galerkin-mat: same iterations as pmg-galerkin by construction —
# the materialized P^T A P blocks are the same matrix — with the chained
# fine-grid recursion replaced by one batched element matvec per coarse
# apply; the win shows in precond_apply_s)
PRECONDS = (
    "none",
    "jacobi",
    "chebyshev",
    "schwarz",
    "pmg",
    "pmg-schwarz",
    "pmg-galerkin",
    "pmg-galerkin-mat",
)
# kind -> (make_preconditioner kind, extra kwargs)
PRECOND_RECIPES = {
    "none": ("none", {}),
    "jacobi": ("jacobi", {}),
    "chebyshev": ("chebyshev", {"degree": 2}),
    "schwarz": ("schwarz", {}),
    "pmg": ("pmg", {}),
    "pmg-schwarz": ("pmg", {"pmg_smoother": "schwarz"}),
    "pmg-galerkin": ("pmg", {"pmg_coarse_op": "galerkin"}),
    "pmg-galerkin-mat": ("pmg", {"pmg_coarse_op": "galerkin_mat"}),
}
TOL = 1e-8
APPLY_REPS = 10


def _use_fused_default():
    from repro.kernels import ops

    return ops.fused_override()  # None -> auto: should_fuse_streams


# the variable-coefficient rows run a representative ladder subset (the
# cheap rungs plus the iteration-count champion) — coefficients reach
# every rung through the same folded g/w streams, so the full 8-rung
# sweep on the const rows already covers the per-rung cost axis
VARCOEF_PRECONDS = ("jacobi", "chebyshev", "schwarz", "pmg", "pmg-galerkin-mat")
# coefficient family -> bc spec for its benchmark rows
VARCOEF_CASES = (("smooth", "mixed"), ("checker", "dirichlet"))


def _solve_case(
    n: int,
    shape,
    lam: float,
    tol: float,
    use_fused=None,
    coefficient: str | None = None,
    bc=None,
    preconds=PRECONDS,
):
    import jax

    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    import numpy as np

    from repro.core import (
        build_problem,
        cg_assembled,
        poisson_assembled,
        status_name,
    )
    from repro.core.fom import cg_iter_bytes, nekbone_flops_per_iter
    from repro.core.operator import cast_problem
    from repro.core.precond import (
        PrecondInfo,
        assembled_diagonal,
        cast_apply,
        jacobi_apply,
        make_preconditioner,
    )
    from repro.kernels import ops
    from repro.roofline import dryrun_roofline

    if use_fused is None:
        use_fused = _use_fused_default()
    fuse = (
        ops.should_fuse_streams(jnp.float32) if use_fused is None else use_fused
    )

    prob = build_problem(
        n, shape, lam=lam, deform=0.15, dtype=jnp.float64,
        coefficient=coefficient, bc=bc,
    )
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = rng.standard_normal(prob.n_global)
    if prob.mask is not None:
        b = b * np.asarray(prob.mask, np.float64)
    b = jnp.asarray(b, jnp.float64)
    e = prob.mesh.n_elements

    out = []
    for name in preconds:
        kind, kwargs = PRECOND_RECIPES[name]
        for dtype_mode in ("fp64", "mixed"):
            if dtype_mode == "mixed" and kind == "none":
                continue  # "mixed" means fp32 M⁻¹; plain CG has no M⁻¹
            mixed = dtype_mode == "mixed"
            pc_kwargs = dict(kwargs)
            if mixed and fuse and kind == "chebyshev":
                # fused d-update streams the fp32 Chebyshev interior
                pc_kwargs["fused_d_update"] = ops.make_fused_cheb_d_update()
            if mixed and fuse and kwargs.get("pmg_coarse_op") == "galerkin_mat":
                # Pallas batched matvec over the fp32 materialized blocks
                pc_kwargs["galerkin_matvec"] = ops.make_block_matvec()
            if mixed and fuse and kind == "jacobi":
                # one fp32 diagonal feeds BOTH the gate apply and the fused
                # stage, so they cannot drift apart
                dinv32 = 1.0 / assembled_diagonal(
                    cast_problem(prob, jnp.float32)
                )
                pc = cast_apply(jacobi_apply(dinv32), jnp.float32, jnp.float64)
                info = PrecondInfo("jacobi", 1, None, dtype="float32")
            else:
                dinv32 = None
                pc, info = make_preconditioner(
                    kind, prob, a,
                    precond_dtype=jnp.float32 if mixed else None,
                    **pc_kwargs,
                )
            cg_kwargs = {}
            if mixed:
                # fp32 M⁻¹ is only approximately symmetric in fp64 -> PR β
                cg_kwargs["cg_variant"] = "flexible"
                if dinv32 is not None:
                    cg_kwargs["fused_precond_dot"] = ops.make_fused_jacobi_dot(
                        dinv32, out_dtype=jnp.float64
                    )
            # AOT: one lowering serves both the timed run and the dry-run
            # HLO roofline analysis (compiled.as_text()).
            solve = jax.jit(
                lambda bb, pc=pc, kw=cg_kwargs: cg_assembled(
                    a, bb, n_iter=500, tol=tol, precond=pc, **kw
                )
            )
            compiled = solve.lower(b).compile()
            res = compiled(b)
            jax.block_until_ready(res.x)
            t0 = time.perf_counter()
            res = compiled(b)
            jax.block_until_ready(res.x)
            dt = time.perf_counter() - t0
            iters = int(res.iterations)
            fom = nekbone_flops_per_iter(e, n) * iters / dt / 1e9
            # pct_roofline: analytic Eq. 6 traffic × the HLO n_iter cap vs
            # the compiled program's own roofline bound — machine-free, so
            # compare_bench.py can gate it across PRs.
            roof = dryrun_roofline(
                compiled,
                model_bytes_per_iter=cg_iter_bytes(e, n, word=8),
                trip_cap=500,
            )

            # per-application M⁻¹ wall time: the bandwidth win shows here
            # even where iteration counts tie
            apply_s = None
            if pc is not None:
                papply = jax.jit(pc)
                jax.block_until_ready(papply(b))
                t0 = time.perf_counter()
                for _ in range(APPLY_REPS):
                    z = papply(b)
                jax.block_until_ready(z)
                apply_s = (time.perf_counter() - t0) / APPLY_REPS

            out.append(
                {
                    "n": n,
                    "dofs": prob.n_global,
                    "lam": lam,
                    "kind": name,
                    "dtype": dtype_mode,
                    # coefficient family ("const" = the legacy constant-λ
                    # screen; part of compare_bench.py's precond key)
                    "coefficient": coefficient or "const",
                    "bc": bc,
                    "iters_to_tol": iters,
                    # SolveStatus wire name; compare_bench.py fails any
                    # gated row whose status is not "converged"
                    "status": status_name(res.status),
                    "time_s": dt,
                    "fom_gflops": fom,
                    "model_bytes": roof["model_bytes"],
                    "achievable_s": roof["achievable_s"],
                    "pct_roofline": roof["pct_roofline"],
                    "precond_apply_s": apply_s,
                    "lmax": info.lmax,
                    "lmin": info.lmin,
                    "levels": None if info.levels is None else list(info.levels),
                }
            )
    return out


def records(quick: bool = True, use_fused=None) -> list[dict]:
    """Structured sweep results (one dict per (N, λ, precond, dtype) case).

    The constant-λ sweep is unchanged from pr9 (same problems, same rng,
    same recipes — iteration counts must gate bit-identical); the
    variable-coefficient rows (``VARCOEF_CASES`` × N ∈ {3, 7} ×
    ``VARCOEF_PRECONDS``) are a strict addition keyed by their
    ``coefficient`` field.
    """
    degrees = [3, 7, 15] if quick else [3, 7, 9, 15]
    shapes = {3: (4, 4, 4), 7: (4, 4, 4), 9: (3, 3, 3), 15: (2, 2, 2)}
    recs: list[dict] = []
    for n in degrees:
        for lam in (0.1, 1.0):
            recs.extend(
                _solve_case(n, shapes[n], lam, tol=TOL, use_fused=use_fused)
            )
    for n in (3, 7):
        for coefficient, bc in VARCOEF_CASES:
            recs.extend(
                _solve_case(
                    n, shapes[n], 1.0, tol=TOL, use_fused=use_fused,
                    coefficient=coefficient, bc=bc,
                    preconds=VARCOEF_PRECONDS,
                )
            )
    return recs


def rows_from(recs: list[dict]) -> list[str]:
    """CSV rows for a list of :func:`records` results."""
    rows = [
        "precond,N,dofs,lam,kind,dtype,coefficient,status,iters_to_tol,"
        "time_s,fom_gflops,pct_roofline,precond_apply_s,cheb_lmax,"
        "cheb_lmin,pmg_levels"
    ]
    for r in recs:
        lmax = "" if r["lmax"] is None else f"{r['lmax']:.3f}"
        lmin = "" if r["lmin"] is None else f"{r['lmin']:.3f}"
        levels = "" if r["levels"] is None else "-".join(map(str, r["levels"]))
        papply = (
            ""
            if r["precond_apply_s"] is None
            else f"{r['precond_apply_s']:.5f}"
        )
        pct = (
            ""
            if r.get("pct_roofline") is None
            else f"{r['pct_roofline']:.1f}"
        )
        rows.append(
            f"precond,{r['n']},{r['dofs']},{r['lam']},{r['kind']},"
            f"{r['dtype']},{r.get('coefficient', 'const')},"
            f"{r.get('status', 'converged')},"
            f"{r['iters_to_tol']},{r['time_s']:.4f},"
            f"{r['fom_gflops']:.2f},{pct},{papply},{lmax},{lmin},{levels}"
        )
    return rows


def main(quick: bool = True):
    return rows_from(records(quick))


if __name__ == "__main__":
    print("\n".join(main(quick=False)))
