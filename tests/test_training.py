"""Training substrate: optimizers, schedules, clipping, checkpoint, runner, data."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import SyntheticLM, TextLM
from repro.models.config import ModelConfig
from repro.models.model import init_model
from repro.training import (
    RunnerConfig,
    TrainRunner,
    adafactor,
    adamw,
    clip_by_global_norm,
    global_norm,
    latest_step,
    make_train_step,
    restore,
    save,
    sgd_momentum,
    warmup_cosine,
)

CFG = ModelConfig(
    name="t", family="dense", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
    head_dim=12, d_ff=96, vocab_size=128, dtype="float32",
)


@pytest.fixture(scope="module")
def setup():
    params, _ = init_model(CFG, jax.random.key(0), jnp.float32)
    data = SyntheticLM(vocab_size=128, batch=8, seq_len=32)
    return params, data


@pytest.mark.parametrize("optname", ["adamw", "adafactor", "sgd"])
def test_loss_decreases(optname, setup):
    params, data = setup
    opt = {"adamw": adamw(), "adafactor": adafactor(), "sgd": sgd_momentum()}[optname]
    lr = {"adamw": 3e-3, "adafactor": 3e-3, "sgd": 3e-2}[optname]
    step = jax.jit(
        make_train_step(CFG, opt, warmup_cosine(peak_lr=lr, warmup=10, total=100))
    )
    p, s = params, opt.init(params)
    losses = []
    for i in range(30):
        p, s, m = step(p, s, {"tokens": jnp.asarray(data(i)["tokens"])}, jnp.int32(i))
        losses.append(float(m["nll"]))
    assert losses[-1] < losses[0] - 0.2, (optname, losses[0], losses[-1])


def test_microbatch_equals_full_batch(setup):
    """Gradient accumulation must match the single-batch gradient step."""
    params, data = setup
    opt = sgd_momentum(momentum=0.0)
    batch = {"tokens": jnp.asarray(data(0)["tokens"])}
    lr = lambda i: jnp.float32(1e-2)
    s1 = jax.jit(make_train_step(CFG, opt, lr, microbatches=1))
    s4 = jax.jit(make_train_step(CFG, opt, lr, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch, jnp.int32(0))
    p4, _, m4 = s4(params, opt.init(params), batch, jnp.int32(0))
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_adafactor_memory_factored(setup):
    params, _ = setup
    state = adafactor().init(params)
    p_bytes = sum(x.size * 4 for x in jax.tree.leaves(params))
    v_bytes = sum(x.size * 4 for x in jax.tree.leaves(state["v"]))
    assert v_bytes < 0.25 * p_bytes  # factored second moment is tiny


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    np.testing.assert_allclose(float(norm), np.sqrt(10 * 9 + 10 * 16), rtol=1e-6)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)


def test_warmup_cosine_shape():
    lr = warmup_cosine(peak_lr=1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    np.testing.assert_allclose(float(lr(jnp.int32(10))), 1.0, rtol=1e-6)
    assert float(lr(jnp.int32(100))) < 0.11


def test_checkpoint_roundtrip_and_retention(setup):
    params, _ = setup
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save(d, s, {"p": params}, keep=2)
        assert latest_step(d) == 5
        kept = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert len(kept) == 2
        tree, step = restore(d, {"p": params})
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves({"p": params})):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_no_partial(setup):
    params, _ = setup
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, {"p": params})
        # a stale .tmp dir from a crashed writer must not break anything
        os.makedirs(os.path.join(d, "step_000000002.tmp"))
        assert latest_step(d) == 1
        tree, step = restore(d, {"p": params})
        assert step == 1


def test_runner_restart_resumes(setup):
    params, data = setup
    opt = adamw()
    stepf = jax.jit(
        make_train_step(CFG, opt, warmup_cosine(peak_lr=1e-3, warmup=5, total=50))
    )

    def data_fn(i):
        return {"tokens": jnp.asarray(data(i)["tokens"])}

    with tempfile.TemporaryDirectory() as d:
        r1 = TrainRunner(
            RunnerConfig(total_steps=10, checkpoint_dir=d, checkpoint_every=5,
                         log_every=1000),
            stepf, data_fn, params, opt.init(params), log=lambda s: None,
        )
        out = r1.run()
        assert out["final_step"] == 10
        r2 = TrainRunner(
            RunnerConfig(total_steps=12, checkpoint_dir=d, checkpoint_every=5,
                         log_every=1000),
            stepf, data_fn, params, opt.init(params), log=lambda s: None,
        )
        assert r2.try_restore() and r2.step == 10
        out2 = r2.run()
        assert out2["final_step"] == 12


def test_data_determinism_and_host_sharding():
    d1 = SyntheticLM(vocab_size=64, batch=8, seq_len=16, seed=3)
    a = d1(7)["tokens"]
    b = d1(7)["tokens"]
    assert np.array_equal(a, b)
    assert not np.array_equal(a, d1(8)["tokens"])
    # host shards draw different streams
    h0 = SyntheticLM(vocab_size=64, batch=8, seq_len=16, host_index=0, host_count=2)
    h1 = SyntheticLM(vocab_size=64, batch=8, seq_len=16, host_index=1, host_count=2)
    assert h0(0)["tokens"].shape == (4, 17)
    assert not np.array_equal(h0(0)["tokens"], h1(0)["tokens"])
    t = TextLM(batch=4, seq_len=32)
    toks = t(0)["tokens"]
    assert toks.shape == (4, 33) and toks.max() < 256
