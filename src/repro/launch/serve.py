"""Solver-service driver: ``python -m repro.launch.serve --config <id>``.

Feeds the :class:`repro.serving.SolverEngine` two rounds of multi-RHS
solve requests from a ``PoissonConfig`` spec: the first round pays the
one-time setup (cache miss), the second reuses it (cache hit, zero
preconditioner setup) — the amortization profile the batched-solve
benchmark measures.  Prints per-column iterations/status and the cache
counters; exits nonzero if any column fails to converge or the second
round misses the cache.

The seed's LM decode driver lives on as ``examples/serve_lm.py``
(``repro.serving.lm``).
"""
import argparse
import sys

import jax.numpy as jnp
import numpy as np

from repro.configs.hipbone import CONFIGS, REDUCED
from repro.core import build_problem
from repro.serving import SolveRequest, SolverEngine, SolverServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--config", default="hipbone_reduced",
        choices=sorted(CONFIGS) + ["hipbone_reduced"],
    )
    ap.add_argument("--requests", type=int, default=None,
                    help="RHS columns per round (default: config batch_rhs)")
    ap.add_argument("--max-batch", type=int, default=16,
                    help="engine slot width per dispatch")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = REDUCED if args.config == "hipbone_reduced" else CONFIGS[args.config]
    n_req = args.requests or max(cfg.batch_rhs, 1)
    prob = build_problem(
        cfg.n_degree, cfg.local_elems, lam=cfg.lam,
        dtype=jnp.dtype(cfg.dtype), **cfg.problem_kwargs()
    )
    engine = SolverEngine(SolverServeConfig(max_batch=args.max_batch))
    rng = np.random.default_rng(args.seed)

    print(
        f"solver service: {cfg.name} N={cfg.n_degree} "
        f"dofs={prob.n_global} precond={cfg.precond} "
        f"requests={n_req}/round × {args.rounds} rounds"
    )
    failures = 0
    for rnd in range(args.rounds):
        reqs = [
            SolveRequest(
                prob=prob,
                b=jnp.asarray(
                    rng.standard_normal(prob.n_global), prob.dtype
                ),
                kind=cfg.precond,
                precond=cfg.precond_kwargs(),
                tol=cfg.tol if cfg.tol is not None else 1e-6,
                n_iter=cfg.n_iter if cfg.tol is None else 500,
                cg_variant=cfg.cg_variant,
            )
            for _ in range(n_req)
        ]
        responses = engine.solve(reqs)
        iters = [r.iterations for r in responses]
        setup = responses[0].setup_cache
        print(
            f"round {rnd}: setup={setup} "
            f"iterations={iters} "
            f"status={[r.status_name for r in responses]}"
        )
        failures += sum(not r.converged for r in responses)
        if rnd > 0 and setup != "hit":
            print("ERROR: repeated round missed the setup cache")
            failures += 1
    print("cache:", engine.cache.stats())
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
