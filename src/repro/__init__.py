"""repro — hipBone (NekBone-on-GPU) rebuilt TPU-native in JAX, plus the
multi-pod LM framework that shares its communication machinery."""
__version__ = "1.0.0"
