"""Benchmark driver — one section per paper table/figure.

``python -m benchmarks.run [--full]`` prints CSV rows per benchmark:
  fig3     — operator GFLOPS vs N + roofline      (paper Fig. 3)
  table1   — kernel occupancy/VMEM analogue       (paper Table 1)
  fig456   — multi-rank scaling + throughput      (paper Figs. 4-6)
  table2   — peak FOM / weak scaling / NekBone-vs-hipBone (paper Table 2)
  exchange — routing-algorithm selection          (paper §MPI Communication)
  precond  — PCG iterations-to-tolerance + FOM    (beyond the benchmark)

``--json PATH`` additionally writes a machine-readable summary: every
section's raw CSV rows plus the precond sweep as structured records
(per-config iterations-to-tol, solve time, effective FOM, per-application
preconditioner wall time ``precond_apply_s`` — the bandwidth axis a mixed
fp32-preconditioner row wins on even when iteration counts tie, and the
``dtype`` column separating fp64 from mixed rows) so the perf trajectory
is tracked across PRs — CI passes ``--json BENCH_pr5.json`` (bump the
name per PR) and gates on ``scripts/compare_bench.py``, which fails if
any (N, λ, kind, dtype) case needs more iterations than the previous
PR's json recorded.  The full json schema and gate rules are documented
in docs/BENCHMARKS.md.
"""
import argparse
import json
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="larger problem sizes")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--json",
        default="",
        help="write a machine-readable summary to this path (off by default)",
    )
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import (
        exchange_select,
        fig3_operator,
        fig456_scaling,
        precond_solve,
        table1_blocks,
        table2_fom,
    )

    sections = {
        "fig3": fig3_operator.main,
        "table1": table1_blocks.main,
        "fig456": fig456_scaling.main,
        "table2": table2_fom.main,
        "exchange": exchange_select.main,
        "precond": None,  # handled below so the sweep runs once
    }
    summary: dict = {"quick": quick, "sections": {}, "failures": []}
    failures = 0
    for name, fn in sections.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"# --- {name} ---", flush=True)
        try:
            if name == "precond":
                recs = precond_solve.records(quick=quick)
                rows = precond_solve.rows_from(recs)
                summary["precond_records"] = recs
            else:
                rows = list(fn(quick=quick))
            for row in rows:
                print(row, flush=True)
            summary["sections"][name] = rows
        except Exception as e:  # report and continue
            failures += 1
            msg = f"{name},ERROR,{type(e).__name__}: {e}"
            summary["failures"].append(msg)
            print(msg, flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(summary, f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
