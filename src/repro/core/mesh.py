"""Structured hexahedral SEM mesh with global (assembled) DOF numbering.

NekBone/hipBone use a regular box mesh of ``E = ex*ey*ez`` hexahedral
elements with a degree-N GLL node grid per element. Nodes on shared
faces/edges/corners are the same global degree of freedom; the local-to-
global map encodes the boolean scatter matrix Z (one nonzero per row).

This module is pure numpy setup code; runtime arrays are produced once
and handed to jax.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from . import sem

__all__ = [
    "BC_FACES",
    "BoxMesh",
    "build_box_mesh",
    "dirichlet_mask",
    "normalize_bc",
    "partition_elements",
]

# face order of a boundary-condition 6-tuple (matches the element-grid axes)
BC_FACES = ("x_lo", "x_hi", "y_lo", "y_hi", "z_lo", "z_hi")
_BC_TAGS = ("dirichlet", "neumann")


def normalize_bc(bc) -> tuple[str, ...] | None:
    """Canonicalize a boundary-condition spec to a 6-face tag tuple.

    Accepts ``None`` (legacy: no essential BCs — the operator is the pure
    screened-Poisson A = S + λ·screen on all DOFs), a shorthand string
    (``"dirichlet"`` / ``"neumann"`` on all six faces, or ``"mixed"`` =
    Dirichlet on the two x-faces, Neumann on y/z), or a 6-sequence of
    per-face tags in :data:`BC_FACES` order.  Neumann faces are *natural*
    in the weak form — they need no DOF treatment — so an all-Neumann spec
    produces no mask, only metadata.
    """
    if bc is None:
        return None
    if isinstance(bc, str):
        if bc == "dirichlet":
            return ("dirichlet",) * 6
        if bc == "neumann":
            return ("neumann",) * 6
        if bc == "mixed":
            return ("dirichlet", "dirichlet") + ("neumann",) * 4
        raise ValueError(
            f"unknown bc shorthand {bc!r}; use 'dirichlet'|'neumann'|'mixed' "
            "or a 6-tuple of per-face tags"
        )
    tags = tuple(bc)
    if len(tags) != 6:
        raise ValueError(
            f"bc must name all 6 faces {BC_FACES}, got {len(tags)} entries"
        )
    for face, tag in zip(BC_FACES, tags):
        if tag not in _BC_TAGS:
            raise ValueError(f"bc[{face}] = {tag!r}; choose from {_BC_TAGS}")
    return tags


def dirichlet_mask(mesh: "BoxMesh", bc) -> np.ndarray | None:
    """(N_G,) 0/1 mask: 0 on Dirichlet-face DOFs, 1 elsewhere.

    The mask is topological — it reads the structured global grid index
    (``gx = ex*N + 1`` points per axis, x fastest, exactly the layout
    :func:`build_box_mesh` assigns), so mesh deformation does not move it.
    Returns ``None`` when no face is Dirichlet (nothing to mask: Neumann
    faces are natural).  Operators apply it as A_m = mask∘A∘mask and
    preconditioners as mask∘M∘mask — both SPD on the interior subspace by
    congruence — with homogeneous values carried by lifting (see
    ``repro.testing.mms``).
    """
    tags = normalize_bc(bc)
    if tags is None or all(t == "neumann" for t in tags):
        return None
    n = mesh.n_degree
    ex, ey, ez = mesh.shape
    gx, gy, gz = ex * n + 1, ey * n + 1, ez * n + 1
    if gx * gy * gz != mesh.n_global:
        raise ValueError(
            "dirichlet_mask needs the structured box numbering: "
            f"{gx}*{gy}*{gz} != n_global={mesh.n_global}"
        )
    g = np.arange(mesh.n_global)
    ix = g % gx
    iy = (g // gx) % gy
    iz = g // (gx * gy)
    keep = np.ones(mesh.n_global, dtype=bool)
    for tag, sel in zip(
        tags,
        (ix == 0, ix == gx - 1, iy == 0, iy == gy - 1, iz == 0, iz == gz - 1),
    ):
        if tag == "dirichlet":
            keep &= ~sel
    return keep.astype(np.float64)


@dataclasses.dataclass(frozen=True)
class BoxMesh:
    """A structured SEM box mesh.

    Attributes:
      n_degree: polynomial degree N.
      shape: (ex, ey, ez) element counts per axis.
      l2g: int32 (E, (N+1)^3) local-node -> global-DOF map (the matrix Z).
      coords: float64 (E, (N+1)^3, 3) physical coordinates of local nodes.
      n_global: number of assembled DOFs N_G.
      n_local: number of element-local nodes N_L = E (N+1)^3.
    """

    n_degree: int
    shape: tuple[int, int, int]
    l2g: np.ndarray
    coords: np.ndarray
    n_global: int
    n_local: int

    @property
    def n_elements(self) -> int:
        return int(np.prod(self.shape))

    @property
    def points_per_element(self) -> int:
        return (self.n_degree + 1) ** 3


def build_box_mesh(
    n_degree: int,
    shape: tuple[int, int, int],
    *,
    extent: tuple[float, float, float] = (1.0, 1.0, 1.0),
    deform: float = 0.0,
    seed: int = 0,
) -> BoxMesh:
    """Build a structured box mesh on [0, Lx] x [0, Ly] x [0, Lz].

    Args:
      n_degree: SEM polynomial degree N.
      shape: element grid (ex, ey, ez).
      extent: box side lengths.
      deform: if nonzero, apply a smooth sinusoidal coordinate deformation of
        this relative amplitude. The deformation is a diffeomorphism of the
        box (conforming: shared nodes stay shared), producing dense metric
        tensors G^e with all six independent entries nonzero — used by tests
        to exercise the full operator. ``deform=0`` reproduces the regular
        NekBone mesh (diagonal G).
      seed: phase seed for the deformation.

    Returns:
      BoxMesh with local-to-global map and node coordinates.
    """
    ex, ey, ez = (int(s) for s in shape)
    n = int(n_degree)
    if min(ex, ey, ez) < 1:
        raise ValueError(f"element grid must be positive, got {shape}")
    gll, _ = sem.gll_nodes_weights(n)

    # Global point grid: (ex*N + 1, ey*N + 1, ez*N + 1), x fastest.
    gx, gy, gz = ex * n + 1, ey * n + 1, ez * n + 1
    n_global = gx * gy * gz

    # 1-D global node positions per axis (GLL points tiled across elements).
    def axis_nodes(ne: int, length: float) -> np.ndarray:
        h = length / ne
        pos = np.empty(ne * n + 1, dtype=np.float64)
        for e in range(ne):
            pos[e * n : (e + 1) * n + 1] = (e + (gll + 1.0) / 2.0) * h
        return pos

    px = axis_nodes(ex, extent[0])
    py = axis_nodes(ey, extent[1])
    pz = axis_nodes(ez, extent[2])

    # Local-to-global map. Local node (a, b, c) of element (i, j, k) sits at
    # global grid point (i*N + a, j*N + b, k*N + c). Local flat index is
    # a + (N+1)*(b + (N+1)*c)  (r fastest), element flat index i + ex*(j + ey*k).
    a = np.arange(n + 1)
    la, lb, lc = np.meshgrid(a, a, a, indexing="ij")  # (r, s, t)
    # local flat ordering: c slow, b mid, a fast
    loc_a = la.transpose(2, 1, 0).reshape(-1)
    loc_b = lb.transpose(2, 1, 0).reshape(-1)
    loc_c = lc.transpose(2, 1, 0).reshape(-1)

    ei, ej, ek = np.meshgrid(
        np.arange(ex), np.arange(ey), np.arange(ez), indexing="ij"
    )
    # element flat ordering: k slow, j mid, i fast
    ei = ei.transpose(2, 1, 0).reshape(-1)
    ej = ej.transpose(2, 1, 0).reshape(-1)
    ek = ek.transpose(2, 1, 0).reshape(-1)

    gxi = ei[:, None] * n + loc_a[None, :]
    gyj = ej[:, None] * n + loc_b[None, :]
    gzk = ek[:, None] * n + loc_c[None, :]
    l2g = (gxi + gx * (gyj + gy * gzk)).astype(np.int32)

    coords = np.stack(
        [px[gxi], py[gyj], pz[gzk]], axis=-1
    )  # (E, p, 3) float64

    if deform:
        rng = np.random.default_rng(seed)
        phase = rng.uniform(0, 2 * np.pi, size=(3,))
        lx, ly, lz = extent
        x, y, z = coords[..., 0], coords[..., 1], coords[..., 2]
        amp = deform * min(extent) / (2 * np.pi)
        sx = np.sin(2 * np.pi * x / lx + phase[0])
        sy = np.sin(2 * np.pi * y / ly + phase[1])
        sz = np.sin(2 * np.pi * z / lz + phase[2])
        coords = coords + amp * np.stack(
            [sy * sz, sx * sz, sx * sy], axis=-1
        )

    e_total = ex * ey * ez
    return BoxMesh(
        n_degree=n,
        shape=(ex, ey, ez),
        l2g=l2g,
        coords=coords,
        n_global=n_global,
        n_local=e_total * (n + 1) ** 3,
    )


def partition_elements(
    shape: tuple[int, int, int], grid: tuple[int, int, int]
) -> np.ndarray:
    """Owner rank for each element of a box mesh under a block partition.

    The element grid ``shape`` is split into ``grid = (px, py, pz)`` near-equal
    boxes; rank ordering matches element ordering (x fastest). Returns an
    int32 array of shape (E,) with the owning rank of each element.
    """
    ex, ey, ez = shape
    px, py, pz = grid
    if ex % px or ey % py or ez % pz:
        raise ValueError(f"element grid {shape} not divisible by process grid {grid}")

    ei, ej, ek = np.meshgrid(
        np.arange(ex), np.arange(ey), np.arange(ez), indexing="ij"
    )
    ei = ei.transpose(2, 1, 0).reshape(-1)
    ej = ej.transpose(2, 1, 0).reshape(-1)
    ek = ek.transpose(2, 1, 0).reshape(-1)
    ri = ei // (ex // px)
    rj = ej // (ey // py)
    rk = ek // (ez // pz)
    return (ri + px * (rj + py * rk)).astype(np.int32)
