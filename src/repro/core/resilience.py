"""Host-side solver guardrails: retry policy and the fallback chain.

`core.cg` detects *that* a solve failed (``CGResult.status``); this module
decides *what to do next*.  On a non-CONVERGED status the chain escalates
through progressively more conservative configurations, re-solving after
each step, until the solve converges or the chain is exhausted:

1. ``retry`` — re-run the same configuration once: a transient fault
   (SDC bit-flip, one corrupted payload) does not recur, and no amount of
   configuration degradation would have been the right response to it;
2. ``flexible_cg`` — switch the β recurrence to Polak–Ribière (tolerates
   an inexactly-symmetric M⁻¹, the usual first casualty of a degraded
   preconditioner chain);
3. ``full_precision_precond`` — drop ``precond_dtype`` back to the solve
   dtype (an fp32 chain that stalls below tol is healed by this rung);
4. ``downgrade_precond`` — step down the preconditioner ladder one rung at
   a time (`PRECOND_DOWNGRADE`: pmg → chebyshev → jacobi → none; schwarz
   also falls back to chebyshev), ending at plain CG with no M⁻¹ at all.

Every attempt is recorded machine-readably (`SolveAttempt` /
`FallbackResult.record`) so a serving layer can log exactly what was tried
and why.  Attempts restart from the caller's x₀ — a failed attempt's
iterate may be NaN or garbage, so nothing is warm-started from it.

`run_fallback_chain` is the generic engine (bring your own solve
callable — the sharded paths use it with `distributed.dist_cg`);
`solve_with_fallback` is the single-device assembled-path convenience that
rebuilds the preconditioner via `core.precond.make_preconditioner` at each
rung.  The graceful-degradation guard for the *fused operator* lives at
the kernel policy point instead (``kernels.ops.should_fuse_operator``
probes the Pallas lowering once and falls back to the split pipeline on
failure) — by the time a solve runs, the operator choice is already safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from .cg import CGResult, SolveStatus, cg_assembled, status_name
from .operator import PoissonProblem, poisson_assembled
from .precond import make_preconditioner

__all__ = [
    "PRECOND_DOWNGRADE",
    "FallbackResult",
    "SolveAttempt",
    "run_fallback_chain",
    "solve_with_fallback",
]

# one rung down the ladder for each preconditioner kind; "none" is the
# chain's floor (plain CG) and has no entry
PRECOND_DOWNGRADE = {
    "pmg": "chebyshev",
    "schwarz": "chebyshev",
    "chebyshev": "jacobi",
    "jacobi": "none",
}


@dataclasses.dataclass(frozen=True)
class SolveAttempt:
    """One rung of the fallback chain, machine-readable."""

    attempt: int
    action: str  # "initial" | "retry" | "flexible_cg"
    #            | "full_precision_precond" | "downgrade_precond:<from>-><to>"
    precond: str
    precond_dtype: str | None
    cg_variant: str
    status: str  # SolveStatus wire name, e.g. "converged"
    iterations: int
    rdotr: float

    def record(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class FallbackResult:
    """Outcome of a fallback chain: final result + the full attempt log."""

    result: Any  # the last attempt's CGResult (or dist result object)
    status: SolveStatus
    recovered: bool  # True iff the final attempt CONVERGED
    attempts: tuple[SolveAttempt, ...]

    def record(self) -> list[dict]:
        """Machine-readable log of every attempt, in order."""
        return [a.record() for a in self.attempts]


def _dtype_name(precond_dtype) -> str | None:
    return None if precond_dtype is None else np.dtype(precond_dtype).name


def _escalate(precond: str, precond_dtype, cg_variant: str):
    """Next rung as (action, precond, precond_dtype, cg_variant), or None.

    Without a preconditioner the flexible β reduces to the standard one
    (core.cg folds it), so the flexible_cg rung only applies while an M⁻¹
    is in play.
    """
    if cg_variant == "standard" and precond != "none":
        return ("flexible_cg", precond, precond_dtype, "flexible")
    if precond_dtype is not None:
        return ("full_precision_precond", precond, None, cg_variant)
    if precond in PRECOND_DOWNGRADE:
        nxt = PRECOND_DOWNGRADE[precond]
        return (f"downgrade_precond:{precond}->{nxt}", nxt, None, cg_variant)
    return None


def run_fallback_chain(
    attempt_fn: Callable[..., Any],
    *,
    precond: str = "none",
    precond_dtype=None,
    cg_variant: str = "standard",
    max_attempts: int = 7,
) -> FallbackResult:
    """Drive the escalation policy over an arbitrary solve callable.

    ``attempt_fn(precond=, precond_dtype=, cg_variant=, attempt=)`` runs
    one solve in the given configuration and returns any object exposing
    ``status`` / ``iterations`` / ``rdotr`` (a `CGResult`, or the scalars
    of a ``dist_cg`` run repackaged).  The first escalation is always one
    plain ``retry`` of the initial configuration (transient-fault
    recovery); after that the chain degrades the configuration
    (`_escalate`).  It stops at the first CONVERGED attempt, when no
    escalation remains, or after ``max_attempts`` attempts (the initial
    solve counts as attempt 0) — the default of 7 covers the longest
    possible chain (standard + fp32 + pmg: initial, retry, flexible,
    fp64, chebyshev, jacobi, plain CG).
    """
    if max_attempts < 1:
        raise ValueError(f"max_attempts must be >= 1, got {max_attempts}")
    attempts: list[SolveAttempt] = []
    action = "initial"
    retried = False
    res = None
    status = None
    for i in range(max_attempts):
        res = attempt_fn(
            precond=precond,
            precond_dtype=precond_dtype,
            cg_variant=cg_variant,
            attempt=i,
        )
        status = SolveStatus(int(res.status))
        attempts.append(
            SolveAttempt(
                attempt=i,
                action=action,
                precond=precond,
                precond_dtype=_dtype_name(precond_dtype),
                cg_variant=cg_variant,
                status=status_name(status),
                iterations=int(res.iterations),
                rdotr=float(res.rdotr),
            )
        )
        if status == SolveStatus.CONVERGED:
            return FallbackResult(res, status, True, tuple(attempts))
        if not retried:
            action, retried = "retry", True
            continue
        nxt = _escalate(precond, precond_dtype, cg_variant)
        if nxt is None:
            break
        action, precond, precond_dtype, cg_variant = nxt
    return FallbackResult(res, status, False, tuple(attempts))


def solve_with_fallback(
    prob: PoissonProblem,
    b_g: jax.Array,
    *,
    operator: Callable[[jax.Array], jax.Array] | None = None,
    precond: str = "none",
    precond_dtype=None,
    cg_variant: str = "standard",
    tol: float = 1e-8,
    n_iter: int = 500,
    x0: jax.Array | None = None,
    max_attempts: int = 7,
    precond_kwargs: dict | None = None,
    instrument: Callable | None = None,
    **cg_kwargs,
) -> FallbackResult:
    """Assembled-path PCG with the full fallback chain behind it.

    Each attempt rebuilds the preconditioner for its rung via
    `make_preconditioner` (``precond_kwargs`` passes rung knobs such as
    ``degree`` / ``pmg_smoother`` through) and re-runs `cg_assembled` from
    the caller's ``x0``.  ``tol`` is required — a CONVERGED certificate is
    what the chain escalates toward — so ``tol=None`` (fixed-count mode)
    raises.  ``cg_kwargs`` forwards detector knobs
    (``divergence_factor`` / ``stagnation_window`` / ``stagnation_rtol``
    / ``record_history``).

    ``instrument``: optional seam called as
    ``instrument(attempt, operator, precond_apply) -> (operator,
    precond_apply)`` after the rung's preconditioner is built and before
    the solve — the fault-injection harness (`repro.testing.faults`) uses
    it to corrupt specific attempts; production callers leave it None.
    """
    if tol is None:
        raise ValueError(
            "solve_with_fallback needs tol mode: the chain escalates until "
            "a CONVERGED certificate, which fixed-count mode cannot issue"
        )
    base_op = operator if operator is not None else poisson_assembled(prob)
    pkw = dict(precond_kwargs or {})

    def attempt_fn(*, precond, precond_dtype, cg_variant, attempt) -> CGResult:
        op = base_op
        pc = None
        if precond != "none":
            pc, _info = make_preconditioner(
                precond, prob, op, precond_dtype=precond_dtype, **pkw
            )
        if instrument is not None:
            op, pc = instrument(attempt, op, pc)
        return cg_assembled(
            op,
            b_g,
            x0,
            n_iter=n_iter,
            tol=tol,
            precond=pc,
            cg_variant=cg_variant,
            **cg_kwargs,
        )

    return run_fallback_chain(
        attempt_fn,
        precond=precond,
        precond_dtype=precond_dtype,
        cg_variant=cg_variant,
        max_attempts=max_attempts,
    )
