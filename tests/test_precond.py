"""Preconditioned CG: diagonal assembly, convergence, kernels, sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import build_problem, cg_assembled, poisson_assembled
from repro.core.precond import (
    assembled_diagonal,
    chebyshev_apply,
    make_preconditioner,
    power_lambda_max,
    deterministic_seed_vector,
)
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(3, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


def test_assembled_diagonal_matches_dense(prob64):
    """Matrix-free diag(Z^T (S_L + λW) Z) == diagonal of the dense assembly."""
    a = poisson_assembled(prob64)
    ng = prob64.n_global
    amat = np.array(jax.vmap(a, in_axes=1, out_axes=1)(jnp.eye(ng)))
    got = np.array(assembled_diagonal(prob64))
    np.testing.assert_allclose(got, np.diag(amat), rtol=1e-12)


def test_power_iteration_brackets_spectrum(prob64):
    a = poisson_assembled(prob64)
    dinv = 1.0 / assembled_diagonal(prob64)
    ng = prob64.n_global
    amat = np.array(jax.vmap(a, in_axes=1, out_axes=1)(jnp.eye(ng)))
    true_lmax = np.abs(np.linalg.eigvals(np.diag(np.array(dinv)) @ amat)).max()
    est = float(power_lambda_max(
        a, dinv, deterministic_seed_vector(ng, jnp.float64), iters=25
    ))
    assert 0.9 * true_lmax <= est <= 1.05 * true_lmax


def test_pcg_matches_plain_cg_solution(prob64):
    """Jacobi and Chebyshev PCG converge to the same solution as plain CG."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    x_plain = cg_assembled(a, b, n_iter=300, tol=1e-12).x
    for kind in ("jacobi", "chebyshev"):
        pc, _ = make_preconditioner(kind, prob64, a, degree=2)
        x_pc = cg_assembled(a, b, n_iter=300, tol=1e-12, precond=pc).x
        np.testing.assert_allclose(np.array(x_pc), np.array(x_plain), atol=1e-8)


def test_pcg_fewer_iterations_to_tol(prob64):
    """ISSUE acceptance: chebyshev reaches tol=1e-6 in strictly fewer
    iterations than plain CG on a deformed-mesh problem."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))

    iters = {}
    for kind in ("none", "jacobi", "chebyshev"):
        pc, _ = make_preconditioner(kind, prob64, a, degree=2)
        res = cg_assembled(a, b, n_iter=500, tol=1e-6, precond=pc)
        # converged, not capped
        assert int(res.iterations) < 500
        rel = np.linalg.norm(np.array(a(res.x) - b)) / np.linalg.norm(np.array(b))
        assert rel < 1e-5
        iters[kind] = int(res.iterations)

    assert iters["chebyshev"] < iters["none"], iters
    assert iters["jacobi"] <= iters["none"], iters


def test_identity_precond_is_plain_cg(prob64):
    """precond=None and an explicit identity M must walk the same iterates."""
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    r1 = cg_assembled(a, b, n_iter=40, record_history=True)
    r2 = cg_assembled(a, b, n_iter=40, precond=lambda r: r, record_history=True)
    np.testing.assert_allclose(
        np.array(r1.rdotr_history), np.array(r2.rdotr_history), rtol=1e-10
    )


def test_fixed_iter_and_tol_modes_agree(prob64):
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(3)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    pc, _ = make_preconditioner("jacobi", prob64, a)
    res_tol = cg_assembled(a, b, n_iter=300, tol=1e-10, precond=pc,
                           record_history=True)
    k = int(res_tol.iterations)
    res_fix = cg_assembled(a, b, n_iter=k, precond=pc, record_history=True)
    np.testing.assert_allclose(
        np.array(res_tol.rdotr_history)[:k],
        np.array(res_fix.rdotr_history), rtol=1e-8)
    np.testing.assert_allclose(
        np.array(res_tol.x), np.array(res_fix.x), atol=1e-9)


def test_chebyshev_apply_is_linear(prob64):
    """q_k(D⁻¹A)D⁻¹ must be linear for PCG validity."""
    a = poisson_assembled(prob64)
    dinv = 1.0 / assembled_diagonal(prob64)
    pc = chebyshev_apply(a, dinv, 2.0, degree=3)
    rng = np.random.default_rng(4)
    u = jnp.asarray(rng.standard_normal(prob64.n_global))
    v = jnp.asarray(rng.standard_normal(prob64.n_global))
    lhs = np.array(pc(2.5 * u - 0.5 * v))
    rhs = 2.5 * np.array(pc(u)) - 0.5 * np.array(pc(v))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10, atol=1e-12)


@pytest.mark.parametrize("n", [128, 1000, 40000])
def test_fused_precond_kernels_match_refs(n, rng):
    dinv = jnp.abs(jnp.asarray(rng.standard_normal(n), jnp.float32)) + 0.1
    r = jnp.asarray(rng.standard_normal(n), jnp.float32)
    z, rz = ops.fused_jacobi_dot(dinv, r, interpret=True)
    z2, rz2 = ref.fused_jacobi_dot_ref(dinv, r)
    np.testing.assert_allclose(np.array(z), np.array(z2), atol=1e-6)
    assert abs(float(rz - rz2)) / abs(float(rz2)) < 1e-5

    d = jnp.asarray(rng.standard_normal(n), jnp.float32)
    a, c = jnp.float32(0.31), jnp.float32(-1.7)
    out = ops.fused_cheb_d_update(a, c, d, r, interpret=True)
    np.testing.assert_allclose(
        np.array(out), np.array(ref.fused_cheb_d_update_ref(a, c, d, r)),
        atol=1e-6,
    )


def test_pcg_with_fused_pallas_stages(rng):
    """PCG with Pallas fused jacobi-dot + cheb-d-update == jnp PCG."""
    prob = build_problem(3, (2, 2, 2), lam=1.0, deform=0.15, dtype=jnp.float32)
    a = poisson_assembled(prob)
    b = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)

    dinv = 1.0 / assembled_diagonal(prob)
    ref_res = cg_assembled(a, b, n_iter=30, precond=lambda r: dinv * r,
                           record_history=True)
    got_res = cg_assembled(
        a, b, n_iter=30,
        precond=lambda r: dinv * r,
        fused_precond_dot=ops.make_fused_jacobi_dot(dinv, interpret=True),
        record_history=True,
    )
    np.testing.assert_allclose(
        np.array(got_res.x), np.array(ref_res.x), rtol=2e-4, atol=2e-5
    )

    # ratio mode so the reference interval matches _lmax_of's power-iteration
    # bound (the lanczos default estimates both interval ends instead)
    pc_ref, _ = make_preconditioner(
        "chebyshev", prob, a, degree=3, lmin_source="ratio"
    )
    pc_pl = chebyshev_apply(
        a, dinv, _lmax_of(prob, a), degree=3,
        fused_d_update=ops.make_fused_cheb_d_update(interpret=True),
    )
    r = jnp.asarray(rng.standard_normal(prob.n_global), jnp.float32)
    np.testing.assert_allclose(
        np.array(pc_pl(r)), np.array(pc_ref(r)), rtol=2e-4, atol=2e-5
    )


def _lmax_of(prob, a):
    from repro.core.precond import CHEB_SAFETY

    dinv = 1.0 / assembled_diagonal(prob)
    v0 = deterministic_seed_vector(prob.n_global, jnp.float32)
    return CHEB_SAFETY * power_lambda_max(a, dinv, v0, iters=15)


def test_scattered_pcg_converges(prob64):
    from repro.core import cg_scattered, poisson_scattered
    from repro.core.gather_scatter import gather, scatter

    rng = np.random.default_rng(5)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    a = poisson_assembled(prob64)
    want = cg_assembled(a, b, n_iter=400, tol=1e-10).x

    # Jacobi on scattered vectors: scatter the assembled dinv
    dinv_l = scatter(1.0 / assembled_diagonal(prob64), prob64.l2g)
    bl = scatter(b, prob64.l2g)
    res = cg_scattered(
        poisson_scattered(prob64), bl, prob64.w_local,
        n_iter=400, tol=1e-10, precond=lambda r: dinv_l * r,
    )
    xg = gather(prob64.w_local * res.x, prob64.l2g, prob64.n_global)
    np.testing.assert_allclose(np.array(xg), np.array(want), atol=1e-7)


def test_distributed_pcg_matches_single_device():
    """ISSUE acceptance: distributed PCG == single-device PCG on a virtual
    8-device mesh, for both jacobi and chebyshev."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
for kind in ("jacobi", "chebyshev"):
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond=kind, cheb_degree=2))
    x_boxes, rdotr, iters, status, hist = run()
    pc, _ = make_preconditioner(kind, ref, A, degree=2)
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc)
    err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
    assert err < 1e-8, (kind, err)
    # distributed solve must actually converge before the cap
    assert int(iters) < 200, (kind, int(iters))
print("OK")
"""
    )


def test_lanczos_brackets_spectrum(prob64):
    """Lanczos Ritz values bracket the true spectrum of D⁻¹A from inside
    (ISSUE satellite: λ_min estimation replaces the fixed λ_max/30 bound)."""
    from repro.core.precond import lanczos_extremes

    a = poisson_assembled(prob64)
    dinv = 1.0 / assembled_diagonal(prob64)
    ng = prob64.n_global
    amat = np.array(jax.vmap(a, in_axes=1, out_axes=1)(jnp.eye(ng)))
    ev = np.linalg.eigvals(np.diag(np.array(dinv)) @ amat).real
    v0 = deterministic_seed_vector(ng, jnp.float64)
    lmin, lmax = lanczos_extremes(a, dinv, v0, iters=12)
    assert 0.9 * ev.max() <= float(lmax) <= 1.02 * ev.max()
    assert 0.98 * ev.min() <= float(lmin) <= 1.6 * ev.min()
    # the tightened interval must sit well above the legacy lmax/30 bound
    # on this well-conditioned problem
    assert float(lmin) > float(lmax) / 30.0


def test_distributed_scattered_pcg_parity():
    """ISSUE satellite: dist_cg_scattered gains precond=/tol= matching
    dist_cg — same solution, preconditioning cuts iterations."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg, dist_cg_scattered
from repro.comms.topology import ProcessGrid

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((8, prob.m3)))
xa, rdotr, it_a, status_a, hist = jax.jit(dist_cg(
    prob, mesh, b, n_iter=300, tol=1e-10, precond="chebyshev"))()
l2g = jnp.asarray(prob.l2g.reshape(-1))
# consistent scattered rhs from the (consistent) assembled solve's b
from repro.comms.halo import copy_exchange
from repro.compat import shard_map
from jax.sharding import PartitionSpec as P
mk = shard_map(
    lambda bb: copy_exchange(
        bb[0].reshape(prob.box_shape[::-1]), prob.grid, "ranks"
    ).reshape(1, -1),
    mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"))
b_cons = mk(b)
bL = jnp.take(b_cons, l2g, axis=1).reshape(8, prob.e_local, -1)
its = {}
for kind in ("none", "jacobi", "chebyshev"):
    xl, rd, its_k, st_k = jax.jit(dist_cg_scattered(
        prob, mesh, bL, n_iter=300, tol=1e-10, precond=kind))()
    its[kind] = int(its_k)
    assert int(st_k) == 0, (kind, int(st_k))  # SolveStatus.CONVERGED
    assert int(its_k) < 300, (kind, int(its_k))
    xl_ref = jnp.take(xa, l2g, axis=1).reshape(xl.shape)
    err = np.abs(np.array(xl) - np.array(xl_ref)).max()
    assert err < 1e-7, (kind, err)
assert its["chebyshev"] < its["none"], its
print("OK", its)
"""
    )


def test_distributed_chebyshev_beats_plain_on_deformed():
    """Sharded PCG on a deformed global mesh: fewer iterations to tol."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_box_mesh, geometric_factors
from repro.core.mesh import partition_elements

N = 3
grid = ProcessGrid((2, 2, 2)); local = (1, 1, 1)
mesh_g = build_box_mesh(N, (2, 2, 2), deform=0.2)
geo = geometric_factors(mesh_g)["G"]
owner = partition_elements((2, 2, 2), grid.shape)
# group per-rank element factors in the halo-first local order (1 elem/rank)
gf = np.stack([geo[owner == r] for r in range(8)])
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64,
                          g_factors=gf)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((8, prob.m3)))
it = {}
for kind in ("none", "chebyshev"):
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=300, tol=1e-6, precond=kind))
    x, rdotr, iters, status, hist = run()
    it[kind] = int(iters)
    assert int(iters) < 300, (kind, int(iters))
assert it["chebyshev"] < it["none"], it

# setup-time spectrum estimate == in-graph estimate (same iterate count)
from repro.core.distributed import dist_lambda_max, dist_spectrum
lmin, lmax = dist_spectrum(prob, mesh)
run = jax.jit(dist_cg(prob, mesh, b, n_iter=300, tol=1e-6,
                      precond="chebyshev", lmin=lmin, lmax=lmax))
x2, rdotr2, iters2, status2, hist2 = run()
assert int(iters2) == it["chebyshev"], (int(iters2), it)
# legacy power-iteration helper still brackets the Lanczos top estimate
lam_pow = dist_lambda_max(prob, mesh)
assert 0.8 * lmax <= lam_pow <= 1.1 * lmax, (lam_pow, lmax)
print("OK", it)
"""
    )
