"""MoE correctness: dense reference, capacity semantics, EP dispatch parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_apply, router_topk

CFG = ModelConfig(
    name="m", family="moe", n_layers=1, d_model=16, n_heads=1, n_kv_heads=1,
    head_dim=16, d_ff=32, vocab_size=8, n_experts=8, experts_per_token=2,
    capacity_factor=16.0,  # dropless for reference comparison
)


def _dense_reference(p, x, cfg):
    """Per-token explicit expert sum — the oracle."""
    logits = np.array(x) @ np.array(p["w_router"])
    w, idx, _ = router_topk(jnp.asarray(logits), cfg)
    w, idx = np.array(w), np.array(idx)
    y = np.zeros_like(np.array(x))
    for t in range(x.shape[0]):
        for j in range(cfg.experts_per_token):
            e = idx[t, j]
            h = np.array(x[t]) @ np.array(p["w_gate"][e])
            h = h / (1 + np.exp(-h)) * (np.array(x[t]) @ np.array(p["w_up"][e]))
            y[t] += w[t, j] * (h @ np.array(p["w_down"][e]))
    return y


def test_moe_matches_dense_reference():
    p, _ = init_moe(jax.random.key(0), CFG, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (24, 16), jnp.float32)
    y, aux = moe_apply(p, x, CFG)
    want = _dense_reference(p, x, CFG)
    np.testing.assert_allclose(np.array(y), want, rtol=2e-4, atol=2e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(CFG, capacity_factor=0.25)
    p, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (64, 16), jnp.float32)
    y_small, _ = moe_apply(p, x, cfg)
    y_big, _ = moe_apply(p, x, CFG)
    # low capacity must drop some contributions
    assert not np.allclose(np.array(y_small), np.array(y_big))


def test_shared_experts_added():
    cfg = dataclasses.replace(CFG, n_shared_experts=1, router_score="sigmoid")
    p, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (8, 16), jnp.float32)
    y, _ = moe_apply(p, x, cfg)
    # zero the shared expert -> output changes
    p2 = dict(p, ws_down=jnp.zeros_like(p["ws_down"]))
    y2, _ = moe_apply(p2, x, cfg)
    assert not np.allclose(np.array(y), np.array(y2))


@pytest.mark.slow
@pytest.mark.parametrize("exchange", ["all_to_all", "pairwise", "crystal_router"])
def test_moe_ep_dispatch_matches_single_device(exchange):
    """EP over 8 shards through each exchange algorithm == 1-device result."""
    run_subprocess(
        f"""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from functools import partial
import dataclasses
from repro.models.config import ModelConfig
from repro.models.moe import init_moe, moe_apply
from repro.compat import make_mesh, shard_map

cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, n_heads=1,
                  n_kv_heads=1, head_dim=16, d_ff=32, vocab_size=8, n_experts=8,
                  experts_per_token=2, capacity_factor=16.0)
p, _ = init_moe(jax.random.key(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.key(1), (64, 16), jnp.float32)
y_ref, _ = moe_apply(p, x, cfg)

mesh = make_mesh((8,), ("model",))
def inner(xs, wr, wg, wu, wd):
    from repro.compat import axis_size
    tpn = axis_size("model"); me = jax.lax.axis_index("model")
    tloc = xs.shape[0] // tpn
    mine = jax.lax.dynamic_slice_in_dim(xs, me * tloc, tloc, axis=0)
    pp = {{"w_router": wr, "w_gate": wg, "w_up": wu, "w_down": wd}}
    y, aux = moe_apply(pp, mine, cfg, ep_axis="model", exchange="{exchange}")
    return jax.lax.all_gather(y, "model", axis=0, tiled=True), jax.lax.pmean(aux, "model")
f = jax.jit(shard_map(inner, mesh=mesh,
    in_specs=(P(), P(None, None), P("model"), P("model"), P("model")),
    out_specs=(P(), P()), check_rep=False))  # all_gather output is replicated
y_ep, aux = f(x, p["w_router"], p["w_gate"], p["w_up"], p["w_down"])
err = np.abs(np.array(y_ep) - np.array(y_ref)).max()
rel = err / (np.abs(np.array(y_ref)).max() + 1e-9)
assert rel < 2e-5, rel
print("OK", rel)
"""
    )
