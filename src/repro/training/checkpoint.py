"""Sharded, atomic, mesh-independent checkpointing (no external deps).

Layout::

    <dir>/step_000123/
        manifest.json        # tree structure, shapes, dtypes, step
        leaf_00000.npy       # one file per pytree leaf (host's shards)
        ...
    <dir>/LATEST             # text file naming the last complete step

Writes go to ``step_X.tmp`` and are renamed only after the manifest is
written — a crash mid-write never corrupts the latest checkpoint
(checkpoint-restart fault tolerance). Restore reshards onto *any* mesh via
``jax.make_array_from_callback``: checkpoints are mesh-independent, which
is what makes elastic restarts (different device count after a failure)
work. In a multi-host deployment each host writes only the shards it owns
(``addressable_shards``); this container is single-host so every leaf is
fully addressable.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "/"


def _flatten(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Write a checkpoint; returns the final path. Atomic via tmp+rename."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(flat):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(os.path.basename(final))

    # retention
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for old in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, old), ignore_errors=True)
    return final


def latest_step(directory: str) -> int | None:
    try:
        with open(os.path.join(directory, "LATEST")) as f:
            return int(f.read().strip().split("_")[1])
    except (FileNotFoundError, IndexError, ValueError):
        return None


def restore(directory: str, tree_like: Any, *, step: int | None = None, shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``; reshard onto ``shardings``.

    ``shardings`` (optional pytree of NamedSharding) places each leaf onto
    the *current* mesh — elastic restore path.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    flat = _flatten(tree_like)
    shard_flat = _flatten(shardings) if shardings is not None else None
    leaves = []
    for i, (key, like) in enumerate(flat):
        meta = by_key[key]
        data = np.load(os.path.join(path, meta["file"]), mmap_mode="r")
        if shard_flat is not None:
            sharding = shard_flat[i][1]
            arr = jax.make_array_from_callback(
                data.shape, sharding, lambda idx, d=data: np.asarray(d[idx])
            )
        else:
            arr = jnp.asarray(np.asarray(data))
        leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, leaves), step


class CheckpointManager:
    """Async checkpoint writer: device_get on caller thread, IO in background."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, step: int, tree: Any) -> None:
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(self.directory, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True,
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
