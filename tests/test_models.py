"""Per-architecture smoke tests (reduced configs) + decode/forward parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, REDUCED, SHAPES
from repro.models.model import decode_step, forward, init_caches, init_model, prefill
from repro.training import adamw, make_train_step, warmup_cosine


@pytest.mark.parametrize("arch", sorted(REDUCED))
def test_arch_smoke_forward_and_train(arch):
    """Reduced config: one forward + one train step on CPU; shapes + no NaNs."""
    cfg = REDUCED[arch]
    cfg.validate()
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    logits, aux, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN in forward"
    assert not bool(jnp.isnan(aux)), f"{arch}: NaN aux"

    opt = adamw()
    step = jax.jit(
        make_train_step(cfg, opt, warmup_cosine(peak_lr=1e-3, warmup=5, total=50))
    )
    batch = {"tokens": jax.random.randint(jax.random.key(2), (2, s + 1), 0, cfg.vocab_size)}
    p2, _, metrics = step(params, opt.init(params), batch, jnp.int32(3))
    assert np.isfinite(float(metrics["loss"])), f"{arch}: non-finite loss"
    # params actually changed
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b_))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert changed


@pytest.mark.parametrize(
    "arch", ["yi-9b", "mixtral-8x7b", "mamba2-780m", "jamba-v0.1-52b", "gemma3-1b"]
)
def test_arch_decode_matches_forward(arch):
    """Prefill + 1 decode step reproduces the forward logits at that position."""
    cfg = REDUCED[arch]
    # dropless capacity so MoE routing is identical between paths
    if cfg.n_experts:
        import dataclasses

        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.key(1), (b, s), 0, cfg.vocab_size)
    logits, _, _ = jax.jit(lambda p, t: forward(p, t, cfg))(params, toks)
    _, caches = jax.jit(lambda p, t: prefill(p, t, cfg))(params, toks[:, : s - 1])
    full = init_caches(cfg, b, s, jnp.float32)

    def place(pref, buf):
        if pref.shape == buf.shape:
            return pref
        sl = [slice(None)] * buf.ndim
        for i, (x, y) in enumerate(zip(pref.shape, buf.shape)):
            if x != y:
                sl[i] = slice(0, x)
                break
        return buf.at[tuple(sl)].set(pref)

    caches = jax.tree.map(place, caches, full)
    lg, _ = jax.jit(lambda p, tok, t, c: decode_step(p, tok, t, c, cfg))(
        params, toks[:, s - 1 : s], jnp.int32(s - 1), caches
    )
    scale = float(jnp.max(jnp.abs(logits[:, s - 1]))) + 1e-9
    err = float(jnp.max(jnp.abs(lg[:, 0] - logits[:, s - 1]))) / scale
    assert err < 3e-4, (arch, err)


def test_full_configs_validate_and_count():
    known = {
        "chameleon-34b": 34.3e9,
        "mixtral-8x7b": 46.7e9,
        "jamba-v0.1-52b": 51.5e9,
        "gemma3-1b": 1.0e9,
        "mamba2-780m": 0.78e9,
    }
    for arch, cfg in ARCHS.items():
        cfg.validate()
        n = cfg.n_params()
        assert n > 0
        if arch in known:
            assert abs(n - known[arch]) / known[arch] < 0.05, (arch, n)
        assert cfg.n_active_params() <= n


def test_shape_table_and_skip_list():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    subq = {a for a, c in ARCHS.items() if c.sub_quadratic}
    assert subq == {"mamba2-780m", "gemma3-1b", "mixtral-8x7b", "jamba-v0.1-52b"}


def test_layer_period_structure():
    jamba = ARCHS["jamba-v0.1-52b"]
    kinds = jamba.period_kinds()
    assert len(kinds) == 8
    assert [k.mixer for k in kinds].count("attn") == 1
    assert kinds[4].mixer == "attn"
    assert [k.ffn for k in kinds].count("moe") == 4

    g3 = ARCHS["gemma3-1b"]
    kinds = g3.period_kinds()
    assert [k.mixer for k in kinds] == ["attn_local"] * 5 + ["attn"]
    assert g3.n_periods == 4 and g3.n_remainder == 2


def test_mtp_head_present_and_used():
    cfg = REDUCED["deepseek-v3-671b"]
    params, _ = init_model(cfg, jax.random.key(0), jnp.float32)
    assert "mtp" in params
    from repro.training import make_loss_fn

    loss_fn = make_loss_fn(cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 17), 0, cfg.vocab_size)
    loss, metrics = jax.jit(loss_fn)(params, {"tokens": toks})
    assert "mtp_nll" in metrics and np.isfinite(float(metrics["mtp_nll"]))
