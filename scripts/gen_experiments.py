"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from results/cells."""
import glob
import json
import sys


def load(pattern="results/cells/*.json"):
    rows = []
    for f in sorted(glob.glob(pattern)):
        rows.append(json.load(open(f))[0])
    return rows


def fmt_table(rows):
    out = [
        "| arch | shape | mesh | dominant | compute_s | memory_s | collective_s "
        "| roofline_frac | useful_flops | mem/chip GB | compile_s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("status") == "skipped":
            continue
        ro = r["roofline"]
        m = r["memory"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {ro['dominant'].replace('_s','')} "
            f"| {ro['compute_s']:.3f} | {ro['memory_s']:.3f} | {ro['collective_s']:.3f} "
            f"| {ro.get('roofline_fraction',0):.4f} | {ro.get('useful_flop_fraction',0):.3f} "
            f"| {m['total_nonaliased_bytes']/2**30:.2f} | {r.get('compile_s','')} |"
        )
    return "\n".join(out)


def fmt_skips(rows):
    out = ["| arch | shape | mesh | reason |", "|---|---|---|---|"]
    for f in sorted(glob.glob("results/cells/*.json")):
        r = json.load(open(f))[0]
        if r.get("status") == "skipped":
            parts = f.split("/")[-1][:-5].rsplit("_", 2)
            out.append(f"| {parts[0]} | {parts[1]}_{parts[2].split('_')[0] if '_' in parts[2] else parts[2]} | | {r['reason']} |")
    # simpler: derive from filename
    out = ["| cell file | reason |", "|---|---|"]
    for f in sorted(glob.glob("results/cells/*.json")):
        r = json.load(open(f))[0]
        if r.get("status") == "skipped":
            out.append(f"| {f.split('/')[-1][:-5]} | {r['reason']} |")
    return "\n".join(out)


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1 else "results/cells/*.json")
    print(fmt_table([r for r in rows if r.get("status") == "ok"]))
    print()
    print(fmt_skips(rows))
