"""Mamba-2 (SSD — state-space duality) mixer, chunked and decode forms.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060): a
quadratic intra-chunk term plus an inter-chunk state recurrence carried by
``lax.scan`` — O(S·Q) work, O(S/Q) sequential steps. Decode carries the
(H, N, P) SSM state and a (width-1) conv tail; cost per token is O(1) in
context length, which is what makes the long_500k cells runnable for the
SSM/hybrid architectures (DESIGN.md skip list).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .common import rms_norm
from .config import ModelConfig
from .params import ParamBuilder

__all__ = [
    "init_mamba",
    "mamba_forward",
    "mamba_decode",
    "init_mamba_cache",
    "ssd_chunked",
    "ssd_sequential",
]


def init_mamba(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    d = cfg.d_model
    di = cfg.d_inner
    g, n, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    proj = 2 * di + 2 * g * n + nh
    pb.param("w_in", (d, proj), ("embed", "mlp"), scale=d**-0.5)
    pb.param("conv_w", (cfg.conv_width, conv_dim), ("conv", "mlp"), scale=0.5)
    pb.param("conv_b", (conv_dim,), ("mlp",), init="zeros")
    pb.param("a_log", (nh,), ("unsharded",), init="zeros")       # A = -exp(a_log)
    pb.param("dt_bias", (nh,), ("unsharded",), init="zeros")
    pb.param("d_skip", (nh,), ("unsharded",), init="ones")
    pb.param("out_norm", (di,), ("mlp",), init="ones")
    pb.param("w_out", (di, d), ("mlp", "embed"), scale=di**-0.5)
    return pb.collect()


# --------------------------------------------------------------------------
# SSD core
# --------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,    # (B, S, H, P) — already dt-independent inputs
    dt: jax.Array,   # (B, S, H) positive step sizes
    a: jax.Array,    # (H,) negative decay rates
    bmat: jax.Array,  # (B, S, G, N)
    cmat: jax.Array,  # (B, S, G, N)
    *,
    chunk: int,
    init_state: jax.Array | None = None,   # (B, H, N, P)
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,N,P)). f32 math."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    q = chunk
    assert s % q == 0, (s, q)
    nc = s // q

    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    bh = jnp.repeat(bmat.astype(jnp.float32), rep, axis=2).reshape(b, nc, q, h, n)
    ch = jnp.repeat(cmat.astype(jnp.float32), rep, axis=2).reshape(b, nc, q, h, n)

    da = dtf * a.astype(jnp.float32)              # (b, nc, q, h), negative
    da_cs = jnp.cumsum(da, axis=2)

    # intra-chunk (quadratic) term
    diff = da_cs[:, :, :, None, :] - da_cs[:, :, None, :, :]   # (b,nc,qi,qj,h)
    ii = jnp.arange(q)
    causal = ii[:, None] >= ii[None, :]
    lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", ch, bh) * lmat
    y = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dtf, xf)

    # chunk-final states
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)        # (b,nc,q,h)
    states = jnp.einsum(
        "bcjhn,bcjh,bcjhp->bchnp", bh, dtf * decay_to_end, xf
    )
    chunk_decay = jnp.exp(da_cs[:, :, -1, :])                  # (b,nc,h)

    # inter-chunk recurrence
    s0 = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(prev, inp):
        st, dec = inp              # (b,h,n,p), (b,h)
        return st + prev * dec[:, :, None, None], prev

    final, prevs = lax.scan(
        body,
        s0,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prevs = prevs.transpose(1, 0, 2, 3, 4)       # (b, nc, h, n, p) state entering chunk

    y_off = jnp.einsum(
        "bcihn,bchnp,bcih->bcihp", ch, prevs, jnp.exp(da_cs)
    )
    out = (y + y_off).reshape(b, s, h, p)
    return out.astype(x.dtype), final


def ssd_sequential(x, dt, a, bmat, cmat, *, init_state=None):
    """Token-by-token reference recurrence (oracle for tests)."""
    b, s, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    bh = jnp.repeat(bmat.astype(jnp.float32), rep, axis=2)
    ch = jnp.repeat(cmat.astype(jnp.float32), rep, axis=2)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    s0 = (
        jnp.zeros((b, h, n, p), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def body(state, inp):
        xt, dtt, bt, ct = inp      # (b,h,p), (b,h), (b,h,n), (b,h,n)
        state = state * jnp.exp(dtt * af)[:, :, None, None] + jnp.einsum(
            "bhn,bh,bhp->bhnp", bt, dtt, xt
        )
        yt = jnp.einsum("bhn,bhnp->bhp", ct, state)
        return state, yt

    xs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        bh.transpose(1, 0, 2, 3),
        ch.transpose(1, 0, 2, 3),
    )
    final, ys = lax.scan(body, s0, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


# --------------------------------------------------------------------------
# full mixer layer
# --------------------------------------------------------------------------
def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * g * n]
    dt = zxbcdt[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, bias: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq: xbc (B,S,C), w (width, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, shape=xbc.shape).astype(jnp.float32)
    for i in range(width):
        out = out + pad[:, i : i + xbc.shape[1], :].astype(jnp.float32) * w[i].astype(
            jnp.float32
        )
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)


def mamba_forward(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    make_cache: bool = False,
):
    """Full-sequence Mamba-2 mixer. Returns (out, cache|None)."""
    b, s, d = x.shape
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hd = di // nh
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xbc_pre, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_pre, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(b, s, nh, hd)
    bmat = xbc[..., di : di + g * n].reshape(b, s, g, n)
    cmat = xbc[..., di + g * n :].reshape(b, s, g, n)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    chunk = min(cfg.ssm_chunk, s)
    while s % chunk:
        chunk -= 1
    y, state = ssd_chunked(xs, dtp, a, bmat, cmat, chunk=chunk)
    y = y + xs * p["d_skip"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["out_norm"], eps=cfg.norm_eps, plus_one=False)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    cache = None
    if make_cache:
        # conv tail: last (width-1) pre-activation inputs
        tail = xbc_pre[:, -(cfg.conv_width - 1) :, :]
        cache = {"state": state.astype(x.dtype), "conv": tail}
    return out, cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype):
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hd = di // nh
    return {
        "state": jnp.zeros((batch, nh, n, hd), dtype),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * g * n), dtype),
    }


def mamba_decode(p: dict, x: jax.Array, cache: dict, cfg: ModelConfig):
    """One-token decode: O(1) state update. x: (B, 1, d)."""
    b = x.shape[0]
    di, g, n, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    hd = di // nh
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)

    # conv over cached tail + new input
    window = jnp.concatenate([cache["conv"], xbc_new], axis=1)  # (B, width, C)
    w = p["conv_w"].astype(jnp.float32)
    conv = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w)
    xbc = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(x.dtype)

    xs = xbc[:, :di].reshape(b, nh, hd)
    bmat = xbc[:, di : di + g * n].reshape(b, g, n)
    cmat = xbc[:, di + g * n :].reshape(b, g, n)
    rep = nh // g
    bh = jnp.repeat(bmat.astype(jnp.float32), rep, axis=1)
    ch = jnp.repeat(cmat.astype(jnp.float32), rep, axis=1)
    dtp = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    state = cache["state"].astype(jnp.float32)
    state = state * jnp.exp(dtp * a)[:, :, None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", bh, dtp, xs.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhnp->bhp", ch, state)
    y = y + xs.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["out_norm"], eps=cfg.norm_eps, plus_one=False)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    cache = {
        "state": state.astype(cache["state"].dtype),
        "conv": window[:, 1:, :],
    }
    return out, cache
