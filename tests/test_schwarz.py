"""Overlapping-Schwarz smoother: FDM blocks, edge cases, dist parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import build_problem, cg_assembled, poisson_assembled, sem
from repro.core.precond import make_pmg_preconditioner, make_preconditioner
from repro.core.schwarz import (
    build_fdm,
    element_lengths,
    element_neighbor_flags,
    extended_l2g,
    fdm_solve,
    make_schwarz_apply,
    overlap_counts_1d,
    overlap_counts_global,
)


@pytest.fixture(scope="module")
def prob64():
    jax.config.update("jax_enable_x64", True)
    return build_problem(4, (3, 2, 2), lam=0.7, deform=0.2, dtype=jnp.float64)


def _dense(fn, n):
    return np.array(jax.vmap(fn, in_axes=1, out_axes=1)(jnp.eye(n)))


# ---------------------------------------------------------------------------
# 1-D pieces
# ---------------------------------------------------------------------------


def test_stiffness_matrix_1d_exact_on_polynomials():
    """A = D^T diag(w) D integrates ∫ p' q' exactly for SEM polynomials."""
    n = 5
    x, _ = sem.gll_nodes_weights(n)
    a = sem.stiffness_matrix_1d(n)
    # ∫_{-1}^{1} (x^2)'(x^3)' dx = ∫ 2x·3x^2 = 0;  ∫ (x^2)'(x^2)' = 8/3
    p2, p3 = x**2, x**3
    assert abs(p2 @ a @ p3) < 1e-12
    np.testing.assert_allclose(p2 @ a @ p2, 8.0 / 3.0, atol=1e-12)
    # symmetric PSD with the constant in the nullspace
    np.testing.assert_allclose(a, a.T, atol=1e-14)
    np.testing.assert_allclose(a @ np.ones(n + 1), 0.0, atol=1e-12)


def test_extended_interval_matrices_shapes_and_bcs():
    n, s = 4, 1
    a_ext, b_ext = sem.extended_interval_matrices(n, s, 0.5)
    assert a_ext.shape == (n + 1 + 2 * s,) * 2 and b_ext.shape == (n + 2 * s + 1,)
    # both-neighbor case: interface nodes carry both elements' mass
    _, w = sem.gll_nodes_weights(n)
    np.testing.assert_allclose(b_ext[s], 2 * 0.25 * w[0], atol=1e-14)
    # missing neighbor: extension slots decouple to identity
    a_lo, b_lo = sem.extended_interval_matrices(n, s, 0.5, has_lo=False)
    assert a_lo[0, 0] == 1.0 and b_lo[0] == 1.0
    np.testing.assert_allclose(a_lo[0, 1:], 0.0, atol=0)
    with pytest.raises(ValueError, match="overlap"):
        sem.extended_interval_matrices(n, n, 0.5)


def test_fast_diagonalization_identities():
    """T^T B T = I and T^T A T = diag(mu) for the generalized eigenpairs."""
    a_ext, b_ext = sem.extended_interval_matrices(5, 2, 0.3)
    t, mu, s = sem.fast_diagonalization_1d(a_ext, b_ext)
    np.testing.assert_allclose(t.T @ np.diag(b_ext) @ t, np.eye(len(mu)), atol=1e-10)
    np.testing.assert_allclose(t.T @ a_ext @ t, np.diag(mu), atol=1e-9)
    np.testing.assert_allclose(s, np.sum(t * t, axis=0), atol=1e-12)


def test_fdm_solve_converges_to_exact_block():
    """The in-eigenbasis Chebyshev block solve approaches the dense inverse
    of the separable screened operator as inner_degree grows."""
    n, s, h, lam = 4, 1, 0.4, 0.7
    m = n + 1 + 2 * s
    lengths = np.full((1, 3), h)
    flags = np.ones((1, 3, 2), bool)
    a_ext, b_ext = sem.extended_interval_matrices(n, s, h)
    t, mu, _ = sem.fast_diagonalization_1d(a_ext, b_ext)
    a1 = np.linalg.solve(t.T, np.diag(mu) @ np.linalg.inv(t))
    k = (
        np.kron(np.diag(b_ext), np.kron(np.diag(b_ext), a1))
        + np.kron(np.diag(b_ext), np.kron(a1, np.diag(b_ext)))
        + np.kron(a1, np.kron(np.diag(b_ext), np.diag(b_ext)))
        + lam * np.eye(m**3)
    )
    rng = np.random.default_rng(0)
    u = rng.standard_normal(m**3)
    z_exact = np.linalg.solve(k, u)
    errs = []
    for deg in (1, 4, 8):
        fdm = build_fdm(lengths, flags, n, lam, s, jnp.float64, inner_degree=deg)
        z = np.array(fdm_solve(fdm, jnp.asarray(u[None])))[0]
        errs.append(np.linalg.norm(z - z_exact) / np.linalg.norm(z_exact))
    assert errs[0] > errs[1] > errs[2], errs
    assert errs[2] < 0.05, errs


def test_fdm_solve_finite_at_lambda_zero():
    """λ=0 collapses the inner Chebyshev interval to a point (H is exactly
    diagonal); the recurrence must stay finite and exact, not divide by the
    zero interval half-width (regression)."""
    jax.config.update("jax_enable_x64", True)
    lengths = np.full((1, 3), 0.4)
    flags = np.ones((1, 3, 2), bool)
    fdm = build_fdm(lengths, flags, 4, 0.0, 1, jnp.float64, inner_degree=7)
    rng = np.random.default_rng(1)
    u = rng.standard_normal((1, fdm.m**3))
    z = np.array(fdm_solve(fdm, jnp.asarray(u)))
    assert np.isfinite(z).all()
    # with λ=0 the fast diagonalization is exact: K z == u for the dense
    # separable operator K = T⁻ᵀ diag(μsum) T⁻¹
    t3 = np.kron(
        np.array(fdm.tmats[0, 2]),
        np.kron(np.array(fdm.tmats[0, 1]), np.array(fdm.tmats[0, 0])),
    )
    k = np.linalg.solve(
        t3.T, np.diag(np.array(fdm.musum[0]).reshape(-1)) @ np.linalg.inv(t3)
    )
    np.testing.assert_allclose(k @ z[0], u[0], atol=1e-9)


# ---------------------------------------------------------------------------
# extended maps and weights
# ---------------------------------------------------------------------------


def test_extended_l2g_degenerates_to_l2g_at_overlap0(prob64):
    """ISSUE satellite: overlap width 0 == the plain element map (block
    Jacobi), and the counts reduce to the gather-scatter node degree."""
    mesh = prob64.mesh
    l2g0 = extended_l2g(mesh.n_degree, mesh.shape, 0)
    np.testing.assert_array_equal(l2g0, mesh.l2g)
    counts = overlap_counts_global(mesh.n_degree, mesh.shape, 0)
    ref = np.zeros(mesh.n_global)
    np.add.at(ref, mesh.l2g.reshape(-1), 1.0)
    np.testing.assert_array_equal(counts, ref)


def test_extended_l2g_overlap_counts_match(prob64):
    """Analytic separable counts == histogram of the extended map."""
    mesh = prob64.mesh
    for s in (1, 2):
        l2g = extended_l2g(mesh.n_degree, mesh.shape, s)
        ref = np.zeros(mesh.n_global + 1)
        np.add.at(ref, l2g.reshape(-1), 1.0)
        np.testing.assert_array_equal(
            overlap_counts_global(mesh.n_degree, mesh.shape, s),
            ref[:-1],
        )
    assert overlap_counts_1d(3, 4, 1).max() == 2


# ---------------------------------------------------------------------------
# the assembled apply
# ---------------------------------------------------------------------------


def test_schwarz_apply_symmetric_positive_definite(prob64):
    """Symmetric weighted additive Schwarz must be an SPD linear map."""
    for s in (0, 1):
        pc = make_schwarz_apply(prob64, overlap=s)
        mmat = _dense(pc, prob64.n_global)
        np.testing.assert_allclose(mmat, mmat.T, atol=1e-12)
        ev = np.linalg.eigvalsh(0.5 * (mmat + mmat.T))
        assert ev.min() > 0, f"overlap={s}: not PD ({ev.min()})"


def test_overlap0_is_block_jacobi():
    """ISSUE satellite: overlap 0 applies independent per-element block
    solves — verified against an independently kron-assembled reference.
    λ = 0 makes the fast diagonalization *exact* (only the algebraic
    screen breaks tensor structure), so the match is to solver precision."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(4, (3, 2, 2), lam=0.0, deform=0.2, dtype=jnp.float64)
    pc = make_schwarz_apply(prob, overlap=0, inner_degree=1)
    mesh = prob.mesh
    lengths = element_lengths(mesh.coords, mesh.n_degree)
    ex, ey, ez = mesh.shape
    eidx = np.stack(np.meshgrid(
        np.arange(ex), np.arange(ey), np.arange(ez), indexing="ij"
    ), axis=-1).transpose(2, 1, 0, 3).reshape(-1, 3)
    flags = element_neighbor_flags(eidx, mesh.shape)
    counts = overlap_counts_global(mesh.n_degree, mesh.shape, 0)
    wh = 1.0 / np.sqrt(counts)

    mref = np.zeros((prob.n_global,) * 2)
    for e in range(mesh.n_elements):
        mats = []
        for d in range(3):
            a_ext, b_ext = sem.extended_interval_matrices(
                mesh.n_degree, 0, lengths[e, d],
                has_lo=flags[e, d, 0], has_hi=flags[e, d, 1],
            )
            mats.append((a_ext, np.diag(b_ext)))
        (a1, b1), (a2, b2), (a3, b3) = mats
        # separable block (Kronecker sum of 1-D stiffness with mass factors)
        blk = (
            np.kron(b3, np.kron(b2, a1))
            + np.kron(b3, np.kron(a2, b1))
            + np.kron(a3, np.kron(b2, b1))
        )
        idx = mesh.l2g[e]
        mref[np.ix_(idx, idx)] += np.linalg.inv(blk)
    mref = wh[:, None] * mref * wh[None, :]

    mgot = _dense(pc, prob.n_global)
    np.testing.assert_allclose(mgot, mref, atol=1e-8)


def test_single_element_mesh():
    """ISSUE satellite: a single-element mesh exercises the no-neighbor
    path in every direction; the Schwarz-preconditioned solve must converge
    in (far) fewer iterations than plain CG."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(5, (1, 1, 1), lam=0.5, dtype=jnp.float64)
    a = poisson_assembled(prob)
    pc = make_schwarz_apply(prob, overlap=1)
    mmat = _dense(pc, prob.n_global)
    np.testing.assert_allclose(mmat, mmat.T, atol=1e-12)
    assert np.linalg.eigvalsh(mmat).min() > 0
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal(prob.n_global))
    plain = cg_assembled(a, b, n_iter=400, tol=1e-8)
    res = cg_assembled(a, b, n_iter=400, tol=1e-8, precond=pc)
    assert int(res.iterations) < 400
    assert int(res.iterations) < int(plain.iterations) // 2, (
        int(res.iterations), int(plain.iterations)
    )


def test_schwarz_beats_jacobi_on_deformed_mesh(prob64):
    a = poisson_assembled(prob64)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal(prob64.n_global))
    x_ref = cg_assembled(a, b, n_iter=500, tol=1e-12).x
    iters = {}
    for kind in ("jacobi", "schwarz"):
        pc, _ = make_preconditioner(kind, prob64, a)
        res = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc)
        assert int(res.iterations) < 500
        np.testing.assert_allclose(np.array(res.x), np.array(x_ref), atol=1e-6)
        iters[kind] = int(res.iterations)
    assert iters["schwarz"] < iters["jacobi"], iters


def test_schwarz_weighting_post_rejected_for_pcg(prob64):
    a = poisson_assembled(prob64)
    with pytest.raises(ValueError, match="nonsymmetric"):
        make_preconditioner("schwarz", prob64, a, schwarz_weighting="post")


# ---------------------------------------------------------------------------
# pMG integration: Schwarz smoothing + Galerkin coarse operators
# ---------------------------------------------------------------------------


def test_schwarz_smoothed_vcycle_spd(prob64):
    """ISSUE satellite: the Schwarz-smoothed V-cycle stays a symmetric
    positive-definite map (the plain-PCG validity requirement)."""
    a = poisson_assembled(prob64)
    pc, info = make_pmg_preconditioner(prob64, a, smoother="schwarz")
    assert info.smoother == "schwarz" and info.degree == 2
    mmat = _dense(pc, prob64.n_global)
    np.testing.assert_allclose(mmat, mmat.T, atol=1e-11)
    assert np.linalg.eigvalsh(0.5 * (mmat + mmat.T)).min() > 0


def test_galerkin_coarse_operator_is_triple_product(prob64):
    """pmg coarse_op="galerkin" level-1 operator equals R A P exactly."""
    from repro.core.operator import coarsen_problem
    from repro.core.precond import make_transfer_pair

    a = poisson_assembled(prob64)
    prob_c = coarsen_problem(prob64, 2)
    prolong, restrict = make_transfer_pair(prob64, prob_c)
    want = _dense(lambda v: restrict(a(prolong(v))), prob_c.n_global)
    # rebuild the chained operator the way make_pmg_preconditioner does
    pc, info = make_pmg_preconditioner(
        prob64, a, coarse_op="galerkin", ladder=(4, 2, 1)
    )
    assert info.coarse_op == "galerkin"
    # the V-cycle with exact coarse ops must still be SPD
    mmat = _dense(pc, prob64.n_global)
    np.testing.assert_allclose(mmat, mmat.T, atol=1e-11)
    assert np.linalg.eigvalsh(0.5 * (mmat + mmat.T)).min() > 0
    # and the triple product itself is symmetric (R = P^T)
    np.testing.assert_allclose(want, want.T, atol=1e-11)


def test_pmg_galerkin_closes_small_lambda_gap():
    """ISSUE acceptance: at N=7, λ=0.1, tol=1e-8 the Galerkin coarse
    operator needs no more iterations than rediscretized pmg (it closes
    the rediscretization gap), and pmg-schwarz matches pmg too."""
    jax.config.update("jax_enable_x64", True)
    prob = build_problem(7, (4, 4, 4), lam=0.1, deform=0.15, dtype=jnp.float64)
    a = poisson_assembled(prob)
    rng = np.random.default_rng(0)
    b = jnp.asarray(rng.standard_normal(prob.n_global))
    iters = {}
    for name, kw in (
        ("pmg", {}),
        ("pmg-galerkin", {"pmg_coarse_op": "galerkin"}),
        ("pmg-schwarz", {"pmg_smoother": "schwarz"}),
    ):
        pc, _ = make_preconditioner("pmg", prob, a, **kw)
        res = cg_assembled(a, b, n_iter=500, tol=1e-8, precond=pc)
        assert int(res.iterations) < 500
        iters[name] = int(res.iterations)
    assert iters["pmg-galerkin"] <= iters["pmg"], iters
    assert iters["pmg-schwarz"] <= iters["pmg"], iters
    # the gap is real: galerkin should be a strict improvement here
    assert iters["pmg-galerkin"] < iters["pmg"], iters


# ---------------------------------------------------------------------------
# distributed parity
# ---------------------------------------------------------------------------


def test_halo_expand_contract_adjoint():
    """contract_exchange is the exact adjoint of expand_exchange:
    sum_r <expand(x_r), y_r> == sum_r <x_r, contract(y_r)>."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh, shard_map
from jax.sharding import PartitionSpec as P
from jax import lax
from repro.comms.halo import expand_exchange, contract_exchange
from repro.comms.topology import ProcessGrid

grid = ProcessGrid((2, 2, 2)); depth = 2
shape = (5, 4, 6)
ext = tuple(s + 2*depth for s in shape)
mesh = make_mesh((8,), ("ranks",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.standard_normal((8,) + shape))
y = jnp.asarray(rng.standard_normal((8,) + ext))

def fn(x_s, y_s):
    ex = expand_exchange(x_s[0], grid, "ranks", depth)
    ct = contract_exchange(y_s[0], grid, "ranks", depth)
    a = lax.psum(jnp.vdot(ex, y_s[0]), "ranks")
    b = lax.psum(jnp.vdot(x_s[0], ct), "ranks")
    return a, b

spec = P("ranks")
a, b = jax.jit(shard_map(fn, mesh=mesh, in_specs=(spec, spec),
                         out_specs=(P(), P()), check_rep=False))(x, y)
assert abs(float(a) - float(b)) < 1e-10 * max(1.0, abs(float(a))), (a, b)
print("OK", float(a))
"""
    )


@pytest.mark.slow
def test_distributed_schwarz_matches_single_shard():
    """ISSUE satellite: dist_cg(precond="schwarz") reproduces the
    single-shard solution and iteration count on an 8-rank mesh."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_problem, poisson_assembled, cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (2, 1, 1)
gshape = (4, 2, 2)
ref = build_problem(N, gshape, lam=0.8, dtype=jnp.float64)
A = poisson_assembled(ref)
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.8, dtype=jnp.float64)
rng = np.random.default_rng(0)
bg = rng.standard_normal(ref.n_global)
GX, GY = gshape[0]*N+1, gshape[1]*N+1
def box_from_global(vec):
    out = np.zeros((grid.size, prob.m3))
    mx, my, mz = prob.box_shape
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ox, oy, oz = ci*local[0]*N, cj*local[1]*N, ck*local[2]*N
        x, y, z = np.meshgrid(np.arange(mx), np.arange(my), np.arange(mz), indexing="ij")
        gidx = (ox+x) + GX*((oy+y) + GY*(oz+z))
        out[r] = vec[gidx.transpose(2,1,0).reshape(-1)]
    return out
b_boxes = jnp.asarray(box_from_global(bg))
for overlap in (0, 1, 2):
    run = jax.jit(dist_cg(prob, mesh, b_boxes, n_iter=200, tol=1e-10,
                          precond="schwarz", schwarz_overlap=overlap))
    x_boxes, rdotr, iters, status, hist = run()
    assert int(iters) < 200, int(iters)
    pc, _ = make_preconditioner("schwarz", ref, A, schwarz_overlap=overlap)
    res = cg_assembled(A, jnp.asarray(bg), n_iter=200, tol=1e-10, precond=pc)
    assert int(iters) == int(res.iterations), (overlap, int(iters), int(res.iterations))
    err = np.abs(np.array(x_boxes) - box_from_global(np.array(res.x))).max()
    assert err < 1e-6, (overlap, err)
    print("OK overlap", overlap, int(iters))
"""
    )


@pytest.mark.slow
def test_distributed_pmg_schwarz_smoother_on_deformed_coords():
    """Sharded Schwarz-smoothed pmg on a deformed global mesh (coords
    path): matches the single-shard V-cycle and converges."""
    run_subprocess(
        """
import jax
jax.config.update("jax_enable_x64", True)
import numpy as np, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core.distributed import build_dist_problem, dist_cg
from repro.comms.topology import ProcessGrid
from repro.core import build_box_mesh
from repro.core.mesh import partition_elements
from repro.core.operator import problem_from_mesh, poisson_assembled
from repro.core.cg import cg_assembled
from repro.core.precond import make_preconditioner

N = 3
grid = ProcessGrid((2, 2, 2)); local = (1, 1, 1)
mesh_g = build_box_mesh(N, (2, 2, 2), deform=0.2)
owner = partition_elements((2, 2, 2), grid.shape)
coords = np.stack([mesh_g.coords[owner == r] for r in range(8)])
mesh = make_mesh((8,), ("ranks",))
prob = build_dist_problem(N, grid, local, lam=0.3, dtype=jnp.float64,
                          coords=coords)
rng = np.random.default_rng(0)
b = jnp.asarray(rng.standard_normal((8, prob.m3)))
it = {}
for smoother in ("chebyshev", "schwarz"):
    run = jax.jit(dist_cg(prob, mesh, b, n_iter=300, tol=1e-8, precond="pmg",
                          pmg_smoother=smoother))
    x, rdotr, iters, status, hist = run()
    assert int(iters) < 300, (smoother, int(iters))
    it[smoother] = int(iters)
print("OK", it)
"""
    )
