"""repro.core — the paper's contribution: hipBone in JAX.

Screened-Poisson SEM operator (assembled + scattered storage), CG solver
with hipBone's fusion/overlap schedule, gather-scatter machinery, and the
paper's FOM/roofline models.
"""
from .cg import (
    CG_VARIANTS,
    CGResult,
    SolveStatus,
    batched_cg_assembled,
    cg_assembled,
    cg_scattered,
    fused_residual_update,
    status_name,
)
from .solver_cache import (
    SolverCache,
    SolverSetup,
    mesh_signature,
    solver_setup_key,
)
from .resilience import (
    PRECOND_DOWNGRADE,
    FallbackResult,
    SolveAttempt,
    run_fallback_chain,
    solve_with_fallback,
)
from .fom import (
    TPU_V5E,
    TpuSpec,
    cg_iter_bytes,
    fom_gflops,
    hipbone_flops_per_iter,
    nekbone_flops_per_iter,
    operator_bytes,
    operator_flops,
    roofline_gflops,
)
from .galerkin import (
    coarsen_element_blocks,
    galerkin_assembled_diagonal,
    galerkin_block_apply,
    galerkin_element_blocks,
    galerkin_ladder_blocks,
)
from .gather_scatter import (
    gather,
    gather_scatter,
    inverse_degree,
    local_inverse_degree,
    scatter,
)
from .coefficients import (
    COEFFICIENTS,
    checker_k,
    coefficient_fields,
    smooth_k,
    smooth_k_grad,
)
from .geometry import geometric_factors
from .mesh import (
    BC_FACES,
    BoxMesh,
    build_box_mesh,
    dirichlet_mask,
    normalize_bc,
    partition_elements,
)
from .operator import (
    PoissonProblem,
    build_problem,
    cast_problem,
    coarsen_problem,
    local_poisson,
    poisson_assembled,
    poisson_scattered,
    problem_from_mesh,
    screen_stream,
)
from .precond import (
    PMG_COARSE_OPS,
    PMG_SMOOTHERS,
    PRECOND_KINDS,
    assembled_diagonal,
    chebyshev_apply,
    jacobi_apply,
    lanczos_extremes,
    local_operator_diagonal,
    make_pmg_preconditioner,
    make_preconditioner,
    precond_signature,
    make_transfer_pair,
    make_vcycle,
    pmg_degree_ladder,
    power_lambda_max,
    tensor3_interp,
)
from .schwarz import (
    SCHWARZ_INNER_DEGREE,
    SchwarzFDM,
    build_fdm,
    fdm_solve,
    make_schwarz_apply,
)
from .sem import (
    derivative_matrix,
    extended_interval_matrices,
    fast_diagonalization_1d,
    gll_nodes_weights,
    interpolation_matrix,
    reference_element,
    stiffness_matrix_1d,
)

__all__ = [k for k in dir() if not k.startswith("_")]
