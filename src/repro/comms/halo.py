"""Structured 3-D halo communication for the distributed Poisson operator.

Each rank owns a padded DOF box of shape (mx, my, mz); interface points are
replicated on every sharing rank. Two primitives, both built from static
``lax.ppermute`` face shifts (2 per partitioned dimension):

  * ``sum_exchange``  — assemble partial sums at interface points AND leave
    every replica holding the summed value (the gather Z^T fused with the
    scatter-side refresh; see DESIGN.md: the padded-consistent storage
    merges hipBone's two communication phases into one).
  * ``copy_exchange`` — refresh replicas from the canonical owner only
    (used by the paper-faithful two-phase mode and by tests).

Sequential dimension sweeps propagate edge/corner contributions without
explicit 26-neighbor messages — the structured-grid trick NekBone's
gslib setup discovers generically.

All functions run inside shard_map over ``axis_name`` whose size equals
``grid.size``. Boxes are passed as 3-D arrays indexed [z, y, x]
(x fastest in the flat layout).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .topology import ProcessGrid

__all__ = ["sum_exchange", "copy_exchange", "rank_coords"]


def rank_coords(grid: ProcessGrid, axis_name: str) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Traced (i, j, k) coordinates of this rank in the process grid."""
    r = lax.axis_index(axis_name)
    px, py, _ = grid.shape
    return r % px, (r // px) % py, r // (px * py)


# Boxes are stored flat with x fastest (flat = x + mx*(y + my*z)), so the
# reshaped 3-D array is indexed [z, y, x]: spatial dim d lives on array
# axis (2 - d).


def _axis(dim: int) -> int:
    return 2 - dim


def _face(box: jax.Array, dim: int, idx: int) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box[tuple(sl)]


def _set_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].set(val)


def _add_face(box: jax.Array, dim: int, idx: int, val: jax.Array) -> jax.Array:
    sl = [slice(None)] * 3
    sl[_axis(dim)] = slice(idx, idx + 1)
    return box.at[tuple(sl)].add(val)


def sum_exchange(box: jax.Array, grid: ProcessGrid, axis_name: str) -> jax.Array:
    """Assemble interface partial sums; all replicas end up consistent.

    Per partitioned dim: (1) low faces shift down and accumulate into the
    -neighbor's high face (which is the canonical interface slab); (2) the
    summed high face shifts back up into the +neighbor's low face.
    Boundary ranks receive ppermute zero-fill and are masked.
    """
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        # (1) low face -> -neighbor high face (sum)
        low = _face(box, dim, 0)
        recv = lax.ppermute(low, axis_name, grid.shift_perm(dim, -1))
        box = _add_face(box, dim, m - 1, recv)
        # (2) summed high face -> +neighbor low face (copy)
        hi = _face(box, dim, m - 1)
        recv = lax.ppermute(hi, axis_name, grid.shift_perm(dim, +1))
        keep = _face(box, dim, 0)
        new_low = jnp.where(c > 0, recv, keep)
        box = _set_face(box, dim, 0, new_low)
    return box


def copy_exchange(box: jax.Array, grid: ProcessGrid, axis_name: str) -> jax.Array:
    """Refresh replica slabs from owners (owner = low-side rank).

    The canonical copy of an interface point lives on the rank where it sits
    on the HIGH face of the padded box; the +neighbor's low-face replica is
    overwritten. This is hipBone's scatter-side halo exchange in isolation.
    """
    coords = rank_coords(grid, axis_name)
    for dim in range(3):
        pd = grid.shape[dim]
        if pd == 1:
            continue
        m = box.shape[_axis(dim)]
        c = coords[dim]
        hi = _face(box, dim, m - 1)
        recv = lax.ppermute(hi, axis_name, grid.shift_perm(dim, +1))
        keep = _face(box, dim, 0)
        box = _set_face(box, dim, 0, jnp.where(c > 0, recv, keep))
    return box
