"""Deterministic data pipeline: batch = f(step), the restartability invariant.

Two sources:
  * ``SyntheticLM`` — Zipf-distributed tokens with planted bigram structure
    (so a real model's loss visibly decreases below the unigram entropy);
  * ``TextLM`` — char-level corpus (embedded fallback text or a file),
    for the end-to-end ~100M-param example.

Each batch is produced from (seed, step, host_slice) alone — no iterator
state to checkpoint; resume = recompute. Host sharding: each process takes
its contiguous slice of the global batch (``host_index``/``host_count``),
the standard multi-host feed pattern.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = ["SyntheticLM", "TextLM"]

_FALLBACK_TEXT = (
    "We present hipBone, an open source performance portable proxy "
    "application for the Nek5000 and NekRS CFD applications. HipBone is a "
    "fully GPU accelerated C++ implementation of the original NekBone CPU "
    "proxy application with several novel algorithmic and implementation "
    "improvements which optimize its performance on modern fine grain "
    "parallel GPU accelerators. Our optimizations include a conversion to "
    "store the degrees of freedom of the problem in assembled form in "
    "order to reduce the amount of data moved during the main iteration "
    "and a portable implementation of the main Poisson operator kernel. "
) * 64


def _rng_for(seed: int, step: int, host: int) -> np.random.Generator:
    h = hashlib.blake2b(
        f"{seed}:{step}:{host}".encode(), digest_size=8
    ).digest()
    return np.random.default_rng(int.from_bytes(h, "little"))


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    batch: int                 # global batch
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1
    zipf_a: float = 1.2

    def __call__(self, step: int) -> dict:
        b_local = self.batch // self.host_count
        rng = _rng_for(self.seed, step, self.host_index)
        v = self.vocab_size
        # zipf base distribution truncated to vocab
        base = rng.zipf(self.zipf_a, size=(b_local, self.seq_len + 1))
        toks = (base - 1) % v
        # plant deterministic bigram structure: every even position's
        # successor is (tok*7+3) % v with prob 1/2 — learnable signal
        mask = rng.random((b_local, self.seq_len)) < 0.5
        nxt = (toks[:, :-1] * 7 + 3) % v
        toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
        return {"tokens": toks.astype(np.int32)}


@dataclasses.dataclass(frozen=True)
class TextLM:
    batch: int
    seq_len: int
    path: str | None = None
    seed: int = 0
    host_index: int = 0
    host_count: int = 1

    def _corpus(self) -> np.ndarray:
        if self.path:
            with open(self.path, "rb") as f:
                data = f.read()
        else:
            data = _FALLBACK_TEXT.encode()
        return np.frombuffer(data, dtype=np.uint8)

    @property
    def vocab_size(self) -> int:
        return 256

    def __call__(self, step: int) -> dict:
        corpus = self._corpus()
        b_local = self.batch // self.host_count
        rng = _rng_for(self.seed, step, self.host_index)
        starts = rng.integers(0, len(corpus) - self.seq_len - 1, size=b_local)
        toks = np.stack(
            [corpus[s : s + self.seq_len + 1] for s in starts]
        ).astype(np.int32)
        return {"tokens": toks}
