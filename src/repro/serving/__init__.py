"""repro.serving — batched prefill/decode engine over the model zoo."""
from .engine import Engine, ServeConfig

__all__ = ["Engine", "ServeConfig"]
