"""§Perf kernel-substitution accounting for the three hillclimb cells.

Methodology (see EXPERIMENTS.md §Perf): restructuring changes are measured
directly from the re-compiled dry-run HLO; Pallas-kernel changes are
measured by substitution — compile the jnp subgraph the kernel replaces in
isolation (same per-chip shapes), charge its loop-aware HBM proxy as
"eliminated", and charge the kernel's operand/result streams (its HBM
traffic by construction; internals are VMEM-resident, budget verified in
benchmarks/table1) as "added".

Run:  PYTHONPATH=src python scripts/perf_kernel_substitution.py
"""
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.roofline.hlo_model import analyze_hlo

W = 4  # f32 bytes (CPU-lowered HLO is f32 for these subgraphs)


def measure(fn, *args) -> float:
    """Loop-aware HBM proxy bytes of a jit'd subgraph."""
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return analyze_hlo(txt).hbm_bytes


# ---------------------------------------------------------------- hipbone
def hipbone_n15_large():
    """Per-chip, per-CG-iteration traffic, jnp operator vs Pallas kernels."""
    from repro.core import sem
    from repro.core.operator import local_poisson

    n = 15
    e_loc = 512
    p = (n + 1) ** 3
    d = jnp.asarray(sem.derivative_matrix(n), jnp.float32)
    u = jax.ShapeDtypeStruct((e_loc, p), jnp.float32)
    g = jax.ShapeDtypeStruct((e_loc, 6, p), jnp.float32)
    w = jax.ShapeDtypeStruct((e_loc, p), jnp.float32)

    jnp_op_bytes = measure(
        lambda u_, g_, w_: local_poisson(u_, g_, d, 1.0, w_), u, g, w
    )
    # kernel true traffic: one pass over u, G(6), W in; y out
    kernel_bytes = (p * e_loc * (1 + 6 + 1 + 1)) * W

    # CG vector-op fusion: r/p/x updates + dots (assembled, m3 DOFs/chip)
    m3 = (8 * n + 1) ** 3

    def cg_vec(r, ap, x, pvec, mask):
        pap = jnp.vdot(pvec * mask, ap)
        alpha = 1.7 / pap
        r2 = r - alpha * ap
        rr = jnp.vdot(r2 * mask, r2)
        x2 = x + alpha * pvec
        p2 = r2 + (rr / 3.0) * pvec
        return x2, r2, p2, rr

    vs = [jax.ShapeDtypeStruct((m3,), jnp.float32)] * 5
    jnp_vec_bytes = measure(cg_vec, *vs)
    # fused kernels: fused_axpy_dot (3 streams) + xpay (3) + axpy (3) + wdot (3)
    kernel_vec_bytes = 12 * m3 * W

    return {
        "cell": "hipbone_n15_large x multi (paper-representative)",
        "per_iter": {
            "operator_jnp_bytes": jnp_op_bytes,
            "operator_kernel_bytes": kernel_bytes,
            "cg_vec_jnp_bytes": jnp_vec_bytes,
            "cg_vec_kernel_bytes": kernel_vec_bytes,
        },
        "eliminated_per_iter": (jnp_op_bytes - kernel_bytes)
        + (jnp_vec_bytes - kernel_vec_bytes),
    }


# ------------------------------------------------------- chameleon prefill
def chameleon_prefill():
    """Per-chip per-layer attention traffic, jnp chunked vs flash kernel."""
    from repro.models import attention

    # per-chip shapes on the multi-pod mesh: B = 32/32 = 1, q heads 64/16 = 4.
    # kv heads are replicated (8 not divisible by 16); we measure with
    # kv = h_loc = 4 — the dominant score traffic (b*s^2*h_loc) is exact,
    # the small k/v streams are slightly under-counted (4 of 8 heads).
    b, h_loc, kv, s, dh = 1, 4, 4, 32768, 128
    q = jax.ShapeDtypeStruct((b, s, h_loc, dh), jnp.float32)
    k = jax.ShapeDtypeStruct((b, s, kv, dh), jnp.float32)
    v = jax.ShapeDtypeStruct((b, s, kv, dh), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def jnp_attn(q_, k_, v_):
        chunk = 1024

        def kv_fn(c):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * chunk, chunk, 1)
            return sl(k_), sl(v_), sl(pos)

        return attention.flash_attention(
            q_, kv_fn, s // chunk, q_positions=pos,
            n_kv_heads=kv, window=None, scale=dh**-0.5, dv=dh,
        )

    jnp_bytes = measure(jnp_attn, q, k, v)
    kernel_bytes = (b * s * dh * (h_loc * 2 + kv * 2)) * W  # q,o,k,v one pass
    return {
        "cell": "chameleon-34b x prefill_32k x multi (worst memory-bound)",
        "per_layer": {
            "attention_jnp_bytes": jnp_bytes,
            "attention_kernel_bytes": kernel_bytes,
        },
        "eliminated_per_layer": jnp_bytes - kernel_bytes,
        "n_layers": 48,
    }


# --------------------------------------------------------- deepseek train
def deepseek_attn():
    """Absorbed-MLA + flash: per-layer traffic, measured both jnp forms."""
    from repro.models import attention
    from repro.configs import ARCHS

    cfg = ARCHS["deepseek-v3-671b"]
    # per-chip: batch 256/32=8, heads 128/16=8, seq 4096
    b, h_loc, s = 8, 8, 4096
    r, rope, nope, dv = cfg.kv_lora_rank, cfg.qk_rope_head_dim, cfg.qk_nope_head_dim, cfg.v_head_dim
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    scale = (nope + rope) ** -0.5

    q = jax.ShapeDtypeStruct((b, s, h_loc, nope + rope), jnp.float32)
    ckv = jax.ShapeDtypeStruct((b, s, r), jnp.float32)
    krope = jax.ShapeDtypeStruct((b, s, rope), jnp.float32)
    wukv = jax.ShapeDtypeStruct((r, h_loc, nope + dv), jnp.float32)

    def expanded(q_, c_, kr_, w_):
        chunk = 1024
        def kv_fn(c):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * chunk, chunk, 1)
            kv = jnp.einsum("bcr,rhk->bchk", sl(c_), w_)
            k_nope, vv = kv[..., :nope], kv[..., nope:]
            kr = jnp.broadcast_to(sl(kr_)[:, :, None, :], k_nope.shape[:3] + (rope,))
            return jnp.concatenate([k_nope, kr], -1), vv, sl(pos)
        return attention.flash_attention(
            q_, kv_fn, s // chunk, q_positions=pos, n_kv_heads=h_loc,
            window=None, scale=scale, dv=dv,
        )

    def absorbed(q_, c_, kr_, w_):
        w_uk, w_uv = w_[..., :nope], w_[..., nope:]
        q_abs = jnp.einsum("bshn,rhn->bshr", q_[..., :nope], w_uk)
        q_full = jnp.concatenate([q_abs, q_[..., nope:]], -1)
        k_full = jnp.concatenate([c_, kr_], -1)[:, :, None, :]
        v_c = c_[:, :, None, :]
        chunk = 1024
        def kv_fn(c):
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, c * chunk, chunk, 1)
            return sl(k_full), sl(v_c), sl(pos)
        out_c = attention.flash_attention(
            q_full, kv_fn, s // chunk, q_positions=pos, n_kv_heads=1,
            window=None, scale=scale, dv=r,
        )
        return jnp.einsum("bshr,rhv->bshv", out_c, w_uv)

    exp_bytes = measure(expanded, q, ckv, krope, wukv)
    abs_bytes = measure(absorbed, q, ckv, krope, wukv)
    # absorbed + flash kernel: q_full, k_full, v_c, out_c streams once
    flash_bytes = (
        b * s * (h_loc * (r + rope)       # q_full
                 + (r + rope) + r         # k_full + v_c
                 + h_loc * r              # out_c
                 + h_loc * (nope + rope)  # q in
                 + h_loc * dv)            # out
    ) * W
    return {
        "cell": "deepseek-v3-671b x train_4k x multi (paper-technique cell)",
        "per_layer_fwd": {
            "mla_expanded_jnp_bytes": exp_bytes,
            "mla_absorbed_jnp_bytes": abs_bytes,
            "mla_absorbed_flash_bytes": flash_bytes,
        },
        "n_layers": 61,
    }


if __name__ == "__main__":
    out = {
        "hipbone": hipbone_n15_large(),
        "chameleon_prefill": chameleon_prefill(),
        "deepseek_attn": deepseek_attn(),
    }
    print(json.dumps(out, indent=2, default=float))
    with open("results/perf/kernel_substitution.json", "w") as f:
        json.dump(out, f, indent=2, default=float)
