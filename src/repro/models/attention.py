"""Attention: GQA/MQA/MHA, MLA (DeepSeek), sliding-window, local:global.

Prefill/training uses a chunked online-softmax ("flash-style") pure-JAX
attention — memory O(S·chunk) instead of O(S²), which is what lets the
32k-prefill dry-runs fit. KV is produced chunk-by-chunk through a provider
callback so MLA can expand its compressed cache lazily (never materializing
the full K/V).

Decode attends over a KV cache whose *sequence axis is sharded* across mesh
axes (sequence-parallel flash-decode): each shard computes a partial
softmax over its chunk of the cache, then partials merge with a max/psum
combine. For 500k-token contexts on 512 chips this turns the KV-cache walk
into a perfectly-parallel operation with one tiny collective — the paper's
C4 philosophy (local compute ‖ small exchange) applied to serving.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import pcast_varying
from .common import rms_norm, rope
from .config import ModelConfig
from .params import ParamBuilder

__all__ = [
    "init_attention",
    "attn_forward",
    "attn_decode",
    "init_attn_cache",
    "flash_attention",
]

NEG_INF = -1e30


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, dtype) -> tuple[dict, dict]:
    pb = ParamBuilder(key, dtype=dtype)
    d = cfg.d_model
    if cfg.attn_kind == "mla":
        qk = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
        pb.param("wdq", (d, cfg.q_lora_rank), ("embed", "lora"), scale=d**-0.5)
        pb.param("q_norm", (cfg.q_lora_rank,), ("unsharded",), init="ones")
        pb.param(
            "wuq",
            (cfg.q_lora_rank, cfg.n_heads, qk),
            ("lora", "heads", "qk"),
            scale=cfg.q_lora_rank**-0.5,
        )
        pb.param(
            "wdkv",
            (d, cfg.kv_lora_rank + cfg.qk_rope_head_dim),
            ("embed", "lora"),
            scale=d**-0.5,
        )
        pb.param("kv_norm", (cfg.kv_lora_rank,), ("unsharded",), init="ones")
        pb.param(
            "wukv",
            (
                cfg.kv_lora_rank,
                cfg.n_heads,
                cfg.qk_nope_head_dim + cfg.v_head_dim,
            ),
            ("lora", "heads", "qk"),
            scale=cfg.kv_lora_rank**-0.5,
        )
        pb.param(
            "wo",
            (cfg.n_heads, cfg.v_head_dim, d),
            ("heads", "qk", "embed"),
            scale=(cfg.n_heads * cfg.v_head_dim) ** -0.5,
        )
    else:
        hd = cfg.head_dim
        pb.param("wq", (d, cfg.n_heads, hd), ("embed", "heads", "qk"), scale=d**-0.5)
        pb.param(
            "wk", (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "qk"), scale=d**-0.5
        )
        pb.param(
            "wv", (d, cfg.n_kv_heads, hd), ("embed", "kv_heads", "qk"), scale=d**-0.5
        )
        pb.param(
            "wo",
            (cfg.n_heads, hd, d),
            ("heads", "qk", "embed"),
            scale=(cfg.n_heads * hd) ** -0.5,
        )
        if cfg.qk_norm:
            pb.param("q_norm", (hd,), ("unsharded",), init="ones")
            pb.param("k_norm", (hd,), ("unsharded",), init="ones")
    return pb.collect()


# --------------------------------------------------------------------------
# chunked online-softmax attention
# --------------------------------------------------------------------------
class _Carry(NamedTuple):
    o: jax.Array  # (B, Sq, H, Dv) f32 — unnormalized
    m: jax.Array  # (B, Sq, H) running max
    l: jax.Array  # (B, Sq, H) running sum


def flash_attention(
    q: jax.Array,                      # (B, Sq, H, Dq)
    kv_fn: Callable[[int], tuple[jax.Array, jax.Array, jax.Array]],
    n_chunks: int,
    *,
    q_positions: jax.Array,            # (B, Sq) global positions of queries
    n_kv_heads: int,
    window: int | None,
    scale: float,
    dv: int,
) -> jax.Array:
    """Online-softmax attention over KV chunks from ``kv_fn``.

    kv_fn(c) -> (k, v, kv_pos): k (B, C, KV, Dq), v (B, C, KV, Dv),
    kv_pos (B, C) global positions (negative = invalid slot).
    Causal mask: kv_pos <= q_pos; window mask: kv_pos > q_pos - window.
    """
    b, sq, h, dq = q.shape
    g = h // n_kv_heads
    qf = q.astype(jnp.float32) * scale
    q5 = qf.reshape(b, sq, n_kv_heads, g, dq)

    def body(carry: _Carry, c: jax.Array) -> tuple[_Carry, None]:
        k, v, kv_pos = kv_fn(c)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        # scores: (B, Sq, KV, G, C)
        s = jnp.einsum("bskgd,bckd->bskgc", q5, kf)
        mask = kv_pos[:, None, None, None, :] <= q_positions[:, :, None, None, None]
        mask &= kv_pos[:, None, None, None, :] >= 0
        if window is not None:
            mask &= (
                kv_pos[:, None, None, None, :]
                > q_positions[:, :, None, None, None] - window
            )
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(carry.m, jnp.max(s, axis=-1).reshape(b, sq, h))
        p = jnp.exp(s - m_new.reshape(b, sq, n_kv_heads, g)[..., None])
        corr = jnp.exp(carry.m - m_new)
        l_new = carry.l * corr + jnp.sum(p, axis=-1).reshape(b, sq, h)
        pv = jnp.einsum("bskgc,bckd->bskgd", p, vf).reshape(b, sq, h, dv)
        o_new = carry.o * corr[..., None] + pv
        return _Carry(o_new, m_new, l_new), None

    init = _Carry(
        o=jnp.zeros((b, sq, h, dv), jnp.float32),
        m=jnp.full((b, sq, h), NEG_INF, jnp.float32),
        l=jnp.zeros((b, sq, h), jnp.float32),
    )
    carry, _ = lax.scan(body, init, jnp.arange(n_chunks))
    out = carry.o / jnp.maximum(carry.l, 1e-37)[..., None]
    return out.astype(q.dtype)


def _pick_chunk(s: int, want: int = 1024) -> int:
    c = min(want, s)
    while s % c:
        c -= 1
    return c


# --------------------------------------------------------------------------
# Pallas flash-attention dispatch (TPU fast path; see kernels/flash_attention)
# --------------------------------------------------------------------------
# Switch for the fused-kernel path. Default: only on real TPUs (the CPU
# dry-run keeps the jnp path so the HLO analysis reflects what runs there).
USE_PALLAS_FLASH: bool | None = None


def _pallas_flash_enabled() -> bool:
    if USE_PALLAS_FLASH is not None:
        return USE_PALLAS_FLASH
    return jax.default_backend() == "tpu"


def _jnp_attention_bhsd(q, k, v, *, scale, window):
    """Chunked online-softmax reference in (B, H, S, D) layout (vjp bwd)."""
    b, h, sq, d = q.shape
    kvh = k.shape[1]
    kt = jnp.swapaxes(k, 1, 2).reshape(b, k.shape[2], kvh, d)
    vt = jnp.swapaxes(v, 1, 2).reshape(b, v.shape[2], kvh, d)
    qt = jnp.swapaxes(q, 1, 2)                       # (B, S, H, D)
    pos = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    chunk = _pick_chunk(k.shape[2])

    def kv_fn(c):
        sl = lambda a: lax.dynamic_slice_in_dim(a, c * chunk, chunk, axis=1)
        return sl(kt), sl(vt), sl(pos)

    out = flash_attention(
        qt, kv_fn, k.shape[2] // chunk,
        q_positions=pos, n_kv_heads=kvh, window=window, scale=scale, dv=d,
    )
    return jnp.swapaxes(out, 1, 2)                   # (B, H, S, D)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _flash_attn_op(q, k, v, scale, window):
    """Causal attention, (B,H,S,D) layout. Pallas kernel fwd on TPU."""
    if _pallas_flash_enabled():
        from ..kernels.flash_attention import flash_attention_fwd_pallas

        return flash_attention_fwd_pallas(
            q, k, v, scale=scale, causal=True, window=window, interpret=False
        )
    return _jnp_attention_bhsd(q, k, v, scale=scale, window=window)


def _flash_fwd(q, k, v, scale, window):
    return _flash_attn_op(q, k, v, scale, window), (q, k, v)


def _flash_bwd(scale, window, res, g):
    q, k, v = res
    # rematerializing backward through the chunked jnp path (Pallas backward
    # kernel: future work — EXPERIMENTS.md §Perf)
    _, vjp = jax.vjp(
        lambda q_, k_, v_: _jnp_attention_bhsd(
            q_, k_, v_, scale=scale, window=window
        ),
        q, k, v,
    )
    return vjp(g)


_flash_attn_op.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------------------------------------
# GQA / MLA forward (training & prefill)
# --------------------------------------------------------------------------
def _gqa_qkv(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], eps=cfg.norm_eps, plus_one=False)
        k = rms_norm(k, p["k_norm"], eps=cfg.norm_eps, plus_one=False)
    q = rope(q, positions, theta=cfg.rope_theta)
    k = rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _mla_q(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    cq = jnp.einsum("bsd,dr->bsr", x, p["wdq"])
    cq = rms_norm(cq, p["q_norm"], eps=cfg.norm_eps, plus_one=False)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q_nope = q[..., : cfg.qk_nope_head_dim]
    q_rope = rope(q[..., cfg.qk_nope_head_dim :], positions, theta=cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _mla_ckv(p: dict, x: jax.Array, positions: jax.Array, cfg: ModelConfig):
    """Compressed KV: (c_kv normed, k_rope roped) — this is what gets cached."""
    dkv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"])
    c_kv = rms_norm(
        dkv[..., : cfg.kv_lora_rank], p["kv_norm"], eps=cfg.norm_eps, plus_one=False
    )
    k_rope = rope(dkv[..., cfg.kv_lora_rank :], positions, theta=cfg.rope_theta)
    return c_kv, k_rope


def _mla_expand(p: dict, c_kv: jax.Array, k_rope: jax.Array, cfg: ModelConfig):
    """Expand compressed cache chunk to per-head K (nope+rope) and V."""
    kv = jnp.einsum("bcr,rhk->bchk", c_kv, p["wukv"])
    k_nope = kv[..., : cfg.qk_nope_head_dim]
    v = kv[..., cfg.qk_nope_head_dim :]
    kr = jnp.broadcast_to(
        k_rope[:, :, None, :],
        k_nope.shape[:3] + (cfg.qk_rope_head_dim,),
    )
    k = jnp.concatenate([k_nope, kr], axis=-1)
    return k, v


def attn_forward(
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    cfg: ModelConfig,
    *,
    local: bool,
    make_cache: bool = False,
):
    """Full-sequence attention (training / prefill). Returns (out, cache|None)."""
    b, s, _ = x.shape
    window = cfg.sliding_window if local else None
    chunk = _pick_chunk(s)
    n_chunks = s // chunk

    if cfg.attn_kind == "mla":
        # ABSORBED form (beyond-paper optimization, EXPERIMENTS.md §Perf):
        # scores q_nope·(W_uk c) == (q_nope W_uk)·c, so MLA becomes MQA over
        # the compressed cache — one 1-head K of dim (kv_lora + rope), V = c.
        # Eliminates the per-chunk (S, H, dqk+dv) K/V expansion entirely and
        # makes the attention flash-kernel-eligible.
        q = _mla_q(p, x, positions, cfg)                     # (b,s,h,nope+rope)
        c_kv, k_rope = _mla_ckv(p, x, positions, cfg)
        w_uk = p["wukv"][..., : cfg.qk_nope_head_dim]        # (r, h, nope)
        w_uv = p["wukv"][..., cfg.qk_nope_head_dim :]        # (r, h, v)
        q_abs = jnp.einsum("bshn,rhn->bshr", q[..., : cfg.qk_nope_head_dim], w_uk)
        q_full = jnp.concatenate([q_abs, q[..., cfg.qk_nope_head_dim :]], axis=-1)
        k_full = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]
        v_c = c_kv[:, :, None, :]                            # (b,s,1,r)
        scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5

        if _pallas_flash_enabled() and s % 256 == 0:
            out_c = _flash_attn_op(
                jnp.swapaxes(q_full, 1, 2), jnp.swapaxes(k_full, 1, 2),
                jnp.swapaxes(v_c, 1, 2), scale, window,
            )
            out_c = jnp.swapaxes(out_c, 1, 2)
        else:
            def kv_fn(c):
                sl = lambda a: lax.dynamic_slice_in_dim(a, c * chunk, chunk, axis=1)
                return sl(k_full), sl(v_c), sl(positions)

            out_c = flash_attention(
                q_full, kv_fn, n_chunks,
                q_positions=positions, n_kv_heads=1,
                window=window, scale=scale, dv=cfg.kv_lora_rank,
            )
        out = jnp.einsum("bshr,rhv->bshv", out_c, w_uv)      # absorbed V proj
        cache = {"c_kv": c_kv, "k_rope": k_rope} if make_cache else None
    else:
        q, k, v = _gqa_qkv(p, x, positions, cfg)
        scale = cfg.head_dim**-0.5

        if _pallas_flash_enabled() and s % 256 == 0 and cfg.head_dim % 64 == 0:
            # fused-kernel path: scores never touch HBM (kernels/flash_attention)
            out = _flash_attn_op(
                jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                jnp.swapaxes(v, 1, 2), scale, window,
            )
            out = jnp.swapaxes(out, 1, 2)
        else:
            def kv_fn(c):
                sl = lambda a: lax.dynamic_slice_in_dim(a, c * chunk, chunk, axis=1)
                return sl(k), sl(v), sl(positions)

            out = flash_attention(
                q, kv_fn, n_chunks,
                q_positions=positions, n_kv_heads=cfg.n_kv_heads,
                window=window, scale=scale, dv=cfg.head_dim,
            )
        cache = {"k": k, "v": v} if make_cache else None

    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache


# --------------------------------------------------------------------------
# decode (single token, cached KV; cache seq axis may be sharded)
# --------------------------------------------------------------------------
def init_attn_cache(cfg: ModelConfig, batch: int, capacity: int, dtype):
    """Zeroed cache pytree for one attention layer."""
    if cfg.attn_kind == "mla":
        return {
            "c_kv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, capacity, cfg.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, capacity, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


def attn_decode(
    p: dict,
    x: jax.Array,            # (B, 1, d)
    t: jax.Array,            # scalar int32 — current position
    cache: dict,
    cfg: ModelConfig,
    *,
    local: bool,
    seq_axes=None,           # mesh axes the cache seq dim is sharded over
    vary_axes=None,          # all shard_map axes the carry varies over
):
    """One decode step inside shard_map (seq_axes) or plain jit (None).

    Writes the new token's KV into the cache slot ``t`` (which lives on
    exactly one seq shard), attends over valid positions <= t with the
    sequence-parallel partial-softmax combine, and returns (out, cache).
    """
    b = x.shape[0]
    pos = jnp.full((b, 1), t, jnp.int32)

    if seq_axes:
        shard_id = lax.axis_index(seq_axes)
    else:
        shard_id = 0

    if cfg.attn_kind == "mla":
        q = _mla_q(p, x, pos, cfg)
        c_kv_new, k_rope_new = _mla_ckv(p, x, pos, cfg)
        local_cap = cache["c_kv"].shape[1]
        offset = shard_id * local_cap
        li = jnp.clip(t - offset, 0, local_cap - 1)
        in_shard = (t >= offset) & (t < offset + local_cap)

        def write(buf, new):
            upd = lax.dynamic_update_slice_in_dim(buf, new.astype(buf.dtype), li, 1)
            return jnp.where(in_shard, upd, buf)

        cache = {
            "c_kv": write(cache["c_kv"], c_kv_new),
            "k_rope": write(cache["k_rope"], k_rope_new),
        }
        kv_pos_all = offset + jnp.arange(local_cap, dtype=jnp.int32)
        chunk = _pick_chunk(local_cap, 2048)
        n_chunks = local_cap // chunk
        scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
        dv = cfg.v_head_dim
        n_kv = cfg.n_heads

        def kv_fn(c):
            sl = lambda a: lax.dynamic_slice_in_dim(a, c * chunk, chunk, axis=1)
            k, v = _mla_expand(p, sl(cache["c_kv"]), sl(cache["k_rope"]), cfg)
            kp = lax.dynamic_slice_in_dim(kv_pos_all, c * chunk, chunk, axis=0)
            return k, v, jnp.broadcast_to(kp[None], (b, chunk))
    else:
        q, k_new, v_new = _gqa_qkv(p, x, pos, cfg)
        local_cap = cache["k"].shape[1]
        offset = shard_id * local_cap
        li = jnp.clip(t - offset, 0, local_cap - 1)
        in_shard = (t >= offset) & (t < offset + local_cap)

        def write(buf, new):
            upd = lax.dynamic_update_slice_in_dim(buf, new.astype(buf.dtype), li, 1)
            return jnp.where(in_shard, upd, buf)

        cache = {"k": write(cache["k"], k_new), "v": write(cache["v"], v_new)}
        kv_pos_all = offset + jnp.arange(local_cap, dtype=jnp.int32)
        chunk = _pick_chunk(local_cap, 2048)
        n_chunks = local_cap // chunk
        scale = cfg.head_dim**-0.5
        dv = cfg.head_dim
        n_kv = cfg.n_kv_heads

        def kv_fn(c):
            sl = lambda a: lax.dynamic_slice_in_dim(a, c * chunk, chunk, axis=1)
            kp = lax.dynamic_slice_in_dim(kv_pos_all, c * chunk, chunk, axis=0)
            return sl(cache["k"]), sl(cache["v"]), jnp.broadcast_to(kp[None], (b, chunk))

    window = cfg.sliding_window if local else None

    # local partial attention (unnormalized o, running m and l)
    h = q.shape[2]
    g = h // n_kv
    qf = q.astype(jnp.float32) * scale
    q5 = qf.reshape(b, 1, n_kv, g, q.shape[-1])

    def body(carry: _Carry, c):
        k, v, kv_pos = kv_fn(c)
        s = jnp.einsum("bskgd,bckd->bskgc", q5, k.astype(jnp.float32))
        mask = kv_pos[:, None, None, None, :] <= pos[:, :, None, None, None]
        if window is not None:
            mask &= kv_pos[:, None, None, None, :] > pos[:, :, None, None, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(carry.m, jnp.max(s, axis=-1).reshape(b, 1, h))
        pmat = jnp.exp(s - m_new.reshape(b, 1, n_kv, g)[..., None])
        corr = jnp.exp(carry.m - m_new)
        l_new = carry.l * corr + jnp.sum(pmat, axis=-1).reshape(b, 1, h)
        pv = jnp.einsum("bskgc,bckd->bskgd", pmat, v.astype(jnp.float32))
        o_new = carry.o * corr[..., None] + pv.reshape(b, 1, h, dv)
        return _Carry(o_new, m_new, l_new), None

    init = _Carry(
        o=jnp.zeros((b, 1, h, dv), jnp.float32),
        m=jnp.full((b, 1, h), NEG_INF, jnp.float32),
        l=jnp.zeros((b, 1, h), jnp.float32),
    )
    if vary_axes:
        # inside shard_map the body output varies across shards; the zero
        # init must be marked varying too (scan carry type invariant)
        init = jax.tree.map(
            lambda a: pcast_varying(a, tuple(vary_axes)), init
        )
    carry, _ = lax.scan(body, init, jnp.arange(n_chunks))

    if seq_axes:
        # sequence-parallel flash-decode combine: one pmax + two psums
        m_g = lax.pmax(carry.m, seq_axes)
        corr = jnp.exp(carry.m - m_g)
        l_g = lax.psum(carry.l * corr, seq_axes)
        o_g = lax.psum(carry.o * corr[..., None], seq_axes)
    else:
        l_g, o_g = carry.l, carry.o

    out = (o_g / jnp.maximum(l_g, 1e-37)[..., None]).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache
