#!/usr/bin/env python
"""Gate iterations-to-tolerance against the previous PR's BENCH json.

Usage:
    python scripts/compare_bench.py BENCH_pr2.json BENCH_pr3.json [--slack N]

Compares the ``precond_records`` of two ``benchmarks.run --json`` summaries
on the (N, lam, kind, dtype) cases they share and fails (exit 1) if any
case in the new json needs more than ``slack`` extra CG iterations to reach
tolerance — the preconditioner-quality axis of the FOM must never regress.
Records without a ``dtype`` field (jsons predating the mixed-precision
sweep, e.g. BENCH_pr3.json) are treated as "fp64", so shared-case matching
stays stable across that schema growth; mixed rows enter the gate the first
time they appear.  New kinds (ladder growth) and removed cases are reported
but never fail; wall-clock and GFLOPS are machine-dependent and
intentionally ignored.
"""
from __future__ import annotations

import argparse
import json
import sys


def load_records(path: str) -> dict[tuple, int]:
    with open(path) as f:
        summary = json.load(f)
    recs = summary.get("precond_records", [])
    if not recs:
        raise SystemExit(f"{path}: no precond_records section")
    return {
        (r["n"], r["lam"], r["kind"], r.get("dtype", "fp64")): int(
            r["iters_to_tol"]
        )
        for r in recs
    }


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="previous PR's BENCH json")
    ap.add_argument("candidate", help="this PR's BENCH json")
    ap.add_argument(
        "--slack",
        type=int,
        default=0,
        help="allowed extra iterations per case (default 0)",
    )
    args = ap.parse_args()

    base = load_records(args.baseline)
    cand = load_records(args.candidate)
    shared = sorted(set(base) & set(cand))
    new = sorted(set(cand) - set(base))
    gone = sorted(set(base) - set(cand))

    failures = []
    for key in shared:
        n, lam, kind, dtype = key
        delta = cand[key] - base[key]
        marker = "REGRESSION" if delta > args.slack else "ok"
        print(
            f"{marker:>10}  N={n} lam={lam} {kind:>14} [{dtype}]: "
            f"{base[key]} -> {cand[key]} ({delta:+d})"
        )
        if delta > args.slack:
            failures.append(key)
    for key in new:
        n, lam, kind, dtype = key
        print(f"{'new':>10}  N={n} lam={lam} {kind:>14} [{dtype}]: {cand[key]}")
    for key in gone:
        n, lam, kind, dtype = key
        print(f"{'removed':>10}  N={n} lam={lam} {kind:>14} [{dtype}]")

    if not shared:
        print("error: no shared (N, lam, kind) cases to compare")
        return 1
    if failures:
        print(
            f"\n{len(failures)} iterations-to-tol regression(s) vs "
            f"{args.baseline}"
        )
        return 1
    print(f"\nall {len(shared)} shared cases within slack={args.slack}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
