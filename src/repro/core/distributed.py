"""Distributed hipBone: the screened Poisson operator over a device mesh.

The global element grid is block-partitioned over a 3-D process grid mapped
onto the (flattened) device mesh — each rank owns a box of elements plus a
*padded, consistent* assembled-DOF box (interface points replicated across
sharing ranks, every replica holding the true value). See DESIGN.md §5.

Operator application follows the paper's Fig. 2 communication-hiding split:

    scatter (local)                     u_L = x_box[l2g]
    halo elements first                 y_h = (S_L + λW) u_L[:Eh]
    local gather of halo contributions  box_h = Z_loc^T y_h
    ── sum_exchange(box_h) ──╮          (async collective...)
    interior elements        │          y_i = (S_L + λW) u_L[Eh:]   ...overlaps
    local gather             │          box_i = Z_loc^T y_i          this compute
    ─────────────────────────╯
    combine                             A x = exchanged(box_h) + box_i

Interior elements touch no rank-boundary points, so their contributions
commute with the exchange — that is exactly why the split hides the
communication. Because the padded storage keeps replicas consistent, one
sum-exchange does the work of hipBone's two phases (halo + gather); the
paper-faithful two-phase dataflow is available as ``two_phase=True`` for
comparison.

Inner products mask out replica slots (each interface DOF counted once),
then ``psum`` — the assembled-storage analogue of the paper's observation
that hipBone needs no weighted inner products.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from ..comms import plan as xplan
from ..comms.halo import (
    contract_exchange,
    copy_exchange,
    expand_exchange,
    sum_exchange,
)
from ..comms.topology import ProcessGrid
from ..compat import shard_map
from . import sem
from .coefficients import coefficient_fields
from .mesh import normalize_bc
from .cg import (
    CG_VARIANTS,
    DIVERGENCE_FACTOR,
    STAGNATION_RTOL,
    STAGNATION_WINDOW,
    _pcg,
)
from .galerkin import block_matvec_einsum, galerkin_ladder_blocks
from .geometry import geometric_factors_from_coords
from .operator import COARSE_K_FLOOR, local_poisson
from .precond import (
    CHEB_LMIN_SAFETY,
    CHEB_SAFETY,
    PMG_SMOOTHERS,
    PRECOND_KINDS,
    SCHWARZ_INNER_DEGREE,
    cast_apply,
    chebyshev_apply,
    chebyshev_apply_deferred,
    jacobi_apply,
    lanczos_extremes,
    local_operator_diagonal,
    make_vcycle,
    make_vcycle_overlapped,
    pmg_degree_ladder,
    pmg_smooth_degree_default,
    power_lambda_max,
    seed_values,
    smoother_interval,
    tensor3_interp,
)
from .schwarz import (
    SchwarzFDM,
    build_fdm,
    element_lengths,
    element_neighbor_flags,
    fdm_solve,
    overlap_counts_1d,
)

__all__ = [
    "DistPoisson",
    "build_dist_problem",
    "build_pmg_levels",
    "build_pmg_galerkin_blocks",
    "dist_cg",
    "dist_cg_scattered",
    "dist_lambda_max",
    "dist_spectrum",
]

# dist_cg's supported coarse-operator constructions: the chained "galerkin"
# stays single-device (its recursive fine applies would serialize the whole
# transfer chain through every rank); the materialized "galerkin_mat" is the
# sharded-capable form — per-rank blocks, standard sum-exchange at apply.
PMG_COARSE_OPS_DIST = ("redisc", "galerkin_mat")

# (routing, wire_dtype) pair threaded from the ExchangePlan into each halo
# primitive call; the default is the historical per-dim face sweep at the
# native wire
_XCH = ("face_sweep", None)


@dataclasses.dataclass(frozen=True)
class DistPoisson:
    """Sharded screened-Poisson problem state.

    Static (identical on every rank): l2g, halo_elems, d, lam, box_shape,
    grid. Sharded data (leading axis = ranks): g, w_local, mask, and the
    solution/rhs vectors (P, m3).
    """

    grid: ProcessGrid
    axis_name: Any               # mesh axis name (or tuple) the ranks live on
    n_degree: int
    local_shape: tuple[int, int, int]    # elements per rank (bx, by, bz)
    box_shape: tuple[int, int, int]      # padded DOF box (bx*N+1, ...)
    lam: float
    halo_elems: int              # elements [0:Eh] touch the rank boundary
    l2g: np.ndarray              # (E_loc, p) int32, same on all ranks
    d: jax.Array                 # (n1, n1)
    g: jax.Array                 # (R, E_loc, 6, p) sharded
    w_local: jax.Array           # (R, E_loc, p) sharded — global inverse degree
    mask: jax.Array              # (R, m3) sharded — 1 where rank owns the DOF
    dtype: Any
    # (R, E_loc, p, 3) numpy node coords in halo-first element order, kept so
    # p-multigrid can rediscretize coarse levels on the same curved geometry;
    # None for the regular unit-box mesh (coarse factors are then analytic)
    coords: np.ndarray | None = None
    regular: bool = True         # True iff built from the default regular mesh
    # variable-coefficient state.  k / lam_field are (R, E_loc, p) numpy
    # setup copies in the same halo-first element order (p-multigrid
    # resamples them per coarse level; Schwarz takes element means); k is
    # already folded into ``g`` at build time.  ``screen`` is the sharded
    # runtime stream JW·λ(x) that replaces ``(w_local, lam)`` in every
    # A-apply when present — the weak mass screen with the kernels' static
    # ``lam`` pinned to 1.0, mirroring ``core.operator.screen_stream``.
    # ``bc_mask`` is the sharded replica-consistent 0/1 Dirichlet mask over
    # padded-box slots (None when no face is Dirichlet).
    k: np.ndarray | None = None
    lam_field: np.ndarray | None = None
    screen: jax.Array | None = None
    bc: tuple | None = None
    bc_mask: jax.Array | None = None

    @property
    def m3(self) -> int:
        return int(np.prod(self.box_shape))

    @property
    def e_local(self) -> int:
        return int(np.prod(self.local_shape))

    @property
    def n_global(self) -> int:
        n = self.n_degree
        gx = self.grid.shape[0] * self.local_shape[0] * n + 1
        gy = self.grid.shape[1] * self.local_shape[1] * n + 1
        gz = self.grid.shape[2] * self.local_shape[2] * n + 1
        return gx * gy * gz


def _local_node_offsets(n: int, pad: int = 0) -> tuple[np.ndarray, ...]:
    """Flattened (t, s, r)-ordered local node offsets [-pad, n + pad]."""
    a = np.arange(-pad, n + pad + 1)
    la, lb, lc = np.meshgrid(a, a, a, indexing="ij")
    return (
        la.transpose(2, 1, 0).reshape(-1),
        lb.transpose(2, 1, 0).reshape(-1),
        lc.transpose(2, 1, 0).reshape(-1),
    )


def _ordered_elements(local_shape: tuple[int, int, int]) -> tuple[np.ndarray, int]:
    """Halo-first local element coordinates: (E_loc, 3) int array + halo count.

    Elements on any face of the rank's local box come first — their
    operator contributions feed the halo exchange, and their Schwarz blocks
    are the only ones reading the expanded-box shells, so the same ordering
    drives both communication-hiding splits.
    """
    bx, by, bz = local_shape
    elems = [
        (i, j, k) for k in range(bz) for j in range(by) for i in range(bx)
    ]
    halo = [
        e
        for e in elems
        if e[0] in (0, bx - 1) or e[1] in (0, by - 1) or e[2] in (0, bz - 1)
    ]
    halo_set = set(halo)
    interior = [e for e in elems if e not in halo_set]
    return np.array(halo + interior, dtype=np.int64), len(halo)


def _local_l2g(n: int, local_shape: tuple[int, int, int]) -> tuple[np.ndarray, int]:
    """Halo-first element ordering + local node -> padded-box flat map."""
    bx, by, bz = local_shape
    mx, my = bx * n + 1, by * n + 1
    loc_a, loc_b, loc_c = _local_node_offsets(n)
    ordered, n_halo = _ordered_elements(local_shape)

    gx = ordered[:, 0, None] * n + loc_a[None, :]
    gy = ordered[:, 1, None] * n + loc_b[None, :]
    gz = ordered[:, 2, None] * n + loc_c[None, :]
    return (gx + mx * (gy + my * gz)).astype(np.int32), n_halo


def _rank_data(
    grid: ProcessGrid,
    n: int,
    local_shape: tuple[int, int, int],
    l2g: np.ndarray,
) -> tuple[np.ndarray, np.ndarray]:
    """Per-rank (mask, w_local) arrays, stacked over ranks (numpy)."""
    bx, by, bz = local_shape
    px, py, pz = grid.shape
    mx, my, mz = bx * n + 1, by * n + 1, bz * n + 1
    gx_n, gy_n, gz_n = px * bx * n, py * by * n, pz * bz * n  # global max index

    def axis_count(g: np.ndarray, gmax: int) -> np.ndarray:
        """Number of elements sharing a global grid line index."""
        return np.where((g % n == 0) & (g > 0) & (g < gmax), 2, 1)

    masks, ws = [], []
    x = np.arange(mx)
    y = np.arange(my)
    z = np.arange(mz)
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        gx = ci * bx * n + x
        gy = cj * by * n + y
        gz = ck * bz * n + z
        # ownership: not on a low face that has a -neighbor
        own_x = (x > 0) | (ci == 0)
        own_y = (y > 0) | (cj == 0)
        own_z = (z > 0) | (ck == 0)
        mask = (
            own_x[:, None, None] & own_y[None, :, None] & own_z[None, None, :]
        )
        # mask grid is (x, y, z) but flat box index is x + mx*(y + my*z)
        mask_flat = mask.transpose(2, 1, 0).reshape(-1)  # z slow -> matches
        cx = axis_count(gx, gx_n)
        cy = axis_count(gy, gy_n)
        cz = axis_count(gz, gz_n)
        count = (
            cx[:, None, None] * cy[None, :, None] * cz[None, None, :]
        ).transpose(2, 1, 0).reshape(-1)
        w_box = 1.0 / count
        ws.append(w_box[l2g])          # scatter to element-local layout
        masks.append(mask_flat.astype(np.float64))
    return np.stack(masks), np.stack(ws)


def _regular_box_coords(
    grid: ProcessGrid, n: int, local_shape: tuple[int, int, int]
) -> np.ndarray:
    """(R, E_loc, p, 3) node coords of the regular unit-box global mesh.

    Evaluates the *same* per-axis node formula as ``mesh.build_box_mesh``
    on the global element grid, then gathers each rank's halo-first
    elements — so coefficient fields sampled here are bitwise identical to
    the single-device mesh's, which is what the sharded-vs-single
    iteration-parity tests rely on.
    """
    gll, _ = sem.gll_nodes_weights(n)
    bx, by, bz = local_shape
    px, py, pz = grid.shape

    def axis_nodes(ne: int) -> np.ndarray:
        h = 1.0 / ne
        pos = np.empty(ne * n + 1)
        for e in range(ne):
            pos[e * n : (e + 1) * n + 1] = (e + (gll + 1.0) / 2.0) * h
        return pos

    pxn, pyn, pzn = axis_nodes(px * bx), axis_nodes(py * by), axis_nodes(pz * bz)
    ordered, _ = _ordered_elements(local_shape)
    loc_a, loc_b, loc_c = _local_node_offsets(n)
    e_loc = bx * by * bz
    out = np.empty((grid.size, e_loc, (n + 1) ** 3, 3))
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        gx = (ordered[:, 0] + ci * bx)[:, None] * n + loc_a[None, :]
        gy = (ordered[:, 1] + cj * by)[:, None] * n + loc_b[None, :]
        gz = (ordered[:, 2] + ck * bz)[:, None] * n + loc_c[None, :]
        out[r] = np.stack([pxn[gx], pyn[gy], pzn[gz]], axis=-1)
    return out


def _box_dirichlet_mask(
    grid: ProcessGrid,
    n: int,
    local_shape: tuple[int, int, int],
    tags: tuple[str, ...] | None,
) -> np.ndarray | None:
    """(R, m3) 0/1 Dirichlet mask over padded-box slots, or None.

    The sharded twin of ``mesh.dirichlet_mask``: purely topological on the
    structured *global* node grid, so replica slots on different ranks get
    identical values by construction and mesh deformation does not move
    the mask.  Returns None when no face is Dirichlet (Neumann faces are
    natural in the weak form).
    """
    if tags is None or all(t == "neumann" for t in tags):
        return None
    bx, by, bz = local_shape
    px, py, pz = grid.shape
    mx, my, mz = bx * n + 1, by * n + 1, bz * n + 1
    gx_n, gy_n, gz_n = px * bx * n, py * by * n, pz * bz * n  # global max idx
    x, y, z = np.meshgrid(
        np.arange(mx), np.arange(my), np.arange(mz), indexing="ij"
    )
    out = np.empty((grid.size, mx * my * mz))
    for r in range(grid.size):
        ci, cj, ck = grid.coords(r)
        ix, iy, iz = ci * bx * n + x, cj * by * n + y, ck * bz * n + z
        keep = np.ones(x.shape, dtype=bool)
        for tag, sel in zip(
            tags,
            (ix == 0, ix == gx_n, iy == 0, iy == gy_n, iz == 0, iz == gz_n),
        ):
            if tag == "dirichlet":
                keep &= ~sel
        out[r] = keep.transpose(2, 1, 0).reshape(-1).astype(np.float64)
    return out


def build_dist_problem(
    n_degree: int,
    grid: ProcessGrid,
    local_shape: tuple[int, int, int],
    *,
    axis_name: Any = "ranks",
    lam: float = 1.0,
    dtype: Any = jnp.float32,
    g_factors: np.ndarray | None = None,
    coords: np.ndarray | None = None,
    coefficient: str | None = None,
    bc: Any = None,
    k: np.ndarray | None = None,
    lam_field: np.ndarray | None = None,
) -> DistPoisson:
    """Build the sharded screened-Poisson problem.

    Args:
      n_degree: SEM polynomial degree N.
      grid: (px, py, pz) process grid over the flattened device mesh.
      local_shape: (bx, by, bz) elements owned per rank.
      axis_name: mesh axis name the ranks live on.
      lam: screen parameter λ.
      dtype: runtime dtype of the sharded arrays.
      g_factors: optional (R, E_loc, 6, p) geometric factors in halo-first
        element order (tests pass factors extracted from a deformed global
        mesh); default is the regular unit-box mesh where every element is
        identical.
      coords: optional (R, E_loc, p, 3) node coordinates in the same
        halo-first element order — geometric factors are then computed
        here, and p-multigrid (``dist_cg(precond="pmg")``) can
        rediscretize its coarse levels on the same geometry (with bare
        ``g_factors`` there is no geometry to coarsen, so pmg requires
        either ``coords`` or the default regular mesh).  The Schwarz
        preconditioner also reads ``coords`` for its per-element
        directional lengths (regular meshes use the analytic spacing).
      coefficient: named coefficient family (``core.coefficients``) —
        evaluates k(x) / λ(x) on this mesh's node coordinates (regular
        meshes synthesize them analytically); ``"const"``/``None`` is the
        legacy constant-λ problem, bit-identical code paths.
      bc: boundary-condition spec (``mesh.normalize_bc`` forms) — Dirichlet
        faces produce the replica-consistent ``bc_mask``; Neumann faces
        are natural and need no treatment.
      k / lam_field: explicit (R, E_loc, p) per-quadrature-point fields in
        halo-first element order (p-multigrid passes resampled coarse
        fields; tests pass fields partitioned from a single-device
        problem).  Mutually exclusive with ``coefficient``.  k is folded
        multiplicatively into the packed geometric factors here — kernels
        never see it; λ(x) switches every A-apply to the weak mass screen
        ``JW·λ`` riding the w stream (``DistPoisson.screen``), which needs
        node coordinates (or the regular mesh) for the JW weights.

    Returns:
      A :class:`DistPoisson`; per-rank padded box shape is
      ``(bx·N+1, by·N+1, bz·N+1)`` with interface replicas.
    """
    n = n_degree
    bx, by, bz = local_shape
    l2g, halo = _local_l2g(n, local_shape)
    mask, w_local = _rank_data(grid, n, local_shape, l2g)

    e_loc = bx * by * bz
    p = (n + 1) ** 3
    regular = g_factors is None and coords is None
    jw = None
    if coords is not None:
        geo = geometric_factors_from_coords(
            coords.reshape(grid.size * e_loc, p, 3), n
        )
        jw = geo["JW"].reshape(grid.size, e_loc, p)
        if g_factors is None:
            g_factors = geo["G"].reshape(grid.size, e_loc, 6, p)
    if g_factors is None:
        # regular mesh: every element congruent; element size = 1/(P_d*b_d)
        from .geometry import geometric_factors
        from .mesh import build_box_mesh

        ref_mesh = build_box_mesh(
            n,
            (1, 1, 1),
            extent=(
                1.0 / (grid.shape[0] * bx),
                1.0 / (grid.shape[1] * by),
                1.0 / (grid.shape[2] * bz),
            ),
        )
        geo_one = geometric_factors(ref_mesh)
        g_one = geo_one["G"][0]  # (6, p)
        g_factors = np.broadcast_to(
            g_one, (grid.size, e_loc, 6, g_one.shape[-1])
        )
        jw = np.broadcast_to(geo_one["JW"][0], (grid.size, e_loc, p))

    if coefficient is not None:
        if k is not None or lam_field is not None:
            raise ValueError(
                "pass either coefficient= or explicit k/lam_field, not both"
            )
        node_coords = coords
        if node_coords is None:
            if not regular:
                raise ValueError(
                    "coefficient evaluation needs node coordinates; pass "
                    "coords= alongside bare g_factors"
                )
            node_coords = _regular_box_coords(grid, n, local_shape)
        k, lam_field = coefficient_fields(
            coefficient, node_coords.reshape(grid.size * e_loc, p, 3), lam
        )
        if k is not None:
            k = k.reshape(grid.size, e_loc, p)
        if lam_field is not None:
            lam_field = lam_field.reshape(grid.size, e_loc, p)

    if k is not None:
        k = np.asarray(k, np.float64)
        if k.shape != (grid.size, e_loc, p):
            raise ValueError(
                f"k must have shape {(grid.size, e_loc, p)}, got {k.shape}"
            )
        # fold k into the packed factors: DᵀGD then discretizes -∇·(k∇·)
        g_factors = np.asarray(g_factors) * k[:, :, None, :]
    screen = None
    if lam_field is not None:
        lam_field = np.asarray(lam_field, np.float64)
        if lam_field.shape != (grid.size, e_loc, p):
            raise ValueError(
                f"lam_field must have shape {(grid.size, e_loc, p)}, "
                f"got {lam_field.shape}"
            )
        if jw is None:
            raise ValueError(
                "lam_field needs node coordinates (or the regular mesh) to "
                "form the JW mass weights of the weak screen; pass coords="
            )
        screen = jnp.asarray(np.asarray(jw) * lam_field, dtype)

    tags = normalize_bc(bc)
    bc_mask = _box_dirichlet_mask(grid, n, local_shape, tags)

    d = sem.derivative_matrix(n)
    return DistPoisson(
        grid=grid,
        axis_name=axis_name,
        n_degree=n,
        local_shape=local_shape,
        box_shape=(bx * n + 1, by * n + 1, bz * n + 1),
        lam=float(lam),
        halo_elems=halo,
        l2g=l2g,
        d=jnp.asarray(d, dtype),
        g=jnp.asarray(g_factors, dtype),
        w_local=jnp.asarray(w_local, dtype),
        mask=jnp.asarray(mask, dtype),
        dtype=dtype,
        coords=coords,
        regular=regular,
        k=k,
        lam_field=lam_field,
        screen=screen,
        bc=tags,
        bc_mask=(
            None if bc_mask is None else jnp.asarray(bc_mask, dtype)
        ),
    )


def build_pmg_levels(
    prob: DistPoisson, ladder: tuple[int, ...] | None = None
) -> tuple[list[DistPoisson], list[np.ndarray]]:
    """The p-multigrid hierarchy for a sharded problem.

    Args:
      prob: the fine-level :class:`DistPoisson`.
      ladder: explicit degree ladder; default ``pmg_degree_ladder`` halving.

    Returns ``(levels, jmats)``: ``levels[0] is prob`` and each coarser
    level is a full DistPoisson on the *same* process grid and element
    partition (so every level's operator reuses the Fig. 2
    communication-hiding split on its own, smaller padded box);
    ``jmats[i]`` is the 1-D coarse->fine interpolation between levels
    i+1 and i.  Coarse geometric factors are rediscretized from sampled
    coordinates (curved meshes) or the analytic regular-box reference.
    """
    degrees = tuple(ladder) if ladder is not None else pmg_degree_ladder(
        prob.n_degree
    )
    if not prob.regular and prob.coords is None:
        raise ValueError(
            "pmg on a sharded problem needs per-rank coords (or the default "
            "regular mesh) to rediscretize coarse levels; rebuild with "
            "build_dist_problem(..., coords=...)"
        )
    levels = [prob]
    jmats: list[np.ndarray] = []
    for nc in degrees[1:]:
        pf = levels[-1]
        coords_c = None
        if pf.coords is not None:
            jc = sem.interpolation_matrix(pf.n_degree, nc)
            r, e_loc, p, _ = pf.coords.shape
            coords_c = sem.interp_coords_3d(
                jc, pf.coords.reshape(r * e_loc, p, 3)
            ).reshape(r, e_loc, (nc + 1) ** 3, 3)
        # coefficient fields ride down by the same tensor interpolation as
        # the coordinates, with the same fixed positivity floors as the
        # single-device ``operator.coarsen_problem`` — value-for-value
        # identical resampling rank by rank
        k_c = lam_c = None
        if pf.k is not None or pf.lam_field is not None:
            jf = sem.interpolation_matrix(pf.n_degree, nc)
            r, e_loc = prob.grid.size, pf.e_local
            if pf.k is not None:
                k_c = np.maximum(
                    sem.interp_field_3d(
                        jf, np.asarray(pf.k, np.float64).reshape(r * e_loc, -1)
                    ),
                    COARSE_K_FLOOR,
                ).reshape(r, e_loc, -1)
            if pf.lam_field is not None:
                lam_c = np.maximum(
                    sem.interp_field_3d(
                        jf,
                        np.asarray(pf.lam_field, np.float64).reshape(
                            r * e_loc, -1
                        ),
                    ),
                    0.0,
                ).reshape(r, e_loc, -1)
        levels.append(
            build_dist_problem(
                nc,
                prob.grid,
                prob.local_shape,
                axis_name=prob.axis_name,
                lam=prob.lam,
                dtype=prob.dtype,
                coords=coords_c,
                k=k_c,
                lam_field=lam_c,
                bc=pf.bc,
            )
        )
        jmats.append(sem.interpolation_matrix(nc, pf.n_degree))
    return levels, jmats


def build_pmg_galerkin_blocks(
    prob: DistPoisson, levels: list[DistPoisson]
) -> list[jax.Array]:
    """Per-rank materialized Galerkin blocks for every coarse pMG level.

    The sharded face of ``core.galerkin``: each dense element block
    ``Ĵᵀ(S_L^e + λW_e)Ĵ`` reads only the owning rank's geometric factors
    and inverse-degree weights — and ``w_local`` already carries the
    *global* inverse degree (cross-rank sharing accounted for at
    ``_rank_data`` time) — so assembly of the owned coarse elements is
    embarrassingly rank-local on the padded box: **no setup exchange**.
    Apply time then needs only the standard sum-exchange of halo-element
    contributions (``_box_galerkin_apply``), identical in shape to any
    rediscretized level's.

    Fields are cast to ``prob.dtype`` first, so a mixed-precision caller
    (``dist_cg(precond_dtype=jnp.float32)`` passes its cast problem view)
    assembles the blocks once in fp32 behind the usual cast boundary.

    Args:
      prob: the fine-level :class:`DistPoisson` (or its cast view).
      levels: the ``build_pmg_levels`` hierarchy (``levels[0] is prob``).

    Returns:
      One ``(R, E_loc, p_c, p_c)`` sharded block stack per coarse level
      ``levels[1:]``.
    """
    r, e_loc = prob.g.shape[:2]
    degrees = tuple(lvl.n_degree for lvl in levels)
    # variable λ(x): the screen stream JW·λ replaces (w_local, λ) in the
    # element blocks — Ĵᵀ(S_L^e + diag(JW·λ))Ĵ — matching screen_stream
    w_src = prob.w_local if prob.screen is None else prob.screen
    lam_eff = prob.lam if prob.screen is None else 1.0

    def build(g: jax.Array, w: jax.Array) -> list[jax.Array]:
        g2 = g.astype(prob.dtype).reshape(r * e_loc, *g.shape[2:])
        w2 = w.astype(prob.dtype).reshape(r * e_loc, -1)
        blocks = galerkin_ladder_blocks(g2, prob.d, lam_eff, w2, degrees)
        return [b.reshape(r, e_loc, *b.shape[1:]) for b in blocks]

    if not isinstance(prob.g, jax.Array):
        # dry-run lowering passes abstract ShapeDtypeStruct shards; give the
        # compiled program matching abstract block operands
        return list(jax.eval_shape(build, prob.g, w_src))
    return build(prob.g, w_src)


def _box_galerkin_apply(
    prob: DistPoisson,
    blocks: jax.Array,
    *,
    two_phase: bool = False,
    xsum: tuple = _XCH,
    xcopy: tuple = _XCH,
) -> Callable[..., jax.Array]:
    """Materialized Galerkin coarse-level A-apply on consistent padded boxes.

    The Fig. 2 halo/interior split of ``_apply_assembled`` with the fused
    local kernel replaced by one batched dense element matvec: halo-element
    matvecs feed the sum-exchange first, interior-element matvecs overlap
    it, and zero fine-operator work happens per apply — the coarse level
    touches only its own (E_loc, p_c, p_c) blocks and its own box.
    ``two_phase`` mirrors ``_apply_assembled``'s paper-faithful explicit
    scatter-side halo refresh, so the comparison mode stays uniform across
    every level of the V-cycle.  ``xsum``/``xcopy`` are the exchange plan's
    (routing, wire) picks for this level's sum/copy sites.

    The returned apply takes an optional deferred twin ``x_raw`` (the box
    before its producing sum-exchange): interior blocks gather from it —
    raw interior slots are bitwise final — so their matvecs need not wait
    for the upstream exchange (cross-level V-cycle overlap).
    """
    eh = prob.halo_elems
    l2g_flat = jnp.asarray(prob.l2g.reshape(-1))
    m3 = prob.m3
    p = prob.l2g.shape[1]

    def apply(x_box: jax.Array, x_raw: jax.Array | None = None) -> jax.Array:
        if two_phase:
            x_box = copy_exchange(
                x_box.reshape(prob.box_shape[::-1]), prob.grid,
                prob.axis_name, xcopy[1], xcopy[0],
            ).reshape(-1)
            x_raw = None  # the refreshed box is the only valid source
        u_h = jnp.take(x_box, l2g_flat[: eh * p], axis=0).reshape(eh, p)
        y_h = block_matvec_einsum(blocks[:eh], u_h)
        box_h = jax.ops.segment_sum(
            y_h.reshape(-1), l2g_flat[: eh * p], num_segments=m3
        )
        box_h = sum_exchange(
            box_h.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
            xsum[1], xsum[0],
        ).reshape(-1)

        # interior blocks: no rank-boundary contact -> overlap the exchange
        # (and, given a raw twin, the upstream transfer exchange too)
        u_i = jnp.take(
            x_box if x_raw is None else x_raw, l2g_flat[eh * p :], axis=0
        ).reshape(prob.e_local - eh, p)
        y_i = block_matvec_einsum(blocks[eh:], u_i)
        box_i = jax.ops.segment_sum(
            y_i.reshape(-1), l2g_flat[eh * p :], num_segments=m3
        )
        return box_h + box_i

    return apply


def _apply_assembled(
    prob: DistPoisson,
    x_box: jax.Array,       # (m3,)
    g: jax.Array,           # (E_loc, 6, p)
    w: jax.Array,           # (E_loc, p)
    *,
    local_op: Callable[..., jax.Array],
    two_phase: bool,
    fused_interior: bool = False,
    xsum: tuple = _XCH,
    xcopy: tuple = _XCH,
    x_raw: jax.Array | None = None,
    screen: jax.Array | None = None,
) -> jax.Array:
    """One A-apply inside shard_map, with the Fig. 2 overlap split.

    ``screen``, when given, is the rank's (E_loc, p) weak mass screen
    JW·λ(x): it replaces ``w`` on the kernels' w stream with the static
    ``lam`` pinned to 1.0 (``core.operator.screen_stream``'s contract —
    kernel signatures unchanged, Pallas' static lam stays a python float).

    ``fused_interior`` replaces the interior block's three-stage pipeline
    (gather u, ``local_op``, segment_sum) with the single-pass Pallas
    kernel ``kernels.ops.poisson_assembled_fused`` over the rank-local
    padded box — the interior elements touch no rank boundary, so their
    gather source and scatter target are both the local box and the fused
    apply still overlaps the halo sum-exchange.  The halo block stays
    split: its scatter-add must be materialized before it can feed the
    exchange.

    ``xsum``/``xcopy`` carry the exchange plan's (routing, wire) picks for
    this site.  ``x_raw``, when given, is the deferred twin of ``x_box``
    (same box *before* its producing sum-exchange): interior gathers read
    it instead — bitwise identical, since the exchange only rewrites face
    slabs interior elements never touch — which releases the interior
    block from the upstream exchange's data dependence (cross-level
    V-cycle overlap).
    """
    eh = prob.halo_elems
    p = prob.l2g.shape[1]
    l2g_flat = jnp.asarray(prob.l2g.reshape(-1))
    m3 = prob.m3
    w_eff, lam_eff = (w, prob.lam) if screen is None else (screen, 1.0)

    if two_phase:
        # paper-faithful: explicit scatter-side halo refresh first
        x_box = copy_exchange(
            x_box.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
            xcopy[1], xcopy[0],
        ).reshape(-1)
        x_raw = None  # the refreshed box is the only valid source
    x_int = x_box if x_raw is None else x_raw

    # halo elements first; their contributions feed the exchange
    u_h = jnp.take(x_box, l2g_flat[: eh * p], axis=0).reshape(eh, p)
    y_h = local_op(u_h, g[:eh], prob.d, lam_eff, w_eff[:eh])
    box_h = jax.ops.segment_sum(
        y_h.reshape(-1), l2g_flat[: eh * p], num_segments=m3
    )
    box_h = sum_exchange(
        box_h.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
        xsum[1], xsum[0],
    ).reshape(-1)

    # interior elements: no boundary contact -> overlaps the exchange above
    if fused_interior:
        if prob.e_local > eh:
            from ..kernels import ops as _kops  # lazy: kernels import core

            box_i = _kops.poisson_assembled_fused(
                x_int,
                jnp.asarray(prob.l2g)[eh:],
                g[eh:],
                w_eff[eh:],
                prob.d,
                lam=lam_eff,
            )
        else:
            box_i = jnp.zeros_like(box_h)
    else:
        u_i = jnp.take(x_int, l2g_flat[eh * p :], axis=0).reshape(
            prob.e_local - eh, p
        )
        y_i = local_op(u_i, g[eh:], prob.d, lam_eff, w_eff[eh:])
        box_i = jax.ops.segment_sum(
            y_i.reshape(-1), l2g_flat[eh * p :], num_segments=m3
        )
    return box_h + box_i


def _box_global_indices(prob: DistPoisson) -> np.ndarray:
    """(R, m3) flat *global* DOF index of every padded-box slot (numpy).

    Replica slots on different ranks map to the same global index, so any
    function of this array is automatically replica-consistent.
    """
    n = prob.n_degree
    bx, by, bz = prob.local_shape
    mx, my, mz = prob.box_shape
    px, py, _ = prob.grid.shape
    gx_n, gy_n = px * bx * n + 1, py * by * n + 1
    x, y, z = np.meshgrid(
        np.arange(mx), np.arange(my), np.arange(mz), indexing="ij"
    )
    out = np.empty((prob.grid.size, prob.m3), np.int64)
    for r in range(prob.grid.size):
        ci, cj, ck = prob.grid.coords(r)
        gidx = (ci * bx * n + x) + gx_n * (
            (cj * by * n + y) + gy_n * (ck * bz * n + z)
        )
        out[r] = gidx.transpose(2, 1, 0).reshape(-1)
    return out


def _box_dinv(
    prob: DistPoisson,
    g1: jax.Array,
    w1: jax.Array,
    xsum: tuple = _XCH,
    screen: jax.Array | None = None,
) -> jax.Array:
    """Inverse assembled diagonal in consistent padded-box storage:
    Z_loc^T diag(S_L + λW) Z made consistent by one sum-exchange.
    ``screen`` swaps in the weak mass screen JW·λ(x) with lam pinned to
    1.0 (see ``_apply_assembled``); the diagonal itself stays unmasked —
    Dirichlet handling multiplies ``1/diag`` by the bc mask afterwards,
    mirroring ``precond.masked_dinv``."""
    w_eff, lam_eff = (w1, prob.lam) if screen is None else (screen, 1.0)
    dloc = local_operator_diagonal(g1, prob.d, lam_eff, w_eff)
    box_diag = jax.ops.segment_sum(
        dloc.reshape(-1),
        jnp.asarray(prob.l2g.reshape(-1)),
        num_segments=prob.m3,
    )
    box_diag = sum_exchange(
        box_diag.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
        xsum[1], xsum[0],
    ).reshape(-1)
    return 1.0 / box_diag


def _box_transfer_pair(
    lf: DistPoisson,
    lc: DistPoisson,
    jmat: jax.Array,
    w_lf: jax.Array,
    xsum_f: tuple = _XCH,
    xsum_c: tuple = _XCH,
):
    """(prolong, restrict) between two padded-box levels of one rank.

    Same P = Z_f^T W_f Ĵ Z_c / R = P^T pair as the single-shard
    ``precond.make_transfer_pair``, with the gathers expressed as local
    segment-sums plus one halo sum-exchange (interface contributions from
    neighbouring ranks complete the weighted average / the transpose sum).
    Inputs are consistent boxes; each output is the ``(raw, consistent)``
    pair — the locally summed box before and after its halo exchange.  The
    raw twin's interior slots are bitwise final (the exchange only
    rewrites face slabs), which is what the overlapped V-cycle hands to
    the next level's interior work; plain consumers just take ``[1]`` and
    the unused raw output folds away in tracing.  ``xsum_f``/``xsum_c``
    are the plan's picks for the fine/coarse sum sites.
    """
    l2g_f = jnp.asarray(lf.l2g.reshape(-1))
    l2g_c = jnp.asarray(lc.l2g.reshape(-1))

    def prolong(x_c: jax.Array) -> tuple[jax.Array, jax.Array]:
        u_c = jnp.take(x_c, l2g_c, axis=0).reshape(lc.e_local, -1)
        u_f = tensor3_interp(jmat, u_c)
        raw = jax.ops.segment_sum(
            (w_lf * u_f).reshape(-1), l2g_f, num_segments=lf.m3
        )
        con = sum_exchange(
            raw.reshape(lf.box_shape[::-1]), lf.grid, lf.axis_name,
            xsum_f[1], xsum_f[0],
        ).reshape(-1)
        return raw, con

    def restrict(r_f: jax.Array) -> tuple[jax.Array, jax.Array]:
        u_f = w_lf * jnp.take(r_f, l2g_f, axis=0).reshape(lf.e_local, -1)
        u_c = tensor3_interp(jmat.T, u_f)
        raw = jax.ops.segment_sum(
            u_c.reshape(-1), l2g_c, num_segments=lc.m3
        )
        con = sum_exchange(
            raw.reshape(lc.box_shape[::-1]), lc.grid, lc.axis_name,
            xsum_c[1], xsum_c[0],
        ).reshape(-1)
        return raw, con

    return prolong, restrict


@dataclasses.dataclass(frozen=True)
class _SchwarzDist:
    """Setup for the sharded overlapping-Schwarz apply on one level.

    Static (identical on all ranks): the extended local-to-box index maps,
    split halo-first like the operator — interior blocks read the original
    box only (their solves overlap the shell exchange in the XLA dataflow),
    halo blocks read the shell-expanded box.  Sharded (leading axis ranks):
    the per-element FDM factors (rank-boundary flags and deformed-element
    lengths differ per rank) and the partition-of-unity weights.
    """

    overlap: int
    eh: int                      # halo element count (blocks using shells)
    ext_shape: tuple[int, int, int]   # expanded box (mx+2s, my+2s, mz+2s)
    l2g_halo: np.ndarray         # (Eh, m^3) flat indices into expanded box
    l2g_int: np.ndarray          # (E-Eh, m^3) flat indices into original box
    fdm_fields: tuple[jax.Array, ...]   # stacked SchwarzFDM arrays (R, ...)
    wsqrt: jax.Array             # (R, m3) 1/sqrt(overlap counts)
    # float for the legacy algebraic screen; None when a per-element λ
    # array (element means of λ(x), mass-screen mode) rides fdm_fields[6]
    lam: float | None
    inner_degree: int

    def rank_fdm(self, fields: tuple[jax.Array, ...], sl: slice) -> SchwarzFDM:
        """Per-rank SchwarzFDM from shard-sliced field arrays."""
        tm, cm, di, mu, lo, hi = (f[sl] for f in fields[:6])
        lam = self.lam if self.lam is not None else fields[6][sl]
        return SchwarzFDM(
            tmats=tm, cmats=cm, denom_inv=di, musum=mu, inner_lo=lo,
            inner_hi=hi, lam=lam, overlap=self.overlap,
            inner_degree=self.inner_degree,
        )


def _schwarz_setup(
    prob: DistPoisson, overlap: int, inner_degree: int
) -> _SchwarzDist:
    """Numpy setup of the sharded Schwarz smoother for one level.

    Per-element FDM factors use the rank's node coordinates (or the
    analytic regular-mesh spacing) and *global* neighbor flags — a rank
    boundary is interior to the global element grid, so blocks there extend
    across it; only physical domain boundaries clamp.  The extended index
    maps shift every coordinate by the overlap so halo blocks address the
    shell-expanded box.
    """
    n = prob.n_degree
    s = int(overlap)
    if not 0 <= s <= n - 1:
        raise ValueError(f"overlap must be in [0, {n - 1}] for N={n}, got {s}")
    bx, by, bz = prob.local_shape
    px, py, pz = prob.grid.shape
    mx, my, mz = prob.box_shape
    ordered, eh = _ordered_elements(prob.local_shape)
    loc_a, loc_b, loc_c = _local_node_offsets(n, pad=s)

    # extended maps: halo blocks -> expanded box, interior -> original box
    ex_x = ordered[:, 0, None] * n + loc_a[None, :]
    ex_y = ordered[:, 1, None] * n + loc_b[None, :]
    ex_z = ordered[:, 2, None] * n + loc_c[None, :]
    mex, mey, mez = mx + 2 * s, my + 2 * s, mz + 2 * s
    l2g_halo = (
        (ex_x[:eh] + s) + mex * ((ex_y[:eh] + s) + mey * (ex_z[:eh] + s))
    ).astype(np.int32)
    l2g_int = (
        ex_x[eh:] + mx * (ex_y[eh:] + my * ex_z[eh:])
    ).astype(np.int32)

    gshape = (px * bx, py * by, pz * bz)   # global element grid
    regular_lengths = np.array(
        [1.0 / gshape[0], 1.0 / gshape[1], 1.0 / gshape[2]]
    )
    cx = overlap_counts_1d(gshape[0], n, s)
    cy = overlap_counts_1d(gshape[1], n, s)
    cz = overlap_counts_1d(gshape[2], n, s)

    # variable coefficients enter the blocks by per-element means, exactly
    # like the single-device ``schwarz.element_screen_means``: k scales the
    # stiffness eigenvalue sums; a λ(x) field switches the screen to the
    # in-basis-exact mass form with per-element λ riding a 7th field array
    k_means = (
        None if prob.k is None
        else np.asarray(prob.k, np.float64).mean(axis=2)
    )
    lam_means = (
        None if prob.lam_field is None
        else np.asarray(prob.lam_field, np.float64).mean(axis=2)
    )
    screen_mode = "algebraic" if lam_means is None else "mass"

    nfield = 6 if lam_means is None else 7
    fields: list[list[np.ndarray]] = [[] for _ in range(nfield)]
    wsqrt = np.empty((prob.grid.size, prob.m3))
    for r in range(prob.grid.size):
        ci, cj, ck = prob.grid.coords(r)
        eidx = ordered + np.array([ci * bx, cj * by, ck * bz])
        flags = element_neighbor_flags(eidx, gshape)
        if prob.coords is not None:
            lengths = element_lengths(prob.coords[r], n)
        else:
            lengths = np.broadcast_to(regular_lengths, (prob.e_local, 3))
        fdm = build_fdm(
            lengths, flags, n,
            prob.lam if lam_means is None else lam_means[r],
            s, prob.dtype,
            inner_degree=inner_degree,
            k_elem=None if k_means is None else k_means[r],
            screen=screen_mode,
        )
        per_rank = (fdm.tmats, fdm.cmats, fdm.denom_inv, fdm.musum,
                    fdm.inner_lo, fdm.inner_hi)
        if lam_means is not None:
            per_rank = per_rank + (fdm.lam,)
        for f, arr in zip(fields, per_rank):
            f.append(np.asarray(arr))
        counts = (
            cz[ck * bz * n : ck * bz * n + mz][:, None, None]
            * cy[cj * by * n : cj * by * n + my][None, :, None]
            * cx[ci * bx * n : ci * bx * n + mx][None, None, :]
        )
        wsqrt[r] = 1.0 / np.sqrt(counts.reshape(-1))

    return _SchwarzDist(
        overlap=s,
        eh=eh,
        ext_shape=(mex, mey, mez),
        l2g_halo=l2g_halo,
        l2g_int=l2g_int,
        fdm_fields=tuple(jnp.asarray(np.stack(f)) for f in fields),
        wsqrt=jnp.asarray(wsqrt, prob.dtype),
        lam=float(prob.lam) if lam_means is None else None,
        inner_degree=int(inner_degree),
    )


def _box_schwarz_apply(
    prob: DistPoisson,
    sd: _SchwarzDist,
    fdm_fields: tuple[jax.Array, ...],
    wsq: jax.Array,
    *,
    xsum: tuple = _XCH,
    xexpand: tuple = _XCH,
    xcontract: tuple = _XCH,
) -> Callable[[jax.Array], jax.Array]:
    """Per-rank Schwarz application on consistent padded boxes.

    The Fig. 2 split, Schwarz flavor: the shell expansion (ppermutes) is
    launched first, interior blocks solve from the *original* box with no
    data dependence on it (XLA overlaps them with the exchange), halo
    blocks then read the expanded box and their out-of-rank contributions
    ride the contract exchange home.  One final sum-exchange makes the
    interface replicas consistent, exactly like the operator's gather.
    """
    s = sd.overlap
    eh = sd.eh
    m3_ext = int(np.prod(sd.ext_shape))
    halo_flat = jnp.asarray(sd.l2g_halo.reshape(-1))
    int_flat = jnp.asarray(sd.l2g_int.reshape(-1))
    fdm = sd.rank_fdm(fdm_fields, slice(None))

    def sub(lo: int, hi: int | None) -> SchwarzFDM:
        return dataclasses.replace(
            fdm,
            tmats=fdm.tmats[lo:hi], cmats=fdm.cmats[lo:hi],
            denom_inv=fdm.denom_inv[lo:hi], musum=fdm.musum[lo:hi],
            inner_lo=fdm.inner_lo[lo:hi], inner_hi=fdm.inner_hi[lo:hi],
            # a per-element (E, 1, 1, 1) λ array must follow the block split
            lam=fdm.lam if isinstance(fdm.lam, float) else fdm.lam[lo:hi],
        )

    fdm_halo, fdm_int = sub(0, eh), sub(eh, None)

    def apply(r_box: jax.Array) -> jax.Array:
        rw = wsq * r_box
        # shell expansion first: halo-block inputs feed on the ppermutes
        ext = expand_exchange(
            rw.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name, s,
            xexpand[1], xexpand[0],
        ).reshape(-1)
        u_h = jnp.take(ext, halo_flat, axis=0).reshape(eh, -1)
        acc = jax.ops.segment_sum(
            fdm_solve(fdm_halo, u_h).reshape(-1),
            halo_flat,
            num_segments=m3_ext,
        )
        box = contract_exchange(
            acc.reshape(sd.ext_shape[::-1]), prob.grid, prob.axis_name, s,
            xcontract[1], xcontract[0],
        ).reshape(-1)
        # interior blocks: no shell contact -> overlap the exchanges above
        if eh < prob.e_local:
            u_i = jnp.take(rw, int_flat, axis=0).reshape(
                prob.e_local - eh, -1
            )
            box = box + jax.ops.segment_sum(
                fdm_solve(fdm_int, u_i).reshape(-1),
                int_flat,
                num_segments=prob.m3,
            )
        out = sum_exchange(
            box.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
            xsum[1], xsum[0],
        ).reshape(-1)
        return wsq * out

    return apply


def _exchange_sites(
    prob: DistPoisson,
    levels: list,
    schwarz_setups: list,
    *,
    two_phase: bool = False,
) -> list:
    """Enumerate every halo-exchange site of one dist_cg configuration.

    One ``sum``/``copy`` site per pMG level (level 0 carries the *outer*
    problem dtype — the dominant payload — even when the preconditioner
    chain is cast down), plus ``expand``/``contract`` shell sites for each
    Schwarz-smoothed level.  The tuner groups sites by (kind, box shape,
    dtype, depth), so equal-shaped levels share one measurement.
    """
    box0 = tuple(prob.box_shape[::-1])
    dt0 = jnp.dtype(prob.dtype).name
    sites = [
        xplan.ExchangeSite("sum", 0, box0, dt0),
        xplan.ExchangeSite("copy", 0, box0, dt0),
    ]
    for i, lvl in enumerate(levels[1:], start=1):
        box = tuple(lvl.box_shape[::-1])
        dt = jnp.dtype(lvl.dtype).name
        sites.append(xplan.ExchangeSite("sum", i, box, dt))
        if two_phase:
            sites.append(xplan.ExchangeSite("copy", i, box, dt))
    for i, sd in enumerate(schwarz_setups):
        lvl = levels[i]
        dt = jnp.dtype(lvl.dtype).name
        sites.append(
            xplan.ExchangeSite(
                "expand", i, tuple(lvl.box_shape[::-1]), dt, depth=sd.overlap
            )
        )
        sites.append(
            xplan.ExchangeSite(
                "contract", i, tuple(sd.ext_shape[::-1]), dt, depth=sd.overlap
            )
        )
    return sites


def dist_spectrum(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    *,
    lanczos_iters: int = 10,
    local_op: Callable[..., jax.Array] | None = None,
    two_phase: bool = False,
) -> tuple[float, float]:
    """Eager (λ_min, λ_max) Ritz estimates of D⁻¹A (raw, no safety factors).

    The sharded analogue of ``precond.lanczos_extremes``: replica-masked
    dots, psum across ranks.  Pass the results to
    ``dist_cg(..., lmin=..., lmax=...)`` so repeated Chebyshev solves don't
    re-run the estimation inside the compiled program.

    Returns:
      ``(lmin, lmax)`` python floats (the compiled estimate is pulled
      eagerly at setup time).
    """
    op = local_op or local_poisson
    spec = P(prob.axis_name)
    seed_boxes = jnp.asarray(seed_values(_box_global_indices(prob)), prob.dtype)
    if prob.bc_mask is not None:
        # Dirichlet: estimate on the interior subspace — masked seed, no
        # null-space pollution (mirrors precond.masked_seed)
        seed_boxes = seed_boxes * prob.bc_mask.astype(seed_boxes.dtype)
    aux = tuple(x for x in (prob.screen, prob.bc_mask) if x is not None)
    has_screen = prob.screen is not None
    has_bc = prob.bc_mask is not None

    def shard_fn(g_s, w_s, mask_s, seed_s, aux_s):
        g1, w1, m1 = g_s[0], w_s[0], mask_s[0]
        s1 = aux_s[0][0] if has_screen else None
        bcm1 = aux_s[1 if has_screen else 0][0] if has_bc else None
        base = lambda v: _apply_assembled(
            prob, v, g1, w1, local_op=op, two_phase=two_phase, screen=s1
        )
        operator = base if bcm1 is None else (
            lambda v: bcm1 * base(bcm1 * v)
        )
        dinv = _box_dinv(prob, g1, w1, screen=s1)
        if bcm1 is not None:
            dinv = bcm1 * dinv
        mdot = lambda a, bb: jnp.vdot(a * m1, bb)
        lmin, lmax = lanczos_extremes(
            operator, dinv, seed_s[0],
            iters=lanczos_iters, dot=mdot,
            psum=lambda v: lax.psum(v, prob.axis_name),
        )
        return lmin, lmax

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, tuple(spec for _ in aux)),
        out_specs=(P(), P()),
        # check_rep cannot type the mixed sharded/replicated Lanczos carry
        check_rep=False,
    )
    lmin, lmax = jax.jit(fn)(prob.g, prob.w_local, prob.mask, seed_boxes, aux)
    return float(lmin), float(lmax)


def dist_lambda_max(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    *,
    power_iters: int = 12,
    local_op: Callable[..., jax.Array] | None = None,
    two_phase: bool = False,
) -> float:
    """Eagerly estimate λ_max(D⁻¹A) once at setup time (raw, no safety
    factor).  Pass the result to ``dist_cg(..., lmax=...)`` so repeated
    Chebyshev solves don't re-run the power iteration inside the compiled
    program (keeps benchmark timings pure solve)."""
    op = local_op or local_poisson
    spec = P(prob.axis_name)
    seed_boxes = jnp.asarray(seed_values(_box_global_indices(prob)), prob.dtype)
    if prob.bc_mask is not None:
        seed_boxes = seed_boxes * prob.bc_mask.astype(seed_boxes.dtype)
    aux = tuple(x for x in (prob.screen, prob.bc_mask) if x is not None)
    has_screen = prob.screen is not None
    has_bc = prob.bc_mask is not None

    def shard_fn(g_s, w_s, mask_s, seed_s, aux_s):
        g1, w1, m1 = g_s[0], w_s[0], mask_s[0]
        s1 = aux_s[0][0] if has_screen else None
        bcm1 = aux_s[1 if has_screen else 0][0] if has_bc else None
        base = lambda v: _apply_assembled(
            prob, v, g1, w1, local_op=op, two_phase=two_phase, screen=s1
        )
        operator = base if bcm1 is None else (
            lambda v: bcm1 * base(bcm1 * v)
        )
        dinv = _box_dinv(prob, g1, w1, screen=s1)
        if bcm1 is not None:
            dinv = bcm1 * dinv
        mdot = lambda a, bb: jnp.vdot(a * m1, bb)
        return power_lambda_max(
            operator, dinv, seed_s[0],
            iters=power_iters, dot=mdot,
            psum=lambda v: lax.psum(v, prob.axis_name),
        )

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, tuple(spec for _ in aux)),
        out_specs=P(),
        # old jax's check_rep cannot type the power-iteration scan carry
        # (sharded iterate + replicated psum-derived norm)
        check_rep=False,
    )
    return float(
        jax.jit(fn)(prob.g, prob.w_local, prob.mask, seed_boxes, aux)
    )


def dist_cg(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    b: jax.Array,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    precond: str = "none",
    cheb_degree: int = 2,
    lanczos_iters: int = 10,
    lmax: float | None = None,
    lmin: float | None = None,
    pmg_smooth_degree: int | None = None,
    pmg_smoother: str = "chebyshev",
    pmg_coarse_op: str = "redisc",
    pmg_coarse_iters: int = 16,
    pmg_ladder: tuple[int, ...] | None = None,
    schwarz_overlap: int = 1,
    schwarz_inner_degree: int = SCHWARZ_INNER_DEGREE,
    precond_dtype: Any = None,
    cg_variant: str = "standard",
    local_op: Callable[..., jax.Array] | None = None,
    fused_operator: bool | None = None,
    two_phase: bool = False,
    exchange: str | None = None,
    exchange_wire: str = "native",
    exchange_plan: Any = None,
    vcycle_overlap: bool | None = None,
    record_history: bool = False,
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
    per_rank_stats: bool = False,
):
    """Distributed hipBone (P)CG over the device mesh.

    Args:
      prob: the sharded problem (``build_dist_problem``).
      mesh: jax device mesh whose flattened size equals ``prob.grid.size``.
      b: (R, m3) sharded right-hand side boxes (made consistent here).
      n_iter: iteration cap (NekBone's fixed count when ``tol`` is None).
      tol: optional relative-residual stopping threshold (while_loop mode).
      precond: "none" | "jacobi" | "chebyshev" | "schwarz" | "pmg".
      cheb_degree: standalone-Chebyshev polynomial degree.
      lanczos_iters: in-graph Lanczos steps for Chebyshev intervals.
      lmax / lmin: pre-estimated spectrum bounds (from ``dist_spectrum``)
        — passing them keeps the estimation out of the compiled solve;
        ``lmax`` alone falls back to the legacy λ_max/30 interval bottom.
      pmg_smooth_degree: Chebyshev stages per pMG smoothing sweep (default:
        4 for the Jacobi base, 2 for the Schwarz base).
      pmg_smoother: "chebyshev" (Chebyshev–Jacobi) or "schwarz"
        (Chebyshev-accelerated overlapping Schwarz on every smoothed
        level — the nekRS configuration).
      pmg_coarse_op: "redisc" (default) rediscretizes every coarse level;
        "galerkin_mat" applies the variationally-exact PᵀAP coarse
        operators as materialized per-element blocks
        (``build_pmg_galerkin_blocks``): assembly is rank-local at setup
        (no extra exchange — ``w_local`` already carries the global
        inverse degree), and each coarse apply is one batched element
        matvec riding the standard halo/interior split + sum-exchange
        (``_box_galerkin_apply``) — matching the single-device
        ``make_pmg_preconditioner(coarse_op="galerkin_mat")``
        iteration-for-iteration, including under ``precond_dtype``.  The
        *chained* "galerkin" form stays single-device (its coarse applies
        recurse to the fine grid) and raises here.
      pmg_coarse_iters: degree of the coarsest-level full-interval Chebyshev.
      pmg_ladder: explicit degree ladder (default N → ⌈N/2⌉ → … → 1).
      schwarz_overlap / schwarz_inner_degree: overlapping-Schwarz knobs
        (extension width in GLL nodes; in-eigenbasis block-solve degree) for
        ``precond="schwarz"`` and ``pmg_smoother="schwarz"``.
      precond_dtype: compute dtype of the whole preconditioner chain
        (default None = ``prob.dtype``).  With fp32 inside an fp64 solve,
        every preconditioner ingredient — A-applies, diagonals, Schwarz
        FDM fields, every coarse pMG level and transfer — runs on fp32
        boxes, so *all* preconditioner halo payloads (sum/copy/expand/
        contract exchanges, coarse-level included) are fp32 on the wire
        while the outer fp64 recurrence keeps tol=1e-8 reachable.  One
        cast boundary wraps the apply; the outer operator and its halo
        exchange stay fp64.  Pair with ``cg_variant="flexible"``.
      cg_variant: "standard" (Fletcher–Reeves β) or "flexible"
        (Polak–Ribière β; robust when M⁻¹ is only fp32-symmetric — see
        core.cg).
      local_op: optional Pallas element kernel replacing the jnp reference.
      fused_operator: run the outer operator's interior block through the
        single-pass fused assembled kernel
        (``kernels.ops.poisson_assembled_fused`` — gather, local op and
        scatter-add in one Pallas pass over the rank-local box) instead of
        the split pipeline.  ``None`` defers to
        ``kernels.ops.should_fuse_operator`` (native-Pallas backend + VMEM
        fit; ``HIPBONE_FUSED=0/1`` forces), except when an explicit
        ``local_op`` pins the split pipeline.  Preconditioner-internal
        A-applies keep the split form — they run in ``precond_dtype`` and
        their traffic is not the Eq. 4 bound this kernel targets.
      two_phase: paper-faithful two-phase exchange instead of the fused one.
      exchange: halo-exchange policy — "face_sweep" (per-dim sweep, the
        default), "crystal" (staged bidirectional route), "fused"
        (one-round diagonal route), or "auto" (time every candidate per
        exchange *site* at setup and pick winners; persisted, see
        ``comms.plan``).  ``None`` defers to ``HIPBONE_EXCHANGE``.  Every
        routing reproduces the face sweep's IEEE reduction tree
        bit-for-bit at the native wire, so PCG iteration counts are
        identical whatever the policy says.
      exchange_wire: wire-dtype axis of the "auto" search — "native"
        (default; keeps the bit-identity guarantee), "auto" (adds
        fp32-on-the-wire candidates for fp64 boxes; replica-consistent
        but moves rounding points), or a concrete dtype name.
      exchange_plan: inject a pre-built ``comms.plan.ExchangePlan``
        (skips plan resolution entirely — benchmarks reuse one plan
        across solver variants).
      vcycle_overlap: cross-level exchange/compute overlap in the pMG
        V-cycle — coarse-level smoothers and fine-level post-smooth
        residuals start their interior element work from the *raw*
        (pre-exchange) transfer boxes, releasing each level's halo
        exchange to overlap the neighbouring level's compute
        (``precond.make_vcycle_overlapped``; bit-identical by
        construction).  ``None`` defers to ``HIPBONE_VCYCLE_OVERLAP``
        (default on).
      record_history: carry the per-iteration ‖r‖² history buffer.
      divergence_factor / stagnation_window / stagnation_rtol: in-loop
        breakdown-detector knobs (see ``core.cg.SolveStatus``); every
        detector input is one of the already-psum'd recurrence scalars, so
        the failure flag is replica-consistent by construction and all
        ranks exit the tolerance-mode loop on the same iteration with the
        same status — no extra collective rides the loop.
      per_rank_stats: return ``iterations`` and ``status`` as per-rank
        (R,)-sharded arrays instead of replicated scalars — observability
        hook for asserting the lockstep-exit property (the slow halo-
        corruption test uses it); the values are identical across ranks.

    Variable coefficients thread through every rung: a k(x) field is
    already folded into ``prob.g`` at build time (nothing to do here), a
    λ(x) field swaps the weak mass screen ``prob.screen`` onto the w
    stream of every A-apply/diagonal/Galerkin block and switches the
    Schwarz blocks to per-element mean-λ mass screens, and Dirichlet
    faces wrap the operator and every preconditioner ingredient in
    ``prob.bc_mask`` (mask∘f∘mask — SPD on the interior subspace by
    congruence), with spectrum-estimation seeds masked per level.  The
    caller is expected to pass a bc-masked ``b`` (the same contract as
    the single-device ``poisson_assembled`` path), and the result then
    matches the single-device solve iteration-for-iteration, including
    under ``precond_dtype``.

    The Jacobi diagonal is assembled in padded-box storage — local element
    diagonals gathered with Z_loc^T then made consistent by one
    sum-exchange — so its apply is a pure elementwise scale (replicas stay
    consistent for free).  Chebyshev A-applies reuse the
    communication-hiding split operator, and the Lanczos spectrum
    estimation runs with replica-masked inner products; its seed vector is
    a hash of *global* DOF indices, hence consistent across replicas by
    construction.

    ``precond="schwarz"`` runs symmetric weighted overlapping Schwarz with
    the overlap transported by ``comms.halo.expand_exchange`` /
    ``contract_exchange`` shells; interior element blocks read only the
    original box, so their solves hide the shell exchange exactly like the
    operator's Fig. 2 split (see ``_box_schwarz_apply``).

    ``precond="pmg"`` runs the degree-ladder V-cycle of ``core.precond``
    with every level's A-apply, transfer, diagonal and Schwarz blocks
    assembled through this rank's *coarsened* padded box — coarse-level
    applies are latency-dominated, so the halo/interior overlap matters
    most there.  The coarsest (degree-1) level is solved by a
    full-interval degree-``pmg_coarse_iters`` Chebyshev.

    Returns:
      A jitted-callable partial () -> (x, rdotr, iterations, status,
      history) — ``status`` is the jit-safe ``core.cg.SolveStatus`` code —
      also usable for dry-run lowering via
      ``jax.jit(run.func).lower(*run.args)``.
    """
    if precond not in PRECOND_KINDS:
        raise ValueError(f"unknown precond {precond!r}; choose from {PRECOND_KINDS}")
    if pmg_smoother not in PMG_SMOOTHERS:
        raise ValueError(
            f"unknown pmg smoother {pmg_smoother!r}; choose from {PMG_SMOOTHERS}"
        )
    if pmg_coarse_op not in PMG_COARSE_OPS_DIST:
        raise NotImplementedError(
            f"dist_cg pmg_coarse_op={pmg_coarse_op!r}: the chained Galerkin "
            "form is single-device only (make_pmg_preconditioner) — its "
            "coarse applies recurse to the fine grid; use the materialized "
            "'galerkin_mat' for the sharded variationally-exact V-cycle, "
            f"or one of {PMG_COARSE_OPS_DIST}"
        )
    if cg_variant not in CG_VARIANTS:
        raise ValueError(
            f"unknown cg_variant {cg_variant!r}; choose from {CG_VARIANTS}"
        )
    if pmg_smooth_degree is None:
        pmg_smooth_degree = pmg_smooth_degree_default(pmg_smoother)
    op = local_op or local_poisson
    if fused_operator is None:
        if local_op is not None:
            fused_operator = False
        else:
            from ..kernels import ops as _kops  # lazy: kernels import core

            fused_operator = _kops.should_fuse_operator(
                prob.dtype, n_degree=prob.n_degree, n_global=prob.m3
            )
    spec = P(prob.axis_name)
    hist_len = n_iter

    # Mixed precision: the preconditioner chain is built from a cast *view*
    # of the problem (pprob) — its d matrix and every coarse pMG level /
    # Schwarz FDM field carry cdtype, so preconditioner boxes (and hence
    # every preconditioner halo payload) live in cdtype end to end.  The
    # fine-level sharded g/w are cast once inside the compiled program.
    cdtype = jnp.dtype(prob.dtype if precond_dtype is None else precond_dtype)
    mixed = cdtype != jnp.dtype(prob.dtype)
    pprob = prob if not mixed else dataclasses.replace(
        prob, d=prob.d.astype(cdtype), dtype=cdtype
    )

    # variable-coefficient state: static presence flags (shard_map pytree
    # specs must be static, so optional arrays ride conditional tuples) —
    # coarse pMG levels inherit both fields from the fine problem, so one
    # flag pair covers every level
    has_screen = prob.screen is not None
    has_bc = prob.bc_mask is not None

    def _masked_seed(lvl: DistPoisson) -> jax.Array:
        """Spectrum-estimation seed for one level, Dirichlet rows zeroed
        (mirrors precond.masked_seed — Lanczos stays on the subspace)."""
        sd = jnp.asarray(seed_values(_box_global_indices(lvl)), cdtype)
        if lvl.bc_mask is None:
            return sd
        return sd * lvl.bc_mask.astype(cdtype)

    need_power = (precond == "chebyshev" and lmax is None) or precond == "pmg"
    # the seeds only feed preconditioner spectrum estimation -> cdtype
    seed_boxes = (
        _masked_seed(prob) if need_power
        else jnp.zeros((prob.grid.size, 1), cdtype)
    )

    if precond == "pmg":
        levels, jmats = build_pmg_levels(pprob, pmg_ladder)
        jmats = [jnp.asarray(j, cdtype) for j in jmats]
        # materialized Galerkin: per-rank block assembly at setup (pprob is
        # the cast view when mixed, so blocks are assembled once in cdtype)
        gal_blocks = (
            build_pmg_galerkin_blocks(pprob, levels)
            if pmg_coarse_op == "galerkin_mat"
            else [() for _ in levels[1:]]
        )
        pmg_data = tuple(
            (
                lvl.g,
                lvl.w_local,
                lvl.mask,
                _masked_seed(lvl),
            )
            + ((lvl.screen,) if has_screen else ())
            + ((lvl.bc_mask,) if has_bc else ())
            + ((blk,) if pmg_coarse_op == "galerkin_mat" else ())
            for lvl, blk in zip(levels[1:], gal_blocks)
        )
    else:
        levels, jmats, pmg_data = [pprob], [], ()
    # fine-level optional arrays ride their own conditional tuple
    aux_data = tuple(
        x for x in (prob.screen, prob.bc_mask) if x is not None
    )

    # Schwarz setup: one _SchwarzDist per level that smooths with it —
    # level 0 for the standalone kind (overlap validated like the
    # single-device path), every smoothed level for the Schwarz-smoothed
    # V-cycle (overlap clamped to each level's degree, matching
    # make_pmg_preconditioner).  Sharded FDM fields ride the shard_map
    # arguments; static index maps stay in the closure.
    if precond == "schwarz":
        schwarz_setups = [
            _schwarz_setup(pprob, schwarz_overlap, schwarz_inner_degree)
        ]
    elif precond == "pmg" and pmg_smoother == "schwarz":
        schwarz_setups = [
            _schwarz_setup(
                lvl,
                min(schwarz_overlap, lvl.n_degree - 1),
                schwarz_inner_degree,
            )
            for lvl in levels[:-1]
        ]
    else:
        schwarz_setups = []
    schwarz_data = tuple(
        sd.fdm_fields + (sd.wsqrt,) for sd in schwarz_setups
    )

    # Exchange plan: resolve one (routing, wire) pick per halo site.  A
    # forced policy resolves instantly; "auto" times candidates per site
    # class at first setup and loads the persisted plan afterwards.  The
    # picks are static python strings, so each policy traces to its own
    # compiled program with the chosen ppermute schedule baked in.
    if exchange_plan is None:
        exchange_plan = xplan.build_exchange_plan(
            mesh, prob.grid, prob.axis_name,
            _exchange_sites(prob, levels, schwarz_setups, two_phase=two_phase),
            policy=exchange, wire=exchange_wire,
        )
    xsum = [exchange_plan.lookup("sum", i) for i in range(len(levels))]
    xcopy = [exchange_plan.lookup("copy", i) for i in range(len(levels))]
    xexp = [
        exchange_plan.lookup("expand", i) for i in range(len(schwarz_setups))
    ]
    xcon = [
        exchange_plan.lookup("contract", i) for i in range(len(schwarz_setups))
    ]
    if vcycle_overlap is None:
        vcycle_overlap = os.environ.get("HIPBONE_VCYCLE_OVERLAP", "1") != "0"

    def shard_fn(b_s, g_s, w_s, mask_s, seed_s, aux_s, pmg_s, schwarz_s):
        b1, g1, w1, m1 = b_s[0], g_s[0], w_s[0], mask_s[0]
        # make rhs consistent (replicas hold true values)
        b1 = copy_exchange(
            b1.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
            xcopy[0][1], xcopy[0][0],
        ).reshape(-1)
        s1 = aux_s[0][0] if has_screen else None
        bcm1 = aux_s[1 if has_screen else 0][0] if has_bc else None

        def _bc_wrap(bm, f):
            """mask∘f∘mask on the Dirichlet subspace — both the operator
            (congruence keeps it SPD there) and every preconditioner
            ingredient, mirroring the single-device ``poisson_assembled`` /
            ``precond._mask_wrap`` contract.  The optional deferred raw
            twin is masked too: the mask is elementwise and the exchange
            only rewrites face slabs, so the masked raw stays a
            bitwise-valid interior gather source."""
            if bm is None:
                return f
            def wrapped(v, raw=None):
                if raw is None:
                    return bm * f(bm * v)
                return bm * f(bm * v, bm * raw)
            return wrapped

        operator = _bc_wrap(bcm1, lambda v: _apply_assembled(
            prob, v, g1, w1, local_op=op, two_phase=two_phase,
            fused_interior=fused_operator, xsum=xsum[0], xcopy=xcopy[0],
            screen=s1,
        ))
        psum = lambda v: lax.psum(v, prob.axis_name)

        # preconditioner-dtype views of the fine-level shards: the casts are
        # single ops reused by every M⁻¹-internal A-apply in the program
        if mixed:
            g1c, w1c, m1c = (
                g1.astype(cdtype), w1.astype(cdtype), m1.astype(cdtype)
            )
            s1c = None if s1 is None else s1.astype(cdtype)
            bcm1c = None if bcm1 is None else bcm1.astype(cdtype)
            operator_pc = _bc_wrap(bcm1c, lambda v, raw=None: _apply_assembled(
                pprob, v, g1c, w1c, local_op=op, two_phase=two_phase,
                xsum=xsum[0], xcopy=xcopy[0], x_raw=raw, screen=s1c,
            ))
        else:
            g1c, w1c, m1c = g1, w1, m1
            s1c, bcm1c = s1, bcm1
            # same program as the outer operator (fused interior included),
            # plus the optional deferred raw twin for the V-cycle overlap
            operator_pc = _bc_wrap(bcm1c, lambda v, raw=None: _apply_assembled(
                prob, v, g1, w1, local_op=op, two_phase=two_phase,
                fused_interior=fused_operator,
                xsum=xsum[0], xcopy=xcopy[0], x_raw=raw, screen=s1,
            ))

        def schwarz_apply(i: int, lvl: DistPoisson, bm):
            nf = len(schwarz_setups[i].fdm_fields)
            fields1 = tuple(f[0] for f in schwarz_s[i][:nf])
            return _bc_wrap(bm, _box_schwarz_apply(
                lvl, schwarz_setups[i], fields1, schwarz_s[i][nf][0],
                xsum=xsum[i], xexpand=xexp[i], xcontract=xcon[i],
            ))

        pc = None
        if precond != "none":
            dinv = _box_dinv(pprob, g1c, w1c, xsum[0], screen=s1c)
            if bcm1c is not None:
                dinv = bcm1c * dinv
            if precond == "jacobi":
                pc = jacobi_apply(dinv)
            elif precond == "schwarz":
                pc = schwarz_apply(0, pprob, bcm1c)
            elif precond == "chebyshev":
                if lmax is None:
                    mdot = lambda a, bb: jnp.vdot(a * m1c, bb)
                    lmin_e, lmax_e = lanczos_extremes(
                        operator_pc, dinv, seed_s[0],
                        iters=lanczos_iters, dot=mdot, psum=psum,
                    )
                    top = CHEB_SAFETY * lmax_e
                    low = CHEB_LMIN_SAFETY * lmin_e
                else:
                    top = CHEB_SAFETY * jnp.asarray(lmax, cdtype)
                    low = None if lmin is None else (
                        CHEB_LMIN_SAFETY * jnp.asarray(lmin, cdtype)
                    )
                pc = chebyshev_apply(
                    operator_pc, dinv, top, lmin=low, degree=cheb_degree
                )
            else:  # pmg
                lvl_ops = [operator_pc]
                lvl_dinvs = [dinv]
                lvl_masks = [m1c]
                lvl_seeds = [seed_s[0]]
                lvl_wlocs = [w1c]
                lvl_bcms = [bcm1c]
                for li, (lvl, data_l) in enumerate(
                    zip(levels[1:], pmg_s), start=1
                ):
                    g_l, w_l, mk_l, sd_l = data_l[:4]
                    ix = 4
                    scr_l = None
                    if has_screen:
                        scr_l = data_l[ix][0]
                        ix += 1
                    bcm_l = None
                    if has_bc:
                        bcm_l = data_l[ix][0]
                        ix += 1
                    g1l, w1l = g_l[0], w_l[0]
                    if pmg_coarse_op == "galerkin_mat":
                        # materialized P^T A P apply: batched element
                        # matvec + the standard sum-exchange, zero
                        # fine-operator work per coarse apply; the bc wrap
                        # uses this level's own mask (R = Pᵀ smears
                        # interior residual onto coarse Dirichlet rows)
                        lvl_ops.append(_bc_wrap(
                            bcm_l,
                            _box_galerkin_apply(
                                lvl, data_l[ix][0], two_phase=two_phase,
                                xsum=xsum[li], xcopy=xcopy[li],
                            ),
                        ))
                    else:
                        lvl_ops.append(_bc_wrap(
                            bcm_l,
                            lambda v, raw=None, lvl=lvl, g1l=g1l, w1l=w1l,
                            li=li, scr_l=scr_l:
                            _apply_assembled(
                                lvl, v, g1l, w1l, local_op=op,
                                two_phase=two_phase,
                                xsum=xsum[li], xcopy=xcopy[li], x_raw=raw,
                                screen=scr_l,
                            ),
                        ))
                    # smoother diagonals stay the rediscretized ones for
                    # the Galerkin variants, matching the single-device path
                    dinv_l = _box_dinv(lvl, g1l, w1l, xsum[li], screen=scr_l)
                    if bcm_l is not None:
                        dinv_l = bcm_l * dinv_l
                    lvl_dinvs.append(dinv_l)
                    lvl_masks.append(mk_l[0])
                    lvl_seeds.append(sd_l[0])
                    lvl_wlocs.append(w1l)
                    lvl_bcms.append(bcm_l)
                # every lvl_ops entry accepts (v, raw=None); the pair form
                # feeds the overlapped V-cycle's deferred interior gathers
                lvl_ops_pair = [
                    (lambda raw, con, f=f: f(con, raw)) for f in lvl_ops
                ]

                smoothers, smoothers_pair = [], []
                for i in range(len(levels) - 1):
                    mdot = lambda a, bb, mk=lvl_masks[i]: jnp.vdot(a * mk, bb)
                    if pmg_smoother == "schwarz":
                        base = schwarz_apply(i, levels[i], lvl_bcms[i])
                    else:
                        base = lvl_dinvs[i]
                    lo, lmax_e, _ = smoother_interval(
                        lvl_ops[i], base, lvl_seeds[i],
                        smoother=pmg_smoother, lanczos_iters=lanczos_iters,
                        dot=mdot, psum=psum,
                    )
                    smooth = chebyshev_apply(
                        lvl_ops[i],
                        base,
                        CHEB_SAFETY * lmax_e,
                        lmin=lo,
                        degree=pmg_smooth_degree,
                    )
                    smoothers.append(smooth)
                    if pmg_smoother == "schwarz":
                        # Schwarz expand shells transport face values, so
                        # the base apply cannot start from the raw twin
                        smoothers_pair.append(
                            lambda raw, con, sm=smooth: sm(con)
                        )
                    else:
                        smoothers_pair.append(
                            chebyshev_apply_deferred(
                                lvl_ops[i], lvl_ops_pair[i], base,
                                CHEB_SAFETY * lmax_e, lmin=lo,
                                degree=pmg_smooth_degree,
                            )
                        )
                # coarsest (degree-1): full-interval Chebyshev "solve"
                mdot_c = lambda a, bb: jnp.vdot(a * lvl_masks[-1], bb)
                lmin_e, lmax_e = lanczos_extremes(
                    lvl_ops[-1], lvl_dinvs[-1], lvl_seeds[-1],
                    iters=lanczos_iters, dot=mdot_c, psum=psum,
                )
                coarse_apply = chebyshev_apply(
                    lvl_ops[-1],
                    lvl_dinvs[-1],
                    CHEB_SAFETY * lmax_e,
                    lmin=CHEB_LMIN_SAFETY * lmin_e,
                    degree=pmg_coarse_iters,
                )
                coarse_apply_pair = chebyshev_apply_deferred(
                    lvl_ops[-1], lvl_ops_pair[-1], lvl_dinvs[-1],
                    CHEB_SAFETY * lmax_e,
                    lmin=CHEB_LMIN_SAFETY * lmin_e,
                    degree=pmg_coarse_iters,
                )
                prolongs, restricts = [], []
                for i in range(len(levels) - 1):
                    p_up, r_down = _box_transfer_pair(
                        levels[i], levels[i + 1], jmats[i], lvl_wlocs[i],
                        xsum[i], xsum[i + 1],
                    )
                    prolongs.append(p_up)
                    restricts.append(r_down)
                if vcycle_overlap:
                    pc = make_vcycle_overlapped(
                        lvl_ops[:-1], lvl_ops_pair[:-1],
                        smoothers, smoothers_pair,
                        restricts, prolongs, coarse_apply_pair,
                    )
                else:
                    pc = make_vcycle(
                        lvl_ops[:-1], smoothers,
                        [lambda r, f=f: f(r)[1] for f in restricts],
                        [lambda z, f=f: f(z)[1] for f in prolongs],
                        coarse_apply,
                    )
        if mixed and pc is not None:
            # the one cast boundary: round r to cdtype, widen z back
            pc = cast_apply(pc, cdtype, b1.dtype)

        res = _pcg(
            operator,
            b1,
            None,
            n_iter=n_iter,
            tol=tol,
            weight=m1,
            psum=psum,
            precond=pc,
            fused_update=None,
            fused_precond_dot=None,
            record_history=record_history,
            variant=cg_variant,
            divergence_factor=divergence_factor,
            stagnation_window=stagnation_window,
            stagnation_rtol=stagnation_rtol,
        )
        hist = res.rdotr_history
        iters = jnp.asarray(res.iterations)
        status = jnp.asarray(res.status)
        if per_rank_stats:
            iters, status = iters[None], status[None]
        return (
            res.x[None],
            res.rdotr,
            iters,
            status,
            hist if hist is not None else jnp.zeros((hist_len,), b1.dtype),
        )

    stat_spec = spec if per_rank_stats else P()
    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(
            spec, spec, spec, spec, spec,
            tuple(spec for _ in aux_data),
            tuple(tuple(spec for _ in entry) for entry in pmg_data),
            tuple(tuple(spec for _ in lvl) for lvl in schwarz_data),
        ),
        out_specs=(spec, P(), stat_spec, stat_spec, P()),
        # old jax's check_rep has no rule for while_loop (tol mode) and
        # cannot type the Lanczos/power-iteration carries (in-graph spectrum
        # estimation); keep the guard wherever it can actually run — its
        # replicated outputs are psum-derived either way
        check_rep=tol is None and not need_power and precond != "schwarz",
    )
    run = functools.partial(
        fn, b, prob.g, prob.w_local, prob.mask, seed_boxes, aux_data,
        pmg_data, schwarz_data,
    )
    # observability: benchmarks/tests read the resolved plan off the handle
    run.exchange_plan = exchange_plan
    return run


def dist_cg_scattered(
    prob: DistPoisson,
    mesh: jax.sharding.Mesh,
    b_l: jax.Array,
    *,
    n_iter: int = 100,
    tol: float | None = None,
    precond: str = "none",
    cheb_degree: int = 2,
    lanczos_iters: int = 10,
    lmax: float | None = None,
    lmin: float | None = None,
    precond_dtype: Any = None,
    cg_variant: str = "standard",
    local_op: Callable[..., jax.Array] | None = None,
    exchange: str | None = None,
    exchange_wire: str = "native",
    exchange_plan: Any = None,
    divergence_factor: float | None = DIVERGENCE_FACTOR,
    stagnation_window: int | None = STAGNATION_WINDOW,
    stagnation_rtol: float = STAGNATION_RTOL,
):
    """Distributed NekBone baseline: scattered (R, E_loc, p) vectors.

    Operator: b = ZZ^T S_L x + λ x  (gather-scatter through the padded box
    + sum exchange); weighted inner products read the W stream, exactly the
    extra traffic the paper charges against NekBone.

    Args:
      prob / mesh: as in :func:`dist_cg`.
      b_l: (R, E_loc, p) *consistent* scattered right-hand side (NekBone
        gather-scatters its random forcing at setup; applying ZZ^T here
        would alter a general rhs).
      n_iter / tol / cheb_degree / lanczos_iters / lmax / lmin / local_op:
        as in :func:`dist_cg`.
      precond: "none" | "jacobi" | "chebyshev" — the assembled-only rungs
        (schwarz and p-multigrid live on assembled storage, where block
        solves and transfers are single gathers; the paper's argument for
        assembled storage applies doubly to preconditioning).
      precond_dtype / cg_variant: as in :func:`dist_cg` — an fp32
        Jacobi/Chebyshev chain (scattered fields, gather-scatter boxes and
        their exchanges all in fp32) behind one cast boundary, with the
        flexible (Polak–Ribière) β available for robustness.
      exchange / exchange_wire / exchange_plan: as in :func:`dist_cg` —
        here there is exactly one site, the gather-scatter sum-exchange.

    The assembled diagonal is built in padded-box storage and scattered to
    the element-local layout; on the continuous subspace (range of Z,
    where the scattered iterates live) the diagonal scale and the
    Chebyshev polynomial act exactly as their assembled counterparts, so
    weighted-dot PCG remains valid.

    Returns:
      A jitted-callable partial () -> (x, rdotr, iterations, status) — note
      the 4-tuple, unlike :func:`dist_cg`'s 5-tuple with history.
      ``status`` is the ``core.cg.SolveStatus`` code; the detector knobs
      (``divergence_factor`` / ``stagnation_window`` / ``stagnation_rtol``)
      behave as in :func:`dist_cg`.
    """
    if precond not in ("none", "jacobi", "chebyshev"):
        raise ValueError(
            f"dist_cg_scattered supports none|jacobi|chebyshev, got {precond!r}"
        )
    if prob.lam_field is not None or prob.bc_mask is not None:
        # the scattered baseline mirrors NekBone's constant-λ pure-Neumann
        # problem; a k-folded g is transparent here, but the weak λ(x)
        # screen and Dirichlet masking live on assembled storage only
        raise NotImplementedError(
            "dist_cg_scattered supports only the constant-λ problem without "
            "Dirichlet faces; use dist_cg for variable λ(x) or bc masks"
        )
    if cg_variant not in CG_VARIANTS:
        raise ValueError(
            f"unknown cg_variant {cg_variant!r}; choose from {CG_VARIANTS}"
        )
    op = local_op or local_poisson
    spec = P(prob.axis_name)
    l2g_flat = jnp.asarray(prob.l2g.reshape(-1))
    m3 = prob.m3
    cdtype = jnp.dtype(prob.dtype if precond_dtype is None else precond_dtype)
    mixed = cdtype != jnp.dtype(prob.dtype)
    pprob = prob if not mixed else dataclasses.replace(
        prob, d=prob.d.astype(cdtype), dtype=cdtype
    )
    d_pc = pprob.d

    need_lanczos = precond == "chebyshev" and lmax is None
    seed_boxes = jnp.asarray(
        seed_values(_box_global_indices(prob)), cdtype
    ) if need_lanczos else jnp.zeros((prob.grid.size, 1), cdtype)

    if exchange_plan is None:
        exchange_plan = xplan.build_exchange_plan(
            mesh, prob.grid, prob.axis_name,
            [
                xplan.ExchangeSite(
                    "sum", 0, tuple(prob.box_shape[::-1]),
                    jnp.dtype(prob.dtype).name,
                )
            ],
            policy=exchange, wire=exchange_wire,
        )
    xs = exchange_plan.lookup("sum", 0)

    def gather_scatter(y_l):
        box = jax.ops.segment_sum(y_l.reshape(-1), l2g_flat, num_segments=m3)
        box = sum_exchange(
            box.reshape(prob.box_shape[::-1]), prob.grid, prob.axis_name,
            xs[1], xs[0],
        ).reshape(-1)
        return jnp.take(box, l2g_flat, axis=0).reshape(y_l.shape)

    def shard_fn(b_s, g_s, w_s, seed_s):
        # caller passes a consistent b_L (NekBone gather-scatters its random
        # forcing at setup; applying ZZ^T here would alter a general rhs)
        b1, g1, w1 = b_s[0], g_s[0], w_s[0]
        psum = lambda v: lax.psum(v, prob.axis_name)

        def operator(x_l):
            s = op(x_l, g1, prob.d, 0.0, None)
            return gather_scatter(s) + prob.lam * x_l

        # preconditioner-dtype operator: fp32 local fields, fp32
        # gather-scatter boxes (hence fp32 exchange payloads) when mixed
        if mixed:
            g1c, w1c = g1.astype(cdtype), w1.astype(cdtype)

            def operator_pc(x_l):
                s = op(x_l, g1c, d_pc, 0.0, None)
                return gather_scatter(s) + jnp.asarray(prob.lam, cdtype) * x_l

        else:
            g1c, w1c = g1, w1
            operator_pc = operator

        pc = None
        if precond != "none":
            # assembled diag in box storage, scattered to the local layout:
            # Z diag(A)⁻¹ — consistent on the continuous subspace for free
            dinv_l = jnp.take(
                _box_dinv(pprob, g1c, w1c), l2g_flat, axis=0
            ).reshape(b1.shape)
            if precond == "jacobi":
                pc = jacobi_apply(dinv_l)
            else:
                wdot = lambda a, bb: jnp.vdot(a * w1c, bb)
                if lmax is None:
                    seed_l = jnp.take(seed_s[0], l2g_flat, axis=0).reshape(
                        b1.shape
                    )
                    lmin_e, lmax_e = lanczos_extremes(
                        operator_pc, dinv_l, seed_l,
                        iters=lanczos_iters, dot=wdot, psum=psum,
                    )
                    top = CHEB_SAFETY * lmax_e
                    low = CHEB_LMIN_SAFETY * lmin_e
                else:
                    top = CHEB_SAFETY * jnp.asarray(lmax, cdtype)
                    low = None if lmin is None else (
                        CHEB_LMIN_SAFETY * jnp.asarray(lmin, cdtype)
                    )
                pc = chebyshev_apply(
                    operator_pc, dinv_l, top, lmin=low, degree=cheb_degree
                )
            if mixed:
                pc = cast_apply(pc, cdtype, b1.dtype)

        res = _pcg(
            operator,
            b1,
            None,
            n_iter=n_iter,
            tol=tol,
            weight=w1,
            psum=psum,
            precond=pc,
            fused_update=None,
            fused_precond_dot=None,
            record_history=False,
            variant=cg_variant,
            divergence_factor=divergence_factor,
            stagnation_window=stagnation_window,
            stagnation_rtol=stagnation_rtol,
        )
        return (
            res.x[None],
            res.rdotr,
            jnp.asarray(res.iterations),
            jnp.asarray(res.status),
        )

    fn = shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(spec, spec, spec, spec),
        out_specs=(spec, P(), P(), P()),
        # same check_rep caveats as dist_cg: while_loop (tol mode) and the
        # Lanczos carry have no replication rule on old jax
        check_rep=tol is None and not need_lanczos,
    )
    run = functools.partial(fn, b_l, prob.g, prob.w_local, seed_boxes)
    run.exchange_plan = exchange_plan
    return run
